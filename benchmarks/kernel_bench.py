"""Kernel microbenchmarks: us/call of the jnp reference paths on this CPU
host (the Pallas kernels target TPU; interpret-mode timing is not meaningful)
plus derived arithmetic intensities from the kernel's tile math, plus the
xla-vs-fused NMP hot-loop comparison consumed by ``BENCH_segment_agg.json``.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.segment_agg.ref import edge_mlp_agg_ref
from repro.kernels.embedding_bag.ref import embedding_bag_ref


def _time(fn, *args, iters=20):
    # one warmup call: compiles once, and its result tells us how to block
    out = fn(*args)
    (out[0] if isinstance(out, tuple) else out).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def segment_agg_compare(block_n: int = 32, block_e: int = 64,
                        hidden: int = 16) -> dict:
    """xla-vs-fused NMP edge-update+aggregate on a real SEM mesh graph.

    The fused path runs the production Pallas kernels — compiled on TPU,
    through the interpreter elsewhere (flagged; interpreter timings are not
    comparable to compiled ones, but the consistency check is exact either
    way).  Asserts fp32-level agreement of both outputs against the XLA
    lowering and reports the dst-aligned layout's padding-waste fraction.
    """
    from repro.core import box_mesh, partition_mesh
    from repro.core.consistent_mp import edge_update_aggregate, init_nmp_layer
    from repro.core.reference import rank_static_inputs

    interpret = jax.default_backend() != "tpu"
    mesh = box_mesh((4, 4, 2), p=2)
    pg = partition_mesh(mesh, (1, 1, 1))
    meta = rank_static_inputs(pg, mesh.coords, seg_layout=(block_n, block_e))
    meta_r = {k: v[0] for k, v in meta.items()}
    waste = pg.segment_layout(block_n, block_e)["waste"]

    rng = np.random.default_rng(0)
    params = init_nmp_layer(jax.random.PRNGKey(0), hidden, 2)
    x = jnp.asarray(rng.normal(size=(pg.n_pad, hidden)), jnp.float32)
    e = jnp.asarray(rng.normal(size=(pg.e_pad, hidden)), jnp.float32)

    xla_fn = jax.jit(lambda p, x, e: edge_update_aggregate(
        p, x, e, meta_r, backend="xla"))
    fused_fn = jax.jit(lambda p, x, e: edge_update_aggregate(
        p, x, e, meta_r, backend="fused", interpret=interpret,
        block_n=block_n))

    e_x, a_x = xla_fn(params, x, e)
    e_f, a_f = fused_fn(params, x, e)
    err_e = float(jnp.abs(e_x - e_f).max())
    err_a = float(jnp.abs(a_x - a_f).max())
    assert err_e < 1e-4 and err_a < 1e-4, (err_e, err_a)

    iters = 3 if interpret else 20
    xla_us = _time(xla_fn, params, x, e, iters=iters)
    fused_us = _time(fused_fn, params, x, e, iters=iters)
    return dict(
        n_nodes=pg.n_pad, n_edges=pg.e_pad, hidden=hidden,
        block_n=block_n, block_e=block_e,
        xla_us=xla_us, fused_us=fused_us,
        fused_interpret=interpret, backend=jax.default_backend(),
        layout_waste=waste, max_abs_err_e=err_e, max_abs_err_agg=err_a,
    )


def run(verbose: bool = True, seg_cmp: dict | None = None):
    """``seg_cmp``: pass a precomputed ``segment_agg_compare()`` payload to
    avoid re-running the (interpret-mode-slow) comparison twice."""
    rows = []
    rng = np.random.default_rng(0)

    B, H, S, D = 1, 4, 1024, 64
    q = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    fa = jax.jit(lambda q, k, v: attention_ref(q, k, v, scale=D ** -0.5))
    us = _time(fa, q, q, q)
    flops = 4 * B * H * S * S * D
    rows.append(("flash_attention_ref_1k", us, f"gflops={flops/1e9:.2f}"))

    E, N, FIN, HID = 8192, 2048, 24, 16
    feats = jnp.asarray(rng.normal(size=(E, FIN)), jnp.float32)
    dst = jnp.asarray(rng.integers(0, N, E), jnp.int32)
    w = jnp.ones(E)
    w1 = jnp.asarray(rng.normal(size=(FIN, HID)), jnp.float32)
    b1 = jnp.zeros(HID)
    w2 = jnp.asarray(rng.normal(size=(HID, HID)), jnp.float32)
    b2 = jnp.zeros(HID)
    sa = jax.jit(lambda f: edge_mlp_agg_ref(f, w1, b1, w2, b2, dst, w, N))
    us = _time(sa, feats)
    rows.append(("segment_agg_ref_8k_edges", us,
                 f"gflops={2*E*(FIN*HID+HID*HID)/1e9:.3f}"))

    V, D2, Bb, bag = 100_000, 64, 4096, 4
    table = jnp.asarray(rng.normal(size=(V, D2)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, V, (Bb, bag)), jnp.int32)
    eb = jax.jit(embedding_bag_ref)
    us = _time(eb, table, idx)
    rows.append(("embedding_bag_ref_4k_bags", us,
                 f"gbytes={(Bb*bag*D2*4)/1e9:.4f}"))

    cmp = seg_cmp if seg_cmp is not None else segment_agg_compare()
    tag = "interp" if cmp["fused_interpret"] else cmp["backend"]
    rows.append(("nmp_edge_agg_xla", cmp["xla_us"],
                 f"waste={cmp['layout_waste']:.3f}"))
    rows.append((f"nmp_edge_agg_fused_{tag}", cmp["fused_us"],
                 f"err={max(cmp['max_abs_err_e'], cmp['max_abs_err_agg']):.1e}"))

    if verbose:
        for r in rows:
            print(f"{r[0]}: {r[1]:.0f} us  ({r[2]})")
    return rows


if __name__ == "__main__":
    run()
