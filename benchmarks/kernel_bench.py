"""Kernel microbenchmarks: us/call of the jnp reference paths on this CPU
host (the Pallas kernels target TPU; interpret-mode timing is not meaningful)
plus derived arithmetic intensities from the kernel's tile math.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.segment_agg.ref import edge_mlp_agg_ref
from repro.kernels.embedding_bag.ref import embedding_bag_ref


def _time(fn, *args, iters=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run(verbose: bool = True):
    rows = []
    rng = np.random.default_rng(0)

    B, H, S, D = 1, 4, 1024, 64
    q = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    fa = jax.jit(lambda q, k, v: attention_ref(q, k, v, scale=D ** -0.5))
    us = _time(fa, q, q, q)
    flops = 4 * B * H * S * S * D
    rows.append(("flash_attention_ref_1k", us, f"gflops={flops/1e9:.2f}"))

    E, N, FIN, HID = 8192, 2048, 24, 16
    feats = jnp.asarray(rng.normal(size=(E, FIN)), jnp.float32)
    dst = jnp.asarray(rng.integers(0, N, E), jnp.int32)
    w = jnp.ones(E)
    w1 = jnp.asarray(rng.normal(size=(FIN, HID)), jnp.float32)
    b1 = jnp.zeros(HID)
    w2 = jnp.asarray(rng.normal(size=(HID, HID)), jnp.float32)
    b2 = jnp.zeros(HID)
    sa = jax.jit(lambda f: edge_mlp_agg_ref(f, w1, b1, w2, b2, dst, w, N))
    us = _time(sa, feats)
    rows.append(("segment_agg_ref_8k_edges", us,
                 f"gflops={2*E*(FIN*HID+HID*HID)/1e9:.3f}"))

    V, D2, Bb, bag = 100_000, 64, 4096, 4
    table = jnp.asarray(rng.normal(size=(V, D2)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, V, (Bb, bag)), jnp.int32)
    eb = jax.jit(embedding_bag_ref)
    us = _time(eb, table, idx)
    rows.append(("embedding_bag_ref_4k_bags", us,
                 f"gbytes={(Bb*bag*D2*4)/1e9:.4f}"))

    if verbose:
        for r in rows:
            print(f"{r[0]}: {r[1]:.0f} us  ({r[2]})")
    return rows


if __name__ == "__main__":
    run()
