"""Kernel microbenchmarks: us/call of the jnp reference paths on this CPU
host (the Pallas kernels target TPU; interpret-mode timing is not meaningful)
plus derived arithmetic intensities from the kernel's tile math, plus the
xla-vs-fused NMP hot-loop comparison consumed by ``BENCH_segment_agg.json``.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.segment_agg.ref import edge_mlp_agg_ref
from repro.kernels.embedding_bag.ref import embedding_bag_ref


def _time(fn, *args, iters=20):
    # one warmup call: compiles once, and its result tells us how to block
    out = fn(*args)
    (out[0] if isinstance(out, tuple) else out).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


#: identifies the fused kernel generation in BENCH_segment_agg.json: the
#: scalar-prefetch DMA-gather kernels (O(E) in the node count) replaced the
#: one-hot MXU gathers ("onehot_matmul", O(E·N)) of the earlier generation.
GATHER_MODE = "prefetch_dma"


def _fused_timing_key(interpret: bool) -> str:
    """Interpreter timings are not comparable to compiled ones — they get
    their own key so downstream consumers can't confuse the two (the bench
    gate only ever reads ``fused_us``)."""
    return "fused_interpret_us" if interpret else "fused_us"


def segment_agg_compare(block_n: int | None = None,
                        block_e: int | None = None,
                        hidden: int = 16) -> dict:
    """xla-vs-fused NMP edge-update+aggregate on a real SEM mesh graph.

    The fused path runs the production Pallas kernels — compiled on TPU,
    through the interpreter elsewhere.  Interpreter runs record their timing
    under ``fused_interpret_us`` (``fused_us`` means a compiled run, full
    stop), and the consistency check is exact either way.  Block sizes
    default to the static autotune table (``pick_block_sizes``; the chosen
    tile is logged in the payload).
    """
    from repro.core import NMPPlan, ShardedGraph, box_mesh, partition_mesh
    from repro.core.consistent_mp import edge_update_aggregate, init_nmp_layer
    from repro.kernels.segment_agg.ops import pick_block_sizes

    interpret = jax.default_backend() != "tpu"
    autotuned = block_n is None or block_e is None
    auto_n, auto_e = pick_block_sizes(hidden, jnp.float32)
    block_n = block_n or auto_n
    block_e = block_e or auto_e
    mesh = box_mesh((4, 4, 2), p=2)
    pg = partition_mesh(mesh, (1, 1, 1))
    plan_fused = NMPPlan(backend="fused", interpret=interpret,
                         block_n=block_n, block_e=block_e)
    plan_xla = plan_fused.replace(backend="xla")
    graph_r = ShardedGraph.build(pg, mesh.coords, plan_fused).rank(0)

    rng = np.random.default_rng(0)
    params = init_nmp_layer(jax.random.PRNGKey(0), hidden, 2)
    x = jnp.asarray(rng.normal(size=(pg.n_pad, hidden)), jnp.float32)
    e = jnp.asarray(rng.normal(size=(pg.e_pad, hidden)), jnp.float32)

    xla_fn = jax.jit(lambda p, x, e: edge_update_aggregate(
        p, x, e, graph_r, plan_xla))
    fused_fn = jax.jit(lambda p, x, e: edge_update_aggregate(
        p, x, e, graph_r, plan_fused))

    e_x, a_x = xla_fn(params, x, e)
    e_f, a_f = fused_fn(params, x, e)
    err_e = float(jnp.abs(e_x - e_f).max())
    err_a = float(jnp.abs(a_x - a_f).max())
    assert err_e < 1e-4 and err_a < 1e-4, (err_e, err_a)

    iters = 3 if interpret else 20
    xla_us = _time(xla_fn, params, x, e, iters=iters)
    fused_us = _time(fused_fn, params, x, e, iters=iters)
    return {
        "n_nodes": pg.n_pad, "n_edges": pg.e_pad, "hidden": hidden,
        "block_n": block_n, "block_e": block_e, "autotuned_blocks": autotuned,
        "gather_mode": GATHER_MODE,
        "xla_us": xla_us, _fused_timing_key(interpret): fused_us,
        "fused_interpret": interpret, "backend": jax.default_backend(),
        "max_abs_err_e": err_e, "max_abs_err_agg": err_a,
    }


def _nmp_flops_per_edge(hidden: int, n_hidden: int, n_round: int,
                        block_e: int) -> dict:
    """Per-edge FLOP models for the two gather generations (the crossover
    the size sweep demonstrates analytically alongside the timings):

    * ``prefetch_dma`` — MLP matmuls only: the row gathers and the
      scatter-add are O(H) data movement per edge, no gather FLOPs.
    * ``onehot_matmul`` — the retired generation's extra ``[BE, N_round]``
      one-hot matmul per src gather (+ the block-local dst one-hot): grows
      linearly with the node count, the O(E·N) term this PR removed.
    """
    mlp = 2 * hidden * hidden * (3 + n_hidden)       # w0 slices + hidden stack
    return dict(
        prefetch_dma=mlp + 2 * hidden,               # + weighted scatter-add
        onehot_matmul=mlp + 2 * n_round * hidden + 4 * block_e * hidden,
    )


def segment_agg_size_sweep(sizes=(1_000, 10_000, 100_000), hidden: int = 16,
                           degree: int = 6, verbose: bool = False) -> list:
    """Fused-vs-xla timing sweep over graph sizes: N nodes, E = degree·N
    random edges.

    Demonstrates the O(E·N) -> O(E) crossover of the DMA-gather rewrite: the
    measured fused time per edge stays ~flat in N (``us_per_edge``), while
    the per-edge FLOP model of the retired one-hot generation grows linearly
    with N (``flops_per_edge_onehot`` vs ``flops_per_edge_dma``).  Off-TPU
    the timings come from the Pallas interpreter (``fused_interpret_us``) —
    the scaling *shape* still shows, absolute numbers do not transfer.
    """
    from repro import nn
    from repro.graph import segment
    from repro.kernels.segment_agg.ops import (
        compact_gather_layout, fused_nmp_edge_agg, pick_block_sizes)

    interpret = jax.default_backend() != "tpu"
    rows = []
    for n in sizes:
        n = int(n)
        E = degree * n
        block_n, block_e = pick_block_sizes(hidden, jnp.float32)
        rng = np.random.default_rng(n)
        src = rng.integers(0, n, E)
        dst = rng.integers(0, n, E)
        emask = np.ones(E, np.float32)
        einv = np.ones(E, np.float32)
        lay = compact_gather_layout(src, dst, n, block_e)
        perm = jnp.asarray(lay["perm"])
        seg_src = jnp.asarray(lay["src"])
        seg_dst = jnp.asarray(lay["dst"])
        x = jnp.asarray(rng.normal(size=(n, hidden)), jnp.float32)
        e = jnp.asarray(rng.normal(size=(E, hidden)), jnp.float32)
        params = nn.init_mlp(jax.random.PRNGKey(0), 3 * hidden,
                             [hidden] * 2, hidden)
        emask_j, einv_j = jnp.asarray(emask), jnp.asarray(einv)
        dst_j = jnp.asarray(dst, jnp.int32)
        src_j = jnp.asarray(src, jnp.int32)

        def xla_fn(p, x, e):
            feats = jnp.concatenate(
                [segment.gather(x, src_j), segment.gather(x, dst_j), e], -1)
            e_new = (e + nn.mlp(p, feats)) * emask_j[:, None]
            return e_new, segment.segment_sum(
                e_new * einv_j[:, None], dst_j, n)

        def fused_fn(p, x, e):
            return fused_nmp_edge_agg(
                x, e, p, perm, seg_src, seg_dst, emask_j, einv_j,
                block_n=block_n, interpret=interpret)

        xla_jit, fused_jit = jax.jit(xla_fn), jax.jit(fused_fn)
        e_x, a_x = xla_jit(params, x, e)
        e_f, a_f = fused_jit(params, x, e)
        err = max(float(jnp.abs(e_x - e_f).max()),
                  float(jnp.abs(a_x - a_f).max()))
        assert err < 1e-3, err

        iters = 2 if interpret else 10
        xla_us = _time(xla_jit, params, x, e, iters=iters)
        fused_us = _time(fused_jit, params, x, e, iters=iters)
        flops = _nmp_flops_per_edge(hidden, 2, -(-n // 8) * 8, block_e)
        row = {
            "n_nodes": n, "n_edges": E, "hidden": hidden,
            "block_n": block_n, "block_e": block_e,
            "gather_mode": GATHER_MODE,
            "xla_us": xla_us, _fused_timing_key(interpret): fused_us,
            "us_per_edge": fused_us / E,
            "flops_per_edge_dma": flops["prefetch_dma"],
            "flops_per_edge_onehot": flops["onehot_matmul"],
            "fused_interpret": interpret, "max_abs_err": err,
        }
        rows.append(row)
        if verbose:
            print(f"sweep N={n}: fused {fused_us:.0f} us "
                  f"({row['us_per_edge']:.3f} us/edge), xla {xla_us:.0f} us, "
                  f"onehot-model {flops['onehot_matmul']} flops/edge vs "
                  f"dma {flops['prefetch_dma']}")
    return rows


def run(verbose: bool = True, seg_cmp: dict | None = None):
    """``seg_cmp``: pass a precomputed ``segment_agg_compare()`` payload to
    avoid re-running the (interpret-mode-slow) comparison twice."""
    rows = []
    rng = np.random.default_rng(0)

    B, H, S, D = 1, 4, 1024, 64
    q = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    fa = jax.jit(lambda q, k, v: attention_ref(q, k, v, scale=D ** -0.5))
    us = _time(fa, q, q, q)
    flops = 4 * B * H * S * S * D
    rows.append(("flash_attention_ref_1k", us, f"gflops={flops/1e9:.2f}"))

    E, N, FIN, HID = 8192, 2048, 24, 16
    feats = jnp.asarray(rng.normal(size=(E, FIN)), jnp.float32)
    dst = jnp.asarray(rng.integers(0, N, E), jnp.int32)
    w = jnp.ones(E)
    w1 = jnp.asarray(rng.normal(size=(FIN, HID)), jnp.float32)
    b1 = jnp.zeros(HID)
    w2 = jnp.asarray(rng.normal(size=(HID, HID)), jnp.float32)
    b2 = jnp.zeros(HID)
    sa = jax.jit(lambda f: edge_mlp_agg_ref(f, w1, b1, w2, b2, dst, w, N))
    us = _time(sa, feats)
    rows.append(("segment_agg_ref_8k_edges", us,
                 f"gflops={2*E*(FIN*HID+HID*HID)/1e9:.3f}"))

    V, D2, Bb, bag = 100_000, 64, 4096, 4
    table = jnp.asarray(rng.normal(size=(V, D2)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, V, (Bb, bag)), jnp.int32)
    eb = jax.jit(embedding_bag_ref)
    us = _time(eb, table, idx)
    rows.append(("embedding_bag_ref_4k_bags", us,
                 f"gbytes={(Bb*bag*D2*4)/1e9:.4f}"))

    cmp = seg_cmp if seg_cmp is not None else segment_agg_compare()
    tag = "interp" if cmp["fused_interpret"] else cmp["backend"]
    fused_us = cmp[_fused_timing_key(cmp["fused_interpret"])]
    rows.append(("nmp_edge_agg_xla", cmp["xla_us"],
                 f"blocks={cmp['block_n']}x{cmp['block_e']}"))
    rows.append((f"nmp_edge_agg_fused_{tag}", fused_us,
                 f"err={max(cmp['max_abs_err_e'], cmp['max_abs_err_agg']):.1e}"))

    if verbose:
        for r in rows:
            print(f"{r[0]}: {r[1]:.0f} us  ({r[2]})")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sweep-sizes", default=None,
                    help="comma-separated node counts: run only the "
                         "fused-vs-xla size sweep (e.g. '1000,10000')")
    args = ap.parse_args()
    if args.sweep_sizes:
        sizes = [int(s) for s in args.sweep_sizes.split(",")]
        segment_agg_size_sweep(sizes, verbose=True)
    else:
        run()
