"""Serving-engine benchmark (BENCH_serve.json).

Measures the resident :class:`repro.runtime.engine.InferenceEngine` the way
a solver feed exercises it:

  * per-request latency (p50/p95/mean, submit -> result) and steady-state
    throughput, swept over ``batch_slots`` — the tradeoff the engine's
    fixed-slot batching buys (one compiled program, higher slots = higher
    throughput under concurrent producers);
  * ``graph_cache`` — cold ``register_mesh`` build time (partition +
    ShardedGraph + NMPPlan + jitted-fn construction) vs a cache hit for the
    same mesh hash, with the speedup ratio.  The cache is the engine's
    whole point: a resident service must never rebuild per request;
  * ``bitwise_vs_offline`` rider asserted on every run: the first streamed
    prediction of every case equals the engine's batch-1 offline oracle
    bitwise (batching/padding/queueing are arithmetically invisible).

Gated by ``scripts/bench_gate.py --serve-out`` (baseline-free: the bitwise
rider is strict, cached-graph reuse must beat the cold build by > 5x —
absolute latencies are host-dependent, the structural properties are not).
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

import numpy as np
import jax

from repro.core import GNNConfig, NMPPlan, box_mesh, init_gnn, partition_mesh
from repro.core.mesh_gen import taylor_green_velocity
from repro.ckpt import checkpoint as ckpt
from repro.runtime.engine import EngineConfig, InferenceEngine
from repro.train.loop import TrainConfig, run_fingerprint

N_REQUESTS = 24
BATCH_SLOTS_SWEEP = (1, 4)
ROLLOUT_STEPS = 2
DT = 0.05


def serve_sweep(n_requests: int = N_REQUESTS,
                batch_slots_sweep=BATCH_SLOTS_SWEEP,
                rollout_steps: int = ROLLOUT_STEPS) -> dict:
    sem = box_mesh((4, 4, 2), p=2)
    cfg = GNNConfig(hidden=8, n_mp_layers=2, mlp_hidden_layers=2)
    params = init_gnn(jax.random.PRNGKey(0), cfg)

    def snapshot_fn(step: int):
        return taylor_green_velocity(
            sem.coords, t=(step * DT) % 2.0).astype(np.float32)

    with tempfile.TemporaryDirectory() as d:
        ckdir = Path(d) / "ck"
        pg0 = partition_mesh(sem, (1, 1, 1))
        fp = run_fingerprint(sem, pg0, cfg, TrainConfig(), NMPPlan())
        # serving timings/consistency don't depend on training quality, so a
        # fresh init is a valid (and fast) stand-in for trained weights
        ckpt.save(ckdir, 0, {"params": params}, extra={"fingerprint": fp})

        cases = []
        cache = {"cold_build_ms": None, "hit_ms": None}
        bitwise = True
        for slots in batch_slots_sweep:
            engine = InferenceEngine(
                ckdir, cfg,
                EngineConfig(batch_slots=slots, rollout_steps=rollout_steps))
            t0 = time.perf_counter()
            mesh_hash = engine.register_mesh(sem)
            cold_ms = (time.perf_counter() - t0) * 1e3
            t0 = time.perf_counter()
            engine.register_mesh(sem)
            hit_ms = (time.perf_counter() - t0) * 1e3
            if cache["cold_build_ms"] is None:
                cache.update(cold_build_ms=cold_ms, hit_ms=hit_ms)
            engine.warmup()
            with engine:
                t0 = time.perf_counter()
                results = dict(engine.stream(mesh_hash, snapshot_fn,
                                             n_requests, n_producers=2))
                wall = time.perf_counter() - t0
            lat = np.sort([r.latency_s for r in results.values()]) * 1e3
            first = min(results)
            bitwise &= bool(np.array_equal(
                results[first].preds,
                engine.offline_reference(mesh_hash, snapshot_fn(first))))
            cases.append({
                "batch_slots": slots,
                "latency_ms_p50": float(np.percentile(lat, 50)),
                "latency_ms_p95": float(np.percentile(lat, 95)),
                "latency_ms_mean": float(lat.mean()),
                "req_per_s": float(len(results) / wall),
                "batches": int(engine.stats["batches"]),
                "padded_slots": int(engine.stats["padded_slots"]),
            })
        cache["speedup"] = cache["cold_build_ms"] / max(cache["hit_ms"], 1e-6)

    return {
        "n_nodes": int(pg0.n_global),
        "ranks": len(jax.devices()),
        "rollout_steps": rollout_steps,
        "requests": n_requests,
        "producers": 2,
        "cases": cases,
        "graph_cache": cache,
        "bitwise_vs_offline": bool(bitwise),
    }


def run(verbose: bool = False, payload: dict | None = None):
    payload = payload or serve_sweep()
    rows = []
    for c in payload["cases"]:
        rows.append((
            f"serve/slots{c['batch_slots']}",
            c["latency_ms_p50"] * 1e3,
            f"p95 {c['latency_ms_p95']:.1f}ms, {c['req_per_s']:.1f} req/s, "
            f"bitwise={payload['bitwise_vs_offline']}"))
    gc = payload["graph_cache"]
    rows.append((
        "serve/graph_cache",
        gc["cold_build_ms"] * 1e3,
        f"hit {gc['hit_ms'] * 1e3:.0f}us, reuse speedup "
        f"{gc['speedup']:.0f}x"))
    if verbose:
        for name, us, derived in rows:
            print(f"{name}: {us:.1f} us ({derived})")
    return rows
