"""Paper Table II: per-rank sub-graph statistics vs number of ranks.

Partitions a cubic p=5 SEM mesh (scaled to fit host memory) and reports
(min, max, avg) of local nodes, halo nodes, and neighbor counts — the halo
fraction and bounded neighbor count are the properties the paper's N-A2A
relies on.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import box_mesh
from repro.core.partition import from_element_partition, partition_elements, build_halo_plan


def run(verbose: bool = True):
    rows = []
    mesh = box_mesh((8, 8, 8), p=3)
    if verbose:
        print(f"mesh: {mesh.n_elem} elements p={mesh.p}, {mesh.n_nodes} nodes")
        print(f"{'R':>4} {'nodes(min,max,avg)':>28} {'halo(min,max,avg)':>26} "
              f"{'neighbors(min,max,avg)':>24} {'halo %':>7}")
    for grid in ((2, 1, 1), (2, 2, 1), (2, 2, 2), (4, 2, 2), (4, 4, 2), (4, 4, 4)):
        R = int(np.prod(grid))
        t0 = time.perf_counter()
        e2r = partition_elements(mesh, grid)
        graphs = from_element_partition(mesh, e2r, R)
        plan = build_halo_plan(graphs)
        us = (time.perf_counter() - t0) * 1e6
        nodes = [g.n_nodes for g in graphs]
        halos, nbrs = [], []
        for r in range(R):
            h = int(plan.a2a_send_mask[r].sum())
            n_nbr = int((plan.a2a_send_mask[r].sum(axis=-1) > 0).sum())
            halos.append(h)
            nbrs.append(n_nbr)
        frac = np.mean(halos) / np.mean(nodes) * 100
        if verbose:
            print(f"{R:>4} {min(nodes):>9},{max(nodes):>8},{int(np.mean(nodes)):>8} "
                  f"{min(halos):>9},{max(halos):>7},{int(np.mean(halos)):>7} "
                  f"{min(nbrs):>9},{max(nbrs):>6},{np.mean(nbrs):>6.1f} {frac:>6.1f}%")
        rows.append((f"tableII_R{R}", us,
                     f"nodes_avg={int(np.mean(nodes))};halo_avg={int(np.mean(halos))};"
                     f"nbr_avg={np.mean(nbrs):.1f};halo_pct={frac:.1f}"))
    return rows


if __name__ == "__main__":
    run()
