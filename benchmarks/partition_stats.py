"""Partition quality: paper Table II stats + block-vs-spectral comparison.

Two parts:

* ``partition_sweep()`` — the ``BENCH_partition.json`` payload: block vs
  spectral decompositions of a *stretched* SEM mesh (the case block
  decompositions handle worst) across a rank-count grid, reporting the
  structural quality metrics from ``repro.core.partition_quality`` (halo
  volume, edge cut, boundary fraction, imbalance) plus a consistency check
  per method x rank-count cell: ``max_abs_err`` is the max disagreement
  between coincident copies of any node in the stacked forward — EXACTLY
  0.0, because the oracle's halo sum is canonically rank-ordered (Eq. 2's
  partition invariance, bitwise) — and ``loss_dev_vs_1rank`` compares the
  consistent loss against the un-partitioned run (fp32 ulp tolerance).
  Partition choice is a pure performance knob under the paper's Eq. 2/3
  guarantee.  The metrics are topological (no timing), so
  ``scripts/bench_gate.py`` gates them strictly: spectral must cut halo
  volume vs block at >= 4 ranks and every cell must report
  ``max_abs_err == 0.0``.

* ``run()`` — the paper's Table II printer (per-rank sub-graph statistics
  on a cubic mesh) plus a summary of the sweep payload, for the CSV rows
  ``benchmarks/run.py`` prints.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import box_mesh
from repro.core.partition import (
    build_halo_plan, from_element_partition, partition_elements,
)

#: balanced rank grids a user would pick for the block method
BLOCK_GRIDS = {2: (2, 1, 1), 4: (2, 2, 1), 8: (2, 2, 2)}


def partition_sweep(elements=(16, 2, 2), order=2, lengths=(8.0, 1.0, 1.0),
                    rank_counts=(2, 4, 8)) -> dict:
    """Block vs spectral partition quality on a stretched mesh + consistency."""
    import jax
    import jax.numpy as jnp

    from repro.core import (
        A2A, NONE, GNNConfig, HaloSpec, NMPPlan, ShardedGraph,
        gather_node_features, init_gnn, partition_mesh, partition_quality,
        taylor_green_velocity,
    )
    from repro.core.mesh_gen import mesh_graph_edges
    from repro.core.reference import gnn_forward_stacked, loss_and_grad_stacked

    mesh = box_mesh(elements, p=order, lengths=lengths)
    cfg = GNNConfig.small()
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    x_global = taylor_green_velocity(mesh.coords)

    def eval_of(pg, mode):
        plan = NMPPlan(halo=HaloSpec(mode=mode))
        graph = ShardedGraph.build(pg, mesh.coords, plan)
        x = jnp.asarray(gather_node_features(pg, x_global))
        y = np.asarray(gnn_forward_stacked(params, x, graph, plan))
        loss, _, _ = loss_and_grad_stacked(params, x, x, graph, plan,
                                           cfg.node_out)
        return float(loss), y

    def copy_spread(pg, y):
        """Max disagreement between coincident copies of any global node."""
        mx = np.full((pg.n_global, y.shape[-1]), -np.inf)
        mn = np.full((pg.n_global, y.shape[-1]), np.inf)
        gids = np.asarray(pg.global_ids)
        nm = np.asarray(pg.node_mask)
        for r in range(pg.R):
            m = nm[r] > 0
            np.maximum.at(mx, gids[r][m], y[r][m])
            np.minimum.at(mn, gids[r][m], y[r][m])
        return float((mx - mn).max())

    loss_1, _ = eval_of(partition_mesh(mesh, (1, 1, 1)), NONE)

    cases = []
    for R in rank_counts:
        grid = BLOCK_GRIDS[R]
        methods = {}
        for method in ("block", "spectral"):
            t0 = time.perf_counter()
            pg = partition_mesh(mesh, grid, method=method)
            build_us = (time.perf_counter() - t0) * 1e6
            q = partition_quality(pg)
            loss, y = eval_of(pg, A2A)
            err = copy_spread(pg, y)
            assert err == 0.0, (
                f"{method} @ R={R}: coincident copies disagree by {err} — "
                f"partition choice must be consistency-neutral (Eq. 2)")
            loss_dev = abs(loss - loss_1)
            assert loss_dev < 2e-6 * max(1.0, abs(loss_1)), (method, R, loss_dev)
            methods[method] = dict(q, build_us=build_us, max_abs_err=err,
                                   loss_dev_vs_1rank=loss_dev)
        cases.append(dict(ranks=R, block_grid=list(grid), methods=methods))

    return dict(backend=jax.default_backend(), elements=list(elements),
                order=order, lengths=list(lengths), n_nodes=mesh.n_nodes,
                n_edges=int(len(mesh_graph_edges(mesh))), loss_1rank=loss_1,
                cases=cases)


def run(verbose: bool = True, payload: dict | None = None):
    rows = []
    mesh = box_mesh((8, 8, 8), p=3)
    if verbose:
        print(f"mesh: {mesh.n_elem} elements p={mesh.p}, {mesh.n_nodes} nodes")
        print(f"{'R':>4} {'nodes(min,max,avg)':>28} {'halo(min,max,avg)':>26} "
              f"{'neighbors(min,max,avg)':>24} {'halo %':>7}")
    for grid in ((2, 1, 1), (2, 2, 1), (2, 2, 2), (4, 2, 2), (4, 4, 2), (4, 4, 4)):
        R = int(np.prod(grid))
        t0 = time.perf_counter()
        e2r = partition_elements(mesh, grid)
        graphs = from_element_partition(mesh, e2r, R)
        plan = build_halo_plan(graphs)
        us = (time.perf_counter() - t0) * 1e6
        nodes = [g.n_nodes for g in graphs]
        halos, nbrs = [], []
        for r in range(R):
            h = int(plan.a2a_send_mask[r].sum())
            n_nbr = int((plan.a2a_send_mask[r].sum(axis=-1) > 0).sum())
            halos.append(h)
            nbrs.append(n_nbr)
        frac = np.mean(halos) / np.mean(nodes) * 100
        if verbose:
            print(f"{R:>4} {min(nodes):>9},{max(nodes):>8},{int(np.mean(nodes)):>8} "
                  f"{min(halos):>9},{max(halos):>7},{int(np.mean(halos)):>7} "
                  f"{min(nbrs):>9},{max(nbrs):>6},{np.mean(nbrs):>6.1f} {frac:>6.1f}%")
        rows.append((f"tableII_R{R}", us,
                     f"nodes_avg={int(np.mean(nodes))};halo_avg={int(np.mean(halos))};"
                     f"nbr_avg={np.mean(nbrs):.1f};halo_pct={frac:.1f}"))

    if payload is not None:
        if verbose:
            print(f"\nstretched mesh {payload['elements']} p={payload['order']} "
                  f"({payload['n_nodes']} nodes): block vs spectral")
        for c in payload["cases"]:
            for method, q in c["methods"].items():
                if verbose:
                    print(f"  R={c['ranks']} {method:9s} halo_volume="
                          f"{q['halo_volume']:>5} edge_cut={q['edge_cut']:>5} "
                          f"imbalance={q['imbalance']:.2f} "
                          f"err={q['max_abs_err']:.1e}")
                rows.append((
                    f"partition_{method}_R{c['ranks']}", q["build_us"],
                    f"halo_volume={q['halo_volume']};edge_cut={q['edge_cut']};"
                    f"imbalance={q['imbalance']:.3f}"))
    return rows


if __name__ == "__main__":
    run(payload=partition_sweep())
