"""Autoregressive rollout sweep: us/node/step vs rollout depth K.

Times the jitted stacked K-step rollout (``repro.core.reference.
rollout_stacked`` — the same scan-over-own-predictions dataflow the
production shard_map path runs) for a sweep of K on a fixed 4-partition
mesh, under BOTH halo/compute schedules, asserting on the way that every
(K, schedule) cell's rollout loss matches its own 1-rank run — the
consistency guarantee compounds through the autoregressive feedback, so
the sweep doubles as the sharpest end-to-end check in the bench suite.
The payload becomes ``BENCH_rollout.json`` (written by ``benchmarks/run.py``
/ ``scripts/bench_gate.py --rollout-out`` and uploaded by the CI
``bench-gate`` job).

Absolute timings are host-dependent; no timing is gated (the consistency
assertions are the gate).  ``us_per_node_step`` should stay ~flat in K —
the scan adds no per-step overhead beyond the forward itself.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.halo_overlap import _time

KS = (1, 2, 4)
DT = 0.05


def rollout_sweep(ks=KS, elements=(4, 4, 2), order=2, grid=(2, 2, 1)) -> dict:
    import numpy as np

    from repro.core import (
        A2A, NONE, GNNConfig, NMPPlan, ShardedGraph, box_mesh,
        gather_node_features, init_gnn, partition_mesh,
        taylor_green_velocity,
    )
    from repro.core.reference import rollout_stacked

    mesh = box_mesh(elements, p=order)
    cfg = GNNConfig.small()
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    R = int(np.prod(grid))

    def setup(g, mode, schedule):
        pg = partition_mesh(mesh, g)
        plan = NMPPlan.build(pg, mode, schedule=schedule)
        graph = ShardedGraph.build(pg, mesh.coords, plan)
        x0 = jnp.asarray(gather_node_features(
            pg, taylor_green_velocity(mesh.coords)))
        return pg, plan, graph, x0

    # partitions/graphs depend only on the schedule — build once, reuse
    # across the K sweep (the layout/split passes are the expensive part)
    setups = {s: (setup(grid, A2A if R > 1 else NONE, s),
                  setup((1, 1, 1), NONE, s))
              for s in ("blocking", "overlap")}
    # schedule="auto": the measured tuner's pick for this (graph, R); each
    # K row copies the picked schedule's timings under "auto" so the gate
    # can check auto matches-or-beats the best fixed schedule at every K
    (_, _, graph_o, _), _ = setups["overlap"]
    auto_plan = setups["overlap"][0][1].replace(schedule="auto")
    auto_schedule = auto_plan.autotune(graph_o, hidden=cfg.hidden).schedule
    cases = []
    for k in ks:
        tg = [taylor_green_velocity(mesh.coords, t=(i + 1) * DT)
              for i in range(k)]
        row = dict(k=k, schedules={})
        for schedule in ("blocking", "overlap"):
            (pg, plan, graph, x0), (pg1, plan1, graph1, x01) = \
                setups[schedule]
            tgts = jnp.stack([jnp.asarray(gather_node_features(pg, t))
                              for t in tg])
            f = jax.jit(lambda p, x, t, _g=graph, _pl=plan: rollout_stacked(
                p, x, t, _g, _pl, cfg.node_out)[0])
            # consistency vs this K's own 1-rank run — asserted, not gated
            tgts1 = jnp.stack([jnp.asarray(gather_node_features(pg1, t))
                               for t in tg])
            l_r = float(f(params, x0, tgts))
            l_1 = float(jax.jit(
                lambda p, x, t: rollout_stacked(
                    p, x, t, graph1, plan1, cfg.node_out)[0])(
                        params, x01, tgts1))
            err = abs(l_r - l_1)
            assert err < 2e-6 * max(1.0, abs(l_1)), \
                f"rollout consistency violated at K={k} {schedule}: {err}"
            us = _time(f, params, x0, tgts, iters=10)
            row["schedules"][schedule] = dict(
                us=us,
                us_per_node_step=us / (mesh.n_nodes * k),
                loss_dev_vs_1rank=err,
            )
        row["schedules"]["auto"] = dict(row["schedules"][auto_schedule],
                                        picked=auto_schedule)
        cases.append(row)
    return dict(backend=jax.default_backend(), elements=list(elements),
                order=order, grid=list(grid), n_nodes=mesh.n_nodes,
                ranks=R, auto_schedule=auto_schedule, cases=cases)


def run(verbose: bool = True, payload: dict | None = None):
    payload = payload if payload is not None else rollout_sweep()
    rows = []
    for c in payload["cases"]:
        for schedule, s in c["schedules"].items():
            rows.append((f"rollout_K{c['k']}_{schedule}", s["us"],
                         f"us/node/step={s['us_per_node_step']:.3f} "
                         f"dev={s['loss_dev_vs_1rank']:.1e}"))
    if verbose:
        for r in rows:
            print(f"{r[0]}: {r[1]:.0f} us  ({r[2]})")
    return rows


if __name__ == "__main__":
    run()
