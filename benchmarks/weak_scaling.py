"""Paper Fig. 7 + Fig. 8: weak-scaling model on TPU v5e constants.

CPU wall-clock is meaningless for a TPU target, so this benchmark combines
(a) MEASURED per-rank partition statistics from our partitioner at a fixed
per-rank loading with (b) the v5e roofline constants to model one training
iteration for R = 8..2048 in the paper's three modes (None / A2A / NEIGHBOR).
The same three terms the dry-run measures (compute, HBM, collective) drive
the model; halo-buffer bytes follow the paper's setup (hidden-dim x halo
nodes, fwd+bwd per NMP layer).

Reproduced qualitative claims:
  * None + NEIGHBOR stay >90% weak-scaling efficiency at large R;
  * dense A2A collapses (buffer volume grows linearly in R);
  * smaller loadings and the small model lose efficiency earlier (Fig. 8).
"""
from __future__ import annotations

import numpy as np

from repro.core import GNNConfig, box_mesh
from repro.core.partition import build_halo_plan, from_element_partition, partition_elements
from repro.roofline.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS


def _measure_halo_fraction():
    """Per-rank halo fraction + neighbor count from a real partition.

    Halo nodes live on sub-domain surfaces, so the fraction scales as
    (nodes/rank)^(-1/3) for 3-D decompositions; we measure the constant at a
    host-feasible loading and return (constant, neighbors) — callers scale to
    the target loading. (At 512k/rank this gives ~7%, matching the paper's
    Table II 11% to within the mesh-order difference.)"""
    mesh = box_mesh((8, 8, 8), p=3)
    e2r = partition_elements(mesh, (4, 4, 4))
    graphs = from_element_partition(mesh, e2r, 64)
    plan = build_halo_plan(graphs)
    nodes = np.mean([g.n_nodes for g in graphs])
    halo = np.mean(plan.a2a_send_mask.sum(axis=(1, 2)))
    nbr = np.mean((plan.a2a_send_mask.sum(axis=-1) > 0).sum(axis=-1))
    coeff = (halo / nodes) * nodes ** (1.0 / 3.0)
    return coeff, nbr


def halo_fraction_at(coeff: float, nodes_per_rank: float) -> float:
    return coeff / nodes_per_rank ** (1.0 / 3.0)


def model_step_time(R: int, nodes_per_rank: float, cfg: GNNConfig, mode: str,
                    halo_frac: float, n_neighbors: float) -> float:
    """Seconds per training iteration (fwd+bwd) under the roofline model."""
    H = cfg.hidden
    edges_per_node = 6.0   # interior lattice degree (p>=1 box mesh)
    E = nodes_per_rank * edges_per_node
    # per NMP layer dots: edge MLP (3H->H->H) on E edges + node MLP (2H->H->H)
    mlp_layers = cfg.mlp_hidden_layers + 1
    flops_layer = 2 * E * (3 * H * H + (mlp_layers - 1) * H * H) \
        + 2 * nodes_per_rank * (2 * H * H + (mlp_layers - 1) * H * H)
    flops = 3 * cfg.n_mp_layers * flops_layer          # fwd + bwd(2x)
    compute_s = flops / PEAK_FLOPS
    # HBM: activations + params streamed ~3x per layer
    hbm = 3 * cfg.n_mp_layers * (E + nodes_per_rank) * H * 4 * 3
    memory_s = hbm / HBM_BW

    halo_nodes = halo_frac * nodes_per_rank
    buf = halo_nodes * H * 4                            # fp32 aggregates
    per_layer_exchanges = 2                             # fwd + bwd (Eq. 3)
    if mode == "none":
        coll = 0.0
    elif mode == "a2a":
        # equal buffers to ALL ranks: max pair-buffer replicated R times
        pair_buf = buf / max(n_neighbors, 1)
        coll = cfg.n_mp_layers * per_layer_exchanges * pair_buf * R
    else:  # neighbor
        coll = cfg.n_mp_layers * per_layer_exchanges * buf
    # DDP gradient all-reduce (ring) + two loss all-reduces (negligible size)
    n_params = {"small": 3979, "large": 91459}.get(cfg.name, 50000)
    coll += 2 * n_params * 4
    collective_s = coll / ICI_BW
    return compute_s + memory_s + collective_s


def run(verbose: bool = True):
    coeff, nbr = _measure_halo_fraction()
    rows = []
    if verbose:
        print(f"halo-fraction coefficient {coeff:.2f} (surface/volume law), "
              f"avg neighbors {nbr:.1f}; at 512k/rank -> "
              f"{halo_fraction_at(coeff, 512_000)*100:.1f}%")
    for cfg in (GNNConfig.small(), GNNConfig.large()):
        for loading in (256_000, 512_000):
            halo_frac = halo_fraction_at(coeff, loading)
            base = None
            for R in (8, 64, 512, 2048):
                times = {m: model_step_time(R, loading, cfg, m, halo_frac, nbr)
                         for m in ("none", "a2a", "neighbor")}
                thr = {m: loading * R / t for m, t in times.items()}
                if base is None:
                    base = thr
                eff = {m: thr[m] / (base[m] * R / 8) for m in thr}
                rel = {m: thr[m] / thr["none"] for m in thr}
                if verbose:
                    print(f"{cfg.name:6s} load={loading//1000}k R={R:5d} | "
                          f"eff none {eff['none']*100:5.1f}% a2a {eff['a2a']*100:5.1f}% "
                          f"nbr {eff['neighbor']*100:5.1f}% | rel-thr a2a "
                          f"{rel['a2a']:.3f} nbr {rel['neighbor']:.3f}")
                rows.append((f"fig7_{cfg.name}_{loading//1000}k_R{R}",
                             times["neighbor"] * 1e6,
                             f"eff_nbr={eff['neighbor']:.3f};eff_a2a={eff['a2a']:.3f};"
                             f"rel_nbr={rel['neighbor']:.3f};rel_a2a={rel['a2a']:.3f}"))
            assert eff["neighbor"] > 0.85, "neighbor mode must weak-scale"
            assert eff["a2a"] < 0.5, "dense A2A must collapse at R=2048"
    return rows


if __name__ == "__main__":
    run()
