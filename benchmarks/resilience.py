"""Checkpoint/resilience overhead benchmark (BENCH_resilience.json).

Measures what elastic fault tolerance costs at steady state:

  * ``save_ms`` / ``restore_ms`` — synchronous checkpoint save and restore
    latency for the full training state (params + AdamW state + rng), with
    ``tree_bytes`` for scale;
  * ``overhead_pct`` — wall-clock overhead of the ``run_resilient`` driver
    (async checkpoint every ``ckpt_every`` steps, straggler monitor,
    manifest fingerprinting) vs a bare python loop over the SAME jitted
    step functions, so compile time cancels and the number is the
    steady-state tax of checkpointing;
  * correctness riders asserted on every run: the resilient loop's loss
    trajectory is BITWISE identical to the bare loop's (checkpointing must
    never perturb training), and a save -> restore round trip is
    byte-exact.

Gated by ``scripts/bench_gate.py --resilience-out`` (baseline-free:
bitwise riders strict, overhead bounded loosely — absolute timings are
host-dependent and the async save of a small tree is noisy on shared
runners, but a structural catastrophe like a synchronous full-tree save
per step blows far past any sane bound).
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import GNNConfig, NMPPlan, box_mesh, init_gnn, partition_mesh
from repro.core.distributed import make_gnn_step_fns, shard_graph
from repro.core.graph_state import ShardedGraph
from repro.ckpt import checkpoint as ckpt
from repro.launch.mesh import make_mesh
from repro.runtime.fault_tolerance import ResilientConfig, run_resilient
from repro.train.loop import make_tgv_batch_fn
from repro.train.optimizer import AdamWConfig, adamw_update, init_adamw

N_STEPS = 40
CKPT_EVERY = 5


def resilience_sweep(n_steps: int = N_STEPS,
                     ckpt_every: int = CKPT_EVERY) -> dict:
    sem = box_mesh((2, 2, 2), p=3)
    pg = partition_mesh(sem, (1, 1, 1))
    mesh_dev = make_mesh((1, 1), ("data", "graph"))
    cfg = GNNConfig.small()
    plan = NMPPlan.build(pg, "none", axis="graph")
    graph = ShardedGraph.build(pg, sem.coords, plan)
    gs = shard_graph(mesh_dev, graph)
    opt_cfg = AdamWConfig(schedule=lambda s: jnp.asarray(1e-3),
                          weight_decay=0.0)
    _, _, grad_step, _ = make_gnn_step_fns(mesh_dev, cfg, plan)
    batch_fn = make_tgv_batch_fn(pg, sem, batch=1)

    @jax.jit
    def update(params, opt_state, grads):
        return adamw_update(grads, opt_state, params, opt_cfg)

    def init_state_fn():
        params = init_gnn(jax.random.PRNGKey(0), cfg)
        return {"params": params, "opt": init_adamw(params, opt_cfg)}

    def step_fn(state, batch):
        xs = jnp.asarray(batch)
        loss, grads = grad_step(state["params"], xs, xs, gs)
        params, opt_state, _ = update(state["params"], state["opt"], grads)
        return {"params": params, "opt": opt_state}, {"loss": float(loss)}

    # warm with a full untimed pass so both timed loops see steady state
    # only (a single warm step leaves residual compile/autotune in whichever
    # timed loop runs first); the warm pass also yields the reference losses
    state = init_state_fn()
    plain_losses = []
    for s in range(n_steps):
        state, m = step_fn(state, batch_fn(s))
        plain_losses.append(m["loss"])

    # bare loop: the exact computation, no resilience machinery
    state = init_state_fn()
    t0 = time.perf_counter()
    for s in range(n_steps):
        state, m = step_fn(state, batch_fn(s))
    plain_s = time.perf_counter() - t0

    with tempfile.TemporaryDirectory() as d:
        rcfg = ResilientConfig(ckpt_dir=str(Path(d) / "ck"),
                               ckpt_every=ckpt_every)
        t0 = time.perf_counter()
        state_r, hist = run_resilient(init_state_fn, step_fn, batch_fn,
                                      n_steps, rcfg)
        resilient_s = time.perf_counter() - t0
        losses_equal = hist["losses"] == plain_losses

        # sync save/restore latency on the final state
        host = jax.tree.map(np.asarray, state_r)
        tree_bytes = sum(leaf.nbytes for leaf in jax.tree.leaves(host))
        sdir = Path(d) / "lat"
        t0 = time.perf_counter()
        ckpt.save(sdir, 0, host)
        save_ms = (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        restored, _ = ckpt.restore(sdir, host)
        restore_ms = (time.perf_counter() - t0) * 1e3
        restore_exact = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(restored),
                            jax.tree.leaves(host)))

    overhead_pct = 100.0 * (resilient_s - plain_s) / plain_s
    return {
        "n_steps": n_steps,
        "ckpt_every": ckpt_every,
        "n_nodes": int(pg.n_global),
        "ranks": 1,
        "tree_bytes": int(tree_bytes),
        "plain_s": plain_s,
        "resilient_s": resilient_s,
        "overhead_pct": overhead_pct,
        "save_ms": save_ms,
        "restore_ms": restore_ms,
        "losses_bitwise_equal": bool(losses_equal),
        "restore_exact": bool(restore_exact),
    }


def run(verbose: bool = False, payload: dict | None = None):
    payload = payload or resilience_sweep()
    rows = [
        ("resilience/save", payload["save_ms"] * 1e3,
         f"{payload['tree_bytes']}B sync save"),
        ("resilience/restore", payload["restore_ms"] * 1e3,
         "validated+checksummed restore"),
        ("resilience/overhead",
         1e6 * (payload["resilient_s"] - payload["plain_s"])
         / payload["n_steps"],
         f"{payload['overhead_pct']:.1f}% at ckpt_every="
         f"{payload['ckpt_every']}, bitwise={payload['losses_bitwise_equal']}"),
    ]
    if verbose:
        for name, us, derived in rows:
            print(f"{name}: {us:.1f} us ({derived})")
    return rows
