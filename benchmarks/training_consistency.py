"""Paper Fig. 6 (right): training-curve equivalence.

Trains the small GNN (TGV autoencoding) for N iterations: R=1 unpartitioned
vs R=8 consistent vs R=8 standard. Consistent R=8 must track R=1 step for
step; standard NMP drifts.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    A2A, NONE, GNNConfig, HaloSpec, NMPPlan, ShardedGraph, box_mesh,
    init_gnn, partition_mesh, gather_node_features, taylor_green_velocity,
)
from repro.core.reference import loss_and_grad_stacked
from repro.train.optimizer import AdamWConfig, adamw_update, init_adamw


def _train(mesh, pg, cfg, mode, n_steps, lr=3e-3):
    plan = NMPPlan(halo=HaloSpec(mode=mode))
    graph = ShardedGraph.build(pg, mesh.coords, plan)
    x = jnp.asarray(gather_node_features(pg, taylor_green_velocity(mesh.coords)))
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(schedule=lambda s: jnp.asarray(lr), weight_decay=0.0)
    opt = init_adamw(params, opt_cfg)

    @jax.jit
    def step(params, opt):
        loss, _, grads = loss_and_grad_stacked(params, x, x, graph, plan, cfg.node_out)
        params, opt, _ = adamw_update(grads, opt, params, opt_cfg)
        return params, opt, loss

    losses = []
    for _ in range(n_steps):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    return np.asarray(losses)


def run(verbose: bool = True, n_steps: int = 60):
    mesh = box_mesh((4, 4, 2), p=2)
    cfg = GNNConfig.small()
    t0 = time.perf_counter()
    l_ref = _train(mesh, partition_mesh(mesh, (1, 1, 1)), cfg, NONE, n_steps)
    l_con = _train(mesh, partition_mesh(mesh, (4, 2, 1)), cfg, A2A, n_steps)
    l_std = _train(mesh, partition_mesh(mesh, (4, 2, 1)), cfg, NONE, n_steps)
    us = (time.perf_counter() - t0) * 1e6 / (3 * n_steps)

    dev_con = np.abs(l_con - l_ref).max()
    dev_std = np.abs(l_std - l_ref).max()
    if verbose:
        print(f"max |loss - R1| over {n_steps} steps: consistent {dev_con:.2e}, "
              f"standard {dev_std:.2e}")
        print(f"final: R1 {l_ref[-1]:.6f}  consistent {l_con[-1]:.6f}  "
              f"standard {l_std[-1]:.6f}")
    assert dev_con < 5e-4, "consistent training must track R=1"
    return [("fig6R_train_step", us,
             f"dev_consistent={dev_con:.2e};dev_standard={dev_std:.2e}")]


if __name__ == "__main__":
    run()
