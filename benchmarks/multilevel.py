"""Multilevel V-cycle sweep: us/node vs level count.

Times the stacked consistent-GNN forward (xla backend, jit-compiled) for a
sweep of hierarchy depths on a fixed partitioned mesh, asserting on the way
that every depth's partitioned loss matches its own 1-rank run (the
multilevel consistency guarantee — the timing sweep doubles as an
end-to-end check).  The payload becomes ``BENCH_multilevel.json`` (written
by ``benchmarks/run.py`` / ``scripts/bench_gate.py`` and uploaded by the CI
``bench-gate`` job).

Per level count the sweep records the level sizes (node count shrinks
geometrically), wall time, us/node, and the graph *diameter proxy* — the
number of NMP hops information can travel per forward, which is what the
coarse levels buy: one hop at level l spans ~``(p * 2^(l-1))`` fine-graph
hops, so depth buys long-range transfer at a near-constant us/node cost.

Absolute timings are host-dependent; no ratio is gated (the consistency
assertions are the gate).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.halo_overlap import _time

LEVELS = (1, 2, 3)


def multilevel_sweep(levels=LEVELS, elements=(4, 4, 2), order=2,
                     grid=(2, 2, 1)) -> dict:
    """One case per hierarchy depth: partitioned stacked forward, timed."""
    import numpy as np

    from repro.core import (
        A2A, NONE, GNNConfig, NMPPlan, ShardedGraph, box_mesh,
        build_hierarchy, gather_node_features, init_gnn,
        taylor_green_velocity,
    )
    from repro.core.partition import scatter_node_outputs
    from repro.core.reference import gnn_forward_stacked

    mesh = box_mesh(elements, p=order)
    x_global = taylor_green_velocity(mesh.coords)
    R = int(np.prod(grid))

    cases = []
    for n_levels in levels:
        cfg = GNNConfig(hidden=8, n_mp_layers=2, mlp_hidden_layers=2,
                        n_levels=n_levels, coarse_mp_layers=2)
        params = init_gnn(jax.random.PRNGKey(0), cfg)

        def ev(g, mode):
            ml = build_hierarchy(mesh, g, n_levels)
            plan = NMPPlan.build(ml, mode)
            graph = ShardedGraph.build(ml.levels[0], ml.coords[0], plan,
                                       hierarchy=ml)
            f = jax.jit(lambda p, xx: gnn_forward_stacked(p, xx, graph, plan))
            x = jnp.asarray(gather_node_features(ml.levels[0], x_global))
            return f, x, ml

        f_r, x_r, ml = ev(grid, A2A if R > 1 else NONE)
        f_1, x_1, ml1 = ev((1, 1, 1), NONE)
        # consistency: the partitioned run must match 1-rank node-for-node
        g_r = scatter_node_outputs(ml.levels[0], np.asarray(f_r(params, x_r)))
        g_1 = scatter_node_outputs(ml1.levels[0], np.asarray(f_1(params, x_1)))
        err = float(np.abs(g_r - g_1).max())
        assert err < 1e-4, f"multilevel consistency violated at L={n_levels}: {err}"

        us = _time(f_r, params, x_r, iters=10)
        # reach: fine hops spanned per forward (fine layers + coarse layers
        # at stride p * 2^(l-1) per hop)
        reach = cfg.n_mp_layers
        for lvl in range(1, n_levels):
            reach += cfg.coarse_mp_layers * mesh.p * (2 ** (lvl - 1))
        cases.append(dict(
            levels=n_levels,
            level_sizes=ml.level_sizes(),
            us=us,
            us_per_node=us / mesh.n_nodes,
            hop_reach=reach,
            max_abs_err_vs_1rank=err,
        ))
    return dict(backend=jax.default_backend(), elements=list(elements),
                order=order, grid=list(grid), n_nodes=mesh.n_nodes,
                cases=cases)


def run(verbose: bool = True, payload: dict | None = None):
    payload = payload if payload is not None else multilevel_sweep()
    rows = []
    for c in payload["cases"]:
        sizes = "/".join(str(s) for s in c["level_sizes"])
        rows.append((f"multilevel_L{c['levels']}", c["us"],
                     f"sizes={sizes} reach={c['hop_reach']} "
                     f"err={c['max_abs_err_vs_1rank']:.1e}"))
    if verbose:
        for r in rows:
            print(f"{r[0]}: {r[1]:.0f} us  ({r[2]})")
    return rows


if __name__ == "__main__":
    run()
