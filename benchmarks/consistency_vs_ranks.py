"""Paper Fig. 6 (left): loss vs number of ranks R, consistent vs standard NMP.

Random-parameter GNN evaluated on partitions of a cubic SEM mesh; the
consistent formulation must be R-invariant, the standard one deviates
~linearly in R.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    A2A, NONE, GNNConfig, HaloSpec, NMPPlan, ShardedGraph, box_mesh,
    init_gnn, partition_mesh, gather_node_features, taylor_green_velocity,
)
from repro.core.reference import loss_and_grad_stacked


def run(verbose: bool = True):
    mesh = box_mesh((4, 4, 4), p=3)
    cfg = GNNConfig.small()
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    x_global = taylor_green_velocity(mesh.coords)

    def ev(grid, mode):
        pg = partition_mesh(mesh, grid)
        plan = NMPPlan(halo=HaloSpec(mode=mode))
        graph = ShardedGraph.build(pg, mesh.coords, plan)
        x = jnp.asarray(gather_node_features(pg, x_global))
        t0 = time.perf_counter()
        loss, _, _ = loss_and_grad_stacked(params, x, x, graph, plan,
                                           cfg.node_out)
        return float(loss), (time.perf_counter() - t0) * 1e6

    rows = []
    l1, us = ev((1, 1, 1), NONE)
    rows.append(("fig6L_R1_baseline", us, f"loss={l1:.8f}"))
    for grid in ((2, 1, 1), (2, 2, 1), (2, 2, 2), (4, 2, 2), (4, 4, 2)):
        R = int(np.prod(grid))
        lc, us_c = ev(grid, A2A)
        ln, us_n = ev(grid, NONE)
        rows.append((f"fig6L_R{R}_consistent", us_c,
                     f"dev={abs(lc-l1):.2e}"))
        rows.append((f"fig6L_R{R}_standard", us_n,
                     f"dev={abs(ln-l1):.2e}"))
        if verbose:
            print(f"R={R:3d} consistent dev {abs(lc-l1):.2e} | "
                  f"standard dev {abs(ln-l1):.2e}")
    rows += run_fused_backend(verbose=verbose)
    return rows


def run_fused_backend(verbose: bool = True, block_n: int = 16,
                      block_e: int = 32):
    """Consistency of the fused Pallas NMP backend through the kernel swap:
    the fused path must match the xla path (fp32 tolerance) on 1-rank AND
    partitioned halo graphs — the paper's guarantee survives the kernel.

    Uses a smaller mesh than the Fig. 6 sweep: off-TPU the kernels run
    through the Pallas interpreter.
    """
    interpret = jax.default_backend() != "tpu"
    mesh = box_mesh((2, 2, 2), p=2)
    cfg = GNNConfig(hidden=8, n_mp_layers=2, mlp_hidden_layers=2)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    x_global = taylor_green_velocity(mesh.coords)

    def ev(grid, mode, backend):
        pg = partition_mesh(mesh, grid)
        plan = NMPPlan(halo=HaloSpec(mode=mode), backend=backend,
                       interpret=interpret, block_n=block_n, block_e=block_e)
        graph = ShardedGraph.build(pg, mesh.coords,
                                   plan.replace(backend="fused"))
        x = jnp.asarray(gather_node_features(pg, x_global))
        t0 = time.perf_counter()
        loss, _, _ = loss_and_grad_stacked(params, x, x, graph, plan,
                                           cfg.node_out)
        return float(loss), (time.perf_counter() - t0) * 1e6

    rows = []
    for grid, mode in (((1, 1, 1), NONE), ((2, 2, 1), A2A)):
        R = int(np.prod(grid))
        lx, us_x = ev(grid, mode, "xla")
        lf, us_f = ev(grid, mode, "fused")
        dev = abs(lf - lx)
        assert dev < 1e-5 * max(1.0, abs(lx)), (lx, lf)
        rows.append((f"fig6L_R{R}_fused_vs_xla", us_f, f"dev={dev:.2e}"))
        if verbose:
            print(f"R={R:3d} fused-vs-xla dev {dev:.2e} "
                  f"({'interpret' if interpret else 'compiled'})")
    return rows


if __name__ == "__main__":
    run()
