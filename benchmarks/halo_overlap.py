"""Blocking-vs-overlap NMP schedule comparison per rank count.

Times the stacked consistent-GNN forward (xla backend, jit-compiled — real
compiled timings on any host) under both halo/compute schedules for a sweep
of partition grids, asserts fp32-level agreement of the losses, and reports
each partition's interior-edge fraction — the share of Eq. 4a+4b work the
overlap schedule can hide behind the exchange.  The payload becomes
``BENCH_halo_overlap.json`` (see ``benchmarks/run.py`` and
``scripts/bench_gate.py``).

Absolute timings are host-dependent; the gate therefore compares the
overlap/blocking *ratio* against the committed baseline, which normalizes
the hardware away.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

GRIDS = ((1, 1, 1), (2, 1, 1), (2, 2, 1), (2, 2, 2))


def _time(fn, *args, iters=20):
    """Min-of-iters wall time (us) — min is far more noise-robust than the
    mean for micro-timings, which matters for the ratio gate on shared CI
    hosts."""
    out = fn(*args)
    jax.tree.leaves(out)[0].block_until_ready()
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree.leaves(out)[0].block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _halo_mode_sweep(pg, graph, hidden: int) -> dict:
    """The (schedule x halo-mode x wire) probe on one partition: per-mode
    wire bytes, the measured candidate table, the tuner's resolved triple,
    and the packed-vs-dense copy agreement (must be exactly 0.0)."""
    import numpy as np
    from repro.core import (NMPPlan, halo_sync_stacked,
                            measure_plan_candidates)
    from repro.core.consistent_mp import _mode_label, _wire_name

    wire = {
        "a2a": pg.wire_bytes("a2a", feat_dim=hidden),
        "neighbor": pg.wire_bytes("neighbor", feat_dim=hidden),
        "neighbor-packed": pg.wire_bytes("neighbor", packed=True,
                                         feat_dim=hidden),
    }
    # the packed kernels run interpreted anywhere but TPU
    interpret = jax.default_backend() != "tpu"
    plan = NMPPlan.build(pg, "auto", schedule="auto", interpret=interpret)
    table = measure_plan_candidates(plan, graph, hidden=hidden, iters=10)
    tuned = plan.autotune(graph, measure=True, hidden=hidden, iters=10)
    triple = (tuned.schedule, _mode_label(tuned.halo),
              _wire_name(tuned.halo.wire_dtype))
    best = min(table, key=table.get)

    # packed is pure data movement: bitwise-equal to the dense exchange
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(pg.R, pg.n_pad, hidden)).astype(
        np.float32)) * jnp.asarray(pg.node_mask)[..., None]
    import dataclasses
    packed_spec = dataclasses.replace(plan.halo, mode="neighbor",
                                      packed=True)
    dense_spec = dataclasses.replace(packed_spec, packed=False)
    err = float(jnp.abs(halo_sync_stacked(a, graph, packed_spec)
                        - halo_sync_stacked(a, graph, dense_spec)).max())
    return dict(
        wire_bytes=wire,
        candidates_us={f"{s}|{m}|{w or 'fp32'}": t * 1e6
                       for (s, m, w), t in table.items()},
        auto_triple=list(triple),
        auto_matches_best=(triple == best),
        packed_max_abs_err=err,
    )


def overlap_compare(grids=GRIDS, elements=(4, 4, 2), order=2) -> dict:
    """One case per partition grid: blocking vs overlap stacked forward,
    plus the halo-mode sweep (wire bytes per format, measured (schedule x
    halo-mode x wire) candidate timings, the tuner's pick, packed-vs-dense
    copy agreement) on every multi-rank grid."""
    from repro.core import (
        A2A, NONE, GNNConfig, HaloSpec, NMPPlan, ShardedGraph, box_mesh,
        gather_node_features, init_gnn, partition_mesh,
        taylor_green_velocity,
    )
    from repro.core.reference import gnn_forward_stacked

    mesh = box_mesh(elements, p=order)
    cfg = GNNConfig.small()
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    x_global = taylor_green_velocity(mesh.coords)

    cases = []
    for grid in grids:
        pg = partition_mesh(mesh, grid)
        spec = HaloSpec(mode=NONE if pg.R == 1 else A2A)
        plans = {s: NMPPlan(halo=spec, schedule=s)
                 for s in ("blocking", "overlap")}
        # one graph serves every candidate: halo mode "auto" makes the
        # build attach the packed pk{k}_* arrays next to the dense ones
        build_plan = NMPPlan.build(pg, NONE if pg.R == 1 else "auto",
                                   schedule="auto")
        graph = ShardedGraph.build(pg, mesh.coords, build_plan)
        x = jnp.asarray(gather_node_features(pg, x_global))

        def fwd(schedule):
            plan = plans[schedule]
            return jax.jit(lambda p, xx: gnn_forward_stacked(
                p, xx, graph, plan))

        f_b, f_o = fwd("blocking"), fwd("overlap")
        y_b = f_b(params, x)
        y_o = f_o(params, x)
        err = float(jnp.abs(y_b - y_o).max())
        assert err < 1e-4, f"overlap deviates from blocking: {err}"
        timings = {"blocking": _time(f_b, params, x),
                   "overlap": _time(f_o, params, x)}
        # schedule="auto": the measured tuner's pick for this (graph, R) —
        # the gate checks it matches (or beats) the best fixed schedule
        auto = (NMPPlan(halo=spec, schedule="auto")
                .autotune(graph, hidden=cfg.hidden).schedule)
        case = dict(
            ranks=pg.R, grid=list(grid),
            blocking_us=timings["blocking"],
            overlap_us=timings["overlap"],
            auto_schedule=auto,
            auto_us=timings[auto],
            interior_frac=pg.interior_split()["interior_frac"],
            max_abs_err=err,
        )
        if pg.R > 1:
            case.update(_halo_mode_sweep(pg, graph, cfg.hidden))
        cases.append(case)
    return dict(backend=jax.default_backend(), n_nodes=mesh.n_nodes,
                elements=list(elements), order=order, cases=cases)


def run(verbose: bool = True, overlap_payload: dict | None = None):
    payload = overlap_payload if overlap_payload is not None else overlap_compare()
    rows = []
    for c in payload["cases"]:
        rows.append((f"nmp_blocking_R{c['ranks']}", c["blocking_us"],
                     f"int_frac={c['interior_frac']:.3f}"))
        rows.append((f"nmp_overlap_R{c['ranks']}", c["overlap_us"],
                     f"err={c['max_abs_err']:.1e}"))
        if "auto_schedule" in c:
            rows.append((f"nmp_auto_R{c['ranks']}", c["auto_us"],
                         f"picked={c['auto_schedule']}"))
    if verbose:
        for r in rows:
            print(f"{r[0]}: {r[1]:.0f} us  ({r[2]})")
    return rows


if __name__ == "__main__":
    run()
