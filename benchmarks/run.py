"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes
``BENCH_segment_agg.json`` (xla/fused NMP hot-loop timings + optional graph
size sweep + per-SHA ``history`` trajectory), ``BENCH_halo_overlap.json``
(blocking-vs-overlap NMP schedule timings per rank count, plus the
measured ``auto`` pick), ``BENCH_rollout.json`` (us/node/step vs
autoregressive rollout depth K, both schedules, consistency-asserted), and
``BENCH_partition.json`` (block-vs-spectral partition quality on a
stretched mesh, bitwise copy-agreement asserted), and
``BENCH_resilience.json`` (checkpoint save/restore latency + steady-state
``run_resilient`` overhead %, bitwise-trajectory asserted), and
``BENCH_serve.json`` (inference-engine latency/throughput vs batch slots,
graph-cache reuse speedup, bitwise-vs-offline asserted) so future PRs
have a perf trajectory to regress against (see ``scripts/bench_gate.py``).
Run:
    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import json
import os
import subprocess

#: history entries carried in BENCH_segment_agg.json (oldest dropped first)
HISTORY_CAP = 50


def _write_json(path: str, payload: dict) -> dict:
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return payload


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, timeout=10, check=True).stdout.strip()
    except Exception:
        return "unknown"


def _with_history(path: str, payload: dict) -> dict:
    """Append this run's timings to the prior file's ``history`` list so the
    JSON carries a per-SHA trajectory (future gates can regress against the
    trend instead of a single overwritten baseline)."""
    prior = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                prior = json.load(f)
        except (OSError, json.JSONDecodeError):
            prior = {}
    entry = {"sha": _git_sha()}
    for k in ("xla_us", "fused_us", "fused_interpret_us", "gather_mode",
              "backend"):
        if k in payload:
            entry[k] = payload[k]
    payload["history"] = (prior.get("history", []) + [entry])[-HISTORY_CAP:]
    return payload


def write_segment_agg_json(path: str = "BENCH_segment_agg.json",
                           sweep_sizes=None) -> dict:
    """Collect the xla-vs-fused segment-agg comparison (plus the graph-size
    sweep when ``sweep_sizes`` is given) and persist it with the per-SHA
    timing history appended."""
    from benchmarks.kernel_bench import (
        segment_agg_compare, segment_agg_size_sweep)
    payload = segment_agg_compare()
    if sweep_sizes:
        payload["sweep"] = segment_agg_size_sweep(sweep_sizes)
    return _write_json(path, _with_history(path, payload))


def write_halo_overlap_json(path: str = "BENCH_halo_overlap.json") -> dict:
    """Collect the blocking-vs-overlap schedule comparison and persist it."""
    from benchmarks.halo_overlap import overlap_compare
    return _write_json(path, overlap_compare())


def write_multilevel_json(path: str = "BENCH_multilevel.json") -> dict:
    """Collect the us/node-vs-level-count V-cycle sweep (with its built-in
    partitioned-vs-1-rank consistency assertions) and persist it."""
    from benchmarks.multilevel import multilevel_sweep
    return _write_json(path, multilevel_sweep())


def write_rollout_json(path: str = "BENCH_rollout.json") -> dict:
    """Collect the us/node/step-vs-K autoregressive rollout sweep (both
    schedules, with its built-in 1-rank-vs-partitioned consistency
    assertions) and persist it."""
    from benchmarks.rollout import rollout_sweep
    return _write_json(path, rollout_sweep())


def write_resilience_json(path: str = "BENCH_resilience.json") -> dict:
    """Collect the checkpoint/resilience overhead benchmark (sync
    save/restore latency, steady-state run_resilient overhead %, with its
    built-in bitwise-trajectory and exact-roundtrip assertions) and
    persist it."""
    from benchmarks.resilience import resilience_sweep
    return _write_json(path, resilience_sweep())


def write_serve_json(path: str = "BENCH_serve.json") -> dict:
    """Collect the inference-engine serving benchmark (latency/throughput
    vs batch slots, graph-cache cold-build vs hit, with its built-in
    bitwise-vs-offline assertion) and persist it."""
    from benchmarks.serve import serve_sweep
    return _write_json(path, serve_sweep())


def write_partition_json(path: str = "BENCH_partition.json") -> dict:
    """Collect the block-vs-spectral partition quality sweep (stretched
    mesh, with its built-in bitwise copy-agreement assertions) and persist
    it."""
    from benchmarks.partition_stats import partition_sweep
    return _write_json(path, partition_sweep())


def main() -> None:
    from benchmarks import (consistency_vs_ranks, training_consistency,
                            partition_stats, weak_scaling, kernel_bench,
                            halo_overlap, multilevel, rollout, resilience,
                            serve)
    payload = write_segment_agg_json()   # computed once, reused by kernel_bench
    overlap_payload = write_halo_overlap_json()  # reused by halo_overlap.run
    multilevel_payload = write_multilevel_json()  # reused by multilevel.run
    rollout_payload = write_rollout_json()        # reused by rollout.run
    partition_payload = write_partition_json()    # reused by partition_stats.run
    resilience_payload = write_resilience_json()  # reused by resilience.run
    serve_payload = write_serve_json()            # reused by serve.run
    all_rows = []
    for mod, label in ((consistency_vs_ranks, "Fig6-left"),
                       (training_consistency, "Fig6-right"),
                       (partition_stats, "TableII"),
                       (weak_scaling, "Fig7/8"),
                       (kernel_bench, "kernels"),
                       (halo_overlap, "halo-overlap"),
                       (multilevel, "multilevel"),
                       (rollout, "rollout"),
                       (resilience, "resilience"),
                       (serve, "serve")):
        print(f"\n=== {label}: {mod.__name__} ===", flush=True)
        kw = {}
        if mod is kernel_bench:
            kw = dict(seg_cmp=payload)
        elif mod is halo_overlap:
            kw = dict(overlap_payload=overlap_payload)
        elif mod is multilevel:
            kw = dict(payload=multilevel_payload)
        elif mod is rollout:
            kw = dict(payload=rollout_payload)
        elif mod is partition_stats:
            kw = dict(payload=partition_payload)
        elif mod is resilience:
            kw = dict(payload=resilience_payload)
        elif mod is serve:
            kw = dict(payload=serve_payload)
        all_rows += mod.run(verbose=True, **kw)
    fused_us = payload.get("fused_us", payload.get("fused_interpret_us", 0.0))
    print(f"\nwrote BENCH_segment_agg.json "
          f"(xla {payload['xla_us']:.0f} us, fused {fused_us:.0f} us"
          f"{' [interpret]' if payload['fused_interpret'] else ''}, "
          f"gather_mode {payload['gather_mode']})")
    worst = max((c["overlap_us"] / c["blocking_us"]
                 for c in overlap_payload["cases"]), default=1.0)
    print(f"wrote BENCH_halo_overlap.json ({len(overlap_payload['cases'])} "
          f"rank counts, worst overlap/blocking ratio {worst:.2f} on "
          f"{overlap_payload['backend']})")
    deepest = multilevel_payload["cases"][-1]
    print(f"wrote BENCH_multilevel.json (levels up to {deepest['levels']}, "
          f"{deepest['us_per_node']:.2f} us/node at depth, hop reach "
          f"{deepest['hop_reach']})")
    longest = rollout_payload["cases"][-1]
    print(f"wrote BENCH_rollout.json (K up to {longest['k']}, "
          f"{longest['schedules']['blocking']['us_per_node_step']:.3f} "
          f"us/node/step blocking, auto->"
          f"{rollout_payload['auto_schedule']}, consistency-asserted)")
    worst_case = max(partition_payload["cases"], key=lambda c: c["ranks"])
    hv_b = worst_case["methods"]["block"]["halo_volume"]
    hv_s = worst_case["methods"]["spectral"]["halo_volume"]
    print(f"wrote BENCH_partition.json (R up to {worst_case['ranks']}: "
          f"halo volume block {hv_b} vs spectral {hv_s}, "
          f"copy agreement exact)")
    rp = resilience_payload
    print(f"wrote BENCH_resilience.json (save {rp['save_ms']:.1f} ms / "
          f"restore {rp['restore_ms']:.1f} ms for {rp['tree_bytes']}B, "
          f"{rp['overhead_pct']:.1f}% overhead at ckpt_every="
          f"{rp['ckpt_every']}, trajectory bitwise="
          f"{rp['losses_bitwise_equal']})")
    sp = serve_payload
    best = max(sp["cases"], key=lambda c: c["req_per_s"])
    print(f"wrote BENCH_serve.json ({best['req_per_s']:.1f} req/s at "
          f"{best['batch_slots']} slots, p50 {best['latency_ms_p50']:.1f} ms, "
          f"graph-cache reuse {sp['graph_cache']['speedup']:.0f}x, "
          f"bitwise_vs_offline={sp['bitwise_vs_offline']})")
    print("\nname,us_per_call,derived")
    for name, us, derived in all_rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == '__main__':
    main()
