"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes
``BENCH_segment_agg.json`` (xla/fused NMP hot-loop timings + layout
padding-waste) and ``BENCH_halo_overlap.json`` (blocking-vs-overlap NMP
schedule timings per rank count) so future PRs have a perf trajectory to
regress against (see ``scripts/bench_gate.py``). Run:
    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import json


def _write_json(path: str, payload: dict) -> dict:
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return payload


def write_segment_agg_json(path: str = "BENCH_segment_agg.json") -> dict:
    """Collect the xla-vs-fused segment-agg comparison and persist it."""
    from benchmarks.kernel_bench import segment_agg_compare
    return _write_json(path, segment_agg_compare())


def write_halo_overlap_json(path: str = "BENCH_halo_overlap.json") -> dict:
    """Collect the blocking-vs-overlap schedule comparison and persist it."""
    from benchmarks.halo_overlap import overlap_compare
    return _write_json(path, overlap_compare())


def main() -> None:
    from benchmarks import (consistency_vs_ranks, training_consistency,
                            partition_stats, weak_scaling, kernel_bench,
                            halo_overlap)
    payload = write_segment_agg_json()   # computed once, reused by kernel_bench
    overlap_payload = write_halo_overlap_json()  # reused by halo_overlap.run
    all_rows = []
    for mod, label in ((consistency_vs_ranks, "Fig6-left"),
                       (training_consistency, "Fig6-right"),
                       (partition_stats, "TableII"),
                       (weak_scaling, "Fig7/8"),
                       (kernel_bench, "kernels"),
                       (halo_overlap, "halo-overlap")):
        print(f"\n=== {label}: {mod.__name__} ===", flush=True)
        kw = {}
        if mod is kernel_bench:
            kw = dict(seg_cmp=payload)
        elif mod is halo_overlap:
            kw = dict(overlap_payload=overlap_payload)
        all_rows += mod.run(verbose=True, **kw)
    print(f"\nwrote BENCH_segment_agg.json "
          f"(xla {payload['xla_us']:.0f} us, fused {payload['fused_us']:.0f} us"
          f"{' [interpret]' if payload['fused_interpret'] else ''}, "
          f"waste {payload['layout_waste']:.3f})")
    worst = max((c["overlap_us"] / c["blocking_us"]
                 for c in overlap_payload["cases"]), default=1.0)
    print(f"wrote BENCH_halo_overlap.json ({len(overlap_payload['cases'])} "
          f"rank counts, worst overlap/blocking ratio {worst:.2f} on "
          f"{overlap_payload['backend']})")
    print("\nname,us_per_call,derived")
    for name, us, derived in all_rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == '__main__':
    main()
