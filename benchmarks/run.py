"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Run:
    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (consistency_vs_ranks, training_consistency,
                            partition_stats, weak_scaling, kernel_bench)
    all_rows = []
    for mod, label in ((consistency_vs_ranks, "Fig6-left"),
                       (training_consistency, "Fig6-right"),
                       (partition_stats, "TableII"),
                       (weak_scaling, "Fig7/8"),
                       (kernel_bench, "kernels")):
        print(f"\n=== {label}: {mod.__name__} ===", flush=True)
        all_rows += mod.run(verbose=True)
    print("\nname,us_per_call,derived")
    for name, us, derived in all_rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == '__main__':
    main()
