"""Single-device stacked-rank reference evaluation of the consistent GNN.

Runs the R-rank partitioned model on ONE device by looping ranks in python
and emulating the halo exchange with plain gathers (``halo_sync_reference``).
This is the oracle used by tests and the Fig. 6 benchmarks; the production
shard_map path must agree with it exactly (same arithmetic, real collectives).

All entry points take the stacked :class:`~repro.core.graph_state.
ShardedGraph` (leading rank axis intact — ``ShardedGraph.build``) and one
:class:`~repro.core.graph_state.NMPPlan`; per-rank slices are produced with
``graph.rank(r)``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro import nn as rnn
from repro.core.consistent_mp import (
    edge_update_aggregate, edge_update_aggregate_part, node_update,
    prolong_aggregate, restrict_aggregate,
)
from repro.core.gnn import build_edge_inputs
from repro.core.graph_state import OVERLAP, NMPPlan, ShardedGraph, as_graph
from repro.core.halo import halo_sync_reference


def _smooth_stacked(lp, h, e, g: ShardedGraph, plan: NMPPlan, sync_fn=None):
    """One consistent NMP layer over the stacked ranks (reference halo).

    ``sync_fn`` (signature of :func:`halo_sync_reference`) overrides the
    exchange emulator — pass ``repro.core.halo.halo_sync_stacked`` (curried
    with ``rounds_perms`` for rounds2d specs) to follow the PRODUCTION
    per-mode/per-wire arithmetic instead of the canonical A2A oracle; the
    (schedule × halo-mode × wire) autotune probe and the packed-vs-dense
    bitwise tests run this layer that way.
    """
    sync = halo_sync_reference if sync_fn is None else sync_fn
    R = h.shape[0]
    ranks = [g.rank(r) for r in range(R)]
    if plan.schedule == OVERLAP:
        outs_b = [edge_update_aggregate_part(lp, h[r], e[r], ranks[r], "bnd",
                                             plan) for r in range(R)]
        outs_i = [edge_update_aggregate_part(lp, h[r], e[r], ranks[r], "int",
                                             plan) for r in range(R)]
        agg = jnp.stack([o[1] for o in outs_b])
        if plan.halo.mode != "none":
            agg = sync(agg, g, plan.halo, combine="sum")
        agg = agg + jnp.stack([o[1] for o in outs_i])
        e_new = jnp.stack([b[0] + i[0] for b, i in zip(outs_b, outs_i)])
    else:
        outs = [edge_update_aggregate(lp, h[r], e[r], ranks[r], plan)
                for r in range(R)]
        agg = jnp.stack([o[1] for o in outs])
        if plan.halo.mode != "none":
            agg = sync(agg, g, plan.halo, combine="sum")
        e_new = jnp.stack([o[0] for o in outs])
    h_new = jnp.stack([node_update(lp, h[r], agg[r], ranks[r])
                       for r in range(R)])
    return h_new, e_new


def vcycle_stacked(
    coarse_params,
    h: jnp.ndarray,                  # [R, N_pad, H]
    graph: ShardedGraph,             # fine level w/ nested coarse chain
    plan: NMPPlan,
) -> jnp.ndarray:
    """Single-device oracle for ``consistent_mp.multilevel_vcycle``: ranks
    loop in python and every exchange — the restriction/prolongation
    completion halo-sums included — goes through ``halo_sync_reference``
    over each level's stacked A2A arrays.  The production shard_map V-cycle
    must agree with this exactly (tests/test_multilevel.py, values and
    gradients, both backends x both schedules)."""
    graph = as_graph(graph)
    n_levels = len(coarse_params) + 1
    graph.level(n_levels - 1)          # loud error if coarse levels missing
    levels = graph.levels
    R = h.shape[0]

    states = [h]
    for lvl in range(1, n_levels):
        g = levels[lvl]
        n_pad_c = g["node_mask"].shape[-1]
        c = jnp.stack([restrict_aggregate(states[-1][r], g.rank(r), n_pad_c)
                       for r in range(R)])
        if plan.halo.mode != "none":
            c = halo_sync_reference(c, g, plan.halo, combine="sum")
        c = c * g["node_mask"][..., None]
        p = coarse_params[lvl - 1]
        e = jnp.stack([
            rnn.mlp(p["edge_enc"], g["static_edge_feats"][r])
            * g["edge_mask"][r][..., None] for r in range(R)])
        for lp in p["mp"]:
            c, e = _smooth_stacked(lp, c, e, g, plan)
        states.append(c)
    for lvl in range(n_levels - 1, 0, -1):
        gt = levels[lvl]
        gf = levels[lvl - 1]
        n_pad_f = gf["node_mask"].shape[-1]
        up = jnp.stack([prolong_aggregate(states[lvl][r], gt.rank(r), n_pad_f)
                        for r in range(R)])
        if plan.halo.mode != "none":
            up = halo_sync_reference(up, gf, plan.halo, combine="sum")
        states[lvl - 1] = (states[lvl - 1] + up) * gf["node_mask"][..., None]
    return states[0]


def gnn_forward_stacked(
    params: rnn.Params,
    x: jnp.ndarray,                  # [R, N_pad, F_x]
    graph: ShardedGraph,             # stacked arrays incl. static_edge_feats
    plan: NMPPlan,
    sync_fn=None,
) -> jnp.ndarray:
    """Paper GNN forward over all R ranks on one device (reference halo).

    The Eq. 4a+4b hot loop goes through the same ``edge_update_aggregate``
    the production shard_map path uses, so a fused plan exercises the Pallas
    kernels under this single-device oracle too, and an overlap plan runs
    the interior/boundary split with the exchange restricted to the boundary
    partial aggregate — the same dataflow the production overlap path hides
    communication behind.  Params carrying coarse levels run the multilevel
    V-cycle through :func:`vcycle_stacked` before the decoder (``graph``
    then needs the nested coarse chain from
    ``ShardedGraph.build(..., hierarchy=...)``).
    """
    graph = as_graph(graph)
    g0 = graph.levels[0]
    R = x.shape[0]
    hs, es = [], []
    for r in range(R):
        g_r = g0.rank(r)
        e_in = build_edge_inputs(x[r], g_r)
        hs.append(rnn.mlp(params["node_enc"], x[r]) * g_r["node_mask"][..., None])
        es.append(rnn.mlp(params["edge_enc"], e_in) * g_r["edge_mask"][..., None])
    h, e = jnp.stack(hs), jnp.stack(es)

    for lp in params["mp"]:
        h, e = _smooth_stacked(lp, h, e, g0, plan, sync_fn)

    if "coarse" in params:
        h = vcycle_stacked(params["coarse"], h, graph, plan)
    return jnp.stack([rnn.mlp(params["node_dec"], h[r])
                      * g0["node_mask"][r][..., None] for r in range(R)])


def consistent_loss_stacked(y: jnp.ndarray, y_hat: jnp.ndarray,
                            graph, fy: int) -> jnp.ndarray:
    """Eq. 6 with the psum replaced by an explicit sum over the stacked ranks."""
    err2 = jnp.sum((y - y_hat) ** 2, axis=-1)          # [R, N_pad]
    inv = graph["node_inv_mult"]
    s = jnp.sum(err2 * inv)
    n_eff = jnp.sum(inv)
    return s / (n_eff * fy)


def loss_and_grad_stacked(
    params: rnn.Params,
    x: jnp.ndarray,
    y_hat: jnp.ndarray,
    graph: ShardedGraph,
    plan: NMPPlan,
    fy: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, rnn.Params]:
    graph = as_graph(graph)

    def f(p):
        y = gnn_forward_stacked(p, x, graph, plan)
        return consistent_loss_stacked(y, y_hat, graph.levels[0], fy), y
    (loss, y), grads = jax.value_and_grad(f, has_aux=True)(params)
    return loss, y, grads


def rollout_stacked(
    params: rnn.Params,
    x0: jnp.ndarray,                 # [R, N_pad, F]
    targets: jnp.ndarray,            # [K, R, N_pad, F]
    graph: ShardedGraph,
    plan: NMPPlan,
    fy: int,
    noise: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-device oracle for the K-step autoregressive rollout
    (``repro.train.rollout``): the model is scanned over its OWN predictions,
    each step's halo-consistent loss is accumulated, and optional pushforward
    noise perturbs the step-1 input with gradients stopped through the
    noised state.  Returns (mean per-step loss, predictions [K, R, N_pad, F]).
    """
    graph = as_graph(graph)
    g0 = graph.levels[0]
    x = x0
    if noise is not None:
        x = x + jax.lax.stop_gradient(noise)
    losses, preds = [], []
    for k in range(targets.shape[0]):
        y = gnn_forward_stacked(params, x, graph, plan)
        losses.append(consistent_loss_stacked(y, targets[k], g0, fy))
        preds.append(y)
        x = y                                   # scan over own prediction
    return jnp.stack(losses).mean(), jnp.stack(preds)
