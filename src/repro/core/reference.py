"""Single-device stacked-rank reference evaluation of the consistent GNN.

Runs the R-rank partitioned model on ONE device by looping ranks in python
and emulating the halo exchange with plain gathers (``halo_sync_reference``).
This is the oracle used by tests and the Fig. 6 benchmarks; the production
shard_map path must agree with it exactly (same arithmetic, real collectives).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn as rnn
from repro.core.gnn import build_edge_inputs
from repro.core.halo import HaloSpec, halo_sync_reference
from repro.core.mesh_gen import edge_features as static_edge_features
from repro.core.partition import PartitionedGraphs, gather_node_features


def rank_static_inputs(pg: PartitionedGraphs, coords: np.ndarray,
                       seg_layout: tuple | None = None,
                       split: bool = False) -> Dict[str, jnp.ndarray]:
    """Stacked per-rank static arrays: halo/edge metadata + edge geometry feats.

    ``seg_layout=(block_n, block_e)`` additionally attaches the cached
    compact gather/scatter index lists (``seg_perm``/``seg_src``/``seg_dst``)
    for the fused NMP backend — the host-side sort runs once per partition
    (memoized on ``pg``), not per step.

    ``split=True`` attaches the interior/boundary edge split the overlap
    schedule consumes (see ``PartitionedGraphs.interior_split``).
    """
    meta = {k: jnp.asarray(v)
            for k, v in pg.device_arrays(seg_layout=seg_layout,
                                         split=split).items()}
    coords_r = gather_node_features(pg, coords)
    ef = []
    for r in range(pg.R):
        e = np.stack([pg.edge_src[r], pg.edge_dst[r]], axis=-1)
        ef.append(static_edge_features(coords_r[r], e) * pg.edge_mask[r][:, None])
    meta["static_edge_feats"] = jnp.asarray(np.stack(ef).astype(np.float32))
    return meta


def vcycle_stacked(
    coarse_params,
    h: jnp.ndarray,                  # [R, N_pad, H]
    meta: Dict[str, jnp.ndarray],    # flat multilevel stacked metadata
    halo: HaloSpec,
    *,
    backend: str = "xla",
    interpret: bool = False,
    block_n: int = 128,
    schedule: str = "blocking",
    precision: str = "fp32",
) -> jnp.ndarray:
    """Single-device oracle for ``consistent_mp.multilevel_vcycle``: ranks
    loop in python and every exchange — the restriction/prolongation
    completion halo-sums included — goes through ``halo_sync_reference``
    over each level's stacked A2A arrays.  The production shard_map V-cycle
    must agree with this exactly (tests/test_multilevel.py, values and
    gradients, both backends x both schedules)."""
    from repro.core.consistent_mp import (
        edge_update_aggregate, edge_update_aggregate_part, level_meta,
        node_update, prolong_aggregate, restrict_aggregate)

    n_levels = len(coarse_params) + 1
    metas = [level_meta(meta, lvl) for lvl in range(n_levels)]
    R = h.shape[0]
    part_kw = dict(backend=backend, interpret=interpret, block_n=block_n,
                   precision=precision)

    def smooth(lp, hl, el, m):
        """One consistent NMP layer over the stacked ranks (reference halo)."""
        if schedule == "overlap":
            outs_b = [edge_update_aggregate_part(
                lp, hl[r], el[r], {k: v[r] for k, v in m.items()}, "bnd",
                **part_kw) for r in range(R)]
            outs_i = [edge_update_aggregate_part(
                lp, hl[r], el[r], {k: v[r] for k, v in m.items()}, "int",
                **part_kw) for r in range(R)]
            agg = jnp.stack([o[1] for o in outs_b])
            if halo.mode != "none":
                agg = halo_sync_reference(agg, m, halo, combine="sum")
            agg = agg + jnp.stack([o[1] for o in outs_i])
            e_new = jnp.stack([b[0] + i[0] for b, i in zip(outs_b, outs_i)])
        else:
            outs = [edge_update_aggregate(
                lp, hl[r], el[r], {k: v[r] for k, v in m.items()}, **part_kw)
                for r in range(R)]
            agg = jnp.stack([o[1] for o in outs])
            if halo.mode != "none":
                agg = halo_sync_reference(agg, m, halo, combine="sum")
            e_new = jnp.stack([o[0] for o in outs])
        h_new = jnp.stack([
            node_update(lp, hl[r], agg[r], {k: v[r] for k, v in m.items()})
            for r in range(R)])
        return h_new, e_new

    states = [h]
    for lvl in range(1, n_levels):
        m = metas[lvl]
        n_pad_c = m["node_mask"].shape[-1]
        c = jnp.stack([restrict_aggregate(
            states[-1][r], {k: v[r] for k, v in m.items()}, n_pad_c)
            for r in range(R)])
        if halo.mode != "none":
            c = halo_sync_reference(c, m, halo, combine="sum")
        c = c * m["node_mask"][..., None]
        p = coarse_params[lvl - 1]
        e = jnp.stack([
            rnn.mlp(p["edge_enc"], m["static_edge_feats"][r])
            * m["edge_mask"][r][..., None] for r in range(R)])
        for lp in p["mp"]:
            c, e = smooth(lp, c, e, m)
        states.append(c)
    for lvl in range(n_levels - 1, 0, -1):
        mt = metas[lvl]
        mf = metas[lvl - 1]
        n_pad_f = mf["node_mask"].shape[-1]
        up = jnp.stack([prolong_aggregate(
            states[lvl][r], {k: v[r] for k, v in mt.items()}, n_pad_f)
            for r in range(R)])
        if halo.mode != "none":
            up = halo_sync_reference(up, mf, halo, combine="sum")
        states[lvl - 1] = (states[lvl - 1] + up) * mf["node_mask"][..., None]
    return states[0]


def gnn_forward_stacked(
    params: rnn.Params,
    x: jnp.ndarray,                  # [R, N_pad, F_x]
    meta: Dict[str, jnp.ndarray],    # stacked arrays incl. static_edge_feats
    halo: HaloSpec,
    *,
    backend: str = "xla",
    interpret: bool = False,
    block_n: int = 128,
    schedule: str = "blocking",
    precision: str = "fp32",
) -> jnp.ndarray:
    """Paper GNN forward over all R ranks on one device (reference halo).

    The Eq. 4a+4b hot loop goes through the same ``edge_update_aggregate``
    the production shard_map path uses, so ``backend="fused"`` exercises the
    Pallas kernel under this single-device oracle too.  ``schedule="overlap"``
    runs the interior/boundary split with the exchange restricted to the
    boundary partial aggregate — the same dataflow the production overlap
    path hides communication behind (``meta`` then needs the split arrays
    from ``rank_static_inputs(..., split=True)``).  Params carrying coarse
    levels run the multilevel V-cycle through :func:`vcycle_stacked` before
    the decoder (``meta`` from
    ``repro.core.coarsen.multilevel_static_inputs``).
    """
    from repro.core.consistent_mp import (
        edge_update_aggregate, edge_update_aggregate_part, level_meta,
        node_update)

    full_meta = meta
    if "coarse" in params:
        meta = level_meta(meta, 0)
    R = x.shape[0]
    hs, es = [], []
    for r in range(R):
        meta_r = {k: v[r] for k, v in meta.items()}
        e_in = build_edge_inputs(x[r], meta_r["static_edge_feats"], meta_r)
        hs.append(rnn.mlp(params["node_enc"], x[r]) * meta_r["node_mask"][..., None])
        es.append(rnn.mlp(params["edge_enc"], e_in) * meta_r["edge_mask"][..., None])
    h, e = jnp.stack(hs), jnp.stack(es)

    part_kw = dict(backend=backend, interpret=interpret, block_n=block_n,
                   precision=precision)
    for lp in params["mp"]:
        if schedule == "overlap":
            e_bnd, agg_bnd, e_int, agg_int = [], [], [], []
            for r in range(R):
                meta_r = {k: v[r] for k, v in meta.items()}
                eb, ab = edge_update_aggregate_part(
                    lp, h[r], e[r], meta_r, "bnd", **part_kw)
                ei, ai = edge_update_aggregate_part(
                    lp, h[r], e[r], meta_r, "int", **part_kw)
                e_bnd.append(eb)
                agg_bnd.append(ab)
                e_int.append(ei)
                agg_int.append(ai)
            agg = jnp.stack(agg_bnd)
            if halo.mode != "none":
                agg = halo_sync_reference(agg, meta, halo, combine="sum")
            agg = agg + jnp.stack(agg_int)
            new_e = [b + i for b, i in zip(e_bnd, e_int)]
        elif schedule == "blocking":
            new_e, aggs = [], []
            for r in range(R):
                meta_r = {k: v[r] for k, v in meta.items()}
                er, agg_r = edge_update_aggregate(
                    lp, h[r], e[r], meta_r, **part_kw)
                aggs.append(agg_r)
                new_e.append(er)
            agg = jnp.stack(aggs)
            if halo.mode != "none":
                agg = halo_sync_reference(agg, meta, halo, combine="sum")
        else:
            raise ValueError(f"unknown NMP schedule {schedule!r}")
        h = jnp.stack([
            node_update(lp, h[r], agg[r], {k: v[r] for k, v in meta.items()})
            for r in range(R)
        ])
        e = jnp.stack(new_e)

    if "coarse" in params:
        h = vcycle_stacked(params["coarse"], h, full_meta, halo,
                           backend=backend, interpret=interpret,
                           block_n=block_n, schedule=schedule,
                           precision=precision)
    return jnp.stack([rnn.mlp(params["node_dec"], h[r]) * meta["node_mask"][r][..., None]
                      for r in range(R)])


def consistent_loss_stacked(y: jnp.ndarray, y_hat: jnp.ndarray,
                            meta: Dict[str, jnp.ndarray], fy: int) -> jnp.ndarray:
    """Eq. 6 with the psum replaced by an explicit sum over the stacked ranks."""
    err2 = jnp.sum((y - y_hat) ** 2, axis=-1)          # [R, N_pad]
    s = jnp.sum(err2 * meta["node_inv_mult"])
    n_eff = jnp.sum(meta["node_inv_mult"])
    return s / (n_eff * fy)


def loss_and_grad_stacked(
    params: rnn.Params,
    x: jnp.ndarray,
    y_hat: jnp.ndarray,
    meta: Dict[str, jnp.ndarray],
    halo: HaloSpec,
    fy: int,
    backend: str = "xla",
    interpret: bool = False,
    block_n: int = 128,
    schedule: str = "blocking",
    precision: str = "fp32",
) -> Tuple[jnp.ndarray, jnp.ndarray, rnn.Params]:
    def f(p):
        y = gnn_forward_stacked(p, x, meta, halo, backend=backend,
                                interpret=interpret, block_n=block_n,
                                schedule=schedule, precision=precision)
        return consistent_loss_stacked(y, y_hat, meta, fy), y
    (loss, y), grads = jax.value_and_grad(f, has_aux=True)(params)
    return loss, y, grads
