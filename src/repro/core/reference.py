"""Single-device stacked-rank reference evaluation of the consistent GNN.

Runs the R-rank partitioned model on ONE device by looping ranks in python
and emulating the halo exchange with plain gathers (``halo_sync_reference``).
This is the oracle used by tests and the Fig. 6 benchmarks; the production
shard_map path must agree with it exactly (same arithmetic, real collectives).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn as rnn
from repro.core.gnn import build_edge_inputs
from repro.core.halo import HaloSpec, halo_sync_reference
from repro.core.mesh_gen import edge_features as static_edge_features
from repro.core.partition import PartitionedGraphs, gather_node_features


def rank_static_inputs(pg: PartitionedGraphs, coords: np.ndarray,
                       seg_layout: tuple | None = None,
                       split: bool = False) -> Dict[str, jnp.ndarray]:
    """Stacked per-rank static arrays: halo/edge metadata + edge geometry feats.

    ``seg_layout=(block_n, block_e)`` additionally attaches the cached
    compact gather/scatter index lists (``seg_perm``/``seg_src``/``seg_dst``)
    for the fused NMP backend — the host-side sort runs once per partition
    (memoized on ``pg``), not per step.

    ``split=True`` attaches the interior/boundary edge split the overlap
    schedule consumes (see ``PartitionedGraphs.interior_split``).
    """
    meta = {k: jnp.asarray(v)
            for k, v in pg.device_arrays(seg_layout=seg_layout,
                                         split=split).items()}
    coords_r = gather_node_features(pg, coords)
    ef = []
    for r in range(pg.R):
        e = np.stack([pg.edge_src[r], pg.edge_dst[r]], axis=-1)
        ef.append(static_edge_features(coords_r[r], e) * pg.edge_mask[r][:, None])
    meta["static_edge_feats"] = jnp.asarray(np.stack(ef).astype(np.float32))
    return meta


def gnn_forward_stacked(
    params: rnn.Params,
    x: jnp.ndarray,                  # [R, N_pad, F_x]
    meta: Dict[str, jnp.ndarray],    # stacked arrays incl. static_edge_feats
    halo: HaloSpec,
    *,
    backend: str = "xla",
    interpret: bool = False,
    block_n: int = 128,
    schedule: str = "blocking",
    precision: str = "fp32",
) -> jnp.ndarray:
    """Paper GNN forward over all R ranks on one device (reference halo).

    The Eq. 4a+4b hot loop goes through the same ``edge_update_aggregate``
    the production shard_map path uses, so ``backend="fused"`` exercises the
    Pallas kernel under this single-device oracle too.  ``schedule="overlap"``
    runs the interior/boundary split with the exchange restricted to the
    boundary partial aggregate — the same dataflow the production overlap
    path hides communication behind (``meta`` then needs the split arrays
    from ``rank_static_inputs(..., split=True)``).
    """
    from repro.core.consistent_mp import (
        edge_update_aggregate, edge_update_aggregate_part, node_update)

    R = x.shape[0]
    hs, es = [], []
    for r in range(R):
        meta_r = {k: v[r] for k, v in meta.items()}
        e_in = build_edge_inputs(x[r], meta_r["static_edge_feats"], meta_r)
        hs.append(rnn.mlp(params["node_enc"], x[r]) * meta_r["node_mask"][..., None])
        es.append(rnn.mlp(params["edge_enc"], e_in) * meta_r["edge_mask"][..., None])
    h, e = jnp.stack(hs), jnp.stack(es)

    part_kw = dict(backend=backend, interpret=interpret, block_n=block_n,
                   precision=precision)
    for lp in params["mp"]:
        if schedule == "overlap":
            e_bnd, agg_bnd, e_int, agg_int = [], [], [], []
            for r in range(R):
                meta_r = {k: v[r] for k, v in meta.items()}
                eb, ab = edge_update_aggregate_part(
                    lp, h[r], e[r], meta_r, "bnd", **part_kw)
                ei, ai = edge_update_aggregate_part(
                    lp, h[r], e[r], meta_r, "int", **part_kw)
                e_bnd.append(eb)
                agg_bnd.append(ab)
                e_int.append(ei)
                agg_int.append(ai)
            agg = jnp.stack(agg_bnd)
            if halo.mode != "none":
                agg = halo_sync_reference(agg, meta, halo, combine="sum")
            agg = agg + jnp.stack(agg_int)
            new_e = [b + i for b, i in zip(e_bnd, e_int)]
        elif schedule == "blocking":
            new_e, aggs = [], []
            for r in range(R):
                meta_r = {k: v[r] for k, v in meta.items()}
                er, agg_r = edge_update_aggregate(
                    lp, h[r], e[r], meta_r, **part_kw)
                aggs.append(agg_r)
                new_e.append(er)
            agg = jnp.stack(aggs)
            if halo.mode != "none":
                agg = halo_sync_reference(agg, meta, halo, combine="sum")
        else:
            raise ValueError(f"unknown NMP schedule {schedule!r}")
        h = jnp.stack([
            node_update(lp, h[r], agg[r], {k: v[r] for k, v in meta.items()})
            for r in range(R)
        ])
        e = jnp.stack(new_e)

    return jnp.stack([rnn.mlp(params["node_dec"], h[r]) * meta["node_mask"][r][..., None]
                      for r in range(R)])


def consistent_loss_stacked(y: jnp.ndarray, y_hat: jnp.ndarray,
                            meta: Dict[str, jnp.ndarray], fy: int) -> jnp.ndarray:
    """Eq. 6 with the psum replaced by an explicit sum over the stacked ranks."""
    err2 = jnp.sum((y - y_hat) ** 2, axis=-1)          # [R, N_pad]
    s = jnp.sum(err2 * meta["node_inv_mult"])
    n_eff = jnp.sum(meta["node_inv_mult"])
    return s / (n_eff * fy)


def loss_and_grad_stacked(
    params: rnn.Params,
    x: jnp.ndarray,
    y_hat: jnp.ndarray,
    meta: Dict[str, jnp.ndarray],
    halo: HaloSpec,
    fy: int,
    backend: str = "xla",
    interpret: bool = False,
    block_n: int = 128,
    schedule: str = "blocking",
    precision: str = "fp32",
) -> Tuple[jnp.ndarray, jnp.ndarray, rnn.Params]:
    def f(p):
        y = gnn_forward_stacked(p, x, meta, halo, backend=backend,
                                interpret=interpret, block_n=block_n,
                                schedule=schedule, precision=precision)
        return consistent_loss_stacked(y, y_hat, meta, fy), y
    (loss, y), grads = jax.value_and_grad(f, has_aux=True)(params)
    return loss, y, grads
