"""Differentiable halo exchanges (Sec. II-B Eq. 4c-d), TPU-native.

Three modes, matching the paper's study:

* ``NONE``     — skip the exchange: the *inconsistent* baseline.
* ``A2A``      — ``jax.lax.all_to_all`` with equal-size buffers to every rank
                 (the paper's naive differentiable baseline).
* ``NEIGHBOR`` — the paper's N-A2A insight adapted to ICI: K rounds of
                 ``jax.lax.ppermute`` (collective-permute = neighbor DMA),
                 one round per color of the rank-adjacency edge coloring.
                 K is bounded by the max number of neighboring ranks
                 (7-15 in paper Table II), independent of R.

All modes are differentiable: JAX's transpose rules for ppermute/all_to_all
provide Eq. 3's gradient consistency with no custom VJP code (the torch
implementation needed torch.distributed.nn for this).

The "synchronization" (Eq. 4d) is fused into the exchange: received buffers
are scatter-added directly onto the owning local rows, which is arithmetically
identical to materializing halo rows then summing coincident groups. Combine
op 'max' supports the consistent edge-softmax extension (Sec. 4 of DESIGN.md).

``wire_dtype`` optionally compresses on-wire buffers (e.g. bf16) —
a beyond-paper optimization measured in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NONE = "none"
A2A = "a2a"
NEIGHBOR = "neighbor"

_NEG = -1e30


@dataclasses.dataclass(frozen=True)
class HaloSpec:
    """Static (trace-time) halo configuration: mode, axis, ppermute rounds.

    ``rounds2d`` enables TWO-LEVEL halo exchange (sub-graphs spread over two
    mesh axes, e.g. 16x16 = 256 spatial partitions): each round is a sequence
    of (axis, perm) hops — a uniform grid shift (dd, dm) is routed as one
    ppermute along each axis (torus routing; diagonal neighbor pairs take
    two hops). Used with mode NEIGHBOR; overrides ``perms`` when non-empty.
    """
    mode: str                                  # none | a2a | neighbor | auto
    axis: str = "graph"                        # mesh axis carrying sub-graphs
    perms: Tuple[Tuple[Tuple[int, int], ...], ...] = ()   # per-round ppermute pairs
    wire_dtype: Optional[jnp.dtype] = None     # e.g. jnp.bfloat16 compression
    rounds2d: Tuple = ()   # per round: ((axis, ((s,d),...)), ...) hop chain
    # packed wire format (NEIGHBOR only): per-round bucketed pk{k}_* arrays
    # instead of the dense global-max-width nbr_* arrays, with the pack
    # (gather) and unpack (scatter-add) fused into Pallas kernels for
    # combine="sum".  Pure data movement: bitwise-equal to the dense path.
    packed: bool = False
    interpret: bool = False                    # run packed kernels interpreted


def _scatter_combine(a: jnp.ndarray, idx: jnp.ndarray, upd: jnp.ndarray, op: str) -> jnp.ndarray:
    """Scatter ``upd`` rows into ``a`` at node rows ``idx`` along axis -2."""
    if a.ndim == upd.ndim + 0 and a.ndim == 3:  # [B, N, F] with idx [M]
        if op == "sum":
            return a.at[:, idx].add(upd)
        return a.at[:, idx].max(upd)
    if op == "sum":
        return a.at[idx].add(upd)
    return a.at[idx].max(upd)


def _maybe_compress(buf: jnp.ndarray, spec: HaloSpec) -> Tuple[jnp.ndarray, jnp.dtype]:
    if spec.wire_dtype is not None and buf.dtype != spec.wire_dtype:
        return buf.astype(spec.wire_dtype), buf.dtype
    return buf, buf.dtype


def _wire_encode(buf: jnp.ndarray, mask: jnp.ndarray, spec: HaloSpec,
                 combine: str) -> Tuple[jnp.ndarray, jnp.dtype]:
    """Send-side wire prep shared by every mode: mask padding slots to the
    combine's neutral (0 for sum, ``_NEG`` for max), THEN compress to the
    wire dtype.  Masking before compression means only the neutral — never a
    real value polluted by it — crosses the wire on padding slots; the recv
    side re-masks with a fresh full-precision neutral (see ``_wire_decode``),
    so wire compression of the neutral itself cannot drift into results."""
    m = mask[..., None]
    buf = buf * m if combine == "sum" else jnp.where(m > 0, buf, _NEG)
    return _maybe_compress(buf, spec)


def _wire_decode(got: jnp.ndarray, mask: jnp.ndarray, spec: HaloSpec,
                 combine: str, orig_dtype) -> jnp.ndarray:
    """Recv-side: restore the compute dtype, then re-neutralize masked slots
    (a ppermute non-destination receives zeros; under combine="max" a raw
    zero would beat negative values, so masked rows are forced to ``_NEG``
    in FULL precision — the wire-compressed neutral never survives here)."""
    got = got.astype(orig_dtype)
    rm = mask[..., None]
    return got * rm if combine == "sum" else jnp.where(rm > 0, got, _NEG)


def _round_arrays(graph, spec: HaloSpec, k: int):
    """Round-``k`` (send_idx, send_mask, recv_idx, recv_mask) in the wire
    format the spec selects: bucketed per-round ``pk{k}_*`` arrays when
    packed, slices of the dense ``nbr_*`` arrays otherwise."""
    if spec.packed:
        return (graph[f"pk{k}_send_idx"], graph[f"pk{k}_send_mask"],
                graph[f"pk{k}_recv_idx"], graph[f"pk{k}_recv_mask"])
    return (graph["nbr_send_idx"][k], graph["nbr_send_mask"][k],
            graph["nbr_recv_idx"][k], graph["nbr_recv_mask"][k])


def _use_fused_pack(spec: HaloSpec, combine: str) -> bool:
    # the fused Pallas pack/unpack implements masked gather + scatter-ADD;
    # combine="max" keeps the XLA where/scatter-max path (still on the
    # narrow packed arrays, so the wire-volume win is format-level)
    return spec.packed and combine == "sum"


def _gather_wire(a: jnp.ndarray, idx: jnp.ndarray, mask: jnp.ndarray,
                 spec: HaloSpec, combine: str,
                 batched: bool) -> Tuple[jnp.ndarray, jnp.dtype]:
    """Pack boundary rows into one round's send buffer (Eq. 4c send side)."""
    if _use_fused_pack(spec, combine):
        from repro.kernels.halo_pack.ops import halo_pack
        if batched:
            buf = jnp.stack([halo_pack(a[b], idx, mask,
                                       interpret=spec.interpret)
                             for b in range(a.shape[0])])
        else:
            buf = halo_pack(a, idx, mask, interpret=spec.interpret)
        return _maybe_compress(buf, spec)
    buf = a[:, idx] if batched else a[idx]
    return _wire_encode(buf, mask, spec, combine)


def _scatter_wire(out: jnp.ndarray, idx: jnp.ndarray, mask: jnp.ndarray,
                  got: jnp.ndarray, spec: HaloSpec, combine: str,
                  orig_dtype, batched: bool) -> jnp.ndarray:
    """Apply one round's received buffer onto the local rows (Eq. 4d)."""
    got = got.astype(orig_dtype)
    if _use_fused_pack(spec, combine):
        from repro.kernels.halo_pack.ops import halo_unpack_add
        if batched:
            return jnp.stack([halo_unpack_add(out[b], got[b], idx, mask,
                                              interpret=spec.interpret)
                              for b in range(out.shape[0])])
        return halo_unpack_add(out, got, idx, mask, interpret=spec.interpret)
    rm = mask[..., None]
    upd = got * rm if combine == "sum" else jnp.where(rm > 0, got, _NEG)
    return _scatter_combine(out, idx, upd, combine)


def halo_sync(
    a: jnp.ndarray,
    graph,
    spec: HaloSpec,
    combine: str = "sum",
) -> jnp.ndarray:
    """Exchange + synchronize local aggregates across coincident node copies.

    Args:
      a: local aggregates, [N_pad, F] or [B, N_pad, F] (per shard).
      graph: the rank-local ``ShardedGraph`` (leading rank axis already
        sliced away by shard_map / ``graph.rank_local()``) carrying the halo
        arrays a2a_send_idx [R, Bf], ..., nbr_send_idx [K, Bn], ...
      spec: HaloSpec (mode + static perms).
      combine: 'sum' (Eq. 4d) or 'max' (consistent softmax extension).
    Returns:
      a* with every coincident copy holding the combined value.
    """
    if spec.mode == NONE:
        return a
    _check_spec(spec)

    batched = a.ndim == 3
    neutral = 0.0 if combine == "sum" else _NEG

    if spec.mode == A2A:
        send_idx = graph["a2a_send_idx"]      # [R, Bf]
        recv_idx = graph["a2a_recv_idx"]
        recv_mask = graph["a2a_recv_mask"]
        buf = a[:, send_idx] if batched else a[send_idx]   # [(B,) R, Bf, F]
        buf, orig_dtype = _wire_encode(buf, graph["a2a_send_mask"], spec,
                                       combine)
        if batched:
            # all_to_all splits the rank axis; move it leading
            buf = jnp.moveaxis(buf, 1, 0)     # [R, B, Bf, F]
            got = jax.lax.all_to_all(buf, spec.axis, split_axis=0, concat_axis=0)
            got = jnp.moveaxis(got, 0, 1).astype(orig_dtype)   # [B, R, Bf, F]
            got_flat = got.reshape(got.shape[0], -1, got.shape[-1])
        else:
            got = jax.lax.all_to_all(buf, spec.axis, split_axis=0, concat_axis=0)
            got = got.astype(orig_dtype)
            got_flat = got.reshape(-1, got.shape[-1])
        rm = recv_mask.reshape(-1)[..., None]
        upd = got_flat * rm if combine == "sum" else jnp.where(rm > 0, got_flat, neutral)
        return _scatter_combine(a, recv_idx.reshape(-1), upd, combine)

    if spec.mode == NEIGHBOR and spec.rounds2d:
        out = a
        for k, hops in enumerate(spec.rounds2d):
            send_idx, send_mask, recv_idx, recv_mask = \
                _round_arrays(graph, spec, k)
            buf, orig_dtype = _gather_wire(a, send_idx, send_mask, spec,
                                           combine, batched)
            for axis, perm in hops:                 # chained torus hops
                buf = jax.lax.ppermute(buf, axis, perm=list(perm))
            out = _scatter_wire(out, recv_idx, recv_mask, buf, spec,
                                combine, orig_dtype, batched)
        return out

    if spec.mode == NEIGHBOR:
        out = a
        for k, perm in enumerate(spec.perms):
            if not perm:
                continue
            send_idx, send_mask, recv_idx, recv_mask = \
                _round_arrays(graph, spec, k)
            buf, orig_dtype = _gather_wire(a, send_idx, send_mask, spec,
                                           combine, batched)
            got = jax.lax.ppermute(buf, spec.axis, perm=list(perm))
            out = _scatter_wire(out, recv_idx, recv_mask, got, spec,
                                combine, orig_dtype, batched)
        return out

    raise ValueError(f"unknown halo mode {spec.mode!r}")


def _check_spec(spec: HaloSpec):
    if spec.mode == "auto":
        raise ValueError(
            "halo mode 'auto' must be resolved before the exchange runs: "
            "call plan.autotune(graph) after ShardedGraph.build (the "
            "training loop does this for you)")
    if spec.packed and spec.mode == A2A:
        raise ValueError(
            "HaloSpec(packed=True) is neighbor-only: jax.lax.all_to_all "
            "needs uniform per-rank buffers, which is exactly the O(R*Bf) "
            "wire waste the packed format removes — use mode='neighbor'")


def halo_spec_from_plan(plan, mode: str, axis: str = "graph",
                        wire_dtype=None, packed: bool = False) -> HaloSpec:
    """Build the static HaloSpec from a host-side ``HaloPlan``."""
    perms = tuple(tuple(( int(a), int(b)) for a, b in rnd) for rnd in plan.perms)
    return HaloSpec(mode=mode, axis=axis, perms=perms, wire_dtype=wire_dtype,
                    packed=packed)


def halo_sync_reference(a_stacked: jnp.ndarray, graph, spec: HaloSpec,
                        combine: str = "sum") -> jnp.ndarray:
    """Single-device oracle for halo_sync over a stacked [R, ...] graph.

    Emulates the A2A exchange with plain gathers (no collectives); used to run
    consistency tests fast on one device and as the vmap-style reference the
    shard_map path is checked against.

    The synchronization sums contributions in CANONICAL ascending-rank order
    (own partial spliced in at its rank position, zero base), so every
    coincident copy of a node evaluates the identical floating-point
    expression: copy agreement is bitwise-exact for ANY copy multiplicity,
    which ``BENCH_partition.json`` asserts as ``max_abs_err == 0.0``.  (The
    production ``halo_sync`` seeds the scatter-add with the local aggregate
    instead — same math, own-first grouping — so 3+-way copies may differ
    from this oracle in the last ulp; tests compare with tolerances.)
    """
    R = a_stacked.shape[0]
    send_idx = graph["a2a_send_idx"]            # [R, R, Bf]
    send_mask = graph["a2a_send_mask"]
    recv_idx = graph["a2a_recv_idx"]
    recv_mask = graph["a2a_recv_mask"]
    neutral = 0.0 if combine == "sum" else _NEG
    out = (jnp.zeros_like(a_stacked) if combine == "sum"
           else jnp.full_like(a_stacked, _NEG))
    batched = a_stacked.ndim == 4               # [R, B, N, F]
    for r in range(R):
        for s in range(R):
            if s == r:
                # own partial, at its canonical rank position (full rows:
                # 0 + x is exact, so un-shared rows pass through bitwise)
                new_r = (out[r] + a_stacked[r] if combine == "sum"
                         else jnp.maximum(out[r], a_stacked[r]))
                out = out.at[r].set(new_r)
                continue
            # buffer sent by rank s to rank r
            idx_s = send_idx[s, r]
            m_s = send_mask[s, r][..., None]
            buf = a_stacked[s][:, idx_s] if batched else a_stacked[s][idx_s]
            buf = buf * m_s if combine == "sum" else jnp.where(m_s > 0, buf, neutral)
            if spec.wire_dtype is not None:
                buf = buf.astype(spec.wire_dtype).astype(a_stacked.dtype)
            rm = recv_mask[r, s][..., None]
            upd = buf * rm if combine == "sum" else jnp.where(rm > 0, buf, neutral)
            tgt = recv_idx[r, s]
            if batched:
                new_r = out[r].at[:, tgt].add(upd) if combine == "sum" else out[r].at[:, tgt].max(upd)
            else:
                new_r = out[r].at[tgt].add(upd) if combine == "sum" else out[r].at[tgt].max(upd)
            out = out.at[r].set(new_r)
    return out


def halo_sync_stacked(a_stacked: jnp.ndarray, graph, spec: HaloSpec,
                      combine: str = "sum", rounds_perms=None) -> jnp.ndarray:
    """MODE-FAITHFUL single-device emulator of the production ``halo_sync``
    over a stacked ``[R, N, F]`` graph (no collectives).

    Where :func:`halo_sync_reference` is the canonical-order A2A-array
    oracle (zero base, ascending-rank summation — used for copy-agreement
    assertions), this function follows the PRODUCTION per-rank arithmetic of
    whichever mode/wire format ``spec`` selects: per-rank gathers, wire
    masking + compression, the exchange (emulated by indexing the senders'
    buffers), and a scatter-combine seeded from the local aggregate.  That
    makes it the right probe body for the (schedule × halo-mode × wire)
    autotuner and the right harness for packed-vs-dense bitwise tests — the
    math per rank is the one the ``shard_map`` path executes, including the
    fused Pallas pack/unpack when ``spec.packed`` and combine="sum".

    ``rounds2d`` specs additionally need ``rounds_perms`` — the flat
    per-round (src, dst) rank pairs from
    ``repro.core.partition.flat_rounds2d_perms(grid)`` — because the
    per-axis hop chains are only meaningful on a live device mesh.
    """
    if spec.mode == NONE:
        return a_stacked
    _check_spec(spec)
    if a_stacked.ndim != 3:
        raise ValueError("halo_sync_stacked expects a stacked [R, N, F] "
                         f"aggregate, got shape {a_stacked.shape}")
    R = a_stacked.shape[0]
    neutral = 0.0 if combine == "sum" else _NEG

    if spec.mode == A2A:
        send_idx = graph["a2a_send_idx"]        # [R, R, Bf]
        recv_idx = graph["a2a_recv_idx"]
        recv_mask = graph["a2a_recv_mask"]
        bufs = []
        for r in range(R):
            buf = a_stacked[r][send_idx[r]]     # [R, Bf, F]
            buf, orig_dtype = _wire_encode(buf, graph["a2a_send_mask"][r],
                                           spec, combine)
            bufs.append(buf)
        out = a_stacked
        for r in range(R):
            # what all_to_all delivers to rank r: sender s's slice r
            got = jnp.stack([bufs[s][r] for s in range(R)])
            got_flat = got.astype(orig_dtype).reshape(-1, got.shape[-1])
            rm = recv_mask[r].reshape(-1)[..., None]
            upd = (got_flat * rm if combine == "sum"
                   else jnp.where(rm > 0, got_flat, neutral))
            out = out.at[r].set(_scatter_combine(
                a_stacked[r], recv_idx[r].reshape(-1), upd, combine))
        return out

    # NEIGHBOR: per-round disjoint pair exchanges
    if spec.rounds2d:
        if rounds_perms is None:
            raise ValueError(
                "halo_sync_stacked: a rounds2d spec needs the flat per-round "
                "(src, dst) pairs — pass rounds_perms="
                "flat_rounds2d_perms(grid) (repro.core.partition)")
        rounds = rounds_perms
    else:
        rounds = spec.perms
    out = a_stacked
    for k, perm in enumerate(rounds):
        if not perm:
            continue
        src_of = {int(d): int(s) for (s, d) in perm}
        new_out = out
        for r in range(R):
            s = src_of.get(r)
            if s is None:
                continue   # non-destination ranks receive zeros -> no-op
            sidx, smask, _, _ = (x[s] for x in _stacked_round_arrays(
                graph, spec, k))
            _, _, ridx, rmask = (x[r] for x in _stacked_round_arrays(
                graph, spec, k))
            # production gathers from the ORIGINAL aggregate each round and
            # scatters into the running result
            buf, orig_dtype = _gather_wire(a_stacked[s], sidx, smask, spec,
                                           combine, batched=False)
            new_out = new_out.at[r].set(_scatter_wire(
                out[r], ridx, rmask, buf, spec, combine, orig_dtype,
                batched=False))
        out = new_out
    return out


def _stacked_round_arrays(graph, spec: HaloSpec, k: int):
    """Stacked-graph variant of ``_round_arrays`` (leading rank axis kept)."""
    if spec.packed:
        return (graph[f"pk{k}_send_idx"], graph[f"pk{k}_send_mask"],
                graph[f"pk{k}_recv_idx"], graph[f"pk{k}_recv_mask"])
    return (graph["nbr_send_idx"][:, k], graph["nbr_send_mask"][:, k],
            graph["nbr_recv_idx"][:, k], graph["nbr_recv_mask"][:, k])
