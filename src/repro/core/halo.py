"""Differentiable halo exchanges (Sec. II-B Eq. 4c-d), TPU-native.

Three modes, matching the paper's study:

* ``NONE``     — skip the exchange: the *inconsistent* baseline.
* ``A2A``      — ``jax.lax.all_to_all`` with equal-size buffers to every rank
                 (the paper's naive differentiable baseline).
* ``NEIGHBOR`` — the paper's N-A2A insight adapted to ICI: K rounds of
                 ``jax.lax.ppermute`` (collective-permute = neighbor DMA),
                 one round per color of the rank-adjacency edge coloring.
                 K is bounded by the max number of neighboring ranks
                 (7-15 in paper Table II), independent of R.

All modes are differentiable: JAX's transpose rules for ppermute/all_to_all
provide Eq. 3's gradient consistency with no custom VJP code (the torch
implementation needed torch.distributed.nn for this).

The "synchronization" (Eq. 4d) is fused into the exchange: received buffers
are scatter-added directly onto the owning local rows, which is arithmetically
identical to materializing halo rows then summing coincident groups. Combine
op 'max' supports the consistent edge-softmax extension (Sec. 4 of DESIGN.md).

``wire_dtype`` optionally compresses on-wire buffers (e.g. bf16) —
a beyond-paper optimization measured in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NONE = "none"
A2A = "a2a"
NEIGHBOR = "neighbor"

_NEG = -1e30


@dataclasses.dataclass(frozen=True)
class HaloSpec:
    """Static (trace-time) halo configuration: mode, axis, ppermute rounds.

    ``rounds2d`` enables TWO-LEVEL halo exchange (sub-graphs spread over two
    mesh axes, e.g. 16x16 = 256 spatial partitions): each round is a sequence
    of (axis, perm) hops — a uniform grid shift (dd, dm) is routed as one
    ppermute along each axis (torus routing; diagonal neighbor pairs take
    two hops). Used with mode NEIGHBOR; overrides ``perms`` when non-empty.
    """
    mode: str                                  # none | a2a | neighbor
    axis: str = "graph"                        # mesh axis carrying sub-graphs
    perms: Tuple[Tuple[Tuple[int, int], ...], ...] = ()   # per-round ppermute pairs
    wire_dtype: Optional[jnp.dtype] = None     # e.g. jnp.bfloat16 compression
    rounds2d: Tuple = ()   # per round: ((axis, ((s,d),...)), ...) hop chain


def _scatter_combine(a: jnp.ndarray, idx: jnp.ndarray, upd: jnp.ndarray, op: str) -> jnp.ndarray:
    """Scatter ``upd`` rows into ``a`` at node rows ``idx`` along axis -2."""
    if a.ndim == upd.ndim + 0 and a.ndim == 3:  # [B, N, F] with idx [M]
        if op == "sum":
            return a.at[:, idx].add(upd)
        return a.at[:, idx].max(upd)
    if op == "sum":
        return a.at[idx].add(upd)
    return a.at[idx].max(upd)


def _maybe_compress(buf: jnp.ndarray, spec: HaloSpec) -> Tuple[jnp.ndarray, jnp.dtype]:
    if spec.wire_dtype is not None and buf.dtype != spec.wire_dtype:
        return buf.astype(spec.wire_dtype), buf.dtype
    return buf, buf.dtype


def halo_sync(
    a: jnp.ndarray,
    graph,
    spec: HaloSpec,
    combine: str = "sum",
) -> jnp.ndarray:
    """Exchange + synchronize local aggregates across coincident node copies.

    Args:
      a: local aggregates, [N_pad, F] or [B, N_pad, F] (per shard).
      graph: the rank-local ``ShardedGraph`` (leading rank axis already
        sliced away by shard_map / ``graph.rank_local()``) carrying the halo
        arrays a2a_send_idx [R, Bf], ..., nbr_send_idx [K, Bn], ...
      spec: HaloSpec (mode + static perms).
      combine: 'sum' (Eq. 4d) or 'max' (consistent softmax extension).
    Returns:
      a* with every coincident copy holding the combined value.
    """
    if spec.mode == NONE:
        return a

    batched = a.ndim == 3
    neutral = 0.0 if combine == "sum" else _NEG

    def take(idx):
        return a[:, idx] if batched else a[idx]

    if spec.mode == A2A:
        send_idx = graph["a2a_send_idx"]      # [R, Bf]
        send_mask = graph["a2a_send_mask"]
        recv_idx = graph["a2a_recv_idx"]
        recv_mask = graph["a2a_recv_mask"]
        buf = take(send_idx)                  # [(B,) R, Bf, F]
        m = send_mask[..., None]
        buf = buf * m if combine == "sum" else jnp.where(m > 0, buf, neutral)
        buf, orig_dtype = _maybe_compress(buf, spec)
        if batched:
            # all_to_all splits the rank axis; move it leading
            buf = jnp.moveaxis(buf, 1, 0)     # [R, B, Bf, F]
            got = jax.lax.all_to_all(buf, spec.axis, split_axis=0, concat_axis=0)
            got = jnp.moveaxis(got, 0, 1).astype(orig_dtype)   # [B, R, Bf, F]
            got_flat = got.reshape(got.shape[0], -1, got.shape[-1])
        else:
            got = jax.lax.all_to_all(buf, spec.axis, split_axis=0, concat_axis=0)
            got = got.astype(orig_dtype)
            got_flat = got.reshape(-1, got.shape[-1])
        rm = recv_mask.reshape(-1)[..., None]
        upd = got_flat * rm if combine == "sum" else jnp.where(rm > 0, got_flat, neutral)
        return _scatter_combine(a, recv_idx.reshape(-1), upd, combine)

    if spec.mode == NEIGHBOR and spec.rounds2d:
        out = a
        for k, hops in enumerate(spec.rounds2d):
            send_idx = graph["nbr_send_idx"][k]
            send_mask = graph["nbr_send_mask"][k]
            recv_idx = graph["nbr_recv_idx"][k]
            recv_mask = graph["nbr_recv_mask"][k]
            buf = take(send_idx)
            m = send_mask[..., None]
            buf = buf * m if combine == "sum" else jnp.where(m > 0, buf, neutral)
            buf, orig_dtype = _maybe_compress(buf, spec)
            for axis, perm in hops:                 # chained torus hops
                buf = jax.lax.ppermute(buf, axis, perm=list(perm))
            buf = buf.astype(orig_dtype)
            rm = recv_mask[..., None]
            upd = buf * rm if combine == "sum" else jnp.where(rm > 0, buf, neutral)
            out = _scatter_combine(out, recv_idx, upd, combine)
        return out

    if spec.mode == NEIGHBOR:
        out = a
        for k, perm in enumerate(spec.perms):
            if not perm:
                continue
            send_idx = graph["nbr_send_idx"][k]     # [Bn]
            send_mask = graph["nbr_send_mask"][k]
            recv_idx = graph["nbr_recv_idx"][k]
            recv_mask = graph["nbr_recv_mask"][k]
            buf = take(send_idx)
            m = send_mask[..., None]
            buf = buf * m if combine == "sum" else jnp.where(m > 0, buf, neutral)
            buf, orig_dtype = _maybe_compress(buf, spec)
            got = jax.lax.ppermute(buf, spec.axis, perm=list(perm)).astype(orig_dtype)
            rm = recv_mask[..., None]
            upd = got * rm if combine == "sum" else jnp.where(rm > 0, got, neutral)
            out = _scatter_combine(out, recv_idx, upd, combine)
        return out

    raise ValueError(f"unknown halo mode {spec.mode!r}")


def halo_spec_from_plan(plan, mode: str, axis: str = "graph",
                        wire_dtype=None) -> HaloSpec:
    """Build the static HaloSpec from a host-side ``HaloPlan``."""
    perms = tuple(tuple(( int(a), int(b)) for a, b in rnd) for rnd in plan.perms)
    return HaloSpec(mode=mode, axis=axis, perms=perms, wire_dtype=wire_dtype)


def halo_sync_reference(a_stacked: jnp.ndarray, graph, spec: HaloSpec,
                        combine: str = "sum") -> jnp.ndarray:
    """Single-device oracle for halo_sync over a stacked [R, ...] graph.

    Emulates the A2A exchange with plain gathers (no collectives); used to run
    consistency tests fast on one device and as the vmap-style reference the
    shard_map path is checked against.

    The synchronization sums contributions in CANONICAL ascending-rank order
    (own partial spliced in at its rank position, zero base), so every
    coincident copy of a node evaluates the identical floating-point
    expression: copy agreement is bitwise-exact for ANY copy multiplicity,
    which ``BENCH_partition.json`` asserts as ``max_abs_err == 0.0``.  (The
    production ``halo_sync`` seeds the scatter-add with the local aggregate
    instead — same math, own-first grouping — so 3+-way copies may differ
    from this oracle in the last ulp; tests compare with tolerances.)
    """
    R = a_stacked.shape[0]
    send_idx = graph["a2a_send_idx"]            # [R, R, Bf]
    send_mask = graph["a2a_send_mask"]
    recv_idx = graph["a2a_recv_idx"]
    recv_mask = graph["a2a_recv_mask"]
    neutral = 0.0 if combine == "sum" else _NEG
    out = (jnp.zeros_like(a_stacked) if combine == "sum"
           else jnp.full_like(a_stacked, _NEG))
    batched = a_stacked.ndim == 4               # [R, B, N, F]
    for r in range(R):
        for s in range(R):
            if s == r:
                # own partial, at its canonical rank position (full rows:
                # 0 + x is exact, so un-shared rows pass through bitwise)
                new_r = (out[r] + a_stacked[r] if combine == "sum"
                         else jnp.maximum(out[r], a_stacked[r]))
                out = out.at[r].set(new_r)
                continue
            # buffer sent by rank s to rank r
            idx_s = send_idx[s, r]
            m_s = send_mask[s, r][..., None]
            buf = a_stacked[s][:, idx_s] if batched else a_stacked[s][idx_s]
            buf = buf * m_s if combine == "sum" else jnp.where(m_s > 0, buf, neutral)
            if spec.wire_dtype is not None:
                buf = buf.astype(spec.wire_dtype).astype(a_stacked.dtype)
            rm = recv_mask[r, s][..., None]
            upd = buf * rm if combine == "sum" else jnp.where(rm > 0, buf, neutral)
            tgt = recv_idx[r, s]
            if batched:
                new_r = out[r].at[:, tgt].add(upd) if combine == "sum" else out[r].at[:, tgt].max(upd)
            else:
                new_r = out[r].at[tgt].add(upd) if combine == "sum" else out[r].at[tgt].max(upd)
            out = out.at[r].set(new_r)
    return out
