"""Distributed mesh-based graph partitioning with halo metadata (Sec. II-A).

Two partitioners produce the same ``PartitionedGraphs`` structure:

* ``from_element_partition`` — the paper's scheme: elements of an ``SEMMesh``
  are assigned to ranks (NekRS-style slab/pencil/block decompositions); nodes
  on shared element faces become *coincident copies* on every touching rank,
  and face-lattice edges are duplicated across ranks (edge multiplicity
  d_ij > 1, undone by 1/d_ij scaling during aggregation — Eq. 4b).

* ``from_edge_partition`` — beyond-paper generalization to arbitrary graphs:
  directed edges are assigned to ranks (default: owner of the destination
  node); every endpoint gets a local copy on each rank using it. Each edge
  lives on exactly one rank (d_ij = 1) but node copies still require the
  halo aggregate-sum, so the same consistent-NMP machinery applies to any
  GNN architecture (GAT/GraphCast/NequIP/MACE configs use this path).

The halo plan supports the paper's exchange implementations:
  * A2A       — equal-size buffers to *all* ranks (paper's naive baseline);
  * NEIGHBOR  — TPU-native adaptation of the paper's N-A2A: the rank
    adjacency graph is greedily edge-colored; each color becomes one
    ``jax.lax.ppermute`` round in which disjoint rank pairs swap buffers.
    Rounds are O(max rank degree), independent of R (paper Table II).

Everything here is host-side numpy; device arrays are produced by ``pack``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.mesh_gen import SEMMesh, undirected_to_directed


# ---------------------------------------------------------------------------
# containers
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RankGraph:
    """One rank's local sub-graph (host-side, un-padded)."""
    global_ids: np.ndarray       # [N_r] sorted unique global node ids
    edges: np.ndarray            # [E_r, 2] directed edges, local node indices
    edge_inv_mult: np.ndarray    # [E_r] 1/d_ij
    node_inv_mult: np.ndarray    # [N_r] 1/d_i

    @property
    def n_nodes(self) -> int:
        return int(self.global_ids.shape[0])

    @property
    def n_edges(self) -> int:
        return int(self.edges.shape[0])


@dataclasses.dataclass
class HaloPlan:
    """Padded, stacked halo-exchange metadata for R ranks.

    A2A arrays are [R, R, B_a2a]; NEIGHBOR arrays are [R, K, B_nbr] with
    ``perms`` holding one global permutation per round (static python data,
    consumed by ``jax.lax.ppermute``).
    """
    # equal-buffer all-to-all (paper's A2A)
    a2a_send_idx: np.ndarray     # int32 [R, R, B] local node idx to send to rank s
    a2a_send_mask: np.ndarray    # float32 [R, R, B]
    a2a_recv_idx: np.ndarray     # int32 [R, R, B] local idx receiving from rank s
    a2a_recv_mask: np.ndarray    # float32 [R, R, B]
    # neighbor rounds (TPU N-A2A): K ppermute rounds
    perms: List[List[Tuple[int, int]]]            # per round: [(src, dst), ...]
    nbr_send_idx: np.ndarray     # int32 [R, K, B2]
    nbr_send_mask: np.ndarray    # float32 [R, K, B2]
    nbr_recv_idx: np.ndarray     # int32 [R, K, B2]
    nbr_recv_mask: np.ndarray    # float32 [R, K, B2]

    @property
    def n_rounds(self) -> int:
        return len(self.perms)


@dataclasses.dataclass
class PartitionedGraphs:
    """Stacked padded per-rank arrays, ready to shard over the graph mesh axis."""
    R: int
    n_global: int                # unique global nodes (N of Eq. 5)
    global_ids: np.ndarray       # int32 [R, N_pad], -1 padding
    node_mask: np.ndarray        # float32 [R, N_pad]
    node_inv_mult: np.ndarray    # float32 [R, N_pad] (0 on padding)
    edge_src: np.ndarray         # int32 [R, E_pad] (0 on padding)
    edge_dst: np.ndarray         # int32 [R, E_pad]
    edge_mask: np.ndarray        # float32 [R, E_pad]
    edge_inv_mult: np.ndarray    # float32 [R, E_pad] (0 on padding)
    halo: HaloPlan
    # compact gather/scatter index layouts for the fused NMP kernel,
    # memoized per (block_n, block_e, part) — the host-side sort runs once
    # per partition, not once per training step
    _seg_layouts: Dict[Tuple[int, int, str], dict] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    # interior/boundary edge classification for the overlap schedule,
    # memoized (host-side, one pass per partition)
    _int_split: dict | None = dataclasses.field(
        default=None, repr=False, compare=False)
    # bucketed per-round packed halo arrays, memoized per bucket size
    _packed_halos: Dict[int, dict] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    @property
    def n_pad(self) -> int:
        return int(self.global_ids.shape[1])

    @property
    def e_pad(self) -> int:
        return int(self.edge_src.shape[1])

    def interior_split(self) -> dict:
        """Cached interior/boundary classification (overlap-schedule support).

        A node is *boundary* when a coincident copy lives on another rank
        (it appears in some halo send buffer); an edge is *boundary* when its
        destination is a boundary node — its aggregate contribution feeds the
        halo exchange. Interior edges land only on rows the exchange never
        reads or writes, which is what makes the overlap schedule
        arithmetically identical to the blocking one
        (``halo_sync(agg_bnd) + agg_int == halo_sync(agg_bnd + agg_int)``).

        Returns stacked [R, ...] arrays:
          node_bnd_mask  [R, N_pad]  1.0 on boundary nodes;
          edge_bnd_mask / edge_int_mask [R, E_pad] disjoint split of
            edge_mask;
          edge_bnd_idx / edge_int_idx [R, EB] / [R, EI] compacted edge-id
            lists (0 on padding) with edge_bnd_valid / edge_int_valid masks —
            the xla backend gathers each sub-problem through these;
          interior_frac  fraction of real edges that are interior (the share
            of Eq. 4a+4b work overlappable with the exchange).
        """
        if self._int_split is not None:
            return self._int_split
        h = self.halo
        node_bnd = np.zeros((self.R, self.n_pad), dtype=np.float32)
        for r in range(self.R):
            sent = h.a2a_send_idx[r][h.a2a_send_mask[r] > 0]
            node_bnd[r, sent] = 1.0
        node_bnd *= self.node_mask
        edge_bnd = np.take_along_axis(node_bnd, self.edge_dst, axis=1) \
            * self.edge_mask
        edge_int = self.edge_mask - edge_bnd

        def compact(mask):
            ids = [np.nonzero(mask[r] > 0)[0] for r in range(self.R)]
            width = _round_up(max((i.size for i in ids), default=1), 8)
            idx = np.zeros((self.R, width), dtype=np.int32)
            valid = np.zeros((self.R, width), dtype=np.float32)
            for r, i in enumerate(ids):
                idx[r, :i.size] = i
                valid[r, :i.size] = 1.0
            return idx, valid

        bnd_idx, bnd_valid = compact(edge_bnd)
        int_idx, int_valid = compact(edge_int)
        n_real = float(self.edge_mask.sum())
        self._int_split = dict(
            node_bnd_mask=node_bnd,
            edge_bnd_mask=edge_bnd, edge_int_mask=edge_int,
            edge_bnd_idx=bnd_idx, edge_bnd_valid=bnd_valid,
            edge_int_idx=int_idx, edge_int_valid=int_valid,
            interior_frac=float(edge_int.sum()) / n_real if n_real else 0.0,
        )
        return self._int_split

    def segment_layout(self, block_n: int, block_e: int,
                       part: str = "all") -> dict:
        """Cached compact gather/scatter index layout for the fused
        segment-agg kernel (scalar-prefetch DMA gathers).

        Runs ``compact_gather_layout`` once per rank (padding edges are
        routed to an out-of-range sentinel so they are dropped from the
        tiles) and pads the per-rank tile counts to a common maximum so the
        stacked arrays shard over the rank axis — the pad tiles are entirely
        empty (``perm == -1``, src/dst 0) and weight-masked inside the
        kernel. Unlike the old dst-aligned block layout there is no
        per-node-block padding: tile occupancy is E / (T·BE) by
        construction, so no waste metric is recorded.

        ``part`` restricts the layout to one side of the interior/boundary
        split (``"int"`` | ``"bnd"``, see :meth:`interior_split`) — the
        overlap schedule runs the fused kernel once per side, so each side's
        layout must drop the other side's edges.

        ``block_n`` does not shape the compact layout (node rows are
        DMA-gathered individually) but stays in the cache key so callers
        that thread (block_n, block_e) uniformly keep exact memoization.

        Returns {perm [R, T, BE] int32 (-1 = empty slot), src [R, T, BE]
                 int32, dst [R, T, BE] int32, n_tiles, block_n, block_e}.
        """
        key = (int(block_n), int(block_e), part)
        cached = self._seg_layouts.get(key)
        if cached is not None:
            return cached
        from repro.kernels.segment_agg.ops import compact_gather_layout
        if part == "all":
            keep = self.edge_mask
        elif part in ("int", "bnd"):
            keep = self.interior_split()[f"edge_{part}_mask"]
        else:
            raise ValueError(f"unknown layout part {part!r}")
        per_rank = []
        for r in range(self.R):
            # excluded edges get dst = n_pad -> dropped by the layout pass
            dst = np.where(keep[r] > 0, self.edge_dst[r], self.n_pad)
            per_rank.append(compact_gather_layout(
                self.edge_src[r], dst, self.n_pad, block_e))
        nt = max(l["n_tiles"] for l in per_rank)
        perm = np.full((self.R, nt, block_e), -1, dtype=np.int32)
        src = np.zeros((self.R, nt, block_e), dtype=np.int32)
        dst_t = np.zeros((self.R, nt, block_e), dtype=np.int32)
        for r, l in enumerate(per_rank):
            perm[r, :l["n_tiles"]] = l["perm"]
            src[r, :l["n_tiles"]] = l["src"]
            dst_t[r, :l["n_tiles"]] = l["dst"]
        layout = dict(perm=perm, src=src, dst=dst_t, n_tiles=nt,
                      block_n=int(block_n), block_e=int(block_e))
        self._seg_layouts[key] = layout
        return layout

    def packed_halo(self, bucket: int = 8) -> Dict[str, np.ndarray]:
        """Cached bucketed per-round packed halo arrays (the packed wire
        format — see :func:`packed_halo_arrays`).  One dict entry set per
        NEIGHBOR round ``k``: ``pk{k}_send_idx / _send_mask / _recv_idx /
        _recv_mask`` of per-round width ``w_k`` (max real entries over ranks
        in that round, rounded up to ``bucket``) instead of the dense global
        max ``B``."""
        key = int(bucket)
        cached = self._packed_halos.get(key)
        if cached is None:
            h = self.halo
            cached = packed_halo_arrays(dict(
                nbr_send_idx=h.nbr_send_idx, nbr_send_mask=h.nbr_send_mask,
                nbr_recv_idx=h.nbr_recv_idx, nbr_recv_mask=h.nbr_recv_mask,
            ), bucket=bucket)
            self._packed_halos[key] = cached
        return cached

    def wire_bytes(self, mode: str, packed: bool = False, feat_dim: int = 1,
                   wire_dtype=None, bucket: int = 8) -> dict:
        """Per-rank on-wire halo payload for ONE exchange of a
        ``[N, feat_dim]`` aggregate (``partition_quality``-style metric).

        * ``mode="a2a"``: every rank ships its full dense buffer to each of
          the other R-1 ranks — ``(R-1) * B * feat_dim`` elements regardless
          of how many of them are masked padding.
        * ``mode="neighbor"``: a rank ships one ``B``-wide buffer per round
          it participates in (K = max rank degree rounds total).
        * ``packed=True`` (neighbor only): the round-``k`` buffer is the
          bucketed width ``w_k`` instead of the dense global max ``B``.

        Returns ``{mode, packed, itemsize, per_rank, max, mean, total}``
        (bytes; ``per_rank`` is a plain list for JSON).
        """
        if mode not in ("a2a", "neighbor"):
            raise ValueError(f"wire_bytes: unknown halo mode {mode!r}")
        if packed and mode == "a2a":
            raise ValueError(
                "wire_bytes: packed buffers are neighbor-only — a2a "
                "(jax.lax.all_to_all) requires uniform per-rank buffers")
        itemsize = int(np.dtype(np.float32 if wire_dtype is None
                                else wire_dtype).itemsize)
        h = self.halo
        per_rank = np.zeros(self.R, dtype=np.int64)
        if mode == "a2a":
            B = h.a2a_send_idx.shape[-1]
            per_rank[:] = (self.R - 1) * B * feat_dim * itemsize
        else:
            K, B = h.nbr_send_idx.shape[1], h.nbr_send_idx.shape[2]
            pk = self.packed_halo(bucket) if packed else None
            for k in range(K):
                width = pk[f"pk{k}_send_idx"].shape[-1] if packed else B
                participates = (h.nbr_send_mask[:, k].sum(axis=-1) > 0) \
                    | (h.nbr_recv_mask[:, k].sum(axis=-1) > 0)
                per_rank += participates * width * feat_dim * itemsize
        return dict(mode=mode, packed=bool(packed), itemsize=itemsize,
                    per_rank=[int(v) for v in per_rank],
                    max=int(per_rank.max()) if self.R else 0,
                    mean=float(per_rank.mean()) if self.R else 0.0,
                    total=int(per_rank.sum()))

    def device_arrays(self, seg_layout: Tuple[int, int] | None = None,
                      split: bool = False,
                      packed: bool = False) -> Dict[str, np.ndarray]:
        """The dict of arrays a train/serve step consumes (shard over axis 0).

        ``seg_layout=(block_n, block_e)`` additionally includes the cached
        compact gather/scatter index lists (``seg_perm``/``seg_src``/
        ``seg_dst``) the fused NMP backend's scalar-prefetch DMA kernels
        consume.

        ``split=True`` attaches the interior/boundary edge split
        (:meth:`interior_split`) consumed by the overlap-schedule NMP
        implementations (``NMPPlan(schedule="overlap")``)
        — the compacted ``edge_{bnd,int}_idx``/``_valid`` index lists for the
        xla backend and, when ``seg_layout`` is also given, the per-side
        fused layouts ``seg_{perm,src,dst}_{bnd,int}``.

        ``packed=True`` attaches the bucketed per-round packed halo arrays
        (:meth:`packed_halo`) consumed by ``HaloSpec(packed=True)`` and the
        halo-mode autotuner.
        """
        h = self.halo
        out = dict(
            node_mask=self.node_mask, node_inv_mult=self.node_inv_mult,
            edge_src=self.edge_src, edge_dst=self.edge_dst,
            edge_mask=self.edge_mask, edge_inv_mult=self.edge_inv_mult,
            a2a_send_idx=h.a2a_send_idx, a2a_send_mask=h.a2a_send_mask,
            a2a_recv_idx=h.a2a_recv_idx, a2a_recv_mask=h.a2a_recv_mask,
            nbr_send_idx=h.nbr_send_idx, nbr_send_mask=h.nbr_send_mask,
            nbr_recv_idx=h.nbr_recv_idx, nbr_recv_mask=h.nbr_recv_mask,
        )
        if seg_layout is not None:
            layout = self.segment_layout(*seg_layout)
            out["seg_perm"] = layout["perm"]
            out["seg_src"] = layout["src"]
            out["seg_dst"] = layout["dst"]
        if split:
            sp = self.interior_split()
            for k in ("edge_bnd_idx", "edge_bnd_valid",
                      "edge_int_idx", "edge_int_valid"):
                out[k] = sp[k]
            if seg_layout is not None:
                for part in ("bnd", "int"):
                    lay = self.segment_layout(*seg_layout, part=part)
                    out[f"seg_perm_{part}"] = lay["perm"]
                    out[f"seg_src_{part}"] = lay["src"]
                    out[f"seg_dst_{part}"] = lay["dst"]
        if packed:
            out.update(self.packed_halo())
        return out


# ---------------------------------------------------------------------------
# element partitioning (NekRS-style decompositions)
# ---------------------------------------------------------------------------

def partition_elements(mesh: SEMMesh, rank_grid: Sequence[int]) -> np.ndarray:
    """Assign elements to ranks by blocks of the element grid.

    ``rank_grid`` has one entry per axis; (R,1,1) = slabs, (a,b,1) = pencils,
    (a,b,c) = sub-cubes (the decompositions discussed around Table II).
    """
    if len(rank_grid) != mesh.dim:
        raise ValueError("rank_grid must match mesh dim")
    for n, r in zip(mesh.nelem_axes, rank_grid):
        if n % r != 0:
            raise ValueError(f"elements per axis {n} not divisible by ranks {r}")
    blocks = [n // r for n, r in zip(mesh.nelem_axes, rank_grid)]
    e2r = np.empty(mesh.n_elem, dtype=np.int64)
    for e in range(mesh.n_elem):
        gidx = mesh.element_grid_index(e)
        ridx = [g // b for g, b in zip(gidx, blocks)]
        rank = 0
        for ax in range(mesh.dim - 1, -1, -1):
            rank = rank * rank_grid[ax] + ridx[ax]
        e2r[e] = rank
    return e2r


def from_element_partition(mesh: SEMMesh, elem2rank: np.ndarray, R: int) -> List[RankGraph]:
    """Build per-rank reduced local graphs (Fig. 3c) from an element partition."""
    # per-element undirected edge list (same generator, but per rank subset)
    from repro.core.mesh_gen import element_lattice_edges
    le = element_lattice_edges(mesh.p, mesh.dim)

    node_mult = np.zeros(mesh.n_nodes, dtype=np.int64)
    # edge multiplicity: count ranks owning each undirected global edge
    edge_key_mult: Dict[Tuple[int, int], int] = {}
    rank_nodes: List[np.ndarray] = []
    rank_und_edges: List[np.ndarray] = []

    for r in range(R):
        elems = np.nonzero(elem2rank == r)[0]
        if elems.size == 0:
            rank_nodes.append(np.zeros(0, dtype=np.int64))
            rank_und_edges.append(np.zeros((0, 2), dtype=np.int64))
            continue
        en = mesh.elem_nodes[elems]                  # [ne, npts]
        gids = np.unique(en)                         # local collapse of coincident nodes
        src = en[:, le[:, 0]].reshape(-1)
        dst = en[:, le[:, 1]].reshape(-1)
        lo, hi = np.minimum(src, dst), np.maximum(src, dst)
        pairs = np.unique(np.stack([lo, hi], axis=-1), axis=0)  # local dedup
        rank_nodes.append(gids)
        rank_und_edges.append(pairs)
        node_mult[gids] += 1
        for a, b in pairs:
            edge_key_mult[(int(a), int(b))] = edge_key_mult.get((int(a), int(b)), 0) + 1

    graphs: List[RankGraph] = []
    for r in range(R):
        gids = rank_nodes[r]
        g2l = {int(g): i for i, g in enumerate(gids)}
        und_r = rank_und_edges[r]
        dir_r = undirected_to_directed(und_r) if und_r.size else np.zeros((0, 2), dtype=np.int64)
        loc = np.array([[g2l[int(a)], g2l[int(b)]] for a, b in dir_r], dtype=np.int64).reshape(-1, 2)
        inv_mult = np.array(
            [1.0 / edge_key_mult[(min(int(a), int(b)), max(int(a), int(b)))] for a, b in dir_r],
            dtype=np.float32,
        ).reshape(-1)
        graphs.append(RankGraph(
            global_ids=gids,
            edges=loc,
            edge_inv_mult=inv_mult,
            node_inv_mult=(1.0 / node_mult[gids]).astype(np.float32),
        ))
    return graphs


# ---------------------------------------------------------------------------
# generic edge partitioning for arbitrary graphs (beyond-paper)
# ---------------------------------------------------------------------------

def from_edge_partition(
    n_nodes: int,
    directed_edges: np.ndarray,
    R: int,
    node2part: np.ndarray | None = None,
    assign: str = "dst",
    extra_nodes: Sequence[np.ndarray] | None = None,
) -> List[RankGraph]:
    """Vertex-cut partition of an arbitrary directed edge list.

    Every node's *primary* copy lives on ``node2part[node]`` (contiguous
    blocks by default); each directed edge is assigned to one rank
    (``assign`` = 'dst' | 'src'); endpoint copies are replicated wherever
    used. d_ij == 1 always; d_i = number of ranks holding a copy of i.

    ``extra_nodes`` (one array of global ids per rank) forces additional
    replica copies beyond the edge-endpoint closure — the multilevel
    hierarchy uses this to place a coarse-node copy on every rank that owns
    restriction/prolongation edges into it (``repro.core.coarsen``), so the
    inter-level transfer aggregates can be completed by the same halo-sum
    machinery as the edge aggregates.
    """
    if node2part is None:
        node2part = (np.arange(n_nodes) * R) // max(n_nodes, 1)
    node2part = node2part.astype(np.int64)
    e_owner = node2part[directed_edges[:, 1 if assign == "dst" else 0]]

    node_mult = np.zeros(n_nodes, dtype=np.int64)
    rank_nodes: List[np.ndarray] = []
    rank_edges: List[np.ndarray] = []
    for r in range(R):
        er = directed_edges[e_owner == r]
        prim = np.nonzero(node2part == r)[0]
        parts = [er.reshape(-1), prim]
        if extra_nodes is not None and len(extra_nodes[r]):
            parts.append(np.asarray(extra_nodes[r], dtype=np.int64))
        gids = np.unique(np.concatenate(parts))
        rank_nodes.append(gids)
        rank_edges.append(er)
        node_mult[gids] += 1

    graphs: List[RankGraph] = []
    for r in range(R):
        gids = rank_nodes[r]
        lookup = np.full(n_nodes, -1, dtype=np.int64)
        lookup[gids] = np.arange(gids.size)
        er = rank_edges[r]
        loc = lookup[er].reshape(-1, 2) if er.size else np.zeros((0, 2), dtype=np.int64)
        graphs.append(RankGraph(
            global_ids=gids,
            edges=loc,
            edge_inv_mult=np.ones(loc.shape[0], dtype=np.float32),
            node_inv_mult=(1.0 / node_mult[gids]).astype(np.float32),
        ))
    return graphs


# ---------------------------------------------------------------------------
# halo plan construction
# ---------------------------------------------------------------------------

def _round_up(x: int, m: int) -> int:
    return ((max(x, 1) + m - 1) // m) * m


def greedy_edge_coloring(pairs: List[Tuple[int, int]]) -> List[List[Tuple[int, int]]]:
    """Color rank-pair edges so same-color pairs are disjoint (<= Δ+1 colors).

    Pairs are processed largest-degree-endpoints first for tighter colorings.
    Returns rounds: list of lists of (r, s) with r < s.
    """
    deg: Dict[int, int] = {}
    for a, b in pairs:
        deg[a] = deg.get(a, 0) + 1
        deg[b] = deg.get(b, 0) + 1
    order = sorted(pairs, key=lambda p: -(deg[p[0]] + deg[p[1]]))
    used: Dict[int, set] = {}
    rounds: List[List[Tuple[int, int]]] = []
    for a, b in order:
        c = 0
        while c in used.get(a, set()) or c in used.get(b, set()):
            c += 1
        while len(rounds) <= c:
            rounds.append([])
        rounds[c].append((a, b))
        used.setdefault(a, set()).add(c)
        used.setdefault(b, set()).add(c)
    return rounds


def build_halo_plan(graphs: List[RankGraph], pad_to: int = 8) -> HaloPlan:
    """Shared-node send/recv masks for both exchange modes.

    For each rank pair (r, s) with shared global ids, both directions exchange
    the local aggregates at those ids, sorted by global id (fixing summation
    order => deterministic results).
    """
    R = len(graphs)
    g2l = []
    for g in graphs:
        d = {int(gid): i for i, gid in enumerate(g.global_ids)}
        g2l.append(d)

    shared: Dict[Tuple[int, int], np.ndarray] = {}
    for r in range(R):
        for s in range(r + 1, R):
            common = np.intersect1d(graphs[r].global_ids, graphs[s].global_ids, assume_unique=True)
            if common.size:
                shared[(r, s)] = common  # sorted

    # ---- A2A equal buffers (paper baseline) ----
    B = _round_up(max((v.size for v in shared.values()), default=1), pad_to)
    a2a_send_idx = np.zeros((R, R, B), dtype=np.int32)
    a2a_send_mask = np.zeros((R, R, B), dtype=np.float32)
    a2a_recv_idx = np.zeros((R, R, B), dtype=np.int32)
    a2a_recv_mask = np.zeros((R, R, B), dtype=np.float32)
    for (r, s), common in shared.items():
        n = common.size
        lr = np.array([g2l[r][int(g)] for g in common], dtype=np.int32)
        ls = np.array([g2l[s][int(g)] for g in common], dtype=np.int32)
        # r -> s
        a2a_send_idx[r, s, :n] = lr
        a2a_send_mask[r, s, :n] = 1.0
        a2a_recv_idx[s, r, :n] = ls
        a2a_recv_mask[s, r, :n] = 1.0
        # s -> r
        a2a_send_idx[s, r, :n] = ls
        a2a_send_mask[s, r, :n] = 1.0
        a2a_recv_idx[r, s, :n] = lr
        a2a_recv_mask[r, s, :n] = 1.0

    # ---- NEIGHBOR ppermute rounds ----
    rounds = greedy_edge_coloring(list(shared.keys())) if shared else []
    K = max(len(rounds), 1)
    B2 = B
    nbr_send_idx = np.zeros((R, K, B2), dtype=np.int32)
    nbr_send_mask = np.zeros((R, K, B2), dtype=np.float32)
    nbr_recv_idx = np.zeros((R, K, B2), dtype=np.int32)
    nbr_recv_mask = np.zeros((R, K, B2), dtype=np.float32)
    perms: List[List[Tuple[int, int]]] = []
    for k, rnd in enumerate(rounds or [[]]):
        perm: List[Tuple[int, int]] = []
        for (r, s) in rnd:
            common = shared[(r, s)]
            n = common.size
            lr = np.array([g2l[r][int(g)] for g in common], dtype=np.int32)
            ls = np.array([g2l[s][int(g)] for g in common], dtype=np.int32)
            nbr_send_idx[r, k, :n] = lr
            nbr_send_mask[r, k, :n] = 1.0
            nbr_recv_idx[r, k, :n] = lr
            nbr_recv_mask[r, k, :n] = 1.0
            nbr_send_idx[s, k, :n] = ls
            nbr_send_mask[s, k, :n] = 1.0
            nbr_recv_idx[s, k, :n] = ls
            nbr_recv_mask[s, k, :n] = 1.0
            perm.append((r, s))
            perm.append((s, r))
        perms.append(perm)
    return HaloPlan(
        a2a_send_idx=a2a_send_idx, a2a_send_mask=a2a_send_mask,
        a2a_recv_idx=a2a_recv_idx, a2a_recv_mask=a2a_recv_mask,
        perms=perms,
        nbr_send_idx=nbr_send_idx, nbr_send_mask=nbr_send_mask,
        nbr_recv_idx=nbr_recv_idx, nbr_recv_mask=nbr_recv_mask,
    )


def packed_halo_arrays(nbr: Dict[str, np.ndarray],
                       bucket: int = 8) -> Dict[str, np.ndarray]:
    """Bucketed per-round truncation of dense NEIGHBOR halo arrays.

    The dense ``nbr_*`` arrays are ``[R, K, B]`` with ``B`` the GLOBAL max
    shared-boundary size over all rank pairs — at realistic rank counts most
    of every round's buffer is masked padding.  Because the plan builders
    prefix-pack real entries (mask is a 1.0-prefix), truncating round ``k``
    to ``w_k = round_up(max real entries over ranks, bucket)`` keeps every
    real entry: the packed arrays are pure slices of the dense ones, which
    is what makes the packed wire format bitwise-identical in value.

    Works on both :func:`build_halo_plan` NEIGHBOR arrays and
    :func:`build_2d_halo_rounds` arrays.  Returns one rectangular array set
    per round (``pk{k}_send_idx`` [R, w_k], ...), so each can live in a
    ``ShardedGraph`` and shard over the rank axis.
    """
    send_mask, recv_mask = nbr["nbr_send_mask"], nbr["nbr_recv_mask"]
    R, K, B = send_mask.shape
    out: Dict[str, np.ndarray] = {}
    for k in range(K):
        occ = max(int((send_mask[:, k] > 0).sum(axis=-1).max(initial=0)),
                  int((recv_mask[:, k] > 0).sum(axis=-1).max(initial=0)))
        w = min(_round_up(occ, bucket), B)
        # the truncation must drop only padding (prefix-packed invariant)
        if float(send_mask[:, k, w:].sum()) or float(recv_mask[:, k, w:].sum()):
            raise ValueError(
                f"packed_halo_arrays: round {k} has real entries beyond "
                f"width {w} — halo arrays are not prefix-packed")
        for name in ("send_idx", "send_mask", "recv_idx", "recv_mask"):
            out[f"pk{k}_{name}"] = np.ascontiguousarray(
                nbr[f"nbr_{name}"][:, k, :w])
    return out


def flat_rounds2d_perms(grid: Tuple[int, int]) -> Tuple[Tuple[Tuple[int, int], ...], ...]:
    """Flat per-round (src, dst) rank pairs for :func:`build_2d_halo_rounds`.

    Each rounds2d round routes one uniform (da, db) torus shift as <=2
    chained per-axis ppermute hops; their composition delivers rank
    ``a*Gb + b``'s buffer to ``(a+da)*Gb + (b+db)`` exactly when that rank
    exists (partial chains deliver zeros, which the recv mask drops).  The
    single-device emulator (``halo_sync_stacked``) uses these flat pairs in
    place of the per-axis collectives; the shift order here mirrors
    ``build_2d_halo_rounds`` and must stay in sync with it.
    """
    Ga, Gb = grid
    shifts = [(da, db) for da in (-1, 0, 1) for db in (-1, 0, 1)
              if not (da == 0 and db == 0)]
    rounds = []
    for da, db in shifts:
        perm = []
        for a in range(Ga):
            for b in range(Gb):
                a2, b2 = a + da, b + db
                if 0 <= a2 < Ga and 0 <= b2 < Gb:
                    perm.append((a * Gb + b, a2 * Gb + b2))
        rounds.append(tuple(perm))
    return tuple(rounds)


def pack(graphs: List[RankGraph], n_global: int, pad_to: int = 8) -> PartitionedGraphs:
    """Pad per-rank graphs to common shapes and stack along axis 0."""
    R = len(graphs)
    n_pad = _round_up(max(g.n_nodes for g in graphs), pad_to)
    e_pad = _round_up(max(g.n_edges for g in graphs), pad_to)
    gid = np.full((R, n_pad), -1, dtype=np.int32)
    nmask = np.zeros((R, n_pad), dtype=np.float32)
    ninv = np.zeros((R, n_pad), dtype=np.float32)
    esrc = np.zeros((R, e_pad), dtype=np.int32)
    edst = np.zeros((R, e_pad), dtype=np.int32)
    emask = np.zeros((R, e_pad), dtype=np.float32)
    einv = np.zeros((R, e_pad), dtype=np.float32)
    for r, g in enumerate(graphs):
        gid[r, :g.n_nodes] = g.global_ids
        nmask[r, :g.n_nodes] = 1.0
        ninv[r, :g.n_nodes] = g.node_inv_mult
        esrc[r, :g.n_edges] = g.edges[:, 0]
        edst[r, :g.n_edges] = g.edges[:, 1]
        emask[r, :g.n_edges] = 1.0
        einv[r, :g.n_edges] = g.edge_inv_mult
    return PartitionedGraphs(
        R=R, n_global=n_global,
        global_ids=gid, node_mask=nmask, node_inv_mult=ninv,
        edge_src=esrc, edge_dst=edst, edge_mask=emask, edge_inv_mult=einv,
        halo=build_halo_plan(graphs, pad_to=pad_to),
    )


def build_2d_halo_rounds(graphs: List[RankGraph], grid: Tuple[int, int],
                         axes: Tuple[str, str] = ("data", "model"),
                         pad_to: int = 8):
    """Two-level halo plan: sub-graphs laid out on a (Ga, Gb) grid spanning
    TWO mesh axes; every neighbor shift (da, db) becomes one exchange round
    routed as <=2 chained ppermute hops (uniform torus translation — no
    relay conflicts). Rank id = a * Gb + b, a over axes[0], b over axes[1].

    Returns (rounds2d, nbr arrays [R, K, B]) to splice into a HaloPlan /
    ``ShardedGraph.with_arrays``.
    """
    Ga, Gb = grid
    R = len(graphs)
    assert R == Ga * Gb
    g2l = [{int(g): i for i, g in enumerate(gr.global_ids)} for gr in graphs]

    shifts = [(da, db) for da in (-1, 0, 1) for db in (-1, 0, 1)
              if not (da == 0 and db == 0)]
    # shared-id lists per (rank, shift)
    shared: Dict[Tuple[int, int], np.ndarray] = {}
    maxb = 1
    for r in range(R):
        a, b = divmod(r, Gb)
        for si, (da, db) in enumerate(shifts):
            a2, b2 = a + da, b + db
            if not (0 <= a2 < Ga and 0 <= b2 < Gb):
                continue
            s = a2 * Gb + b2
            common = np.intersect1d(graphs[r].global_ids, graphs[s].global_ids,
                                    assume_unique=True)
            if common.size:
                shared[(r, si)] = common
                maxb = max(maxb, common.size)

    B = _round_up(maxb, pad_to)
    K = len(shifts)
    send_idx = np.zeros((R, K, B), dtype=np.int32)
    send_mask = np.zeros((R, K, B), dtype=np.float32)
    recv_idx = np.zeros((R, K, B), dtype=np.int32)
    recv_mask = np.zeros((R, K, B), dtype=np.float32)
    rounds2d = []
    for si, (da, db) in enumerate(shifts):
        # ppermute perms are indexed ALONG the named axis (the shift applies
        # uniformly across the other axis)
        hops = []
        if db:
            hops.append((axes[1], tuple((b, b + db) for b in range(Gb)
                                        if 0 <= b + db < Gb)))
        if da:
            hops.append((axes[0], tuple((a, a + da) for a in range(Ga)
                                        if 0 <= a + da < Ga)))
        rounds2d.append(tuple(hops))
        for r in range(R):
            common = shared.get((r, si))
            if common is None:
                continue
            a, b = divmod(r, Gb)
            s = (a + da) * Gb + (b + db)
            n = common.size
            send_idx[r, si, :n] = [g2l[r][int(g)] for g in common]
            send_mask[r, si, :n] = 1.0
            recv_idx[s, si, :n] = [g2l[s][int(g)] for g in common]
            recv_mask[s, si, :n] = 1.0
    arrays = dict(nbr_send_idx=send_idx, nbr_send_mask=send_mask,
                  nbr_recv_idx=recv_idx, nbr_recv_mask=recv_mask)
    return tuple(rounds2d), arrays


# ---------------------------------------------------------------------------
# convenience front doors
# ---------------------------------------------------------------------------

def partition_mesh(mesh: SEMMesh, rank_grid: Sequence[int], pad_to: int = 8,
                   method: str = "block") -> PartitionedGraphs:
    """Partition an SEM mesh onto ``prod(rank_grid)`` ranks.

    ``method="block"`` is the NekRS-style element-block decomposition along
    the rank grid (d_ij > 1 coincident GLL copies); ``method="spectral"``
    runs recursive spectral bisection + KL refinement on the mesh graph
    (``repro.core.partition_quality``) and builds a vertex-cut edge
    partition (d_ij == 1).  Consistency (Eqs. 2, 3) holds either way — the
    choice only moves halo volume and balance.
    """
    R = int(np.prod(rank_grid))
    if method == "block":
        e2r = partition_elements(mesh, rank_grid)
        return pack(from_element_partition(mesh, e2r, R), mesh.n_nodes,
                    pad_to=pad_to)
    if method == "spectral":
        from repro.core.mesh_gen import mesh_graph_edges
        from repro.core.partition_quality import mesh_node2part
        node2part = mesh_node2part(mesh, R)
        directed = undirected_to_directed(mesh_graph_edges(mesh))
        return pack(from_edge_partition(mesh.n_nodes, directed, R,
                                        node2part=node2part),
                    mesh.n_nodes, pad_to=pad_to)
    raise ValueError(f"unknown partition method {method!r} "
                     "(expected 'block' or 'spectral')")


def partition_graph(n_nodes: int, directed_edges: np.ndarray, R: int,
                    pad_to: int = 8, assign: str = "dst",
                    method: str = "block",
                    node2part: np.ndarray = None) -> PartitionedGraphs:
    """Partition an arbitrary directed graph onto R ranks.

    ``node2part`` (any [N] int array, ranks may even be empty) wins over
    ``method``; otherwise ``method="block"`` keeps the contiguous index
    split and ``method="spectral"`` computes a node2part with
    :func:`repro.core.partition_quality.spectral_node2part`.
    """
    if node2part is None and method == "spectral":
        from repro.core.partition_quality import spectral_node2part
        node2part = spectral_node2part(n_nodes, directed_edges, R)
    elif node2part is None and method != "block":
        raise ValueError(f"unknown partition method {method!r} "
                         "(expected 'block' or 'spectral')")
    return pack(from_edge_partition(n_nodes, directed_edges, R,
                                    node2part=node2part, assign=assign),
                n_nodes, pad_to=pad_to)


def gather_node_features(pg: PartitionedGraphs, global_x: np.ndarray) -> np.ndarray:
    """[n_global, F] -> [R, N_pad, F]; coincident copies get identical rows."""
    safe = np.clip(pg.global_ids, 0, None)
    out = global_x[safe.reshape(-1)].reshape(pg.R, pg.n_pad, -1)
    return out * pg.node_mask[..., None]


def scatter_node_outputs(pg: PartitionedGraphs, per_rank_y: np.ndarray) -> np.ndarray:
    """Inverse of gather (Eq. 2's "cat" by global index): [R, N_pad, F] -> [n_global, F].

    Coincident copies are asserted consistent by taking any owner's row.
    """
    F = per_rank_y.shape[-1]
    out = np.zeros((pg.n_global, F), dtype=per_rank_y.dtype)
    for r in range(pg.R):
        m = pg.node_mask[r] > 0
        out[pg.global_ids[r, m]] = per_rank_y[r, m]
    return out
