"""Unified execution state for the consistent GNN: ShardedGraph + NMPPlan.

Before this module, every forward path threaded the same execution policy
by hand — ``backend=`` / ``schedule=`` / ``precision=`` / ``interpret=`` /
``block_n=`` kwargs plus an ever-growing bag of string keys in a loose
``meta`` dict — through eight files in lockstep.  The two classes here
replace that plumbing:

* :class:`ShardedGraph` — a registered pytree bundling the per-rank static
  arrays of one partition level (node/edge indices, masks, inverse
  multiplicities, halo exchange buffers, static geometric edge features,
  the fused-kernel segment layouts and the interior/boundary split), with
  each coarser level of a multilevel hierarchy nested as a child
  ``ShardedGraph`` carrying its restriction/prolongation transfer maps.
  Because it is a pytree, the whole graph flows through ``jit`` /
  ``shard_map`` / ``jax.tree.map`` like any other argument; the dict keys
  live in the (hashable) treedef, so rebuilding an identically-shaped graph
  never retraces.

* :class:`NMPPlan` — a frozen, hashable execution policy: NMP backend
  (``xla`` | ``fused``), halo/compute schedule (``blocking`` | ``overlap``),
  edge-MLP matmul precision (``fp32`` | ``bf16``), Pallas interpreter flag,
  fused-kernel block sizes, and the fine + per-coarse-level
  :class:`~repro.core.halo.HaloSpec`\\ s.  Layer implementations register
  themselves per ``(backend, schedule)`` cell via :func:`register_nmp_impl`
  once, instead of being dispatched by stringly-typed kwargs at every call
  site — the next backend or schedule is a one-file registry entry.

Raw ``meta`` dicts are rejected with a ``TypeError`` wherever a
``ShardedGraph`` is expected (:func:`as_graph`), so stale callers fail
loudly instead of silently half-working.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.halo import HaloSpec, halo_spec_from_plan

# ---------------------------------------------------------------------------
# NMPPlan: frozen execution policy + the (backend, schedule) registry
# ---------------------------------------------------------------------------

XLA = "xla"
FUSED = "fused"
BLOCKING = "blocking"
OVERLAP = "overlap"
AUTO = "auto"                    # resolved to blocking|overlap by autotune()
SCHEDULES = (BLOCKING, OVERLAP, AUTO)
FP32 = "fp32"
BF16 = "bf16"
PRECISIONS = (FP32, BF16)


@dataclasses.dataclass(frozen=True)
class NMPPlan:
    """Static execution policy for every consistent-NMP forward path.

    All fields are trace-time constants: the plan is hashable and compares
    by value, so it can be closed over by ``jit`` (or passed as a static
    argument) without retracing when an equal plan is rebuilt.

    ``halo`` is the fine (level-0) exchange spec; ``coarse_halos[l-1]`` is
    level l's — each coarse level has its own ppermute rounds.  The policy
    knobs select the registered layer implementation and configure it (see
    the backend/schedule/precision taxonomy in ``repro.core.consistent_mp``).
    ``block_n`` / ``block_e`` are the fused-kernel tile sizes; they also key
    the cached segment layout ``ShardedGraph.build`` attaches.
    """
    halo: HaloSpec = HaloSpec(mode="none")
    coarse_halos: Tuple[HaloSpec, ...] = ()
    backend: str = XLA
    schedule: str = BLOCKING
    precision: str = FP32
    interpret: bool = False
    block_n: int = 128
    block_e: int = 128

    def __post_init__(self):
        if self.precision not in PRECISIONS:
            raise ValueError(f"unknown precision {self.precision!r}; "
                             f"expected one of {PRECISIONS}")
        if self.schedule not in SCHEDULES:
            raise ValueError(f"unknown schedule {self.schedule!r}; "
                             f"expected one of {SCHEDULES}")
        # the plan's interpret flag is authoritative: mirror it into every
        # halo spec so the packed exchange's Pallas pack/unpack kernels run
        # under the same interpreter policy as the fused NMP kernels
        sync = tuple(
            h if h.interpret == self.interpret
            else dataclasses.replace(h, interpret=self.interpret)
            for h in (self.halo, *self.coarse_halos))
        object.__setattr__(self, "halo", sync[0])
        object.__setattr__(self, "coarse_halos", tuple(sync[1:]))

    def replace(self, **kw) -> "NMPPlan":
        return dataclasses.replace(self, **kw)

    @property
    def seg_layout(self) -> Tuple[int, int] | None:
        """The (block_n, block_e) layout key the fused backend needs, or
        None when the xla backend makes no use of a segment layout."""
        return (self.block_n, self.block_e) if self.backend == FUSED else None

    @property
    def wants_split(self) -> bool:
        """Whether the graph must carry the interior/boundary edge split.

        ``auto`` also wants it: the graph must support whichever schedule
        the tuner picks (blocking simply ignores the split arrays).
        """
        return self.schedule in (OVERLAP, AUTO)

    @property
    def wants_packed(self) -> bool:
        """Whether the graph must carry the bucketed per-round packed halo
        arrays (``pk{k}_*``).  True for any ``HaloSpec(packed=True)`` level
        and for halo mode ``"auto"`` — the tuner's candidate set includes the
        packed neighbor format, so the graph must support it."""
        return any(h.packed or h.mode == AUTO
                   for h in (self.halo, *self.coarse_halos))

    def halos(self, n_levels: int) -> Tuple[HaloSpec, ...]:
        """Per-level exchange specs for an ``n_levels``-deep hierarchy.

        Missing coarse entries fall back to the fine spec — correct ONLY for
        the A2A / NONE modes (a NEIGHBOR fine spec with a missing coarse
        entry is rejected by ``multilevel_vcycle``, whose ``sync_fns``
        overrides are the one legitimate reason to reach that state).
        """
        return (self.halo,) + tuple(
            self.coarse_halos[i] if i < len(self.coarse_halos) else self.halo
            for i in range(n_levels - 1))

    @classmethod
    def build(cls, pg_or_hierarchy, mode: str, axis: str = "graph",
              wire_dtype=None, packed: bool = False, **policy) -> "NMPPlan":
        """Build a plan with halo specs derived from a partition's halo plan.

        ``pg_or_hierarchy`` is a ``PartitionedGraphs`` (flat model) or a
        ``MultiLevelGraphs`` (every level gets its own spec); ``mode`` is the
        exchange mode (``none`` | ``a2a`` | ``neighbor`` | ``auto`` — the
        last resolved by :meth:`autotune` over the (schedule × halo-mode ×
        wire) cross-product); ``packed=True`` selects the bucketed per-round
        wire format (neighbor only); remaining kwargs are the policy fields
        (backend/schedule/precision/...).
        """
        levels = getattr(pg_or_hierarchy, "levels", [pg_or_hierarchy])
        specs = tuple(halo_spec_from_plan(lvl.halo, mode, axis=axis,
                                          wire_dtype=wire_dtype,
                                          packed=packed)
                      for lvl in levels)
        return cls(halo=specs[0], coarse_halos=specs[1:], **policy)

    def autotune_blocks(self, hidden: int, dtype=jnp.float32) -> "NMPPlan":
        """Replace ``block_n``/``block_e`` with the static autotune table's
        choice for this model width (``repro.kernels.segment_agg.ops.
        pick_block_sizes``, keyed on hidden/dtype/platform and overridable
        via the ``REPRO_SEG_BLOCKS`` env var).  Compose with the halo
        constructors: ``NMPPlan.build(pg, mode, backend="fused")
        .autotune_blocks(cfg.hidden)``.
        """
        from repro.kernels.segment_agg.ops import pick_block_sizes
        bn, be = pick_block_sizes(hidden, dtype)
        return self.replace(block_n=bn, block_e=be)

    def autotune(self, graph, measure: bool | None = None,
                 hidden: int = 8, iters: int = 20) -> "NMPPlan":
        """Resolve ``schedule="auto"`` and/or halo mode ``"auto"``.

        Times one jitted stacked NMP layer per candidate — the (schedule ×
        halo-mode × wire) cross-product when the halo mode is ``"auto"``,
        schedules only otherwise — on ``graph`` (a stacked
        :class:`ShardedGraph`, the same proxy ``benchmarks/halo_overlap.py``
        reports) and returns a plan with the measured winner, cached per
        (graph-hash, rank-count, policy) for the process lifetime so
        repeated builds pay nothing.  ``hidden`` should match the model
        width (compute/communication balance moves the crossover).  With
        ``measure=False`` — or env var ``REPRO_SCHEDULE_AUTOTUNE=0`` — falls
        back to structural heuristics (``interior_frac`` < 0.5 -> overlap;
        halo mode -> packed neighbor).  Plans with everything fixed are
        returned unchanged.  Mirrors :meth:`autotune_blocks`.
        """
        if self.schedule != AUTO and self.halo.mode != AUTO:
            return self
        from repro.core.consistent_mp import autotune_plan
        return autotune_plan(self, graph, measure=measure,
                             hidden=hidden, iters=iters)

    def policy(self) -> dict:
        """JSON-able policy fields (no halo specs) — the plan's entry in a
        checkpoint manifest's mesh fingerprint.  An elastic resume compares
        these to decide whether the execution policy changed (allowed —
        backends/schedules are arithmetically consistent) and reuses the
        recorded resolved schedule instead of re-autotuning ``auto`` when
        the rank count is unchanged."""
        return {"backend": self.backend, "schedule": self.schedule,
                "precision": self.precision, "interpret": self.interpret,
                "block_n": self.block_n, "block_e": self.block_e,
                "halo_mode": self.halo.mode,
                "halo_packed": self.halo.packed,
                "halo_wire": (None if self.halo.wire_dtype is None
                              else jnp.dtype(self.halo.wire_dtype).name)}


_NMP_IMPLS: Dict[Tuple[str, str], Callable] = {}


def register_nmp_impl(backend: str, schedule: str):
    """Register one consistent-NMP layer implementation for a
    (backend, schedule) cell.  The registered callable has the signature

        impl(params, x, e, graph, plan, halo, sync_fn, edge_parallel_axes)
            -> (x', e')

    and is looked up once per ``nmp_layer`` call via :func:`nmp_impl` —
    adding a backend or schedule is one registration, not an eight-file
    kwarg thread.
    """
    def deco(fn):
        _NMP_IMPLS[(backend, schedule)] = fn
        return fn
    return deco


def nmp_impl(plan: NMPPlan) -> Callable:
    """Resolve the layer implementation registered for ``plan``."""
    try:
        return _NMP_IMPLS[(plan.backend, plan.schedule)]
    except KeyError:
        if plan.schedule == AUTO:
            raise ValueError(
                "schedule='auto' must be resolved before layer dispatch: "
                "call plan.autotune(graph) after ShardedGraph.build (the "
                "training loop does this for you)") from None
        known = sorted(_NMP_IMPLS)
        raise ValueError(
            f"no NMP implementation registered for backend={plan.backend!r}, "
            f"schedule={plan.schedule!r}; registered cells: {known}") from None


def registered_nmp_impls() -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted(_NMP_IMPLS))


# ---------------------------------------------------------------------------
# ShardedGraph: the per-rank static arrays as one pytree
# ---------------------------------------------------------------------------

class ShardedGraph:
    """Stacked per-rank static arrays of one partition level, as a pytree.

    ``arrays`` maps name -> array with a leading rank axis (the axis the
    production mesh shards over); ``coarse`` optionally chains the next
    coarser level of a multilevel hierarchy (whose arrays additionally carry
    the ``t_fine`` / ``t_coarse`` / ``t_rw`` / ``t_pw`` transfer maps from
    this level).  Inside ``shard_map`` the same structure holds the
    rank-local slices (leading axes consumed by the sharding) — use
    :meth:`rank` to strip them explicitly.

    The array *names* live in the treedef (hashable aux data), so two graphs
    built from the same partition are trace-compatible: ``jit`` does not
    retrace across flatten/unflatten round trips or rebuilds.
    """

    __slots__ = ("arrays", "coarse")

    def __init__(self, arrays: Dict[str, jnp.ndarray],
                 coarse: "ShardedGraph | None" = None):
        if not isinstance(arrays, dict):
            raise TypeError(f"arrays must be a dict, got {type(arrays)}")
        if coarse is not None and not isinstance(coarse, ShardedGraph):
            raise TypeError("coarse must be a ShardedGraph (or None), got "
                            f"{type(coarse)}")
        self.arrays = dict(arrays)
        self.coarse = coarse

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        keys = tuple(sorted(self.arrays))
        return (tuple(self.arrays[k] for k in keys), self.coarse), keys

    @classmethod
    def tree_unflatten(cls, keys, children):
        vals, coarse = children
        obj = cls.__new__(cls)
        obj.arrays = dict(zip(keys, vals))
        obj.coarse = coarse
        return obj

    # -- mapping-style access ----------------------------------------------
    def __getitem__(self, key: str):
        try:
            return self.arrays[key]
        except KeyError:
            raise KeyError(
                f"ShardedGraph has no array {key!r} at this level; present: "
                f"{sorted(self.arrays)} — was the graph built with the plan "
                "that needs it (ShardedGraph.build(pg, coords, plan=...))?"
            ) from None

    def __contains__(self, key: str) -> bool:
        return key in self.arrays

    def keys(self):
        return self.arrays.keys()

    def items(self):
        return self.arrays.items()

    def __repr__(self) -> str:
        lv = ", ".join(f"L{i}:{len(l.arrays)} arrays"
                       for i, l in enumerate(self.levels))
        return f"ShardedGraph({lv})"

    # -- hierarchy ----------------------------------------------------------
    @property
    def levels(self) -> Tuple["ShardedGraph", ...]:
        """Fine-to-coarse chain of levels (``levels[0] is self``)."""
        out, g = [], self
        while g is not None:
            out.append(g)
            g = g.coarse
        return tuple(out)

    @property
    def n_levels(self) -> int:
        return len(self.levels)

    def level(self, lvl: int) -> "ShardedGraph":
        levels = self.levels
        if lvl >= len(levels):
            raise ValueError(
                f"multilevel graph for level {lvl} missing (graph has "
                f"{len(levels)} levels) — build the graph from the "
                "hierarchy: ShardedGraph.build(pg, coords, plan, "
                "hierarchy=...)")
        return levels[lvl]

    # -- transforms ----------------------------------------------------------
    def rank(self, r: int) -> "ShardedGraph":
        """Slice every array's leading rank axis (all levels)."""
        return jax.tree.map(lambda v: v[r], self)

    def rank_local(self) -> "ShardedGraph":
        """Strip the size-1 leading rank axis inside a shard_map body."""
        return self.rank(0)

    def with_arrays(self, **updates) -> "ShardedGraph":
        """Copy of this level with arrays added/replaced (coarse chain kept)."""
        return ShardedGraph({**self.arrays, **updates}, self.coarse)

    def specs(self, graph_axis="graph") -> "ShardedGraph":
        """Same-structure pytree of PartitionSpecs: every array sharded over
        its leading rank ax(es).  ``graph_axis`` may be one mesh axis name or
        a tuple of names (two-level spatial grids consume two leading axes).
        Feed directly to ``shard_map`` in_specs / ``NamedSharding``.
        """
        axes = (graph_axis,) if isinstance(graph_axis, str) else tuple(graph_axis)
        return jax.tree.map(
            lambda v: P(*axes, *(None,) * (v.ndim - len(axes))), self)

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_arrays(cls, arrays: Dict[str, jnp.ndarray],
                    coarse: "ShardedGraph | None" = None) -> "ShardedGraph":
        """Wrap an existing name -> array mapping (adapter for callers that
        assemble their own static arrays, e.g. the sampler block metadata or
        the dry-run's ShapeDtypeStruct graphs)."""
        return cls(dict(arrays), coarse)

    @classmethod
    def build(cls, pg, coords: np.ndarray | None,
              plan: NMPPlan | None = None, hierarchy=None) -> "ShardedGraph":
        """THE constructor for real partitions (replaces the retired
        ``prepare_gnn_meta`` / ``rank_static_inputs`` /
        ``multilevel_static_inputs`` trio).

        Collects the per-rank static arrays of ``pg`` (a
        ``PartitionedGraphs``) plus the static geometric edge features from
        ``coords``; ``plan`` decides what else rides along — the fused
        backend's cached segment layout (``plan.seg_layout``) and the
        overlap schedule's interior/boundary split (``plan.wants_split``).
        The O(E log E) layout/split passes are memoized on ``pg``, so they
        run once per partition, never per step.

        ``hierarchy`` (a ``repro.core.coarsen.MultiLevelGraphs`` whose level
        0 is ``pg``) nests each coarse level as a child ShardedGraph carrying
        its transfer maps; ``coords`` must then agree with the hierarchy's
        build-time coordinates (which define every level's edge features).
        """
        plan = plan or NMPPlan()
        seg = plan.seg_layout
        split = plan.wants_split
        packed = plan.wants_packed
        if hierarchy is None:
            return cls(_level_arrays(pg, coords, seg, split, packed))
        if hierarchy.levels[0] is not pg:
            raise ValueError("hierarchy.levels[0] must be the pg passed in "
                             "(the fine partition the step fns shard over)")
        if coords is not None and coords is not hierarchy.coords[0] \
                and not np.array_equal(coords, hierarchy.coords[0]):
            raise ValueError(
                "coords disagrees with hierarchy.coords[0]: the hierarchy's "
                "build-time coordinates define every level's static edge "
                "features — rebuild the hierarchy from the transformed mesh "
                "instead of passing different coords here")
        graph = None
        for lvl in range(hierarchy.n_levels - 1, -1, -1):
            arrays = _level_arrays(hierarchy.levels[lvl], hierarchy.coords[lvl],
                                   seg, split, packed)
            if lvl >= 1:
                t = hierarchy.transfers[lvl - 1]
                arrays["t_fine"] = jnp.asarray(t.fine_idx)
                arrays["t_coarse"] = jnp.asarray(t.coarse_idx)
                arrays["t_rw"] = jnp.asarray(t.r_w)
                arrays["t_pw"] = jnp.asarray(t.p_w)
            graph = cls(arrays, graph)
        return graph


jax.tree_util.register_pytree_node_class(ShardedGraph)


def _level_arrays(pg, coords, seg_layout, split,
                  packed: bool = False) -> Dict[str, jnp.ndarray]:
    """One level's stacked static arrays: halo/edge metadata + edge geometry."""
    from repro.core.mesh_gen import edge_features as static_edge_features
    from repro.core.partition import gather_node_features

    arrays = {k: jnp.asarray(v)
              for k, v in pg.device_arrays(seg_layout=seg_layout,
                                           split=split,
                                           packed=packed).items()}
    coords_r = gather_node_features(pg, coords)
    ef = []
    for r in range(pg.R):
        e = np.stack([pg.edge_src[r], pg.edge_dst[r]], axis=-1)
        ef.append(static_edge_features(coords_r[r], e) * pg.edge_mask[r][:, None])
    arrays["static_edge_feats"] = jnp.asarray(np.stack(ef).astype(np.float32))
    return arrays


def as_graph(graph) -> ShardedGraph:
    """Validate a ShardedGraph argument; reject the retired meta-dict path
    loudly so stale callers fail with an actionable error instead of a
    shape mismatch three layers down."""
    if isinstance(graph, ShardedGraph):
        return graph
    if isinstance(graph, dict):
        raise TypeError(
            "raw meta dicts are no longer accepted by the consistent-GNN "
            "forward paths — build a ShardedGraph instead: "
            "ShardedGraph.build(pg, coords, plan, hierarchy=...) for real "
            "partitions, or ShardedGraph.from_arrays(d) to wrap an existing "
            "mapping (see CONTRIBUTING.md, 'Migrating from meta dicts')")
    raise TypeError(f"expected a ShardedGraph, got {type(graph).__name__}")
