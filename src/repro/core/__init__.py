"""The paper's primary contribution: consistent distributed mesh-based GNNs."""
from repro.core.coarsen import MultiLevelGraphs, TransferPlan, build_hierarchy
from repro.core.gnn import GNNConfig, gnn_forward, init_coarse_levels, init_gnn
from repro.core.graph_state import (
    NMPPlan, ShardedGraph, as_graph, nmp_impl, register_nmp_impl,
    registered_nmp_impls,
)
from repro.core.halo import (
    A2A, NEIGHBOR, NONE, HaloSpec, halo_spec_from_plan, halo_sync,
    halo_sync_stacked,
)
from repro.core.consistent_loss import consistent_mse, consistent_node_count, consistent_node_sum
from repro.core.consistent_mp import (
    BLOCKING, OVERLAP, autotune_plan, autotune_schedule, init_nmp_layer,
    interior_frac, measure_plan_candidates, multilevel_vcycle, nmp_layer,
    prolong_aggregate, restrict_aggregate,
)
from repro.core.graph_state import AUTO
from repro.core.partition_quality import (
    mesh_node2part, partition_quality, spectral_node2part,
)
from repro.core.mesh_gen import SEMMesh, box_mesh, gll_points, mesh_graph_edges, taylor_green_velocity
from repro.core.partition import (
    PartitionedGraphs,
    RankGraph,
    flat_rounds2d_perms,
    gather_node_features,
    packed_halo_arrays,
    partition_graph,
    partition_mesh,
    scatter_node_outputs,
)
