"""Production distributed GNN steps: shard_map over ('data', 'graph') axes.

Layout (matches the paper's Frontier runs, adapted to a TPU mesh):
  * 'graph' axis — the paper's spatial decomposition: R sub-graphs of one
    mesh-based graph; halo ppermute/all_to_all traffic lives ONLY here
    (intra-pod ICI).
  * 'data' axis — DDP over snapshots (batches of time steps on the same
    mesh); gradients are psum'ed over ('data', 'graph', ['pod']).
  * optional 'pod' axis — pure data parallelism across pods; only gradient
    all-reduce crosses the inter-pod links.

Inputs per device: x, y_hat blocks [B_local, N_pad, F]; static metadata
sharded over 'graph' (identical for all data replicas).
"""
from __future__ import annotations

import functools
from typing import Dict, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.consistent_loss import consistent_mse
from repro.core.gnn import GNNConfig, gnn_forward
from repro.core.halo import HaloSpec


def _meta_specs(meta: Dict[str, jnp.ndarray], graph_axis: str) -> Dict[str, P]:
    """Static metadata is sharded over the graph axis (leading rank dim)."""
    return {k: P(graph_axis, *(None,) * (v.ndim - 1)) for k, v in meta.items()}


def make_gnn_step_fns(
    mesh: Mesh,
    cfg: GNNConfig,
    halo: HaloSpec,
    data_axes: Sequence[str] = ("data",),
    graph_axis: str = "graph",
    learning_rate: float = 1e-3,
    coarse_halos: Sequence[HaloSpec] = (),
):
    """Build jit'd (eval_step, loss_step, train_step) closed over mesh/halo.

    train_step here is plain SGD for consistency experiments; the full
    training loop (AdamW etc.) lives in repro.train and reuses grad_step.

    Multilevel models (``cfg.n_levels > 1``) additionally need
    ``coarse_halos`` — one HaloSpec per coarse level, each built from that
    level's own halo plan (``halo_spec_from_plan(hierarchy.levels[l].halo,
    mode, axis=graph_axis)``) — and metadata carrying the ``lvl{l}_*``
    arrays (``prepare_gnn_meta(hierarchy=...)``).
    """
    all_axes = tuple(data_axes) + (graph_axis,)
    # NMP hot-loop backend + halo/compute schedule from the model config
    # (see repro.core.consistent_mp)
    backend_kw = dict(backend=cfg.mp_backend, interpret=cfg.mp_interpret,
                      block_n=cfg.seg_block_n, schedule=cfg.mp_schedule,
                      precision=cfg.mp_precision,
                      coarse_halos=tuple(coarse_halos))

    def shard_meta(meta):
        """Strip the leading rank axis inside the shard."""
        return {k: v[0] for k, v in meta.items()}

    def forward_local(params, x, meta):
        # x arrives as [B_local, 1, N_pad, F] (graph axis sharded to size 1)
        m = shard_meta(meta)
        y = gnn_forward(params, x[:, 0], m["static_edge_feats"], m, halo,
                        **backend_kw)
        return y[:, None]

    def loss_local(params, x, y_hat, meta):
        m = shard_meta(meta)
        x, y_hat = x[:, 0], y_hat[:, 0]
        y = gnn_forward(params, x, m["static_edge_feats"], m, halo,
                        **backend_kw)
        # consistent over the graph axis (Eq. 6), mean over data axes
        loss = consistent_mse(y, y_hat, m["node_inv_mult"], axis_names=(graph_axis,))
        if data_axes:
            loss = jax.lax.pmean(loss, tuple(data_axes))
        return loss, y

    def grad_local(params, x, y_hat, meta):
        (loss, y), grads = jax.value_and_grad(loss_local, has_aux=True)(params, x, y_hat, meta)
        # The local backward of the replicated loss computes, on device q,
        # d(sum over ALL devices of the replicated scalar)/d theta_q
        # = n_dev * dL/d theta_q  (theta paths local to q, incl. halo routes).
        # pmean over every axis therefore yields exactly dL/d theta.
        grads = jax.tree.map(lambda g: jax.lax.pmean(g, all_axes), grads)
        return loss, grads

    def _wrap(fn, out_specs, n_feature_args):
        def call(params, *args):
            meta = args[-1]
            in_specs = (
                P(),  # params replicated
                *(P(tuple(data_axes), graph_axis, None, None) for _ in range(n_feature_args)),
                _meta_specs(meta, graph_axis),
            )
            return jax.shard_map(
                functools.partial(fn),
                mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_vma=False,
            )(params, *args)
        return jax.jit(call)

    eval_step = _wrap(forward_local, P(tuple(data_axes), graph_axis, None, None), 1)
    loss_step = _wrap(lambda p, x, y, m: loss_local(p, x, y, m)[0], P(), 2)

    def train_local(params, x, y_hat, meta):
        loss, grads = grad_local(params, x, y_hat, meta)
        new_params = jax.tree.map(lambda p, g: p - learning_rate * g, params, grads)
        return loss, new_params

    def train_call(params, x, y_hat, meta):
        in_specs = (
            P(),
            P(tuple(data_axes), graph_axis, None, None),
            P(tuple(data_axes), graph_axis, None, None),
            _meta_specs(meta, graph_axis),
        )
        return jax.shard_map(
            train_local, mesh=mesh,
            in_specs=in_specs, out_specs=(P(), P()),
            check_vma=False,
        )(params, x, y_hat, meta)

    train_step = jax.jit(train_call, donate_argnums=(0,))

    def grad_call(params, x, y_hat, meta):
        in_specs = (
            P(),
            P(tuple(data_axes), graph_axis, None, None),
            P(tuple(data_axes), graph_axis, None, None),
            _meta_specs(meta, graph_axis),
        )
        return jax.shard_map(
            grad_local, mesh=mesh,
            in_specs=in_specs, out_specs=(P(), P()),
            check_vma=False,
        )(params, x, y_hat, meta)

    grad_step = jax.jit(grad_call)

    return eval_step, loss_step, grad_step, train_step


def shard_inputs(mesh: Mesh, x, meta, data_axes=("data",), graph_axis="graph"):
    """Place host arrays with the step-function shardings."""
    xs = jax.device_put(x, NamedSharding(mesh, P(tuple(data_axes), graph_axis, None, None)))
    ms = {
        k: jax.device_put(v, NamedSharding(mesh, P(graph_axis, *(None,) * (v.ndim - 1))))
        for k, v in meta.items()
    }
    return xs, ms
