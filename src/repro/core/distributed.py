"""Production distributed GNN steps: shard_map over ('data', 'graph') axes.

Layout (matches the paper's Frontier runs, adapted to a TPU mesh):
  * 'graph' axis — the paper's spatial decomposition: R sub-graphs of one
    mesh-based graph; halo ppermute/all_to_all traffic lives ONLY here
    (intra-pod ICI).
  * 'data' axis — DDP over snapshots (batches of time steps on the same
    mesh); gradients are psum'ed over ('data', 'graph', ['pod']).
  * optional 'pod' axis — pure data parallelism across pods; only gradient
    all-reduce crosses the inter-pod links.

Inputs per device: x, y_hat blocks [B_local, N_pad, F]; the static
:class:`~repro.core.graph_state.ShardedGraph` is sharded over 'graph' via
its own ``specs(graph_axis)`` (identical for all data replicas), and the
execution policy — incl. the per-level halo specs — is one
:class:`~repro.core.graph_state.NMPPlan`.
"""
from __future__ import annotations

from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.consistent_loss import consistent_mse
from repro.core.gnn import GNNConfig, gnn_forward
from repro.core.graph_state import NMPPlan, as_graph


def make_gnn_step_fns(
    mesh: Mesh,
    cfg: GNNConfig,
    plan: NMPPlan,
    data_axes: Sequence[str] = ("data",),
    graph_axis: str = "graph",
    learning_rate: float = 1e-3,
):
    """Build jit'd (eval_step, loss_step, grad_step, train_step) closed over
    mesh + plan.

    train_step here is plain SGD for consistency experiments; the full
    training loop (AdamW etc.) lives in repro.train and reuses grad_step.

    Multilevel models (``cfg.n_levels > 1``) need a plan whose
    ``coarse_halos`` carry one HaloSpec per coarse level
    (``NMPPlan.build(hierarchy, mode, ...)``) and a graph built with the
    hierarchy (``ShardedGraph.build(pg, coords, plan, hierarchy=...)``).
    """
    del cfg  # architecture is entirely encoded in the params pytree
    all_axes = tuple(data_axes) + (graph_axis,)

    def forward_local(params, x, graph):
        # x arrives as [B_local, 1, N_pad, F] (graph axis sharded to size 1)
        g = graph.rank_local()
        y = gnn_forward(params, x[:, 0], g, plan)
        return y[:, None]

    def loss_local(params, x, y_hat, graph):
        g = graph.rank_local()
        x, y_hat = x[:, 0], y_hat[:, 0]
        y = gnn_forward(params, x, g, plan)
        # consistent over the graph axis (Eq. 6), mean over data axes
        loss = consistent_mse(y, y_hat, g["node_inv_mult"],
                              axis_names=(graph_axis,))
        if data_axes:
            loss = jax.lax.pmean(loss, tuple(data_axes))
        return loss, y

    def grad_local(params, x, y_hat, graph):
        (loss, y), grads = jax.value_and_grad(loss_local, has_aux=True)(
            params, x, y_hat, graph)
        # The local backward of the replicated loss computes, on device q,
        # d(sum over ALL devices of the replicated scalar)/d theta_q
        # = n_dev * dL/d theta_q  (theta paths local to q, incl. halo routes).
        # pmean over every axis therefore yields exactly dL/d theta.
        grads = jax.tree.map(lambda g: jax.lax.pmean(g, all_axes), grads)
        return loss, grads

    def _wrap(fn, out_specs, n_feature_args):
        def call(params, *args):
            graph = as_graph(args[-1])
            in_specs = (
                P(),  # params replicated
                *(P(tuple(data_axes), graph_axis, None, None)
                  for _ in range(n_feature_args)),
                graph.specs(graph_axis),
            )
            return jax.shard_map(
                fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_vma=False,
            )(params, *args)
        return jax.jit(call)

    eval_step = _wrap(forward_local, P(tuple(data_axes), graph_axis, None, None), 1)
    loss_step = _wrap(lambda p, x, y, g: loss_local(p, x, y, g)[0], P(), 2)

    def train_local(params, x, y_hat, graph):
        loss, grads = grad_local(params, x, y_hat, graph)
        new_params = jax.tree.map(lambda p, g: p - learning_rate * g, params, grads)
        return loss, new_params

    def _wrap_pair(fn, donate=False):
        def call(params, x, y_hat, graph):
            graph = as_graph(graph)
            in_specs = (
                P(),
                P(tuple(data_axes), graph_axis, None, None),
                P(tuple(data_axes), graph_axis, None, None),
                graph.specs(graph_axis),
            )
            return jax.shard_map(
                fn, mesh=mesh,
                in_specs=in_specs, out_specs=(P(), P()),
                check_vma=False,
            )(params, x, y_hat, graph)
        return jax.jit(call, donate_argnums=(0,) if donate else ())

    train_step = _wrap_pair(train_local, donate=True)
    grad_step = _wrap_pair(grad_local)

    return eval_step, loss_step, grad_step, train_step


def shard_graph(mesh: Mesh, graph, graph_axis="graph"):
    """Place the static ShardedGraph with its own shardings — once per run;
    the graph is loop-invariant, so keep the result across steps."""
    graph = as_graph(graph)
    return jax.device_put(
        graph,
        jax.tree.map(lambda s: NamedSharding(mesh, s), graph.specs(graph_axis),
                     is_leaf=lambda v: isinstance(v, P)))


def shard_inputs(mesh: Mesh, x, graph, data_axes=("data",), graph_axis="graph"):
    """Place host arrays with the step-function shardings."""
    xs = jax.device_put(x, NamedSharding(mesh, P(tuple(data_axes), graph_axis, None, None)))
    return xs, shard_graph(mesh, graph, graph_axis)
