"""Consistent multilevel (coarse-grid) hierarchy over the SEM mesh.

Flat message passing moves information one graph hop per layer, so a
surrogate at O(1B) nodes would need thousands of layers for domain-scale
transfer.  Multi-Grid GNNs (Garnier et al., 2024) and X-MeshGraphNet
(Nabian et al., 2024) show the scalable answer is a coarse-grid hierarchy:
restrict node state to a much smaller graph, message-pass there (one coarse
hop spans many fine hops), and prolong the result back.  This module builds
that hierarchy *consistently* — the R-rank partitioned V-cycle is
arithmetically identical to the 1-rank run — by expressing both inter-level
transfers as edge aggregates completed by the existing halo-sum machinery.

Levels
  0   the GLL-node graph (``SEMMesh``, the paper's Sec. II-A graph);
  1   element centroids: one node per spectral element, edges between
      elements sharing at least one GLL node;
  l>1 element-block clustering: the element grid is coarsened by
      ``cluster`` per axis, nodes are block centroids, edges connect blocks
      containing adjacent members (projection of the level below).

Consistency construction (the load-bearing part):

* every level is a ``PartitionedGraphs`` over the SAME R ranks, built with
  ``from_edge_partition`` on a ``node2rank`` derived from the element
  partition — a coarse node's primary copy lives on the rank owning its
  (first) fine children, so restriction is rank-local in the common case;
* each restriction/prolongation edge (fine f -> coarse c, weight pair) is
  assigned to exactly ONE rank: the primary rank of the fine endpoint.
  That rank always holds f; a replica copy of c is forced onto it via
  ``from_edge_partition(extra_nodes=...)``;
* the restriction aggregate is therefore a *partial sum over rank-local
  children*, completed by ``halo_sync(..., combine='sum')`` over the coarse
  level's halo plan — exactly like the Eq. 4b edge aggregate.  Replica
  copies contribute zero and end up holding the full sum, so every coarse
  copy is consistent.  Prolongation is the transpose: partial sums land on
  the fine primary copy and the FINE level's halo plan completes them.
  1-rank == R-rank then holds level by level (values and gradients), which
  ``tests/test_multilevel.py`` and ``tests/drivers/multilevel_driver.py``
  assert for both NMP backends and both halo schedules.

Everything here is host-side numpy, computed once per partition; device
arrays come from ``ShardedGraph.build(pg, coords, plan, hierarchy=...)``
(``repro.core.graph_state``), which nests each coarse level as a child
``ShardedGraph`` carrying its transfer maps.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from repro.core.mesh_gen import SEMMesh, mesh_graph_edges, undirected_to_directed
from repro.core.partition import (
    PartitionedGraphs, RankGraph, _round_up, from_edge_partition,
    from_element_partition, pack, partition_elements,
)


@dataclasses.dataclass
class TransferPlan:
    """Padded per-rank restriction/prolongation index maps between two levels.

    Each row set r holds the transfer edges assigned to rank r (primary rank
    of the fine endpoint); ``fine_idx``/``coarse_idx`` are LOCAL node indices
    on that rank at the fine/coarse level.  ``r_w`` (restriction) and
    ``p_w`` (prolongation) are the per-edge weights — 1/|children(c)| and
    1/|parents(f)| respectively, so both transfers are means over the
    membership relation; padding slots carry weight 0.
    """
    fine_idx: np.ndarray     # int32 [R, M_pad]
    coarse_idx: np.ndarray   # int32 [R, M_pad]
    r_w: np.ndarray          # float32 [R, M_pad]
    p_w: np.ndarray          # float32 [R, M_pad]

    @property
    def m_pad(self) -> int:
        return int(self.fine_idx.shape[1])


@dataclasses.dataclass
class MultiLevelGraphs:
    """The full coarsening hierarchy: per-level partitions + transfers.

    ``levels[0]`` is the fine (GLL-node) partition; ``transfers[l-1]``
    connects level l-1 to level l.  ``coords[l]`` are the global node
    coordinates of level l (centroids for l >= 1) — the source of each
    level's static geometric edge features.
    """
    levels: List[PartitionedGraphs]
    coords: List[np.ndarray]
    transfers: List[TransferPlan]

    @property
    def n_levels(self) -> int:
        return len(self.levels)

    def level_sizes(self) -> List[int]:
        return [pg.n_global for pg in self.levels]


def _primary_ranks(graphs: List[RankGraph], n_nodes: int) -> np.ndarray:
    """Lowest rank holding a copy of each global node (-1 if unowned)."""
    primary = np.full(n_nodes, -1, dtype=np.int64)
    for r in range(len(graphs) - 1, -1, -1):
        primary[graphs[r].global_ids] = r
    return primary


def _parents_table(pairs: np.ndarray, n_fine: int) -> np.ndarray:
    """Ragged membership as a padded table: parents[f] -> [P] coarse ids,
    -1 padding (P = max parents per fine node, <= 2^dim for SEM meshes)."""
    order = np.lexsort((pairs[:, 1], pairs[:, 0]))
    f_sorted = pairs[order, 0]
    counts = np.bincount(f_sorted, minlength=n_fine)
    P = int(counts.max()) if counts.size else 1
    table = np.full((n_fine, max(P, 1)), -1, dtype=np.int64)
    slot = np.arange(pairs.shape[0]) - np.concatenate(
        [[0], np.cumsum(counts)[:-1]])[f_sorted]
    table[f_sorted, slot] = pairs[order, 1]
    return table


def _project_edges(fine_edges: np.ndarray, parents: np.ndarray) -> np.ndarray:
    """Coarse directed edges: project fine edges through the membership
    relation (every parent-pair of a fine edge's endpoints, self-loops
    dropped, deduplicated).  Vectorized: the cross product of the padded
    parent lists of each edge's endpoints, masked and uniqued."""
    if fine_edges.size == 0:
        return np.zeros((0, 2), dtype=np.int64)
    pu = parents[fine_edges[:, 0]]          # [E, P]
    pv = parents[fine_edges[:, 1]]          # [E, P]
    cu = np.repeat(pu[:, :, None], pu.shape[1], axis=2).reshape(-1)
    cv = np.repeat(pv[:, None, :], pv.shape[1], axis=1).reshape(-1)
    keep = (cu >= 0) & (cv >= 0) & (cu != cv)
    if not keep.any():
        return np.zeros((0, 2), dtype=np.int64)
    return np.unique(np.stack([cu[keep], cv[keep]], axis=-1), axis=0)


def _local_lookup(graphs: List[RankGraph], n_nodes: int) -> np.ndarray:
    """[R, n_nodes] global -> local node index per rank (-1 if absent)."""
    lut = np.full((len(graphs), n_nodes), -1, dtype=np.int64)
    for r, g in enumerate(graphs):
        lut[r, g.global_ids] = np.arange(g.global_ids.size)
    return lut


def _pack_transfer(pairs: np.ndarray, owner: np.ndarray,
                   fine_graphs: List[RankGraph],
                   coarse_graphs: List[RankGraph],
                   R: int, pad_to: int = 8,
                   n_fine: int = 0, n_coarse: int = 0) -> TransferPlan:
    """Assign each (fine, coarse) transfer edge to ``owner`` (the fine
    endpoint's primary rank) and pack local-index maps padded per rank."""
    f_g, c_g = pairs[:, 0].astype(np.int64), pairs[:, 1].astype(np.int64)
    n_children = np.bincount(c_g, minlength=n_coarse)
    n_parents = np.bincount(f_g, minlength=n_fine)
    lut_f = _local_lookup(fine_graphs, n_fine)
    lut_c = _local_lookup(coarse_graphs, n_coarse)

    counts = np.bincount(owner, minlength=R)
    m_pad = _round_up(int(counts.max()) if counts.size else 1, pad_to)
    fi = np.zeros((R, m_pad), dtype=np.int32)
    ci = np.zeros((R, m_pad), dtype=np.int32)
    rw = np.zeros((R, m_pad), dtype=np.float32)
    pw = np.zeros((R, m_pad), dtype=np.float32)
    order = np.argsort(owner, kind="stable")
    slot = np.arange(pairs.shape[0]) - np.concatenate(
        [[0], np.cumsum(counts)[:-1]])[owner[order]]
    r_o, f_o, c_o = owner[order], f_g[order], c_g[order]
    lf, lc = lut_f[r_o, f_o], lut_c[r_o, c_o]
    assert (lf >= 0).all() and (lc >= 0).all(), \
        "transfer edge references a node missing from its owner rank"
    fi[r_o, slot] = lf
    ci[r_o, slot] = lc
    rw[r_o, slot] = 1.0 / n_children[c_o]
    pw[r_o, slot] = 1.0 / n_parents[f_o]
    return TransferPlan(fine_idx=fi, coarse_idx=ci, r_w=rw, p_w=pw)


def build_hierarchy(mesh: SEMMesh, rank_grid: Sequence[int], n_levels: int,
                    cluster: int = 2, pad_to: int = 8,
                    node2part: np.ndarray = None) -> MultiLevelGraphs:
    """Build the consistent multilevel hierarchy for an element partition.

    Level 0 reuses the paper's element partitioner; level 1 collapses each
    element to its centroid (``node2rank = elem2rank``, so coarse nodes live
    with their fine children); deeper levels cluster the element grid by
    ``cluster`` per axis, a block's primary rank being that of its first
    member — rank-grid/cluster misalignment then genuinely splits a block's
    children across ranks, which is the case the halo-summed restriction
    exists for.

    ``node2part`` (e.g. from ``repro.core.partition_quality``) overrides the
    block element decomposition: level 0 becomes the vertex-cut edge
    partition of the mesh graph, and each element centroid lives on the
    majority rank of its GLL nodes — the transfer/halo machinery is
    partition-agnostic, so everything downstream is unchanged.
    """
    if n_levels < 1:
        raise ValueError("n_levels must be >= 1")
    R = int(np.prod(rank_grid))
    if node2part is None:
        e2r = partition_elements(mesh, rank_grid)
        graphs0 = from_element_partition(mesh, e2r, R)
    else:
        node2part = np.asarray(node2part, dtype=np.int64)
        graphs0 = from_edge_partition(
            mesh.n_nodes, undirected_to_directed(mesh_graph_edges(mesh)), R,
            node2part=node2part)
        # centroid rank = majority rank over the element's GLL nodes
        e2r = np.array([
            np.bincount(node2part[mesh.elem_nodes[el]], minlength=R).argmax()
            for el in range(mesh.n_elem)], dtype=np.int64)
    pg0 = pack(graphs0, mesh.n_nodes, pad_to=pad_to)

    levels = [pg0]
    coords = [mesh.coords]
    transfers: List[TransferPlan] = []

    prev_graphs = graphs0
    prev_coords = mesh.coords
    prev_primary = _primary_ranks(graphs0, mesh.n_nodes)
    prev_edges = undirected_to_directed(mesh_graph_edges(mesh))
    # element-grid position per level-(l-1) node, used for block clustering
    prev_grid = None
    prev_grid_dims = None

    for level in range(1, n_levels):
        if level == 1:
            # element centroids: membership = the element-node incidence
            n_coarse = mesh.n_elem
            t_fine = mesh.elem_nodes.reshape(-1)
            t_coarse = np.repeat(np.arange(mesh.n_elem), mesh.nodes_per_elem)
            pairs = np.stack([t_fine, t_coarse], axis=-1)
            coarse_coords = np.stack([
                prev_coords[mesh.elem_nodes[e]].mean(axis=0)
                for e in range(mesh.n_elem)])
            node2rank = e2r.copy()
            grid = np.array([mesh.element_grid_index(e)
                             for e in range(mesh.n_elem)], dtype=np.int64)
            grid_dims = np.array(mesh.nelem_axes, dtype=np.int64)
        else:
            # cluster the element grid by `cluster` per axis
            block = prev_grid // cluster
            grid_dims = (prev_grid_dims + cluster - 1) // cluster
            strides = np.ones_like(grid_dims)
            for ax in range(1, len(grid_dims)):
                strides[ax] = strides[ax - 1] * grid_dims[ax - 1]
            flat = (block * strides[None, :]).sum(axis=1)
            n_coarse = int(np.prod(grid_dims))
            pairs = np.stack([np.arange(flat.size, dtype=np.int64), flat],
                             axis=-1)
            coarse_coords = np.zeros((n_coarse, prev_coords.shape[1]))
            counts = np.bincount(flat, minlength=n_coarse).astype(np.float64)
            for d in range(prev_coords.shape[1]):
                coarse_coords[:, d] = np.bincount(
                    flat, weights=prev_coords[:, d], minlength=n_coarse)
            coarse_coords /= np.maximum(counts, 1.0)[:, None]
            # a block lives with its first member's children, reusing the
            # existing rank assignment
            first = np.full(n_coarse, flat.size, dtype=np.int64)
            np.minimum.at(first, flat, np.arange(flat.size))
            node2rank = prev_primary[first]
            grid = np.zeros((n_coarse, len(grid_dims)), dtype=np.int64)
            rem = np.arange(n_coarse)
            for ax in range(len(grid_dims)):
                grid[:, ax] = rem % grid_dims[ax]
                rem = rem // grid_dims[ax]

        if n_coarse < 1:
            raise ValueError(f"level {level} has no nodes")

        # dedup the membership pairs (a face GLL node appears once per
        # touching element — each (f, c) must count once in the transfer)
        pairs = np.unique(pairs, axis=0)
        parents = _parents_table(pairs, len(prev_coords))
        coarse_edges = _project_edges(prev_edges, parents)

        # transfer edges are owned by the fine endpoint's primary rank;
        # force a coarse replica there so both endpoints are rank-local
        owner = prev_primary[pairs[:, 0]]
        extra_arr = [np.unique(pairs[owner == r, 1]) for r in range(R)]

        coarse_graphs = from_edge_partition(
            n_coarse, coarse_edges, R, node2part=node2rank,
            extra_nodes=extra_arr)
        pg_c = pack(coarse_graphs, n_coarse, pad_to=pad_to)
        transfers.append(_pack_transfer(
            pairs, owner, prev_graphs, coarse_graphs, R, pad_to=pad_to,
            n_fine=len(prev_coords), n_coarse=n_coarse))
        levels.append(pg_c)
        coords.append(coarse_coords)

        prev_graphs = coarse_graphs
        prev_coords = coarse_coords
        prev_primary = node2rank.copy()
        prev_edges = coarse_edges
        prev_grid = grid
        prev_grid_dims = grid_dims

    return MultiLevelGraphs(levels=levels, coords=coords, transfers=transfers)


# Device arrays for a hierarchy are produced by ``ShardedGraph.build(pg,
# coords, plan, hierarchy=ml)`` (repro.core.graph_state), which nests each
# coarse level as a child ShardedGraph carrying its transfer maps — the
# retired flat ``lvl{l}_*``-prefixed meta dict is gone.
