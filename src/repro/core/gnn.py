"""The paper's encode-process-decode consistent GNN (Sec. III, Table I).

  1) node & edge encoders: local MLPs lifting F_x / F_e -> N_H;
  2) M consistent NMP layers (Sec. II-B);
  3) node decoder: local MLP N_H -> F_y (edge features discarded).

Configs: "small" (N_H=8, M=4, 2 MLP hidden layers, 3,979 params) and
"large" (N_H=32, M=4, 5 MLP hidden layers, 91,459 params) with F_x=3
(velocity), F_e=7 (relative velocity + distance vector + magnitude).

``GNNConfig`` is pure architecture; the execution policy (backend,
schedule, precision, halo specs, ...) lives in one
:class:`~repro.core.graph_state.NMPPlan` and the static graph arrays in one
:class:`~repro.core.graph_state.ShardedGraph`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import nn
from repro.core.consistent_mp import init_nmp_layer, multilevel_vcycle, nmp_layer
from repro.core.graph_state import NMPPlan, as_graph


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    hidden: int = 8              # N_H
    n_mp_layers: int = 4         # M
    mlp_hidden_layers: int = 2
    node_in: int = 3             # F_x (velocity)
    edge_in: int = 7             # F_e
    node_out: int = 3            # F_y
    name: str = "small"
    # --- multilevel (coarse-grid) message passing (repro.core.coarsen) ---
    n_levels: int = 1            # 1 = flat NMP; >1 adds a consistent V-cycle
    coarse_mp_layers: int = 2    # NMP layers smoothing each coarse level
    coarse_edge_in: int = 4      # coarse static edge feats (dist vec + mag)

    @staticmethod
    def small() -> "GNNConfig":
        return GNNConfig(hidden=8, n_mp_layers=4, mlp_hidden_layers=2, name="small")

    @staticmethod
    def large() -> "GNNConfig":
        return GNNConfig(hidden=32, n_mp_layers=4, mlp_hidden_layers=5, name="large")


def init_gnn(key, cfg: GNNConfig, dtype=jnp.float32) -> nn.Params:
    keys = jax.random.split(key, cfg.n_mp_layers + 3)
    params = {
        "node_enc": nn.init_mlp(keys[0], cfg.node_in, [cfg.hidden] * cfg.mlp_hidden_layers, cfg.hidden, dtype),
        "edge_enc": nn.init_mlp(keys[1], cfg.edge_in, [cfg.hidden] * cfg.mlp_hidden_layers, cfg.hidden, dtype),
        "mp": [init_nmp_layer(keys[2 + i], cfg.hidden, cfg.mlp_hidden_layers, dtype)
               for i in range(cfg.n_mp_layers)],
        "node_dec": nn.init_mlp(keys[-1], cfg.hidden, [cfg.hidden] * cfg.mlp_hidden_layers,
                                cfg.node_out, dtype, final_layernorm=False),
    }
    if cfg.n_levels > 1:
        params["coarse"] = init_coarse_levels(
            jax.random.fold_in(key, 7), cfg.hidden, cfg.mlp_hidden_layers,
            cfg.n_levels, cfg.coarse_mp_layers, cfg.coarse_edge_in, dtype)
    return params


def init_coarse_levels(key, hidden: int, mlp_hidden_layers: int,
                       n_levels: int, coarse_mp_layers: int,
                       coarse_edge_in: int, dtype=jnp.float32) -> list:
    """Per-coarse-level params for the V-cycle: an edge encoder lifting the
    level's static geometric edge features to the hidden width, plus
    ``coarse_mp_layers`` consistent NMP layers smoothing that level."""
    out = []
    for lvl in range(1, n_levels):
        kl = jax.random.fold_in(key, lvl)
        ke, *kmp = jax.random.split(kl, coarse_mp_layers + 1)
        out.append({
            "edge_enc": nn.init_mlp(ke, coarse_edge_in,
                                    [hidden] * mlp_hidden_layers, hidden, dtype),
            "mp": [init_nmp_layer(k, hidden, mlp_hidden_layers, dtype)
                   for k in kmp],
        })
    return out


def build_edge_inputs(x: jnp.ndarray, graph) -> jnp.ndarray:
    """Paper's 7-dim edge init: relative node features ++ distance vec ++ |dist|."""
    src, dst = graph["edge_src"], graph["edge_dst"]
    static_edge_feats = graph["static_edge_feats"]
    rel = jnp.take(x, dst, axis=-2) - jnp.take(x, src, axis=-2)
    if x.ndim == 3 and static_edge_feats.ndim == 2:
        static_edge_feats = jnp.broadcast_to(
            static_edge_feats[None], (x.shape[0],) + static_edge_feats.shape)
    return jnp.concatenate([rel, static_edge_feats], axis=-1)


def gnn_forward(
    params: nn.Params,
    x: jnp.ndarray,                    # [N_pad, F_x] or [B, N_pad, F_x]
    graph,                             # ShardedGraph (rank-local slice)
    plan: NMPPlan,
) -> jnp.ndarray:
    """Full encode-process-decode forward on one shard. Returns [..., N_pad, F_y].

    ``graph`` holds every static array (edge indices, masks, halo buffers,
    static geometric edge features, fused layouts, interior/boundary split,
    nested coarse levels); ``plan`` selects the NMP implementation and the
    per-level halo specs.

    When the params carry coarse levels (``GNNConfig.n_levels > 1``), the M
    fine NMP layers act as the pre-smoother and a consistent multilevel
    V-cycle runs before the decoder; ``graph`` must then carry the coarse
    chain (``ShardedGraph.build(pg, coords, plan, hierarchy=...)``).
    """
    graph = as_graph(graph)
    g0 = graph.levels[0]
    e_in = build_edge_inputs(x, g0)
    h = nn.mlp(params["node_enc"], x) * g0["node_mask"][..., None]
    e = nn.mlp(params["edge_enc"], e_in) * g0["edge_mask"][..., None]
    for lp in params["mp"]:
        h, e = nmp_layer(lp, h, e, g0, plan)
    if "coarse" in params:
        h = multilevel_vcycle(params["coarse"], h, graph, plan)
    y = nn.mlp(params["node_dec"], h) * g0["node_mask"][..., None]
    return y
