"""Spectral-element mesh generation (NekRS-style) and mesh-based graph creation.

Reproduces Sec. II-A of the paper: a box domain is discretized by
non-intersecting hexahedral (or quad, in 2D) elements, each carrying a
(p+1)^dim lattice of Gauss-Legendre-Lobatto (GLL) quadrature points. The
quadrature points become graph nodes; undirected edges connect neighboring
quadrature points along each lattice axis within every element (Fig. 2).

Coincidence structure (Fig. 3) is derived *exactly* via integer lattice
indices (element-endpoint GLL points of adjacent elements share a global
lattice index), avoiding any floating-point coordinate matching.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


# ---------------------------------------------------------------------------
# GLL quadrature
# ---------------------------------------------------------------------------

def gll_points(p: int) -> np.ndarray:
    """GLL nodes on [-1, 1] for polynomial order p ((p+1) points).

    Nodes are the roots of (1-x^2) P'_p(x): endpoints plus the extrema of the
    Legendre polynomial P_p.
    """
    if p < 1:
        raise ValueError("polynomial order must be >= 1")
    if p == 1:
        return np.array([-1.0, 1.0])
    # interior nodes: roots of P'_p
    cp = np.zeros(p + 1)
    cp[p] = 1.0
    dcp = np.polynomial.legendre.legder(cp)
    interior = np.polynomial.legendre.legroots(dcp)
    return np.concatenate([[-1.0], np.sort(interior), [1.0]])


# ---------------------------------------------------------------------------
# mesh / graph containers
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SEMMesh:
    """Box spectral-element mesh.

    Attributes:
      dim: spatial dimension (2 or 3).
      p: polynomial order.
      nelem_axes: elements per axis, length `dim`.
      elem_nodes: [n_elem, (p+1)^dim] global (deduplicated) node ids of every
        element's GLL lattice, in lexicographic lattice order.
      coords: [n_nodes, dim] physical coordinates of each unique global node.
      n_nodes: number of unique global nodes.
    """
    dim: int
    p: int
    nelem_axes: Tuple[int, ...]
    elem_nodes: np.ndarray
    coords: np.ndarray

    @property
    def n_elem(self) -> int:
        return int(self.elem_nodes.shape[0])

    @property
    def n_nodes(self) -> int:
        return int(self.coords.shape[0])

    @property
    def nodes_per_elem(self) -> int:
        return int(self.elem_nodes.shape[1])

    def element_grid_index(self, e: int) -> Tuple[int, ...]:
        """Element's (ex, ey[, ez]) grid position, lexicographic (x fastest)."""
        idx = []
        rem = e
        for n in self.nelem_axes:
            idx.append(rem % n)
            rem //= n
        return tuple(idx)


def box_mesh(nelem_axes: Tuple[int, ...], p: int, lengths: Tuple[float, ...] | None = None) -> SEMMesh:
    """Build a box SEM mesh with `nelem_axes` elements per axis at order p.

    Global node ids come from the global GLL lattice: element `ex` covers
    lattice slots `[ex*p, ex*p + p]` along each axis; adjacent elements share
    the endpoint slot — exactly the coincident-node structure of Fig. 3.
    """
    dim = len(nelem_axes)
    if dim not in (1, 2, 3):
        raise ValueError("dim must be 1, 2, or 3")
    lengths = lengths or tuple(1.0 for _ in range(dim))
    npts_axes = tuple(n * p + 1 for n in nelem_axes)  # global lattice points per axis

    # physical coordinates along each axis (per-element GLL spacing)
    ref = (gll_points(p) + 1.0) / 2.0  # [0, 1] within element
    axis_coords = []
    for ax in range(dim):
        n, L = nelem_axes[ax], lengths[ax]
        h = L / n
        c = np.empty(npts_axes[ax])
        for e in range(n):
            c[e * p:(e + 1) * p + 1] = (e + ref) * h
        axis_coords.append(c)

    # unique global nodes = full lattice
    grids = np.meshgrid(*axis_coords, indexing="ij")
    coords = np.stack([g.reshape(-1) for g in grids], axis=-1)  # lexicographic, axis0 slowest

    # strides for flattening a lattice index (axis 0 slowest, matching reshape above)
    strides = np.ones(dim, dtype=np.int64)
    for ax in range(dim - 2, -1, -1):
        strides[ax] = strides[ax + 1] * npts_axes[ax + 1]

    n_elem = int(np.prod(nelem_axes))
    local_lattice = np.stack(
        np.meshgrid(*[np.arange(p + 1)] * dim, indexing="ij"), axis=-1
    ).reshape(-1, dim)  # [(p+1)^dim, dim]

    elem_nodes = np.empty((n_elem, (p + 1) ** dim), dtype=np.int64)
    for e in range(n_elem):
        # element grid position, x fastest
        idx = []
        rem = e
        for n in nelem_axes:
            idx.append(rem % n)
            rem //= n
        base = np.array(idx, dtype=np.int64) * p  # offset per axis
        glat = local_lattice + base[None, :]
        elem_nodes[e] = (glat * strides[None, :]).sum(axis=1)

    return SEMMesh(dim=dim, p=p, nelem_axes=tuple(nelem_axes), elem_nodes=elem_nodes, coords=coords)


# ---------------------------------------------------------------------------
# graph generation
# ---------------------------------------------------------------------------

def element_lattice_edges(p: int, dim: int) -> np.ndarray:
    """Undirected lattice edges within one element: neighbors along each axis.

    Returns [n_edges, 2] pairs of *local* lattice indices (lexicographic,
    axis 0 slowest — matching `elem_nodes` ordering).
    """
    shape = (p + 1,) * dim
    ids = np.arange(np.prod(shape)).reshape(shape)
    pairs = []
    for ax in range(dim):
        a = np.take(ids, np.arange(p), axis=ax).reshape(-1)
        b = np.take(ids, np.arange(1, p + 1), axis=ax).reshape(-1)
        pairs.append(np.stack([a, b], axis=-1))
    return np.concatenate(pairs, axis=0)


def mesh_graph_edges(mesh: SEMMesh) -> np.ndarray:
    """Deduplicated undirected edges [n_edges, 2] (global ids, sorted pairs)."""
    le = element_lattice_edges(mesh.p, mesh.dim)  # [m, 2]
    src = mesh.elem_nodes[:, le[:, 0]].reshape(-1)
    dst = mesh.elem_nodes[:, le[:, 1]].reshape(-1)
    lo = np.minimum(src, dst)
    hi = np.maximum(src, dst)
    pairs = np.unique(np.stack([lo, hi], axis=-1), axis=0)
    return pairs


def undirected_to_directed(pairs: np.ndarray) -> np.ndarray:
    """[m,2] undirected -> [2m,2] both directions (message passing form)."""
    return np.concatenate([pairs, pairs[:, ::-1]], axis=0)


def edge_features(coords: np.ndarray, edges: np.ndarray) -> np.ndarray:
    """Paper's edge feature init: relative position (dim), distance vector is
    the same thing here, plus its magnitude -> for dim=3 that is the 7-dim
    feature of Sec. III with relative node features added by the caller."""
    rel = coords[edges[:, 1]] - coords[edges[:, 0]]
    mag = np.linalg.norm(rel, axis=-1, keepdims=True)
    return np.concatenate([rel, mag], axis=-1)


def taylor_green_velocity(coords: np.ndarray, t: float = 0.0, nu: float = 0.01) -> np.ndarray:
    """Analytic Taylor-Green vortex velocity field (paper's test data source).

    For dim=3 uses the classical initial condition advected by viscous decay;
    for dim=2 the exact decaying TGV solution.
    """
    dim = coords.shape[1]
    two_pi = 2.0 * np.pi
    decay = np.exp(-2.0 * nu * (two_pi ** 2) * t)
    x = coords * two_pi
    if dim == 3:
        u = np.sin(x[:, 0]) * np.cos(x[:, 1]) * np.cos(x[:, 2])
        v = -np.cos(x[:, 0]) * np.sin(x[:, 1]) * np.cos(x[:, 2])
        w = np.zeros_like(u)
        return (np.stack([u, v, w], axis=-1) * decay).astype(np.float32)
    if dim == 2:
        u = np.sin(x[:, 0]) * np.cos(x[:, 1])
        v = -np.cos(x[:, 0]) * np.sin(x[:, 1])
        return (np.stack([u, v], axis=-1) * decay).astype(np.float32)
    return (np.sin(x) * decay).astype(np.float32)
