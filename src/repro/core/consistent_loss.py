"""Consistent loss function (Sec. II-C, Eq. 6) and consistent node reductions.

The MSE over a partitioned graph equals the un-partitioned Eq. 5 value:
squared errors are weighted by inverse node multiplicity 1/d_i (padding and
halo rows carry weight 0), summed locally, then AllReduce'd (psum) together
with the effective node count N_eff = psum(sum_i 1/d_i).

``axis_names`` lists every mesh axis the reduction spans — for the production
mesh that is ('graph',) for the spatial sum; data-parallel averaging across
('data','pod') is applied by the caller on the already-consistent loss.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def _psum(x, axis_names: Sequence[str]):
    if not axis_names:
        return x
    return jax.lax.psum(x, tuple(axis_names))


def consistent_mse(
    y: jnp.ndarray,                 # [N_pad, Fy] or [B, N_pad, Fy] local prediction
    y_hat: jnp.ndarray,             # same shape, target
    node_inv_mult: jnp.ndarray,     # [N_pad] (0 on padding)
    axis_names: Sequence[str] = (),
) -> jnp.ndarray:
    """Eq. 6: partition-invariant MSE. Returns a scalar (replicated)."""
    fy = y.shape[-1]
    err2 = jnp.sum((y - y_hat) ** 2, axis=-1)          # [..., N_pad]
    w = node_inv_mult
    s_r = jnp.sum(err2 * w, axis=-1)                   # Eq. 6b, [B] or scalar
    n_r = jnp.sum(w)                                    # Eq. 6c local term
    s = _psum(jnp.mean(s_r) if s_r.ndim else s_r, axis_names)   # AllReduce #1
    n_eff = _psum(n_r, axis_names)                     # AllReduce #2
    return s / (n_eff * fy)


def consistent_node_sum(
    values: jnp.ndarray,            # [N_pad, ...] local node values
    node_inv_mult: jnp.ndarray,
    axis_names: Sequence[str] = (),
) -> jnp.ndarray:
    """Partition-invariant sum over graph nodes of an arbitrary node field."""
    w = node_inv_mult[(...,) + (None,) * (values.ndim - 1)]
    return _psum(jnp.sum(values * w, axis=0), axis_names)


def consistent_node_count(node_inv_mult: jnp.ndarray,
                          axis_names: Sequence[str] = ()) -> jnp.ndarray:
    """N_eff of Eq. 6c — equals the un-partitioned node count."""
    return _psum(jnp.sum(node_inv_mult), axis_names)
