"""Consistent neural message passing layer (Sec. II-B, Eq. 4a-e).

Operates on one rank's (shard's) padded arrays; the halo exchange injects the
cross-rank synchronization. With ``HaloSpec(mode='none')`` this reduces to the
standard (inconsistent) NMP layer the paper compares against; with R=1
partitioning it is the un-partitioned baseline.

Layer structure follows the paper exactly:
  4a  e_ij' = MLP_e(x_i, x_j, e_ij)            (residual MLP, LayerNorm, ELU)
  4b  a_i   = sum_{j in N(i)} e_ij' / d_ij     (segment_sum with 1/d_ij)
  4c  halo swap of local aggregates            (differentiable collective)
  4d  a_i*  = sum over coincident copies       (fused scatter-add)
  4e  x_i'  = MLP_n(a_i*, x_i)                 (residual on node features)

Backends for the 4a+4b hot loop (``backend=`` on :func:`nmp_layer`):

* ``"xla"``   — plain lowering: HBM-materialized ``[E, 3H]`` gather+concat,
  edge MLP, then a serialized ``segment_sum`` scatter-add.  Always available.
* ``"fused"`` — the Pallas kernel pair in ``repro.kernels.segment_agg``:
  per-tile src/dst node-id lists are scalar-prefetched into SMEM and drive
  double-buffered DMA row gathers of node features out of HBM/ANY memory;
  the full residual edge MLP (incl. LayerNorm) and the 1/d_ij-weighted
  aggregation run on the VMEM tile, with the aggregate accumulated by
  per-row scatter-adds (cost O(E·H) — no one-hot matrices, no O(E·N) term);
  a ``jax.custom_vjp`` routes the backward pass through a second Pallas
  kernel, so the layer stays fully differentiable (Eq. 3 gradient
  consistency is preserved — tested).  Requires ``meta["seg_perm"]`` /
  ``meta["seg_src"]`` / ``meta["seg_dst"]`` from the cached layout pass
  (``PartitionedGraphs.segment_layout(block_n, block_e)``), built with the
  same ``block_e`` passed here.  ``interpret=True`` executes the same
  kernels through the Pallas interpreter so CPU CI exercises the production
  code path.

Both backends compute identical arithmetic (fp32-tolerance identical: only
the aggregation summation order differs), so the paper's consistency
guarantee survives the kernel swap; ``tests/test_consistency.py`` asserts
this on 1-rank and multi-partition halo graphs for values *and* gradients.

Mixed precision (``precision=`` on :func:`nmp_layer`): ``"bf16"`` runs the
Eq. 4a edge-MLP matmuls with bf16 operands and fp32 accumulation on *both*
backends (``nn.mlp(precision=...)`` for xla, the in-kernel policy for
fused); aggregation always accumulates fp32.  The default ``"fp32"`` is
bit-stable with the pre-knob code, which is what the consistency tests pin
— bf16 trades ~3 decimal digits of edge-MLP mantissa for MXU throughput and
is NOT covered by the bitwise consistency guarantee (tested to bf16
tolerance only).

Schedules for the whole layer (``schedule=`` on :func:`nmp_layer`):

* ``"blocking"`` — exchange and compute run serially (paper order).
* ``"overlap"``  — interior/boundary split: edges whose destination is
  shared with another rank run first, their partial aggregate enters the
  halo exchange, and the (typically much larger) interior edge set — whose
  aggregate rows the exchange never touches — is processed with no data
  dependence on the collective, so XLA's latency-hiding scheduler can run
  it under the in-flight ppermute rounds.  Values and gradients match the
  blocking schedule to fp32 tolerance (tested, incl. the two-level
  ``rounds2d`` halo).
"""
from __future__ import annotations

from typing import Callable, Dict, Sequence

import jax
import jax.numpy as jnp

from repro import nn
from repro.core.halo import NEIGHBOR, HaloSpec, halo_sync
from repro.graph import segment

XLA = "xla"
FUSED = "fused"

BLOCKING = "blocking"
OVERLAP = "overlap"

FP32 = "fp32"
BF16 = "bf16"
PRECISIONS = (FP32, BF16)


def init_nmp_layer(key, hidden: int, mlp_hidden_layers: int, dtype=jnp.float32) -> nn.Params:
    ke, kn = jax.random.split(key)
    return {
        # edge MLP consumes [x_i, x_j, e_ij] -> hidden
        "edge": nn.init_mlp(ke, 3 * hidden, [hidden] * mlp_hidden_layers, hidden, dtype),
        # node MLP consumes [a_i*, x_i] -> hidden
        "node": nn.init_mlp(kn, 2 * hidden, [hidden] * mlp_hidden_layers, hidden, dtype),
    }


def _map_batched(one, x, e):
    """Apply ``one(x_b, e_b) -> (e', agg)`` over an optional leading batch
    dim (python loop: batch sizes here are tiny and the fused kernel path
    is not vmappable)."""
    if x.ndim == 3:
        outs = [one(x[b], e[b]) for b in range(x.shape[0])]
        return jnp.stack([o[0] for o in outs]), jnp.stack([o[1] for o in outs])
    return one(x, e)


def edge_update_aggregate(
    params: nn.Params,
    x: jnp.ndarray,            # [N_pad, H] or [B, N_pad, H]
    e: jnp.ndarray,            # [E_pad, H] or [B, E_pad, H]
    meta: Dict[str, jnp.ndarray],
    *,
    backend: str = XLA,
    interpret: bool = False,
    block_n: int = 128,
    precision: str = FP32,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Eq. 4a + 4b on one shard: returns (e', local aggregate a).

    The rank-local part of the layer, shared by the production shard_map path
    and the stacked single-device reference — both backends are available to
    both paths, which is how backend-vs-backend consistency is tested.
    """
    if precision not in PRECISIONS:
        raise ValueError(f"unknown precision {precision!r}; expected one of "
                         f"{PRECISIONS}")
    src = meta["edge_src"]
    dst = meta["edge_dst"]
    n_pad = x.shape[-2]

    if backend == FUSED:
        if "seg_perm" not in meta or "seg_src" not in meta:
            raise ValueError(
                "backend='fused' needs meta['seg_perm']/meta['seg_src']/"
                "meta['seg_dst'] — attach the cached layout via "
                "PartitionedGraphs.segment_layout / rank_static_inputs("
                "seg_layout=...)")
        from repro.kernels.segment_agg.ops import fused_nmp_edge_agg

        def one(xb, eb):
            return fused_nmp_edge_agg(
                xb, eb, params["edge"], meta["seg_perm"], meta["seg_src"],
                meta["seg_dst"], meta["edge_mask"], meta["edge_inv_mult"],
                block_n=block_n, interpret=interpret, precision=precision)

        return _map_batched(one, x, e)

    if backend != XLA:
        raise ValueError(f"unknown NMP backend {backend!r}")

    # --- Eq. 4a: edge update (residual) ---
    xi = segment.gather(x, src)
    xj = segment.gather(x, dst)
    feats = jnp.concatenate([xi, xj, e], axis=-1)
    e_new = e + nn.mlp(params["edge"], feats,
                       precision=None if precision == FP32 else precision)
    e_new = e_new * meta["edge_mask"][..., None]

    # --- Eq. 4b: local aggregation with inverse edge multiplicity ---
    weighted = e_new * meta["edge_inv_mult"][..., None]
    if x.ndim == 3:
        agg = jax.vmap(lambda w: segment.segment_sum(w, dst, n_pad))(weighted)
    else:
        agg = segment.segment_sum(weighted, dst, n_pad)
    return e_new, agg


def edge_update_aggregate_part(
    params: nn.Params,
    x: jnp.ndarray,            # [N_pad, H] or [B, N_pad, H]
    e: jnp.ndarray,            # [E_pad, H] or [B, E_pad, H]
    meta: Dict[str, jnp.ndarray],
    part: str,                 # "bnd" | "int"
    *,
    backend: str = XLA,
    interpret: bool = False,
    block_n: int = 128,
    precision: str = FP32,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Eq. 4a + 4b restricted to one side of the interior/boundary edge split.

    Returns (e_part, agg_part), both full-size ([.., E_pad, H] / [.., N_pad,
    H]) but zero outside the side's edges / destination rows.  The two sides
    partition the real edges, so ``e_bnd + e_int`` / ``agg_bnd + agg_int``
    reproduce the unsplit ``edge_update_aggregate`` outputs; interior rows
    are disjoint from the halo send/recv rows, which is what lets the
    overlap schedule run the exchange on ``agg_bnd`` alone.
    """
    if part not in ("bnd", "int"):
        raise ValueError(f"unknown edge split part {part!r}")
    if precision not in PRECISIONS:
        raise ValueError(f"unknown precision {precision!r}; expected one of "
                         f"{PRECISIONS}")
    n_pad = x.shape[-2]

    if backend == FUSED:
        if f"seg_perm_{part}" not in meta:
            raise ValueError(
                "schedule='overlap' with backend='fused' needs the per-side "
                f"layout meta['seg_perm_{part}']/meta['seg_src_{part}']/"
                f"meta['seg_dst_{part}'] — attach it via "
                "PartitionedGraphs.device_arrays(seg_layout=..., "
                "split=True) / rank_static_inputs(..., split=True)")
        from repro.kernels.segment_agg.ops import fused_nmp_edge_agg

        def one(xb, eb):
            # the per-side layout holds only this side's edges, so the full
            # mask/inv-mult arrays select exactly the side's contributions
            return fused_nmp_edge_agg(
                xb, eb, params["edge"], meta[f"seg_perm_{part}"],
                meta[f"seg_src_{part}"], meta[f"seg_dst_{part}"],
                meta["edge_mask"], meta["edge_inv_mult"],
                block_n=block_n, interpret=interpret, precision=precision)

        return _map_batched(one, x, e)

    if backend != XLA:
        raise ValueError(f"unknown NMP backend {backend!r}")
    if f"edge_{part}_idx" not in meta:
        raise ValueError(
            "schedule='overlap' needs the interior/boundary edge split "
            f"(meta['edge_{part}_idx']) — attach it via "
            "PartitionedGraphs.device_arrays(split=True) / "
            "rank_static_inputs(..., split=True) / "
            "prepare_gnn_meta(..., schedule='overlap')")

    idx = meta[f"edge_{part}_idx"]          # [EP] compacted edge ids (0 pad)
    valid = meta[f"edge_{part}_valid"]      # [EP]
    src = meta["edge_src"][idx]
    dst = meta["edge_dst"][idx]
    mask = meta["edge_mask"][idx] * valid
    inv = meta["edge_inv_mult"][idx] * valid

    def one(xb, eb):
        e_sub = eb[idx]
        feats = jnp.concatenate([xb[src], xb[dst], e_sub], axis=-1)
        e_sub = (e_sub + nn.mlp(
            params["edge"], feats,
            precision=None if precision == FP32 else precision)) \
            * mask[..., None]
        agg = segment.segment_sum(e_sub * inv[..., None], dst, n_pad)
        e_full = jnp.zeros(eb.shape[:-1] + (e_sub.shape[-1],), e_sub.dtype)
        e_full = e_full.at[idx].add(e_sub * valid[..., None])
        return e_full, agg

    return _map_batched(one, x, e)


def node_update(params: nn.Params, x: jnp.ndarray, agg: jnp.ndarray,
                meta: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Eq. 4e: residual node MLP on [a_i*, x_i]."""
    x_new = x + nn.mlp(params["node"], jnp.concatenate([agg, x], axis=-1))
    return x_new * meta["node_mask"][..., None]


def nmp_layer(
    params: nn.Params,
    x: jnp.ndarray,            # [N_pad, H] or [B, N_pad, H]
    e: jnp.ndarray,            # [E_pad, H] or [B, E_pad, H]
    meta: Dict[str, jnp.ndarray],
    halo: HaloSpec,
    sync_fn: Callable | None = None,
    edge_parallel_axes: tuple = (),
    backend: str = XLA,
    interpret: bool = False,
    block_n: int = 128,
    schedule: str = BLOCKING,
    precision: str = FP32,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One consistent NMP layer. Returns (x', e').

    ``edge_parallel_axes``: second-level edge parallelism (beyond-paper,
    EXPERIMENTS §Perf): this shard holds only a slice of the sub-graph's
    edges (node set replicated across those mesh axes); the local aggregate
    is psum'ed over them before the halo sync. Arithmetically identical to
    the paper's layer — the aggregation sum is simply split one level more.

    ``backend``/``interpret``/``block_n``/``precision`` select and configure
    the Eq. 4a+4b implementation — see the module docstring (``precision=
    "bf16"`` runs the edge-MLP matmuls with bf16 operands / fp32
    accumulation; the fp32 default keeps the consistency tests bit-stable).

    ``schedule`` picks the communication schedule:

    * ``"blocking"`` — the paper's serial order: full Eq. 4a+4b, then the
      halo exchange, then Eq. 4e.
    * ``"overlap"``  — interior/boundary split: boundary edges (dst shared
      with another rank) are processed first and their partial aggregate
      enters the exchange immediately; interior edges — the bulk of the
      graph for surface-to-volume partitions — have no data dependence on
      the collective, so the compiler is free to run their Eq. 4a+4b under
      the in-flight ppermute/all_to_all rounds.  Requires split metadata
      (``PartitionedGraphs.device_arrays(split=True)``).  Arithmetically
      identical to blocking: interior aggregates land only on rows the
      exchange neither reads nor writes.
    """
    if schedule == OVERLAP:
        part_kw = dict(backend=backend, interpret=interpret, block_n=block_n,
                       precision=precision)
        # boundary side first — the exchange consumes its aggregate
        e_bnd, agg_bnd = edge_update_aggregate_part(
            params, x, e, meta, "bnd", **part_kw)
        if edge_parallel_axes:
            agg_bnd = jax.lax.psum(agg_bnd.astype(e.dtype), edge_parallel_axes)
        # --- Eq. 4c + 4d on the boundary rows only ---
        if sync_fn is not None:
            agg_sync = sync_fn(agg_bnd)
        else:
            agg_sync = halo_sync(agg_bnd, meta, halo, combine="sum")
        # interior side: independent of the collective -> overlappable
        e_int, agg_int = edge_update_aggregate_part(
            params, x, e, meta, "int", **part_kw)
        if edge_parallel_axes:
            agg_int = jax.lax.psum(agg_int.astype(e.dtype), edge_parallel_axes)
        agg = agg_sync + agg_int          # disjoint row support
        return node_update(params, x, agg, meta), e_bnd + e_int
    if schedule != BLOCKING:
        raise ValueError(f"unknown NMP schedule {schedule!r}")

    e_new, agg = edge_update_aggregate(
        params, x, e, meta, backend=backend, interpret=interpret,
        block_n=block_n, precision=precision)
    if edge_parallel_axes:
        # combine partial aggregates in the activation dtype (halves wire
        # bytes when activations are bf16)
        agg = jax.lax.psum(agg.astype(e.dtype), edge_parallel_axes)

    # --- Eq. 4c + 4d: halo swap + synchronization ---
    if sync_fn is not None:
        agg = sync_fn(agg)
    else:
        agg = halo_sync(agg, meta, halo, combine="sum")

    # --- Eq. 4e: node update (residual) ---
    return node_update(params, x, agg, meta), e_new


# ---------------------------------------------------------------------------
# multilevel (coarse-grid) message passing
# ---------------------------------------------------------------------------

def level_meta(meta: Dict[str, jnp.ndarray], level: int) -> Dict[str, jnp.ndarray]:
    """Extract one level's sub-metadata from the flat multilevel dict.

    Level 0 keys are unprefixed; coarse levels are prefixed ``lvl{l}_``
    (see ``repro.core.coarsen.multilevel_static_inputs``).
    """
    if level == 0:
        return {k: v for k, v in meta.items() if not k.startswith("lvl")}
    prefix = f"lvl{level}_"
    sub = {k[len(prefix):]: v for k, v in meta.items() if k.startswith(prefix)}
    if not sub:
        raise ValueError(
            f"multilevel meta for level {level} missing — attach the "
            "coarse-level arrays via repro.core.coarsen."
            "multilevel_static_inputs / prepare_gnn_meta(hierarchy=...)")
    return sub


def _transfer(x: jnp.ndarray, src_idx: jnp.ndarray, dst_idx: jnp.ndarray,
              w: jnp.ndarray, n_out: int) -> jnp.ndarray:
    """Weighted gather/scatter-add: out[dst] += w * x[src] (0-weight pad)."""
    def one(xb):
        return segment.segment_sum(xb[src_idx] * w[:, None], dst_idx, n_out)
    return jax.vmap(one)(x) if x.ndim == 3 else one(x)


def restrict_aggregate(x_fine: jnp.ndarray, tmeta: Dict[str, jnp.ndarray],
                       n_coarse_pad: int) -> jnp.ndarray:
    """Rank-local restriction partial sum (fine -> coarse, weight 1/|children|).

    Each restriction edge lives on exactly one rank (the fine endpoint's
    primary), so this is a PARTIAL sum: the caller must complete it with
    ``halo_sync(..., combine='sum')`` over the coarse level's halo plan —
    the same synchronization the Eq. 4b edge aggregate gets.  Without the
    halo-sum, coarse replica copies would hold zeros and the hierarchy
    would break the 1-rank == R-rank guarantee.
    """
    return _transfer(x_fine, tmeta["t_fine"], tmeta["t_coarse"],
                     tmeta["t_rw"], n_coarse_pad)


def prolong_aggregate(x_coarse: jnp.ndarray, tmeta: Dict[str, jnp.ndarray],
                      n_fine_pad: int) -> jnp.ndarray:
    """Rank-local prolongation partial sum (coarse -> fine, weight
    1/|parents|); completed by a halo-sum over the FINE level's plan."""
    return _transfer(x_coarse, tmeta["t_coarse"], tmeta["t_fine"],
                     tmeta["t_pw"], n_fine_pad)


def multilevel_vcycle(
    coarse_params: Sequence[nn.Params],   # one {"edge_enc", "mp"} per coarse level
    h: jnp.ndarray,                       # [N_pad, H] or [B, N_pad, H] fine state
    meta: Dict[str, jnp.ndarray],         # flat multilevel metadata (lvl{l}_ keys)
    halo: HaloSpec,                       # level-0 halo
    coarse_halos: Sequence[HaloSpec] = (),
    sync_fns: Sequence[Callable | None] | None = None,
    *,
    backend: str = XLA,
    interpret: bool = False,
    block_n: int = 128,
    schedule: str = BLOCKING,
    precision: str = FP32,
) -> jnp.ndarray:
    """One consistent V-cycle over the coarsening hierarchy. Returns h'.

    Down sweep, level l-1 -> l: the fine state is restricted
    (:func:`restrict_aggregate`), the partial sums are halo-summed over the
    coarse level's plan — the step that makes the hierarchy consistent —
    then ``coarse_params[l-1]["mp"]`` consistent NMP layers smooth at that
    level (running through the SAME backend/schedule/precision machinery as
    the fine layers: fused layouts and interior/boundary splits come from
    each level's own ``PartitionedGraphs``).  Up sweep: each level's state
    is prolonged (:func:`prolong_aggregate`), halo-summed over the finer
    level's plan, and residually added.

    ``coarse_halos[l-1]`` is level l's HaloSpec (each level has its own
    ppermute rounds); with fewer entries than coarse levels the level-0
    ``halo`` spec is reused — correct ONLY for the A2A and NONE modes, and
    note the fallback inherits ``wire_dtype`` too (fine-level wire
    compression then also applies to the coarse exchanges).  A NEIGHBOR-mode
    ``halo`` with a missing coarse spec raises rather than routing that
    level's exchange through the fine level's rank-adjacency perms (unless a
    ``sync_fns`` entry overrides that level's exchange).  ``sync_fns``
    optionally overrides the exchange per level (index l applies to level
    l), mirroring ``nmp_layer(sync_fn=...)``.
    """
    n_levels = len(coarse_params) + 1
    metas = [level_meta(meta, lvl) for lvl in range(n_levels)]
    if halo.mode == NEIGHBOR:
        for lvl in range(1, n_levels):
            covered = (lvl - 1 < len(coarse_halos)
                       or (sync_fns is not None and sync_fns[lvl] is not None))
            if not covered:
                raise ValueError(
                    "NEIGHBOR-mode multilevel exchange needs one HaloSpec "
                    f"per coarse level (level {lvl} has neither a "
                    f"coarse_halos entry — got {len(coarse_halos)} for "
                    f"{n_levels - 1} coarse levels — nor a sync_fns "
                    "override): the level-0 perms encode the FINE rank "
                    "adjacency and cannot be reused — build each level's "
                    "spec via halo_spec_from_plan(hierarchy.levels[l].halo, "
                    "...)")
    halos = [halo] + [
        coarse_halos[i] if i < len(coarse_halos) else halo
        for i in range(n_levels - 1)
    ]

    def sync(a, lvl, m):
        if sync_fns is not None and sync_fns[lvl] is not None:
            return sync_fns[lvl](a)
        return halo_sync(a, m, halos[lvl], combine="sum")

    layer_kw = dict(backend=backend, interpret=interpret, block_n=block_n,
                    schedule=schedule, precision=precision)
    states = [h]
    # --- down sweep: restrict, complete partial sums, smooth ---
    for lvl in range(1, n_levels):
        m = metas[lvl]
        n_pad_c = m["node_mask"].shape[-1]
        c = restrict_aggregate(states[-1], m, n_pad_c)
        c = sync(c, lvl, m) * m["node_mask"][..., None]
        p = coarse_params[lvl - 1]
        e = nn.mlp(p["edge_enc"], m["static_edge_feats"]) \
            * m["edge_mask"][..., None]
        if c.ndim == 3:
            e = jnp.broadcast_to(e[None], (c.shape[0],) + e.shape)
        for lp in p["mp"]:
            c, e = nmp_layer(lp, c, e, m, halos[lvl],
                             sync_fn=sync_fns[lvl] if sync_fns else None,
                             **layer_kw)
        states.append(c)
    # --- up sweep: prolong, complete partial sums, residual add ---
    for lvl in range(n_levels - 1, 0, -1):
        mf = metas[lvl - 1]
        n_pad_f = mf["node_mask"].shape[-1]
        up = prolong_aggregate(states[lvl], metas[lvl], n_pad_f)
        up = sync(up, lvl - 1, mf)
        states[lvl - 1] = (states[lvl - 1] + up) * mf["node_mask"][..., None]
    return states[0]
