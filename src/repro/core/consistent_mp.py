"""Consistent neural message passing layer (Sec. II-B, Eq. 4a-e).

Operates on one rank's (shard's) padded arrays; the halo exchange injects the
cross-rank synchronization. With ``HaloSpec(mode='none')`` this reduces to the
standard (inconsistent) NMP layer the paper compares against; with R=1
partitioning it is the un-partitioned baseline.

Layer structure follows the paper exactly:
  4a  e_ij' = MLP_e(x_i, x_j, e_ij)            (residual MLP, LayerNorm, ELU)
  4b  a_i   = sum_{j in N(i)} e_ij' / d_ij     (segment_sum with 1/d_ij)
  4c  halo swap of local aggregates            (differentiable collective)
  4d  a_i*  = sum over coincident copies       (fused scatter-add)
  4e  x_i'  = MLP_n(a_i*, x_i)                 (residual on node features)

Backends for the 4a+4b hot loop (``backend=`` on :func:`nmp_layer`):

* ``"xla"``   — plain lowering: HBM-materialized ``[E, 3H]`` gather+concat,
  edge MLP, then a serialized ``segment_sum`` scatter-add.  Always available.
* ``"fused"`` — the Pallas kernel in ``repro.kernels.segment_agg``: the
  src/dst node-feature gathers, the full residual edge MLP (incl. LayerNorm)
  and the 1/d_ij-weighted aggregation run as MXU matmuls over VMEM tiles of a
  destination-aligned edge layout; a ``jax.custom_vjp`` routes the backward
  pass through a second Pallas kernel, so the layer stays fully
  differentiable (Eq. 3 gradient consistency is preserved — tested).
  Requires ``meta["seg_perm"]`` / ``meta["seg_dstl"]`` from the cached
  layout pass (``PartitionedGraphs.segment_layout(block_n, block_e)``), built
  with the same ``block_n``/``block_e`` passed here.  ``interpret=True``
  executes the same kernels through the Pallas interpreter so CPU CI
  exercises the production code path.

Both backends compute identical arithmetic (fp32-tolerance identical: the
aggregation order differs — one-hot matmul vs scatter-add), so the paper's
consistency guarantee survives the kernel swap; ``tests/test_consistency.py``
asserts this on 1-rank and multi-partition halo graphs for values *and*
gradients.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro import nn
from repro.core.halo import HaloSpec, halo_sync
from repro.graph import segment

XLA = "xla"
FUSED = "fused"


def init_nmp_layer(key, hidden: int, mlp_hidden_layers: int, dtype=jnp.float32) -> nn.Params:
    ke, kn = jax.random.split(key)
    return {
        # edge MLP consumes [x_i, x_j, e_ij] -> hidden
        "edge": nn.init_mlp(ke, 3 * hidden, [hidden] * mlp_hidden_layers, hidden, dtype),
        # node MLP consumes [a_i*, x_i] -> hidden
        "node": nn.init_mlp(kn, 2 * hidden, [hidden] * mlp_hidden_layers, hidden, dtype),
    }


def edge_update_aggregate(
    params: nn.Params,
    x: jnp.ndarray,            # [N_pad, H] or [B, N_pad, H]
    e: jnp.ndarray,            # [E_pad, H] or [B, E_pad, H]
    meta: Dict[str, jnp.ndarray],
    *,
    backend: str = XLA,
    interpret: bool = False,
    block_n: int = 128,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Eq. 4a + 4b on one shard: returns (e', local aggregate a).

    The rank-local part of the layer, shared by the production shard_map path
    and the stacked single-device reference — both backends are available to
    both paths, which is how backend-vs-backend consistency is tested.
    """
    src = meta["edge_src"]
    dst = meta["edge_dst"]
    n_pad = x.shape[-2]

    if backend == FUSED:
        if "seg_perm" not in meta or "seg_dstl" not in meta:
            raise ValueError(
                "backend='fused' needs meta['seg_perm']/meta['seg_dstl'] — "
                "attach the cached layout via "
                "PartitionedGraphs.segment_layout / rank_static_inputs("
                "seg_layout=...)")
        from repro.kernels.segment_agg.ops import fused_nmp_edge_agg

        def one(xb, eb):
            return fused_nmp_edge_agg(
                xb, eb, params["edge"], meta["seg_perm"], meta["seg_dstl"],
                src, meta["edge_mask"], meta["edge_inv_mult"],
                block_n=block_n, interpret=interpret)

        if x.ndim == 3:
            outs = [one(x[b], e[b]) for b in range(x.shape[0])]
            e_new = jnp.stack([o[0] for o in outs])
            agg = jnp.stack([o[1] for o in outs])
        else:
            e_new, agg = one(x, e)
        return e_new, agg

    if backend != XLA:
        raise ValueError(f"unknown NMP backend {backend!r}")

    # --- Eq. 4a: edge update (residual) ---
    xi = segment.gather(x, src)
    xj = segment.gather(x, dst)
    feats = jnp.concatenate([xi, xj, e], axis=-1)
    e_new = e + nn.mlp(params["edge"], feats)
    e_new = e_new * meta["edge_mask"][..., None]

    # --- Eq. 4b: local aggregation with inverse edge multiplicity ---
    weighted = e_new * meta["edge_inv_mult"][..., None]
    if x.ndim == 3:
        agg = jax.vmap(lambda w: segment.segment_sum(w, dst, n_pad))(weighted)
    else:
        agg = segment.segment_sum(weighted, dst, n_pad)
    return e_new, agg


def node_update(params: nn.Params, x: jnp.ndarray, agg: jnp.ndarray,
                meta: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Eq. 4e: residual node MLP on [a_i*, x_i]."""
    x_new = x + nn.mlp(params["node"], jnp.concatenate([agg, x], axis=-1))
    return x_new * meta["node_mask"][..., None]


def nmp_layer(
    params: nn.Params,
    x: jnp.ndarray,            # [N_pad, H] or [B, N_pad, H]
    e: jnp.ndarray,            # [E_pad, H] or [B, E_pad, H]
    meta: Dict[str, jnp.ndarray],
    halo: HaloSpec,
    sync_fn: Callable | None = None,
    edge_parallel_axes: tuple = (),
    backend: str = XLA,
    interpret: bool = False,
    block_n: int = 128,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One consistent NMP layer. Returns (x', e').

    ``edge_parallel_axes``: second-level edge parallelism (beyond-paper,
    EXPERIMENTS §Perf): this shard holds only a slice of the sub-graph's
    edges (node set replicated across those mesh axes); the local aggregate
    is psum'ed over them before the halo sync. Arithmetically identical to
    the paper's layer — the aggregation sum is simply split one level more.

    ``backend``/``interpret``/``block_n`` select and configure the Eq. 4a+4b
    implementation — see the module docstring.
    """
    e_new, agg = edge_update_aggregate(
        params, x, e, meta, backend=backend, interpret=interpret,
        block_n=block_n)
    if edge_parallel_axes:
        # combine partial aggregates in the activation dtype (halves wire
        # bytes when activations are bf16)
        agg = jax.lax.psum(agg.astype(e.dtype), edge_parallel_axes)

    # --- Eq. 4c + 4d: halo swap + synchronization ---
    if sync_fn is not None:
        agg = sync_fn(agg)
    else:
        agg = halo_sync(agg, meta, halo, combine="sum")

    # --- Eq. 4e: node update (residual) ---
    return node_update(params, x, agg, meta), e_new
