"""Consistent neural message passing layer (Sec. II-B, Eq. 4a-e).

Operates on one rank's (shard's) padded arrays; the halo exchange injects the
cross-rank synchronization. With ``HaloSpec(mode='none')`` this reduces to the
standard (inconsistent) NMP layer the paper compares against; with R=1
partitioning it is the un-partitioned baseline.

Layer structure follows the paper exactly:
  4a  e_ij' = MLP_e(x_i, x_j, e_ij)            (residual MLP, LayerNorm, ELU)
  4b  a_i   = sum_{j in N(i)} e_ij' / d_ij     (segment_sum with 1/d_ij)
  4c  halo swap of local aggregates            (differentiable collective)
  4d  a_i*  = sum over coincident copies       (fused scatter-add)
  4e  x_i'  = MLP_n(a_i*, x_i)                 (residual on node features)
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro import nn
from repro.core.halo import HaloSpec, halo_sync
from repro.graph import segment


def init_nmp_layer(key, hidden: int, mlp_hidden_layers: int, dtype=jnp.float32) -> nn.Params:
    ke, kn = jax.random.split(key)
    return {
        # edge MLP consumes [x_i, x_j, e_ij] -> hidden
        "edge": nn.init_mlp(ke, 3 * hidden, [hidden] * mlp_hidden_layers, hidden, dtype),
        # node MLP consumes [a_i*, x_i] -> hidden
        "node": nn.init_mlp(kn, 2 * hidden, [hidden] * mlp_hidden_layers, hidden, dtype),
    }


def nmp_layer(
    params: nn.Params,
    x: jnp.ndarray,            # [N_pad, H] or [B, N_pad, H]
    e: jnp.ndarray,            # [E_pad, H] or [B, E_pad, H]
    meta: Dict[str, jnp.ndarray],
    halo: HaloSpec,
    sync_fn: Callable | None = None,
    edge_parallel_axes: tuple = (),
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One consistent NMP layer. Returns (x', e').

    ``edge_parallel_axes``: second-level edge parallelism (beyond-paper,
    EXPERIMENTS §Perf): this shard holds only a slice of the sub-graph's
    edges (node set replicated across those mesh axes); the local aggregate
    is psum'ed over them before the halo sync. Arithmetically identical to
    the paper's layer — the aggregation sum is simply split one level more.
    """
    src = meta["edge_src"]
    dst = meta["edge_dst"]
    n_pad = x.shape[-2]

    # --- Eq. 4a: edge update (residual) ---
    xi = segment.gather(x, src)
    xj = segment.gather(x, dst)
    feats = jnp.concatenate([xi, xj, e], axis=-1)
    e_new = e + nn.mlp(params["edge"], feats)
    e_new = e_new * meta["edge_mask"][..., None]

    # --- Eq. 4b: local aggregation with inverse edge multiplicity ---
    weighted = e_new * meta["edge_inv_mult"][..., None]
    if x.ndim == 3:
        agg = jax.vmap(lambda w: segment.segment_sum(w, dst, n_pad))(weighted)
    else:
        agg = segment.segment_sum(weighted, dst, n_pad)
    if edge_parallel_axes:
        # combine partial aggregates in the activation dtype (halves wire
        # bytes when activations are bf16)
        agg = jax.lax.psum(agg.astype(e.dtype), edge_parallel_axes)

    # --- Eq. 4c + 4d: halo swap + synchronization ---
    if sync_fn is not None:
        agg = sync_fn(agg)
    else:
        agg = halo_sync(agg, meta, halo, combine="sum")

    # --- Eq. 4e: node update (residual) ---
    x_new = x + nn.mlp(params["node"], jnp.concatenate([agg, x], axis=-1))
    x_new = x_new * meta["node_mask"][..., None]
    return x_new, e_new
