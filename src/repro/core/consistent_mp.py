"""Consistent neural message passing layer (Sec. II-B, Eq. 4a-e).

Operates on one rank's (shard's) padded arrays; the halo exchange injects the
cross-rank synchronization. With ``HaloSpec(mode='none')`` this reduces to the
standard (inconsistent) NMP layer the paper compares against; with R=1
partitioning it is the un-partitioned baseline.

Layer structure follows the paper exactly:
  4a  e_ij' = MLP_e(x_i, x_j, e_ij)            (residual MLP, LayerNorm, ELU)
  4b  a_i   = sum_{j in N(i)} e_ij' / d_ij     (segment_sum with 1/d_ij)
  4c  halo swap of local aggregates            (differentiable collective)
  4d  a_i*  = sum over coincident copies       (fused scatter-add)
  4e  x_i'  = MLP_n(a_i*, x_i)                 (residual on node features)

Execution policy comes from one :class:`~repro.core.graph_state.NMPPlan`;
graph state from one :class:`~repro.core.graph_state.ShardedGraph`.  The
four (backend x schedule) layer implementations register themselves in the
``graph_state`` registry at import:

Backends for the 4a+4b hot loop (``plan.backend``):

* ``"xla"``   — plain lowering: HBM-materialized ``[E, 3H]`` gather+concat,
  edge MLP, then a serialized ``segment_sum`` scatter-add.  Always available.
* ``"fused"`` — the Pallas kernel pair in ``repro.kernels.segment_agg``:
  per-tile src/dst node-id lists are scalar-prefetched into SMEM and drive
  double-buffered DMA row gathers of node features out of HBM/ANY memory;
  the full residual edge MLP (incl. LayerNorm) and the 1/d_ij-weighted
  aggregation run on the VMEM tile; a ``jax.custom_vjp`` routes the backward
  pass through a second Pallas kernel (Eq. 3 gradient consistency preserved
  — tested).  Requires the cached segment layout on the graph
  (``ShardedGraph.build`` attaches it when the plan's backend is fused).
  ``plan.interpret`` executes the same kernels through the Pallas
  interpreter so CPU CI exercises the production code path.

Both backends compute identical arithmetic (fp32-tolerance identical: only
the aggregation summation order differs), so the paper's consistency
guarantee survives the kernel swap.

Mixed precision (``plan.precision``): ``"bf16"`` runs the Eq. 4a edge-MLP
matmuls with bf16 operands and fp32 accumulation on *both* backends;
aggregation always accumulates fp32.  The default ``"fp32"`` is what the
bitwise consistency tests pin.

Schedules (``plan.schedule``):

* ``"blocking"`` — exchange and compute run serially (paper order).
* ``"overlap"``  — interior/boundary split: edges whose destination is
  shared with another rank run first, their partial aggregate enters the
  halo exchange, and the (typically much larger) interior edge set — whose
  aggregate rows the exchange never touches — is processed with no data
  dependence on the collective, so XLA's latency-hiding scheduler can run
  it under the in-flight ppermute rounds.  Arithmetically identical to
  blocking (``halo_sync(agg_bnd) + agg_int == halo_sync(agg_bnd + agg_int)``).
"""
from __future__ import annotations

import functools
from typing import Callable, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro import nn
from repro.core.graph_state import (
    BF16, BLOCKING, FP32, FUSED, OVERLAP, PRECISIONS, XLA, NMPPlan,
    ShardedGraph, as_graph, nmp_impl, register_nmp_impl,
)
from repro.core.halo import NEIGHBOR, HaloSpec, halo_sync
from repro.graph import segment

__all__ = [
    "XLA", "FUSED", "BLOCKING", "OVERLAP", "FP32", "BF16", "PRECISIONS",
    "init_nmp_layer", "edge_update_aggregate", "edge_update_aggregate_part",
    "node_update", "nmp_layer", "multilevel_vcycle", "restrict_aggregate",
    "prolong_aggregate", "autotune_schedule", "autotune_plan",
    "measure_plan_candidates", "interior_frac",
]


def init_nmp_layer(key, hidden: int, mlp_hidden_layers: int, dtype=jnp.float32) -> nn.Params:
    ke, kn = jax.random.split(key)
    return {
        # edge MLP consumes [x_i, x_j, e_ij] -> hidden
        "edge": nn.init_mlp(ke, 3 * hidden, [hidden] * mlp_hidden_layers, hidden, dtype),
        # node MLP consumes [a_i*, x_i] -> hidden
        "node": nn.init_mlp(kn, 2 * hidden, [hidden] * mlp_hidden_layers, hidden, dtype),
    }


def _map_batched(one, x, e):
    """Apply ``one(x_b, e_b) -> (e', agg)`` over an optional leading batch
    dim (python loop: batch sizes here are tiny and the fused kernel path
    is not vmappable)."""
    if x.ndim == 3:
        outs = [one(x[b], e[b]) for b in range(x.shape[0])]
        return jnp.stack([o[0] for o in outs]), jnp.stack([o[1] for o in outs])
    return one(x, e)


def _mlp_precision(plan: NMPPlan):
    return None if plan.precision == FP32 else plan.precision


# ---------------------------------------------------------------------------
# Eq. 4a + 4b: one aggregate implementation per backend
# ---------------------------------------------------------------------------

def _agg_xla(params, x, e, graph: ShardedGraph, plan: NMPPlan):
    src = graph["edge_src"]
    dst = graph["edge_dst"]
    n_pad = x.shape[-2]

    # --- Eq. 4a: edge update (residual) ---
    xi = segment.gather(x, src)
    xj = segment.gather(x, dst)
    feats = jnp.concatenate([xi, xj, e], axis=-1)
    e_new = e + nn.mlp(params["edge"], feats, precision=_mlp_precision(plan))
    e_new = e_new * graph["edge_mask"][..., None]

    # --- Eq. 4b: local aggregation with inverse edge multiplicity ---
    weighted = e_new * graph["edge_inv_mult"][..., None]
    if x.ndim == 3:
        agg = jax.vmap(lambda w: segment.segment_sum(w, dst, n_pad))(weighted)
    else:
        agg = segment.segment_sum(weighted, dst, n_pad)
    return e_new, agg


def _agg_fused(params, x, e, graph: ShardedGraph, plan: NMPPlan):
    if "seg_perm" not in graph:
        raise ValueError(
            "backend='fused' needs the cached segment layout (seg_perm/"
            "seg_src/seg_dst) on the graph — build it with the fused plan: "
            "ShardedGraph.build(pg, coords, plan)")
    from repro.kernels.segment_agg.ops import fused_nmp_edge_agg

    def one(xb, eb):
        return fused_nmp_edge_agg(
            xb, eb, params["edge"], graph["seg_perm"], graph["seg_src"],
            graph["seg_dst"], graph["edge_mask"], graph["edge_inv_mult"],
            block_n=plan.block_n, interpret=plan.interpret,
            precision=plan.precision)

    return _map_batched(one, x, e)


def _agg_xla_part(params, x, e, graph: ShardedGraph, part: str, plan: NMPPlan):
    if f"edge_{part}_idx" not in graph:
        raise ValueError(
            "schedule='overlap' needs the interior/boundary edge split "
            f"(edge_{part}_idx) on the graph — build it with the overlap "
            "plan: ShardedGraph.build(pg, coords, plan)")
    n_pad = x.shape[-2]
    idx = graph[f"edge_{part}_idx"]         # [EP] compacted edge ids (0 pad)
    valid = graph[f"edge_{part}_valid"]     # [EP]
    src = graph["edge_src"][idx]
    dst = graph["edge_dst"][idx]
    mask = graph["edge_mask"][idx] * valid
    inv = graph["edge_inv_mult"][idx] * valid

    def one(xb, eb):
        e_sub = eb[idx]
        feats = jnp.concatenate([xb[src], xb[dst], e_sub], axis=-1)
        e_sub = (e_sub + nn.mlp(params["edge"], feats,
                                precision=_mlp_precision(plan))) \
            * mask[..., None]
        agg = segment.segment_sum(e_sub * inv[..., None], dst, n_pad)
        e_full = jnp.zeros(eb.shape[:-1] + (e_sub.shape[-1],), e_sub.dtype)
        e_full = e_full.at[idx].add(e_sub * valid[..., None])
        return e_full, agg

    return _map_batched(one, x, e)


def _agg_fused_part(params, x, e, graph: ShardedGraph, part: str, plan: NMPPlan):
    if f"seg_perm_{part}" not in graph:
        raise ValueError(
            "schedule='overlap' with backend='fused' needs the per-side "
            f"segment layout (seg_perm_{part}/seg_src_{part}/seg_dst_{part}) "
            "on the graph — build it with the fused+overlap plan: "
            "ShardedGraph.build(pg, coords, plan)")
    from repro.kernels.segment_agg.ops import fused_nmp_edge_agg

    def one(xb, eb):
        # the per-side layout holds only this side's edges, so the full
        # mask/inv-mult arrays select exactly the side's contributions
        return fused_nmp_edge_agg(
            xb, eb, params["edge"], graph[f"seg_perm_{part}"],
            graph[f"seg_src_{part}"], graph[f"seg_dst_{part}"],
            graph["edge_mask"], graph["edge_inv_mult"],
            block_n=plan.block_n, interpret=plan.interpret,
            precision=plan.precision)

    return _map_batched(one, x, e)


_AGGS = {XLA: _agg_xla, FUSED: _agg_fused}
_AGGS_PART = {XLA: _agg_xla_part, FUSED: _agg_fused_part}


def edge_update_aggregate(params, x, e, graph, plan: NMPPlan):
    """Eq. 4a + 4b on one shard: returns (e', local aggregate a).

    The rank-local part of the layer, shared by the production shard_map path
    and the stacked single-device reference — both backends are available to
    both paths, which is how backend-vs-backend consistency is tested.
    """
    graph = as_graph(graph)
    if plan.backend not in _AGGS:
        raise ValueError(f"unknown NMP backend {plan.backend!r}; "
                         f"registered: {sorted(_AGGS)}")
    return _AGGS[plan.backend](params, x, e, graph, plan)


def edge_update_aggregate_part(params, x, e, graph, part: str, plan: NMPPlan):
    """Eq. 4a + 4b restricted to one side of the interior/boundary edge split.

    Returns (e_part, agg_part), both full-size ([.., E_pad, H] / [.., N_pad,
    H]) but zero outside the side's edges / destination rows.  The two sides
    partition the real edges, so ``e_bnd + e_int`` / ``agg_bnd + agg_int``
    reproduce the unsplit ``edge_update_aggregate`` outputs; interior rows
    are disjoint from the halo send/recv rows, which is what lets the
    overlap schedule run the exchange on ``agg_bnd`` alone.
    """
    graph = as_graph(graph)
    if part not in ("bnd", "int"):
        raise ValueError(f"unknown edge split part {part!r}")
    if plan.backend not in _AGGS_PART:
        raise ValueError(f"unknown NMP backend {plan.backend!r}; "
                         f"registered: {sorted(_AGGS_PART)}")
    return _AGGS_PART[plan.backend](params, x, e, graph, part, plan)


def node_update(params: nn.Params, x: jnp.ndarray, agg: jnp.ndarray,
                graph) -> jnp.ndarray:
    """Eq. 4e: residual node MLP on [a_i*, x_i]."""
    x_new = x + nn.mlp(params["node"], jnp.concatenate([agg, x], axis=-1))
    return x_new * graph["node_mask"][..., None]


# ---------------------------------------------------------------------------
# the (backend x schedule) layer implementations — registered once
# ---------------------------------------------------------------------------

def _blocking_layer(agg_fn, params, x, e, graph, plan, halo, sync_fn,
                    edge_parallel_axes):
    """The paper's serial order: full Eq. 4a+4b, exchange, Eq. 4e."""
    e_new, agg = agg_fn(params, x, e, graph, plan)
    if edge_parallel_axes:
        # combine partial aggregates in the activation dtype (halves wire
        # bytes when activations are bf16)
        agg = jax.lax.psum(agg.astype(e.dtype), edge_parallel_axes)

    # --- Eq. 4c + 4d: halo swap + synchronization ---
    if sync_fn is not None:
        agg = sync_fn(agg)
    else:
        agg = halo_sync(agg, graph, halo, combine="sum")

    # --- Eq. 4e: node update (residual) ---
    return node_update(params, x, agg, graph), e_new


def _overlap_layer(agg_part_fn, params, x, e, graph, plan, halo, sync_fn,
                   edge_parallel_axes):
    """Interior/boundary split: the exchange consumes only the boundary
    partial aggregate; interior-edge compute has no data dependence on the
    collective and overlaps the in-flight ppermute rounds."""
    # boundary side first — the exchange consumes its aggregate
    e_bnd, agg_bnd = agg_part_fn(params, x, e, graph, "bnd", plan)
    if edge_parallel_axes:
        agg_bnd = jax.lax.psum(agg_bnd.astype(e.dtype), edge_parallel_axes)
    # --- Eq. 4c + 4d on the boundary rows only ---
    if sync_fn is not None:
        agg_sync = sync_fn(agg_bnd)
    else:
        agg_sync = halo_sync(agg_bnd, graph, halo, combine="sum")
    # interior side: independent of the collective -> overlappable
    e_int, agg_int = agg_part_fn(params, x, e, graph, "int", plan)
    if edge_parallel_axes:
        agg_int = jax.lax.psum(agg_int.astype(e.dtype), edge_parallel_axes)
    agg = agg_sync + agg_int          # disjoint row support
    return node_update(params, x, agg, graph), e_bnd + e_int


for _backend, _agg in _AGGS.items():
    register_nmp_impl(_backend, BLOCKING)(
        functools.partial(_blocking_layer, _agg))
for _backend, _agg_part in _AGGS_PART.items():
    register_nmp_impl(_backend, OVERLAP)(
        functools.partial(_overlap_layer, _agg_part))


def nmp_layer(
    params: nn.Params,
    x: jnp.ndarray,            # [N_pad, H] or [B, N_pad, H]
    e: jnp.ndarray,            # [E_pad, H] or [B, E_pad, H]
    graph,                     # ShardedGraph (rank-local or stacked slice)
    plan: NMPPlan,
    halo: HaloSpec | None = None,
    sync_fn: Callable | None = None,
    edge_parallel_axes: tuple = (),
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One consistent NMP layer. Returns (x', e').

    The implementation is resolved from the (backend, schedule) registry in
    ``repro.core.graph_state`` — see the module docstring for the taxonomy.

    ``halo`` defaults to ``plan.halo``; the multilevel V-cycle overrides it
    per level.  ``edge_parallel_axes``: second-level edge parallelism
    (beyond-paper, EXPERIMENTS §Perf): this shard holds only a slice of the
    sub-graph's edges (node set replicated across those mesh axes); the
    local aggregate is psum'ed over them before the halo sync —
    arithmetically identical to the paper's layer, the aggregation sum is
    simply split one level more.
    """
    graph = as_graph(graph)
    impl = nmp_impl(plan)
    halo = plan.halo if halo is None else halo
    return impl(params, x, e, graph, plan, halo, sync_fn, edge_parallel_axes)


# ---------------------------------------------------------------------------
# multilevel (coarse-grid) message passing
# ---------------------------------------------------------------------------

def _transfer(x: jnp.ndarray, src_idx: jnp.ndarray, dst_idx: jnp.ndarray,
              w: jnp.ndarray, n_out: int) -> jnp.ndarray:
    """Weighted gather/scatter-add: out[dst] += w * x[src] (0-weight pad)."""
    def one(xb):
        return segment.segment_sum(xb[src_idx] * w[:, None], dst_idx, n_out)
    return jax.vmap(one)(x) if x.ndim == 3 else one(x)


def restrict_aggregate(x_fine: jnp.ndarray, coarse_graph,
                       n_coarse_pad: int) -> jnp.ndarray:
    """Rank-local restriction partial sum (fine -> coarse, weight 1/|children|).

    ``coarse_graph`` is the coarse level's ShardedGraph slice, which carries
    the transfer maps from the finer level.  Each restriction edge lives on
    exactly one rank (the fine endpoint's primary), so this is a PARTIAL
    sum: the caller must complete it with ``halo_sync(..., combine='sum')``
    over the coarse level's halo plan — the same synchronization the Eq. 4b
    edge aggregate gets.  Without the halo-sum, coarse replica copies would
    hold zeros and the hierarchy would break the 1-rank == R-rank guarantee.
    """
    return _transfer(x_fine, coarse_graph["t_fine"], coarse_graph["t_coarse"],
                     coarse_graph["t_rw"], n_coarse_pad)


def prolong_aggregate(x_coarse: jnp.ndarray, coarse_graph,
                      n_fine_pad: int) -> jnp.ndarray:
    """Rank-local prolongation partial sum (coarse -> fine, weight
    1/|parents|); completed by a halo-sum over the FINE level's plan."""
    return _transfer(x_coarse, coarse_graph["t_coarse"], coarse_graph["t_fine"],
                     coarse_graph["t_pw"], n_fine_pad)


def check_coarse_halos(plan: NMPPlan, n_levels: int,
                       sync_fns: Sequence[Callable | None] | None = None):
    """NEIGHBOR-mode hierarchies need one HaloSpec per coarse level: the
    level-0 perms encode the FINE rank adjacency and cannot be reused."""
    if plan.halo.mode != NEIGHBOR:
        return
    for lvl in range(1, n_levels):
        covered = (lvl - 1 < len(plan.coarse_halos)
                   or (sync_fns is not None and sync_fns[lvl] is not None))
        if not covered:
            raise ValueError(
                "NEIGHBOR-mode multilevel exchange needs one HaloSpec "
                f"per coarse level (level {lvl} has neither a "
                f"coarse_halos entry — got {len(plan.coarse_halos)} for "
                f"{n_levels - 1} coarse levels — nor a sync_fns "
                "override): the level-0 perms encode the FINE rank "
                "adjacency and cannot be reused — build the plan via "
                "NMPPlan.build(hierarchy, mode, ...)")


def multilevel_vcycle(
    coarse_params: Sequence[nn.Params],   # one {"edge_enc", "mp"} per coarse level
    h: jnp.ndarray,                       # [N_pad, H] or [B, N_pad, H] fine state
    graph,                                # fine-level ShardedGraph w/ coarse chain
    plan: NMPPlan,
    sync_fns: Sequence[Callable | None] | None = None,
) -> jnp.ndarray:
    """One consistent V-cycle over the coarsening hierarchy. Returns h'.

    Down sweep, level l-1 -> l: the fine state is restricted
    (:func:`restrict_aggregate`), the partial sums are halo-summed over the
    coarse level's plan — the step that makes the hierarchy consistent —
    then ``coarse_params[l-1]["mp"]`` consistent NMP layers smooth at that
    level (running through the SAME (backend, schedule) registry cell as
    the fine layers: fused layouts and interior/boundary splits come from
    each level's own arrays).  Up sweep: each level's state is prolonged
    (:func:`prolong_aggregate`), halo-summed over the finer level's plan,
    and residually added.

    Per-level halo specs come from ``plan`` (``plan.halos(n_levels)``); a
    NEIGHBOR fine spec with a missing coarse entry raises rather than
    routing that level's exchange through the fine level's rank-adjacency
    perms (unless a ``sync_fns`` entry overrides that level's exchange —
    index l applies to level l, mirroring ``nmp_layer(sync_fn=...)``).
    Note a missing A2A/NONE coarse entry falls back to the fine spec,
    inheriting its ``wire_dtype`` (fine-level wire compression then also
    applies to the coarse exchanges).
    """
    graph = as_graph(graph)
    n_levels = len(coarse_params) + 1
    graph.level(n_levels - 1)          # loud error if coarse levels missing
    levels = graph.levels
    check_coarse_halos(plan, n_levels, sync_fns)
    halos = plan.halos(n_levels)

    def sync(a, lvl, g):
        if sync_fns is not None and sync_fns[lvl] is not None:
            return sync_fns[lvl](a)
        return halo_sync(a, g, halos[lvl], combine="sum")

    states = [h]
    # --- down sweep: restrict, complete partial sums, smooth ---
    for lvl in range(1, n_levels):
        g = levels[lvl]
        n_pad_c = g["node_mask"].shape[-1]
        c = restrict_aggregate(states[-1], g, n_pad_c)
        c = sync(c, lvl, g) * g["node_mask"][..., None]
        p = coarse_params[lvl - 1]
        e = nn.mlp(p["edge_enc"], g["static_edge_feats"]) \
            * g["edge_mask"][..., None]
        if c.ndim == 3:
            e = jnp.broadcast_to(e[None], (c.shape[0],) + e.shape)
        for lp in p["mp"]:
            c, e = nmp_layer(lp, c, e, g, plan, halo=halos[lvl],
                             sync_fn=sync_fns[lvl] if sync_fns else None)
        states.append(c)
    # --- up sweep: prolong, complete partial sums, residual add ---
    for lvl in range(n_levels - 1, 0, -1):
        gf = levels[lvl - 1]
        n_pad_f = gf["node_mask"].shape[-1]
        up = prolong_aggregate(states[lvl], levels[lvl], n_pad_f)
        up = sync(up, lvl - 1, gf)
        states[lvl - 1] = (states[lvl - 1] + up) * gf["node_mask"][..., None]
    return states[0]


# ---------------------------------------------------------------------------
# measured plan autotuning (NMPPlan.autotune: schedule="auto", halo="auto")
# ---------------------------------------------------------------------------

# (graph-hash, R, policy) -> resolved pick (a schedule string for the legacy
# schedule-only path; a (schedule, halo-mode label, wire name) triple for the
# cross-product path), for the process lifetime.  One measurement per
# distinct (graph, rank-count, policy) — the same memoize-the-expensive-probe
# shape as the fused kernels' block-size autotune table.
_SCHEDULE_CACHE: dict = {}

# (graph-hash, R, policy, candidate grid) -> {(schedule, mode label, wire
# name): seconds}.  Kept separate from the pick cache so the benchmark sweep
# (benchmarks/halo_overlap.py) can read the SAME measured table the tuner
# argmins over — the "auto pick matches the best fixed config" acceptance
# check holds by construction.
_TUNE_TABLE_CACHE: dict = {}


def _graph_schedule_key(g0: dict) -> tuple:
    import hashlib
    h = hashlib.sha1()
    for k in ("edge_src", "edge_dst", "node_mask"):
        a = np.asarray(g0[k])
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return (h.hexdigest(),)


def _measure_best_schedule(plan: NMPPlan, g0: dict, hidden: int,
                           iters: int) -> str:
    """Time one jitted stacked NMP layer per schedule; return the winner.

    Uses the stacked single-device evaluator (``reference._smooth_stacked``)
    — the same proxy ``benchmarks/halo_overlap.py`` reports — with random
    params/features at the model's hidden width, min-of-``iters`` timing.
    """
    import time as _time
    from repro.core.reference import _smooth_stacked

    R, n_pad = np.asarray(g0["node_mask"]).shape
    e_pad = np.asarray(g0["edge_mask"]).shape[-1]
    params = init_nmp_layer(jax.random.PRNGKey(0), hidden, 2)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(R, n_pad, hidden)), jnp.float32)
    e = jnp.asarray(rng.normal(size=(R, e_pad, hidden)), jnp.float32)

    best, best_t = BLOCKING, float("inf")
    for sched in (BLOCKING, OVERLAP):
        cand = plan.replace(schedule=sched)
        fn = jax.jit(lambda p, xx, ee, _c=cand:
                     _smooth_stacked(p, xx, ee, g0, _c))
        jax.block_until_ready(fn(params, x, e))        # compile + warm
        t = float("inf")
        for _ in range(iters):
            t0 = _time.perf_counter()
            jax.block_until_ready(fn(params, x, e))
            t = min(t, _time.perf_counter() - t0)
        if t < best_t:
            best, best_t = sched, t
    return best


def interior_frac(g0: dict) -> float:
    """Fraction of real edges in the interior side of the split (edges whose
    aggregate rows the halo exchange never touches)."""
    if "edge_int_valid" not in g0:
        raise ValueError("graph has no interior/boundary split — build it "
                         "with a plan whose schedule is 'overlap' or 'auto'")
    n_int = float(np.asarray(g0["edge_int_valid"]).sum())
    n_bnd = float(np.asarray(g0["edge_bnd_valid"]).sum())
    return n_int / max(n_int + n_bnd, 1.0)


AUTO = "auto"

#: halo-mode labels the cross-product tuner sweeps; "neighbor-packed" is the
#: bucketed wire format (NEIGHBOR collectives over the narrow pk{k}_* arrays)
MODE_LABELS = ("a2a", "neighbor", "neighbor-packed")


def _mode_label(spec: HaloSpec) -> str:
    return f"{spec.mode}-packed" if spec.packed else spec.mode


def _wire_name(wire) -> str | None:
    return None if wire is None else jnp.dtype(wire).name


def _spec_for(spec: HaloSpec, label: str, wire_name: str | None) -> HaloSpec:
    """The fixed HaloSpec a (mode label, wire name) candidate denotes —
    perms/rounds2d/axis/interpret are kept from ``spec``."""
    import dataclasses
    if label == "neighbor-packed":
        mode, packed = NEIGHBOR, True
    elif label in ("a2a", "neighbor", "none"):
        mode, packed = label, False
    else:
        raise ValueError(f"unknown halo-mode label {label!r}; expected one "
                         f"of {MODE_LABELS}")
    wire = None if wire_name is None else jnp.dtype(wire_name)
    return dataclasses.replace(spec, mode=mode, packed=packed,
                               wire_dtype=wire)


def _resolve_plan(plan: NMPPlan, schedule: str, label: str,
                  wire_name: str | None) -> NMPPlan:
    """Apply a resolved (schedule, mode label, wire name) triple to the plan:
    the fine halo and every still-auto coarse halo (each keeps its own
    perms)."""
    halo = _spec_for(plan.halo, label, wire_name)
    coarse = tuple(_spec_for(h, label, wire_name) if h.mode == AUTO else h
                   for h in plan.coarse_halos)
    return plan.replace(schedule=schedule, halo=halo, coarse_halos=coarse)


def _packed_supported(plan: NMPPlan) -> bool:
    # the fused pack/unpack kernels need the Pallas interpreter anywhere
    # but TPU; without it the packed candidate would crash at trace time
    return plan.interpret or jax.default_backend() == "tpu"


def measure_plan_candidates(plan: NMPPlan, graph, hidden: int = 8,
                            iters: int = 20, schedules=None, modes=None,
                            wires=None) -> dict:
    """Time the (schedule × halo-mode × wire) candidate grid on the ACTUAL
    (graph, rank count), memoized for the process lifetime.

    Each candidate times one jitted stacked NMP layer
    (``reference._smooth_stacked``) with the exchange routed through the
    mode-faithful single-device emulator (``halo.halo_sync_stacked``) — the
    same per-rank arithmetic, wire masking/compression, and fused Pallas
    pack/unpack the production shard_map path runs for that candidate.

    Returns {(schedule, mode label, wire name): seconds}; ``NMPPlan.autotune``
    argmins over this table, and ``benchmarks/halo_overlap.py`` records it, so
    the auto pick matches the best measured fixed config by construction.
    """
    import itertools
    import time as _time
    from repro.core.halo import halo_sync_stacked
    from repro.core.reference import _smooth_stacked

    graph = as_graph(graph)
    g0 = graph.levels[0]
    R, n_pad = np.asarray(g0["node_mask"]).shape
    if schedules is None:
        schedules = (BLOCKING, OVERLAP) if plan.schedule == AUTO \
            else (plan.schedule,)
    if modes is None:
        modes = MODE_LABELS if _packed_supported(plan) \
            else ("a2a", "neighbor")
        if plan.halo.mode != AUTO:
            modes = (_mode_label(plan.halo),)
    if wires is None:
        wires = (None,) if plan.halo.wire_dtype is None \
            else (None, _wire_name(plan.halo.wire_dtype))
    wires = tuple(_wire_name(w) for w in wires)
    key = (_graph_schedule_key(g0), R, plan.backend, plan.precision,
           plan.interpret, tuple(schedules), tuple(modes), wires, hidden)
    cached = _TUNE_TABLE_CACHE.get(key)
    if cached is not None:
        return dict(cached)

    e_pad = np.asarray(g0["edge_mask"]).shape[-1]
    params = init_nmp_layer(jax.random.PRNGKey(0), hidden, 2)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(R, n_pad, hidden)), jnp.float32)
    e = jnp.asarray(rng.normal(size=(R, e_pad, hidden)), jnp.float32)

    table = {}
    for sched, label, wire in itertools.product(schedules, modes, wires):
        cand = plan.replace(schedule=sched,
                            halo=_spec_for(plan.halo, label, wire))
        fn = jax.jit(lambda p, xx, ee, _c=cand:
                     _smooth_stacked(p, xx, ee, g0, _c, halo_sync_stacked))
        jax.block_until_ready(fn(params, x, e))        # compile + warm
        t = float("inf")
        for _ in range(iters):
            t0 = _time.perf_counter()
            jax.block_until_ready(fn(params, x, e))
            t = min(t, _time.perf_counter() - t0)
        table[(sched, label, wire)] = t
    _TUNE_TABLE_CACHE[key] = dict(table)
    return table


def autotune_plan(plan: NMPPlan, graph, measure: bool | None = None,
                  hidden: int = 8, iters: int = 20) -> NMPPlan:
    """Resolve every ``"auto"`` field of the plan — ``schedule`` and/or the
    halo ``mode`` — against a stacked graph (see :meth:`NMPPlan.autotune`,
    the public entry point).

    Schedule-only resolution keeps the original measured probe
    (:func:`_measure_best_schedule`) and cache keys; a plan whose halo mode
    is ``"auto"`` upgrades to the (schedule × halo-mode × wire) cross-product
    measured by :func:`measure_plan_candidates`.  Wire candidates are
    ``{None, plan.halo.wire_dtype}`` — the tuner may DROP a requested lossy
    wire dtype when uncompressed measures faster, but never introduces one
    the caller didn't ask for, and never touches the wire of a fixed
    (non-auto) halo mode.
    """
    graph = as_graph(graph)
    g0 = graph.levels[0]
    nm = np.asarray(g0["node_mask"])
    if nm.ndim != 2:
        raise ValueError("autotune needs the stacked graph (leading rank "
                         f"axis); got node_mask of ndim {nm.ndim}")
    if plan.schedule != AUTO and plan.halo.mode != AUTO:
        return plan
    R = nm.shape[0]
    if R <= 1 or plan.halo.mode == "none":
        # no exchange to hide -> blocking trivially optimal; a single rank
        # needs no exchange at all
        out = plan.replace(schedule=BLOCKING) if plan.schedule == AUTO \
            else plan
        if out.halo.mode == AUTO:
            out = _resolve_plan(out, out.schedule, "none", None)
        return out
    if measure is None:
        import os
        measure = os.environ.get("REPRO_SCHEDULE_AUTOTUNE", "1") != "0"

    if plan.halo.mode != AUTO:
        # legacy schedule-only path: same probe, same cache keys
        key = (_graph_schedule_key(g0), R, plan.backend, plan.precision,
               plan.interpret, plan.halo.mode, bool(measure), hidden)
        sched = _SCHEDULE_CACHE.get(key)
        if sched is None:
            if measure:
                sched = _measure_best_schedule(plan, g0, hidden, iters)
            else:
                # structural fallback: once the exchange-independent share
                # of the edge work drops under half, there is not enough
                # interior compute to pay blocking's serialization
                sched = OVERLAP if interior_frac(g0) < 0.5 else BLOCKING
            _SCHEDULE_CACHE[key] = sched
        return plan.replace(schedule=sched)

    # cross-product path: halo mode (and possibly schedule / wire) are auto
    schedules = (BLOCKING, OVERLAP) if plan.schedule == AUTO \
        else (plan.schedule,)
    modes = MODE_LABELS if _packed_supported(plan) else ("a2a", "neighbor")
    wires = (None,) if plan.halo.wire_dtype is None \
        else (None, _wire_name(plan.halo.wire_dtype))
    key = (_graph_schedule_key(g0), R, plan.backend, plan.precision,
           plan.interpret, "cross", tuple(schedules), tuple(modes),
           tuple(wires), bool(measure), hidden)
    triple = _SCHEDULE_CACHE.get(key)
    if triple is None:
        if measure:
            table = measure_plan_candidates(plan, graph, hidden=hidden,
                                            iters=iters, schedules=schedules,
                                            modes=modes, wires=wires)
            triple = min(table, key=table.get)
        else:
            # structural fallback: neighbor rounds bound wire volume by the
            # rank degree (the paper's N-A2A insight) and the packed format
            # only narrows them further; schedule falls back as above
            if plan.schedule == AUTO:
                sched = OVERLAP if interior_frac(g0) < 0.5 else BLOCKING
            else:
                sched = plan.schedule
            label = "neighbor-packed" if _packed_supported(plan) \
                else "neighbor"
            triple = (sched, label, _wire_name(plan.halo.wire_dtype))
        _SCHEDULE_CACHE[key] = triple
    return _resolve_plan(plan, *triple)


def autotune_schedule(plan: NMPPlan, graph, measure: bool | None = None,
                      hidden: int = 8, iters: int = 20) -> NMPPlan:
    """Back-compat alias for :func:`autotune_plan` (historically the tuner
    resolved only ``schedule="auto"``; it now also resolves halo mode
    ``"auto"`` over the full candidate cross-product)."""
    return autotune_plan(plan, graph, measure=measure, hidden=hidden,
                         iters=iters)
