"""Graph-aware partitioning + partition quality metrics (dependency-free).

The paper's consistency guarantee (Eqs. 2, 3) makes the partition a pure
performance knob: ANY ``node2part`` fed to
:func:`repro.core.partition.from_edge_partition` yields bitwise-identical
training, so the only thing a better partitioner changes is how much halo
traffic and replica padding each rank carries.  The block (NekRS-style)
decomposition in :func:`repro.core.partition.partition_elements` is optimal
for isotropic boxes but maximizes halo volume on stretched or unstructured
meshes; this module provides the classic alternative — recursive spectral
bisection with greedy Kernighan–Lin boundary refinement — implemented with
nothing but numpy (no scipy/metis: power iteration recovers the Fiedler
vector).

Entry points
------------
* :func:`spectral_node2part` — node -> part for an arbitrary graph.
* :func:`mesh_node2part` — same, from an ``SEMMesh`` (uses the mesh graph).
* :func:`partition_quality` — halo volume / edge cut / boundary fraction /
  imbalance for a built :class:`~repro.core.partition.PartitionedGraphs`,
  the numbers reported in ``BENCH_partition.json``.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "spectral_node2part",
    "mesh_node2part",
    "partition_quality",
]


# --------------------------------------------------------------------------
# graph helpers
# --------------------------------------------------------------------------

def _undirected_unique(edges: np.ndarray, n_nodes: int) -> np.ndarray:
    """Canonicalize an edge list: [m, 2] unique undirected pairs, no loops."""
    e = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    if e.size == 0:
        return e.reshape(0, 2)
    if e.min() < 0 or e.max() >= n_nodes:
        raise ValueError(f"edge endpoints outside [0, {n_nodes})")
    e = e[e[:, 0] != e[:, 1]]
    lo = np.minimum(e[:, 0], e[:, 1])
    hi = np.maximum(e[:, 0], e[:, 1])
    return np.unique(np.stack([lo, hi], axis=1), axis=0)


def _csr(n: int, und: np.ndarray):
    """Symmetric adjacency in CSR form (ptr, nbr) from undirected edges."""
    src = np.concatenate([und[:, 0], und[:, 1]])
    dst = np.concatenate([und[:, 1], und[:, 0]])
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(src, minlength=n), out=ptr[1:])
    return ptr, dst


def _fiedler_vector(n: int, und: np.ndarray, rng: np.random.Generator,
                    iters: int = 1000) -> np.ndarray:
    """Approximate Fiedler vector (2nd-smallest Laplacian eigenvector).

    Power iteration on the shifted operator ``M = c I - L`` (c = 2 * max
    degree, a Gershgorin bound, so M is PSD and L's smallest eigenvalues
    become M's largest), deflating the constant vector — L's trivial
    kernel — every step.  O(E) per iteration via bincount scatter-adds;
    stops early once the iterate stabilizes (anisotropic meshes have small
    spectral gaps, so the cap must be generous — ``iters`` bounds it).
    """
    v = rng.standard_normal(n)
    v -= v.mean()
    if und.size == 0:
        return v
    src = np.concatenate([und[:, 0], und[:, 1]])
    dst = np.concatenate([und[:, 1], und[:, 0]])
    deg = np.bincount(src, minlength=n).astype(np.float64)
    c = 2.0 * max(float(deg.max()), 1.0)
    v /= np.linalg.norm(v)
    prev = v
    for it in range(iters):
        av = np.bincount(src, weights=v[dst], minlength=n)
        v = (c - deg) * v + av            # M v = c v - (deg * v - A v)
        v -= v.mean()                     # deflate the constant eigenvector
        norm = np.linalg.norm(v)
        if norm < 1e-30:                  # degenerate start: re-seed
            v = rng.standard_normal(n)
            v -= v.mean()
            v /= np.linalg.norm(v)
            continue
        v /= norm
        if it % 10 == 9:
            # sign-aligned change between checkpoints
            if min(np.abs(v - prev).max(), np.abs(v + prev).max()) < 1e-9:
                break
            prev = v
    return v


def _kl_refine(n: int, und: np.ndarray, left: np.ndarray, target_left: int,
               balance_tol: float, passes: int) -> np.ndarray:
    """Greedy Kernighan–Lin boundary refinement of a bisection.

    Repeatedly moves positive-gain boundary nodes across the cut (gain =
    external minus internal degree, recomputed at move time so earlier
    moves in the same pass are accounted for), subject to a balance slack
    of ``max(1, balance_tol * n)`` nodes around the target split.
    """
    left = left.copy()
    if und.size == 0 or n <= 2:
        return left
    ptr, nbr = _csr(n, und)
    slack = max(1, int(balance_tol * n))
    src = np.concatenate([und[:, 0], und[:, 1]])
    dst = np.concatenate([und[:, 1], und[:, 0]])
    for _ in range(passes):
        cross = left[src] != left[dst]
        gain0 = (np.bincount(src[cross], minlength=n)
                 - np.bincount(src[~cross], minlength=n))
        cand = np.nonzero(gain0 > 0)[0]
        if cand.size == 0:
            break
        cand = cand[np.argsort(-gain0[cand], kind="stable")]
        n_left = int(left.sum())
        moved = 0
        for i in cand:
            if left[i]:
                if n_left - 1 < target_left - slack:
                    continue
            elif n_left + 1 > target_left + slack:
                continue
            nb = nbr[ptr[i]:ptr[i + 1]]
            g = int((left[nb] != left[i]).sum()) - int((left[nb] == left[i]).sum())
            if g <= 0:
                continue
            left[i] = not left[i]
            n_left += 1 if left[i] else -1
            moved += 1
        if moved == 0:
            break
    return left


def _bisect(nodes: np.ndarray, und: np.ndarray, part_lo: int, k: int,
            out: np.ndarray, rng: np.random.Generator, balance_tol: float,
            power_iters: int, kl_passes: int) -> None:
    """Recursively split ``nodes`` (global ids) into parts [lo, lo+k)."""
    if k == 1 or nodes.size == 0:
        out[nodes] = part_lo
        return
    k_left = k // 2
    k_right = k - k_left
    n = nodes.size
    # node budget proportional to the sub-part counts (handles odd k)
    n_left = min(max(int(round(n * k_left / k)), 0), n)
    v = _fiedler_vector(n, und, rng, power_iters)
    order = np.argsort(v, kind="stable")
    left = np.zeros(n, dtype=bool)
    left[order[:n_left]] = True
    left = _kl_refine(n, und, left, n_left, balance_tol, kl_passes)
    for side, lo, kk in ((left, part_lo, k_left),
                         (~left, part_lo + k_left, k_right)):
        sub = np.nonzero(side)[0]
        lut = np.full(n, -1, dtype=np.int64)
        lut[sub] = np.arange(sub.size)
        if und.size:
            keep = side[und[:, 0]] & side[und[:, 1]]
            sub_edges = lut[und[keep]]
        else:
            sub_edges = und
        _bisect(nodes[sub], sub_edges, lo, kk, out, rng, balance_tol,
                power_iters, kl_passes)


# --------------------------------------------------------------------------
# public partitioners
# --------------------------------------------------------------------------

def spectral_node2part(
    n_nodes: int,
    edges: np.ndarray,
    n_parts: int,
    *,
    balance_tol: float = 0.05,
    power_iters: int = 1000,
    kl_passes: int = 4,
    seed: int = 0,
) -> np.ndarray:
    """Recursive spectral bisection + KL refinement -> ``node2part`` [N].

    ``edges`` is any [m, 2] edge list (directed or undirected; it is
    symmetrized and deduplicated).  Handles non-power-of-two ``n_parts`` by
    splitting part budgets floor/ceil at every level.  Deterministic for a
    fixed ``seed``.  The result plugs straight into
    :func:`repro.core.partition.from_edge_partition` — consistency is
    guaranteed by construction, so this only moves performance.
    """
    if n_parts < 1:
        raise ValueError(f"n_parts must be >= 1, got {n_parts}")
    out = np.zeros(n_nodes, dtype=np.int64)
    if n_parts == 1 or n_nodes == 0:
        return out
    und = _undirected_unique(edges, n_nodes)
    rng = np.random.default_rng(seed)
    _bisect(np.arange(n_nodes, dtype=np.int64), und, 0, int(n_parts), out,
            rng, balance_tol, power_iters, kl_passes)
    return out


def mesh_node2part(mesh, n_parts: int, **kwargs) -> np.ndarray:
    """Spectral ``node2part`` for an ``SEMMesh`` (GLL-node mesh graph)."""
    from repro.core.mesh_gen import mesh_graph_edges
    return spectral_node2part(mesh.n_nodes, mesh_graph_edges(mesh), n_parts,
                              **kwargs)


# --------------------------------------------------------------------------
# quality metrics
# --------------------------------------------------------------------------

def partition_quality(pg) -> dict:
    """Structural quality metrics for a built ``PartitionedGraphs``.

    Returns (all plain python numbers):
      * ``halo_volume``       — total replica count: sum over ranks of real
        (non-padding) nodes, minus ``n_global``.  This is exactly the number
        of node copies the halo exchange must fill every layer.
      * ``replication``       — mean copies per global node (>= 1.0).
      * ``edge_cut``          — undirected global edges whose endpoints'
        primary (lowest-holding) ranks differ.
      * ``boundary_frac_mean`` / ``boundary_frac_max`` — per-rank fraction
        of real nodes that are shared (``node_inv_mult < 1``), averaged /
        maxed over non-empty ranks.
      * ``imbalance``         — max over ranks of real nodes, divided by the
        ideal ``n_global / R`` (1.0 = perfectly balanced).
    """
    R = pg.R
    node_mask = np.asarray(pg.node_mask)
    inv_mult = np.asarray(pg.node_inv_mult)
    gids = np.asarray(pg.global_ids)
    n_global = int(pg.n_global)

    real = node_mask.sum(axis=1).astype(np.int64)          # [R]
    total_copies = int(real.sum())
    halo_volume = total_copies - n_global

    shared = ((node_mask > 0) & (inv_mult < 1.0)).sum(axis=1)
    nonempty = real > 0
    frac = np.zeros(R, dtype=np.float64)
    frac[nonempty] = shared[nonempty] / real[nonempty]

    # primary rank = lowest rank holding each global node (matches the
    # "first holder owns" convention used by coarsen._primary_ranks)
    primary = np.full(n_global, -1, dtype=np.int64)
    for r in range(R - 1, -1, -1):
        m = node_mask[r] > 0
        primary[gids[r][m]] = r

    # unique undirected global edges across all ranks
    e_src = np.asarray(pg.edge_src)
    e_dst = np.asarray(pg.edge_dst)
    e_mask = np.asarray(pg.edge_mask)
    pairs = []
    for r in range(R):
        m = e_mask[r] > 0
        if not m.any():
            continue
        gs = gids[r][e_src[r][m]]
        gd = gids[r][e_dst[r][m]]
        pairs.append(np.stack([np.minimum(gs, gd), np.maximum(gs, gd)], 1))
    if pairs:
        und = np.unique(np.concatenate(pairs, axis=0), axis=0)
        und = und[und[:, 0] != und[:, 1]]
        edge_cut = int((primary[und[:, 0]] != primary[und[:, 1]]).sum())
    else:
        edge_cut = 0

    ideal = max(n_global / max(R, 1), 1.0)
    return {
        "halo_volume": int(halo_volume),
        "replication": float(total_copies / max(n_global, 1)),
        "edge_cut": edge_cut,
        "boundary_frac_mean": float(frac[nonempty].mean()) if nonempty.any() else 0.0,
        "boundary_frac_max": float(frac.max()) if R else 0.0,
        "imbalance": float(real.max() / ideal) if R else 1.0,
        "max_rank_nodes": int(real.max()) if R else 0,
        "empty_ranks": int((~nonempty).sum()),
    }
