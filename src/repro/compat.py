"""Version-tolerance shims for the JAX API surface this repo targets.

The codebase is written against the post-0.5 public names (``jax.shard_map``,
``jax.sharding.AxisType``, ``jax.make_mesh(..., axis_types=...)``).  Older
runtimes (e.g. 0.4.x) ship the same functionality under experimental paths or
without the newer keyword arguments; :func:`install` patches the gaps in
place so the rest of the package can use one spelling everywhere.

Installed automatically on ``import repro``; idempotent.  Note this patches
the global ``jax`` module (deliberate: the test-suite and benchmark code use
the public spellings directly).  On old JAX the ``axis_types`` argument is
accepted and ignored — every axis behaves as Auto there, which is the only
axis type this repo uses.
"""
from __future__ import annotations

import enum
import inspect

import jax


class _AxisType(enum.Enum):
    """Stand-in for ``jax.sharding.AxisType`` (added after 0.4.x)."""

    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def _shim_axis_type() -> None:
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _AxisType


def _shim_make_mesh() -> None:
    try:
        params = inspect.signature(jax.make_mesh).parameters
    except (TypeError, ValueError):  # C-level or exotic callable: leave it be
        return
    if "axis_types" in params:
        return
    orig = jax.make_mesh

    def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
        del axis_types  # older Mesh has no axis-type concept; all axes "auto"
        return orig(axis_shapes, axis_names, **kw)

    make_mesh.__doc__ = orig.__doc__
    jax.make_mesh = make_mesh


def _shim_shard_map() -> None:
    if hasattr(jax, "shard_map"):
        return
    from jax.experimental.shard_map import shard_map as _shard_map

    sig = inspect.signature(_shard_map).parameters

    def shard_map(f, mesh=None, in_specs=None, out_specs=None, **kw):
        # post-0.5 renamed check_rep -> check_vma; translate when targeting
        # the experimental implementation
        if "check_vma" in kw and "check_vma" not in sig:
            kw["check_rep"] = kw.pop("check_vma")
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)

    shard_map.__doc__ = _shard_map.__doc__
    jax.shard_map = shard_map


def install() -> None:
    """Install every applicable shim (no-op on new-enough JAX)."""
    _shim_axis_type()
    _shim_make_mesh()
    _shim_shard_map()
