"""Logical-axis sharding (MaxText-style): params and activations carry logical
dimension names; per-config rules map them to production-mesh axes.

Init functions build trees whose leaves are ``L(array, dims)``;
``split_tree`` separates them into (params, PartitionSpec tree). Activation
constraints go through ``shard_act``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisRule = Union[None, str, Tuple[str, ...]]
Rules = Dict[str, AxisRule]


@dataclasses.dataclass
class L:
    """A parameter leaf: the array plus its logical dimension names."""
    value: jnp.ndarray
    dims: Tuple[str, ...]


# Registered as a pytree (dims are aux data) so vmap'd initializers can map
# over stacked-layer parameter trees containing L leaves.
jax.tree_util.register_pytree_node(
    L, lambda l: ((l.value,), l.dims), lambda dims, vals: L(vals[0], dims))


def stack_dims(prefix: str, tree):
    """After a vmap'd init added a leading axis, prepend its logical dim."""
    return jax.tree.map(lambda l: L(l.value, (prefix,) + tuple(l.dims)), tree,
                        is_leaf=_is_leaf)


def _is_leaf(x):
    return isinstance(x, L)


def spec_for(dims: Sequence[str], rules: Rules, mesh: Optional[Mesh] = None,
             shape: Optional[Sequence[int]] = None) -> P:
    """PartitionSpec from logical dims; drops rules that don't divide evenly."""
    entries = []
    for i, d in enumerate(dims):
        r = rules.get(d)
        if r is not None and mesh is not None and shape is not None:
            size = 1
            for ax in ((r,) if isinstance(r, str) else r):
                size *= mesh.shape[ax]
            if shape[i] % size != 0:
                r = None  # fall back to replication rather than failing
        entries.append(r)
    return P(*entries)


def split_tree(tree, rules: Rules, mesh: Optional[Mesh] = None):
    """(params, specs) from a tree of L leaves."""
    params = jax.tree.map(lambda l: l.value, tree, is_leaf=_is_leaf)
    specs = jax.tree.map(
        lambda l: spec_for(l.dims, rules, mesh, l.value.shape), tree, is_leaf=_is_leaf)
    return params, specs


def shard_act(x: jnp.ndarray, dims: Sequence[Optional[str]], rules: Rules,
              mesh: Optional[Mesh] = None) -> jnp.ndarray:
    """Constrain activation sharding; no-op when mesh is None (tests on CPU)."""
    if mesh is None:
        return x
    spec = spec_for([d or "_none" for d in dims], rules, mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_shardings(specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))
