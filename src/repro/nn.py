"""Minimal pure-JAX neural-net building blocks (no flax/haiku available offline).

Parameters are plain pytrees (nested dicts of jnp arrays); every module is a
pair of functions: ``init_*(key, ...) -> params`` and ``apply`` (the forward
fn). Initializers follow standard fan-in scaling.
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp

Params = dict


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def glorot(key, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[-2], shape[-1]
    lim = math.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, dtype, -lim, lim)


def lecun_normal(key, shape, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    return jax.random.normal(key, shape, dtype) * math.sqrt(1.0 / fan_in)


def zeros(_key, shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


# ---------------------------------------------------------------------------
# dense / layernorm / mlp
# ---------------------------------------------------------------------------

def init_dense(key, d_in: int, d_out: int, dtype=jnp.float32, bias: bool = True) -> Params:
    kw, _ = jax.random.split(key)
    p = {"w": glorot(kw, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jnp.ndarray, precision: str | None = None) -> jnp.ndarray:
    """``precision="bf16"`` runs the matmul with bf16 operands accumulating
    into fp32 (``preferred_element_type``) — the same mixed-precision policy
    the fused Pallas kernels apply; ``None``/``"fp32"`` is the plain path."""
    if precision == "bf16":
        y = jax.lax.dot_general(
            x.astype(jnp.bfloat16), p["w"].astype(jnp.bfloat16),
            (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    else:
        y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def init_layernorm(d: int, dtype=jnp.float32) -> Params:
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    xhat = (x - mu) * jax.lax.rsqrt(var + eps)
    return xhat * p["g"] + p["b"]


def init_mlp(key, d_in: int, hidden: Sequence[int], d_out: int,
             dtype=jnp.float32, final_layernorm: bool = True) -> Params:
    """Paper-style MLP: hidden layers with ELU, optional output LayerNorm."""
    dims = [d_in, *hidden, d_out]
    keys = jax.random.split(key, len(dims) - 1)
    p: Params = {"layers": [init_dense(k, a, b, dtype) for k, a, b in zip(keys, dims[:-1], dims[1:])]}
    if final_layernorm:
        p["ln"] = init_layernorm(d_out, dtype)
    return p


def mlp(p: Params, x: jnp.ndarray, precision: str | None = None) -> jnp.ndarray:
    n = len(p["layers"])
    for i, lp in enumerate(p["layers"]):
        x = dense(lp, x, precision=precision)
        if i < n - 1:
            x = jax.nn.elu(x)
    if "ln" in p:
        x = layernorm(p["ln"], x)
    return x


def init_residual_mlp(key, d: int, n_hidden_layers: int, dtype=jnp.float32) -> Params:
    """Residual MLP block used by the paper's NMP layers (LayerNorm + ELU)."""
    return init_mlp(key, d, [d] * n_hidden_layers, d, dtype, final_layernorm=True)


def residual_mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return x + mlp(p, x)


# ---------------------------------------------------------------------------
# pytree math helpers
# ---------------------------------------------------------------------------

def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def cast_tree(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)
