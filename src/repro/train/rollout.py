"""Multi-step autoregressive rollout training on the ShardedGraph/NMPPlan API.

One-step training teaches a mesh surrogate to predict t -> t+dt from ground
truth; deployed autoregressively it feeds its OWN predictions back, and the
distribution shift compounds.  X-MeshGraphNet (Nabian et al., 2024) and the
SCALES line of work (Bartoldson et al., 2023) show the fix is to train the
way you roll out: unroll K model steps inside the loss (gradients flow
through the model's own predictions) and optionally perturb the initial
state with *pushforward noise* — a stop-gradient perturbation that emulates
accumulated rollout error without letting the optimizer exploit it.

Everything here preserves the paper's consistency guarantee: each of the K
steps is the full halo-consistent forward, each per-step loss is the
Eq. 6 consistent MSE, so the K-step rollout loss and its parameter
gradients are identical between 1 rank and any R-rank partition (asserted
by ``tests/test_rollout.py`` and ``tests/drivers/rollout_driver.py`` for
both halo/compute schedules, and by ``benchmarks/rollout.py`` on every
bench run).

Shapes (stacked, host side):
  x0       [B, R, N_pad, F]     initial state
  targets  [B, K, R, N_pad, F]  ground-truth states t+1 .. t+K
  noise    [B, R, N_pad, F]     pushforward perturbation (zeros to disable);
                                must be identical across coincident copies —
                                generate on the global node field and
                                ``gather_node_features`` it.

Deterministic-replay contract (elastic resume, CONTRIBUTING.md): every
batch function here is PURE in ``step`` — snapshot times are
``(step*batch + b)*dt`` and noise is drawn from a fresh
``default_rng(seed + step*batch + b)`` — so a run restored from a step-k
checkpoint replays steps k+1.. with exactly the batches the uninterrupted
run saw.  Curriculum state is equally replayable: :func:`curriculum_k` maps
``step`` to its rollout depth as a pure function, never as mutable loop
state.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.consistent_loss import consistent_mse
from repro.core.gnn import GNNConfig, gnn_forward
from repro.core.graph_state import NMPPlan, as_graph
from repro.core.mesh_gen import SEMMesh, taylor_green_velocity
from repro.core.partition import PartitionedGraphs, gather_node_features


def curriculum_k(stages: Sequence[int], n_steps: int, step: int) -> int:
    """Rollout depth K for ``step`` under a staged curriculum.

    ``stages`` (e.g. ``(1, 2, 4)``) split ``n_steps`` into even stages of
    increasing K.  Pure in ``step`` — part of the deterministic-replay
    contract: an elastically resumed run recomputes the same K schedule the
    original run used instead of carrying it as loop state.
    """
    stages = tuple(stages)
    if not stages:
        return 1
    stage_len = max(1, -(-n_steps // len(stages)))
    return stages[min(step // stage_len, len(stages) - 1)]


def rollout_step(params, x0, targets, graph, plan: NMPPlan,
                 noise=None, axis_names: Sequence[str] = ()):
    """Rank-local K-step autoregressive rollout (jit/scan-compiled core).

    Scans the consistent GNN over its own predictions: step k consumes the
    step k-1 output, and every step's halo-consistent MSE against
    ``targets[k]`` enters the mean.  ``noise`` (pushforward) perturbs only
    the step-1 input, wrapped in ``stop_gradient`` so no gradient flows
    through the noised state's perturbation.  Returns (mean per-step loss,
    predictions [K, ..., N_pad, F]).

    ``x0``: [N_pad, F] or [B, N_pad, F]; ``targets``: [K, ...x0 shape...].
    """
    graph = as_graph(graph)
    g0 = graph.levels[0]
    x = x0
    if noise is not None:
        x = x + jax.lax.stop_gradient(noise)

    def body(carry, tgt):
        y = gnn_forward(params, carry, graph, plan)
        loss_k = consistent_mse(y, tgt, g0["node_inv_mult"],
                                axis_names=axis_names)
        return y, (loss_k, y)

    _, (losses, preds) = jax.lax.scan(body, x, targets)
    return losses.mean(), preds


def make_rollout_step_fns(
    mesh: Mesh,
    cfg: GNNConfig,
    plan: NMPPlan,
    rollout_steps: int,
    data_axes: Sequence[str] = ("data",),
    graph_axis: str = "graph",
):
    """Build jit'd (rollout_eval, rollout_grad) over a ('data','graph') mesh.

    ``rollout_eval(params, x0, targets, noise, graph) -> (loss, preds)``
    with preds [B, K, R, N_pad, F]; ``rollout_grad`` additionally returns
    the pmean'd parameter gradients (same contract as
    ``make_gnn_step_fns``'s grad_step).  ``rollout_steps`` must match the
    K dim of ``targets``.
    """
    del cfg  # architecture is entirely encoded in the params pytree
    all_axes = tuple(data_axes) + (graph_axis,)

    def rollout_local(params, x0, targets, noise, graph):
        # x0/noise [B_local, 1, N_pad, F]; targets [B_local, K, 1, N_pad, F]
        g = graph.rank_local()
        tgt = jnp.moveaxis(targets[:, :, 0], 1, 0)        # [K, B, N_pad, F]
        loss, preds = rollout_step(params, x0[:, 0], tgt, g, plan,
                                   noise=noise[:, 0],
                                   axis_names=(graph_axis,))
        if data_axes:
            loss = jax.lax.pmean(loss, tuple(data_axes))
        # preds [K, B, N_pad, F] -> [B, K, 1, N_pad, F]
        return loss, jnp.moveaxis(preds, 0, 1)[:, :, None]

    def grad_local(params, x0, targets, noise, graph):
        (loss, _), grads = jax.value_and_grad(rollout_local, has_aux=True)(
            params, x0, targets, noise, graph)
        grads = jax.tree.map(lambda g: jax.lax.pmean(g, all_axes), grads)
        return loss, grads

    feat = P(tuple(data_axes), graph_axis, None, None)
    seq = P(tuple(data_axes), None, graph_axis, None, None)

    def _wrap(fn, out_specs):
        def call(params, x0, targets, noise, graph):
            graph = as_graph(graph)
            if targets.shape[1] != rollout_steps:
                raise ValueError(
                    f"targets carry K={targets.shape[1]} steps but the step "
                    f"fns were built for rollout_steps={rollout_steps}")
            in_specs = (P(), feat, seq, feat, graph.specs(graph_axis))
            return jax.shard_map(
                fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_vma=False,
            )(params, x0, targets, noise, graph)
        return jax.jit(call)

    rollout_eval = _wrap(rollout_local, (P(), seq))
    rollout_grad = _wrap(grad_local, (P(), P()))
    return rollout_eval, rollout_grad


def make_rollout_predict_fn(
    mesh: Mesh,
    cfg: GNNConfig,
    plan: NMPPlan,
    rollout_steps: int,
    data_axes: Sequence[str] = ("data",),
    graph_axis: str = "graph",
):
    """Inference-only wrapper over :func:`make_rollout_step_fns`' eval step.

    ``predict(params, x0, graph) -> preds [B, K, R, N_pad, F]``.

    The scan body consumes ``targets`` only to compute per-step losses —
    predictions depend on ``x0`` and ``params`` alone — so feeding zero
    targets (and zero pushforward noise) through the EXACT jitted program
    the rollout consistency suite pins yields inference predictions with no
    reimplemented forward.  That reuse is what makes the serving engine's
    bitwise-vs-offline contract checkable at all: engine and offline eval
    literally run the same compiled rollout.

    ``x0`` may be a host array; it is placed with the step function's input
    sharding, and the zero targets/noise are built once per input shape and
    cached (the engine calls this with one fixed batch-slot shape).
    """
    rollout_eval, _ = make_rollout_step_fns(
        mesh, cfg, plan, rollout_steps, data_axes, graph_axis)
    feat_sh = NamedSharding(mesh, P(tuple(data_axes), graph_axis, None, None))
    seq_sh = NamedSharding(mesh, P(tuple(data_axes), None, graph_axis,
                                   None, None))
    zeros_cache: dict = {}

    def predict(params, x0, graph):
        xs = jax.device_put(jnp.asarray(x0, jnp.float32), feat_sh)
        key = tuple(xs.shape)
        if key not in zeros_cache:
            b, r, n, f = xs.shape
            zeros_cache[key] = (
                jax.device_put(
                    jnp.zeros((b, rollout_steps, r, n, f), xs.dtype), seq_sh),
                jax.device_put(jnp.zeros(xs.shape, xs.dtype), feat_sh))
        targets, noise = zeros_cache[key]
        _, preds = rollout_eval(params, xs, targets, noise, graph)
        return preds

    return predict


def make_tgv_rollout_batch_fn(pg: PartitionedGraphs, mesh_sem: SEMMesh,
                              batch: int, rollout_steps: int,
                              dt: float = 0.05, noise_scale=0.0,
                              seed: int = 0):
    """Deterministic Taylor-Green rollout batches keyed by step (replayable).

    Returns ``batch_fn(step) -> (x0, targets, noise)`` with targets the next
    ``rollout_steps`` snapshots of the analytic TGV trajectory.  Pushforward
    noise is drawn on the GLOBAL node field (then gathered per rank), so
    coincident copies receive identical perturbations — a per-copy draw
    would break the 1-rank == R-rank guarantee by construction.

    ``noise_scale`` is a float or a ``step -> float`` callable (annealing
    schedules, see ``TrainConfig.pushforward_noise_final``).
    """
    def batch_fn(step: int):
        scale = noise_scale(step) if callable(noise_scale) else noise_scale
        x0s, tgts, noises = [], [], []
        for b in range(batch):
            t = (step * batch + b) * dt % 2.0
            x0s.append(gather_node_features(
                pg, taylor_green_velocity(mesh_sem.coords, t=t)))
            tgts.append(np.stack([
                gather_node_features(
                    pg, taylor_green_velocity(mesh_sem.coords,
                                              t=t + (k + 1) * dt))
                for k in range(rollout_steps)]))
            rng = np.random.default_rng(
                np.uint64(seed) + np.uint64(step * batch + b))
            nz = rng.normal(size=(mesh_sem.coords.shape[0],
                                  x0s[-1].shape[-1])).astype(np.float32)
            noises.append(scale * gather_node_features(pg, nz))
        return (np.stack(x0s), np.stack(tgts),
                np.stack(noises).astype(np.float32))
    return batch_fn
