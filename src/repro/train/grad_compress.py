"""Int8 error-feedback gradient compression for the DP all-reduce.

Beyond-paper distributed-optimization trick: the data-parallel gradient
all-reduce (the paper's DDP reduction, which its scaling section identifies
as the other communication term besides halo exchanges) is compressed 4x by
quantizing per-leaf to int8 with a shared absmax scale. The quantization
residual is fed back into the next step's gradient (error feedback), which
keeps SGD/Adam convergence (Karimireddy et al., arXiv:1901.09847).

psum over int32 accumulators is exact, so compression only quantizes each
device's *contribution* once — no accumulation drift across replicas.
"""
from __future__ import annotations

from typing import Any, Sequence, Tuple

import jax
import jax.numpy as jnp


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads: Any, errors: Any, axis_names: Sequence[str],
                    n_devices: int) -> Tuple[Any, Any]:
    """Per-leaf int8 quantized psum with error feedback.

    Returns (mean gradients, new error state). Call INSIDE shard_map.
    """
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        scale = jnp.max(jnp.abs(g32)) / 127.0
        # scales differ per device: share a common scale via max-reduce
        scale = jax.lax.pmax(scale, tuple(axis_names))
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(g32 / scale), -127, 127)
        new_err = g32 - q * scale
        total = jax.lax.psum(q.astype(jnp.int32), tuple(axis_names))
        mean = total.astype(jnp.float32) * (scale / n_devices)
        return mean.astype(g.dtype), new_err

    out = jax.tree.map(one, grads, errors)
    mean = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    errs = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return mean, errs
