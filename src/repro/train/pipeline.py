"""GPipe-style pipeline parallelism over a mesh axis via collective-permute.

Stages hold contiguous layer groups (params sharded over the ``stage`` axis);
micro-batches stream through the pipeline: at step t, stage s processes
micro-batch (t - s) and ships its activation to stage s+1 with a single
``ppermute`` (TPU neighbor DMA — the same primitive as the halo exchange).
Bubble fraction is the standard (S-1)/(M+S-1).

Not used in the 40-cell dry-run matrix (DP x TP x EP covers the assigned
sizes) but provided, tested (tests/drivers/pipeline_driver.py), and
composable: ``stage_fn`` may itself contain TP collectives over other axes.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_forward(
    stage_fn: Callable,            # (stage_params, x) -> y  (same shape)
    stage_params,                  # pytree, leaves [S, ...] sharded over stage
    micro_batches: jnp.ndarray,    # [M, B_m, ...]
    mesh: Mesh,
    stage_axis: str = "stage",
):
    """Returns [M, B_m, ...] outputs (valid on the last stage, replicated out
    via a final psum-mask so every device holds them)."""
    S = mesh.shape[stage_axis]
    M = micro_batches.shape[0]

    def local(params_l, micros):
        params_l = jax.tree.map(lambda p: p[0], params_l)   # [1,...] -> [...]
        sid = jax.lax.axis_index(stage_axis)
        T = M + S - 1
        cur = jnp.zeros_like(micros[0])
        outs = jnp.zeros_like(micros)

        def step(carry, t):
            cur, outs = carry
            # stage 0 ingests micro-batch t (when available)
            inject = jnp.where(t < M, t, 0)
            cur = jnp.where(sid == 0,
                            jnp.where(t < M, micros[inject], cur), cur)
            y = stage_fn(params_l, cur)
            # last stage emits micro-batch (t - S + 1)
            out_idx = jnp.clip(t - S + 1, 0, M - 1)
            emit = (sid == S - 1) & (t >= S - 1)
            outs = jax.lax.dynamic_update_slice_in_dim(
                outs,
                jnp.where(emit, y, jax.lax.dynamic_slice_in_dim(outs, out_idx, 1, 0)[0])[None],
                out_idx, axis=0)
            # ship activations downstream (ring; stage S-1 -> 0 ignored)
            nxt = jax.lax.ppermute(y, stage_axis,
                                   perm=[(i, (i + 1) % S) for i in range(S)])
            return (nxt, outs), None

        (cur, outs), _ = jax.lax.scan(step, (cur, outs), jnp.arange(T))
        # replicate the last stage's outputs to every stage member
        outs = jnp.where(sid == S - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, stage_axis)

    pspec = jax.tree.map(lambda _: P(stage_axis), stage_params)
    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(pspec, P()),
        out_specs=P(),
        check_vma=False,
    )(stage_params, micro_batches)


def pipeline_bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
