"""Pure-JAX optimizers (no optax offline): AdamW + SGD with the production
features a framework needs — LR schedules (warmup + cosine/linear), global
gradient-norm clipping, decoupled weight decay with a parameter mask,
gradient accumulation, and mixed-precision moments (bf16 m/v option used by
the largest configs to fit HBM).

Optimizer state is a pytree congruent with params, so any sharding applied to
params transfers to the state (ZeRO-style sharded optimizer comes for free
from the param PartitionSpecs).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro import nn as rnn


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------

def warmup_cosine(base_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1) -> Callable:
    def f(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        warm = base_lr * jnp.minimum(1.0, step / jnp.maximum(warmup_steps, 1))
        t = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = base_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)
    return f


def constant_lr(base_lr: float) -> Callable:
    return lambda step: jnp.full((), base_lr, jnp.float32)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    schedule: Callable = dataclasses.field(default_factory=lambda: constant_lr(1e-3))
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = 1.0
    moment_dtype: jnp.dtype = jnp.float32     # bf16 halves optimizer HBM
    # decay mask: params whose path matches any of these substrings are
    # excluded from weight decay (norms, biases, embeddings typically)
    no_decay_substrings: tuple = ("ln", "norm", "bias", "b",)


def init_adamw(params, cfg: AdamWConfig):
    def zeros(p):
        return jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _decay_mask(params, cfg: AdamWConfig):
    paths = jax.tree_util.tree_flatten_with_path(params)[0]
    flags = []
    for path, _ in paths:
        keystr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        last = keystr.split("/")[-1]
        exclude = any(s == last or (len(s) > 1 and s in keystr) for s in cfg.no_decay_substrings)
        flags.append(0.0 if exclude else 1.0)
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(params), flags)


def clip_by_global_norm(grads, max_norm: float):
    norm = rnn.global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(grads, state, params, cfg: AdamWConfig):
    """One AdamW step. params/grads may be lower precision; math in fp32."""
    step = state["step"] + 1
    lr = cfg.schedule(step)
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = rnn.global_norm(grads)
    mask = _decay_mask(params, cfg)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, dmask):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g32
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g32)
        mhat = m32 / bc1
        vhat = v32 / bc2
        step_vec = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (step_vec + cfg.weight_decay * dmask * p32)
        return p32.astype(p.dtype), m32.astype(cfg.moment_dtype), v32.astype(cfg.moment_dtype)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"], mask)
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}


# ---------------------------------------------------------------------------
# SGD (paper-style consistency experiments)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SGDConfig:
    lr: float = 1e-3
    momentum: float = 0.0


def init_sgd(params, cfg: SGDConfig):
    if cfg.momentum == 0.0:
        return {"step": jnp.zeros((), jnp.int32)}
    return {"mu": rnn.tree_zeros_like(params), "step": jnp.zeros((), jnp.int32)}


def sgd_update(grads, state, params, cfg: SGDConfig):
    step = state["step"] + 1
    if cfg.momentum == 0.0:
        new_params = jax.tree.map(lambda p, g: p - cfg.lr * g.astype(p.dtype), params, grads)
        return new_params, {"step": step}, {}
    mu = jax.tree.map(lambda m, g: cfg.momentum * m + g.astype(m.dtype), state["mu"], grads)
    new_params = jax.tree.map(lambda p, m: p - cfg.lr * m.astype(p.dtype), params, mu)
    return new_params, {"mu": mu, "step": step}, {}


# ---------------------------------------------------------------------------
# gradient accumulation wrapper
# ---------------------------------------------------------------------------

def accumulate_gradients(grad_fn, n_micro: int):
    """Wrap grad_fn(params, batch)->(loss, grads) to average over micro-batches.

    ``batch`` leaves must have a leading [n_micro, ...] axis; the scan keeps
    peak activation memory at one micro-batch.
    """
    def wrapped(params, batch):
        def body(carry, micro):
            acc_loss, acc_g = carry
            loss, g = grad_fn(params, micro)
            return (acc_loss + loss, rnn.tree_add(acc_g, g)), None

        zero = (jnp.zeros((), jnp.float32), rnn.tree_zeros_like(params))
        (loss, grads), _ = jax.lax.scan(body, zero, batch)
        return loss / n_micro, rnn.tree_scale(grads, 1.0 / n_micro)
    return wrapped
