"""Production training loop for the consistent distributed GNN.

Combines: the shard_map grad step (real halo collectives), AdamW, async
checkpointing, fault-tolerant restart, straggler monitoring, and the
consistent loss. Used by examples/train_cfd_gnn.py and the training-
consistency benchmark.

Two training modes, selected by ``TrainConfig.rollout_steps``:

* 1 (default) — one-step prediction (the paper's Fig. 6 training);
* K > 1       — autoregressive rollout training (``repro.train.rollout``):
  the model is scanned over its own predictions for K steps, every step's
  halo-consistent loss enters the objective, and
  ``TrainConfig.pushforward_noise`` optionally perturbs the initial state
  (stop-gradient pushforward trick) to emulate inference-time drift.

Execution policy (backend/schedule/precision/...) is a single
:class:`~repro.core.graph_state.NMPPlan` on the TrainConfig; the per-level
halo specs are filled in from the partition at launch.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.distributed import make_gnn_step_fns, shard_graph
from repro.core.gnn import GNNConfig, init_gnn
from repro.core.graph_state import NMPPlan, ShardedGraph
from repro.core.mesh_gen import SEMMesh, taylor_green_velocity
from repro.core.partition import PartitionedGraphs, gather_node_features
from repro.ckpt import checkpoint as ckpt
from repro.runtime.straggler import StragglerMonitor
from repro.train.optimizer import AdamWConfig, adamw_update, init_adamw
from repro.train.rollout import make_rollout_step_fns, make_tgv_rollout_batch_fn


@dataclasses.dataclass
class TrainConfig:
    n_steps: int = 200
    batch: int = 1
    lr: float = 1e-3
    halo_mode: str = "neighbor"
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    log_every: int = 20
    seed: int = 0
    # NMP execution policy (halo specs are filled in from the partition by
    # train_consistent_gnn; schedule="auto" is resolved against the built
    # graph via NMPPlan.autotune); see repro.core.graph_state.NMPPlan
    plan: NMPPlan = NMPPlan()
    # --- autoregressive rollout training (repro.train.rollout) ---
    rollout_steps: int = 1       # K > 1 scans the model over its predictions
    pushforward_noise: float = 0.0  # stddev of the stop-grad step-1 noise
    # curriculum: per-stage K values, e.g. (1, 2, 4) splits n_steps into
    # three even stages of increasing rollout depth (overrides
    # rollout_steps); step fns are built once per distinct K
    rollout_curriculum: tuple = ()
    # anneal pushforward noise linearly from pushforward_noise to this
    # value over the run (None = constant)
    pushforward_noise_final: Optional[float] = None


def make_tgv_batch_fn(pg: PartitionedGraphs, mesh_sem: SEMMesh, batch: int,
                      dt: float = 0.05):
    """Deterministic Taylor-Green snapshot batches keyed by step (replayable)."""
    def batch_fn(step: int):
        xs = []
        for b in range(batch):
            t = (step * batch + b) * dt % 2.0
            xs.append(gather_node_features(pg, taylor_green_velocity(mesh_sem.coords, t=t)))
        x = np.stack(xs)             # [B, R, N_pad, F] — autoencoding target = input
        return x
    return batch_fn


def train_consistent_gnn(
    mesh_dev,
    pg: PartitionedGraphs,
    sem_mesh: SEMMesh,
    cfg: GNNConfig,
    tcfg: TrainConfig,
    hierarchy=None,
) -> dict:
    """Full training run; returns history with losses (paper Fig. 6 right).

    ``hierarchy`` (``repro.core.coarsen.MultiLevelGraphs`` with ``pg`` as
    level 0) enables the consistent multilevel V-cycle when
    ``cfg.n_levels > 1``: each coarse level gets its own halo spec and its
    static arrays ride along as nested ShardedGraph levels.
    """
    if cfg.n_levels > 1 and hierarchy is None:
        raise ValueError("cfg.n_levels > 1 needs hierarchy= "
                         "(repro.core.coarsen.build_hierarchy)")
    # fill the per-level halo specs into the policy plan
    plan = NMPPlan.build(
        hierarchy if hierarchy is not None and cfg.n_levels > 1 else pg,
        tcfg.halo_mode, axis="graph",
        **{f.name: getattr(tcfg.plan, f.name)
           for f in dataclasses.fields(NMPPlan)
           if f.name not in ("halo", "coarse_halos")})
    # layout + interior/boundary split passes are cached on pg — one
    # host-side pass per partition, amortized over every training step
    graph = ShardedGraph.build(
        pg, sem_mesh.coords, plan,
        hierarchy=hierarchy if cfg.n_levels > 1 else None)
    # schedule="auto": measure blocking vs overlap on this (graph, R) once
    # and commit to the winner (no-op for fixed schedules)
    plan = plan.autotune(graph, hidden=cfg.hidden)

    opt_cfg = AdamWConfig(schedule=lambda s: jnp.asarray(tcfg.lr), weight_decay=0.0)
    params = init_gnn(jax.random.PRNGKey(tcfg.seed), cfg)
    opt_state = init_adamw(params, opt_cfg)

    monitor = StragglerMonitor()
    saver = ckpt.AsyncCheckpointer(tcfg.ckpt_dir) if tcfg.ckpt_dir else None

    @jax.jit
    def update(params, opt_state, loss, grads):
        return adamw_update(grads, opt_state, params, opt_cfg)

    # the static graph is loop-invariant: place it once, not per step
    gs = shard_graph(mesh_dev, graph)
    feat_sh = NamedSharding(mesh_dev, P(("data",), "graph", None, None))
    stages = tuple(tcfg.rollout_curriculum)
    if stages or tcfg.rollout_steps > 1:
        # rollout path; a curriculum splits n_steps into even stages of
        # increasing K (the 1 -> 2 -> 4 schedule of the pushforward line of
        # work), with step fns / batch fns built once per distinct K
        stages = stages or (tcfg.rollout_steps,)
        stage_len = max(1, -(-tcfg.n_steps // len(stages)))
        noise_scale = tcfg.pushforward_noise
        if tcfg.pushforward_noise_final is not None:
            n0 = tcfg.pushforward_noise
            n1 = tcfg.pushforward_noise_final
            denom = max(tcfg.n_steps - 1, 1)
            noise_scale = lambda s: n0 + (n1 - n0) * (s / denom)  # noqa: E731
        seq_sh = NamedSharding(mesh_dev, P(("data",), None, "graph", None, None))
        fns_by_k = {}

        def k_for_step(step: int) -> int:
            return stages[min(step // stage_len, len(stages) - 1)]

        def grad_for_step(params, step):
            k = k_for_step(step)
            if k not in fns_by_k:
                _, rollout_grad = make_rollout_step_fns(mesh_dev, cfg, plan, k)
                bf = make_tgv_rollout_batch_fn(
                    pg, sem_mesh, tcfg.batch, k,
                    noise_scale=noise_scale, seed=tcfg.seed)
                fns_by_k[k] = (rollout_grad, bf)
            rollout_grad, batch_fn = fns_by_k[k]
            x0, targets, noise = batch_fn(step)
            xs = jax.device_put(jnp.asarray(x0), feat_sh)
            ts = jax.device_put(jnp.asarray(targets), seq_sh)
            ns = jax.device_put(jnp.asarray(noise), feat_sh)
            return rollout_grad(params, xs, ts, ns, gs)
    else:
        _, _, grad_step, _ = make_gnn_step_fns(mesh_dev, cfg, plan)
        batch_fn = make_tgv_batch_fn(pg, sem_mesh, tcfg.batch)

        def k_for_step(step: int) -> int:
            return 1

        def grad_for_step(params, step):
            xs = jax.device_put(jnp.asarray(batch_fn(step)), feat_sh)
            return grad_step(params, xs, xs, gs)

    history = {"losses": [], "rollout_k": [], "schedule": plan.schedule}
    for step in range(tcfg.n_steps):
        monitor.start_step()
        loss, grads = grad_for_step(params, step)
        params, opt_state, _ = update(params, opt_state, loss, grads)
        monitor.end_step(step)
        history["losses"].append(float(loss))
        history["rollout_k"].append(k_for_step(step))
        if saver and (step % tcfg.ckpt_every == 0 or step == tcfg.n_steps - 1):
            saver.save(step, {"params": params, "opt": opt_state})
    if saver:
        saver.wait()
    history["straggler_events"] = len(monitor.events)
    history["params"] = params
    return history
