"""Production training loop for the consistent distributed GNN.

Combines: the shard_map grad step (real halo collectives), AdamW, async
checkpointing, fault-tolerant restart, straggler monitoring, and the
consistent loss. Used by examples/train_cfd_gnn.py and the training-
consistency benchmark.

Two training modes, selected by ``TrainConfig.rollout_steps``:

* 1 (default) — one-step prediction (the paper's Fig. 6 training);
* K > 1       — autoregressive rollout training (``repro.train.rollout``):
  the model is scanned over its own predictions for K steps, every step's
  halo-consistent loss enters the objective, and
  ``TrainConfig.pushforward_noise`` optionally perturbs the initial state
  (stop-gradient pushforward trick) to emulate inference-time drift.

Execution policy (backend/schedule/precision/...) is a single
:class:`~repro.core.graph_state.NMPPlan` on the TrainConfig; the per-level
halo specs are filled in from the partition at launch.

Elastic fault tolerance (``TrainConfig.resilience``): the loop is driven by
``repro.runtime.fault_tolerance.run_resilient`` — periodic + straggler-
triggered async checkpoints whose manifests carry a *mesh fingerprint*
(mesh hash, rank count, partitioner, plan policy, replay-critical training
config) and the loss-history tail, catch-all crash recovery with bounded
exponential backoff, and :func:`resume_elastic` restore.  Because the
paper's consistency guarantee makes the partition arithmetically invisible
(Eq. 2/3), a checkpoint written on R ranks restores onto R' ranks — or a
different partitioner — and the loss trajectory *continues*: bitwise when
the partition is unchanged, to float32 summation tolerance (~1e-7 relative)
across a repartition.  Batches are replayed deterministically: every batch
function is pure in ``step`` (see CONTRIBUTING.md "Elastic resume").
"""
from __future__ import annotations

import dataclasses
import hashlib
from types import SimpleNamespace
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.distributed import make_gnn_step_fns, shard_graph
from repro.core.gnn import GNNConfig, init_gnn
from repro.core.graph_state import AUTO, BLOCKING, OVERLAP, NMPPlan, ShardedGraph
from repro.core.mesh_gen import SEMMesh, taylor_green_velocity
from repro.core.partition import PartitionedGraphs, gather_node_features
from repro.ckpt import checkpoint as ckpt
from repro.runtime.fault_tolerance import (
    FaultPlan, ResilientConfig, run_resilient,
)
from repro.runtime.straggler import StragglerMonitor
from repro.train.optimizer import AdamWConfig, adamw_update, init_adamw
from repro.train.rollout import (
    curriculum_k, make_rollout_step_fns, make_tgv_rollout_batch_fn,
)


@dataclasses.dataclass
class TrainConfig:
    n_steps: int = 200
    batch: int = 1
    lr: float = 1e-3
    halo_mode: str = "neighbor"
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    log_every: int = 20
    seed: int = 0
    # NMP execution policy (halo specs are filled in from the partition by
    # train_consistent_gnn; schedule="auto" is resolved against the built
    # graph via NMPPlan.autotune); see repro.core.graph_state.NMPPlan
    plan: NMPPlan = NMPPlan()
    # --- autoregressive rollout training (repro.train.rollout) ---
    rollout_steps: int = 1       # K > 1 scans the model over its predictions
    pushforward_noise: float = 0.0  # stddev of the stop-grad step-1 noise
    # curriculum: per-stage K values, e.g. (1, 2, 4) splits n_steps into
    # three even stages of increasing rollout depth (overrides
    # rollout_steps); step fns are built once per distinct K
    rollout_curriculum: tuple = ()
    # anneal pushforward noise linearly from pushforward_noise to this
    # value over the run (None = constant)
    pushforward_noise_final: Optional[float] = None
    # which mesh decomposition produced ``pg`` ("block" | "spectral") —
    # recorded in the checkpoint fingerprint so an elastic resume knows
    # whether the partitioner changed (allowed: results are consistent)
    partitioner: str = "block"
    # elastic fault tolerance: not None switches the loop to the
    # run_resilient driver (auto-resume from ckpt_dir, crash recovery with
    # bounded backoff, fingerprinted manifests). ``ckpt_dir``/``ckpt_every``
    # above are the plain fire-and-forget checkpoint knobs and are ignored
    # when resilience is configured.
    resilience: Optional[ResilientConfig] = None


def make_tgv_batch_fn(pg: PartitionedGraphs, mesh_sem: SEMMesh, batch: int,
                      dt: float = 0.05):
    """Deterministic Taylor-Green snapshot batches keyed by step (replayable)."""
    def batch_fn(step: int):
        xs = []
        for b in range(batch):
            t = (step * batch + b) * dt % 2.0
            xs.append(gather_node_features(pg, taylor_green_velocity(mesh_sem.coords, t=t)))
        x = np.stack(xs)             # [B, R, N_pad, F] — autoencoding target = input
        return x
    return batch_fn


def mesh_fingerprint_hash(sem_mesh: SEMMesh) -> str:
    """Content hash of the global mesh (node coords + element connectivity).
    Partition-independent: every rank count / partitioner of the same mesh
    hashes identically, so it is the checkpoint field that rejects resuming
    onto a *different problem* while allowing elastic repartitioning."""
    h = hashlib.sha256()
    h.update(np.ascontiguousarray(sem_mesh.coords).tobytes())
    h.update(np.ascontiguousarray(sem_mesh.elem_nodes).tobytes())
    return h.hexdigest()[:16]


# fingerprint fields that MUST match between save and resume: they define
# the trajectory (problem + deterministic batch replay + optimizer math).
# Everything else (ranks, partitioner, halo_mode, policy) is execution
# layout — arithmetically invisible under the consistency guarantee.
_REPLAY_FIELDS = ("mesh_hash", "n_global", "seed", "batch", "lr",
                  "rollout_steps", "rollout_curriculum", "pushforward_noise",
                  "pushforward_noise_final", "n_levels", "hidden")


def run_fingerprint(sem_mesh: SEMMesh, pg: PartitionedGraphs, cfg: GNNConfig,
                    tcfg: TrainConfig, plan: NMPPlan) -> dict:
    """The manifest ``extra["fingerprint"]`` a checkpoint carries."""
    return {
        "mesh_hash": mesh_fingerprint_hash(sem_mesh),
        "n_global": int(pg.n_global),
        "ranks": int(pg.R),
        "partitioner": tcfg.partitioner,
        "halo_mode": tcfg.halo_mode,
        "policy": plan.policy(),
        "seed": int(tcfg.seed),
        "batch": int(tcfg.batch),
        "lr": float(tcfg.lr),
        "rollout_steps": int(tcfg.rollout_steps),
        "rollout_curriculum": list(tcfg.rollout_curriculum),
        "pushforward_noise": float(tcfg.pushforward_noise),
        "pushforward_noise_final": tcfg.pushforward_noise_final,
        "n_levels": int(cfg.n_levels),
        "hidden": int(cfg.hidden),
    }


def _init_state(cfg: GNNConfig, tcfg: TrainConfig, opt_cfg: AdamWConfig) -> dict:
    key = jax.random.PRNGKey(tcfg.seed)
    params = init_gnn(key, cfg)
    return {"params": params, "opt": init_adamw(params, opt_cfg), "rng": key}


def _build_execution(mesh_dev, pg, sem_mesh, cfg, tcfg, hierarchy):
    """Build everything a training step needs for the CURRENT partition:
    plan (halo specs + resolved schedule), ShardedGraph, sharded placement,
    and the per-step grad/update closures.  Shared by the plain and the
    resilient paths — an elastic resume simply rebuilds this for the new
    rank grid and restores params/opt into it."""
    if cfg.n_levels > 1 and hierarchy is None:
        raise ValueError("cfg.n_levels > 1 needs hierarchy= "
                         "(repro.core.coarsen.build_hierarchy)")
    # fill the per-level halo specs into the policy plan
    plan = NMPPlan.build(
        hierarchy if hierarchy is not None and cfg.n_levels > 1 else pg,
        tcfg.halo_mode, axis="graph",
        **{f.name: getattr(tcfg.plan, f.name)
           for f in dataclasses.fields(NMPPlan)
           if f.name not in ("halo", "coarse_halos")})
    # layout + interior/boundary split passes are cached on pg — one
    # host-side pass per partition, amortized over every training step
    graph = ShardedGraph.build(
        pg, sem_mesh.coords, plan,
        hierarchy=hierarchy if cfg.n_levels > 1 else None)
    # schedule="auto": on a same-rank-count resume, reuse the schedule the
    # original run measured (recorded in the manifest fingerprint) so the
    # replayed trajectory runs the exact same program; otherwise measure
    # blocking vs overlap on this (graph, R) once and commit to the winner
    ckpt_dir = tcfg.resilience.ckpt_dir if tcfg.resilience else tcfg.ckpt_dir
    if plan.schedule == AUTO and ckpt_dir:
        try:
            manifest = ckpt.peek_manifest(ckpt_dir)
        except ckpt.CheckpointCorruption:
            manifest = None
        fp = (manifest or {}).get("extra", {}).get("fingerprint", {})
        prev = fp.get("policy", {})
        if (fp.get("ranks") == pg.R and prev.get("backend") == plan.backend
                and prev.get("schedule") in (BLOCKING, OVERLAP)):
            plan = plan.replace(schedule=prev["schedule"])
    plan = plan.autotune(graph, hidden=cfg.hidden)

    opt_cfg = AdamWConfig(schedule=lambda s: jnp.asarray(tcfg.lr), weight_decay=0.0)

    @jax.jit
    def update(params, opt_state, loss, grads):
        return adamw_update(grads, opt_state, params, opt_cfg)

    # the static graph is loop-invariant: place it once, not per step
    gs = shard_graph(mesh_dev, graph)
    feat_sh = NamedSharding(mesh_dev, P(("data",), "graph", None, None))
    stages = tuple(tcfg.rollout_curriculum)
    if stages or tcfg.rollout_steps > 1:
        # rollout path; a curriculum splits n_steps into even stages of
        # increasing K (the 1 -> 2 -> 4 schedule of the pushforward line of
        # work), with step fns / batch fns built once per distinct K
        stages = stages or (tcfg.rollout_steps,)
        noise_scale = tcfg.pushforward_noise
        if tcfg.pushforward_noise_final is not None:
            n0 = tcfg.pushforward_noise
            n1 = tcfg.pushforward_noise_final
            denom = max(tcfg.n_steps - 1, 1)
            noise_scale = lambda s: n0 + (n1 - n0) * (s / denom)  # noqa: E731
        seq_sh = NamedSharding(mesh_dev, P(("data",), None, "graph", None, None))
        fns_by_k = {}

        def k_for_step(step: int) -> int:
            return curriculum_k(stages, tcfg.n_steps, step)

        def grad_for_step(params, step):
            k = k_for_step(step)
            if k not in fns_by_k:
                _, rollout_grad = make_rollout_step_fns(mesh_dev, cfg, plan, k)
                bf = make_tgv_rollout_batch_fn(
                    pg, sem_mesh, tcfg.batch, k,
                    noise_scale=noise_scale, seed=tcfg.seed)
                fns_by_k[k] = (rollout_grad, bf)
            rollout_grad, batch_fn = fns_by_k[k]
            x0, targets, noise = batch_fn(step)
            xs = jax.device_put(jnp.asarray(x0), feat_sh)
            ts = jax.device_put(jnp.asarray(targets), seq_sh)
            ns = jax.device_put(jnp.asarray(noise), feat_sh)
            return rollout_grad(params, xs, ts, ns, gs)
    else:
        _, _, grad_step, _ = make_gnn_step_fns(mesh_dev, cfg, plan)
        batch_fn = make_tgv_batch_fn(pg, sem_mesh, tcfg.batch)

        def k_for_step(step: int) -> int:
            return 1

        def grad_for_step(params, step):
            xs = jax.device_put(jnp.asarray(batch_fn(step)), feat_sh)
            return grad_step(params, xs, xs, gs)

    return SimpleNamespace(plan=plan, graph=graph, gs=gs, opt_cfg=opt_cfg,
                           update=update, grad_for_step=grad_for_step,
                           k_for_step=k_for_step)


def resume_elastic(ckpt_dir, mesh_dev, pg, sem_mesh, cfg, tcfg, plan):
    """Elastic restore: latest valid checkpoint onto the CURRENT mesh/partition.

    The caller has already rebuilt ``PartitionedGraphs`` (+ ``ShardedGraph``
    + ``NMPPlan`` via :func:`_build_execution`) for the new rank grid —
    block or spectral; this function restores the *portable* state
    (params, opt, rng are partition-independent: replicated over the graph
    axis) onto ``mesh_dev`` via per-leaf shardings, validates the manifest
    fingerprint, and classifies the resume:

      * replay-critical mismatch (different mesh, seed, batch schedule,
        optimizer or model config) → ``ValueError`` naming the field: the
        checkpoint belongs to a different trajectory;
      * execution-layout mismatch (rank count, partitioner, halo mode,
        plan policy) → allowed, returned as the ``elastic`` record — the
        consistency guarantee makes the trajectory continue.

    Returns ``None`` when no committed checkpoint exists, else
    ``(state, start_step, prior_losses, manifest, elastic_or_None)``.
    Corrupted newest checkpoints fall back to the previous committed step
    (``ckpt.restore_with_fallback``).
    """
    if not ckpt.committed_steps(ckpt_dir):
        return None
    opt_cfg = AdamWConfig(schedule=lambda s: jnp.asarray(tcfg.lr), weight_decay=0.0)
    template = _init_state(cfg, tcfg, opt_cfg)
    replicated = NamedSharding(mesh_dev, P())
    shardings = jax.tree.map(lambda _: replicated, template)
    state, manifest = ckpt.restore_with_fallback(ckpt_dir, template,
                                                 shardings=shardings)
    fp_now = run_fingerprint(sem_mesh, pg, cfg, tcfg, plan)
    fp_old = manifest.get("extra", {}).get("fingerprint")
    elastic = None
    if fp_old:
        for field in _REPLAY_FIELDS:
            if fp_old.get(field) != fp_now.get(field):
                raise ValueError(
                    f"cannot resume from {ckpt_dir}: replay-critical "
                    f"fingerprint field {field!r} changed "
                    f"({fp_old.get(field)!r} -> {fp_now.get(field)!r}) — "
                    "this checkpoint belongs to a different trajectory")
        changed = {k: [fp_old.get(k), fp_now.get(k)]
                   for k in ("ranks", "partitioner", "halo_mode", "policy")
                   if fp_old.get(k) != fp_now.get(k)}
        if changed:
            elastic = {"step": manifest["step"] + 1,
                       "from_ranks": fp_old.get("ranks"),
                       "to_ranks": fp_now.get("ranks"),
                       "from_partitioner": fp_old.get("partitioner"),
                       "to_partitioner": fp_now.get("partitioner"),
                       "changed": changed}
    start = manifest["step"] + 1
    extra = manifest.get("extra", {})
    off = int(extra.get("losses_offset", 0))
    losses = list(extra.get("losses", []))[:max(start - off, 0)]
    return state, start, losses, manifest, elastic


def _train_resilient(ex, mesh_dev, pg, sem_mesh, cfg, tcfg,
                     fault: Optional[FaultPlan]) -> dict:
    rcfg = tcfg.resilience
    fp = run_fingerprint(sem_mesh, pg, cfg, tcfg, ex.plan)
    monitor = StragglerMonitor()
    elastic_events = []

    def init_state_fn():
        return _init_state(cfg, tcfg, ex.opt_cfg)

    def step_fn(state, step):
        loss, grads = ex.grad_for_step(state["params"], step)
        params, opt_state, _ = ex.update(state["params"], state["opt"],
                                         loss, grads)
        return ({"params": params, "opt": opt_state, "rng": state["rng"]},
                {"loss": float(loss)})

    def restore_fn():
        res = resume_elastic(rcfg.ckpt_dir, mesh_dev, pg, sem_mesh, cfg,
                             tcfg, ex.plan)
        if res is None:
            return None
        state, start, losses, manifest, elastic = res
        if elastic is not None:
            elastic_events.append(elastic)
            # the per-step time scale changed with the layout — stale EWMA
            # stats would flag the first steps as stragglers
            monitor.reset()
        return state, start, losses

    state, history = run_resilient(
        init_state_fn, step_fn, lambda step: step, tcfg.n_steps, rcfg,
        monitor=monitor, fault=fault, restore_fn=restore_fn,
        manifest_extra={"fingerprint": fp})
    history["rollout_k"] = [ex.k_for_step(s) for s in range(tcfg.n_steps)]
    history["schedule"] = ex.plan.schedule
    history["elastic"] = elastic_events[-1] if elastic_events else None
    history["params"] = state["params"]
    return history


def train_consistent_gnn(
    mesh_dev,
    pg: PartitionedGraphs,
    sem_mesh: SEMMesh,
    cfg: GNNConfig,
    tcfg: TrainConfig,
    hierarchy=None,
    fault: Optional[FaultPlan] = None,
) -> dict:
    """Full training run; returns history with losses (paper Fig. 6 right).

    ``hierarchy`` (``repro.core.coarsen.MultiLevelGraphs`` with ``pg`` as
    level 0) enables the consistent multilevel V-cycle when
    ``cfg.n_levels > 1``: each coarse level gets its own halo spec and its
    static arrays ride along as nested ShardedGraph levels.

    With ``tcfg.resilience`` set, the run is driven by ``run_resilient``:
    it auto-resumes from the newest valid checkpoint in
    ``resilience.ckpt_dir`` (elastically — the checkpoint may come from a
    different rank count or partitioner), recovers from crashes up to
    ``max_restarts`` with bounded exponential backoff, and checkpoints
    periodically plus on straggler events.  ``fault`` injects failures for
    tests/drivers (see ``FaultPlan``); it is only honored on the resilient
    path.
    """
    ex = _build_execution(mesh_dev, pg, sem_mesh, cfg, tcfg, hierarchy)
    if tcfg.resilience is not None:
        return _train_resilient(ex, mesh_dev, pg, sem_mesh, cfg, tcfg, fault)

    fp = run_fingerprint(sem_mesh, pg, cfg, tcfg, ex.plan)
    state = _init_state(cfg, tcfg, ex.opt_cfg)
    params, opt_state = state["params"], state["opt"]
    monitor = StragglerMonitor()
    saver = ckpt.AsyncCheckpointer(tcfg.ckpt_dir) if tcfg.ckpt_dir else None

    history = {"losses": [], "rollout_k": [], "schedule": ex.plan.schedule}
    for step in range(tcfg.n_steps):
        monitor.start_step()
        loss, grads = ex.grad_for_step(params, step)
        params, opt_state, _ = ex.update(params, opt_state, loss, grads)
        monitor.end_step(step)
        history["losses"].append(float(loss))
        history["rollout_k"].append(ex.k_for_step(step))
        if saver and (step % tcfg.ckpt_every == 0 or step == tcfg.n_steps - 1):
            # same tree + fingerprinted manifest as the resilient path, so
            # a plain run's checkpoints are elastically resumable too
            saver.save(step, {"params": params, "opt": opt_state,
                              "rng": state["rng"]},
                       extra={"reason": "periodic", "fingerprint": fp,
                              "losses": list(history["losses"]),
                              "losses_offset": 0})
    if saver:
        saver.wait()
    history["straggler_events"] = len(monitor.events)
    history["params"] = params
    return history
