"""Production training loop for the consistent distributed GNN.

Combines: the shard_map grad step (real halo collectives), AdamW, async
checkpointing, fault-tolerant restart, straggler monitoring, and the
consistent loss. Used by examples/train_cfd_gnn.py and the training-
consistency benchmark.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.distributed import make_gnn_step_fns, shard_inputs
from repro.core.gnn import GNNConfig, init_gnn
from repro.core.halo import halo_spec_from_plan
from repro.core.mesh_gen import SEMMesh, taylor_green_velocity
from repro.core.partition import PartitionedGraphs, gather_node_features
from repro.data.pipeline import prepare_gnn_meta
from repro.ckpt import checkpoint as ckpt
from repro.runtime.straggler import StragglerMonitor
from repro.train.optimizer import AdamWConfig, adamw_update, init_adamw


@dataclasses.dataclass
class TrainConfig:
    n_steps: int = 200
    batch: int = 1
    lr: float = 1e-3
    halo_mode: str = "neighbor"
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    log_every: int = 20
    seed: int = 0
    # NMP hot-loop backend / schedule / precision overrides (None = keep the
    # GNNConfig's choice); see repro.core.consistent_mp for the semantics
    mp_backend: Optional[str] = None
    mp_interpret: bool = False
    mp_schedule: Optional[str] = None
    mp_precision: Optional[str] = None


def make_tgv_batch_fn(pg: PartitionedGraphs, mesh_sem: SEMMesh, batch: int,
                      dt: float = 0.05):
    """Deterministic Taylor-Green snapshot batches keyed by step (replayable)."""
    def batch_fn(step: int):
        xs = []
        for b in range(batch):
            t = (step * batch + b) * dt % 2.0
            xs.append(gather_node_features(pg, taylor_green_velocity(mesh_sem.coords, t=t)))
        x = np.stack(xs)             # [B, R, N_pad, F] — autoencoding target = input
        return x
    return batch_fn


def train_consistent_gnn(
    mesh_dev,
    pg: PartitionedGraphs,
    sem_mesh: SEMMesh,
    cfg: GNNConfig,
    tcfg: TrainConfig,
    hierarchy=None,
) -> dict:
    """Full training run; returns history with losses (paper Fig. 6 right).

    ``hierarchy`` (``repro.core.coarsen.MultiLevelGraphs`` with ``pg`` as
    level 0) enables the consistent multilevel V-cycle when
    ``cfg.n_levels > 1``: each coarse level gets its own halo spec and its
    static arrays ride along in the step metadata.
    """
    if tcfg.mp_backend is not None:
        cfg = dataclasses.replace(cfg, mp_backend=tcfg.mp_backend,
                                  mp_interpret=tcfg.mp_interpret)
    if tcfg.mp_schedule is not None:
        cfg = dataclasses.replace(cfg, mp_schedule=tcfg.mp_schedule)
    if tcfg.mp_precision is not None:
        cfg = dataclasses.replace(cfg, mp_precision=tcfg.mp_precision)
    if cfg.n_levels > 1 and hierarchy is None:
        raise ValueError("cfg.n_levels > 1 needs hierarchy= "
                         "(repro.core.coarsen.build_hierarchy)")
    spec = halo_spec_from_plan(pg.halo, tcfg.halo_mode, axis="graph")
    coarse_specs = ()
    if hierarchy is not None and cfg.n_levels > 1:
        coarse_specs = tuple(
            halo_spec_from_plan(lvl.halo, tcfg.halo_mode, axis="graph")
            for lvl in hierarchy.levels[1:])
    # layout + interior/boundary split passes are cached on pg — one
    # host-side pass per partition, amortized over every training step
    meta = prepare_gnn_meta(pg, sem_mesh.coords, backend=cfg.mp_backend,
                            seg_block_n=cfg.seg_block_n,
                            seg_block_e=cfg.seg_block_e,
                            schedule=cfg.mp_schedule,
                            hierarchy=hierarchy if cfg.n_levels > 1 else None)
    _, _, grad_step, _ = make_gnn_step_fns(mesh_dev, cfg, spec,
                                           coarse_halos=coarse_specs)

    opt_cfg = AdamWConfig(schedule=lambda s: jnp.asarray(tcfg.lr), weight_decay=0.0)
    params = init_gnn(jax.random.PRNGKey(tcfg.seed), cfg)
    opt_state = init_adamw(params, opt_cfg)

    batch_fn = make_tgv_batch_fn(pg, sem_mesh, tcfg.batch)
    monitor = StragglerMonitor()
    saver = ckpt.AsyncCheckpointer(tcfg.ckpt_dir) if tcfg.ckpt_dir else None

    @jax.jit
    def update(params, opt_state, loss, grads):
        return adamw_update(grads, opt_state, params, opt_cfg)

    history = {"losses": []}
    for step in range(tcfg.n_steps):
        x = jnp.asarray(batch_fn(step))
        xs, ms = shard_inputs(mesh_dev, x, meta)
        monitor.start_step()
        loss, grads = grad_step(params, xs, xs, ms)
        params, opt_state, _ = update(params, opt_state, loss, grads)
        monitor.end_step(step)
        history["losses"].append(float(loss))
        if saver and (step % tcfg.ckpt_every == 0 or step == tcfg.n_steps - 1):
            saver.save(step, {"params": params, "opt": opt_state})
    if saver:
        saver.wait()
    history["straggler_events"] = len(monitor.events)
    history["params"] = params
    return history
