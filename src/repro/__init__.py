"""repro: consistent distributed mesh-based GNNs in JAX (SC24-W reproduction
+ TPU-pod framework). See README.md / DESIGN.md / EXPERIMENTS.md."""

__version__ = "1.0.0"

from repro import compat as _compat

_compat.install()
del _compat
