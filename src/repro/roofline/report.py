"""Roofline report: three terms per (arch x shape) from the dry-run artifacts.

Reads runs/dryrun/records.jsonl + saved HLO, runs the trip-count-correcting
analyzer, and emits a markdown table + JSON (consumed by EXPERIMENTS.md).
Single-pod (16x16) only, per the assignment; multi-pod records prove the
'pod' axis shards and are summarized separately.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.roofline.hlo_analysis import analyze

ROOT = Path(__file__).resolve().parents[3]


def build_report(records_path: Path, mesh: str = "16x16", tag: str = ""):
    rows = []
    for line in records_path.read_text().splitlines():
        r = json.loads(line)
        if r.get("status") != "ok" or r.get("mesh") != mesh or r.get("tag", "") != tag:
            continue
        hlo_path = r.get("hlo_path")
        if not hlo_path or not Path(hlo_path).exists():
            continue
        txt = Path(hlo_path).read_text()
        s = analyze(txt, total_devices=r["n_devices"])
        terms = s.terms()
        dom = max(terms, key=terms.get)
        model_flops = r["meta"].get("model_flops", 0)
        per_dev_model = model_flops / r["n_devices"] if model_flops else 0
        rows.append(dict(
            arch=r["arch"], shape=r["shape"],
            compute_s=terms["compute_s"], memory_s=terms["memory_s"],
            collective_s=terms["collective_s"], dominant=dom.replace("_s", ""),
            dot_flops=s.dot_flops, hbm_bytes=s.hbm_bytes,
            wire_bytes=s.collective_wire_bytes,
            by_collective=s.by_collective,
            model_flops_per_dev=per_dev_model,
            useful_ratio=(per_dev_model / s.dot_flops) if s.dot_flops else 0.0,
            peak_gib=r["per_device_bytes"]["peak_estimate"] / 2 ** 30,
            xla_flops=r["cost"]["flops"],
        ))
    return rows


def fmt_markdown(rows) -> str:
    out = ["| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
           "dominant | 6ND/HLO | peak GiB/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.2f} | "
            f"{r['memory_s']*1e3:.2f} | {r['collective_s']*1e3:.2f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | {r['peak_gib']:.1f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", default=str(ROOT / "runs/dryrun/records.jsonl"))
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=str(ROOT / "runs/roofline.json"))
    args = ap.parse_args()
    rows = build_report(Path(args.records), args.mesh, args.tag)
    Path(args.out).write_text(json.dumps(rows, indent=1))
    print(fmt_markdown(rows))
    print(f"\n{len(rows)} cells -> {args.out}")


if __name__ == "__main__":
    main()
