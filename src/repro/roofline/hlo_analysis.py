"""HLO-text cost analyzer for the roofline report.

Why not ``compiled.cost_analysis()`` alone: XLA's HloCostAnalysis counts a
``while`` body ONCE (measured in calibration), so scanned-layer models are
undercounted by ~n_layers. This walker parses the optimized HLO text,
builds the computation call graph, multiplies while-bodies by their trip
count (recovered from the loop-condition constant), and accumulates:

  * dot FLOPs            -> compute term   (MXU)
  * per-op HBM traffic   -> memory term    (operands+results of top-level ops;
                            post-fusion HLO is a good HBM-op granularity)
  * collective wire bytes -> collective term (ring cost models per op type)

Hardware constants (TPU v5e): 197 TF/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(r"\b([a-z]+\d*(?:e\d+m\d+\w*)?)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->")


def shape_bytes(shape_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_dims(shape_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Op:
    name: str
    shape: str
    opcode: str
    rest: str           # operand list + attributes


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]
    ops: List[Op]
    is_entry: bool = False


def parse_hlo(txt: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in txt.splitlines():
        if cur is None:
            if line.rstrip().endswith("{"):
                m = _COMP_HDR_RE.match(line.strip())
                if m:
                    name, paramstr = m.groups()
                    params = {}
                    for p in re.finditer(r"([\w\.\-]+):\s*([^,()]+(?:\([^)]*\))?)", paramstr):
                        params[p.group(1)] = p.group(2)
                    cur = Computation(name=name, params=params, ops=[],
                                      is_entry=line.strip().startswith("ENTRY"))
        else:
            if line.strip() == "}":
                comps[cur.name] = cur
                cur = None
                continue
            m = _OP_RE.match(line)
            if m:
                cur.ops.append(Op(*m.groups()))
    return comps


def _find_callees(op: Op) -> List[Tuple[str, str]]:
    """[(kind, comp_name)] referenced by this op."""
    out = []
    for attr, kind in (("body", "while_body"), ("condition", "while_cond"),
                       ("calls", "fusion"), ("to_apply", "call"),
                       ("branch_computations", "cond")):
        for m in re.finditer(attr + r"=\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?", op.rest):
            for name in re.split(r",\s*%?", m.group(1)):
                out.append((kind, name))
    return out


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the loop condition (scan trip count)."""
    best = 1
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.match(r"(\d+)", op.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _group_size(rest: str, total_devices: int) -> int:
    # iota form: replica_groups=[8,64]<=[512] -> group size 64
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", rest)
    if m:
        return int(m.group(2))
    # explicit form: replica_groups={{0,1,2,...},{...}}
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", rest)
    if m:
        return len(m.group(1).split(","))
    return total_devices


@dataclasses.dataclass
class CostSummary:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    by_collective: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_count: Dict[str, int] = dataclasses.field(default_factory=dict)
    trip_counts: Dict[str, int] = dataclasses.field(default_factory=dict)

    def terms(self) -> Dict[str, float]:
        return dict(
            compute_s=self.dot_flops / PEAK_FLOPS,
            memory_s=self.hbm_bytes / HBM_BW,
            collective_s=self.collective_wire_bytes / ICI_BW,
        )


_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _fusion_operand_bytes(operands, sym, callee: "Computation | None") -> float:
    """Charge fusion operands at the bytes actually READ.

    XLA fuses a scan body's per-iteration ``dynamic-slice`` of the stacked
    [L, ...] parameter array into consumer fusions: the fusion *operand* is
    the whole stack, but each iteration reads one slice. For every fused
    parameter whose only in-fusion uses are (dynamic-)slices, charge the
    slice results instead of the full operand (59x overcount otherwise —
    measured on the DeepSeek train cell)."""
    if callee is None:
        return sum(shape_bytes(sym.get(o, "")) for o in operands)
    pnames = list(callee.params)
    uses: dict = {p: [] for p in pnames}
    for op in callee.ops:
        ops_in = re.findall(r"%([\w\.\-]+)", op.rest.split(")")[0])
        for o in ops_in:
            if o in uses:
                uses[o].append(op)
    total = 0.0
    for i, o in enumerate(operands):
        full = shape_bytes(sym.get(o, ""))
        p = pnames[i] if i < len(pnames) else None
        ops_using = uses.get(p, []) if p else []
        if ops_using and all(u.opcode in ("dynamic-slice", "slice") for u in ops_using):
            total += min(full, sum(shape_bytes(u.shape) for u in ops_using))
        elif ops_using and all(u.opcode == "dynamic-update-slice"
                               and u.rest.split(")")[0].startswith(f"%{p}")
                               for u in ops_using):
            pass  # aliased in-place destination: write counted at the root
        else:
            total += full
    return total


def _collective_wire_bytes(opcode: str, result_bytes: float, operand_bytes: float,
                           g: int) -> float:
    """Ring-model wire bytes per device."""
    if g <= 1:
        return 0.0
    if opcode.startswith("all-reduce"):
        return 2.0 * result_bytes * (g - 1) / g
    if opcode.startswith("all-gather"):
        return result_bytes * (g - 1) / g
    if opcode.startswith("reduce-scatter"):
        return operand_bytes * (g - 1) / g
    if opcode.startswith("all-to-all"):
        return result_bytes * (g - 1) / g
    if opcode.startswith("collective-permute"):
        return result_bytes
    return 0.0


def analyze(txt: str, total_devices: int = 256) -> CostSummary:
    comps = parse_hlo(txt)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # accumulate multipliers over the call graph (BFS from entry)
    mult: Dict[str, float] = defaultdict(float)
    via_fusion: Dict[str, bool] = defaultdict(lambda: True)
    mult[entry.name] = 1.0
    via_fusion[entry.name] = False
    queue = [entry.name]
    seen_edges = set()
    while queue:
        cname = queue.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for op in comp.ops:
            for kind, callee in _find_callees(op):
                if callee not in comps:
                    continue
                key = (cname, op.name, callee)
                if key in seen_edges:
                    continue
                seen_edges.add(key)
                if kind == "while_body":
                    condname = None
                    mm = re.search(r"condition=%?([\w\.\-]+)", op.rest)
                    if mm:
                        condname = mm.group(1)
                    trip = _trip_count(comps[condname]) if condname in comps else 1
                    mult[callee] += m * trip
                    via_fusion[callee] = False
                elif kind == "while_cond":
                    trip = _trip_count(comps[callee])
                    mult[callee] += m * trip
                    via_fusion[callee] = False
                elif kind == "fusion":
                    mult[callee] += m
                    # bytes counted at the fusion op site, not inside
                else:
                    mult[callee] += m
                    via_fusion[callee] = via_fusion[callee] and (kind == "fusion")
                queue.append(callee)

    summary = CostSummary()
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        # local symbol table for operand shapes
        sym: Dict[str, str] = dict(comp.params)
        for op in comp.ops:
            sym[op.name] = op.shape

        fused_only = via_fusion[cname] and not comp.is_entry
        for op in comp.ops:
            oc = op.opcode
            if oc == "dot":
                operands = re.findall(r"%([\w\.\-]+)", op.rest.split(")")[0])
                lhs_shape = sym.get(operands[0], "") if operands else ""
                cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
                csize = 1
                ls = shape_dims(lhs_shape)
                if cdims and ls and cdims.group(1):
                    for d in cdims.group(1).split(","):
                        di = int(d)
                        if di < len(ls[1]):
                            csize *= ls[1][di]
                out_elems = 1
                od = shape_dims(op.shape)
                if od:
                    for d in od[1]:
                        out_elems *= d
                summary.dot_flops += m * 2.0 * out_elems * csize
            if not fused_only:
                rb = shape_bytes(op.shape)
                if any(oc.startswith(c) for c in _COLLECTIVES):
                    operands = re.findall(r"%([\w\.\-]+)", op.rest.split(")")[0])
                    ob = sum(shape_bytes(sym.get(o, "")) for o in operands)
                    g = _group_size(op.rest, total_devices)
                    wb = _collective_wire_bytes(oc, rb, ob, g)
                    summary.collective_wire_bytes += m * wb
                    base = next(c for c in _COLLECTIVES if oc.startswith(c))
                    summary.by_collective[base] = summary.by_collective.get(base, 0.0) + m * wb
                    summary.collective_count[base] = summary.collective_count.get(base, 0) + 1
                # HBM traffic: results + operands of ops that actually move
                # data on TPU. Standalone layout/elementwise ops (reshape,
                # broadcast, convert, iota, ...) fuse into neighbors on the
                # TPU backend, so counting them would inflate the memory term
                # with CPU-backend fusion artifacts.
                if oc in ("fusion", "dot", "convolution", "scatter", "gather",
                          "dynamic-slice", "dynamic-update-slice",
                          "sort", "copy", "concatenate",
                          "custom-call") or any(oc.startswith(c) for c in _COLLECTIVES):
                    operands = re.findall(r"%([\w\.\-]+)", op.rest.split(")")[0])
                    if oc == "dynamic-update-slice":
                        # in-place aliased: traffic = read+write of the UPDATE
                        # slice, not the whole (often [L, ...]-stacked) buffer
                        upd = shape_bytes(sym.get(operands[1], "")) if len(operands) > 1 else rb
                        summary.hbm_bytes += m * 2 * upd
                        continue
                    if oc == "fusion":
                        callee_m = re.search(r"calls=%?([\w\.\-]+)", op.rest)
                        callee = comps.get(callee_m.group(1)) if callee_m else None
                        ob = _fusion_operand_bytes(operands, sym, callee)
                        # dus-carrying fusion: the big destination buffer is
                        # aliased in place — charge the update slice, not the
                        # whole (scan-stacked) result
                        if callee is not None:
                            dus_ops = [o2 for o2 in callee.ops
                                       if o2.opcode == "dynamic-update-slice"
                                       and _SHAPE_RE.search(o2.shape)
                                       and o2.shape.split("{")[0] in op.shape]
                            if dus_ops:
                                upd_sym = dict(callee.params)
                                for o2 in callee.ops:
                                    upd_sym[o2.name] = o2.shape
                                upd_total = 0.0
                                for d_op in dus_ops:
                                    r_ops = re.findall(r"%([\w\.\-]+)",
                                                       d_op.rest.split(")")[0])
                                    if len(r_ops) > 1:
                                        upd_total += shape_bytes(upd_sym.get(r_ops[1], ""))
                                if upd_total:
                                    rb = min(rb, upd_total)
                    else:
                        ob = sum(shape_bytes(sym.get(o, "")) for o in operands)
                    summary.hbm_bytes += m * (rb + ob)

    # record trip counts for reporting
    for cname, comp in comps.items():
        for op in comp.ops:
            if op.opcode == "while":
                mm = re.search(r"condition=%?([\w\.\-]+)", op.rest)
                if mm and mm.group(1) in comps:
                    summary.trip_counts[op.name] = _trip_count(comps[mm.group(1)])
    return summary
