"""Fault-tolerant training driver: checkpoint/restart with failure injection.

``run_resilient`` wraps a step function with:
  * periodic async checkpoints (+ straggler-triggered early checkpoints);
  * crash recovery: on ANY exception the driver restores the latest valid
    committed checkpoint and resumes (up to ``max_restarts``, with bounded
    exponential backoff between attempts) — the same path a preempted or
    killed pod takes on rescheduling.  Corrupted checkpoints are skipped by
    ``ckpt.restore_with_fallback`` (checksum validation) and restore falls
    back to the previous committed step;
  * deterministic data replay: the data iterator is keyed by step, so a
    restart replays exactly the batches after the restored step (bitwise
    recovery is asserted in tests);
  * preemption handling (:func:`preemption_guard`): SIGTERM — the
    scheduler's eviction warning on k8s/SLURM/spot VMs — finishes the
    current step, commits an early checkpoint with reason ``"preempted"``,
    and returns cleanly so the relaunched job loses zero steps;
  * fault injection (:class:`FaultPlan`) used by the tests and the
    subprocess resilience driver: step-indexed exceptions of any type,
    hard process kills (``os._exit`` — emulates a dropped rank), crashes
    inside the checkpoint save path (truncated shard / missing COMMIT),
    and post-commit shard corruption.

The GNN training loop (``repro.train.loop``) drives this with its own
``restore_fn`` (elastic restore: fingerprint check + re-sharding onto the
current mesh) and ``manifest_extra`` (mesh fingerprint).
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import signal
import threading
import time
from pathlib import Path
from typing import Any, Callable, Optional

from repro.ckpt import checkpoint as ckpt
from repro.runtime.straggler import StragglerMonitor


@dataclasses.dataclass
class ResilientConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    max_restarts: int = 3
    straggler_checkpoint: bool = True
    # bounded exponential backoff between restarts:
    # sleep min(backoff_base * 2**(restarts-1), backoff_max) seconds
    backoff_base: float = 0.05
    backoff_max: float = 5.0
    # manifests carry the last `history_tail` losses so a resumed run's
    # history is continuous (full fidelity for runs shorter than the tail)
    history_tail: int = 10000
    # SIGTERM (the scheduler's eviction warning) triggers an early
    # fingerprinted checkpoint and a clean return instead of a mid-step
    # kill; the relaunched job resumes from it with zero lost steps
    preempt_checkpoint: bool = True


class InjectedFailure(RuntimeError):
    pass


def backoff_seconds(restarts: int, cfg: ResilientConfig) -> float:
    """Bounded exponential backoff for restart attempt ``restarts`` (1-based)."""
    return min(cfg.backoff_base * (2.0 ** max(restarts - 1, 0)), cfg.backoff_max)


@dataclasses.dataclass
class FaultPlan:
    """Declarative fault injection for resilience tests and drivers.

    Step faults (checked by ``maybe_fail`` before each training step):
      * ``crash_at_step`` — raise ``exc`` (default :class:`InjectedFailure`;
        set e.g. ``RuntimeError`` to model a real OOM/IO crash) the first
        ``n_crashes`` times the step is reached;
      * ``kill_process_at_step`` — ``os._exit(exit_code)``: no cleanup, no
        atexit, async saver thread dies mid-flight — the closest a test can
        get to a dropped rank / preempted pod.  Used by the subprocess
        resilience driver; the orchestrator relaunches (possibly on a
        different rank count) and expects elastic resume.

    Checkpoint-save faults (installed as the ``ckpt`` fault hook while the
    plan is active via :meth:`installed`):
      * ``crash_save_at_step`` — the first save at/after this step dies at
        ``save_stage``: "pre_commit" leaves shard+manifest but no COMMIT
        (``latest_step`` must skip it); "truncate_shard" additionally
        truncates the shard npz before raising (a half-written file).

    ``corrupt_shard`` is a static helper that damages an already-committed
    shard in place — restore must detect it by checksum and fall back.
    """
    crash_at_step: Optional[int] = None
    exc: type = InjectedFailure
    n_crashes: int = 1
    kill_process_at_step: Optional[int] = None
    exit_code: int = 17
    crash_save_at_step: Optional[int] = None
    save_stage: str = "pre_commit"          # or "truncate_shard"
    crashes_fired: int = 0
    save_crashes_fired: int = 0

    def maybe_fail(self, step: int):
        if self.kill_process_at_step is not None and step == self.kill_process_at_step:
            os._exit(self.exit_code)
        if (self.crash_at_step is not None and step == self.crash_at_step
                and self.crashes_fired < self.n_crashes):
            self.crashes_fired += 1
            raise self.exc(f"injected failure at step {step}")

    def _ckpt_hook(self, stage: str, step: int, step_dir: Path):
        if self.crash_save_at_step is None or step < self.crash_save_at_step:
            return
        if self.save_crashes_fired >= self.n_crashes:
            return
        if self.save_stage == "truncate_shard" and stage == "arrays_written":
            shard = step_dir / "shard_0.npz"
            size = shard.stat().st_size
            with open(shard, "r+b") as f:
                f.truncate(max(size // 2, 1))
            self.save_crashes_fired += 1
            raise InjectedFailure(
                f"injected save crash (truncated shard) at step {step}")
        if self.save_stage == "pre_commit" and stage == "pre_commit":
            self.save_crashes_fired += 1
            raise InjectedFailure(
                f"injected save crash (no COMMIT) at step {step}")

    @contextlib.contextmanager
    def installed(self):
        """Activate the checkpoint-save faults for the duration."""
        if self.crash_save_at_step is None:
            yield self
            return
        prev = ckpt.set_fault_hook(self._ckpt_hook)
        try:
            yield self
        finally:
            ckpt.set_fault_hook(prev)

    @staticmethod
    def corrupt_shard(ckpt_dir: str | Path, step: int, n_bytes: int = 16):
        """Flip bytes in the middle of a COMMITTED step's shard (bit rot /
        partial overwrite after commit).  Restore detects it by checksum."""
        shard = Path(ckpt_dir) / f"step_{step:010d}" / "shard_0.npz"
        size = shard.stat().st_size
        off = size // 2
        with open(shard, "r+b") as f:
            f.seek(off)
            chunk = f.read(n_bytes)
            f.seek(off)
            f.write(bytes(b ^ 0xFF for b in chunk))


@contextlib.contextmanager
def preemption_guard(enabled: bool = True):
    """Turn SIGTERM into a cooperative flag for the duration of the block.

    Schedulers (k8s, SLURM, spot/preemptible VMs) send SIGTERM with a grace
    window before SIGKILL.  Inside the guard the default die-now behavior
    becomes ``flag["preempted"] = True``; ``run_resilient`` checks the flag
    between steps and commits an early checkpoint instead of losing up to
    ``ckpt_every`` steps of work.  The previous handler is restored on
    exit.  Signal handlers are a main-thread-only facility — on any other
    thread (or with ``enabled=False``) the guard is an inert flag."""
    flag = {"preempted": False, "signum": None}
    if not enabled or threading.current_thread() is not threading.main_thread():
        yield flag
        return

    def _handler(signum, frame):
        flag["preempted"] = True
        flag["signum"] = signum

    prev = signal.signal(signal.SIGTERM, _handler)
    try:
        yield flag
    finally:
        signal.signal(signal.SIGTERM, prev)


def _default_restore(cfg: ResilientConfig, init_state_fn):
    """Restore the newest valid committed step, or None for a fresh start.
    Returns (state, start_step, prior_losses, manifest)."""
    if not ckpt.committed_steps(cfg.ckpt_dir):
        return None
    state, manifest = ckpt.restore_with_fallback(cfg.ckpt_dir, init_state_fn())
    start = manifest["step"] + 1
    extra = manifest.get("extra", {})
    off = int(extra.get("losses_offset", 0))
    losses = list(extra.get("losses", []))[:max(start - off, 0)]
    return state, start, losses, manifest


def run_resilient(
    init_state_fn: Callable[[], Any],
    step_fn: Callable[[Any, Any], tuple],     # (state, batch) -> (state, metrics)
    batch_fn: Callable[[int], Any],           # step -> batch (deterministic replay)
    n_steps: int,
    cfg: ResilientConfig,
    inject_failure_at: Optional[int] = None,
    monitor: Optional[StragglerMonitor] = None,
    fault: Optional[FaultPlan] = None,
    restore_fn: Optional[Callable[[], Optional[tuple]]] = None,
    manifest_extra: Optional[dict] = None,
):
    """Returns (final_state, history dict).

    Any ``Exception`` from a step (or a surfaced async-save failure) counts
    as a crash: the driver restores the latest valid committed checkpoint,
    sleeps a bounded exponential backoff, and replays.  After
    ``cfg.max_restarts`` failed restarts the exception propagates.
    ``KeyboardInterrupt``/``SystemExit`` always propagate.

    ``restore_fn`` overrides the default restore — it must return
    ``(state, start_step, prior_losses)`` (extra trailing values are
    allowed) or None for a fresh start.  The GNN loop uses this for elastic
    restore across rank counts.  ``manifest_extra`` is merged into every
    checkpoint manifest's ``extra`` (static metadata: the mesh fingerprint).

    With ``cfg.preempt_checkpoint`` (default), SIGTERM during the run is
    handled cooperatively: the current step finishes, an early checkpoint
    is committed with reason ``"preempted"``, and the driver returns
    cleanly with ``history["preempted_at"]`` set — the relaunched job
    resumes from that exact step.
    """
    saver = ckpt.AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep)
    monitor = monitor or StragglerMonitor()
    history = {"losses": [], "restarts": 0, "straggler_events": 0,
               "restart_steps": [], "resume_steps": [], "backoffs": [],
               "preempted_at": None}
    if inject_failure_at is not None and fault is None:
        fault = FaultPlan(crash_at_step=inject_failure_at)

    def save_extra(reason: str) -> dict:
        tail = history["losses"][-cfg.history_tail:]
        extra = {"reason": reason,
                 "losses": list(tail),     # copy: async thread serializes later
                 "losses_offset": len(history["losses"]) - len(tail)}
        if manifest_extra:
            extra.update(manifest_extra)
        return extra

    restarts = 0
    step = 0
    with preemption_guard(cfg.preempt_checkpoint) as sig:
        while True:
            try:
                with (fault.installed() if fault is not None
                      else contextlib.nullcontext()):
                    restored = (restore_fn() if restore_fn is not None
                                else _default_restore(cfg, init_state_fn))
                    if restored is None:
                        state, start = init_state_fn(), 0
                        history["losses"] = []
                    else:
                        state, start, prior_losses = (
                            restored[0], restored[1], restored[2])
                        # truncate to the restored prefix — replayed steps
                        # must not be double-counted in the history
                        history["losses"] = list(prior_losses)
                        history["resume_steps"].append(start - 1)

                    for step in range(start, n_steps):
                        if fault is not None:
                            fault.maybe_fail(step)
                        batch = batch_fn(step)
                        monitor.start_step()
                        state, metrics = step_fn(state, batch)
                        ev = monitor.end_step(step)
                        history["losses"].append(float(metrics.get("loss", 0.0)))
                        if sig["preempted"]:
                            # eviction warning: commit NOW, exit cleanly —
                            # the relaunch resumes from this exact step
                            history["preempted_at"] = step
                            saver.save(step, state,
                                       extra=save_extra("preempted"))
                            saver.wait()
                            return state, history
                        if ev is not None:
                            history["straggler_events"] += 1
                            if cfg.straggler_checkpoint:
                                saver.save(step, state,
                                           extra=save_extra("straggler"))
                        if step % cfg.ckpt_every == 0 or step == n_steps - 1:
                            saver.save(step, state, extra=save_extra("periodic"))
                    saver.wait()
                    return state, history

            except Exception:
                restarts += 1
                history["restarts"] = restarts
                history["restart_steps"].append(step)
                if restarts > cfg.max_restarts:
                    raise
                # a failed in-flight save must not abort the recovery itself
                try:
                    saver.wait()
                except Exception:
                    pass
                delay = backoff_seconds(restarts, cfg)
                history["backoffs"].append(delay)
                time.sleep(delay)
                # loop re-enters: restore from latest valid committed
                # checkpoint
