"""Fault-tolerant training driver: checkpoint/restart with failure injection.

``run_resilient`` wraps a step function with:
  * periodic async checkpoints (+ straggler-triggered early checkpoints);
  * crash recovery: on any exception the driver restores the latest committed
    checkpoint and resumes (up to ``max_restarts``) — the same path a
    preempted/killed pod takes on rescheduling;
  * deterministic data replay: the data iterator is keyed by step, so a
    restart replays exactly the batches after the restored step (bitwise
    recovery is asserted in tests);
  * optional failure injection (``inject_failure_at``) used by the tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from repro.ckpt import checkpoint as ckpt
from repro.runtime.straggler import StragglerMonitor


@dataclasses.dataclass
class ResilientConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    max_restarts: int = 3
    straggler_checkpoint: bool = True


class InjectedFailure(RuntimeError):
    pass


def run_resilient(
    init_state_fn: Callable[[], Any],
    step_fn: Callable[[Any, Any], tuple],     # (state, batch) -> (state, metrics)
    batch_fn: Callable[[int], Any],           # step -> batch (deterministic replay)
    n_steps: int,
    cfg: ResilientConfig,
    inject_failure_at: Optional[int] = None,
    monitor: Optional[StragglerMonitor] = None,
):
    """Returns (final_state, history dict)."""
    saver = ckpt.AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep)
    monitor = monitor or StragglerMonitor()
    history = {"losses": [], "restarts": 0, "straggler_events": 0}

    restarts = 0
    while True:
        try:
            latest = ckpt.latest_step(cfg.ckpt_dir)
            if latest is not None:
                template = init_state_fn()
                state, manifest = ckpt.restore(cfg.ckpt_dir, template)
                start = manifest["step"] + 1
            else:
                state = init_state_fn()
                start = 0

            for step in range(start, n_steps):
                if inject_failure_at is not None and step == inject_failure_at \
                        and restarts == 0:
                    raise InjectedFailure(f"injected at step {step}")
                batch = batch_fn(step)
                monitor.start_step()
                state, metrics = step_fn(state, batch)
                ev = monitor.end_step(step)
                if ev is not None:
                    history["straggler_events"] += 1
                    if cfg.straggler_checkpoint:
                        saver.save(step, state, extra={"reason": "straggler"})
                history["losses"].append(float(metrics.get("loss", 0.0)))
                if step % cfg.ckpt_every == 0 or step == n_steps - 1:
                    saver.save(step, state, extra={"reason": "periodic"})
            saver.wait()
            return state, history

        except InjectedFailure:
            restarts += 1
            history["restarts"] = restarts
            if restarts > cfg.max_restarts:
                raise
            saver.wait()
            # loop re-enters: restore from latest committed checkpoint
