"""Straggler detection + mitigation hooks.

On a real pod, stragglers show up as step-time outliers on some hosts. The
monitor keeps an EWMA + variance of step times, flags outliers
(> mean + k*std and > slack*mean), and drives mitigation callbacks:
the training loop uses it to (a) log/alert, (b) trigger an early checkpoint
so a replacement host can join (elastic restart path), and (c) optionally
skip a slow host's data shard for one step (bounded-staleness semantics).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time: float
    mean: float
    std: float


class StragglerMonitor:
    def __init__(self, alpha: float = 0.05, k_std: float = 4.0,
                 slack: float = 1.5, warmup_steps: int = 10):
        self.alpha = alpha
        self.k_std = k_std
        self.slack = slack
        self.warmup = warmup_steps
        self.mean: Optional[float] = None
        self.var: float = 0.0
        self.n = 0
        self.events: List[StragglerEvent] = []
        self._t0: Optional[float] = None

    def start_step(self):
        self._t0 = time.perf_counter()

    def end_step(self, step: int) -> Optional[StragglerEvent]:
        if self._t0 is None:
            # start_step never ran for this step (e.g. the previous step
            # died mid-flight and a resilient driver restarted the loop) —
            # there is nothing valid to measure
            return None
        dt = time.perf_counter() - self._t0
        self._t0 = None
        return self.observe(step, dt)

    def reset(self):
        """Forget the timing statistics (not the recorded events) — used
        after an elastic restart, where a new rank count changes the
        per-step time scale and stale EWMA stats would misfire."""
        self.mean = None
        self.var = 0.0
        self.n = 0
        self._t0 = None

    def observe(self, step: int, dt: float) -> Optional[StragglerEvent]:
        self.n += 1
        if self.mean is None:
            self.mean = dt
            return None
        is_outlier = False
        std = self.var ** 0.5
        if self.n > self.warmup:
            is_outlier = dt > self.mean + self.k_std * std and dt > self.slack * self.mean
        if not is_outlier:
            # EWMA updates exclude outliers so one straggler doesn't poison stats
            d = dt - self.mean
            self.mean += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        if is_outlier:
            ev = StragglerEvent(step, dt, self.mean, std)
            self.events.append(ev)
            return ev
        return None
