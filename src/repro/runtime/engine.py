"""Resident solver-in-the-loop inference engine for the consistent GNN.

The paper's end goal is interfacing the trained surrogate with a running
solver (NekRS): the solver streams snapshots into a RESIDENT model and
gets K-step predictions back, with partitioned inference arithmetically
identical to single-rank inference.  This module is that serving path:

* :class:`InferenceEngine` holds the trained params — loaded ONCE from a
  fingerprinted checkpoint (see the checkpoint contract below) — and a
  graph cache keyed by ``(mesh_fingerprint_hash, partitioner)``: the first
  request for a mesh pays the ``partition_mesh`` + ``ShardedGraph`` +
  ``NMPPlan`` build, every later request reuses it.  This is the maxtext
  offline-inference pattern (threaded engine loop, cached executables,
  explicit batch slots) and the hook where X-MeshGraphNet-style
  multi-geometry serving lands: one cache entry per geometry.
* Requests (global ``[N, F]`` snapshot fields) arrive on a BOUNDED
  thread-safe queue — :meth:`InferenceEngine.submit` blocks when the
  engine is saturated, which is the backpressure contract — get grouped
  into ``batch_slots`` fixed slots (zero-padded: the jitted program has
  exactly one batch shape, so there is never a recompile per request
  count), and run through the jitted K-step rollout eval from
  ``repro.train.rollout`` — the exact program the rollout consistency
  suite pins, not a reimplementation.
* Results stream back per request through single-shot futures;
  :meth:`InferenceEngine.stream` wires a multi-producer
  ``PrefetchingLoader`` (the repo's hang-safe transport) in front of the
  queue for solver-style feeds.

Consistency contract (asserted in-process by ``tests/test_engine.py`` and
on real collectives by ``tests/drivers/serve_driver.py`` under the CI
serve-smoke job): the engine's streamed predictions are BITWISE identical
to the offline ``rollout_step`` eval of the same snapshot at the same
device count — batching, slot padding, queueing and threading are
arithmetically invisible — and consistent across device counts to fp32
tolerance (Eqs. 2-3: the paper's guarantee extends from training to
serving).  Zero-padded slots can't perturb real slots because the forward
has no cross-batch mixing; the batch dim rides through ``shard_map`` +
``scan`` elementwise.

Checkpoint contract: the engine refuses a checkpoint without a mesh
fingerprint, refuses params whose recorded model config disagrees with
the engine's ``GNNConfig`` (field named), and refuses requests or mesh
registrations whose ``mesh_fingerprint_hash`` differs from the
checkpoint's — naming BOTH hashes, so a solver pointed at the wrong model
learns which mesh the params were trained on instead of silently getting
garbage.  Corrupted newest checkpoints fall back to the previous
committed step, like the resilient trainer.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Optional

import numpy as np
import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.ckpt import checkpoint as ckpt
from repro.core import GNNConfig, NMPPlan, init_gnn, partition_mesh
from repro.core.distributed import shard_graph
from repro.core.graph_state import ShardedGraph
from repro.core.mesh_gen import SEMMesh
from repro.core.partition import gather_node_features, scatter_node_outputs
from repro.data.pipeline import PrefetchingLoader
from repro.launch.mesh import make_mesh
from repro.train.loop import mesh_fingerprint_hash
from repro.train.rollout import make_rollout_predict_fn


class EngineError(RuntimeError):
    """Engine lifecycle/request failure (shutdown, saturation, bad input)."""


class MeshMismatchError(EngineError):
    """Request/registration mesh hash differs from the checkpoint's trained
    mesh — the engine refuses by name rather than serving garbage."""


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static engine policy.

    ``batch_slots`` is the FIXED slot count of the jitted program (requests
    are zero-padded up to it); ``max_pending`` bounds the request queue —
    the backpressure point; ``flush_timeout_s`` is how long a non-full
    batch waits for more requests before running padded (latency floor
    under light load).
    """
    batch_slots: int = 4
    rollout_steps: int = 1
    max_pending: int = 16
    flush_timeout_s: float = 0.02
    result_timeout_s: float = 300.0
    halo_mode: str = "a2a"
    partitioner: str = "block"

    def __post_init__(self):
        if self.batch_slots < 1 or self.rollout_steps < 1 \
                or self.max_pending < 1:
            raise ValueError(
                "batch_slots, rollout_steps and max_pending must be >= 1 "
                f"(got {self.batch_slots}/{self.rollout_steps}/"
                f"{self.max_pending})")


@dataclasses.dataclass
class InferenceResult:
    """One request's K-step prediction, scattered back to the global mesh."""
    step: int
    mesh_hash: str
    preds: np.ndarray          # [K, N_global, F_out]
    latency_s: float


class RequestFuture:
    """Single-shot future for one submitted snapshot."""

    def __init__(self, step: int):
        self.step = step
        self._ev = threading.Event()
        self._val: Optional[InferenceResult] = None
        self._err: Optional[BaseException] = None

    def _set(self, val: InferenceResult):
        self._val = val
        self._ev.set()

    def _fail(self, err: BaseException):
        self._err = err
        self._ev.set()

    def done(self) -> bool:
        return self._ev.is_set()

    def result(self, timeout: Optional[float] = None) -> InferenceResult:
        if not self._ev.wait(timeout):
            raise EngineError(
                f"request step={self.step} not completed after {timeout}s — "
                "is the engine started?")
        if self._err is not None:
            raise self._err
        return self._val


@dataclasses.dataclass
class _Request:
    step: int
    key: tuple
    x: np.ndarray              # global [N, F] snapshot
    future: RequestFuture
    t_submit: float


@dataclasses.dataclass
class _GraphEntry:
    """One mesh's cached execution state (built once, reused per request)."""
    mesh_hash: str
    pg: Any
    plan: NMPPlan
    gs: ShardedGraph
    predict: Callable
    build_s: float


class InferenceEngine:
    """Resident serving engine over the jitted rollout eval step.

    Lifecycle: construct (loads params from ``ckpt_dir``), then
    :meth:`register_mesh` each geometry, optionally :meth:`warmup` (pays
    the jit compile up front), :meth:`start` the engine thread, feed it via
    :meth:`submit`/:meth:`stream`, and :meth:`close`.  Also a context
    manager (``with InferenceEngine(...) as eng``) that starts on enter and
    closes on exit.
    """

    def __init__(self, ckpt_dir, cfg: GNNConfig,
                 config: EngineConfig = EngineConfig(),
                 plan: NMPPlan = NMPPlan(), mesh_dev=None):
        self.cfg = cfg
        self.config = config
        # execution-policy fields forwarded into each mesh's NMPPlan.build
        # (halo specs are per-partition, derived at register_mesh time)
        self._policy = {
            "backend": plan.backend, "schedule": plan.schedule,
            "precision": plan.precision, "interpret": plan.interpret,
            "block_n": plan.block_n, "block_e": plan.block_e}
        self.mesh_dev = mesh_dev if mesh_dev is not None else make_mesh(
            (1, len(jax.devices())), ("data", "graph"))
        self.R = int(self.mesh_dev.shape["graph"])
        self.params, self.fingerprint, self.ckpt_step = \
            self._load_params(ckpt_dir)
        self._graphs: dict[tuple, _GraphEntry] = {}
        self._q: queue.Queue = queue.Queue(maxsize=config.max_pending)
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._failure: Optional[BaseException] = None
        self._thread: Optional[threading.Thread] = None
        self.stats = {"requests": 0, "batches": 0, "padded_slots": 0,
                      "cache_hits": 0, "cache_builds": 0}

    # -- checkpoint ---------------------------------------------------------

    def _load_params(self, ckpt_dir):
        steps = ckpt.committed_steps(ckpt_dir)
        if not steps:
            raise EngineError(
                f"no committed checkpoint under {ckpt_dir} — train with "
                "TrainConfig.ckpt_dir (repro.train.loop) first")
        template = init_gnn(jax.random.PRNGKey(0), self.cfg)
        repl = NamedSharding(self.mesh_dev, P())
        shardings = jax.tree.map(lambda _: repl, template)
        last_err: Optional[BaseException] = None
        for step in reversed(steps):
            try:
                manifest = ckpt.peek_manifest(ckpt_dir, step)
                fp = (manifest.get("extra") or {}).get("fingerprint")
                if not fp or "mesh_hash" not in fp:
                    raise EngineError(
                        f"checkpoint step {step} under {ckpt_dir} carries no "
                        "mesh fingerprint — the engine only serves "
                        "fingerprinted checkpoints (repro.train.loop stamps "
                        "run_fingerprint into every manifest)")
                for field, have in (("hidden", self.cfg.hidden),
                                    ("n_levels", self.cfg.n_levels)):
                    if fp.get(field) is not None \
                            and int(fp[field]) != int(have):
                        raise EngineError(
                            f"engine GNNConfig.{field}={have} disagrees with "
                            f"the checkpoint fingerprint {field}={fp[field]} "
                            "— these params belong to a different model")
                params, _ = ckpt.restore_partial(
                    ckpt_dir, template, "params", step=step,
                    shardings=shardings)
                return params, fp, step
            except ckpt.CheckpointCorruption as e:
                # damaged-after-commit newest step: fall back, like the
                # resilient trainer (EngineError/ValueError are config
                # problems and propagate immediately)
                print(f"[engine] checkpoint step {step} corrupted, "
                      f"falling back: {e}")
                last_err = e
        raise EngineError(
            f"no valid committed checkpoint under {ckpt_dir} "
            f"({len(steps)} committed steps, all corrupted; last error: "
            f"{last_err})")

    # -- graph cache --------------------------------------------------------

    def _mismatch(self, mesh_hash: str) -> MeshMismatchError:
        return MeshMismatchError(
            f"mesh {mesh_hash} does not match the checkpoint's trained mesh "
            f"{self.fingerprint['mesh_hash']} "
            f"(n_global={self.fingerprint.get('n_global')}) — the engine "
            "refuses to run a model on a geometry it was not trained on; "
            "serve this mesh from its own checkpoint (multi-geometry "
            "serving keys the graph cache by this hash)")

    def register_mesh(self, sem_mesh: SEMMesh, rank_grid=None,
                      partitioner: Optional[str] = None,
                      hierarchy=None) -> str:
        """Build (or fetch from cache) the execution state for one mesh;
        returns its ``mesh_fingerprint_hash`` — the key every subsequent
        :meth:`submit`/:meth:`stream` call must present."""
        mesh_hash = mesh_fingerprint_hash(sem_mesh)
        if mesh_hash != self.fingerprint["mesh_hash"]:
            raise self._mismatch(mesh_hash)
        partitioner = partitioner or self.config.partitioner
        key = (mesh_hash, partitioner)
        with self._lock:
            if key in self._graphs:
                self.stats["cache_hits"] += 1
                return mesh_hash
            t0 = time.perf_counter()
            grid = tuple(rank_grid) if rank_grid is not None \
                else (self.R, 1, 1)
            if int(np.prod(grid)) != self.R:
                raise EngineError(
                    f"rank_grid {grid} does not cover the device mesh's "
                    f"graph axis (R={self.R})")
            pg = partition_mesh(sem_mesh, grid, method=partitioner)
            src = hierarchy if (hierarchy is not None
                                and self.cfg.n_levels > 1) else pg
            mode = self.config.halo_mode if self.R > 1 else "none"
            plan = NMPPlan.build(src, mode, axis="graph", **self._policy)
            graph = ShardedGraph.build(
                pg, sem_mesh.coords, plan,
                hierarchy=hierarchy if self.cfg.n_levels > 1 else None)
            plan = plan.autotune(graph, hidden=self.cfg.hidden)
            gs = shard_graph(self.mesh_dev, graph)
            predict = make_rollout_predict_fn(
                self.mesh_dev, self.cfg, plan, self.config.rollout_steps)
            self._graphs[key] = _GraphEntry(
                mesh_hash=mesh_hash, pg=pg, plan=plan, gs=gs,
                predict=predict, build_s=time.perf_counter() - t0)
            self.stats["cache_builds"] += 1
        return mesh_hash

    def _entry(self, mesh_hash: str, partitioner: Optional[str] = None
               ) -> _GraphEntry:
        if mesh_hash != self.fingerprint["mesh_hash"]:
            raise self._mismatch(mesh_hash)
        key = (mesh_hash, partitioner or self.config.partitioner)
        with self._lock:
            entry = self._graphs.get(key)
        if entry is None:
            raise EngineError(
                f"mesh {mesh_hash} (partitioner={key[1]!r}) is not "
                "registered — call register_mesh(sem_mesh) before "
                "submitting requests")
        return entry

    def warmup(self, mesh_hash: Optional[str] = None):
        """Compile each cached mesh's batch-slot program (one zero batch
        through the jitted rollout eval) so the first real request does not
        pay the compile."""
        with self._lock:
            entries = [e for k, e in self._graphs.items()
                       if mesh_hash is None or k[0] == mesh_hash]
        for entry in entries:
            x0 = np.stack([gather_node_features(
                entry.pg, np.zeros((entry.pg.n_global, self.cfg.node_in),
                                   np.float32))
                for _ in range(self.config.batch_slots)])
            np.asarray(entry.predict(self.params, x0, entry.gs))

    # -- lifecycle ----------------------------------------------------------

    @property
    def closed(self) -> bool:
        return self._stop.is_set()

    def _shutdown_error(self) -> EngineError:
        if self._failure is not None:
            return EngineError(f"engine terminated: {self._failure!r}")
        return EngineError("engine is shut down")

    def start(self) -> "InferenceEngine":
        if self._thread is not None:
            raise EngineError("engine already started")
        if self._stop.is_set():
            raise self._shutdown_error()
        self._thread = threading.Thread(
            target=self._loop, daemon=True, name="inference-engine")
        self._thread.start()
        return self

    def close(self, error: Optional[BaseException] = None):
        """Stop the engine thread and fail every still-queued request (with
        ``error``, when given, as the terminal cause)."""
        if error is not None and self._failure is None:
            self._failure = error
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=30)
        self._drain_failed()

    def __enter__(self) -> "InferenceEngine":
        return self.start()

    def __exit__(self, exc_type, exc, tb):
        self.close()
        return False

    def _drain_failed(self):
        while True:
            try:
                req = self._q.get_nowait()
            except queue.Empty:
                return
            req.future._fail(self._shutdown_error())

    # -- request path -------------------------------------------------------

    def submit(self, mesh_hash: str, x, step: int = 0,
               timeout: Optional[float] = None,
               partitioner: Optional[str] = None) -> RequestFuture:
        """Queue one global ``[N, F]`` snapshot; returns its future.

        Blocks while ``max_pending`` requests are already queued — the
        backpressure contract — for at most ``timeout`` seconds
        (:class:`EngineError` on expiry; ``None`` waits forever)."""
        if self._stop.is_set():
            raise self._shutdown_error()
        entry = self._entry(mesh_hash, partitioner)
        x = np.asarray(x, np.float32)
        want = (int(entry.pg.n_global), int(self.cfg.node_in))
        if tuple(x.shape) != want:
            raise EngineError(
                f"snapshot shape {tuple(x.shape)} does not match the "
                f"registered mesh ({want[0]} nodes x {want[1]} fields)")
        fut = RequestFuture(step)
        req = _Request(step=step,
                       key=(mesh_hash, partitioner or self.config.partitioner),
                       x=x, future=fut, t_submit=time.perf_counter())
        try:
            self._q.put(req, timeout=timeout)
        except queue.Full:
            raise EngineError(
                f"request queue full ({self.config.max_pending} pending) "
                f"after {timeout}s — the engine is saturated "
                "(backpressure)") from None
        if self._stop.is_set():
            # raced a shutdown: make sure this request cannot hang
            self._drain_failed()
        return fut

    def stream(self, mesh_hash: str, batch_fn: Callable[[int], Any],
               n_requests: int, n_producers: int = 1, prefetch: int = 4,
               start_step: int = 0):
        """Producer-threaded streaming: yields ``(step, InferenceResult)``
        in submission order.

        ``batch_fn(step) -> [N, F]`` global snapshot runs on ``n_producers``
        background threads inside a :class:`PrefetchingLoader` (the repo's
        hang-safe transport); a feeder thread submits each item into the
        bounded request queue, so a slow consumer backpressures all the way
        into the producers.  A dead producer (``batch_fn`` raised) drains
        what it already queued, then SHUTS THE ENGINE DOWN and raises
        :class:`EngineError` — a solver feed dying must never leave the
        service half-alive and hanging (the CI serve-smoke job pins this).
        """
        loader = PrefetchingLoader(batch_fn, prefetch=prefetch,
                                   start_step=start_step,
                                   n_producers=n_producers)
        futs: queue.Queue = queue.Queue()
        done = object()
        box: dict = {"err": None}

        def feed():
            try:
                for _ in range(n_requests):
                    step, batch = next(loader)
                    futs.put((step, self.submit(mesh_hash, np.asarray(batch),
                                                step=step)))
            except StopIteration:
                pass
            except BaseException as e:
                box["err"] = e
            finally:
                loader.close()
                futs.put(done)

        feeder = threading.Thread(target=feed, daemon=True,
                                  name="engine-stream-feeder")
        feeder.start()
        try:
            while True:
                item = futs.get()
                if item is done:
                    break
                step, fut = item
                yield step, fut.result(
                    timeout=self.config.result_timeout_s)
        finally:
            feeder.join(timeout=30)
        if box["err"] is not None:
            err = box["err"]
            self.close(error=err)
            raise EngineError(
                f"producer feed for mesh {mesh_hash} died; engine shut "
                f"down: {err!r}") from err

    # -- engine thread ------------------------------------------------------

    def _loop(self):
        try:
            while not self._stop.is_set():
                try:
                    first = self._q.get(timeout=0.05)
                except queue.Empty:
                    continue
                batch = [first]
                deadline = time.perf_counter() + self.config.flush_timeout_s
                while len(batch) < self.config.batch_slots:
                    rem = deadline - time.perf_counter()
                    if rem <= 0:
                        break
                    try:
                        batch.append(self._q.get(timeout=rem))
                    except queue.Empty:
                        break
                # group by graph-cache key: multi-geometry ready (today all
                # requests share the checkpoint's one mesh)
                groups: dict = {}
                for r in batch:
                    groups.setdefault(r.key, []).append(r)
                for key, reqs in groups.items():
                    self._run_batch(key, reqs)
        except BaseException as e:
            # an internal failure poisons the engine: record it, fail every
            # queued request, and refuse further submits — never limp along
            self._failure = e
            self._stop.set()
            self._drain_failed()

    def _run_batch(self, key: tuple, reqs: list):
        entry = self._graphs[key]
        slots = self.config.batch_slots
        try:
            xs = [gather_node_features(entry.pg, r.x) for r in reqs]
            n_pad = slots - len(xs)
            xs.extend(np.zeros_like(xs[0]) for _ in range(n_pad))
            preds = np.asarray(
                entry.predict(self.params, np.stack(xs), entry.gs))
            t_done = time.perf_counter()
            for i, r in enumerate(reqs):
                out = np.stack([
                    scatter_node_outputs(entry.pg, preds[i, k])
                    for k in range(self.config.rollout_steps)])
                r.future._set(InferenceResult(
                    step=r.step, mesh_hash=key[0], preds=out,
                    latency_s=t_done - r.t_submit))
            self.stats["requests"] += len(reqs)
            self.stats["batches"] += 1
            self.stats["padded_slots"] += n_pad
        except BaseException as e:
            for r in reqs:
                r.future._fail(e)
            raise

    # -- offline oracle -----------------------------------------------------

    def offline_reference(self, mesh_hash: str, x,
                          partitioner: Optional[str] = None) -> np.ndarray:
        """Run ONE snapshot synchronously at batch=1 through the same
        cached plan/graph, bypassing the queue entirely — the documented
        oracle for the bitwise consistency contract (``benchmarks/serve.py``
        asserts engine == offline on every bench run; the CI driver builds
        its own rollout eval from scratch for a stronger check)."""
        entry = self._entry(mesh_hash, partitioner)
        xs = gather_node_features(entry.pg,
                                  np.asarray(x, np.float32))[None]
        preds = np.asarray(entry.predict(self.params, xs, entry.gs))[0]
        return np.stack([scatter_node_outputs(entry.pg, preds[k])
                         for k in range(self.config.rollout_steps)])
