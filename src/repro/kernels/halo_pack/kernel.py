"""Pallas TPU kernels for the packed halo wire format.

Two tiny data-movement kernels that replace the ``take(send_idx)`` /
``scatter-add`` XLA pattern on the neighbor-exchange hot path:

* ``pack``   — gather boundary rows ``x[idx]`` into a contiguous send
  buffer, multiplied by the 0/1 send mask.  Row gathers are issued as
  double-buffered per-row HBM->VMEM DMAs driven by a scalar-prefetched
  index list, the same machinery as ``kernels/segment_agg``.
* ``unpack`` — masked scatter-add of a recv buffer into the destination
  array: ``out = a.at[idx].add(buf * mask)``.  The accumulator lives in
  a VMEM scratch initialised from ``a`` on the first tile and flushed on
  the last, with sequential per-row read-modify-write (duplicate indices
  within a round cannot race).

Both kernels are pure data movement: the packed halo path must stay
BITWISE equal to the dense path, so there is no re-association of sums —
each output row receives exactly the rows the dense path would add, in
the same tile order.

Index lists ride in SMEM as 2-D ``[T, BLOCK]`` int32 via
``pltpu.PrefetchScalarGridSpec(num_scalar_prefetch=1)``; padding rows
carry index 0 and mask 0.0 so they gather/scatter harmless zeros.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.segment_agg.kernel import _gather_rows, _scatter_add_rows


def _pack_kernel(idx_ref, x_any, mask_ref, buf_ref, gat, sem, *, block_b):
    t = pl.program_id(0)
    nt = pl.num_programs(0)
    rows = _gather_rows(idx_ref, t, nt, x_any, gat, sem, block_b)
    buf_ref[0] = (rows * mask_ref[0][:, None]).astype(buf_ref.dtype)


def pack_pallas(x: jnp.ndarray, idx_t: jnp.ndarray, mask_t: jnp.ndarray,
                *, interpret: bool = False) -> jnp.ndarray:
    """Masked row gather ``x[idx] * mask`` -> tiled ``[T, BB, F]`` buffer.

    ``idx_t``/``mask_t`` are pre-tiled ``[T, BB]`` (int32 / x.dtype);
    padding slots have index 0 and mask 0.
    """
    n_tiles, block_b = idx_t.shape
    feat = x.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),          # x: manual DMA
            pl.BlockSpec((1, block_b), lambda t, *_: (t, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_b, feat), lambda t, *_: (t, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((2, block_b, feat), x.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_pack_kernel, block_b=block_b),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_tiles, block_b, feat), x.dtype),
        interpret=interpret,
    )(idx_t, x, mask_t)


def _unpack_kernel(idx_ref, a_ref, buf_ref, mask_ref, out_ref, acc, *,
                   block_b):
    t = pl.program_id(0)
    nt = pl.num_programs(0)

    @pl.when(t == 0)
    def _init():
        acc[...] = a_ref[...]

    rows = buf_ref[0] * mask_ref[0][:, None]
    _scatter_add_rows(idx_ref, t, rows, acc, block_b)

    @pl.when(t == nt - 1)
    def _flush():
        out_ref[...] = acc[...]


def unpack_add_pallas(a: jnp.ndarray, buf_t: jnp.ndarray, idx_t: jnp.ndarray,
                      mask_t: jnp.ndarray, *,
                      interpret: bool = False) -> jnp.ndarray:
    """Masked scatter-add ``a.at[idx].add(buf * mask)`` over tiled inputs.

    ``a`` is ``[N, F]`` with N a multiple of 8; ``buf_t`` is
    ``[T, BB, F]`` in ``a.dtype``; padding slots (index 0, mask 0) add
    exact zeros to row 0.
    """
    n_tiles, block_b = idx_t.shape
    n_rows, feat = a.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((n_rows, feat), lambda t, *_: (0, 0)),
            pl.BlockSpec((1, block_b, feat), lambda t, *_: (t, 0, 0)),
            pl.BlockSpec((1, block_b), lambda t, *_: (t, 0)),
        ],
        out_specs=pl.BlockSpec((n_rows, feat), lambda t, *_: (0, 0)),
        scratch_shapes=[pltpu.VMEM((n_rows, feat), a.dtype)],
    )
    return pl.pallas_call(
        functools.partial(_unpack_kernel, block_b=block_b),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rows, feat), a.dtype),
        interpret=interpret,
    )(idx_t, a, buf_t, mask_t)
