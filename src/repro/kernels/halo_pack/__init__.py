from repro.kernels.halo_pack.ops import halo_pack, halo_unpack_add
from repro.kernels.halo_pack.ref import halo_pack_ref, halo_unpack_add_ref

__all__ = ["halo_pack", "halo_unpack_add", "halo_pack_ref",
           "halo_unpack_add_ref"]
