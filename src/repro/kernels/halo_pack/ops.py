"""Host wrappers + custom VJPs for the fused halo pack/unpack ops.

Entry points (used by ``repro.core.halo`` when ``HaloSpec(packed=True)``):

* ``halo_pack(x, idx, mask)``        -> ``buf = x[idx] * mask[:, None]``
* ``halo_unpack_add(a, buf, idx, mask)`` -> ``a.at[idx].add(buf * mask)``

Both are pure data movement, bitwise-equal to the XLA expressions in
``ref.py`` (tested in ``tests/test_halo_pack.py``).  They form a closed
adjoint pair, so each op's backward pass is the other op's kernel:

* d pack / d x      = unpack_add(zeros_like(x), g, idx, mask)
* d unpack / d a    = g
* d unpack / d buf  = pack(g, idx, mask)

Index lists are graph metadata — the VJPs return zero cotangents for
them (float0 for the int indices, zeros for the masks), mirroring the
``fused_nmp_edge_agg`` gradient contract.

Host-side layout: the wire width ``W`` is padded up to a multiple of the
tile depth ``block_b`` (padding slots: index 0, mask 0 — they move exact
zeros), indices are clipped into range, and the destination row count is
rounded up to a multiple of 8 so the unpack kernel's VMEM accumulator
tiles cleanly.  ``interpret=True`` runs both kernels on CPU for CI.
"""
from __future__ import annotations

import functools
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.halo_pack.kernel import pack_pallas, unpack_add_pallas

#: env var overriding the wire tile depth (rows per kernel tile)
BLOCK_ENV = "REPRO_HALO_PACK_BLOCK"


def pick_block_b(backend: str | None = None,
                 interpret: bool = False) -> int:
    """Tile depth for the pack/unpack kernels.

    Wire buffers are narrow (a few bucket-rounded rows per neighbor), so
    tiles stay shallow: 8 rows in interpret/CPU mode (the interpreter runs
    the per-row loops eagerly), 128 on TPU to amortize per-row DMA issue
    overhead.  ``REPRO_HALO_PACK_BLOCK`` overrides.
    """
    override = os.environ.get(BLOCK_ENV)
    if override:
        return int(override)
    if backend is None:
        backend = jax.default_backend()
    return 8 if (interpret or backend != "tpu") else 128


_INT_ZERO = functools.partial(np.zeros, dtype=jax.dtypes.float0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _pack_core(static, x, idx_t, mask_t):
    (interpret,) = static
    return pack_pallas(x, idx_t, mask_t, interpret=interpret)


def _pack_core_fwd(static, x, idx_t, mask_t):
    return _pack_core(static, x, idx_t, mask_t), (x, idx_t, mask_t)


def _pack_core_bwd(static, res, g):
    (interpret,) = static
    x, idx_t, mask_t = res
    gx = unpack_add_pallas(jnp.zeros_like(x), g.astype(x.dtype), idx_t,
                           mask_t, interpret=interpret)
    return gx, _INT_ZERO(idx_t.shape), jnp.zeros_like(mask_t)


_pack_core.defvjp(_pack_core_fwd, _pack_core_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _unpack_core(static, a, buf_t, idx_t, mask_t):
    (interpret,) = static
    return unpack_add_pallas(a, buf_t, idx_t, mask_t, interpret=interpret)


def _unpack_core_fwd(static, a, buf_t, idx_t, mask_t):
    out = _unpack_core(static, a, buf_t, idx_t, mask_t)
    return out, (buf_t, idx_t, mask_t)


def _unpack_core_bwd(static, res, g):
    (interpret,) = static
    buf_t, idx_t, mask_t = res
    gbuf = pack_pallas(g, idx_t, mask_t.astype(g.dtype), interpret=interpret)
    return (g, gbuf.astype(buf_t.dtype), _INT_ZERO(idx_t.shape),
            jnp.zeros_like(mask_t))


_unpack_core.defvjp(_unpack_core_fwd, _unpack_core_bwd)


def _tile_wire(idx, mask, n_round, block_b, dtype):
    """Clip + pad a [W] wire index/mask pair into [T, BB] tiles."""
    w = idx.shape[0]
    w_pad = -(-max(w, 1) // block_b) * block_b
    idx_p = jnp.pad(jnp.clip(idx.astype(jnp.int32), 0, n_round - 1),
                    (0, w_pad - w))
    mask_p = jnp.pad(mask.astype(dtype), (0, w_pad - w))
    return idx_p.reshape(-1, block_b), mask_p.reshape(-1, block_b), w_pad


def halo_pack(x: jnp.ndarray, idx: jnp.ndarray, mask: jnp.ndarray, *,
              interpret: bool = False) -> jnp.ndarray:
    """Fused masked row gather: ``buf = x[idx] * mask[:, None]``.

    Args:
      x: [N, F] source rows.
      idx: [W] int row ids (padding slots may be any in-range value).
      mask: [W] 0/1 send mask (0 on padding — those slots become zeros).

    Returns [W, F] send buffer in ``x.dtype``, bitwise-equal to
    ``halo_pack_ref``.
    """
    n, f = x.shape
    w = idx.shape[0]
    block_b = pick_block_b(interpret=interpret)
    n_round = -(-max(n, 1) // 8) * 8
    x_k = jnp.pad(x, ((0, n_round - n), (0, 0)))
    idx_t, mask_t, w_pad = _tile_wire(idx, mask, n_round, block_b, x.dtype)
    buf = _pack_core((bool(interpret),), x_k, idx_t, mask_t)
    return buf.reshape(w_pad, f)[:w]


def halo_unpack_add(a: jnp.ndarray, buf: jnp.ndarray, idx: jnp.ndarray,
                    mask: jnp.ndarray, *,
                    interpret: bool = False) -> jnp.ndarray:
    """Fused masked scatter-add: ``out = a.at[idx].add(buf * mask[:, None])``.

    Args:
      a: [N, F] destination rows (the combine seed).
      buf: [W, F] recv buffer (cast to ``a.dtype`` before accumulation).
      idx: [W] int destination row ids.
      mask: [W] 0/1 recv mask (0 on padding — exact-zero no-op adds).

    Returns [N, F] in ``a.dtype``, bitwise-equal to ``halo_unpack_add_ref``
    (recv ids are unique within a halo round, so add order is moot).
    """
    n, f = a.shape
    w = idx.shape[0]
    block_b = pick_block_b(interpret=interpret)
    n_round = -(-max(n, 1) // 8) * 8
    a_k = jnp.pad(a, ((0, n_round - n), (0, 0)))
    idx_t, mask_t, w_pad = _tile_wire(idx, mask, n_round, block_b, a.dtype)
    buf_t = jnp.pad(buf.astype(a.dtype),
                    ((0, w_pad - w), (0, 0))).reshape(-1, block_b, f)
    out = _unpack_core((bool(interpret),), a_k, buf_t, idx_t, mask_t)
    return out[:n]
