"""Plain-XLA references for the fused halo pack/unpack ops.

These are the exact expressions ``core/halo.py`` used before the packed
wire format existed (``take(send_idx)`` masked multiply on the send side,
``a.at[recv_idx].add`` on the recv side).  The Pallas ops are pure data
movement over the same rows, so ``tests/test_halo_pack.py`` pins them
BITWISE equal to these references — values and gradients.
"""
from __future__ import annotations

import jax.numpy as jnp


def halo_pack_ref(x: jnp.ndarray, idx: jnp.ndarray,
                  mask: jnp.ndarray) -> jnp.ndarray:
    """``buf[i] = x[idx[i]] * mask[i]`` — masked row gather, [W, F]."""
    return x[idx] * mask[:, None]


def halo_unpack_add_ref(a: jnp.ndarray, buf: jnp.ndarray, idx: jnp.ndarray,
                        mask: jnp.ndarray) -> jnp.ndarray:
    """``out = a.at[idx].add(buf * mask[:, None])`` — masked scatter-add."""
    return a.at[idx].add(buf * mask[:, None])
