"""Pure-jnp oracle for the fused edge-MLP + segment aggregation kernel."""
from __future__ import annotations

import jax


def edge_mlp_agg_ref(feats, w1, b1, w2, b2, dst, weights, n_nodes: int):
    """feats [E, F_in] (pre-gathered [x_i ++ x_j ++ e_ij]); 2-layer ELU MLP;
    weighted (1/d_ij) segment-sum to dst. Returns (e_new [E, H], agg [N, H])."""
    h = jax.nn.elu(feats @ w1 + b1)
    e_new = h @ w2 + b2
    agg = jax.ops.segment_sum(e_new * weights[:, None], dst, num_segments=n_nodes)
    return e_new, agg
