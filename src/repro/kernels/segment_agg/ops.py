"""Host layout pass + jit'd wrappers for the fused segment-aggregation kernels.

Two device entry points:

* ``fused_edge_mlp_agg`` — the original forward-only op over pre-gathered
  ``[E, 3H]`` features (kept as a microbenchmark / oracle target);
* ``fused_nmp_edge_agg`` — the production op used by
  ``repro.core.consistent_mp``: node-feature gathers are fused into the
  kernel (no HBM ``[E, 3H]`` concat), the full residual edge MLP (incl.
  LayerNorm) runs in VMEM, and a ``jax.custom_vjp`` routes the backward pass
  through a second Pallas kernel.

The host-side ``dst_aligned_layout`` pass is O(E log E) (one argsort + one
``searchsorted``) and is cached per partition by
``repro.core.partition.PartitionedGraphs.segment_layout``.
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.segment_agg.kernel import (
    edge_mlp_agg, nmp_edge_mlp_agg_bwd, nmp_edge_mlp_agg_fwd)


def dst_aligned_layout(dst: np.ndarray, n_nodes: int, block_n: int,
                       block_e: int) -> dict:
    """Sort edges by destination and pad per node-block to edge-block
    multiples, vectorized (argsort + searchsorted — no per-block scans).

    Edges with ``dst >= n_nodes`` (e.g. padding edges redirected to a
    sentinel) are dropped from the layout: their slots stay ``-1``.

    Returns index maps (``perm`` -> original edge id, ``dstl`` block-local
    dst per slot) + the padding overhead (waste fraction).
    """
    dst = np.asarray(dst, dtype=np.int64)
    keep = np.nonzero((dst >= 0) & (dst < n_nodes))[0]
    order = keep[np.argsort(dst[keep], kind="stable")]
    dst_sorted = dst[order]
    nb = math.ceil(max(n_nodes, 1) / block_n)
    bounds = np.arange(nb + 1, dtype=np.int64) * block_n
    starts = np.searchsorted(dst_sorted, bounds[:-1], side="left")
    ends = np.searchsorted(dst_sorted, bounds[1:], side="left")
    counts = ends - starts
    max_count = int(counts.max()) if counts.size else 0
    ne = max(1, math.ceil(max_count / block_e))
    perm = np.full((nb, ne * block_e), -1, dtype=np.int64)
    if dst_sorted.size:
        blk = dst_sorted // block_n
        col = np.arange(dst_sorted.size, dtype=np.int64) - starts[blk]
        perm[blk, col] = order
    waste = 1.0 - (dst_sorted.size / perm.size) if perm.size else 0.0
    perm = perm.reshape(nb, ne, block_e)
    dstl = np.where(
        perm >= 0,
        dst[np.clip(perm, 0, None)] - np.arange(nb)[:, None, None] * block_n,
        0).astype(np.int32)
    return dict(perm=perm, dstl=dstl, n_node_blocks=nb, n_edge_blocks=ne,
                block_n=int(block_n), block_e=int(block_e), waste=waste)


def fused_edge_mlp_agg(feats, dst, weights, w1, b1, w2, b2, layout, *,
                       n_nodes: int, block_n: int, block_e: int,
                       interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """feats [E, Fin] in original edge order; applies the dst-aligned layout,
    runs the kernel, and scatters e_new back to the original order.

    Returns (e_new [E, H], agg [n_nodes_padded_to_block, H])."""
    perm = jnp.asarray(layout["perm"])                      # [NB, NE, BE]
    safe = jnp.clip(perm, 0, feats.shape[0] - 1)
    valid = (perm >= 0).astype(feats.dtype)
    tile_feats = feats[safe] * valid[..., None]
    tile_dstl = jnp.asarray(layout["dstl"])
    tile_w = weights[safe] * valid

    e_tiles, agg = edge_mlp_agg(tile_feats, tile_dstl, tile_w, w1, b1, w2, b2,
                                n_node_blocks=layout["n_node_blocks"],
                                block_n=block_n, block_e=block_e,
                                interpret=interpret)
    # un-permute e_new to original edge order
    e_new = jnp.zeros((feats.shape[0], e_tiles.shape[-1]), e_tiles.dtype)
    e_new = e_new.at[safe.reshape(-1)].add(
        e_tiles.reshape(-1, e_tiles.shape[-1]) * valid.reshape(-1, 1))
    return e_new, agg.reshape(-1, agg.shape[-1])


# ---------------------------------------------------------------------------
# production fused NMP op (differentiable)
# ---------------------------------------------------------------------------

def _stack_edge_mlp(params):
    """``nn.mlp``-style params dict -> stacked kernel operands.

    Returns (w0 [3H,H], b0 [1,H], wrest [Lp,H,H], brest [Lp,H], lng [1,H],
    lnb [1,H], n_hidden, has_ln).  When the MLP has a single dense layer the
    hidden stack is a zero dummy (skipped statically inside the kernel).
    """
    layers = params["layers"]
    w0 = layers[0]["w"]
    b0 = layers[0]["b"][None]
    hid = w0.shape[1]
    if len(layers) > 1:
        wrest = jnp.stack([l["w"] for l in layers[1:]])
        brest = jnp.stack([l["b"] for l in layers[1:]])
    else:
        wrest = jnp.zeros((1, hid, hid), w0.dtype)
        brest = jnp.zeros((1, hid), w0.dtype)
    ln = params.get("ln")
    has_ln = ln is not None
    if has_ln:
        lng, lnb = ln["g"][None], ln["b"][None]
    else:
        lng = jnp.ones((1, hid), w0.dtype)
        lnb = jnp.zeros((1, hid), w0.dtype)
    return w0, b0, wrest, brest, lng, lnb, len(layers) - 1, has_ln


_INT_ZERO = functools.partial(np.zeros, dtype=jax.dtypes.float0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _nmp_core(static, x, e_t, srcg, dstl, emask, einv,
              w0, b0, wrest, brest, lng, lnb):
    block_n, block_e, n_hidden, has_ln, interpret = static
    return nmp_edge_mlp_agg_fwd(
        x, e_t, srcg, dstl, emask, einv, w0, b0, wrest, brest, lng, lnb,
        block_n=block_n, block_e=block_e, n_hidden=n_hidden, has_ln=has_ln,
        interpret=interpret)


def _nmp_core_fwd(static, x, e_t, srcg, dstl, emask, einv,
                  w0, b0, wrest, brest, lng, lnb):
    out = _nmp_core(static, x, e_t, srcg, dstl, emask, einv,
                    w0, b0, wrest, brest, lng, lnb)
    return out, (x, e_t, srcg, dstl, emask, einv, w0, b0, wrest, brest,
                 lng, lnb)


def _nmp_core_bwd(static, res, g):
    block_n, block_e, n_hidden, has_ln, interpret = static
    x, e_t, srcg, dstl, emask, einv, w0, b0, wrest, brest, lng, lnb = res
    g_enew, g_agg = g
    gx, ge, gw0, gb0, gwrest, gbrest, glng, glnb = nmp_edge_mlp_agg_bwd(
        x, e_t, srcg, dstl, emask, einv, w0, b0, wrest, brest, lng, lnb,
        g_enew.astype(e_t.dtype), g_agg.astype(jnp.float32),
        block_n=block_n, block_e=block_e, n_hidden=n_hidden, has_ln=has_ln,
        interpret=interpret)
    return (gx.astype(x.dtype), ge.astype(e_t.dtype),
            _INT_ZERO(srcg.shape), _INT_ZERO(dstl.shape),
            jnp.zeros_like(emask), jnp.zeros_like(einv),
            gw0.astype(w0.dtype), gb0.astype(b0.dtype),
            gwrest.astype(wrest.dtype), gbrest.astype(brest.dtype),
            glng.astype(lng.dtype), glnb.astype(lnb.dtype))


_nmp_core.defvjp(_nmp_core_fwd, _nmp_core_bwd)


def fused_nmp_edge_agg(x, e, edge_params, perm, dstl, edge_src, edge_mask,
                       edge_inv_mult, *, block_n: int,
                       interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused, differentiable Eq. 4a+4b (edge MLP -> weighted aggregate).

    Args:
      x: [N_pad, H] node features.
      e: [E_pad, H] edge features (original edge order).
      edge_params: ``nn.mlp`` params of the edge MLP (consumes 3H).
      perm: [NB, NE, BE] dst-aligned layout (original edge id per slot, -1 pad).
      dstl: [NB, NE, BE] block-local dst per slot (0 on padding).
      edge_src / edge_mask / edge_inv_mult: [E_pad] metadata arrays.
      block_n: node rows per block — must match the value the layout was
        built with (checked: the layout's block count must equal
        ``ceil(N_pad / block_n)``).

    Gradient contract: ``edge_src``/``edge_mask``/``edge_inv_mult`` (and the
    layout maps) are static graph metadata — the custom VJP returns zero
    cotangents for them.  (The xla backend would propagate mask/inv-mult
    gradients if asked; nothing in this repo differentiates graph metadata.)

    Returns (e_new [E_pad, H] == (e + MLP([x_i,x_j,e])) * mask,
             agg [N_pad, H] == segment_sum(e_new * 1/d_ij, dst)).
    """
    n_pad, hid = x.shape
    nb = perm.shape[0]
    n_round = nb * block_n
    if nb != -(-n_pad // block_n):
        raise ValueError(
            f"layout has {nb} node blocks but ceil({n_pad}/{block_n}) = "
            f"{-(-n_pad // block_n)}; was the layout built with a different "
            "block_n?")
    w0, b0, wrest, brest, lng, lnb, n_hidden, has_ln = _stack_edge_mlp(edge_params)
    if w0.shape[0] != 3 * hid:
        raise ValueError(f"edge MLP consumes {w0.shape[0]} features, expected "
                         f"3*H = {3 * hid}")

    safe = jnp.clip(perm, 0, e.shape[0] - 1)
    valid = (perm >= 0)
    validf = valid.astype(e.dtype)
    e_t = e[safe] * validf[..., None]
    srcg = jnp.where(valid, edge_src[safe], 0).astype(jnp.int32)
    emask_t = (edge_mask[safe] * validf).astype(jnp.float32)
    einv_t = (edge_inv_mult[safe] * validf).astype(jnp.float32)
    x_k = jnp.pad(x, ((0, n_round - n_pad), (0, 0)))

    static = (int(block_n), int(perm.shape[-1]), int(n_hidden), bool(has_ln),
              bool(interpret))
    e_tiles, agg = _nmp_core(static, x_k, e_t, srcg, dstl, emask_t, einv_t,
                             w0, b0, wrest, brest, lng, lnb)

    e_new = jnp.zeros_like(e, shape=(e.shape[0], hid))
    e_new = e_new.at[safe.reshape(-1)].add(
        (e_tiles * validf[..., None]).reshape(-1, hid))
    return e_new, agg.reshape(n_round, hid)[:n_pad].astype(e.dtype)
