"""Host layout pass + jit'd wrapper for the fused segment-aggregation kernel."""
from __future__ import annotations

import math
from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.segment_agg.kernel import edge_mlp_agg


def dst_aligned_layout(dst: np.ndarray, n_nodes: int, block_n: int,
                       block_e: int) -> dict:
    """Sort edges by destination and pad per node-block to edge-block
    multiples. Returns index maps + the padding overhead (waste fraction)."""
    order = np.argsort(dst, kind="stable")
    dst_sorted = dst[order]
    nb = math.ceil(n_nodes / block_n)
    per_block_edges = []
    for i in range(nb):
        sel = np.nonzero((dst_sorted >= i * block_n) & (dst_sorted < (i + 1) * block_n))[0]
        per_block_edges.append(sel)
    ne = max(1, max((math.ceil(len(s) / block_e) for s in per_block_edges), default=1))
    perm = np.full((nb, ne * block_e), -1, dtype=np.int64)   # -> original edge id
    for i, sel in enumerate(per_block_edges):
        perm[i, :len(sel)] = order[sel]
    waste = 1.0 - (dst.shape[0] / perm.size) if perm.size else 0.0
    return dict(perm=perm.reshape(nb, ne, block_e), n_node_blocks=nb,
                n_edge_blocks=ne, waste=waste)


def fused_edge_mlp_agg(feats, dst, weights, w1, b1, w2, b2, layout, *,
                       n_nodes: int, block_n: int, block_e: int,
                       interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """feats [E, Fin] in original edge order; applies the dst-aligned layout,
    runs the kernel, and scatters e_new back to the original order.

    Returns (e_new [E, H], agg [n_nodes_padded_to_block, H])."""
    perm = jnp.asarray(layout["perm"])                      # [NB, NE, BE]
    safe = jnp.clip(perm, 0, feats.shape[0] - 1)
    valid = (perm >= 0).astype(feats.dtype)
    tile_feats = feats[safe] * valid[..., None]
    tile_dstl = (dst[safe] - (jnp.arange(layout["n_node_blocks"])[:, None, None]
                              * block_n)).astype(jnp.int32)
    tile_w = weights[safe] * valid

    e_tiles, agg = edge_mlp_agg(tile_feats, tile_dstl, tile_w, w1, b1, w2, b2,
                                n_node_blocks=layout["n_node_blocks"],
                                block_n=block_n, block_e=block_e,
                                interpret=interpret)
    # un-permute e_new to original edge order
    e_new = jnp.zeros((feats.shape[0], e_tiles.shape[-1]), e_tiles.dtype)
    e_new = e_new.at[safe.reshape(-1)].add(
        e_tiles.reshape(-1, e_tiles.shape[-1]) * valid.reshape(-1, 1))
    return e_new, agg.reshape(-1, agg.shape[-1])
