"""Host layout passes + jit'd wrappers for the fused segment-aggregation
kernels.

Two device entry points:

* ``fused_edge_mlp_agg`` — the original forward-only op over pre-gathered
  ``[E, 3H]`` features (kept as a microbenchmark / oracle target); consumes
  the legacy block layout from ``dst_aligned_layout``.
* ``fused_nmp_edge_agg`` — the production op used by
  ``repro.core.consistent_mp``: node-feature rows are DMA-gathered inside
  the kernel from per-tile index lists (scalar prefetch — no HBM ``[E, 3H]``
  concat and no one-hot gather matmuls), the full residual edge MLP (incl.
  LayerNorm) runs in VMEM, and a ``jax.custom_vjp`` routes the backward pass
  through a second Pallas kernel. ``precision="bf16"`` runs the edge-MLP
  matmuls in bf16 with fp32 accumulation.

Layout passes (host-side, O(E log E), cached per partition by
``repro.core.partition.PartitionedGraphs.segment_layout``):

* ``compact_gather_layout`` — the production layout: edges sorted by
  destination, chopped into flat ``[n_tiles, block_e]`` tiles with the
  original edge id plus global src/dst node id recorded per slot. Only the
  final tile carries padding, so the tile occupancy is ``E / (T·BE)``
  regardless of the degree distribution — the per-node-block padding the old
  dst-aligned layout paid (its ``waste`` metric) does not exist here.
* ``dst_aligned_layout`` — the legacy per-node-block layout, kept for the
  microbenchmark kernel.
"""
from __future__ import annotations

import functools
import math
import os
from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.segment_agg.kernel import (
    FP32, PRECISIONS, edge_mlp_agg, nmp_edge_mlp_agg_bwd, nmp_edge_mlp_agg_fwd)

#: env var overriding the autotune table: "block_n,block_e"
BLOCKS_ENV = "REPRO_SEG_BLOCKS"


def pick_block_sizes(hidden: int, dtype=jnp.float32,
                     backend: str | None = None) -> Tuple[int, int]:
    """Static block-size autotune for the fused NMP kernels.

    Returns ``(block_n, block_e)`` from a small table keyed on (hidden,
    dtype, backend): edge tiles deep enough to amortize the per-row DMA
    issue overhead, shallower for wide hidden sizes so the double-buffered
    gather scratch ([2, BE, H] per operand) stays small. ``block_n`` only
    sets the node-padding granularity for the DMA-gather kernels (the
    compact layout has no node blocks) but still shapes the legacy
    dst-aligned path.

    The ``REPRO_SEG_BLOCKS`` env var ("block_n,block_e") overrides the
    table — the escape hatch for hand-tuning on new hardware.
    """
    override = os.environ.get(BLOCKS_ENV)
    if override:
        bn, be = (int(v) for v in override.split(","))
        return bn, be
    if backend is None:
        backend = jax.default_backend()
    itemsize = jnp.dtype(dtype).itemsize
    # (max_hidden, block_n, block_e) rows; first match wins. CPU/interpret
    # rows use small tiles: the interpreter executes the per-row loops
    # eagerly, so deep tiles only add latency there.
    table = ((64, 16, 32), (256, 32, 64), (4096, 32, 32)) \
        if backend != "tpu" else ((64, 128, 512), (256, 128, 256),
                                  (4096, 128, 128))
    for max_h, bn, be in table:
        if hidden <= max_h:
            break
    if itemsize <= 2:       # bf16 rows are half the bytes: go deeper
        be *= 2
    return bn, be


# ---------------------------------------------------------------------------
# layout passes
# ---------------------------------------------------------------------------

def compact_gather_layout(src: np.ndarray, dst: np.ndarray, n_nodes: int,
                          block_e: int) -> dict:
    """Compact per-tile gather/scatter index lists for the DMA-gather kernel.

    Edges are sorted by destination (stable, so coincident-copy summation
    order is deterministic) and chopped into flat ``[n_tiles, block_e]``
    tiles. Edges with ``dst`` outside ``[0, n_nodes)`` (padding edges routed
    to a sentinel) are dropped. Per slot the layout records the original
    edge id (``perm``, -1 on padding — only the last tile can have any) and
    the global src/dst node ids (0 on padding; the kernel's padding rows
    are weight-masked to zero, so their row-0 scatters are no-ops).

    Returns {perm [T, BE] int32, src [T, BE] int32, dst [T, BE] int32,
             n_tiles, block_e, n_edges}.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    keep = np.nonzero((dst >= 0) & (dst < n_nodes))[0]
    order = keep[np.argsort(dst[keep], kind="stable")]
    n_real = int(order.size)
    nt = max(1, math.ceil(n_real / block_e))
    perm = np.full(nt * block_e, -1, dtype=np.int32)
    perm[:n_real] = order
    valid = perm >= 0
    safe = np.clip(perm, 0, None)
    src_t = np.where(valid, src[safe], 0).astype(np.int32)
    dst_t = np.where(valid, dst[safe], 0).astype(np.int32)
    return dict(perm=perm.reshape(nt, block_e),
                src=src_t.reshape(nt, block_e),
                dst=dst_t.reshape(nt, block_e),
                n_tiles=nt, block_e=int(block_e), n_edges=n_real)


def dst_aligned_layout(dst: np.ndarray, n_nodes: int, block_n: int,
                       block_e: int) -> dict:
    """Legacy layout for the microbenchmark kernel: sort edges by destination
    and pad per node-block to edge-block multiples, vectorized (argsort +
    searchsorted — no per-block scans).

    Edges with ``dst >= n_nodes`` (e.g. padding edges redirected to a
    sentinel) are dropped from the layout: their slots stay ``-1``.

    Returns index maps (``perm`` -> original edge id, ``dstl`` block-local
    dst per slot) + the padding overhead (waste fraction).
    """
    dst = np.asarray(dst, dtype=np.int64)
    keep = np.nonzero((dst >= 0) & (dst < n_nodes))[0]
    order = keep[np.argsort(dst[keep], kind="stable")]
    dst_sorted = dst[order]
    nb = math.ceil(max(n_nodes, 1) / block_n)
    bounds = np.arange(nb + 1, dtype=np.int64) * block_n
    starts = np.searchsorted(dst_sorted, bounds[:-1], side="left")
    ends = np.searchsorted(dst_sorted, bounds[1:], side="left")
    counts = ends - starts
    max_count = int(counts.max()) if counts.size else 0
    ne = max(1, math.ceil(max_count / block_e))
    perm = np.full((nb, ne * block_e), -1, dtype=np.int64)
    if dst_sorted.size:
        blk = dst_sorted // block_n
        col = np.arange(dst_sorted.size, dtype=np.int64) - starts[blk]
        perm[blk, col] = order
    waste = 1.0 - (dst_sorted.size / perm.size) if perm.size else 0.0
    perm = perm.reshape(nb, ne, block_e)
    dstl = np.where(
        perm >= 0,
        dst[np.clip(perm, 0, None)] - np.arange(nb)[:, None, None] * block_n,
        0).astype(np.int32)
    return dict(perm=perm, dstl=dstl, n_node_blocks=nb, n_edge_blocks=ne,
                block_n=int(block_n), block_e=int(block_e), waste=waste)


def fused_edge_mlp_agg(feats, dst, weights, w1, b1, w2, b2, layout, *,
                       n_nodes: int, block_n: int, block_e: int,
                       interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """feats [E, Fin] in original edge order; applies the dst-aligned layout,
    runs the kernel, and scatters e_new back to the original order.

    Returns (e_new [E, H], agg [n_nodes_padded_to_block, H])."""
    perm = jnp.asarray(layout["perm"])                      # [NB, NE, BE]
    safe = jnp.clip(perm, 0, feats.shape[0] - 1)
    valid = (perm >= 0).astype(feats.dtype)
    tile_feats = feats[safe] * valid[..., None]
    tile_dstl = jnp.asarray(layout["dstl"])
    tile_w = weights[safe] * valid

    e_tiles, agg = edge_mlp_agg(tile_feats, tile_dstl, tile_w, w1, b1, w2, b2,
                                n_node_blocks=layout["n_node_blocks"],
                                block_n=block_n, block_e=block_e,
                                interpret=interpret)
    # un-permute e_new to original edge order
    e_new = jnp.zeros((feats.shape[0], e_tiles.shape[-1]), e_tiles.dtype)
    e_new = e_new.at[safe.reshape(-1)].add(
        e_tiles.reshape(-1, e_tiles.shape[-1]) * valid.reshape(-1, 1))
    return e_new, agg.reshape(-1, agg.shape[-1])


# ---------------------------------------------------------------------------
# production fused NMP op (differentiable)
# ---------------------------------------------------------------------------

def _stack_edge_mlp(params):
    """``nn.mlp``-style params dict -> stacked kernel operands.

    Returns (w0 [3H,H], b0 [1,H], wrest [Lp,H,H], brest [Lp,H], lng [1,H],
    lnb [1,H], n_hidden, has_ln).  When the MLP has a single dense layer the
    hidden stack is a zero dummy (skipped statically inside the kernel).
    """
    layers = params["layers"]
    w0 = layers[0]["w"]
    b0 = layers[0]["b"][None]
    hid = w0.shape[1]
    if len(layers) > 1:
        wrest = jnp.stack([l["w"] for l in layers[1:]])
        brest = jnp.stack([l["b"] for l in layers[1:]])
    else:
        wrest = jnp.zeros((1, hid, hid), w0.dtype)
        brest = jnp.zeros((1, hid), w0.dtype)
    ln = params.get("ln")
    has_ln = ln is not None
    if has_ln:
        lng, lnb = ln["g"][None], ln["b"][None]
    else:
        lng = jnp.ones((1, hid), w0.dtype)
        lnb = jnp.zeros((1, hid), w0.dtype)
    return w0, b0, wrest, brest, lng, lnb, len(layers) - 1, has_ln


_INT_ZERO = functools.partial(np.zeros, dtype=jax.dtypes.float0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _nmp_core(static, x, e_t, srcg, dstg, emask, einv,
              w0, b0, wrest, brest, lng, lnb):
    block_e, n_hidden, has_ln, precision, interpret = static
    return nmp_edge_mlp_agg_fwd(
        x, e_t, srcg, dstg, emask, einv, w0, b0, wrest, brest, lng, lnb,
        block_e=block_e, n_hidden=n_hidden, has_ln=has_ln,
        precision=precision, interpret=interpret)


def _nmp_core_fwd(static, x, e_t, srcg, dstg, emask, einv,
                  w0, b0, wrest, brest, lng, lnb):
    out = _nmp_core(static, x, e_t, srcg, dstg, emask, einv,
                    w0, b0, wrest, brest, lng, lnb)
    return out, (x, e_t, srcg, dstg, emask, einv, w0, b0, wrest, brest,
                 lng, lnb)


def _nmp_core_bwd(static, res, g):
    block_e, n_hidden, has_ln, precision, interpret = static
    x, e_t, srcg, dstg, emask, einv, w0, b0, wrest, brest, lng, lnb = res
    g_enew, g_agg = g
    gx, ge, gw0, gb0, gwrest, gbrest, glng, glnb = nmp_edge_mlp_agg_bwd(
        x, e_t, srcg, dstg, emask, einv, w0, b0, wrest, brest, lng, lnb,
        g_enew.astype(e_t.dtype), g_agg.astype(jnp.float32),
        block_e=block_e, n_hidden=n_hidden, has_ln=has_ln,
        precision=precision, interpret=interpret)
    return (gx.astype(x.dtype), ge.astype(e_t.dtype),
            _INT_ZERO(srcg.shape), _INT_ZERO(dstg.shape),
            jnp.zeros_like(emask), jnp.zeros_like(einv),
            gw0.astype(w0.dtype), gb0.astype(b0.dtype),
            gwrest.astype(wrest.dtype), gbrest.astype(brest.dtype),
            glng.astype(lng.dtype), glnb.astype(lnb.dtype))


_nmp_core.defvjp(_nmp_core_fwd, _nmp_core_bwd)


def fused_nmp_edge_agg(x, e, edge_params, seg_perm, seg_src, seg_dst,
                       edge_mask, edge_inv_mult, *, block_n: int = 128,
                       interpret: bool = False,
                       precision: str = FP32) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused, differentiable Eq. 4a+4b (edge MLP -> weighted aggregate).

    Args:
      x: [N_pad, H] node features.
      e: [E_pad, H] edge features (original edge order).
      edge_params: ``nn.mlp`` params of the edge MLP (consumes 3H).
      seg_perm: [T, BE] compact layout (original edge id per slot, -1 pad).
      seg_src / seg_dst: [T, BE] global src/dst node id per slot (0 on
        padding) — scalar-prefetched into SMEM to drive the kernel's row
        DMAs; see ``compact_gather_layout``.
      edge_mask / edge_inv_mult: [E_pad] metadata arrays.
      block_n: node-padding granularity (the DMA-gather kernel has no node
        blocks; kept so config threading stays uniform with the legacy
        layout and the xla backend).
      precision: "fp32" | "bf16" — bf16 runs the edge-MLP matmuls with bf16
        operands and fp32 accumulation (aggregation always accumulates fp32).

    Gradient contract: the index lists and ``edge_mask``/``edge_inv_mult``
    are static graph metadata — the custom VJP returns zero cotangents for
    them.  (The xla backend would propagate mask/inv-mult gradients if
    asked; nothing in this repo differentiates graph metadata.)

    Returns (e_new [E_pad, H] == (e + MLP([x_i,x_j,e])) * mask,
             agg [N_pad, H] == segment_sum(e_new * 1/d_ij, dst)).
    """
    if precision not in PRECISIONS:
        raise ValueError(f"unknown precision {precision!r}; expected one of "
                         f"{PRECISIONS}")
    n_pad, hid = x.shape
    w0, b0, wrest, brest, lng, lnb, n_hidden, has_ln = _stack_edge_mlp(edge_params)
    if w0.shape[0] != 3 * hid:
        raise ValueError(f"edge MLP consumes {w0.shape[0]} features, expected "
                         f"3*H = {3 * hid}")

    # pad node rows so the fp32 VMEM accumulator tiles cleanly
    n_round = -(-max(n_pad, 1) // 8) * 8
    x_k = jnp.pad(x, ((0, n_round - n_pad), (0, 0)))

    safe = jnp.clip(seg_perm, 0, e.shape[0] - 1)
    valid = (seg_perm >= 0)
    validf = valid.astype(e.dtype)
    e_t = e[safe] * validf[..., None]
    srcg = jnp.clip(seg_src, 0, n_round - 1).astype(jnp.int32)
    dstg = jnp.clip(seg_dst, 0, n_round - 1).astype(jnp.int32)
    emask_t = (edge_mask[safe] * validf).astype(jnp.float32)
    einv_t = (edge_inv_mult[safe] * validf).astype(jnp.float32)

    static = (int(seg_perm.shape[-1]), int(n_hidden), bool(has_ln),
              str(precision), bool(interpret))
    e_tiles, agg = _nmp_core(static, x_k, e_t, srcg, dstg, emask_t, einv_t,
                             w0, b0, wrest, brest, lng, lnb)

    e_new = jnp.zeros_like(e, shape=(e.shape[0], hid))
    e_new = e_new.at[safe.reshape(-1)].add(
        (e_tiles * validf[..., None]).reshape(-1, hid))
    return e_new, agg[:n_pad].astype(e.dtype)
