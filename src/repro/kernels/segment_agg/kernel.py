"""Pallas TPU kernels: fused edge-MLP + destination-aligned segment-sum.

The paper's NMP hot loop is (edge MLP -> 1/d_ij-weighted aggregate). A naive
XLA lowering writes the MLP output to HBM, re-reads it for the scatter-add,
and the scatter itself is serialized.

Two generations of kernels live here:

* ``edge_mlp_agg`` — the original forward-only op over pre-gathered
  ``[E, 3H]`` features (microbenchmark / oracle target). It consumes the
  legacy dst-aligned block layout (``ops.dst_aligned_layout``) and
  aggregates through a *block-local* ``[BE, block_n]`` one-hot matmul — an
  MXU op whose cost is O(E · block_n · H), i.e. linear in E for a fixed
  block size (block_n is a tile constant, never the node count).

* ``nmp_edge_mlp_agg_fwd`` / ``nmp_edge_mlp_agg_bwd`` — the production pair
  behind the fused NMP registry cells (``NMPPlan(backend="fused")``),
  rewritten around
  **scalar-prefetch DMA gathers**: per-tile src/dst node-id lists are
  prefetched into SMEM (``pltpu.PrefetchScalarGridSpec``) and drive
  dynamic-slice row copies of node features out of HBM/ANY memory into a
  double-buffered VMEM scratch (tile t+1's rows stream in while tile t
  computes). The earlier generation gathered rows via ``[BE, N_round]``
  one-hot MXU matmuls, making the per-tile cost O(E·N·H) and forcing the
  whole node array to live in VMEM; the DMA gathers cost O(E·H) bytes and
  O(1) VMEM rows per edge, so the fused layer's arithmetic scales with the
  *edge* count — the regime the paper's Frontier runs assume. No one-hot
  gather/scatter matrices are materialized anywhere in the fused pair: the
  aggregation and the backward's node-gradient both run as per-row
  read-modify-write updates against a VMEM accumulator.

Mixed precision: ``precision="bf16"`` runs every edge-MLP matmul with
bf16 operands accumulating into fp32 (``preferred_element_type``); the
aggregation accumulator and all gradient accumulators stay fp32 either way.
``precision="fp32"`` (default) is bit-stable with the XLA reference modulo
summation order and is what the consistency tests pin.

VMEM note: the fused forward holds the ``[N_round, H]`` *aggregate* (and
the backward additionally the node-gradient accumulator) in VMEM scratch;
the node features themselves stay in HBM/ANY and are streamed by rows.
SMEM note: the prefetched index lists are ``[n_tiles, BE]`` int32 — 4·E
bytes per operand; shard the graph harder (or raise ``block_e``) before
per-rank E makes that exceed SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

FP32 = "fp32"
BF16 = "bf16"
PRECISIONS = (FP32, BF16)


def _dot(a, b, precision: str):
    """Matmul with the kernel's precision policy: bf16 operands / fp32
    accumulation when ``precision == "bf16"``, plain fp32 otherwise."""
    if precision == BF16:
        return jax.lax.dot(a.astype(jnp.bfloat16), b.astype(jnp.bfloat16),
                           preferred_element_type=jnp.float32)
    return jax.lax.dot(a, b)


def _kernel(feats_ref, dstl_ref, wgt_ref, w1_ref, b1_ref, w2_ref, b2_ref,
            enew_ref, agg_ref, acc_scr, *, block_n: int, block_e: int):
    ej = pl.program_id(1)
    ne = pl.num_programs(1)

    @pl.when(ej == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    feats = feats_ref[0, 0].astype(jnp.float32)          # [BE, Fin]
    h = jax.lax.dot(feats, w1_ref[...].astype(jnp.float32)) + b1_ref[...]
    h = jax.nn.elu(h)
    e_new = jax.lax.dot(h, w2_ref[...].astype(jnp.float32)) + b2_ref[...]
    enew_ref[0, 0] = e_new.astype(enew_ref.dtype)

    # dst-local one-hot [BE, BN]: aggregation as an MXU matmul, not a scatter
    # (BN = block_n, a tile constant — this is O(E·BN·H), linear in E)
    dstl = dstl_ref[0, 0]                                # [BE] in [0, BN)
    wgt = wgt_ref[0, 0]                                  # [BE] (0 on padding)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (block_e, block_n), 1)
              == dstl[:, None]).astype(jnp.float32) * wgt[:, None]
    acc_scr[...] += jax.lax.dot_general(
        onehot, e_new, (((0,), (0,)), ((), ())))         # [BN, H]

    @pl.when(ej == ne - 1)
    def _flush():
        agg_ref[0] = acc_scr[...].astype(agg_ref.dtype)


# ---------------------------------------------------------------------------
# scalar-prefetch DMA gather / scatter helpers (shared by the fused pair)
# ---------------------------------------------------------------------------

def _gather_rows(idx_ref, t, nt, src_ref, buf, sem, block_e: int):
    """Double-buffered row gather: rows ``idx_ref[t, :]`` of ``src_ref``
    (HBM/ANY) land in ``buf[t % 2]`` (VMEM ``[2, BE, H]``).

    At tile t the copies for tile t+1 are issued into the other slot before
    waiting on tile t's — the next tile's rows stream in under this tile's
    compute. The SMEM-resident index list (scalar prefetch) is what makes
    reading tile t+1's indices ahead of the grid possible.
    """
    def issue(tt, slot):
        def body(k, _):
            pltpu.make_async_copy(
                src_ref.at[pl.ds(idx_ref[tt, k], 1)],
                buf.at[slot, pl.ds(k, 1)], sem.at[slot]).start()
            return 0
        jax.lax.fori_loop(0, block_e, body, 0)

    @pl.when(t == 0)
    def _first():
        issue(0, 0)

    @pl.when(t + 1 < nt)
    def _ahead():
        issue(t + 1, (t + 1) % 2)

    def wait(k, _):
        pltpu.make_async_copy(
            src_ref.at[pl.ds(idx_ref[t, k], 1)],
            buf.at[t % 2, pl.ds(k, 1)], sem.at[t % 2]).wait()
        return 0
    jax.lax.fori_loop(0, block_e, wait, 0)
    return buf[t % 2]


def _scatter_add_rows(idx_ref, t, rows, acc, block_e: int):
    """Sequential per-row read-modify-write: ``acc[idx_ref[t, k]] += rows[k]``.

    Duplicate destinations within the tile are handled by the loop's
    sequential semantics; padding slots carry zero rows (weight-masked), so
    their writes to row 0 are no-ops.
    """
    def body(k, _):
        r = idx_ref[t, k]
        cur = pl.load(acc, (pl.ds(r, 1), slice(None)))
        pl.store(acc, (pl.ds(r, 1), slice(None)),
                 cur + jax.lax.dynamic_slice_in_dim(rows, k, 1, axis=0))
        return 0
    jax.lax.fori_loop(0, block_e, body, 0)


def _edge_mlp_tile(xi, xj, et, mask, w0, b0, wrest, brest, lng, lnb, *,
                   hidden: int, n_hidden: int, has_ln: bool, precision: str,
                   eps: float = 1e-5):
    """Eq. 4a on one ``[BE, H]`` tile: the first dense layer runs as three
    H-slices of w0 over the *virtual* concat [xi ++ xj ++ e] (the ``[BE, 3H]``
    tensor is never materialized), then the hidden stack, LayerNorm, residual
    and edge mask. Matmuls follow the ``precision`` policy; every other op
    (ELU, LN statistics, residual) stays fp32."""
    h = (_dot(xi, w0[:hidden], precision)
         + _dot(xj, w0[hidden:2 * hidden], precision)
         + _dot(et, w0[2 * hidden:], precision) + b0[0])
    for l in range(n_hidden):
        h = jax.nn.elu(h)
        h = _dot(h, wrest[l], precision) + brest[l]
    if has_ln:
        mu = jnp.mean(h, axis=-1, keepdims=True)
        var = jnp.var(h, axis=-1, keepdims=True)
        h = (h - mu) * jax.lax.rsqrt(var + eps)
        h = h * lng[0] + lnb[0]
    return (et + h) * mask[:, None]


# ---------------------------------------------------------------------------
# fused NMP forward
# ---------------------------------------------------------------------------

def _nmp_fwd_kernel(srcg_ref, dstg_ref, x_any, e_ref, emask_ref, einv_ref,
                    w0_ref, b0_ref, wrest_ref, brest_ref, lng_ref, lnb_ref,
                    enew_ref, agg_ref, xi_buf, xj_buf, agg_scr, sem_src,
                    sem_dst, *, block_e: int, hidden: int, n_hidden: int,
                    has_ln: bool, precision: str):
    """Fused Eq. 4a+4b tile: DMA-gather src/dst node rows, run the full
    residual edge MLP (incl. LayerNorm), mask, and scatter the 1/d_ij-
    weighted contribution into the fp32 VMEM aggregate."""
    t = pl.program_id(0)
    nt = pl.num_programs(0)

    @pl.when(t == 0)
    def _init():
        agg_scr[...] = jnp.zeros_like(agg_scr)

    xi = _gather_rows(srcg_ref, t, nt, x_any, xi_buf, sem_src,
                      block_e).astype(jnp.float32)        # [BE, H]
    xj = _gather_rows(dstg_ref, t, nt, x_any, xj_buf, sem_dst,
                      block_e).astype(jnp.float32)        # [BE, H]
    et = e_ref[0].astype(jnp.float32)                     # [BE, H]
    mask = emask_ref[0]                                   # [BE] 1/0
    wgt = einv_ref[0]                                     # [BE] 1/d_ij (0 pad)

    e_new = _edge_mlp_tile(
        xi, xj, et, mask, w0_ref[...].astype(jnp.float32),
        b0_ref[...].astype(jnp.float32), wrest_ref[...].astype(jnp.float32),
        brest_ref[...].astype(jnp.float32), lng_ref[...].astype(jnp.float32),
        lnb_ref[...].astype(jnp.float32), hidden=hidden, n_hidden=n_hidden,
        has_ln=has_ln, precision=precision)
    enew_ref[0] = e_new.astype(enew_ref.dtype)

    _scatter_add_rows(dstg_ref, t, e_new * wgt[:, None], agg_scr, block_e)

    @pl.when(t == nt - 1)
    def _flush():
        agg_ref[...] = agg_scr[...].astype(agg_ref.dtype)


def nmp_edge_mlp_agg_fwd(x, e_tiles, srcg, dstg, emask, einv, w0, b0, wrest,
                         brest, lng, lnb, *, block_e: int, n_hidden: int,
                         has_ln: bool, precision: str = FP32,
                         interpret: bool = False):
    """Fused NMP forward. ``x``: [N_round, H] node features (HBM-resident;
    only gathered rows enter VMEM); ``e_tiles``: [T, BE, H] dst-sorted edge
    tiles; ``srcg``/``dstg``: [T, BE] global src/dst node ids per slot
    (scalar-prefetched to SMEM, 0 on padding); ``emask``/``einv``: [T, BE]
    edge mask and 1/d_ij (both 0 on padding slots).

    Returns (e_new [T, BE, H], agg [N_round, H] fp32).
    """
    T, BE, H = e_tiles.shape
    Lp = wrest.shape[0]
    n_round = x.shape[0]
    kern = functools.partial(
        _nmp_fwd_kernel, block_e=BE, hidden=H, n_hidden=n_hidden,
        has_ln=has_ln, precision=precision)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(T,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),              # x (row DMA)
            pl.BlockSpec((1, BE, H), lambda t, *_: (t, 0, 0)),
            pl.BlockSpec((1, BE), lambda t, *_: (t, 0)),
            pl.BlockSpec((1, BE), lambda t, *_: (t, 0)),
            pl.BlockSpec((3 * H, H), lambda t, *_: (0, 0)),
            pl.BlockSpec((1, H), lambda t, *_: (0, 0)),
            pl.BlockSpec((Lp, H, H), lambda t, *_: (0, 0, 0)),
            pl.BlockSpec((Lp, H), lambda t, *_: (0, 0)),
            pl.BlockSpec((1, H), lambda t, *_: (0, 0)),
            pl.BlockSpec((1, H), lambda t, *_: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, BE, H), lambda t, *_: (t, 0, 0)),
            pl.BlockSpec((n_round, H), lambda t, *_: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, BE, H), x.dtype),                   # xi double-buf
            pltpu.VMEM((2, BE, H), x.dtype),                   # xj double-buf
            pltpu.VMEM((n_round, H), jnp.float32),             # aggregate
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((T, BE, H), e_tiles.dtype),
            jax.ShapeDtypeStruct((n_round, H), jnp.float32),
        ],
        interpret=interpret,
    )(srcg, dstg, x, e_tiles, emask, einv, w0, b0, wrest, brest, lng, lnb)


# ---------------------------------------------------------------------------
# fused NMP backward
# ---------------------------------------------------------------------------

def _nmp_bwd_kernel(srcg_ref, dstg_ref, x_any, gagg_any, e_ref, emask_ref,
                    einv_ref, w0_ref, b0_ref, wrest_ref, brest_ref, lng_ref,
                    lnb_ref, genew_ref,
                    gx_ref, ge_ref, gw0_ref, gb0_ref, gwrest_ref, gbrest_ref,
                    glng_ref, glnb_ref,
                    xi_buf, xj_buf, gag_buf, gx_scr, gw0_scr, gb0_scr,
                    gwrest_scr, gbrest_scr, glng_scr, glnb_scr, sem_src,
                    sem_dst, sem_gag, *, block_e: int, hidden: int,
                    n_hidden: int, has_ln: bool, precision: str):
    """Backward of the fused NMP tile: per-tile VJP of the recomputed edge
    MLP over DMA-gathered node rows.

    The aggregate's cotangent enters as gathered rows of ``g_agg`` (the
    adjoint of a row scatter-add is a row gather scaled by the same 1/d_ij
    weight); grads w.r.t. the gathered xi/xj rows are scattered back into a
    full-size VMEM node-grad accumulator by the same per-row RMW loop the
    forward aggregation uses. Weight grads accumulate in VMEM scratch across
    the grid; everything flushes to HBM on the final tile.
    """
    t = pl.program_id(0)
    nt = pl.num_programs(0)

    @pl.when(t == 0)
    def _init():
        gx_scr[...] = jnp.zeros_like(gx_scr)
        gw0_scr[...] = jnp.zeros_like(gw0_scr)
        gb0_scr[...] = jnp.zeros_like(gb0_scr)
        gwrest_scr[...] = jnp.zeros_like(gwrest_scr)
        gbrest_scr[...] = jnp.zeros_like(gbrest_scr)
        glng_scr[...] = jnp.zeros_like(glng_scr)
        glnb_scr[...] = jnp.zeros_like(glnb_scr)

    xi = _gather_rows(srcg_ref, t, nt, x_any, xi_buf, sem_src,
                      block_e).astype(jnp.float32)
    xj = _gather_rows(dstg_ref, t, nt, x_any, xj_buf, sem_dst,
                      block_e).astype(jnp.float32)
    gag = _gather_rows(dstg_ref, t, nt, gagg_any, gag_buf, sem_gag,
                       block_e).astype(jnp.float32)
    mask = emask_ref[0]
    wgt = einv_ref[0]

    def tile_fwd(xi, xj, et, w0, b0, wrest, brest, lng, lnb):
        # identical arithmetic to the forward tile (incl. the precision
        # policy, so bf16 truncation is differentiated through)
        return _edge_mlp_tile(xi, xj, et, mask, w0, b0, wrest, brest, lng,
                              lnb, hidden=hidden, n_hidden=n_hidden,
                              has_ln=has_ln, precision=precision)

    args = (xi, xj, e_ref[0].astype(jnp.float32),
            w0_ref[...].astype(jnp.float32),
            b0_ref[...].astype(jnp.float32),
            wrest_ref[...].astype(jnp.float32),
            brest_ref[...].astype(jnp.float32),
            lng_ref[...].astype(jnp.float32),
            lnb_ref[...].astype(jnp.float32))
    _, vjp = jax.vjp(tile_fwd, *args)
    # e_new feeds both outputs: its cotangent is g_enew plus the weighted
    # rows of g_agg its scatter-add contributed to
    g_e_new = genew_ref[0].astype(jnp.float32) + gag * wgt[:, None]
    gxi, gxj, ge, gw0, gb0, gwrest, gbrest, glng, glnb = vjp(g_e_new)

    ge_ref[0] = ge.astype(ge_ref.dtype)
    _scatter_add_rows(srcg_ref, t, gxi, gx_scr, block_e)
    _scatter_add_rows(dstg_ref, t, gxj, gx_scr, block_e)
    gw0_scr[...] += gw0
    gb0_scr[...] += gb0
    gwrest_scr[...] += gwrest
    gbrest_scr[...] += gbrest
    glng_scr[...] += glng
    glnb_scr[...] += glnb

    @pl.when(t == nt - 1)
    def _flush():
        gx_ref[...] = gx_scr[...].astype(gx_ref.dtype)
        gw0_ref[...] = gw0_scr[...].astype(gw0_ref.dtype)
        gb0_ref[...] = gb0_scr[...].astype(gb0_ref.dtype)
        gwrest_ref[...] = gwrest_scr[...].astype(gwrest_ref.dtype)
        gbrest_ref[...] = gbrest_scr[...].astype(gbrest_ref.dtype)
        glng_ref[...] = glng_scr[...].astype(glng_ref.dtype)
        glnb_ref[...] = glnb_scr[...].astype(glnb_ref.dtype)


def nmp_edge_mlp_agg_bwd(x, e_tiles, srcg, dstg, emask, einv, w0, b0, wrest,
                         brest, lng, lnb, g_enew, g_agg, *, block_e: int,
                         n_hidden: int, has_ln: bool, precision: str = FP32,
                         interpret: bool = False):
    """Backward Pallas kernel for the fused NMP op.

    ``g_agg`` stays HBM/ANY-resident like ``x``; its rows are DMA-gathered
    per tile. Returns (g_x [N_round, H], g_e [T, BE, H], g_w0, g_b0,
    g_wrest, g_brest, g_lng, g_lnb), all fp32.
    """
    T, BE, H = e_tiles.shape
    Lp = wrest.shape[0]
    n_round = x.shape[0]
    kern = functools.partial(
        _nmp_bwd_kernel, block_e=BE, hidden=H, n_hidden=n_hidden,
        has_ln=has_ln, precision=precision)
    f32 = jnp.float32
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(T,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),              # x
            pl.BlockSpec(memory_space=pltpu.ANY),              # g_agg
            pl.BlockSpec((1, BE, H), lambda t, *_: (t, 0, 0)),
            pl.BlockSpec((1, BE), lambda t, *_: (t, 0)),
            pl.BlockSpec((1, BE), lambda t, *_: (t, 0)),
            pl.BlockSpec((3 * H, H), lambda t, *_: (0, 0)),
            pl.BlockSpec((1, H), lambda t, *_: (0, 0)),
            pl.BlockSpec((Lp, H, H), lambda t, *_: (0, 0, 0)),
            pl.BlockSpec((Lp, H), lambda t, *_: (0, 0)),
            pl.BlockSpec((1, H), lambda t, *_: (0, 0)),
            pl.BlockSpec((1, H), lambda t, *_: (0, 0)),
            pl.BlockSpec((1, BE, H), lambda t, *_: (t, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n_round, H), lambda t, *_: (0, 0)),
            pl.BlockSpec((1, BE, H), lambda t, *_: (t, 0, 0)),
            pl.BlockSpec((3 * H, H), lambda t, *_: (0, 0)),
            pl.BlockSpec((1, H), lambda t, *_: (0, 0)),
            pl.BlockSpec((Lp, H, H), lambda t, *_: (0, 0, 0)),
            pl.BlockSpec((Lp, H), lambda t, *_: (0, 0)),
            pl.BlockSpec((1, H), lambda t, *_: (0, 0)),
            pl.BlockSpec((1, H), lambda t, *_: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, BE, H), x.dtype),                   # xi double-buf
            pltpu.VMEM((2, BE, H), x.dtype),                   # xj double-buf
            pltpu.VMEM((2, BE, H), g_agg.dtype),               # g_agg rows
            pltpu.VMEM((n_round, H), f32),                     # g_x accum
            pltpu.VMEM((3 * H, H), f32),
            pltpu.VMEM((1, H), f32),
            pltpu.VMEM((Lp, H, H), f32),
            pltpu.VMEM((Lp, H), f32),
            pltpu.VMEM((1, H), f32),
            pltpu.VMEM((1, H), f32),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_round, H), f32),
            jax.ShapeDtypeStruct((T, BE, H), f32),
            jax.ShapeDtypeStruct((3 * H, H), f32),
            jax.ShapeDtypeStruct((1, H), f32),
            jax.ShapeDtypeStruct((Lp, H, H), f32),
            jax.ShapeDtypeStruct((Lp, H), f32),
            jax.ShapeDtypeStruct((1, H), f32),
            jax.ShapeDtypeStruct((1, H), f32),
        ],
        interpret=interpret,
    )(srcg, dstg, x, g_agg, e_tiles, emask, einv, w0, b0, wrest, brest,
      lng, lnb, g_enew)


def edge_mlp_agg(feats, dst_local, weights, w1, b1, w2, b2, *,
                 n_node_blocks: int, block_n: int, block_e: int,
                 interpret: bool = False):
    """feats: [NB, NE, BE, Fin] dst-aligned tiles (see ops.dst_aligned_layout);
    dst_local: [NB, NE, BE] in [0, BN); weights: same shape (0 = padding).

    Returns (e_new [NB, NE, BE, H], agg [NB, BN, H]).
    """
    NB, NE, BE, Fin = feats.shape
    H = w2.shape[1]
    kern = functools.partial(_kernel, block_n=block_n, block_e=block_e)
    return pl.pallas_call(
        kern,
        grid=(NB, NE),
        in_specs=[
            pl.BlockSpec((1, 1, BE, Fin), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, BE), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, BE), lambda i, j: (i, j, 0)),
            pl.BlockSpec((Fin, w1.shape[1]), lambda i, j: (0, 0)),
            pl.BlockSpec((w1.shape[1],), lambda i, j: (0,)),
            pl.BlockSpec((w1.shape[1], H), lambda i, j: (0, 0)),
            pl.BlockSpec((H,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, BE, H), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, block_n, H), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((NB, NE, BE, H), feats.dtype),
            jax.ShapeDtypeStruct((NB, block_n, H), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_n, H), jnp.float32)],
        interpret=interpret,
    )(feats, dst_local, weights, w1, b1, w2, b2)
