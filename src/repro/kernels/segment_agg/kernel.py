"""Pallas TPU kernel: fused edge-MLP + destination-aligned segment-sum.

The paper's NMP hot loop is (edge MLP -> 1/d_ij-weighted aggregate). A naive
XLA lowering writes the MLP output to HBM, re-reads it for the scatter-add,
and the scatter itself is serialized. TPU-native design here:

  * host-side layout pass (``ops.dst_aligned_layout``) sorts edges by
    destination and pads so that edge block j of node block i only touches
    dst rows [i*BN, (i+1)*BN): the output BlockSpec becomes a pure function
    of the grid — no data-dependent scatter;
  * grid (n_node_blocks, n_edge_blocks): the MLP (two MXU matmuls) runs on
    the [BE, F] edge tile in VMEM; the tile's contribution is accumulated
    into a [BN, H] VMEM scratch via a one-hot matmul (dst-local one-hot x
    e_new — an MXU op, not a scatter), flushed to HBM on the last edge block;
  * e_new is streamed out tile-by-tile (needed by the next NMP layer).

Mesh graphs have bounded degree, so dst-aligned padding is tight (measured
in tests); power-law graphs pay more — reported by the layout pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(feats_ref, dstl_ref, wgt_ref, w1_ref, b1_ref, w2_ref, b2_ref,
            enew_ref, agg_ref, acc_scr, *, block_n: int, block_e: int):
    ej = pl.program_id(1)
    ne = pl.num_programs(1)

    @pl.when(ej == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    feats = feats_ref[0, 0].astype(jnp.float32)          # [BE, Fin]
    h = jax.lax.dot(feats, w1_ref[...].astype(jnp.float32)) + b1_ref[...]
    h = jax.nn.elu(h)
    e_new = jax.lax.dot(h, w2_ref[...].astype(jnp.float32)) + b2_ref[...]
    enew_ref[0, 0] = e_new.astype(enew_ref.dtype)

    # dst-local one-hot [BE, BN]: aggregation as an MXU matmul, not a scatter
    dstl = dstl_ref[0, 0]                                # [BE] in [0, BN)
    wgt = wgt_ref[0, 0]                                  # [BE] (0 on padding)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (block_e, block_n), 1)
              == dstl[:, None]).astype(jnp.float32) * wgt[:, None]
    acc_scr[...] += jax.lax.dot_general(
        onehot, e_new, (((0,), (0,)), ((), ())))         # [BN, H]

    @pl.when(ej == ne - 1)
    def _flush():
        agg_ref[0] = acc_scr[...].astype(agg_ref.dtype)


def edge_mlp_agg(feats, dst_local, weights, w1, b1, w2, b2, *,
                 n_node_blocks: int, block_n: int, block_e: int,
                 interpret: bool = False):
    """feats: [NB, NE, BE, Fin] dst-aligned tiles (see ops.dst_aligned_layout);
    dst_local: [NB, NE, BE] in [0, BN); weights: same shape (0 = padding).

    Returns (e_new [NB, NE, BE, H], agg [NB, BN, H]).
    """
    NB, NE, BE, Fin = feats.shape
    H = w2.shape[1]
    kern = functools.partial(_kernel, block_n=block_n, block_e=block_e)
    return pl.pallas_call(
        kern,
        grid=(NB, NE),
        in_specs=[
            pl.BlockSpec((1, 1, BE, Fin), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, BE), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, BE), lambda i, j: (i, j, 0)),
            pl.BlockSpec((Fin, w1.shape[1]), lambda i, j: (0, 0)),
            pl.BlockSpec((w1.shape[1],), lambda i, j: (0,)),
            pl.BlockSpec((w1.shape[1], H), lambda i, j: (0, 0)),
            pl.BlockSpec((H,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, BE, H), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, block_n, H), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((NB, NE, BE, H), feats.dtype),
            jax.ShapeDtypeStruct((NB, block_n, H), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_n, H), jnp.float32)],
        interpret=interpret,
    )(feats, dst_local, weights, w1, b1, w2, b2)
