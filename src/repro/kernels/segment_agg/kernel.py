"""Pallas TPU kernel: fused edge-MLP + destination-aligned segment-sum.

The paper's NMP hot loop is (edge MLP -> 1/d_ij-weighted aggregate). A naive
XLA lowering writes the MLP output to HBM, re-reads it for the scatter-add,
and the scatter itself is serialized. TPU-native design here:

  * host-side layout pass (``ops.dst_aligned_layout``) sorts edges by
    destination and pads so that edge block j of node block i only touches
    dst rows [i*BN, (i+1)*BN): the output BlockSpec becomes a pure function
    of the grid — no data-dependent scatter;
  * grid (n_node_blocks, n_edge_blocks): the MLP (two MXU matmuls) runs on
    the [BE, F] edge tile in VMEM; the tile's contribution is accumulated
    into a [BN, H] VMEM scratch via a one-hot matmul (dst-local one-hot x
    e_new — an MXU op, not a scatter), flushed to HBM on the last edge block;
  * e_new is streamed out tile-by-tile (needed by the next NMP layer).

Mesh graphs have bounded degree, so dst-aligned padding is tight (measured
in tests); power-law graphs pay more — reported by the layout pass.

Two generations of kernels live here:

* ``edge_mlp_agg`` — the original forward-only op over pre-gathered
  ``[E, 3H]`` features (microbenchmark / oracle target);
* ``nmp_edge_mlp_agg_fwd`` / ``nmp_edge_mlp_agg_bwd`` — the production pair
  behind ``consistent_mp.nmp_layer(backend="fused")``: node-feature gathers
  are fused into the kernel (src rows via a one-hot matmul against the full
  node array in VMEM, dst rows from the streamed ``[BN, H]`` tile — the
  ``[E, 3H]`` concat never exists in HBM), the full residual edge MLP
  (first layer computed as three H-slices of w0, hidden ``[H, H]`` stack,
  LayerNorm) runs on the tile, and the backward kernel re-derives the tile
  VJP in VMEM (grad-wrt-features = transposed one-hot matmuls, grad-wrt-
  weights accumulated in VMEM scratch across the grid).

VMEM note: both fused kernels hold the full ``[N_round, H]`` node array (and
the backward its gradient) in VMEM — fine for per-rank sub-graph sizes this
repo targets (N_round * H * 4B << 16 MB); shard the graph harder before it
stops fitting.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(feats_ref, dstl_ref, wgt_ref, w1_ref, b1_ref, w2_ref, b2_ref,
            enew_ref, agg_ref, acc_scr, *, block_n: int, block_e: int):
    ej = pl.program_id(1)
    ne = pl.num_programs(1)

    @pl.when(ej == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    feats = feats_ref[0, 0].astype(jnp.float32)          # [BE, Fin]
    h = jax.lax.dot(feats, w1_ref[...].astype(jnp.float32)) + b1_ref[...]
    h = jax.nn.elu(h)
    e_new = jax.lax.dot(h, w2_ref[...].astype(jnp.float32)) + b2_ref[...]
    enew_ref[0, 0] = e_new.astype(enew_ref.dtype)

    # dst-local one-hot [BE, BN]: aggregation as an MXU matmul, not a scatter
    dstl = dstl_ref[0, 0]                                # [BE] in [0, BN)
    wgt = wgt_ref[0, 0]                                  # [BE] (0 on padding)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (block_e, block_n), 1)
              == dstl[:, None]).astype(jnp.float32) * wgt[:, None]
    acc_scr[...] += jax.lax.dot_general(
        onehot, e_new, (((0,), (0,)), ((), ())))         # [BN, H]

    @pl.when(ej == ne - 1)
    def _flush():
        agg_ref[0] = acc_scr[...].astype(agg_ref.dtype)


def _mlp_tail(h, wrest_ref, brest_ref, lng_ref, lnb_ref, *, n_hidden: int,
              has_ln: bool, eps: float = 1e-5):
    """Hidden [H,H] stack + optional LayerNorm, mirroring ``nn.mlp`` exactly:
    ELU after every dense layer except the last, then LN."""
    for l in range(n_hidden):
        h = jax.nn.elu(h)
        h = jax.lax.dot(h, wrest_ref[l].astype(jnp.float32)) + \
            brest_ref[l].astype(jnp.float32)
    if has_ln:
        mu = jnp.mean(h, axis=-1, keepdims=True)
        var = jnp.var(h, axis=-1, keepdims=True)
        h = (h - mu) * jax.lax.rsqrt(var + eps)
        h = h * lng_ref[0].astype(jnp.float32) + lnb_ref[0].astype(jnp.float32)
    return h


def _nmp_fwd_kernel(xfull_ref, xdst_ref, e_ref, srcg_ref, dstl_ref, emask_ref,
                    einv_ref, w0_ref, b0_ref, wrest_ref, brest_ref, lng_ref,
                    lnb_ref, enew_ref, agg_ref, acc_scr, *, block_n: int,
                    block_e: int, hidden: int, n_hidden: int, has_ln: bool):
    """Fused Eq. 4a+4b tile: gather src/dst node rows (one-hot MXU matmuls),
    run the full residual edge MLP (incl. LayerNorm), mask, and accumulate the
    1/d_ij-weighted dst-aligned aggregate in VMEM scratch."""
    ej = pl.program_id(1)
    ne = pl.num_programs(1)

    @pl.when(ej == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = xfull_ref[...].astype(jnp.float32)               # [N_round, H]
    xd = xdst_ref[...].astype(jnp.float32)               # [BN, H]
    et = e_ref[0, 0].astype(jnp.float32)                 # [BE, H]
    srcg = srcg_ref[0, 0]                                # [BE] in [0, N_round)
    dstl = dstl_ref[0, 0]                                # [BE] in [0, BN)
    mask = emask_ref[0, 0]                               # [BE] 1/0
    wgt = einv_ref[0, 0]                                 # [BE] 1/d_ij (0 pad)

    # src gather: one-hot [BE, N_round] x x — MXU matmul, no HBM gather
    oh_src = (jax.lax.broadcasted_iota(jnp.int32, (block_e, x.shape[0]), 1)
              == srcg[:, None]).astype(jnp.float32)
    xi = jax.lax.dot(oh_src, x)                          # [BE, H]
    # dst gather stays inside the streamed [BN, H] node tile
    oh_dst = (jax.lax.broadcasted_iota(jnp.int32, (block_e, block_n), 1)
              == dstl[:, None]).astype(jnp.float32)
    xj = jax.lax.dot(oh_dst, xd)                         # [BE, H]

    # first dense layer on the *virtual* concat [xi ++ xj ++ e]: three
    # H-slices of w0 — the [BE, 3H] tensor is never materialized
    w0 = w0_ref[...].astype(jnp.float32)                 # [3H, H]
    h = (jax.lax.dot(xi, w0[:hidden]) + jax.lax.dot(xj, w0[hidden:2 * hidden])
         + jax.lax.dot(et, w0[2 * hidden:]) + b0_ref[0].astype(jnp.float32))
    h = _mlp_tail(h, wrest_ref, brest_ref, lng_ref, lnb_ref,
                  n_hidden=n_hidden, has_ln=has_ln)

    e_new = (et + h) * mask[:, None]                     # residual + edge mask
    enew_ref[0, 0] = e_new.astype(enew_ref.dtype)

    acc_scr[...] += jax.lax.dot_general(
        oh_dst * wgt[:, None], e_new, (((0,), (0,)), ((), ())))   # [BN, H]

    @pl.when(ej == ne - 1)
    def _flush():
        agg_ref[0] = acc_scr[...].astype(agg_ref.dtype)


def nmp_edge_mlp_agg_fwd(x, e_tiles, srcg, dstl, emask, einv, w0, b0, wrest,
                         brest, lng, lnb, *, block_n: int, block_e: int,
                         n_hidden: int, has_ln: bool, interpret: bool = False):
    """Fused NMP forward. ``x``: [N_round, H] node features (N_round = NB*BN);
    ``e_tiles``: [NB, NE, BE, H] dst-aligned edge tiles; ``srcg``/``dstl``:
    global-src / block-local-dst ids per slot; ``emask``/``einv``: edge mask
    and 1/d_ij (both 0 on padding slots).

    Returns (e_new [NB, NE, BE, H], agg [NB, BN, H] fp32).
    """
    NB, NE, BE, H = e_tiles.shape
    Lp = wrest.shape[0]
    kern = functools.partial(
        _nmp_fwd_kernel, block_n=block_n, block_e=block_e, hidden=H,
        n_hidden=n_hidden, has_ln=has_ln)
    return pl.pallas_call(
        kern,
        grid=(NB, NE),
        in_specs=[
            pl.BlockSpec((x.shape[0], H), lambda i, j: (0, 0)),
            pl.BlockSpec((block_n, H), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1, BE, H), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, BE), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, BE), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, BE), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, BE), lambda i, j: (i, j, 0)),
            pl.BlockSpec((3 * H, H), lambda i, j: (0, 0)),
            pl.BlockSpec((1, H), lambda i, j: (0, 0)),
            pl.BlockSpec((Lp, H, H), lambda i, j: (0, 0, 0)),
            pl.BlockSpec((Lp, H), lambda i, j: (0, 0)),
            pl.BlockSpec((1, H), lambda i, j: (0, 0)),
            pl.BlockSpec((1, H), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, BE, H), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, block_n, H), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((NB, NE, BE, H), e_tiles.dtype),
            jax.ShapeDtypeStruct((NB, block_n, H), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_n, H), jnp.float32)],
        interpret=interpret,
    )(x, x, e_tiles, srcg, dstl, emask, einv, w0, b0, wrest, brest, lng, lnb)


def _nmp_bwd_kernel(xfull_ref, e_ref, srcg_ref, dstl_ref, emask_ref, einv_ref,
                    w0_ref, b0_ref, wrest_ref, brest_ref, lng_ref, lnb_ref,
                    genew_ref, gagg_ref,
                    gx_ref, ge_ref, gw0_ref, gb0_ref, gwrest_ref, gbrest_ref,
                    glng_ref, glnb_ref,
                    gx_scr, gw0_scr, gb0_scr, gwrest_scr, gbrest_scr, glng_scr,
                    glnb_scr, *, block_n: int, block_e: int, hidden: int,
                    n_hidden: int, has_ln: bool):
    """Backward of the fused NMP tile: per-tile VJP of the recomputed forward.

    grad-wrt-node-features flows through the transposed one-hot matmuls and is
    accumulated over the whole grid in a VMEM scratch; grad-wrt-weights
    accumulates per-tile ``feats^T @ g`` (inside the VJP) in VMEM scratch.
    Both are flushed to HBM on the final grid step.
    """
    ei = pl.program_id(0)
    ej = pl.program_id(1)
    last = jnp.logical_and(ei == pl.num_programs(0) - 1,
                           ej == pl.num_programs(1) - 1)

    @pl.when(jnp.logical_and(ei == 0, ej == 0))
    def _init():
        gx_scr[...] = jnp.zeros_like(gx_scr)
        gw0_scr[...] = jnp.zeros_like(gw0_scr)
        gb0_scr[...] = jnp.zeros_like(gb0_scr)
        gwrest_scr[...] = jnp.zeros_like(gwrest_scr)
        gbrest_scr[...] = jnp.zeros_like(gbrest_scr)
        glng_scr[...] = jnp.zeros_like(glng_scr)
        glnb_scr[...] = jnp.zeros_like(glnb_scr)

    n_round = gx_scr.shape[0]
    srcg = srcg_ref[0, 0]
    dstl = dstl_ref[0, 0]
    dstg = dstl + ei * block_n                            # global dst ids
    mask = emask_ref[0, 0]
    wgt = einv_ref[0, 0]
    oh_src = (jax.lax.broadcasted_iota(jnp.int32, (block_e, n_round), 1)
              == srcg[:, None]).astype(jnp.float32)
    oh_dstg = (jax.lax.broadcasted_iota(jnp.int32, (block_e, n_round), 1)
               == dstg[:, None]).astype(jnp.float32)
    oh_dstl = (jax.lax.broadcasted_iota(jnp.int32, (block_e, block_n), 1)
               == dstl[:, None]).astype(jnp.float32)

    def tile_fwd(x, et, w0, b0, wrest, brest, lng, lnb):
        # identical arithmetic to _nmp_fwd_kernel (dst gather routed through
        # the full x so its cotangent lands on the right global rows)
        xi = jax.lax.dot(oh_src, x)
        xj = jax.lax.dot(oh_dstg, x)
        h = (jax.lax.dot(xi, w0[:hidden]) + jax.lax.dot(xj, w0[hidden:2 * hidden])
             + jax.lax.dot(et, w0[2 * hidden:]) + b0[0])
        for l in range(n_hidden):
            h = jax.nn.elu(h)
            h = jax.lax.dot(h, wrest[l]) + brest[l]
        if has_ln:
            mu = jnp.mean(h, axis=-1, keepdims=True)
            var = jnp.var(h, axis=-1, keepdims=True)
            h = (h - mu) * jax.lax.rsqrt(var + 1e-5) * lng[0] + lnb[0]
        e_new = (et + h) * mask[:, None]
        agg_c = jax.lax.dot_general(oh_dstl * wgt[:, None], e_new,
                                    (((0,), (0,)), ((), ())))
        return e_new, agg_c

    args = (xfull_ref[...].astype(jnp.float32),
            e_ref[0, 0].astype(jnp.float32),
            w0_ref[...].astype(jnp.float32),
            b0_ref[...].astype(jnp.float32),
            wrest_ref[...].astype(jnp.float32),
            brest_ref[...].astype(jnp.float32),
            lng_ref[...].astype(jnp.float32),
            lnb_ref[...].astype(jnp.float32))
    _, vjp = jax.vjp(tile_fwd, *args)
    gx, ge, gw0, gb0, gwrest, gbrest, glng, glnb = vjp(
        (genew_ref[0, 0].astype(jnp.float32),
         gagg_ref[0].astype(jnp.float32)))

    ge_ref[0, 0] = ge.astype(ge_ref.dtype)
    gx_scr[...] += gx
    gw0_scr[...] += gw0
    gb0_scr[...] += gb0
    gwrest_scr[...] += gwrest
    gbrest_scr[...] += gbrest
    glng_scr[...] += glng
    glnb_scr[...] += glnb

    @pl.when(last)
    def _flush():
        gx_ref[...] = gx_scr[...].astype(gx_ref.dtype)
        gw0_ref[...] = gw0_scr[...].astype(gw0_ref.dtype)
        gb0_ref[...] = gb0_scr[...].astype(gb0_ref.dtype)
        gwrest_ref[...] = gwrest_scr[...].astype(gwrest_ref.dtype)
        gbrest_ref[...] = gbrest_scr[...].astype(gbrest_ref.dtype)
        glng_ref[...] = glng_scr[...].astype(glng_ref.dtype)
        glnb_ref[...] = glnb_scr[...].astype(glnb_ref.dtype)


def nmp_edge_mlp_agg_bwd(x, e_tiles, srcg, dstl, emask, einv, w0, b0, wrest,
                         brest, lng, lnb, g_enew, g_agg, *, block_n: int,
                         block_e: int, n_hidden: int, has_ln: bool,
                         interpret: bool = False):
    """Backward Pallas kernel for the fused NMP op.

    Returns (g_x [N_round, H], g_e [NB, NE, BE, H], g_w0, g_b0, g_wrest,
    g_brest, g_lng, g_lnb), all fp32.
    """
    NB, NE, BE, H = e_tiles.shape
    Lp = wrest.shape[0]
    N = x.shape[0]
    kern = functools.partial(
        _nmp_bwd_kernel, block_n=block_n, block_e=block_e, hidden=H,
        n_hidden=n_hidden, has_ln=has_ln)
    f32 = jnp.float32
    return pl.pallas_call(
        kern,
        grid=(NB, NE),
        in_specs=[
            pl.BlockSpec((N, H), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1, BE, H), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, BE), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, BE), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, BE), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, BE), lambda i, j: (i, j, 0)),
            pl.BlockSpec((3 * H, H), lambda i, j: (0, 0)),
            pl.BlockSpec((1, H), lambda i, j: (0, 0)),
            pl.BlockSpec((Lp, H, H), lambda i, j: (0, 0, 0)),
            pl.BlockSpec((Lp, H), lambda i, j: (0, 0)),
            pl.BlockSpec((1, H), lambda i, j: (0, 0)),
            pl.BlockSpec((1, H), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1, BE, H), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, block_n, H), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((N, H), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1, BE, H), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((3 * H, H), lambda i, j: (0, 0)),
            pl.BlockSpec((1, H), lambda i, j: (0, 0)),
            pl.BlockSpec((Lp, H, H), lambda i, j: (0, 0, 0)),
            pl.BlockSpec((Lp, H), lambda i, j: (0, 0)),
            pl.BlockSpec((1, H), lambda i, j: (0, 0)),
            pl.BlockSpec((1, H), lambda i, j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, H), f32),
            jax.ShapeDtypeStruct((NB, NE, BE, H), f32),
            jax.ShapeDtypeStruct((3 * H, H), f32),
            jax.ShapeDtypeStruct((1, H), f32),
            jax.ShapeDtypeStruct((Lp, H, H), f32),
            jax.ShapeDtypeStruct((Lp, H), f32),
            jax.ShapeDtypeStruct((1, H), f32),
            jax.ShapeDtypeStruct((1, H), f32),
        ],
        scratch_shapes=[
            pltpu.VMEM((N, H), f32),
            pltpu.VMEM((3 * H, H), f32),
            pltpu.VMEM((1, H), f32),
            pltpu.VMEM((Lp, H, H), f32),
            pltpu.VMEM((Lp, H), f32),
            pltpu.VMEM((1, H), f32),
            pltpu.VMEM((1, H), f32),
        ],
        interpret=interpret,
    )(x, e_tiles, srcg, dstl, emask, einv, w0, b0, wrest, brest, lng, lnb,
      g_enew, g_agg)


def edge_mlp_agg(feats, dst_local, weights, w1, b1, w2, b2, *,
                 n_node_blocks: int, block_n: int, block_e: int,
                 interpret: bool = False):
    """feats: [NB, NE, BE, Fin] dst-aligned tiles (see ops.dst_aligned_layout);
    dst_local: [NB, NE, BE] in [0, BN); weights: same shape (0 = padding).

    Returns (e_new [NB, NE, BE, H], agg [NB, BN, H]).
    """
    NB, NE, BE, Fin = feats.shape
    H = w2.shape[1]
    kern = functools.partial(_kernel, block_n=block_n, block_e=block_e)
    return pl.pallas_call(
        kern,
        grid=(NB, NE),
        in_specs=[
            pl.BlockSpec((1, 1, BE, Fin), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, BE), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, BE), lambda i, j: (i, j, 0)),
            pl.BlockSpec((Fin, w1.shape[1]), lambda i, j: (0, 0)),
            pl.BlockSpec((w1.shape[1],), lambda i, j: (0,)),
            pl.BlockSpec((w1.shape[1], H), lambda i, j: (0, 0)),
            pl.BlockSpec((H,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, BE, H), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, block_n, H), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((NB, NE, BE, H), feats.dtype),
            jax.ShapeDtypeStruct((NB, block_n, H), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_n, H), jnp.float32)],
        interpret=interpret,
    )(feats, dst_local, weights, w1, b1, w2, b2)
