"""Jit'd public wrapper for the flash attention kernel (GQA layout glue)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd


@functools.partial(jax.jit, static_argnames=("scale", "causal", "window",
                                             "softcap", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, scale: float, causal: bool = True,
                    window: int = 0, softcap: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: [B, Sq, Hq, D]; k, v: [B, Skv, Hkv, D] -> [B, Sq, Hq, D].

    GQA is handled by repeating KV head-wise into the fused (B*H) grid axis.
    """
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, D)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * Hq, -1, D)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * Hq, -1, D)
    out = flash_attention_fwd(qf, kf, vf, scale=scale, causal=causal,
                              window=window, softcap=softcap,
                              block_q=block_q, block_k=block_k,
                              interpret=interpret)
    return out.reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3)
