"""Pallas TPU flash attention (forward) — VMEM-tiled online softmax.

Grid: (batch*heads, n_q_blocks, n_kv_blocks), sequential on TPU. Running
max/denominator live in VMEM scratch; the output block is accumulated
un-normalized and rescaled once at the last kv step. Causal block pruning:
kv blocks strictly above the diagonal skip the matmul entirely (the 2x
attention-FLOP saving the jnp path can't express — see EXPERIMENTS §Perf).

Block shapes default to (128, 128) — MXU-aligned (128x128 systolic array),
and the working set  q(128xD) + k,v(128xD) + scores(128x128) + out(128xD)
stays well under the ~16 MB/core VMEM for D <= 256.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                      scale: float, causal: bool, window: int,
                      softcap: float | None, block_q: int, block_k: int,
                      seq_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal pruning: skip blocks entirely above the diagonal
    q_start = qi * block_q
    k_start = ki * block_k
    run = (k_start <= q_start + block_q - 1) if causal else True

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                   # [bq, D]
        k = k_ref[0].astype(jnp.float32)                   # [bk, D]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_kv
        if causal:
            mask &= qpos >= kpos
        if window and window > 0:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(p, v)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, scale: float, causal: bool = True,
                        window: int = 0, softcap: float | None = None,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False):
    """q: [BH, Sq, D]; k, v: [BH, Skv, D] -> [BH, Sq, D]."""
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    nq = -(-Sq // bq)
    nk = -(-Skv // bk)
    q_pad = jnp.pad(q, ((0, 0), (0, nq * bq - Sq), (0, 0)))
    k_pad = jnp.pad(k, ((0, 0), (0, nk * bk - Skv), (0, 0)))
    v_pad = jnp.pad(v, ((0, 0), (0, nk * bk - Skv), (0, 0)))

    kern = functools.partial(
        _flash_fwd_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=bq, block_k=bk, seq_kv=Skv)

    out = pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, nq * bq, D), q.dtype),
        scratch_shapes=[
            # running max / denominator / un-normalized accumulator (VMEM)
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q_pad, k_pad, v_pad)
    return out[:, :Sq]
