"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, scale: float, causal: bool = True,
                  window: int = 0, softcap: float | None = None):
    """q: [B, H, Sq, D]; k, v: [B, H, Skv, D] -> [B, H, Sq, D] (fp32 math)."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    Sq, Skv = q.shape[2], k.shape[2]
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qpos >= kpos
    if window and window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(v.dtype)
