"""Pure-jnp oracle for the embedding-bag kernel."""
from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_ref(table, idx):
    """table [V, D]; idx [B, H] -> sum-pooled bags [B, D]."""
    return jnp.take(table, idx, axis=0).sum(axis=1)
