"""Jit'd wrapper for the embedding-bag kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.embedding_bag.kernel import embedding_bag as _kernel_call


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag(table, idx, *, interpret: bool = False):
    return _kernel_call(table, idx, interpret=interpret)
