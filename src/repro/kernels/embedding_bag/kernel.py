"""Pallas TPU embedding-bag: scalar-prefetched dynamic row gather + pooling.

DLRM's hot path is a ragged gather over a >=GB table followed by a bag-sum —
on TPU the idiomatic implementation is ``PrefetchScalarGridSpec``: the bag
indices are prefetched as scalars, and the *table BlockSpec index_map reads
them* to DMA exactly the needed row-block per grid step (HBM->VMEM), so
arbitrary rows stream through VMEM without materializing a gathered copy.

Grid: (n_bags, bag_size); row blocks of (1, D); bag accumulation in VMEM
scratch, flushed on the last bag element.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, row_ref, out_ref, acc_scr):
    h = pl.program_id(1)
    nh = pl.num_programs(1)

    @pl.when(h == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += row_ref[0].astype(jnp.float32)

    @pl.when(h == nh - 1)
    def _flush():
        out_ref[0] = acc_scr[...].astype(out_ref.dtype)


def embedding_bag(table: jnp.ndarray, idx: jnp.ndarray, *,
                  interpret: bool = False) -> jnp.ndarray:
    """table [V, D]; idx [B, H] int32 -> [B, D] sum-pooled bags."""
    B, H = idx.shape
    V, D = table.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H),
        in_specs=[
            # the table row block to fetch is chosen by the prefetched indices
            pl.BlockSpec((1, D), lambda b, h, idx_pref: (idx_pref[b, h], 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda b, h, idx_pref: (b, 0)),
        scratch_shapes=[pltpu.VMEM((D,), jnp.float32)],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )(idx, table)
