"""Sharded .npz checkpointing with manifest, checksums, async save, and
elastic restore.

No orbax offline — built on numpy:
  * each save writes ``step_<N>/shard_<host>.npz`` (one file per host with its
    addressable array shards; on this single-host container that is one file)
    plus ``manifest.json`` (step, flat key list, shapes/dtypes, per-array
    CRC32 checksums, caller ``extra`` — the training loop stores its mesh
    fingerprint and loss-history tail there) and a terminal ``COMMIT``
    marker — a crash mid-save can never be mistaken for a complete
    checkpoint;
  * ``restore`` loads a *committed* step and validates it BEFORE
    unflattening: every key's shape/dtype against the manifest and the
    template, every array's checksum against the manifest — a corrupted or
    truncated shard raises :class:`CheckpointCorruption` naming the first
    bad key instead of failing three layers down in an unflatten/broadcast;
  * ``restore_with_fallback`` walks committed steps newest-first and falls
    back past corrupted ones — the recovery path a resilient trainer takes
    when the newest checkpoint was damaged after commit;
  * re-sharding is elastic: arrays are saved unsharded per host, so a
    checkpoint written on one mesh restores onto another via the
    ``shardings`` pytree (a device_put per leaf);
  * ``AsyncCheckpointer`` overlaps serialization with training (thread);
    save errors surface on the next ``wait()``/``save()``.

Fault injection for tests lives behind :func:`set_fault_hook`: the hook is
called at the two stages where a real crash corrupts state ("arrays_written"
— shard on disk, no manifest/COMMIT; "pre_commit" — everything but COMMIT)
and may truncate files or raise (see
``repro.runtime.fault_tolerance.FaultPlan``).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
import zlib
from pathlib import Path
from typing import Any, Callable, Optional

import numpy as np
import jax


class CheckpointError(RuntimeError):
    """Base class for checkpoint failures."""


class CheckpointCorruption(CheckpointError):
    """A committed checkpoint's on-disk bytes disagree with its manifest
    (truncated/bit-flipped shard, unreadable npz, checksum mismatch).
    Fallback-eligible: ``restore_with_fallback`` skips to the previous
    committed step."""


_STEP_RE = re.compile(r"^step_(\d+)$")

# test injection point: callable(stage, step, step_dir) invoked by ``save``
# at "arrays_written" (shard npz on disk) and "pre_commit" (manifest written,
# COMMIT not yet) — may mutate files and/or raise to emulate a crash
_fault_hook: Optional[Callable[[str, int, Path], None]] = None


def set_fault_hook(fn: Optional[Callable[[str, int, Path], None]]):
    """Install a save-path fault-injection hook; returns the previous one."""
    global _fault_hook
    prev, _fault_hook = _fault_hook, fn
    return prev


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[key] = leaf
    return out, treedef


def _checksum(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def save(ckpt_dir: str | Path, step: int, tree: Any, extra: Optional[dict] = None):
    """Synchronous checkpoint save with commit marker."""
    ckpt_dir = Path(ckpt_dir)
    step_dir = ckpt_dir / f"step_{step:010d}"
    tmp = step_dir.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat, _ = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(tmp / "shard_0.npz", **{k.replace("/", "__"): v for k, v in arrays.items()})
    if _fault_hook is not None:
        _fault_hook("arrays_written", step, tmp)
    manifest = dict(
        step=step,
        keys=sorted(arrays),
        shapes={k: list(v.shape) for k, v in arrays.items()},
        dtypes={k: str(v.dtype) for k, v in arrays.items()},
        checksums={k: _checksum(v) for k, v in arrays.items()},
        time=time.time(),
        extra=extra or {},
    )
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if _fault_hook is not None:
        _fault_hook("pre_commit", step, tmp)
    (tmp / "COMMIT").write_text("ok")
    if step_dir.exists():
        shutil.rmtree(step_dir)
    os.rename(tmp, step_dir)
    return step_dir


def committed_steps(ckpt_dir: str | Path) -> list[int]:
    """Ascending committed step numbers.  Robust to leftover ``*.tmp`` dirs
    and other debris a mid-save crash leaves behind (those never carry a
    COMMIT and never match the step name pattern)."""
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    steps = []
    for d in ckpt_dir.iterdir():
        m = _STEP_RE.match(d.name)
        if m and (d / "COMMIT").exists():
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    steps = committed_steps(ckpt_dir)
    return steps[-1] if steps else None


def peek_manifest(ckpt_dir: str | Path, step: Optional[int] = None) -> Optional[dict]:
    """Read a committed step's manifest without touching the arrays (cheap
    pre-restore inspection: mesh fingerprint, resolved schedule, step).
    Returns None when there is no committed checkpoint."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        return None
    path = Path(ckpt_dir) / f"step_{step:010d}" / "manifest.json"
    try:
        return json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointCorruption(
            f"manifest unreadable for committed step {step} under "
            f"{ckpt_dir}: {e}") from e


def restore(ckpt_dir: str | Path, tree_like: Any, step: Optional[int] = None,
            shardings: Any = None):
    """Restore into the structure of ``tree_like`` (values replaced).

    Validation happens BEFORE any unflatten: the template's flat keys must
    match the manifest's (missing/unexpected keys are named), each template
    leaf's shape/dtype must match what the manifest recorded (a mismatch
    names the key — usually a model-config drift between save and resume),
    and each loaded array must match its manifest checksum (a mismatch
    raises :class:`CheckpointCorruption` naming the key).

    ``shardings``: optional pytree of NamedSharding for elastic placement on
    the current mesh — how a checkpoint written on R ranks lands on R'.
    """
    ckpt_dir = Path(ckpt_dir)
    committed = committed_steps(ckpt_dir)
    step = step if step is not None else (committed[-1] if committed else None)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
    if step not in committed:
        raise FileNotFoundError(
            f"step {step} has no committed checkpoint under {ckpt_dir} "
            f"(committed: {committed})")
    step_dir = ckpt_dir / f"step_{step:010d}"
    manifest = peek_manifest(ckpt_dir, step)
    flat, treedef = _flatten(tree_like)

    m_keys = set(manifest["keys"])
    t_keys = set(flat)
    if m_keys != t_keys:
        missing = sorted(m_keys - t_keys)
        unexpected = sorted(t_keys - m_keys)
        raise ValueError(
            f"checkpoint step {step} does not match the restore template: "
            f"keys only in checkpoint: {missing[:5]}; keys only in template: "
            f"{unexpected[:5]} — was the model/optimizer config changed "
            "between save and resume?")
    for key, leaf in flat.items():
        want_shape = tuple(manifest["shapes"][key])
        want_dtype = manifest["dtypes"][key]
        have = np.asarray(leaf)
        if tuple(have.shape) != want_shape or str(have.dtype) != want_dtype:
            raise ValueError(
                f"checkpoint step {step} key {key!r} has shape "
                f"{want_shape}/{want_dtype} but the restore template has "
                f"{tuple(have.shape)}/{have.dtype} — the checkpoint was "
                "written with a different model/optimizer configuration")

    try:
        data = np.load(step_dir / "shard_0.npz")
    except Exception as e:
        raise CheckpointCorruption(
            f"shard unreadable for committed step {step} under {ckpt_dir}: "
            f"{e}") from e
    checksums = manifest.get("checksums", {})
    leaves = []
    for key in flat:
        try:
            arr = data[key.replace("/", "__")]
        except Exception as e:
            raise CheckpointCorruption(
                f"step {step} key {key!r} unreadable from shard "
                f"(truncated/corrupted npz): {e}") from e
        if tuple(arr.shape) != tuple(manifest["shapes"][key]):
            raise CheckpointCorruption(
                f"step {step} key {key!r} on-disk shape {tuple(arr.shape)} "
                f"disagrees with its manifest {tuple(manifest['shapes'][key])}")
        if key in checksums and _checksum(arr) != checksums[key]:
            raise CheckpointCorruption(
                f"step {step} key {key!r} failed its checksum — the shard "
                "was corrupted after commit; restore_with_fallback skips to "
                "the previous committed step")
        if shardings is not None:
            shard_flat = _flatten(shardings)[0]
            if key in shard_flat:
                arr = jax.device_put(arr, shard_flat[key])
        leaves.append(arr)
    # order of _flatten matches tree_flatten order
    vals = jax.tree_util.tree_unflatten(treedef, leaves)
    return vals, manifest


def restore_partial(ckpt_dir: str | Path, tree_like: Any, prefix: str,
                    step: Optional[int] = None, shardings: Any = None):
    """Restore ONLY the subtree saved under ``prefix`` (e.g. ``"params"``)
    into the structure of ``tree_like``, ignoring every other key in the
    checkpoint.

    This is how a serving process loads model weights out of a full
    training checkpoint (``{params, opt, rng}``) without reconstructing
    optimizer state it will never use: the template is just the params
    pytree.  The selected subset gets the same validation as
    :func:`restore` — exact key set (missing/unexpected keys named),
    shapes/dtypes against the manifest, per-array CRC32 checksums (a
    mismatch raises :class:`CheckpointCorruption`).  A ``prefix`` absent
    from the checkpoint raises ValueError naming the prefixes that DO
    exist.
    """
    ckpt_dir = Path(ckpt_dir)
    committed = committed_steps(ckpt_dir)
    step = step if step is not None else (committed[-1] if committed else None)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
    if step not in committed:
        raise FileNotFoundError(
            f"step {step} has no committed checkpoint under {ckpt_dir} "
            f"(committed: {committed})")
    step_dir = ckpt_dir / f"step_{step:010d}"
    manifest = peek_manifest(ckpt_dir, step)

    # map sub-key (relative to prefix) -> full checkpoint key; a key equal
    # to the prefix itself means the subtree is a single bare leaf, whose
    # flattened template key is ""
    sub = {}
    for k in manifest["keys"]:
        if k == prefix:
            sub[""] = k
        elif k.startswith(prefix + "/"):
            sub[k[len(prefix) + 1:]] = k
    if not sub:
        avail = sorted({k.split("/", 1)[0] for k in manifest["keys"]})
        raise ValueError(
            f"checkpoint step {step} has no keys under prefix {prefix!r} — "
            f"available top-level prefixes: {avail}")

    flat, treedef = _flatten(tree_like)
    if set(sub) != set(flat):
        missing = sorted(set(sub) - set(flat))
        unexpected = sorted(set(flat) - set(sub))
        raise ValueError(
            f"checkpoint step {step} subtree {prefix!r} does not match the "
            f"restore template: keys only in checkpoint: {missing[:5]}; keys "
            f"only in template: {unexpected[:5]} — was the model config "
            "changed between save and restore?")
    for key, leaf in flat.items():
        full = sub[key]
        want_shape = tuple(manifest["shapes"][full])
        want_dtype = manifest["dtypes"][full]
        have = np.asarray(leaf)
        if tuple(have.shape) != want_shape or str(have.dtype) != want_dtype:
            raise ValueError(
                f"checkpoint step {step} key {full!r} has shape "
                f"{want_shape}/{want_dtype} but the restore template has "
                f"{tuple(have.shape)}/{have.dtype} — the checkpoint was "
                "written with a different model configuration")

    try:
        data = np.load(step_dir / "shard_0.npz")
    except Exception as e:
        raise CheckpointCorruption(
            f"shard unreadable for committed step {step} under {ckpt_dir}: "
            f"{e}") from e
    checksums = manifest.get("checksums", {})
    shard_flat = _flatten(shardings)[0] if shardings is not None else {}
    leaves = []
    for key in flat:
        full = sub[key]
        try:
            arr = data[full.replace("/", "__")]
        except Exception as e:
            raise CheckpointCorruption(
                f"step {step} key {full!r} unreadable from shard "
                f"(truncated/corrupted npz): {e}") from e
        if tuple(arr.shape) != tuple(manifest["shapes"][full]):
            raise CheckpointCorruption(
                f"step {step} key {full!r} on-disk shape {tuple(arr.shape)} "
                f"disagrees with its manifest "
                f"{tuple(manifest['shapes'][full])}")
        if full in checksums and _checksum(arr) != checksums[full]:
            raise CheckpointCorruption(
                f"step {step} key {full!r} failed its checksum — the shard "
                "was corrupted after commit")
        if key in shard_flat:
            arr = jax.device_put(arr, shard_flat[key])
        leaves.append(arr)
    vals = jax.tree_util.tree_unflatten(treedef, leaves)
    return vals, manifest


def restore_with_fallback(ckpt_dir: str | Path, tree_like: Any,
                          shardings: Any = None):
    """Restore the newest committed step that validates, falling back past
    corrupted ones (checksum failures, truncated shards, unreadable
    manifests).  Template mismatches (wrong shapes/keys — a config problem,
    not a disk problem) propagate immediately.  Raises FileNotFoundError
    when no committed step survives validation."""
    steps = committed_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
    last_err: Optional[BaseException] = None
    for step in reversed(steps):
        try:
            return restore(ckpt_dir, tree_like, step=step, shardings=shardings)
        except CheckpointCorruption as e:
            print(f"[ckpt] step {step} corrupted, falling back: {e}")
            last_err = e
    raise FileNotFoundError(
        f"no valid committed checkpoint under {ckpt_dir} "
        f"({len(steps)} committed steps, all corrupted; last error: "
        f"{last_err})")


def prune(ckpt_dir: str | Path, keep: int = 3):
    """Delete old committed steps, keeping the newest ``keep``.

    The newest committed step is NEVER deleted, even with ``keep <= 0``
    (a misconfigured retention policy must not destroy the only recovery
    point)."""
    keep = max(int(keep), 1)
    steps = committed_steps(ckpt_dir)
    ckpt_dir = Path(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s:010d}", ignore_errors=True)


class AsyncCheckpointer:
    """Background-thread checkpointing: snapshot to host, save off-thread.

    A failed async save is surfaced as the raised exception on the next
    ``wait()`` (or the implicit wait inside the next ``save()``) — the
    resilient training driver treats it like any other step failure and
    restores from the previous committed step."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        self.dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot before async

        def work():
            try:
                save(self.dir, step, host_tree, extra)
                prune(self.dir, self.keep)
            except BaseException as e:   # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
