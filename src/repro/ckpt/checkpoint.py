"""Sharded .npz checkpointing with manifest, async save, and elastic restore.

No orbax offline — built on numpy:
  * each save writes ``step_<N>/shard_<host>.npz`` (one file per host with its
    addressable array shards; on this single-host container that is one file)
    plus ``manifest.json`` (step, flat key list, shapes/dtypes, mesh shape,
    config fingerprint) and a terminal ``COMMIT`` marker — a crash mid-save
    can never be mistaken for a complete checkpoint;
  * ``restore`` loads the latest *committed* step, re-shards onto the current
    mesh (elastic: a checkpoint written on one mesh restores onto another —
    arrays are saved unsharded per host here, resharding is a device_put);
  * ``AsyncCheckpointer`` overlaps serialization with training (thread).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import numpy as np
import jax


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[key] = leaf
    return out, treedef


def save(ckpt_dir: str | Path, step: int, tree: Any, extra: Optional[dict] = None):
    """Synchronous checkpoint save with commit marker."""
    ckpt_dir = Path(ckpt_dir)
    step_dir = ckpt_dir / f"step_{step:010d}"
    tmp = step_dir.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat, _ = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(tmp / "shard_0.npz", **{k.replace("/", "__"): v for k, v in arrays.items()})
    manifest = dict(
        step=step,
        keys=sorted(arrays),
        shapes={k: list(v.shape) for k, v in arrays.items()},
        dtypes={k: str(v.dtype) for k, v in arrays.items()},
        time=time.time(),
        extra=extra or {},
    )
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    (tmp / "COMMIT").write_text("ok")
    if step_dir.exists():
        shutil.rmtree(step_dir)
    os.rename(tmp, step_dir)
    return step_dir


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for d in ckpt_dir.iterdir():
        if d.name.startswith("step_") and (d / "COMMIT").exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, tree_like: Any, step: Optional[int] = None,
            shardings: Any = None):
    """Restore into the structure of ``tree_like`` (values replaced).

    ``shardings``: optional pytree of NamedSharding for elastic placement on
    the current mesh.
    """
    ckpt_dir = Path(ckpt_dir)
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
    step_dir = ckpt_dir / f"step_{step:010d}"
    data = np.load(step_dir / "shard_0.npz")
    flat, treedef = _flatten(tree_like)
    shard_flat = _flatten(shardings)[0] if shardings is not None else {}
    leaves = []
    for key in flat:
        arr = data[key.replace("/", "__")]
        if key in shard_flat:
            arr = jax.device_put(arr, shard_flat[key])
        leaves.append(arr)
    # order of _flatten matches tree_flatten order
    vals = jax.tree_util.tree_unflatten(treedef, leaves)
    manifest = json.loads((step_dir / "manifest.json").read_text())
    return vals, manifest


def prune(ckpt_dir: str | Path, keep: int = 3):
    ckpt_dir = Path(ckpt_dir)
    steps = sorted(
        int(d.name.split("_")[1]) for d in ckpt_dir.iterdir()
        if d.name.startswith("step_") and (d / "COMMIT").exists())
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s:010d}", ignore_errors=True)


class AsyncCheckpointer:
    """Background-thread checkpointing: snapshot to host, save off-thread."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        self.dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot before async

        def work():
            try:
                save(self.dir, step, host_tree, extra)
                prune(self.dir, self.keep)
            except BaseException as e:   # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
