import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax-importing module: jax locks the
# device count at first init, and the production meshes need 512 host devices.

import argparse
import json
import re
import time
import traceback
from collections import Counter
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import assigned_archs, family_of, get_arch
from repro.launch.mesh import batch_axes_of, make_production_mesh

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "runs" / "dryrun"


# ---------------------------------------------------------------------------
# per-family cell builders: return (fn, args_sds, in_specs, out_specs, meta)
# ---------------------------------------------------------------------------

def build_lm_cell(arch_mod, shape_id: str, mesh, overrides=None):
    from repro.configs.lm_common import LM_SHAPES, lm_rules
    from repro.models.transformer.model import ParallelCtx
    from repro.models.transformer import steps as S
    from repro.train.optimizer import AdamWConfig

    cfg = arch_mod.config()
    overrides = dict(overrides or {})
    step_ov = {k: overrides.pop(k) for k in
               ("n_micro", "cast_per_micro", "accum_bf16") if k in overrides}
    if overrides:
        cfg = cfg.with_(**overrides)
    shape = LM_SHAPES[shape_id]
    rules = lm_rules(mesh, cfg)
    batch_axes = batch_axes_of(mesh)
    kind = shape["kind"]
    B, seq = shape["global_batch"], shape["seq_len"]
    n_batch_shards = 1
    for a in batch_axes:
        n_batch_shards *= mesh.shape[a]
    if B == 1:
        # long-context decode: batch unshardable; spread the KV sequence over
        # (data, model) = 256 shards instead so every chip participates
        batch_axes = ()
        rules = dict(rules, act_batch=None)
        cfg = cfg.with_(seq_shard_decode=("data", "model"))
    ctx = ParallelCtx(mesh=mesh, batch_axes=batch_axes, rules=rules)

    meta = dict(n_params=cfg.n_params(), n_active=cfg.n_active_params(),
                n_layers=cfg.n_layers, kind=kind, seq=seq, batch=B)

    if kind == "train":
        opt = AdamWConfig(moment_dtype=jnp.bfloat16)
        state_sds, state_specs = S.lm_train_state_specs(cfg, ctx, opt)
        inputs = S.lm_input_specs(cfg, ctx, shape)
        # micro-batch must stay divisible by the batch shard count
        n_micro = int(step_ov.get("n_micro",
                                  max(1, min(cfg.train_microbatches, B // n_batch_shards))))
        step = S.make_train_step(
            cfg, ctx, opt, n_micro=n_micro,
            cast_per_micro=bool(step_ov.get("cast_per_micro", False)),
            accum_dtype=jnp.bfloat16 if step_ov.get("accum_bf16") else jnp.float32)
        args = (state_sds, inputs["tokens"][0], inputs["targets"][0])
        in_specs = (state_specs, inputs["tokens"][1], inputs["targets"][1])
        out_specs = (state_specs, None)
        meta["model_flops"] = 6 * meta["n_active"] * B * seq
        meta["n_micro"] = n_micro
        meta["donate"] = (0,)
        return step, args, in_specs, out_specs, meta

    params_sds, pspecs = S.lm_param_specs(cfg, ctx)
    if kind == "prefill":
        inputs = S.lm_input_specs(cfg, ctx, shape)
        step = S.make_prefill_step(cfg, ctx, capacity=seq)
        from repro.models.transformer.model import cache_specs
        cspecs = cache_specs(cfg, ctx, B)
        args = (params_sds, inputs["tokens"][0])
        in_specs = (pspecs, inputs["tokens"][1])
        out_specs = (P(ctx.batch_axes, None), cspecs)
        meta["model_flops"] = 2 * meta["n_active"] * B * seq
        return step, args, in_specs, out_specs, meta

    # decode
    inputs = S.lm_input_specs(cfg, ctx, shape)
    step = S.make_decode_step(cfg, ctx)
    args = (params_sds, inputs["cache"][0], inputs["tokens"][0], inputs["cache_len"][0])
    in_specs = (pspecs, inputs["cache"][1], inputs["tokens"][1], inputs["cache_len"][1])
    out_specs = (None, inputs["cache"][1])
    meta["model_flops"] = 2 * meta["n_active"] * B * 1
    meta["donate"] = (1,)   # cache updated in place
    return step, args, in_specs, out_specs, meta


def build_gnn_cell(arch_mod, shape_id: str, mesh, overrides=None):
    return arch_mod.build_dryrun_cell(shape_id, mesh, overrides=overrides)


def build_recsys_cell(arch_mod, shape_id: str, mesh, overrides=None):
    return arch_mod.build_dryrun_cell(shape_id, mesh, overrides=overrides)


BUILDERS = {"lm": build_lm_cell, "gnn": build_gnn_cell, "recsys": build_recsys_cell}


def shapes_for_family(family: str):
    if family == "lm":
        from repro.configs.lm_common import LM_SHAPES
        return list(LM_SHAPES)
    if family == "gnn":
        from repro.configs.gnn_common import GNN_SHAPES
        return list(GNN_SHAPES)
    from repro.configs.recsys_common import RECSYS_SHAPES
    return list(RECSYS_SHAPES)


# ---------------------------------------------------------------------------
# lower + compile + record
# ---------------------------------------------------------------------------

COLLECTIVE_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\b")


def run_cell(arch_id: str, shape_id: str, multi_pod: bool, save_hlo: bool = True,
             overrides=None, tag: str = "") -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mod, family = get_arch(arch_id)
    t0 = time.time()
    step, args, in_specs, out_specs, meta = BUILDERS[family](mod, shape_id, mesh,
                                                             overrides=overrides)
    in_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s, in_specs,
        is_leaf=lambda s: isinstance(s, P) or s is None)
    out_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s, out_specs,
        is_leaf=lambda s: isinstance(s, P) or s is None)
    donate = tuple(meta.pop("donate", ()))
    lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                      donate_argnums=donate).lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):   # older JAX: one dict per device
        ca = ca[0] if ca else {}
    txt = compiled.as_text()
    colls = Counter(COLLECTIVE_RE.findall(txt))

    rec = dict(
        arch=arch_id, shape=shape_id,
        mesh="2x16x16" if multi_pod else "16x16",
        n_devices=512 if multi_pod else 256,
        lower_s=round(t1 - t0, 1), compile_s=round(t2 - t1, 1),
        status="ok",
        per_device_bytes=dict(
            arguments=ma.argument_size_in_bytes,
            outputs=ma.output_size_in_bytes,
            temp=ma.temp_size_in_bytes,
            alias=ma.alias_size_in_bytes,
            peak_estimate=ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes,
        ),
        cost=dict(flops=ca.get("flops", 0.0),
                  bytes_accessed=ca.get("bytes accessed", 0.0),
                  transcendentals=ca.get("transcendentals", 0.0)),
        collective_op_counts=dict(colls),
        meta=meta,
        tag=tag,
    )
    if save_hlo:
        ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
        stem = f"{arch_id}_{shape_id}_{rec['mesh']}" + (f"_{tag}" if tag else "")
        (ARTIFACT_DIR / f"{stem}.hlo.txt").write_text(txt)
        rec["hlo_path"] = str(ARTIFACT_DIR / f"{stem}.hlo.txt")
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run driver")
    ap.add_argument("--arch", default=None, help="arch id (default: all assigned)")
    ap.add_argument("--shape", default=None, help="shape id (default: all for family)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=str(ARTIFACT_DIR / "records.jsonl"))
    ap.add_argument("--no-hlo", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else assigned_archs()
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
    out = Path(args.out)

    n_ok = n_fail = 0
    for arch in archs:
        family = family_of(arch)
        shapes = [args.shape] if args.shape else shapes_for_family(family)
        for shape in shapes:
            for mp in meshes:
                label = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                try:
                    rec = run_cell(arch, shape, mp, save_hlo=not args.no_hlo,
                                   tag=args.tag)
                    n_ok += 1
                    print(f"[OK] {label}: compile {rec['compile_s']}s, "
                          f"peak/dev {rec['per_device_bytes']['peak_estimate']/2**30:.2f} GiB, "
                          f"flops/dev {rec['cost']['flops']:.3e}", flush=True)
                except Exception as e:
                    rec = dict(arch=arch, shape=shape,
                               mesh="2x16x16" if mp else "16x16",
                               status="fail", error=f"{type(e).__name__}: {e}",
                               tb=traceback.format_exc()[-2000:], tag=args.tag)
                    n_fail += 1
                    print(f"[FAIL] {label}: {type(e).__name__}: {str(e)[:300]}", flush=True)
                with out.open("a") as f:
                    f.write(json.dumps(rec) + "\n")
    print(f"dry-run done: {n_ok} ok, {n_fail} fail")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
