"""Production mesh construction (single-pod 16x16 and 2-pod 2x16x16).

A function, not a module-level constant: importing this module never touches
jax device state (smoke tests must see 1 device; only dryrun.py forces 512).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """General helper with Auto axis types (silences the 0.9 deprecation)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def batch_axes_of(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
