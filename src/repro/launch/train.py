"""Production training launcher (CLI): consistent GNN on partitioned meshes.

    PYTHONPATH=src python -m repro.launch.train \
        --elements 4 4 2 --order 3 --ranks 2 2 1 --steps 200 \
        --halo neighbor --model small --ckpt /tmp/ckpt

Uses every substrate layer: SEM mesh gen -> partitioner -> shard_map step
with real halo collectives -> AdamW -> prefetching loader -> async
checkpoints -> straggler monitor. On a real pod, remove the XLA_FLAGS
override (jax.distributed.initialize picks up the topology).
"""
import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse

import numpy as np

from repro.core import GNNConfig, box_mesh, partition_mesh
from repro.launch.mesh import make_mesh
from repro.train.loop import TrainConfig, train_consistent_gnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--elements", type=int, nargs=3, default=[4, 4, 2])
    ap.add_argument("--order", type=int, default=3)
    ap.add_argument("--ranks", type=int, nargs=3, default=[2, 2, 1])
    ap.add_argument("--data-parallel", type=int, default=2)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--halo", default="neighbor", choices=["neighbor", "a2a", "none"])
    ap.add_argument("--model", default="small", choices=["small", "large"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--mp-backend", default="xla", choices=["xla", "fused"],
                    help="NMP hot-loop backend (fused = Pallas kernel)")
    ap.add_argument("--mp-interpret", action="store_true",
                    help="run the fused kernels via the Pallas interpreter")
    ap.add_argument("--mp-schedule", default="blocking",
                    choices=["blocking", "overlap"],
                    help="halo/compute schedule (overlap hides the exchange "
                         "behind interior-edge work)")
    ap.add_argument("--mp-precision", default="fp32",
                    choices=["fp32", "bf16"],
                    help="edge-MLP matmul precision: bf16 runs the matmuls "
                         "with bf16 operands and fp32 accumulation (faster "
                         "on MXU hardware; not bit-stable with fp32 — see "
                         "CONTRIBUTING.md)")
    ap.add_argument("--levels", type=int, default=1,
                    help="multilevel message-passing depth: 1 = flat NMP; "
                         ">1 adds a consistent coarse-grid V-cycle (level 1 "
                         "= element centroids, deeper levels cluster the "
                         "element grid 2x per axis — repro.core.coarsen)")
    ap.add_argument("--coarse-mp-layers", type=int, default=2,
                    help="NMP layers smoothing each coarse level")
    args = ap.parse_args()

    sem = box_mesh(tuple(args.elements), p=args.order)
    R = int(np.prod(args.ranks))
    cfg = GNNConfig.small() if args.model == "small" else GNNConfig.large()
    hierarchy = None
    if args.levels > 1:
        import dataclasses

        from repro.core.coarsen import build_hierarchy
        cfg = dataclasses.replace(cfg, n_levels=args.levels,
                                  coarse_mp_layers=args.coarse_mp_layers,
                                  coarse_edge_in=sem.dim + 1)
        hierarchy = build_hierarchy(sem, tuple(args.ranks), args.levels)
        pg = hierarchy.levels[0]
        sizes = " -> ".join(str(s) for s in hierarchy.level_sizes())
        print(f"multilevel hierarchy: {sizes} nodes per level")
    else:
        pg = partition_mesh(sem, tuple(args.ranks))
    mesh_dev = make_mesh((args.data_parallel, R), ("data", "graph"))
    print(f"mesh: {sem.n_elem} elems p={args.order} ({sem.n_nodes} nodes); "
          f"R={R} sub-graphs x DP={args.data_parallel}; halo={args.halo}; "
          f"levels={args.levels}")

    tcfg = TrainConfig(n_steps=args.steps, batch=args.batch, lr=args.lr,
                       halo_mode=args.halo, ckpt_dir=args.ckpt,
                       mp_backend=args.mp_backend,
                       mp_interpret=args.mp_interpret,
                       mp_schedule=args.mp_schedule,
                       mp_precision=args.mp_precision)
    hist = train_consistent_gnn(mesh_dev, pg, sem, cfg, tcfg,
                                hierarchy=hierarchy)
    print(f"loss {hist['losses'][0]:.6f} -> {hist['losses'][-1]:.6f} "
          f"({len(hist['losses'])} steps, {hist['straggler_events']} straggler events)")


if __name__ == "__main__":
    main()
