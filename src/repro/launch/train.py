"""Production training launcher (CLI): consistent GNN on partitioned meshes.

    PYTHONPATH=src python -m repro.launch.train \
        --elements 4 4 2 --order 3 --ranks 2 2 1 --steps 200 \
        --halo neighbor --model small --ckpt /tmp/ckpt

Uses every substrate layer: SEM mesh gen -> partitioner -> shard_map step
with real halo collectives -> AdamW -> async checkpoints -> straggler
monitor. On a real pod, remove the XLA_FLAGS override
(jax.distributed.initialize picks up the topology).

``--rollout-steps K`` (K > 1) switches to autoregressive rollout training
(repro.train.rollout): the model is scanned over its own predictions for K
steps with a per-step halo-consistent loss; ``--pushforward-noise`` adds the
stop-gradient step-1 perturbation that emulates inference-time drift.
"""
import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse

import numpy as np

from repro.core import GNNConfig, NMPPlan, box_mesh, partition_mesh
from repro.launch.mesh import make_mesh
from repro.runtime.fault_tolerance import ResilientConfig
from repro.train.loop import TrainConfig, train_consistent_gnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--elements", type=int, nargs=3, default=[4, 4, 2])
    ap.add_argument("--order", type=int, default=3)
    ap.add_argument("--ranks", type=int, nargs=3, default=[2, 2, 1])
    ap.add_argument("--data-parallel", type=int, default=2)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--halo", default="neighbor", choices=["neighbor", "a2a", "none"])
    ap.add_argument("--model", default="small", choices=["small", "large"])
    ap.add_argument("--ckpt", default=None,
                    help="plain fire-and-forget checkpoint dir (no resume); "
                         "for crash recovery + elastic resume use --ckpt-dir")
    ap.add_argument("--ckpt-dir", default=None,
                    help="resilient checkpoint dir: auto-resumes from the "
                         "newest valid checkpoint (elastically — the "
                         "checkpoint may come from a different --ranks or "
                         "--partitioner), recovers from crashes, and writes "
                         "fingerprinted manifests (see CONTRIBUTING.md "
                         "'Elastic resume')")
    ap.add_argument("--ckpt-every", type=int, default=50,
                    help="steps between periodic checkpoints (with --ckpt-dir)")
    ap.add_argument("--max-restarts", type=int, default=3,
                    help="in-process crash recoveries before giving up "
                         "(with --ckpt-dir)")
    ap.add_argument("--mp-backend", default="xla", choices=["xla", "fused"],
                    help="NMP hot-loop backend (fused = Pallas kernel)")
    ap.add_argument("--mp-interpret", action="store_true",
                    help="run the fused kernels via the Pallas interpreter")
    ap.add_argument("--mp-schedule", default="blocking",
                    choices=["blocking", "overlap", "auto"],
                    help="halo/compute schedule (overlap hides the exchange "
                         "behind interior-edge work; auto measures both on "
                         "this graph x rank count and commits to the winner)")
    ap.add_argument("--partitioner", default="block",
                    choices=["block", "spectral"],
                    help="mesh decomposition: block = NekRS-style element "
                         "blocks along --ranks; spectral = recursive "
                         "spectral bisection + KL refinement "
                         "(repro.core.partition_quality) — lower halo "
                         "volume on stretched/unstructured meshes, "
                         "identical results either way")
    ap.add_argument("--mp-precision", default="fp32",
                    choices=["fp32", "bf16"],
                    help="edge-MLP matmul precision: bf16 runs the matmuls "
                         "with bf16 operands and fp32 accumulation (faster "
                         "on MXU hardware; not bit-stable with fp32 — see "
                         "CONTRIBUTING.md)")
    ap.add_argument("--levels", type=int, default=1,
                    help="multilevel message-passing depth: 1 = flat NMP; "
                         ">1 adds a consistent coarse-grid V-cycle (level 1 "
                         "= element centroids, deeper levels cluster the "
                         "element grid 2x per axis — repro.core.coarsen)")
    ap.add_argument("--coarse-mp-layers", type=int, default=2,
                    help="NMP layers smoothing each coarse level")
    ap.add_argument("--rollout-steps", type=int, default=1,
                    help="K > 1 trains autoregressively: the model is "
                         "scanned over its OWN predictions for K steps with "
                         "a per-step halo-consistent loss "
                         "(repro.train.rollout)")
    ap.add_argument("--pushforward-noise", type=float, default=0.0,
                    help="stddev of the stop-gradient pushforward noise "
                         "added to the rollout's initial state (emulates "
                         "inference-time drift; needs --rollout-steps > 1)")
    args = ap.parse_args()
    if args.rollout_steps < 1:
        ap.error("--rollout-steps must be >= 1")
    if args.pushforward_noise and args.rollout_steps == 1:
        ap.error("--pushforward-noise needs --rollout-steps > 1 (one-step "
                 "training never feeds predictions back)")
    if args.ckpt and args.ckpt_dir:
        ap.error("--ckpt and --ckpt-dir are mutually exclusive (plain "
                 "fire-and-forget saves vs resilient auto-resume)")

    sem = box_mesh(tuple(args.elements), p=args.order)
    R = int(np.prod(args.ranks))
    cfg = GNNConfig.small() if args.model == "small" else GNNConfig.large()
    hierarchy = None
    if args.levels > 1:
        import dataclasses

        from repro.core.coarsen import build_hierarchy
        cfg = dataclasses.replace(cfg, n_levels=args.levels,
                                  coarse_mp_layers=args.coarse_mp_layers,
                                  coarse_edge_in=sem.dim + 1)
        node2part = None
        if args.partitioner == "spectral":
            from repro.core.partition_quality import mesh_node2part
            node2part = mesh_node2part(sem, R)
        hierarchy = build_hierarchy(sem, tuple(args.ranks), args.levels,
                                    node2part=node2part)
        pg = hierarchy.levels[0]
        sizes = " -> ".join(str(s) for s in hierarchy.level_sizes())
        print(f"multilevel hierarchy: {sizes} nodes per level")
    else:
        pg = partition_mesh(sem, tuple(args.ranks), method=args.partitioner)
    mesh_dev = make_mesh((args.data_parallel, R), ("data", "graph"))
    print(f"mesh: {sem.n_elem} elems p={args.order} ({sem.n_nodes} nodes); "
          f"R={R} sub-graphs x DP={args.data_parallel}; halo={args.halo}; "
          f"partitioner={args.partitioner}; levels={args.levels}; "
          f"rollout K={args.rollout_steps}")

    policy = NMPPlan(backend=args.mp_backend, interpret=args.mp_interpret,
                     schedule=args.mp_schedule, precision=args.mp_precision)
    resilience = None
    if args.ckpt_dir:
        resilience = ResilientConfig(ckpt_dir=args.ckpt_dir,
                                     ckpt_every=args.ckpt_every,
                                     max_restarts=args.max_restarts)
    tcfg = TrainConfig(n_steps=args.steps, batch=args.batch, lr=args.lr,
                       halo_mode=args.halo, ckpt_dir=args.ckpt, plan=policy,
                       rollout_steps=args.rollout_steps,
                       pushforward_noise=args.pushforward_noise,
                       partitioner=args.partitioner, resilience=resilience)
    hist = train_consistent_gnn(mesh_dev, pg, sem, cfg, tcfg,
                                hierarchy=hierarchy)
    if args.mp_schedule == "auto":
        print(f"schedule auto -> {hist['schedule']}")
    if hist.get("elastic"):
        el = hist["elastic"]
        print(f"elastic resume at step {el['step']}: "
              f"R={el['from_ranks']}/{el['from_partitioner']} -> "
              f"R={el['to_ranks']}/{el['to_partitioner']}")
    if hist.get("restarts"):
        print(f"recovered from {hist['restarts']} crash(es)")
    print(f"loss {hist['losses'][0]:.6f} -> {hist['losses'][-1]:.6f} "
          f"({len(hist['losses'])} steps, {hist['straggler_events']} straggler events)")


if __name__ == "__main__":
    main()
