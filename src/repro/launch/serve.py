"""Serving launcher (CLI): batched prefill + decode with request batching.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \
        --requests 8 --prompt-len 12 --gen 16

Drives the same prefill/decode path the decode dry-run cells lower, with a
simple continuous-batching queue: requests are grouped to the batch size,
prefilled once, then decoded step-wise (greedy).
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models.transformer.model import (
    ParallelCtx, decode_step, init_transformer, prefill_step,
)
from repro.sharding import split_tree


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    # the batching queue pads the last group up to --batch with empty
    # requests; that covers any positive request count, nothing else
    for name in ("requests", "batch", "prompt_len", "gen"):
        if getattr(args, name) < 1:
            ap.error(f"--{name.replace('_', '-')} must be >= 1, got "
                     f"{getattr(args, name)}")

    mod, family = get_arch(args.arch)
    assert family == "lm", "serving launcher drives LM archs"
    cfg = mod.smoke_config()      # reduced config on CPU; full via dry-run
    ctx = ParallelCtx.single_device()
    params, _ = split_tree(init_transformer(jax.random.PRNGKey(0), cfg), {})

    cap = args.prompt_len + args.gen
    prefill = jax.jit(lambda p, t: prefill_step(p, t, cfg, ctx, capacity=cap))
    decode = jax.jit(lambda p, c, t, n: decode_step(p, c, t, n, cfg, ctx))

    rng = np.random.default_rng(0)
    pending = [rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32)
               for _ in range(args.requests)]
    t0 = time.perf_counter()
    while pending:
        group, pending = pending[:args.batch], pending[args.batch:]
        while len(group) < args.batch:          # pad the last group
            group.append(np.zeros(args.prompt_len, np.int32))
        prompts = jnp.asarray(np.stack(group))
        logits, cache = prefill(params, prompts)
        tok = jnp.argmax(logits, axis=-1)[:, None]
        for i in range(args.gen - 1):
            logits, cache = decode(params, cache, tok, jnp.int32(args.prompt_len + i))
            tok = jnp.argmax(logits[:, 0], axis=-1)[:, None]
    dt = time.perf_counter() - t0
    tput = args.requests * args.gen / dt
    print(f"served {args.requests} requests x {args.gen} tokens in {dt:.2f}s "
          f"({tput:.1f} tok/s on CPU host; production numbers come from the "
          f"decode dry-run roofline)")


if __name__ == "__main__":
    main()
