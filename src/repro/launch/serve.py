"""Solver-in-the-loop serving launcher (CLI): resident GNN inference engine.

    PYTHONPATH=src python -m repro.launch.serve \
        --ckpt-dir /tmp/repro_serve_ckpt --requests 32 --batch-slots 4 \
        --rollout-steps 2 --producers 2 --bootstrap-steps 20

Loads a fingerprinted training checkpoint into a resident
:class:`repro.runtime.engine.InferenceEngine`, registers the mesh (the
``ShardedGraph`` + ``NMPPlan`` build is cached by mesh hash), warms the
jitted batch-slot program, then emulates a solver feed: producer threads
stream Taylor-Green snapshots through the engine's bounded request queue
and the CLI reports per-request latency percentiles and steady-state
throughput.

With an empty ``--ckpt-dir`` and ``--bootstrap-steps N > 0``, a short
training run creates a fingerprinted checkpoint first (demo convenience —
the engine itself refuses unfingerprinted checkpoints).  The earlier LM
serving toy lives on as ``examples/serve_lm.py``.
"""
import argparse
import time

import numpy as np
import jax

from repro.core import GNNConfig, box_mesh, partition_mesh
from repro.core.mesh_gen import taylor_green_velocity
from repro.ckpt import checkpoint as ckpt
from repro.launch.mesh import make_mesh
from repro.runtime.engine import EngineConfig, InferenceEngine
from repro.train.loop import TrainConfig, train_consistent_gnn

DT = 0.05


def _bootstrap(args, sem):
    """Create a fingerprinted checkpoint via a short training run."""
    R = len(jax.devices())
    mesh_dev = make_mesh((1, R), ("data", "graph"))
    pg = partition_mesh(sem, (R, 1, 1), method=args.partitioner)
    tcfg = TrainConfig(
        n_steps=args.bootstrap_steps,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=max(1, args.bootstrap_steps // 2),
        halo_mode=args.halo_mode if R > 1 else "none",
        partitioner=args.partitioner,
        log_every=max(1, args.bootstrap_steps // 4))
    print(f"[serve] no committed checkpoint under {args.ckpt_dir}; "
          f"bootstrapping with a {args.bootstrap_steps}-step training run")
    train_consistent_gnn(mesh_dev, pg, sem, GNNConfig.small(), tcfg)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt-dir", default="/tmp/repro_serve_ckpt",
                    help="fingerprinted checkpoint directory to serve from")
    ap.add_argument("--mesh", default="4,4,2",
                    help="box mesh elements per dim, e.g. 4,4,2")
    ap.add_argument("--p", type=int, default=2, help="SEM polynomial order")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--rollout-steps", type=int, default=1,
                    help="prediction horizon K per request")
    ap.add_argument("--producers", type=int, default=2,
                    help="concurrent solver-feed producer threads")
    ap.add_argument("--max-pending", type=int, default=16,
                    help="bounded request queue depth (backpressure point)")
    ap.add_argument("--partitioner", default="block",
                    choices=["block", "spectral"])
    ap.add_argument("--halo-mode", default="a2a",
                    choices=["a2a", "neighbor"])
    ap.add_argument("--bootstrap-steps", type=int, default=20,
                    help="train this many steps to create a checkpoint when "
                         "--ckpt-dir has none (0 = refuse instead)")
    args = ap.parse_args()
    for name in ("requests", "batch_slots", "rollout_steps", "producers",
                 "max_pending"):
        if getattr(args, name) < 1:
            ap.error(f"--{name.replace('_', '-')} must be >= 1, got "
                     f"{getattr(args, name)}")

    sem = box_mesh(tuple(int(v) for v in args.mesh.split(",")), p=args.p)
    if not ckpt.committed_steps(args.ckpt_dir):
        if args.bootstrap_steps < 1:
            ap.error(f"no committed checkpoint under {args.ckpt_dir} and "
                     "--bootstrap-steps 0: nothing to serve")
        _bootstrap(args, sem)

    engine = InferenceEngine(
        args.ckpt_dir, GNNConfig.small(),
        EngineConfig(batch_slots=args.batch_slots,
                     rollout_steps=args.rollout_steps,
                     max_pending=args.max_pending,
                     halo_mode=args.halo_mode,
                     partitioner=args.partitioner))
    print(f"[serve] params from step {engine.ckpt_step}, trained mesh "
          f"{engine.fingerprint['mesh_hash']} "
          f"(n_global={engine.fingerprint['n_global']}), serving on "
          f"R={engine.R} device(s)")
    mesh_hash = engine.register_mesh(sem)
    engine.warmup()

    def snapshot_fn(step: int):
        return taylor_green_velocity(sem.coords,
                                     t=(step * DT) % 2.0).astype(np.float32)

    with engine:
        t0 = time.perf_counter()
        results = list(engine.stream(mesh_hash, snapshot_fn, args.requests,
                                     n_producers=args.producers))
        wall = time.perf_counter() - t0

    lat = np.sort([r.latency_s for _, r in results]) * 1e3
    p50 = float(np.percentile(lat, 50))
    p95 = float(np.percentile(lat, 95))
    st = engine.stats
    print(f"[serve] {len(results)} requests in {wall:.2f}s "
          f"({len(results) / wall:.1f} req/s) | latency p50 {p50:.1f} ms, "
          f"p95 {p95:.1f} ms | {st['batches']} batches, "
          f"{st['padded_slots']} padded slots, graph cache "
          f"{st['cache_builds']} build(s) / {st['cache_hits']} hit(s)")


if __name__ == "__main__":
    main()
