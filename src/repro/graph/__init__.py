from repro.graph import segment
