"""Segment-op wrappers: the message-passing scatter/gather primitives.

JAX has no native sparse message passing (BCOO only) — per the assignment,
message passing IS implemented via ``jax.ops.segment_sum``-family ops over an
edge index. These wrappers fix num_segments statically and add masked and
softmax variants used across the GNN zoo.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def segment_sum(data: jnp.ndarray, segment_ids: jnp.ndarray, num_segments: int) -> jnp.ndarray:
    return jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)


def segment_max(data: jnp.ndarray, segment_ids: jnp.ndarray, num_segments: int) -> jnp.ndarray:
    return jax.ops.segment_max(data, segment_ids, num_segments=num_segments)


def segment_mean(data: jnp.ndarray, segment_ids: jnp.ndarray, num_segments: int,
                 eps: float = 1e-9) -> jnp.ndarray:
    s = segment_sum(data, segment_ids, num_segments)
    c = segment_sum(jnp.ones(data.shape[:1], data.dtype), segment_ids, num_segments)
    return s / jnp.maximum(c, eps)[(...,) + (None,) * (data.ndim - 1)]


def segment_softmax(logits: jnp.ndarray, segment_ids: jnp.ndarray, num_segments: int,
                    mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Numerically-stable softmax over segments (e.g. GAT edge softmax).

    ``logits``: [E, ...]; mask: [E] 1/0 — masked entries get weight 0.
    """
    if mask is not None:
        logits = jnp.where(mask[(...,) + (None,) * (logits.ndim - 1)] > 0, logits, NEG_INF)
    seg_max = segment_max(logits, segment_ids, num_segments)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    shifted = logits - seg_max[segment_ids]
    expv = jnp.exp(shifted)
    if mask is not None:
        expv = expv * mask[(...,) + (None,) * (logits.ndim - 1)]
    denom = segment_sum(expv, segment_ids, num_segments)
    return expv / jnp.maximum(denom[segment_ids], 1e-20)


def gather(x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Row gather along the node axis (works with leading batch dims on x)."""
    return jnp.take(x, idx, axis=-2)
