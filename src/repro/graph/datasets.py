"""Synthetic dataset generators (offline container: no downloads).

Shapes/statistics mirror the assigned cells: Cora (2708/10556/1433),
ogbn-products-like power-law graphs, Reddit-like for sampled training,
random molecular configurations, Criteo-like click streams, and the
Taylor-Green CFD snapshots used by the paper reproduction.
"""
from __future__ import annotations

import numpy as np


def cora_like(seed: int = 0, n: int = 2708, m_und: int = 5278, d: int = 1433,
              n_classes: int = 7):
    """Random graph with Cora's exact dimensions. Returns (edges[E,2] directed,
    features [n,d], labels [n])."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m_und)
    dst = rng.integers(0, n, m_und)
    keep = src != dst
    und = np.stack([src[keep], dst[keep]], -1)
    edges = np.concatenate([und, und[:, ::-1]], axis=0)
    feats = (rng.random((n, d)) < 0.012).astype(np.float32)  # sparse bag-of-words
    labels = rng.integers(0, n_classes, n)
    return edges, feats, labels.astype(np.int32)


def powerlaw_graph(n: int, avg_deg: int, seed: int = 0) -> np.ndarray:
    """Directed edges [E,2] with power-law-ish in-degrees (preferential-style)."""
    rng = np.random.default_rng(seed)
    m = n * avg_deg
    # Zipf-weighted destination choice via inverse-CDF on sorted weights
    w = 1.0 / np.arange(1, n + 1) ** 0.8
    w /= w.sum()
    dst = rng.choice(n, size=m, p=w)
    src = rng.integers(0, n, m)
    keep = src != dst
    return np.stack([src[keep], dst[keep]], axis=-1).astype(np.int64)


def molecules(batch: int, n_atoms: int = 30, n_species: int = 8,
              cutoff: float = 3.0, seed: int = 0):
    """Random 3D configurations + radius-graph edges per molecule.

    Returns (species [B,n], pos [B,n,3], edges list of [E_i,2])."""
    rng = np.random.default_rng(seed)
    species = rng.integers(0, n_species, (batch, n_atoms)).astype(np.int32)
    pos = rng.normal(scale=2.0, size=(batch, n_atoms, 3)).astype(np.float32)
    edge_lists = []
    for b in range(batch):
        d = np.linalg.norm(pos[b][:, None] - pos[b][None], axis=-1)
        src, dst = np.nonzero((d < cutoff) & (d > 1e-6))
        edge_lists.append(np.stack([src, dst], -1).astype(np.int64))
    return species, pos, edge_lists


def batch_molecules(species, pos, edge_lists, e_pad_per: int = 64):
    """Block-diagonal batch of small graphs with static padding."""
    B, n = species.shape
    n_total = B * n
    e_pad = B * e_pad_per
    esrc = np.zeros(e_pad, np.int32)
    edst = np.zeros(e_pad, np.int32)
    emask = np.zeros(e_pad, np.float32)
    off = 0
    for b, el in enumerate(edge_lists):
        k = min(len(el), e_pad_per)
        esrc[off:off + k] = el[:k, 0] + b * n
        edst[off:off + k] = el[:k, 1] + b * n
        emask[off:off + k] = 1
        off += e_pad_per
    meta = dict(
        node_mask=np.ones(n_total, np.float32),
        node_inv_mult=np.ones(n_total, np.float32),
        edge_src=esrc, edge_dst=edst, edge_mask=emask, edge_inv_mult=emask,
    )
    return species.reshape(-1), pos.reshape(-1, 3), meta


def criteo_like(batch: int, cfg, seed: int = 0):
    """(dense [B,13], sparse_idx [B,F,H] with field offsets applied, labels)."""
    rng = np.random.default_rng(seed)
    dense = rng.lognormal(0, 1, (batch, cfg.n_dense)).astype(np.float32)
    offs = np.concatenate([[0], np.cumsum(cfg.vocab_sizes)[:-1]])
    idx = np.stack([
        offs[f] + rng.integers(0, cfg.vocab_sizes[f], (batch, cfg.multi_hot))
        for f in range(cfg.n_sparse)
    ], axis=1).astype(np.int32)
    labels = rng.integers(0, 2, (batch, 1)).astype(np.float32)
    return dense, idx, labels
