"""Fanout neighbor sampling (GraphSAGE-style) for the minibatch_lg cells.

Host-side CSR sampler producing fixed-size padded blocks for jit'd steps:
for seeds S and fanouts [f1, f2, ...], hop h uniformly samples up to f_h
in-neighbors of the frontier. The returned block is a *local* graph with
edges (src_local -> dst_local) oriented toward the seeds, padded to static
shapes (this IS the data pipeline for sampled training — each data-parallel
device consumes its own stream of blocks).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray    # [N+1]
    indices: np.ndarray   # [E] in-neighbors
    n_nodes: int

    @staticmethod
    def from_edges(n_nodes: int, edges: np.ndarray) -> "CSRGraph":
        """edges [E,2] directed (src, dst): CSR over *incoming* edges per dst."""
        order = np.argsort(edges[:, 1], kind="stable")
        sorted_e = edges[order]
        counts = np.bincount(sorted_e[:, 1], minlength=n_nodes)
        indptr = np.concatenate([[0], np.cumsum(counts)])
        return CSRGraph(indptr=indptr.astype(np.int64),
                        indices=sorted_e[:, 0].astype(np.int64),
                        n_nodes=n_nodes)


@dataclasses.dataclass
class SampledBlock:
    """Fixed-shape sampled subgraph (padded)."""
    node_ids: np.ndarray      # [N_pad] global ids (-1 pad)
    node_mask: np.ndarray     # [N_pad] float
    edge_src: np.ndarray      # [E_pad] local idx
    edge_dst: np.ndarray      # [E_pad]
    edge_mask: np.ndarray     # [E_pad]
    seed_mask: np.ndarray     # [N_pad] 1.0 on seed rows (loss rows)

    @staticmethod
    def pad_sizes(n_seeds: int, fanouts: Sequence[int]):
        n = n_seeds
        total_n = n_seeds
        total_e = 0
        for f in fanouts:
            e = n * f
            total_e += e
            n = e
            total_n += n
        return total_n, total_e


def sample_block(g: CSRGraph, seeds: np.ndarray, fanouts: Sequence[int],
                 rng: np.random.Generator) -> SampledBlock:
    n_pad, e_pad = SampledBlock.pad_sizes(len(seeds), fanouts)
    nodes: List[int] = list(seeds)
    local = {int(s): i for i, s in enumerate(seeds)}
    esrc, edst = [], []
    frontier = list(seeds)
    for f in fanouts:
        nxt = []
        for v in frontier:
            lo, hi = g.indptr[v], g.indptr[v + 1]
            deg = hi - lo
            if deg == 0:
                continue
            k = min(f, deg)
            picks = g.indices[lo + rng.choice(deg, size=k, replace=False)]
            for u in picks:
                u = int(u)
                if u not in local:
                    local[u] = len(nodes)
                    nodes.append(u)
                esrc.append(local[u])
                edst.append(local[int(v)])
                nxt.append(u)
        frontier = nxt
    node_ids = np.full(n_pad, -1, np.int64)
    node_ids[:len(nodes)] = nodes
    nm = np.zeros(n_pad, np.float32)
    nm[:len(nodes)] = 1
    es = np.zeros(e_pad, np.int32)
    ed = np.zeros(e_pad, np.int32)
    em = np.zeros(e_pad, np.float32)
    es[:len(esrc)] = esrc
    ed[:len(edst)] = edst
    em[:len(esrc)] = 1
    sm = np.zeros(n_pad, np.float32)
    sm[:len(seeds)] = 1
    return SampledBlock(node_ids, nm, es, ed, em, sm)


def block_meta(block: SampledBlock) -> dict:
    """meta dict compatible with the message-passing layers (no halo)."""
    return dict(
        node_mask=block.node_mask,
        node_inv_mult=block.seed_mask,       # loss over seeds only
        edge_src=block.edge_src, edge_dst=block.edge_dst,
        edge_mask=block.edge_mask,
        edge_inv_mult=block.edge_mask,       # d_ij = 1
    )
