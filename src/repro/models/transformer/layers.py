"""Transformer building blocks: RMSNorm, RoPE, gated MLPs — with logical dims."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import L


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"g": L(jnp.zeros((d,), dtype), ("embed",))}


def rmsnorm(p, x, eps: float = 1e-6, gemma: bool = False):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    xhat = x32 * jax.lax.rsqrt(var + eps)
    return (xhat * (1.0 + p["g"].astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, S, H, D] (D even); positions: [B, S] or [S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [D/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, D/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs (dense FFN variants)
# ---------------------------------------------------------------------------

def init_ffn(key, d: int, ff: int, variant: str, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    scale_in = d ** -0.5
    scale_out = ff ** -0.5
    if variant in ("swiglu", "geglu"):
        return {
            "wi": L(jax.random.normal(ks[0], (d, ff), dtype) * scale_in, ("embed", "mlp")),
            "wg": L(jax.random.normal(ks[1], (d, ff), dtype) * scale_in, ("embed", "mlp")),
            "wo": L(jax.random.normal(ks[2], (ff, d), dtype) * scale_out, ("mlp", "embed")),
        }
    return {
        "wi": L(jax.random.normal(ks[0], (d, ff), dtype) * scale_in, ("embed", "mlp")),
        "wo": L(jax.random.normal(ks[2], (ff, d), dtype) * scale_out, ("mlp", "embed")),
    }


def ffn(p, x, variant: str):
    if variant == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    elif variant == "geglu":
        h = jax.nn.gelu(x @ p["wg"], approximate=True) * (x @ p["wi"])
    else:
        h = jax.nn.gelu(x @ p["wi"], approximate=True)
    return h @ p["wo"]


def softcap(x: jnp.ndarray, cap: float | None) -> jnp.ndarray:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)
