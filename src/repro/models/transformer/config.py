"""Transformer configuration covering the five assigned LM architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.sharding import Rules


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention dims."""
    q_lora: int = 1536
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_dim: int = 128

    @property
    def qk_dim(self) -> int:
        return self.qk_nope + self.qk_rope


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                       # per-expert hidden
    n_shared: int = 0               # shared (always-on) experts
    first_dense_layers: int = 0     # leading dense-FFN layers (DeepSeek: 1)
    first_dense_ff: int = 0         # their hidden size
    capacity_factor: float = 1.25
    renormalize: bool = True
    aux_coef: float = 0.0           # load-balance aux loss coefficient


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_q: int
    n_kv: int
    head_dim: int
    d_ff: int
    mlp_variant: str = "swiglu"             # swiglu | geglu | gelu_mlp
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    rope_theta: float = 10000.0
    window: Optional[int] = None            # sliding-window size (local layers)
    window_pattern: str = "none"            # none | alternate (gemma2: even layers local)
    attn_softcap: Optional[float] = None    # gemma2: 50.0
    final_softcap: Optional[float] = None   # gemma2: 30.0
    post_norms: bool = False                # gemma2 pre+post block norms
    gemma_norm: bool = False                # (1+g) RMSNorm + sqrt(d) embed scale
    qk_norm: bool = False
    tied_embeddings: bool = True
    norm_eps: float = 1e-6
    param_dtype: jnp.dtype = jnp.bfloat16
    cache_dtype: jnp.dtype = jnp.bfloat16
    # --- parallel/perf knobs ---
    train_microbatches: int = 1
    attn_parallel: str = "heads"            # heads | seq (context parallel)
    remat: str = "dots"                     # dots | full | none
    q_block: int = 512
    kv_block: int = 512
    seq_shard_decode: Tuple[str, ...] = ("model",)
    rules: Rules = dataclasses.field(default_factory=dict)

    def with_(self, **kw) -> "TransformerConfig":
        return dataclasses.replace(self, **kw)

    @property
    def layer_windows(self) -> Tuple[int, ...]:
        """Per-layer attention window (0 = global). Gemma2 alternates
        local (even idx) / global (odd idx)."""
        if self.window is None or self.window_pattern == "none":
            return tuple(0 for _ in range(self.n_layers))
        return tuple(self.window if (i % 2 == 0) else 0 for i in range(self.n_layers))

    def n_params(self) -> int:
        """Analytic parameter count (for 6ND roofline math)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        emb = V * d * (1 if self.tied_embeddings else 2)
        if self.mla is not None:
            m = self.mla
            attn = (d * m.q_lora + m.q_lora * self.n_q * m.qk_dim
                    + d * (m.kv_lora + m.qk_rope)
                    + m.kv_lora * self.n_q * (m.qk_nope + m.v_dim)
                    + self.n_q * m.v_dim * d)
        else:
            attn = d * self.n_q * self.head_dim * 2 + d * self.n_kv * self.head_dim * 2
        mats = 3 if self.mlp_variant in ("swiglu", "geglu") else 2
        total = emb
        for i in range(L):
            total += attn
            if self.moe is not None and i >= self.moe.first_dense_layers:
                total += self.moe.n_experts * mats * d * self.moe.d_ff
                total += self.moe.n_shared * mats * d * self.moe.d_ff
                total += d * self.moe.n_experts
            elif self.moe is not None:
                total += mats * d * self.moe.first_dense_ff
            else:
                total += mats * d * self.d_ff
        return total

    def n_active_params(self) -> int:
        """Active (per-token) params — MoE counts only routed top-k."""
        if self.moe is None:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        mats = 3 if self.mlp_variant in ("swiglu", "geglu") else 2
        full = self.n_params()
        moe_layers = L - self.moe.first_dense_layers
        inactive = moe_layers * (self.moe.n_experts - self.moe.top_k) * mats * d * self.moe.d_ff
        return full - inactive
