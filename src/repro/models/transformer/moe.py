"""Mixture-of-Experts FFN with explicit expert parallelism (shard_map).

Scheme ("replicated-activations EP", GShard-style with deterministic
collectives): expert weights are sharded over the 'model' axis (E_loc =
E / n_model per device); activations stay batch-sharded and model-replicated.
Each device routes its local tokens, builds capacity buffers for *its* expert
slice via scatter (no one-hot einsum — the [T, E, C] dispatch tensor would be
TBs at DeepSeek scale), runs its experts, and the outputs are combined with a
single psum over 'model' per MoE layer (same collective volume as a TP
all-reduce of the layer output).

Over-capacity tokens are dropped (standard GShard semantics; capacity_factor
in the config controls head-room — tests use generous factors so reference
comparisons are drop-free). A switch-style load-balance aux loss is returned.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.transformer.config import MoEConfig
from repro.sharding import L


def init_moe(key, d_model: int, cfg: MoEConfig, variant: str, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 5)
    E, F = cfg.n_experts, cfg.d_ff
    si, so = d_model ** -0.5, F ** -0.5
    p = {
        "router": L(jax.random.normal(ks[0], (d_model, E), jnp.float32) * si,
                    ("embed", "experts")),
        "wi": L(jax.random.normal(ks[1], (E, d_model, F), dtype) * si,
                ("experts", "embed", "expert_mlp")),
        "wo": L(jax.random.normal(ks[2], (E, F, d_model), dtype) * so,
                ("experts", "expert_mlp", "embed")),
    }
    if variant in ("swiglu", "geglu"):
        p["wg"] = L(jax.random.normal(ks[3], (E, d_model, F), dtype) * si,
                    ("experts", "embed", "expert_mlp"))
    return p


def _expert_ffn(wi, wg, wo, xe, variant: str):
    """xe: [E_loc, C, D] capacity buffers; batched expert matmuls."""
    h = jnp.einsum("ecd,edf->ecf", xe, wi)
    if variant == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", xe, wg)
        h = jax.nn.silu(g) * h
    elif variant == "geglu":
        g = jnp.einsum("ecd,edf->ecf", xe, wg)
        h = jax.nn.gelu(g, approximate=True) * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    return jnp.einsum("ecf,efd->ecd", h, wo)


def moe_ffn(
    params,
    x: jnp.ndarray,                  # [B, S, D] (batch sharded over batch_axes)
    cfg: MoEConfig,
    variant: str,
    mesh: Mesh,
    batch_axes: Tuple[str, ...],
    model_axis: str = "model",
    fsdp_axis: str | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y [B,S,D], aux_loss scalar).

    With ``fsdp_axis`` set, expert weights stay 2-D sharded
    (experts x embed-dim) at rest and are all-gathered *inside* the shard —
    per layer, transient — instead of letting XLA hoist a whole-stack gather
    out of the layer scan (ZeRO-3 semantics; backward is reduce-scatter).
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    n_model = mesh.shape[model_axis]
    assert E % n_model == 0, (E, n_model)
    e_loc = E // n_model

    def local(xb, router, wi, wg, wo):
        if fsdp_axis is not None:
            wi = jax.lax.all_gather(wi, fsdp_axis, axis=1, tiled=True)
            wg = jax.lax.all_gather(wg, fsdp_axis, axis=1, tiled=True)
            wo = jax.lax.all_gather(wo, fsdp_axis, axis=2, tiled=True)
        Bl = xb.shape[0]
        T = Bl * S
        xt = xb.reshape(T, D)
        logits = (xt.astype(jnp.float32) @ router)            # [T, E]
        probs = jax.nn.softmax(logits, axis=-1)
        w_topk, idx = jax.lax.top_k(probs, K)                 # [T, K]
        if cfg.renormalize:
            w_topk = w_topk / jnp.maximum(w_topk.sum(-1, keepdims=True), 1e-9)

        e0 = jax.lax.axis_index(model_axis) * e_loc
        cap = max(int(T * K / E * cfg.capacity_factor), 4)

        sel = idx.reshape(-1)                                 # [T*K] expert ids
        w_flat = w_topk.reshape(-1)
        local_sel = (sel >= e0) & (sel < e0 + e_loc)
        loc_e = jnp.where(local_sel, sel - e0, e_loc)         # e_loc = trash bucket
        onehot = jax.nn.one_hot(loc_e, e_loc, dtype=jnp.int32)     # [T*K, E_loc]
        pos = jnp.cumsum(onehot, axis=0) - onehot                   # pos before this sel
        pos = (pos * onehot).sum(-1)                                # [T*K]
        keep = local_sel & (pos < cap)
        slot = jnp.where(keep, loc_e * cap + pos, e_loc * cap)      # overflow row

        tok_idx = jnp.repeat(jnp.arange(T), K)
        buf = jnp.zeros((e_loc * cap + 1, D), x.dtype)
        buf = buf.at[slot].add(jnp.where(keep[:, None], xt[tok_idx], 0))
        xe = buf[:-1].reshape(e_loc, cap, D)

        ye = _expert_ffn(wi, wg, wo, xe, variant)             # [E_loc, C, D]
        ye_flat = jnp.concatenate([ye.reshape(e_loc * cap, D),
                                   jnp.zeros((1, D), ye.dtype)], axis=0)
        contrib = ye_flat[slot] * (w_flat * keep).astype(ye.dtype)[:, None]
        yt = jax.ops.segment_sum(contrib, tok_idx, num_segments=T)
        y = jax.lax.psum(yt, model_axis).reshape(Bl, S, D).astype(xb.dtype)

        # switch aux loss (identical across model shards; router replicated)
        frac = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=(0, 1))
        mean_prob = probs.mean(0)
        aux = E * jnp.sum(frac * mean_prob)
        return y, aux

    wg = params.get("wg", params["wi"])  # dummy when non-gated
    in_spec = P(model_axis, fsdp_axis, None)
    out_spec = P(model_axis, None, fsdp_axis)
    y, aux = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(batch_axes, None, None), P(None, None), in_spec, in_spec, out_spec),
        out_specs=(P(batch_axes, None, None), P()),
        check_vma=False,
    )(x, params["router"], params["wi"], wg, params["wo"])
    return y, aux


def moe_ffn_reference(params, x, cfg: MoEConfig, variant: str):
    """Drop-free dense oracle: every token processed by its top-k experts."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    logits = xt.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.top_k)
    if cfg.renormalize:
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    y = jnp.zeros_like(xt)
    for e in range(cfg.n_experts):
        wi = params["wi"].value if isinstance(params["wi"], L) else params["wi"]
        h = xt @ wi[e]
        if variant in ("swiglu", "geglu"):
            g = xt @ params["wg"][e]
            h = (jax.nn.silu(g) if variant == "swiglu" else jax.nn.gelu(g, approximate=True)) * h
        else:
            h = jax.nn.gelu(h, approximate=True)
        ye = h @ params["wo"][e]
        we = ((idx == e) * w).sum(-1).astype(ye.dtype)
        y = y + ye * we[:, None]
    return y.reshape(B, S, D)
