"""Ring attention: sequence-parallel exact attention with ppermute KV rotation.

Beyond-paper perf feature (EXPERIMENTS §Perf notes): the all-gather variant
(`attention_seq_parallel`) needs the full KV per device transiently; ring
attention keeps only one KV chunk resident, rotating chunks around the
'model' axis with `collective-permute` while accumulating the online softmax
— the same neighbor-DMA primitive as the paper's halo exchange, and XLA's
latency-hiding scheduler overlaps each hop with the current chunk's matmuls
(compute/comm overlap). Wire volume equals the all-gather; peak memory drops
by n_model x on the KV transient — which is what matters for 32k prefill.

Causal masking is positional (chunk indices move with the rotation), so the
result is exactly blocked_attention's.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P



def ring_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    mesh: Mesh, batch_axes: Tuple[str, ...], *, scale: float,
    causal: bool = True, window: int = 0, softcap: Optional[float] = None,
    q_block: int = 512, kv_block: int = 512, axis: str = "model",
) -> jnp.ndarray:
    """q,k,v: [B, S, H, D] global; S sharded over ``axis``. Exact attention."""
    n = mesh.shape[axis]
    perm = [(i, (i + 1) % n) for i in range(n)]

    def local(qs, ks, vs):
        idx = jax.lax.axis_index(axis)
        s_loc = qs.shape[1]
        q_off = idx * s_loc
        B, _, Hq, D = qs.shape
        Hkv, Dv = ks.shape[2], vs.shape[-1]
        G = Hq // Hkv

        NEG = -1e30
        m0 = jnp.full((B, Hq, s_loc), NEG, jnp.float32)
        l0 = jnp.zeros((B, Hq, s_loc), jnp.float32)
        a0 = jnp.zeros((B, Hq, s_loc, Dv), jnp.float32)

        def hop(carry, t):
            m, l, acc, kc, vc = carry
            src_idx = (idx - t) % n          # whose chunk we now hold
            kv_off = src_idx * s_loc
            # one chunk-vs-chunk blocked pass with true global offsets
            qpos = q_off + jnp.arange(s_loc)
            kpos = kv_off + jnp.arange(s_loc)
            kk = jnp.repeat(kc, G, axis=2)
            vv = jnp.repeat(vc, G, axis=2)
            s = jnp.einsum("bqhd,bkhd->bhqk", qs, kk,
                           preferred_element_type=jnp.float32) * scale
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            mask = jnp.ones((s_loc, s_loc), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if not (isinstance(window, int) and window == 0):
                w = jnp.asarray(window, jnp.int32)
                w_eff = jnp.where(w > 0, w, jnp.int32(2 ** 30))
                mask &= (qpos[:, None] - kpos[None, :]) < w_eff
            s = jnp.where(mask[None, None], s, NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(vv.dtype), vv,
                preferred_element_type=jnp.float32)
            # rotate the KV chunk to the next stage (overlappable DMA)
            kc = jax.lax.ppermute(kc, axis, perm=perm)
            vc = jax.lax.ppermute(vc, axis, perm=perm)
            return (m_new, l_new, acc_new, kc, vc), None

        (m, l, acc, _, _), _ = jax.lax.scan(hop, (m0, l0, a0, ks, vs),
                                            jnp.arange(n))
        out = acc / jnp.maximum(l, 1e-20)[..., None]          # [B,Hq,S_loc,Dv]
        return out.transpose(0, 2, 1, 3).astype(vs.dtype)

    spec = P(batch_axes, axis, None, None)
    return jax.shard_map(local, mesh=mesh, in_specs=(spec, spec, spec),
                         out_specs=spec, check_vma=False)(q, k, v)
