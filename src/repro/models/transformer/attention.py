"""Attention: blocked (flash-style) prefill/train + distributed decode.

Memory-safe by construction: scores are only ever materialized per
(q_block x kv_block) tile inside a nested ``lax.scan`` with online softmax —
required for the 32k-prefill cells where full scores would be ~TBs. The
Pallas kernel in ``repro.kernels.flash_attention`` is the TPU-optimized twin
of this function (same math, VMEM tiling + causal block pruning).

Parallel layouts (chosen per arch config):
  * ``heads``  — Q-heads sharded over 'model' via activation constraints
                 (requires n_q %% mesh model == 0: DeepSeek/DBRX/Granite).
  * ``seq``    — context parallelism via shard_map: Q sharded over 'model'
                 on the sequence dim, K/V all-gathered per layer (Llama-3.2
                 24 heads / Gemma-2 8 heads don't divide 16).
Decode uses sequence-sharded KV caches with a two-pass partial-softmax
psum combine ("distributed flash-decode") — O(S) per step, any head count.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.transformer.layers import softcap as apply_softcap

NEG = -1e30


def _window_ok(qpos, kpos, window):
    """Sliding-window predicate; ``window`` may be a python int or a traced
    scalar (0 = global attention). Shape: [len(qpos), len(kpos)] bool."""
    if isinstance(window, int) and window == 0:
        return jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    w = jnp.asarray(window, jnp.int32)
    w_eff = jnp.where(w > 0, w, jnp.int32(2 ** 30))
    return (qpos[:, None] - kpos[None, :]) < w_eff


# ---------------------------------------------------------------------------
# blocked attention (shared by train & prefill)
# ---------------------------------------------------------------------------

def blocked_attention(
    q: jnp.ndarray,                  # [B, Sq, Hq, D]
    k: jnp.ndarray,                  # [B, Skv, Hkv, D]
    v: jnp.ndarray,                  # [B, Skv, Hkv, Dv]
    *,
    scale: float,
    causal: bool = True,
    window: int = 0,                 # >0: only kv with 0 <= qpos-kpos < window
    softcap: Optional[float] = None,
    q_offset=0,                      # global position of q[0] (int or traced)
    kv_offset=0,
    q_block: int = 512,
    kv_block: int = 512,
) -> jnp.ndarray:
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = Hq // Hkv
    qb = min(q_block, Sq)
    kb = min(kv_block, Skv)
    nq, nk = -(-Sq // qb), -(-Skv // kb)
    # pad seq dims to block multiples
    q = jnp.pad(q, ((0, 0), (0, nq * qb - Sq), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, nk * kb - Skv), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, nk * kb - Skv), (0, 0), (0, 0)))

    # [B, Hkv, G, S, D] layout
    qh = q.reshape(B, nq, qb, Hkv, G, D).transpose(1, 0, 3, 4, 2, 5)   # [nq,B,Hkv,G,qb,D]
    kh = k.reshape(B, nk, kb, Hkv, D).transpose(1, 0, 3, 2, 4)         # [nk,B,Hkv,kb,D]
    vh = v.reshape(B, nk, kb, Hkv, Dv).transpose(1, 0, 3, 2, 4)

    qpos_base = jnp.arange(qb)
    kpos_base = jnp.arange(kb)

    def q_step(_, qi_and_i):
        qi, i = qi_and_i
        qpos = q_offset + i * qb + qpos_base                          # [qb]

        def kv_step(carry, kj_and_j):
            m, l, acc = carry
            (kj, vj), j = kj_and_j
            kpos = kv_offset + j * kb + kpos_base                     # [kb]
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            if softcap is not None:
                s = apply_softcap(s, softcap)
            mask = _window_ok(qpos, kpos, window)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            mask &= (kpos < kv_offset + Skv)[None, :]
            s = jnp.where(mask[None, None, None], s, NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, qb), NEG, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qb), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, qb, Dv), jnp.float32)
        # checkpoint the tile body: backward recomputes the score tile instead
        # of storing every [qb, kb] block (flash-attention memory behavior)
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (m0, l0, a0), ((kh, vh), jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-20)[..., None]                  # [B,Hkv,G,qb,Dv]
        return None, out

    _, outs = jax.lax.scan(q_step, None, (qh, jnp.arange(nq)))        # [nq,B,Hkv,G,qb,Dv]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * qb, Hq, Dv)
    return out[:, :Sq].astype(v.dtype)


def attention_seq_parallel(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    mesh: Mesh, batch_axes: Tuple[str, ...], *, scale: float,
    causal: bool = True, window: int = 0, softcap: Optional[float] = None,
    q_block: int = 512, kv_block: int = 512,
) -> jnp.ndarray:
    """Context-parallel blocked attention: Q seq-sharded over 'model',
    K/V all-gathered inside the shard (one tiled all-gather per layer)."""

    def local(qs, ks, vs):
        ks = jax.lax.all_gather(ks, "model", axis=1, tiled=True)
        vs = jax.lax.all_gather(vs, "model", axis=1, tiled=True)
        idx = jax.lax.axis_index("model")
        off = idx * qs.shape[1]
        return blocked_attention(qs, ks, vs, scale=scale, causal=causal,
                                 window=window, softcap=softcap,
                                 q_offset=off, kv_offset=0,
                                 q_block=q_block, kv_block=kv_block)

    spec_q = P(batch_axes, "model", None, None)
    spec_kv = P(batch_axes, "model", None, None)
    return jax.shard_map(local, mesh=mesh,
                         in_specs=(spec_q, spec_kv, spec_kv),
                         out_specs=spec_q, check_vma=False)(q, k, v)


# ---------------------------------------------------------------------------
# distributed decode (sequence-sharded KV cache)
# ---------------------------------------------------------------------------

def _combine_partials(o, m, l, axes):
    """Merge per-shard (out, max, sumexp) partial softmaxes via psum."""
    m_max = jax.lax.pmax(m, axes)
    corr = jnp.exp(m - m_max)
    l_tot = jax.lax.psum(l * corr, axes)
    o_tot = jax.lax.psum(o * corr[..., None], axes)
    return o_tot / jnp.maximum(l_tot, 1e-20)[..., None]


def _local_decode_scores(q, kc, vc, kpos, cache_len, *, scale, window, softcap):
    """q: [B,Hq,D]; kc/vc: [B,Sloc,Hkv,D]; kpos: [Sloc] global positions."""
    B, Sloc, Hkv, D = kc.shape
    Hq = q.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, kc,
                   preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = apply_softcap(s, softcap)
    valid = kpos < cache_len
    if not (isinstance(window, int) and window == 0):
        w = jnp.asarray(window, jnp.int32)
        w_eff = jnp.where(w > 0, w, jnp.int32(2 ** 30))
        valid &= kpos >= (cache_len - w_eff)
    s = jnp.where(valid[None, None, None, :], s, NEG)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p.astype(vc.dtype), vc,
                   preferred_element_type=jnp.float32)
    return o, m, l


def decode_attention_sharded(
    q: jnp.ndarray,                  # [B, Hq, D] one new token per sequence
    k_cache: jnp.ndarray,            # [B, S, Hkv, D]  (seq dim sharded)
    v_cache: jnp.ndarray,
    k_new: jnp.ndarray,              # [B, Hkv, D]
    v_new: jnp.ndarray,
    cache_len: jnp.ndarray,          # scalar int32: tokens already in cache
    mesh: Mesh,
    batch_axes: Tuple[str, ...],
    seq_axes: Tuple[str, ...] = ("model",),
    *, scale: float, window: int = 0, softcap: Optional[float] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Distributed flash-decode: partial softmax per seq shard + psum combine.

    Also writes (k_new, v_new) at position ``cache_len`` (which lives on
    exactly one shard). Returns (attn_out [B,Hq,Dv], k_cache', v_cache').
    """
    S = k_cache.shape[1]
    n_shards = 1
    for ax in seq_axes:
        n_shards *= mesh.shape[ax]
    s_loc = S // n_shards

    def local(qs, kc, vc, kn, vn, clen):
        idx = jnp.zeros((), jnp.int32)
        mult = 1
        for ax in reversed(seq_axes):
            idx = idx + jax.lax.axis_index(ax) * mult
            mult *= mesh.shape[ax]
        start = idx * s_loc
        # --- cache insert (one shard owns position clen) ---
        li = jnp.clip(clen - start, 0, s_loc - 1)
        mine = (clen >= start) & (clen < start + s_loc)
        old_k = jax.lax.dynamic_slice_in_dim(kc, li, 1, axis=1)
        old_v = jax.lax.dynamic_slice_in_dim(vc, li, 1, axis=1)
        upd_k = jnp.where(mine, kn[:, None], old_k)
        upd_v = jnp.where(mine, vn[:, None], old_v)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, upd_k, li, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, upd_v, li, axis=1)
        # --- partial attention over local slice (cache now holds clen+1) ---
        kpos = start + jnp.arange(s_loc)
        o, m, l = _local_decode_scores(qs, kc, vc, kpos, clen + 1,
                                       scale=scale, window=window, softcap=softcap)
        out = _combine_partials(o, m, l, seq_axes)
        B, Hkv, G, Dv = out.shape[0], out.shape[1], out.shape[2], out.shape[3]
        return out.reshape(B, Hkv * G, Dv).astype(v_cache.dtype), kc, vc

    cspec = P(batch_axes, seq_axes, None, None)
    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(batch_axes, None, None), cspec, cspec,
                  P(batch_axes, None, None), P(batch_axes, None, None), P()),
        out_specs=(P(batch_axes, None, None), cspec, cspec),
        check_vma=False,
    )(q, k_cache, v_cache, k_new, v_new, cache_len)


# ---------------------------------------------------------------------------
# MLA decode (absorbed form, compressed cache) — DeepSeek-V2
# ---------------------------------------------------------------------------

def mla_decode_attention_sharded(
    q_lat: jnp.ndarray,              # [B, H, kv_lora] q_nope absorbed through Wk_b
    q_rope: jnp.ndarray,             # [B, H, rope_dim]
    ckv_cache: jnp.ndarray,          # [B, S, kv_lora]   (seq sharded)
    krope_cache: jnp.ndarray,        # [B, S, rope_dim]
    ckv_new: jnp.ndarray,            # [B, kv_lora]
    krope_new: jnp.ndarray,          # [B, rope_dim]
    cache_len: jnp.ndarray,
    mesh: Mesh,
    batch_axes: Tuple[str, ...],
    seq_axes: Tuple[str, ...] = ("model",),
    *, scale: float,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (attn latent out [B,H,kv_lora], ckv', krope')."""
    S = ckv_cache.shape[1]
    n_shards = 1
    for ax in seq_axes:
        n_shards *= mesh.shape[ax]
    s_loc = S // n_shards

    def local(ql, qr, ckv, kr, cn, rn, clen):
        idx = jnp.zeros((), jnp.int32)
        mult = 1
        for ax in reversed(seq_axes):
            idx = idx + jax.lax.axis_index(ax) * mult
            mult *= mesh.shape[ax]
        start = idx * s_loc
        li = jnp.clip(clen - start, 0, s_loc - 1)
        mine = (clen >= start) & (clen < start + s_loc)
        ckv = jax.lax.dynamic_update_slice_in_dim(
            ckv, jnp.where(mine, cn[:, None], jax.lax.dynamic_slice_in_dim(ckv, li, 1, 1)), li, 1)
        kr = jax.lax.dynamic_update_slice_in_dim(
            kr, jnp.where(mine, rn[:, None], jax.lax.dynamic_slice_in_dim(kr, li, 1, 1)), li, 1)
        kpos = start + jnp.arange(s_loc)
        s = (jnp.einsum("bhc,bsc->bhs", ql, ckv, preferred_element_type=jnp.float32)
             + jnp.einsum("bhr,bsr->bhs", qr, kr, preferred_element_type=jnp.float32)) * scale
        s = jnp.where((kpos < clen + 1)[None, None, :], s, NEG)
        m = s.max(axis=-1)
        p = jnp.exp(s - m[..., None])
        l = p.sum(axis=-1)
        o = jnp.einsum("bhs,bsc->bhc", p.astype(ckv.dtype), ckv,
                       preferred_element_type=jnp.float32)
        out = _combine_partials(o, m, l, seq_axes)
        return out.astype(ckv_cache.dtype), ckv, kr

    cspec2 = P(batch_axes, seq_axes, None)
    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(batch_axes, None, None), P(batch_axes, None, None),
                  cspec2, cspec2, P(batch_axes, None), P(batch_axes, None), P()),
        out_specs=(P(batch_axes, None, None), cspec2, cspec2),
        check_vma=False,
    )(q_lat, q_rope, ckv_cache, krope_cache, ckv_new, krope_new, cache_len)
