"""Jit-ready LM steps: train (AdamW + grad accumulation), prefill, decode —
with the input/state ShapeDtypeStructs and PartitionSpecs the launcher and
multi-pod dry-run consume.
"""
from __future__ import annotations

import functools
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.transformer.config import TransformerConfig
from repro.models.transformer.model import (
    ParallelCtx, cache_specs, decode_step, init_cache,
    init_transformer, lm_loss, prefill_step,
)
from repro.sharding import split_tree
from repro.train.optimizer import AdamWConfig, adamw_update, init_adamw


# ---------------------------------------------------------------------------
# state construction (shape-only or concrete)
# ---------------------------------------------------------------------------

def lm_param_specs(cfg: TransformerConfig, ctx: ParallelCtx):
    """(param ShapeDtypeStructs, PartitionSpec tree) without allocating."""
    tree_sds = jax.eval_shape(functools.partial(init_transformer, cfg=cfg),
                              jax.random.PRNGKey(0))
    return split_tree(tree_sds, ctx.rules, ctx.mesh)


def lm_train_state_specs(cfg: TransformerConfig, ctx: ParallelCtx, opt: AdamWConfig):
    params_sds, pspecs = lm_param_specs(cfg, ctx)
    master = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params_sds)
    opt_sds = jax.eval_shape(functools.partial(init_adamw, cfg=opt), master)
    state_sds = {"params": master, "opt": opt_sds}
    state_specs = {
        "params": pspecs,
        "opt": {"m": pspecs, "v": pspecs, "step": P()},
    }
    return state_sds, state_specs


def lm_init_train_state(key, cfg: TransformerConfig, opt: AdamWConfig):
    tree = init_transformer(key, cfg)
    params, _ = split_tree(tree, {})
    master = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    return {"params": master, "opt": init_adamw(master, opt)}


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------

def make_train_step(cfg: TransformerConfig, ctx: ParallelCtx, opt: AdamWConfig,
                    n_micro: int = 1, cast_per_micro: bool = False,
                    accum_dtype=jnp.float32):
    """(state, tokens [B,S], targets [B,S]) -> (state', metrics).

    With n_micro > 1 the batch is split into micro-batches scanned with
    gradient accumulation — the memory lever that fits 4k-seq training of the
    large configs into v5e HBM (see EXPERIMENTS.md §Dry-run).

    ``cast_per_micro=False`` (default after the §Perf iteration) casts the
    fp32 master weights to bf16 ONCE per step, outside the micro-batch scan;
    casting inside the loop (=True, the naive formulation) re-reads the full
    fp32 master and re-materializes the bf16 copy n_micro times per step.
    Gradients w.r.t. the bf16 compute params equal the master gradients
    (astype's JVP is the identity cast).
    """

    def cast(master):
        return jax.tree.map(
            lambda x: x.astype(cfg.param_dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, master)

    def loss_from_master(master, tokens, targets):
        return lm_loss(cast(master), tokens, targets, cfg, ctx)[0]

    def loss_from_compute(params_c, tokens, targets):
        return lm_loss(params_c, tokens, targets, cfg, ctx)[0]

    def step(state, tokens, targets):
        master = state["params"]
        if n_micro > 1:
            B = tokens.shape[0]
            tk = tokens.reshape(n_micro, B // n_micro, -1)
            tg = targets.reshape(n_micro, B // n_micro, -1)
            params_c = None if cast_per_micro else cast(master)

            def body(carry, xs):
                acc_l, acc_g = carry
                if cast_per_micro:
                    l, g = jax.value_and_grad(loss_from_master)(master, xs[0], xs[1])
                else:
                    l, g = jax.value_and_grad(loss_from_compute)(params_c, xs[0], xs[1])
                g = jax.tree.map(lambda a, b: a + b.astype(accum_dtype), acc_g, g)
                return (acc_l + l, g), None

            zero = (jnp.zeros((), jnp.float32),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), master))
            (loss, grads), _ = jax.lax.scan(body, zero, (tk, tg))
            loss = loss / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, grads)
        else:
            loss, grads = jax.value_and_grad(loss_from_master)(master, tokens, targets)
        new_params, new_opt, info = adamw_update(grads, state["opt"], master, opt)
        return {"params": new_params, "opt": new_opt}, {"loss": loss, **info}

    return step


def make_prefill_step(cfg: TransformerConfig, ctx: ParallelCtx, capacity: int):
    def step(params, tokens):
        return prefill_step(params, tokens, cfg, ctx, capacity=capacity)
    return step


def make_decode_step(cfg: TransformerConfig, ctx: ParallelCtx):
    def step(params, cache, tokens, cache_len):
        return decode_step(params, cache, tokens, cache_len, cfg, ctx)
    return step


# ---------------------------------------------------------------------------
# input specs for the dry-run / launcher
# ---------------------------------------------------------------------------

def lm_input_specs(cfg: TransformerConfig, ctx: ParallelCtx, shape: dict):
    """ShapeDtypeStructs + PartitionSpecs for one LM shape cell."""
    B, S = shape["global_batch"], shape["seq_len"]
    batch_axes = ctx.batch_axes if B > 1 else ()
    tok_spec = P(batch_axes or None, None)
    kind = shape["kind"]
    if kind == "train":
        tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
        return {"tokens": (tokens, tok_spec), "targets": (tokens, tok_spec)}
    if kind == "prefill":
        tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
        return {"tokens": (tokens, tok_spec)}
    if kind == "decode":
        tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        cache_sds = jax.eval_shape(
            lambda: init_cache(cfg, B, S, dtype=cfg.cache_dtype))
        cspecs = cache_specs(cfg, ParallelCtx(ctx.mesh, batch_axes or ctx.batch_axes,
                                              ctx.rules), B)
        return {"tokens": (tokens, tok_spec),
                "cache": (cache_sds, cspecs),
                "cache_len": (jax.ShapeDtypeStruct((), jnp.int32), P())}
    raise ValueError(kind)
