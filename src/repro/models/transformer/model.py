"""Transformer LM: init + train/prefill/decode forwards for all 5 assigned archs.

Layers are stacked and executed under ``jax.lax.scan`` (O(1)-layer HLO: the
512-device dry-run compiles in seconds; the roofline analyzer multiplies
while-body costs by the trip count). Remat policy wraps the scan body.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.transformer.attention import (
    attention_seq_parallel, blocked_attention, decode_attention_sharded,
    mla_decode_attention_sharded,
)
from repro.models.transformer.config import TransformerConfig
from repro.models.transformer.layers import (
    apply_rope, ffn, init_ffn, init_rmsnorm, rmsnorm, softcap,
)
from repro.models.transformer.moe import init_moe, moe_ffn
from repro.sharding import L, Rules, shard_act, stack_dims


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    """Mesh + axis naming used by shard_map sub-blocks and act constraints."""
    mesh: Optional[Mesh]
    batch_axes: Tuple[str, ...] = ("data",)
    rules: Rules = dataclasses.field(default_factory=dict)

    @staticmethod
    def single_device() -> "ParallelCtx":
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        return ParallelCtx(mesh=mesh, batch_axes=("data",), rules={})


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_attn(key, cfg: TransformerConfig, dtype):
    d, hq, hkv, hd = cfg.d_model, cfg.n_q, cfg.n_kv, cfg.head_dim
    ks = jax.random.split(key, 8)
    s = d ** -0.5
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "wq_a": L(jax.random.normal(ks[0], (d, m.q_lora), dtype) * s, ("embed", "q_lora")),
            "q_norm": init_rmsnorm(m.q_lora, jnp.float32) | {},
            "wq_b": L(jax.random.normal(ks[1], (m.q_lora, hq, m.qk_dim), dtype) * m.q_lora ** -0.5,
                      ("q_lora", "heads", "head_dim")),
            "wkv_a": L(jax.random.normal(ks[2], (d, m.kv_lora + m.qk_rope), dtype) * s,
                       ("embed", "kv_lora")),
            "kv_norm": init_rmsnorm(m.kv_lora, jnp.float32),
            "wk_b": L(jax.random.normal(ks[3], (m.kv_lora, hq, m.qk_nope), dtype) * m.kv_lora ** -0.5,
                      ("kv_lora", "heads", "head_dim")),
            "wv_b": L(jax.random.normal(ks[4], (m.kv_lora, hq, m.v_dim), dtype) * m.kv_lora ** -0.5,
                      ("kv_lora", "heads", "head_dim")),
            "wo": L(jax.random.normal(ks[5], (hq, m.v_dim, d), dtype) * (hq * m.v_dim) ** -0.5,
                    ("heads", "head_dim", "embed")),
        }
    return {
        "wq": L(jax.random.normal(ks[0], (d, hq, hd), dtype) * s, ("embed", "heads", "head_dim")),
        "wk": L(jax.random.normal(ks[1], (d, hkv, hd), dtype) * s, ("embed", "kv_heads", "head_dim")),
        "wv": L(jax.random.normal(ks[2], (d, hkv, hd), dtype) * s, ("embed", "kv_heads", "head_dim")),
        "wo": L(jax.random.normal(ks[3], (hq, hd, d), dtype) * (hq * hd) ** -0.5,
                ("heads", "head_dim", "embed")),
    }


def _init_layer(key, cfg: TransformerConfig, moe_layer: bool, dense_ff: int):
    ka, kf, ksh = jax.random.split(key, 3)
    dtype = cfg.param_dtype
    p = {
        "attn": _init_attn(ka, cfg, dtype),
        "ln_attn_pre": init_rmsnorm(cfg.d_model),
        "ln_mlp_pre": init_rmsnorm(cfg.d_model),
    }
    if cfg.post_norms:
        p["ln_attn_post"] = init_rmsnorm(cfg.d_model)
        p["ln_mlp_post"] = init_rmsnorm(cfg.d_model)
    if moe_layer:
        p["moe"] = init_moe(kf, cfg.d_model, cfg.moe, cfg.mlp_variant, dtype)
        if cfg.moe.n_shared:
            p["shared"] = init_ffn(ksh, cfg.d_model, cfg.moe.d_ff * cfg.moe.n_shared,
                                   cfg.mlp_variant, dtype)
    else:
        p["ffn"] = init_ffn(kf, cfg.d_model, dense_ff, cfg.mlp_variant, dtype)
    return p


def init_transformer(key, cfg: TransformerConfig):
    """Returns a tree of L leaves (use sharding.split_tree to get params+specs)."""
    k_emb, k_lay, k_dense, k_un = jax.random.split(key, 4)
    dtype = cfg.param_dtype
    n_dense = cfg.moe.first_dense_layers if cfg.moe else 0
    n_scan = cfg.n_layers - n_dense

    layer_init = functools.partial(_init_layer, cfg=cfg,
                                   moe_layer=cfg.moe is not None,
                                   dense_ff=cfg.d_ff)
    layers = jax.vmap(layer_init)(jax.random.split(k_lay, n_scan))
    layers = stack_dims("layers", layers)

    p = {
        "embed": L(jax.random.normal(k_emb, (cfg.vocab, cfg.d_model), dtype)
                   * cfg.d_model ** -0.5, ("vocab", "embed")),
        "layers": layers,
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    if n_dense:
        dense_init = functools.partial(_init_layer, cfg=cfg, moe_layer=False,
                                       dense_ff=cfg.moe.first_dense_ff or cfg.d_ff)
        dense = jax.vmap(dense_init)(jax.random.split(k_dense, n_dense))
        p["dense_layers"] = stack_dims("layers", dense)
    if not cfg.tied_embeddings:
        p["unembed"] = L(jax.random.normal(k_un, (cfg.d_model, cfg.vocab), dtype)
                         * cfg.d_model ** -0.5, ("embed", "vocab"))
    return p


# ---------------------------------------------------------------------------
# attention blocks (train/prefill)
# ---------------------------------------------------------------------------

def _qkv_gqa(p, x, cfg: TransformerConfig, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _qkv_mla(p, x, cfg: TransformerConfig, positions):
    m = cfg.mla
    ql = rmsnorm(p["q_norm"], x @ p["wq_a"], cfg.norm_eps)
    q = jnp.einsum("bsl,lhk->bshk", ql, p["wq_b"])            # [B,S,H,qk_dim]
    q_nope, q_rope = q[..., :m.qk_nope], q[..., m.qk_nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    kv = x @ p["wkv_a"]                                        # [B,S,kv_lora+rope]
    ckv = rmsnorm(p["kv_norm"], kv[..., :m.kv_lora], cfg.norm_eps)
    k_rope = apply_rope(kv[..., None, m.kv_lora:], positions, cfg.rope_theta)
    k_nope = jnp.einsum("bsl,lhk->bshk", ckv, p["wk_b"])
    v = jnp.einsum("bsl,lhk->bshk", ckv, p["wv_b"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:-1] + (m.qk_rope,))],
                        axis=-1)
    return q, k, v, ckv, k_rope[:, :, 0]


def attn_block(p, x, cfg: TransformerConfig, ctx: ParallelCtx, window):
    B, S, D = x.shape
    positions = jnp.arange(S)[None]
    if cfg.mla is not None:
        q, k, v, _, _ = _qkv_mla(p, x, cfg, positions)
        scale = cfg.mla.qk_dim ** -0.5
    else:
        q, k, v = _qkv_gqa(p, x, cfg, positions)
        scale = cfg.head_dim ** -0.5
        vd = cfg.head_dim

    multi_model = ctx.mesh is not None and ctx.mesh.shape.get("model", 1) > 1
    if cfg.attn_parallel == "ring" and multi_model:
        from repro.models.transformer.ring_attention import ring_attention
        out = ring_attention(q, k, v, ctx.mesh, ctx.batch_axes, scale=scale,
                             causal=True, window=window, softcap=cfg.attn_softcap,
                             q_block=cfg.q_block, kv_block=cfg.kv_block)
    elif cfg.attn_parallel == "seq" and multi_model:
        out = attention_seq_parallel(q, k, v, ctx.mesh, ctx.batch_axes,
                                     scale=scale, causal=True, window=window,
                                     softcap=cfg.attn_softcap,
                                     q_block=cfg.q_block, kv_block=cfg.kv_block)
    else:
        q = shard_act(q, ("act_batch", None, "act_heads", None), ctx.rules, ctx.mesh)
        k = shard_act(k, ("act_batch", None, "act_kv_heads", None), ctx.rules, ctx.mesh)
        v = shard_act(v, ("act_batch", None, "act_kv_heads", None), ctx.rules, ctx.mesh)
        out = blocked_attention(q, k, v, scale=scale, causal=True, window=window,
                                softcap=cfg.attn_softcap,
                                q_block=cfg.q_block, kv_block=cfg.kv_block)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ---------------------------------------------------------------------------
# layer + model forward (train/score)
# ---------------------------------------------------------------------------

def _ffn_block(p_l, h, cfg, ctx):
    if "moe" in p_l:
        fsdp = ctx.rules.get("embed")
        fsdp = fsdp if isinstance(fsdp, str) and ctx.mesh is not None \
            and ctx.mesh.shape.get(fsdp, 1) > 1 else None
        f, aux = moe_ffn(p_l["moe"], h, cfg.moe, cfg.mlp_variant, ctx.mesh,
                         ctx.batch_axes, fsdp_axis=fsdp)
        if "shared" in p_l:
            f = f + ffn(p_l["shared"], h, cfg.mlp_variant)
    else:
        f, aux = ffn(p_l["ffn"], h, cfg.mlp_variant), jnp.zeros((), jnp.float32)
    return f, aux


def layer_fn(p_l, x, window, cfg: TransformerConfig, ctx: ParallelCtx):
    h = rmsnorm(p_l["ln_attn_pre"], x, cfg.norm_eps)
    a = attn_block(p_l["attn"], h, cfg, ctx, window)
    if cfg.post_norms:
        a = rmsnorm(p_l["ln_attn_post"], a, cfg.norm_eps)
    x = x + a
    x = shard_act(x, ("act_batch", None, None), ctx.rules, ctx.mesh)
    h = rmsnorm(p_l["ln_mlp_pre"], x, cfg.norm_eps)
    f, aux = _ffn_block(p_l, h, cfg, ctx)
    if cfg.post_norms:
        f = rmsnorm(p_l["ln_mlp_post"], f, cfg.norm_eps)
    x = x + f
    x = shard_act(x, ("act_batch", None, None), ctx.rules, ctx.mesh)
    return x, aux


def _remat(fn, cfg: TransformerConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)  # "full": save nothing


def _scan_layers(stack, x, cfg, ctx, windows):
    body = _remat(lambda xc, p_w: layer_fn(p_w[0], xc, p_w[1], cfg, ctx), cfg)

    def step(xc, p_w):
        xn, aux = body(xc, p_w)
        return xn, aux

    x, auxs = jax.lax.scan(step, x, (stack, windows))
    return x, auxs.sum()


def forward(params, tokens, cfg: TransformerConfig, ctx: ParallelCtx):
    """tokens [B,S] -> logits [B,S,V] (+ MoE aux loss)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.gemma_norm:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = shard_act(x, ("act_batch", None, None), ctx.rules, ctx.mesh)

    windows = jnp.asarray(cfg.layer_windows, jnp.int32)
    aux = jnp.zeros((), jnp.float32)
    n_dense = cfg.moe.first_dense_layers if cfg.moe else 0
    if n_dense:
        x, a0 = _scan_layers(params["dense_layers"], x, cfg, ctx, windows[:n_dense])
        aux += a0
    x, a1 = _scan_layers(params["layers"], x, cfg, ctx, windows[n_dense:])
    aux += a1

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    un = params["embed"].T if cfg.tied_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", x, un)
    logits = softcap(logits, cfg.final_softcap)
    logits = shard_act(logits, ("act_batch", None, "act_vocab"), ctx.rules, ctx.mesh)
    return logits, aux


def lm_loss(params, tokens, targets, cfg: TransformerConfig, ctx: ParallelCtx,
            z_coef: float = 1e-4):
    logits, aux = forward(params, tokens, cfg, ctx)
    logits = logits.astype(jnp.float32)
    z = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = (z - ll).mean()
    zloss = z_coef * jnp.square(z).mean()
    moe_aux = (cfg.moe.aux_coef * aux / cfg.n_layers) if cfg.moe else 0.0
    return ce + zloss + moe_aux, {"ce": ce, "z": zloss}


# ---------------------------------------------------------------------------
# KV cache: init / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg: TransformerConfig, batch: int, capacity: int, dtype=None):
    dtype = dtype or cfg.cache_dtype
    n_scan = cfg.n_layers - (cfg.moe.first_dense_layers if cfg.moe else 0)
    n_dense = cfg.n_layers - n_scan
    def mk(n):
        if cfg.mla is not None:
            return {
                "ckv": jnp.zeros((n, batch, capacity, cfg.mla.kv_lora), dtype),
                "krope": jnp.zeros((n, batch, capacity, cfg.mla.qk_rope), dtype),
            }
        return {
            "k": jnp.zeros((n, batch, capacity, cfg.n_kv, cfg.head_dim), dtype),
            "v": jnp.zeros((n, batch, capacity, cfg.n_kv, cfg.head_dim), dtype),
        }
    cache = {"layers": mk(n_scan)}
    if n_dense:
        cache["dense_layers"] = mk(n_dense)
    return cache


def cache_specs(cfg: TransformerConfig, ctx: ParallelCtx, batch: int):
    """PartitionSpecs for the cache pytree (seq dim sharded for decode)."""
    seq = cfg.seq_shard_decode
    b_axes = ctx.batch_axes if batch > 1 else None
    def mk():
        if cfg.mla is not None:
            return {"ckv": P(None, b_axes, seq, None), "krope": P(None, b_axes, seq, None)}
        return {"k": P(None, b_axes, seq, None, None), "v": P(None, b_axes, seq, None, None)}
    out = {"layers": mk()}
    if cfg.moe and cfg.moe.first_dense_layers:
        out["dense_layers"] = mk()
    return out


def _decode_layer(p_l, x, cache_l, cache_len, window, cfg, ctx):
    """x: [B,1,D]; cache_l: per-layer cache slice. Returns (x', cache_l')."""
    B = x.shape[0]
    h = rmsnorm(p_l["ln_attn_pre"], x, cfg.norm_eps)
    positions = jnp.full((B, 1), cache_len)
    seq_axes = cfg.seq_shard_decode
    if cfg.mla is not None:
        m = cfg.mla
        ql = rmsnorm(p_l["attn"]["q_norm"], h @ p_l["attn"]["wq_a"], cfg.norm_eps)
        q = jnp.einsum("bsl,lhk->bshk", ql, p_l["attn"]["wq_b"])
        q_nope, q_rope = q[..., :m.qk_nope], q[..., m.qk_nope:]
        q_rope = apply_rope(q_rope, positions, cfg.rope_theta)[:, 0]
        q_lat = jnp.einsum("bhk,lhk->bhl", q_nope[:, 0], p_l["attn"]["wk_b"])
        kv = h @ p_l["attn"]["wkv_a"]
        ckv_new = rmsnorm(p_l["attn"]["kv_norm"], kv[..., :m.kv_lora], cfg.norm_eps)[:, 0]
        krope_new = apply_rope(kv[..., None, m.kv_lora:], positions, cfg.rope_theta)[:, 0, 0]
        out_lat, ckv, krope = mla_decode_attention_sharded(
            q_lat.astype(x.dtype), q_rope.astype(x.dtype),
            cache_l["ckv"], cache_l["krope"],
            ckv_new.astype(cache_l["ckv"].dtype), krope_new.astype(cache_l["krope"].dtype),
            cache_len, ctx.mesh, ctx.batch_axes, seq_axes, scale=m.qk_dim ** -0.5)
        out = jnp.einsum("bhl,lhk->bhk", out_lat, p_l["attn"]["wv_b"])
        a = jnp.einsum("bhk,hkd->bd", out, p_l["attn"]["wo"])[:, None]
        new_cache = {"ckv": ckv, "krope": krope}
    else:
        q = jnp.einsum("bsd,dhk->bshk", h, p_l["attn"]["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, p_l["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, p_l["attn"]["wv"])
        q = apply_rope(q, positions, cfg.rope_theta)[:, 0]
        k = apply_rope(k, positions, cfg.rope_theta)[:, 0]
        out, kc, vc = decode_attention_sharded(
            q, cache_l["k"], cache_l["v"], k.astype(cache_l["k"].dtype),
            v[:, 0].astype(cache_l["v"].dtype), cache_len,
            ctx.mesh, ctx.batch_axes, seq_axes,
            scale=cfg.head_dim ** -0.5, window=window, softcap=cfg.attn_softcap)
        a = jnp.einsum("bhk,hkd->bd", out, p_l["attn"]["wo"])[:, None]
        new_cache = {"k": kc, "v": vc}
    if cfg.post_norms:
        a = rmsnorm(p_l["ln_attn_post"], a, cfg.norm_eps)
    x = x + a
    h = rmsnorm(p_l["ln_mlp_pre"], x, cfg.norm_eps)
    f, _ = _ffn_block(p_l, h, cfg, ctx)
    if cfg.post_norms:
        f = rmsnorm(p_l["ln_mlp_post"], f, cfg.norm_eps)
    return x + f, new_cache


def decode_step(params, cache, tokens, cache_len, cfg: TransformerConfig, ctx: ParallelCtx):
    """One decode step: tokens [B,1] + cache -> (logits [B,1,V], cache')."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.gemma_norm:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    windows = jnp.asarray(cfg.layer_windows, jnp.int32)
    n_dense = cfg.moe.first_dense_layers if cfg.moe else 0

    def scan_group(stack, cache_g, x, wins):
        def step(xc, pw_cache):
            p_l, w, c_l = pw_cache
            xn, c_new = _decode_layer(p_l, xc, c_l, cache_len, w, cfg, ctx)
            return xn, c_new
        x, new_cache = jax.lax.scan(step, x, (stack, wins, cache_g))
        return x, new_cache

    new_cache = {}
    if n_dense:
        x, nc = scan_group(params["dense_layers"], cache["dense_layers"], x, windows[:n_dense])
        new_cache["dense_layers"] = nc
    x, nc = scan_group(params["layers"], cache["layers"], x, windows[n_dense:])
    new_cache["layers"] = nc

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    un = params["embed"].T if cfg.tied_embeddings else params["unembed"]
    logits = softcap(jnp.einsum("bsd,dv->bsv", x, un), cfg.final_softcap)
    return logits, new_cache


def prefill_step(params, tokens, cfg: TransformerConfig, ctx: ParallelCtx,
                 capacity: Optional[int] = None):
    """tokens [B,S] -> (last-position logits [B,V], cache at len S).

    Runs the blocked train-style forward; K/V (or MLA latents) per layer are
    collected as scan outputs, padded to cache capacity.
    """
    B, S = tokens.shape
    capacity = capacity or S
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.gemma_norm:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = shard_act(x, ("act_batch", None, None), ctx.rules, ctx.mesh)
    positions = jnp.arange(S)[None]
    windows = jnp.asarray(cfg.layer_windows, jnp.int32)
    n_dense = cfg.moe.first_dense_layers if cfg.moe else 0

    def layer_with_cache(p_l, xc, w):
        h = rmsnorm(p_l["ln_attn_pre"], xc, cfg.norm_eps)
        if cfg.mla is not None:
            q, k, v, ckv, krope = _qkv_mla(p_l["attn"], h, cfg, positions)
            scale = cfg.mla.qk_dim ** -0.5
            cache_out = {
                "ckv": jnp.pad(ckv, ((0, 0), (0, capacity - S), (0, 0))).astype(cfg.cache_dtype),
                "krope": jnp.pad(krope, ((0, 0), (0, capacity - S), (0, 0))).astype(cfg.cache_dtype),
            }
        else:
            q, k, v = _qkv_gqa(p_l["attn"], h, cfg, positions)
            scale = cfg.head_dim ** -0.5
            cache_out = {
                "k": jnp.pad(k, ((0, 0), (0, capacity - S), (0, 0), (0, 0))).astype(cfg.cache_dtype),
                "v": jnp.pad(v, ((0, 0), (0, capacity - S), (0, 0), (0, 0))).astype(cfg.cache_dtype),
            }
        if cfg.attn_parallel == "seq" and ctx.mesh is not None and ctx.mesh.shape.get("model", 1) > 1:
            out = attention_seq_parallel(q, k, v, ctx.mesh, ctx.batch_axes, scale=scale,
                                         causal=True, window=w, softcap=cfg.attn_softcap,
                                         q_block=cfg.q_block, kv_block=cfg.kv_block)
        else:
            out = blocked_attention(q, k, v, scale=scale, causal=True, window=w,
                                    softcap=cfg.attn_softcap,
                                    q_block=cfg.q_block, kv_block=cfg.kv_block)
        a = jnp.einsum("bshk,hkd->bsd", out, p_l["attn"]["wo"])
        if cfg.post_norms:
            a = rmsnorm(p_l["ln_attn_post"], a, cfg.norm_eps)
        xc = xc + a
        h2 = rmsnorm(p_l["ln_mlp_pre"], xc, cfg.norm_eps)
        f, _ = _ffn_block(p_l, h2, cfg, ctx)
        if cfg.post_norms:
            f = rmsnorm(p_l["ln_mlp_post"], f, cfg.norm_eps)
        return xc + f, cache_out

    def scan_group(stack, x, wins):
        body = _remat(lambda xc, pw: layer_with_cache(pw[0], xc, pw[1]), cfg)
        return jax.lax.scan(lambda xc, pw: body(xc, pw), x, (stack, wins))

    cache = {}
    if n_dense:
        x, c0 = scan_group(params["dense_layers"], x, windows[:n_dense])
        cache["dense_layers"] = c0
    x, c1 = scan_group(params["layers"], x, windows[n_dense:])
    cache["layers"] = c1

    x = rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    un = params["embed"].T if cfg.tied_embeddings else params["unembed"]
    logits = softcap(jnp.einsum("bsd,dv->bsv", x, un), cfg.final_softcap)
    return logits[:, 0], cache
