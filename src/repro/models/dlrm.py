"""DLRM RM2 [arXiv:1906.00091]: sparse embedding bags + dot interaction + MLPs.

JAX has no EmbeddingBag or CSR sparse — per the assignment, lookup is built
from ``jnp.take`` + ``jax.ops.segment_sum``. Production sharding is the
classic DLRM hybrid: MLPs data-parallel, embedding tables *row-sharded* over
the 'model' axis inside a shard_map — each shard looks up the rows it owns
(out-of-range hits contribute zero) and a single psum combines, which is the
TPU-native equivalent of the all-to-all exchange in the reference HPC
implementation. ``retrieval_score`` serves the 1M-candidate cell as a
batched dot + top-k (no loops).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.sharding import L


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 64
    bot_mlp: Tuple[int, ...] = (512, 256, 64)
    top_mlp: Tuple[int, ...] = (512, 256, 1)
    # per-field vocabulary sizes (Criteo-like log-uniform spread)
    vocab_sizes: Tuple[int, ...] = ()
    multi_hot: int = 1          # indices per field (bag size)
    name: str = "dlrm-rm2"

    @staticmethod
    def rm2(total_rows: int = 50_000_000, n_sparse: int = 26) -> "DLRMConfig":
        # log-spread vocabularies summing to ~total_rows; the concatenated
        # table total is padded to a multiple of 4096 so row-sharding divides
        # evenly on any production mesh axis
        w = np.logspace(0, 3.2, n_sparse)
        w = w / w.sum()
        sizes = [int(max(128, round(total_rows * wi))) for wi in w]
        total = sum(sizes)
        pad = (-total) % 4096
        sizes[-1] += pad
        return DLRMConfig(vocab_sizes=tuple(sizes))

    @staticmethod
    def smoke() -> "DLRMConfig":
        return DLRMConfig(
            n_dense=13, n_sparse=4, embed_dim=16,
            bot_mlp=(32, 16), top_mlp=(32, 1),
            vocab_sizes=(64, 128, 256, 512), multi_hot=2, name="dlrm-smoke")

    @property
    def n_interactions(self) -> int:
        f = self.n_sparse + 1
        return f * (f - 1) // 2


def init_dlrm(key, cfg: DLRMConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4 + cfg.n_sparse)
    # one concatenated table [sum(vocab), D] with per-field offsets — this is
    # how FBGEMM TBE lays tables out, and it row-shards cleanly
    total = sum(cfg.vocab_sizes)
    tree = {
        "tables": L(jax.random.normal(ks[0], (total, cfg.embed_dim), dtype) * 0.01,
                    ("rows", "embed")),
        "bot": _init_mlp_stack(ks[1], cfg.n_dense, cfg.bot_mlp, dtype),
        "top": _init_mlp_stack(
            ks[2], cfg.n_interactions + cfg.bot_mlp[-1], cfg.top_mlp, dtype),
    }
    return tree


def _init_mlp_stack(key, d_in, dims, dtype):
    layers = []
    for i, d in enumerate(dims):
        k = jax.random.fold_in(key, i)
        layers.append({
            "w": L(jax.random.normal(k, (d_in, d), dtype) * d_in ** -0.5,
                   ("mlp_in", "mlp_out")),
            "b": L(jnp.zeros((d,), dtype), ("mlp_out",)),
        })
        d_in = d
    return layers


def _mlp_stack(layers, x, final_act=False):
    for i, p in enumerate(layers):
        x = x @ p["w"] + p["b"]
        if i < len(layers) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def field_offsets(cfg: DLRMConfig) -> np.ndarray:
    return np.concatenate([[0], np.cumsum(cfg.vocab_sizes)[:-1]]).astype(np.int32)


def embedding_bag_local(table: jnp.ndarray, flat_idx: jnp.ndarray,
                        bag_ids: jnp.ndarray, n_bags: int,
                        row_range: Tuple[jnp.ndarray, jnp.ndarray] | None = None):
    """Sum-pooled EmbeddingBag via take + segment_sum.

    flat_idx: [n_lookups] global row ids; bag_ids: [n_lookups] output bag.
    With row_range=(lo, hi) only rows in [lo, hi) contribute (row-sharding).
    """
    if row_range is not None:
        lo, hi = row_range
        in_range = (flat_idx >= lo) & (flat_idx < hi)
        local_idx = jnp.clip(flat_idx - lo, 0, table.shape[0] - 1)
        rows = jnp.take(table, local_idx, axis=0)
        rows = rows * in_range[:, None].astype(rows.dtype)
    else:
        rows = jnp.take(table, flat_idx, axis=0)
    return jax.ops.segment_sum(rows, bag_ids, num_segments=n_bags)


def dlrm_interact(params, dense: jnp.ndarray, emb: jnp.ndarray, cfg: DLRMConfig):
    """Bottom MLP + dot interaction + top MLP given looked-up bags [B, F, D]."""
    bot = _mlp_stack(params["bot"], dense)                     # [B, D]
    feats = jnp.concatenate([bot[:, None, :], emb], axis=1)    # [B, F+1, D]
    inter = jnp.einsum("bfd,bgd->bfg", feats, feats)
    iu, ju = jnp.triu_indices(cfg.n_sparse + 1, k=1)
    inter_flat = inter[:, iu, ju]
    top_in = jnp.concatenate([bot, inter_flat], axis=-1)
    return _mlp_stack(params["top"], top_in)


def dlrm_forward(params, dense: jnp.ndarray, sparse_idx: jnp.ndarray,
                 cfg: DLRMConfig, mesh: Mesh | None = None,
                 batch_axes: Tuple[str, ...] = ("data",),
                 model_axis: str = "model"):
    """dense: [B, n_dense]; sparse_idx: [B, n_sparse, multi_hot] global row ids
    (field offsets already applied). Returns logits [B, 1]."""
    F, H = cfg.n_sparse, cfg.multi_hot

    def lookup_local(table, idx):
        # idx: [B_loc, F, H] -> bags [B_loc*F]
        Bl = idx.shape[0]
        flat = idx.reshape(-1)
        bag = jnp.repeat(jnp.arange(Bl * F), H)
        if mesh is not None and mesh.shape.get(model_axis, 1) > 1:
            shard = jax.lax.axis_index(model_axis)
            rows_per = table.shape[0]
            lo = shard.astype(jnp.int32) * rows_per
            out = embedding_bag_local(table, flat, bag, Bl * F,
                                      row_range=(lo, lo + rows_per))
            out = jax.lax.psum(out, model_axis)
        else:
            out = embedding_bag_local(table, flat, bag, Bl * F)
        return out.reshape(Bl, F, cfg.embed_dim)

    if mesh is not None:
        emb = jax.shard_map(
            lookup_local, mesh=mesh,
            in_specs=(P(model_axis, None), P(batch_axes, None, None)),
            out_specs=P(batch_axes, None, None), check_vma=False,
        )(params["tables"], sparse_idx)
    else:
        emb = lookup_local(params["tables"], sparse_idx)

    return dlrm_interact(params, dense, emb, cfg)


def retrieval_score(params, dense: jnp.ndarray, sparse_idx: jnp.ndarray,
                    cand_emb: jnp.ndarray, cfg: DLRMConfig, top_k: int = 100,
                    mesh=None, batch_axes=("data",)):
    """Score 1 query against n_candidates item embeddings: user tower ->
    batched dot -> top-k. cand_emb: [n_cand, D]."""
    # user embedding = bottom MLP of dense + mean of sparse bags
    bot = _mlp_stack(params["bot"], dense)                     # [1, D]
    scores = (cand_emb @ bot[0]).astype(jnp.float32)           # [n_cand]
    vals, idx = jax.lax.top_k(scores, top_k)
    return vals, idx
