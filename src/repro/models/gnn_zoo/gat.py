"""GAT [arXiv:1710.10903] with *consistent* distributed edge-softmax.

The paper (Sec. II-B, last paragraph) notes its halo mechanism "can be
generally applied to extend non-local operations in other layers (e.g.
attention)". We implement that: the edge softmax over a partitioned graph
uses three halo synchronizations per layer —

  1. max-sync  of per-destination score maxima (numerics),
  2. sum-sync  of the softmax denominator,
  3. sum-sync  of the attention-weighted message aggregate,

making distributed GAT arithmetically identical to the un-partitioned run.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import nn
from repro.core.halo import HaloSpec, halo_sync
from repro.graph import segment


@dataclasses.dataclass(frozen=True)
class GATConfig:
    in_dim: int = 1433
    hidden: int = 8
    heads: int = 8
    n_classes: int = 7
    n_layers: int = 2
    name: str = "gat-cora"


def init_gat(key, cfg: GATConfig):
    layers = []
    d_in = cfg.in_dim
    for i in range(cfg.n_layers):
        k1, k2, k3, key = jax.random.split(key, 4)
        d_out = cfg.n_classes if i == cfg.n_layers - 1 else cfg.hidden
        heads = 1 if i == cfg.n_layers - 1 else cfg.heads
        layers.append({
            "w": nn.glorot(k1, (d_in, heads, d_out)),
            "a_src": nn.glorot(k2, (heads, d_out, 1))[..., 0],
            "a_dst": nn.glorot(k3, (heads, d_out, 1))[..., 0],
        })
        d_in = d_out * heads if i < cfg.n_layers - 1 else d_out
    return {"layers": layers}


def _gat_layer(p, x, graph, halo: HaloSpec, concat_heads: bool):
    src, dst = graph["edge_src"], graph["edge_dst"]
    emask = graph["edge_mask"]
    n_pad = x.shape[0]
    h = jnp.einsum("nd,dhk->nhk", x, p["w"])                   # [N, H, K]
    s_src = jnp.einsum("nhk,hk->nh", h, p["a_src"])
    s_dst = jnp.einsum("nhk,hk->nh", h, p["a_dst"])
    scores = jax.nn.leaky_relu(s_src[src] + s_dst[dst], 0.2)   # [E, H]
    scores = jnp.where(emask[:, None] > 0, scores, -1e30)

    # --- consistent softmax: max-sync ---
    m_loc = segment.segment_max(scores, dst, n_pad)            # [N, H]
    m_loc = jnp.where(graph["node_mask"][:, None] > 0, m_loc, -1e30)
    m = halo_sync(m_loc, graph, halo, combine="max")
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    expv = jnp.exp(scores - m_safe[dst]) * emask[:, None]
    expv = expv * graph["edge_inv_mult"][:, None]               # d_ij scaling
    # --- denominator sum-sync ---
    denom = halo_sync(segment.segment_sum(expv, dst, n_pad), graph, halo, combine="sum")
    # --- weighted message aggregate, sum-sync ---
    msg = expv[..., None] * h[src]                              # [E, H, K]
    agg = segment.segment_sum(msg, dst, n_pad)
    agg = halo_sync(agg.reshape(n_pad, -1), graph, halo, combine="sum") \
        .reshape(agg.shape)
    out = agg / jnp.maximum(denom, 1e-20)[..., None]
    out = out * graph["node_mask"][:, None, None]
    if concat_heads:
        return out.reshape(n_pad, -1)
    return out.mean(axis=1)


def gat_forward(params, x, graph, halo: HaloSpec, cfg: GATConfig):
    """x: [N_pad, in_dim] -> logits [N_pad, n_classes]."""
    for i, p in enumerate(params["layers"]):
        last = i == len(params["layers"]) - 1
        x = _gat_layer(p, x, graph, halo, concat_heads=not last)
        if not last:
            x = jax.nn.elu(x)
    return x
