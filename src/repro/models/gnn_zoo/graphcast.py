"""GraphCast [arXiv:2212.12794]: encoder-processor-decoder interaction-net GNN.

Two operating modes:

* generic-graph mode (the assigned x-shape cells): node-feature encoder MLP ->
  16 interaction-network processor layers on the given graph (each is exactly
  the paper's consistent NMP layer: edge MLP, 1/d_ij-scaled aggregation, halo
  sync, node MLP, residual) -> decoder MLP.

* weather mode (``examples/graphcast_weather.py``): proper grid2mesh /
  multimesh / mesh2grid edge sets over an icosahedral refinement, built by
  ``icosahedral_mesh`` below.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro import nn
from repro.core.consistent_mp import init_nmp_layer, nmp_layer
from repro.core.graph_state import NMPPlan, as_graph


@dataclasses.dataclass(frozen=True)
class GraphCastConfig:
    in_dim: int = 227           # n_vars (weather); overridden by shape d_feat
    hidden: int = 512
    n_layers: int = 16
    out_dim: int = 227
    mlp_hidden_layers: int = 1
    edge_in: int = 4            # generic geometric edge feats
    name: str = "graphcast"
    # --- perf knobs (EXPERIMENTS §Perf) ---
    remat: bool = False             # recompute processor layers in backward
    act_dtype: object = jnp.float32  # bf16 halves activation carries
    edge_parallel_axes: tuple = ()   # 2nd-level edge sharding (psum combine)
    remat_segment: int = 1           # sqrt(L) checkpointing: layers per segment
    # --- multilevel (coarse-grid) processor (repro.core.coarsen) ---
    n_levels: int = 1               # >1 appends a consistent V-cycle after the scan
    coarse_mp_layers: int = 2       # NMP layers smoothing each coarse level
    coarse_edge_in: int = 4         # coarse static edge feats (dist vec + mag)


def init_graphcast(key, cfg: GraphCastConfig):
    ks = jax.random.split(key, 4)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    # stacked processor layers (scanned)
    stacked = jax.vmap(
        lambda k: init_nmp_layer(k, cfg.hidden, cfg.mlp_hidden_layers))(layer_keys)
    params = {
        "node_enc": nn.init_mlp(ks[1], cfg.in_dim, [cfg.hidden], cfg.hidden),
        "edge_enc": nn.init_mlp(ks[2], cfg.edge_in, [cfg.hidden], cfg.hidden),
        "proc": stacked,
        "node_dec": nn.init_mlp(ks[3], cfg.hidden, [cfg.hidden], cfg.out_dim,
                                final_layernorm=False),
    }
    if cfg.n_levels > 1:
        from repro.core.gnn import init_coarse_levels
        params["coarse"] = init_coarse_levels(
            jax.random.fold_in(key, 7), cfg.hidden, cfg.mlp_hidden_layers,
            cfg.n_levels, cfg.coarse_mp_layers, cfg.coarse_edge_in)
    return params


def graphcast_forward(params, x, edge_feats, graph, plan: NMPPlan,
                      cfg: GraphCastConfig):
    """x: [N_pad, in_dim]; edge_feats: [E_pad, edge_in] -> [N_pad, out_dim].

    ``graph`` is the rank-local ShardedGraph; ``plan`` the NMP execution
    policy (backend/schedule/precision + per-level halo specs).  With
    ``cfg.n_levels > 1`` the scanned processor acts as the fine pre-smoother
    and the consistent multilevel V-cycle runs before the decoder; ``graph``
    must then carry the nested coarse chain
    (``ShardedGraph.build(..., hierarchy=...)``)."""
    graph = as_graph(graph)
    lvl0 = graph.levels[0]
    h = nn.mlp(params["node_enc"], x) * lvl0["node_mask"][..., None]
    e = nn.mlp(params["edge_enc"], edge_feats) * lvl0["edge_mask"][..., None]
    h = h.astype(cfg.act_dtype)
    e = e.astype(cfg.act_dtype)

    def body(carry, p_l):
        hc, ec = carry
        hn, en = nmp_layer(p_l, hc, ec, lvl0, plan,
                           edge_parallel_axes=cfg.edge_parallel_axes)
        return (hn.astype(cfg.act_dtype), en.astype(cfg.act_dtype)), None

    seg = cfg.remat_segment
    if cfg.remat and seg > 1:
        # sqrt(L) checkpointing: only every seg-th layer boundary is saved;
        # inner layers recompute during the (checkpointed) segment backward
        stacked = params["proc"]
        n_seg = jax.tree.leaves(stacked)[0].shape[0] // seg
        seg_params = jax.tree.map(
            lambda x: x.reshape((n_seg, seg) + x.shape[1:]), stacked)

        @jax.checkpoint
        def seg_body(carry, p_seg):
            out, _ = jax.lax.scan(body, carry, p_seg)
            return out, None

        (h, e), _ = jax.lax.scan(seg_body, (h, e), seg_params)
    else:
        if cfg.remat:
            body = jax.checkpoint(body)
        (h, e), _ = jax.lax.scan(body, (h, e), params["proc"])
    if "coarse" in params:
        from repro.core.consistent_mp import multilevel_vcycle
        h = multilevel_vcycle(
            params["coarse"], h.astype(jnp.float32), graph,
            plan).astype(cfg.act_dtype)
    return nn.mlp(params["node_dec"], h.astype(jnp.float32)) \
        * lvl0["node_mask"][..., None]


# ---------------------------------------------------------------------------
# icosahedral multimesh (weather mode)
# ---------------------------------------------------------------------------

def icosahedral_mesh(refinements: int) -> Tuple[np.ndarray, np.ndarray]:
    """Refined icosahedron: (vertices [V,3] unit sphere, multimesh edges [E,2]).

    The multimesh contains the union of edge sets at every refinement level
    (GraphCast's long+short range message passing)."""
    phi = (1 + 5 ** 0.5) / 2
    verts = np.array([
        [-1, phi, 0], [1, phi, 0], [-1, -phi, 0], [1, -phi, 0],
        [0, -1, phi], [0, 1, phi], [0, -1, -phi], [0, 1, -phi],
        [phi, 0, -1], [phi, 0, 1], [-phi, 0, -1], [-phi, 0, 1],
    ], dtype=np.float64)
    verts /= np.linalg.norm(verts, axis=1, keepdims=True)
    faces = np.array([
        [0, 11, 5], [0, 5, 1], [0, 1, 7], [0, 7, 10], [0, 10, 11],
        [1, 5, 9], [5, 11, 4], [11, 10, 2], [10, 7, 6], [7, 1, 8],
        [3, 9, 4], [3, 4, 2], [3, 2, 6], [3, 6, 8], [3, 8, 9],
        [4, 9, 5], [2, 4, 11], [6, 2, 10], [8, 6, 7], [9, 8, 1],
    ])
    all_edges = set()

    def add_edges(fs):
        for f in fs:
            for a, b in ((f[0], f[1]), (f[1], f[2]), (f[2], f[0])):
                all_edges.add((min(a, b), max(a, b)))

    add_edges(faces)
    vlist = [v for v in verts]
    for _ in range(refinements):
        cache = {}
        new_faces = []

        def midpoint(a, b):
            key = (min(a, b), max(a, b))
            if key not in cache:
                m = vlist[a] + vlist[b]
                m /= np.linalg.norm(m)
                vlist.append(m)
                cache[key] = len(vlist) - 1
            return cache[key]

        for f in faces:
            ab, bc, ca = midpoint(f[0], f[1]), midpoint(f[1], f[2]), midpoint(f[2], f[0])
            new_faces += [[f[0], ab, ca], [ab, f[1], bc], [ca, bc, f[2]],
                          [ab, bc, ca]]
        faces = np.array(new_faces)
        add_edges(faces)
    verts = np.stack(vlist)
    edges = np.array(sorted(all_edges), dtype=np.int64)
    return verts, edges


def latlon_grid(n_lat: int, n_lon: int) -> np.ndarray:
    """[n_lat*n_lon, 3] unit-sphere points of a regular lat-lon grid."""
    lats = np.linspace(-np.pi / 2, np.pi / 2, n_lat)
    lons = np.linspace(0, 2 * np.pi, n_lon, endpoint=False)
    lat, lon = np.meshgrid(lats, lons, indexing="ij")
    return np.stack([np.cos(lat) * np.cos(lon), np.cos(lat) * np.sin(lon),
                     np.sin(lat)], axis=-1).reshape(-1, 3)


def grid2mesh_edges(grid_xyz: np.ndarray, mesh_xyz: np.ndarray, k: int = 4) -> np.ndarray:
    """Connect each grid point to its k nearest mesh vertices ([E,2]: grid->mesh)."""
    # chunked brute-force kNN (host-side, small meshes in tests/examples)
    out = []
    for i0 in range(0, grid_xyz.shape[0], 4096):
        chunk = grid_xyz[i0:i0 + 4096]
        d = ((chunk[:, None] - mesh_xyz[None]) ** 2).sum(-1)
        nn_idx = np.argsort(d, axis=1)[:, :k]
        gi = np.repeat(np.arange(i0, i0 + chunk.shape[0]), k)
        out.append(np.stack([gi, nn_idx.reshape(-1)], axis=-1))
    return np.concatenate(out)
