"""Minimal O(3)-irrep machinery for NequIP/MACE (l <= 3, with parity).

Design choice (see DESIGN.md §hardware-adaptation): instead of porting e3nn's
convention-laden analytic Clebsch-Gordan pipeline, the coupling tensors are
derived *numerically* on the host, once, from our own real spherical-harmonic
definitions:

  * Wigner matrices D_l(R) in the real-SH basis are obtained by least-squares
    from SH evaluations at rotated sample points (exact to fp64 round-off);
  * the CG tensor C for (l1 x l2 -> l3) is the (1-dimensional) null space of
    the equivariance constraint  C - D3^T C (D1 (x) D2)  stacked over random
    rotations, found by SVD.

This is self-consistent by construction — equivariance of every tensor
product holds to ~1e-12 regardless of basis conventions — and all tensors are
tiny ([2l+1]^3 <= 343) host-side constants baked into the jit'd graph.

Parity bookkeeping: an irrep is (l, p) with p = +-1; SH of a displacement
carries p = (-1)^l; tensor-product parity multiplies; E(3) selection keeps
only parity-consistent paths.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.sharding import L as PLeaf


# ---------------------------------------------------------------------------
# real spherical harmonics (unnormalized but fixed convention)
# ---------------------------------------------------------------------------

def sh_l(vec: np.ndarray | jnp.ndarray, l: int):
    """Real solid harmonics of degree l for unit-ish vectors [..., 3].

    Components ordered by our own fixed convention. Works under numpy or jnp.
    """
    x, y, z = vec[..., 0], vec[..., 1], vec[..., 2]
    xp = jnp if isinstance(vec, jnp.ndarray) else np
    if l == 0:
        return xp.ones(vec.shape[:-1] + (1,), vec.dtype)
    if l == 1:
        return xp.stack([x, y, z], axis=-1)
    if l == 2:
        return xp.stack([
            x * y, y * z, z * x,
            x * x - y * y,
            2 * z * z - x * x - y * y,
        ], axis=-1)
    if l == 3:
        return xp.stack([
            x * y * z,
            x * (x * x - 3 * y * y),
            y * (3 * x * x - y * y),
            z * (x * x - y * y),
            x * (4 * z * z - x * x - y * y),
            y * (4 * z * z - x * x - y * y),
            z * (2 * z * z - 3 * x * x - 3 * y * y),
        ], axis=-1)
    raise NotImplementedError(l)


def _rand_rotations(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    qs = rng.normal(size=(n, 4))
    qs /= np.linalg.norm(qs, axis=1, keepdims=True)
    w, x, y, z = qs.T
    return np.stack([
        np.stack([1 - 2 * (y * y + z * z), 2 * (x * y - z * w), 2 * (x * z + y * w)], -1),
        np.stack([2 * (x * y + z * w), 1 - 2 * (x * x + z * z), 2 * (y * z - x * w)], -1),
        np.stack([2 * (x * z - y * w), 2 * (y * z + x * w), 1 - 2 * (x * x + y * y)], -1),
    ], axis=-2)


@functools.lru_cache(maxsize=None)
def wigner_d(l: int, key: int = 0) -> np.ndarray:
    """Not used directly — see ``wigner_d_from_R``; cached sample points."""
    raise NotImplementedError


def wigner_d_from_R(l: int, R: np.ndarray) -> np.ndarray:
    """D_l(R) in our real-SH basis: Y_l(R v) = D_l(R) Y_l(v)."""
    if l == 0:
        return np.ones((1, 1))
    rng = np.random.default_rng(l * 7919 + 13)
    pts = rng.normal(size=(max(64, 4 * (2 * l + 1) ** 2), 3))
    Y = sh_l(pts, l)                      # [P, 2l+1]
    Yr = sh_l(pts @ R.T, l)               # [P, 2l+1]
    D, *_ = np.linalg.lstsq(Y, Yr, rcond=None)
    return D.T                             # Yr^T = D Y^T


@functools.lru_cache(maxsize=None)
def clebsch_gordan(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real coupling tensor C [2l1+1, 2l2+1, 2l3+1] (None if path forbidden).

    C is the null space of the *bilinear-map equivariance* constraint

        sum_{ab} D1_{aA} D2_{bB} C_{abc}  =  sum_C D3_{cC} C_{ABC}    for all R,

    which is the correct condition for ``out_c = C_{abc} x_a y_b`` to be
    covariant even though our (unnormalized real-SH) Wigner matrices are not
    orthogonal. Solved once on the host by SVD over stacked rotations.
    """
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return None
    d1, d2, d3 = 2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1
    n = d1 * d2 * d3
    eye1, eye2, eye3 = np.eye(d1), np.eye(d2), np.eye(d3)
    rows = []
    for R in _rand_rotations(6, seed=l1 * 100 + l2 * 10 + l3):
        D1 = wigner_d_from_R(l1, R)
        D2 = wigner_d_from_R(l2, R)
        D3 = wigner_d_from_R(l3, R)
        # T1[(A,B,c),(a,b,c')] = D1_{aA} D2_{bB} delta_{c c'}
        T1 = np.einsum("aA,bB,cx->ABxabc", D1, D2, eye3).reshape(n, n)
        # T2[(A,B,c),(a',b',C)] = delta_{Aa'} delta_{Bb'} D3_{cC}
        T2 = np.einsum("Aa,Bb,cC->ABcabC", eye1, eye2, D3).reshape(n, n)
        rows.append(T1 - T2)
    A = np.concatenate(rows, axis=0)
    _, s, vt = np.linalg.svd(A)
    if s[-1] > 1e-8 * s[0]:
        return None
    c = vt[-1].reshape(d1, d2, d3)
    c = c / np.linalg.norm(c)
    return c


# ---------------------------------------------------------------------------
# irreps containers
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Irreps:
    """List of (multiplicity, l, parity) blocks; arrays are [..., dim]."""
    blocks: Tuple[Tuple[int, int, int], ...]   # (mul, l, p)

    @staticmethod
    def make(spec: Sequence[Tuple[int, int, int]]) -> "Irreps":
        return Irreps(tuple((int(m), int(l), int(p)) for m, l, p in spec))

    @staticmethod
    def scalars(mul: int) -> "Irreps":
        return Irreps(((mul, 0, 1),))

    @property
    def dim(self) -> int:
        return sum(m * (2 * l + 1) for m, l, _ in self.blocks)

    def slices(self):
        out, off = [], 0
        for m, l, p in self.blocks:
            d = m * (2 * l + 1)
            out.append((slice(off, off + d), m, l, p))
            off += d
        return out

    def mul_of(self, l: int, p: int) -> int:
        return sum(m for m, ll, pp in self.blocks if ll == l and pp == p)


def split_irreps(x: jnp.ndarray, irreps: Irreps):
    """[..., dim] -> list of [..., mul, 2l+1] blocks."""
    out = []
    for sl, m, l, p in irreps.slices():
        out.append(x[..., sl].reshape(x.shape[:-1] + (m, 2 * l + 1)))
    return out


def merge_irreps(blocks: List[jnp.ndarray]) -> jnp.ndarray:
    return jnp.concatenate(
        [b.reshape(b.shape[:-2] + (-1,)) for b in blocks], axis=-1)


# ---------------------------------------------------------------------------
# weighted tensor product (the NequIP/MACE workhorse)
# ---------------------------------------------------------------------------

def tp_paths(ir1: Irreps, ir2: Irreps, ir_out: Irreps):
    """Allowed (i, j, k) block triples with their CG tensors."""
    paths = []
    for i, (m1, l1, p1) in enumerate(ir1.blocks):
        for j, (m2, l2, p2) in enumerate(ir2.blocks):
            for k, (m3, l3, p3) in enumerate(ir_out.blocks):
                if p1 * p2 != p3:
                    continue
                C = clebsch_gordan(l1, l2, l3)
                if C is None:
                    continue
                paths.append((i, j, k, jnp.asarray(C, jnp.float32)))
    return paths


def init_tp_weights(key, ir1: Irreps, ir2: Irreps, ir_out: Irreps,
                    n_radial: int, dtype=jnp.float32):
    """Per-path weights modulated by a radial embedding of size n_radial.

    Weight shape per path: [n_radial, m1, m3] — 'uvu'-style (channel mixing
    from input-1 multiplicity to output multiplicity, input-2 broadcast).
    """
    paths = tp_paths(ir1, ir2, ir_out)
    ws = []
    for n, (i, j, k, _) in enumerate(paths):
        m1 = ir1.blocks[i][0]
        m3 = ir_out.blocks[k][0]
        kk = jax.random.fold_in(key, n)
        ws.append(PLeaf(jax.random.normal(kk, (n_radial, m1, m3), dtype)
                        * (m1 * n_radial) ** -0.5, ("radial", "mul_in", "mul_out")))
    return {"path_w": ws}


def weighted_tensor_product(params, x1: jnp.ndarray, x2: jnp.ndarray,
                            radial: jnp.ndarray,
                            ir1: Irreps, ir2: Irreps, ir_out: Irreps):
    """x1: [E, ir1.dim]; x2: [E, ir2.dim] (mul-1 blocks, e.g. SH); radial: [E, n_radial].

    Returns [E, ir_out.dim]. Per edge: out_k += C_{abc} (W(r) x1)_{u a} x2_b.
    """
    paths = tp_paths(ir1, ir2, ir_out)
    b1 = split_irreps(x1, ir1)
    b2 = split_irreps(x2, ir2)
    out_blocks = [None] * len(ir_out.blocks)
    for (i, j, k, C), w in zip(paths, params["path_w"]):
        # x1 block: [E, m1, d1]; x2 block: [E, m2, d2] with m2 == 1 (SH)
        x2b = b2[j][..., 0, :]                       # [E, d2]
        t = jnp.einsum("eua,eb,abc->euc", b1[i], x2b, C)   # [E, m1, d3]
        # memory-aware contraction order: the naive per-edge weight tensor
        # einsum('er,rum->eum') materializes [E, m1, m3] (32 GiB at MACE's
        # m=128 on 531k edges/device); contracting radial into t first keeps
        # the intermediate at [E, m1, d3, n_radial] — d3*n_radial << m3
        s = jnp.einsum("euc,er->eucr", t, radial.astype(t.dtype))
        r = jnp.einsum("eucr,rum->emc", s, w.astype(t.dtype))  # [E, m3, d3]
        out_blocks[k] = r if out_blocks[k] is None else out_blocks[k] + r
    full = []
    for k, (m3, l3, p3) in enumerate(ir_out.blocks):
        if out_blocks[k] is None:
            full.append(jnp.zeros(x1.shape[:-1] + (m3, 2 * l3 + 1), x1.dtype))
        else:
            full.append(out_blocks[k])
    return merge_irreps(full)


def init_linear_irreps(key, ir_in: Irreps, ir_out: Irreps, dtype=jnp.float32):
    ws = []
    for n, (i, k) in enumerate(_linear_pairs(ir_in, ir_out)):
        m_in = ir_in.blocks[i][0]
        m_out = ir_out.blocks[k][0]
        kk = jax.random.fold_in(key, n)
        ws.append(PLeaf(jax.random.normal(kk, (m_in, m_out), dtype) * m_in ** -0.5,
                        ("mul_in", "mul_out")))
    return {"lin_w": ws}


def _linear_pairs(ir_in: Irreps, ir_out: Irreps):
    pairs = []
    for i, (m1, l1, p1) in enumerate(ir_in.blocks):
        for k, (m3, l3, p3) in enumerate(ir_out.blocks):
            if l1 == l3 and p1 == p3:
                pairs.append((i, k))
    return pairs


def linear_irreps(params, x: jnp.ndarray, ir_in: Irreps, ir_out: Irreps):
    """Equivariant linear layer: mixes multiplicities within each (l, p)."""
    bin_ = split_irreps(x, ir_in)
    out_blocks = [None] * len(ir_out.blocks)
    for (i, k), w in zip(_linear_pairs(ir_in, ir_out), params["lin_w"]):
        r = jnp.einsum("...ua,um->...ma", bin_[i], w)
        out_blocks[k] = r if out_blocks[k] is None else out_blocks[k] + r
    full = []
    for k, (m3, l3, p3) in enumerate(ir_out.blocks):
        if out_blocks[k] is None:
            full.append(jnp.zeros(x.shape[:-1] + (m3, 2 * l3 + 1), x.dtype))
        else:
            full.append(out_blocks[k])
    return merge_irreps(full)


def gate_irreps(x: jnp.ndarray, ir: Irreps):
    """Equivariant gated nonlinearity: silu on scalars, l>0 scaled by
    sigmoid(first scalar channels). Requires a scalar block with mul >=
    number of non-scalar blocks... we gate each l>0 block by a learned-free
    sigmoid of the mean scalar activation (simple, equivariant)."""
    blocks = split_irreps(x, ir)
    out = []
    scalar = None
    for b, (sl, m, l, p) in zip(blocks, ir.slices()):
        if l == 0 and scalar is None:
            scalar = b
    for b, (m, l, p) in zip(blocks, ir.blocks):
        if l == 0:
            out.append(jax.nn.silu(b))
        else:
            g = jax.nn.sigmoid(scalar.mean(axis=(-2, -1), keepdims=True)) if scalar is not None else 1.0
            out.append(b * g)
    return merge_irreps(out)


def init_channel_tp_weights(key, ir1: Irreps, ir2: Irreps, ir_out: Irreps,
                            dtype=jnp.float32):
    """Channel-aligned (MACE 'uuu') tensor product weights: one scalar per
    (path, channel). Requires matching multiplicities on all three blocks."""
    paths = tp_paths(ir1, ir2, ir_out)
    ws = []
    for n, (i, j, k, _) in enumerate(paths):
        m = ir1.blocks[i][0]
        assert ir2.blocks[j][0] == m and ir_out.blocks[k][0] == m, \
            "channel TP needs equal multiplicities"
        kk = jax.random.fold_in(key, n)
        ws.append(PLeaf(jax.random.normal(kk, (m,), dtype), ("mul",)))
    return {"ctp_w": ws}


def channel_tensor_product(params, x1: jnp.ndarray, x2: jnp.ndarray,
                           ir1: Irreps, ir2: Irreps, ir_out: Irreps):
    """Per-channel CG product (MACE higher-order B-basis): out_uc += w_u
    C_{abc} x1_{ua} x2_{ub}. All blocks share multiplicity."""
    paths = tp_paths(ir1, ir2, ir_out)
    b1 = split_irreps(x1, ir1)
    b2 = split_irreps(x2, ir2)
    out_blocks = [None] * len(ir_out.blocks)
    for (i, j, k, C), w in zip(paths, params["ctp_w"]):
        t = jnp.einsum("...ua,...ub,abc,u->...uc", b1[i], b2[j], C, w)
        out_blocks[k] = t if out_blocks[k] is None else out_blocks[k] + t
    full = []
    for k, (m3, l3, p3) in enumerate(ir_out.blocks):
        if out_blocks[k] is None:
            full.append(jnp.zeros(x1.shape[:-1] + (m3, 2 * l3 + 1), x1.dtype))
        else:
            full.append(out_blocks[k])
    return merge_irreps(full)


# ---------------------------------------------------------------------------
# radial basis
# ---------------------------------------------------------------------------

def bessel_rbf(r: jnp.ndarray, n_rbf: int, cutoff: float) -> jnp.ndarray:
    """NequIP's Bessel radial basis with smooth polynomial cutoff envelope."""
    r = jnp.clip(r, 1e-6, None)
    n = jnp.arange(1, n_rbf + 1, dtype=r.dtype)
    basis = jnp.sin(n[None, :] * jnp.pi * r[:, None] / cutoff) / r[:, None]
    u = jnp.clip(r / cutoff, 0.0, 1.0)
    env = 1 - 10 * u ** 3 + 15 * u ** 4 - 6 * u ** 5   # C2-smooth cutoff
    return basis * env[:, None]
