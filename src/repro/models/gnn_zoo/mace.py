"""MACE [arXiv:2206.07697]: higher-order equivariant message passing (ACE).

Per layer:
  * atomic basis  A_i = sum_j TP(lin(x_j) (x) SH(r_ij); radial)   (+ halo sync
    and 1/d_ij scaling — the consistent-MP aggregation);
  * product basis B via iterated channel-wise CG products:
        B1 = A,  B2 = ctp(A, A),  B3 = ctp(B2, A)   (correlation order 3);
  * message m_i = lin(B1) + lin(B2) + lin(B3); residual update + gate.
Readout: site energies (sum of per-layer scalar readouts, as in MACE).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.halo import HaloSpec, halo_sync
from repro.graph import segment
from repro.models.gnn_zoo import irreps as ir
from repro.sharding import split_tree


@dataclasses.dataclass(frozen=True)
class MACEConfig:
    n_layers: int = 2
    hidden_mul: int = 128
    l_max: int = 2
    correlation: int = 3
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 8
    name: str = "mace"
    # perf knobs (EXPERIMENTS §Perf recipe transfer from graphcast)
    remat: bool = False
    act_dtype: object = jnp.float32
    edge_parallel_axes: tuple = ()

    @property
    def hidden_irreps(self) -> ir.Irreps:
        return ir.Irreps.make(
            [(self.hidden_mul, l, (-1) ** l) for l in range(self.l_max + 1)])

    @property
    def sh_irreps(self) -> ir.Irreps:
        return ir.Irreps.make([(1, l, (-1) ** l) for l in range(self.l_max + 1)])


def init_mace(key, cfg: MACEConfig):
    hid = cfg.hidden_irreps
    sh = cfg.sh_irreps
    scalars = ir.Irreps.scalars(cfg.hidden_mul)
    keys = jax.random.split(key, 2 + 8 * cfg.n_layers)
    layers = []
    for i in range(cfg.n_layers):
        in_ir = scalars if i == 0 else hid
        kk = keys[2 + 8 * i: 2 + 8 * (i + 1)]
        layer = {
            "lin_pre": ir.init_linear_irreps(kk[0], in_ir, in_ir),
            "tp": ir.init_tp_weights(kk[1], in_ir, sh, hid, cfg.n_rbf),
            "lin_b1": ir.init_linear_irreps(kk[2], hid, hid),
            "lin_self": ir.init_linear_irreps(kk[3], in_ir, hid),
            "readout": ir.init_linear_irreps(kk[4], hid, ir.Irreps.scalars(1)),
        }
        if cfg.correlation >= 2:
            layer["ctp2"] = ir.init_channel_tp_weights(kk[5], hid, hid, hid)
            layer["lin_b2"] = ir.init_linear_irreps(kk[6], hid, hid)
        if cfg.correlation >= 3:
            layer["ctp3"] = ir.init_channel_tp_weights(kk[7], hid, hid, hid)
            layer["lin_b3"] = ir.init_linear_irreps(
                jax.random.fold_in(kk[7], 1), hid, hid)
        layers.append(layer)
    tree = {
        "embed": ir.PLeaf(jax.random.normal(keys[0], (cfg.n_species, cfg.hidden_mul))
                          * cfg.hidden_mul ** -0.5, ("species", "mul")),
        "layers": layers,
    }
    params, _ = split_tree(tree, {})
    return params


def mace_forward(params, species: jnp.ndarray, pos: jnp.ndarray,
                 graph: Dict, halo: HaloSpec, cfg: MACEConfig) -> jnp.ndarray:
    """species [N_pad], pos [N_pad, 3] -> site energies [N_pad]."""
    src, dst = graph["edge_src"], graph["edge_dst"]
    hid, sh_ir = cfg.hidden_irreps, cfg.sh_irreps
    scalars = ir.Irreps.scalars(cfg.hidden_mul)

    vec = pos[dst] - pos[src]
    r = jnp.linalg.norm(vec + 1e-12, axis=-1)
    rbf = ir.bessel_rbf(r, cfg.n_rbf, cfg.cutoff) * graph["edge_mask"][:, None]
    sh = jnp.concatenate([ir.sh_l(vec, l) for l in range(cfg.l_max + 1)], axis=-1)

    x = params["embed"][species] * graph["node_mask"][:, None]
    x = x.astype(cfg.act_dtype)
    n_pad = x.shape[0]
    in_ir = scalars
    e_site = jnp.zeros((n_pad,), jnp.float32)
    for p_l in params["layers"]:
        lin = in_ir

        def layer(p_l, x):
            xs = ir.linear_irreps(p_l["lin_pre"], x, lin, lin)
            msg = ir.weighted_tensor_product(p_l["tp"], xs[src], sh.astype(x.dtype),
                                             rbf.astype(x.dtype), lin, sh_ir, hid)
            msg = msg * (graph["edge_inv_mult"] * graph["edge_mask"])[:, None].astype(x.dtype)
            a = segment.segment_sum(msg, dst, n_pad)
            if cfg.edge_parallel_axes:
                a = jax.lax.psum(a, cfg.edge_parallel_axes)
            a = halo_sync(a, graph, halo, combine="sum")        # consistent-MP
            m = ir.linear_irreps(p_l["lin_b1"], a, hid, hid)
            if "ctp2" in p_l:
                b2 = ir.channel_tensor_product(p_l["ctp2"], a, a, hid, hid, hid)
                m = m + ir.linear_irreps(p_l["lin_b2"], b2, hid, hid)
                if "ctp3" in p_l:
                    b3 = ir.channel_tensor_product(p_l["ctp3"], b2, a, hid, hid, hid)
                    m = m + ir.linear_irreps(p_l["lin_b3"], b3, hid, hid)
            xn = ir.linear_irreps(p_l["lin_self"], x, lin, hid) + m
            xn = ir.gate_irreps(xn, hid) * graph["node_mask"][:, None]
            e_l = ir.linear_irreps(p_l["readout"], xn, hid,
                                   ir.Irreps.scalars(1))[..., 0]
            return xn.astype(cfg.act_dtype), e_l.astype(jnp.float32)

        if cfg.remat:
            x, e_l = jax.checkpoint(layer)(p_l, x)
        else:
            x, e_l = layer(p_l, x)
        e_site = e_site + e_l
        in_ir = hid
    return e_site * graph["node_mask"]
