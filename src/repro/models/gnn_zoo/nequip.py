"""NequIP [arXiv:2101.03164]: O(3)-equivariant interatomic potential.

Structure per interaction layer (faithful to the paper, with the coupling
tensors derived numerically — see irreps.py):
  * edge vectors -> Bessel RBF (cutoff-enveloped) + real SH up to l_max;
  * message = radial-weighted tensor product (x_src (x) SH -> hidden irreps);
  * 1/d_ij-scaled segment-sum aggregation + halo sync (consistent-MP);
  * node update: equivariant self-linear + aggregate-linear, gated
    nonlinearity; residual.
Readout: per-node scalar (site energy); total energy = consistent node sum;
forces available as -grad wrt positions (autodiff through SH/TP).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.halo import HaloSpec, halo_sync
from repro.graph import segment
from repro.models.gnn_zoo import irreps as ir
from repro.sharding import split_tree


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    n_layers: int = 5
    hidden_mul: int = 32
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 8
    name: str = "nequip"
    # perf knobs (EXPERIMENTS §Perf recipe transfer from graphcast)
    remat: bool = False
    act_dtype: object = jnp.float32
    edge_parallel_axes: tuple = ()

    @property
    def hidden_irreps(self) -> ir.Irreps:
        return ir.Irreps.make(
            [(self.hidden_mul, l, (-1) ** l) for l in range(self.l_max + 1)])

    @property
    def sh_irreps(self) -> ir.Irreps:
        return ir.Irreps.make([(1, l, (-1) ** l) for l in range(self.l_max + 1)])


def init_nequip(key, cfg: NequIPConfig):
    hid = cfg.hidden_irreps
    sh = cfg.sh_irreps
    scalars = ir.Irreps.scalars(cfg.hidden_mul)
    ks = jax.random.split(key, 3 + 4 * cfg.n_layers)
    layers = []
    for i in range(cfg.n_layers):
        in_ir = scalars if i == 0 else hid
        layers.append({
            "tp": ir.init_tp_weights(ks[3 + 4 * i], in_ir, sh, hid, cfg.n_rbf),
            "lin_self": ir.init_linear_irreps(ks[4 + 4 * i], in_ir, hid),
            "lin_agg": ir.init_linear_irreps(ks[5 + 4 * i], hid, hid),
        })
    tree = {
        "embed": ir.PLeaf(jax.random.normal(ks[0], (cfg.n_species, cfg.hidden_mul))
                          * cfg.hidden_mul ** -0.5, ("species", "mul")),
        "layers": layers,
        "readout": ir.init_linear_irreps(ks[1], hid, ir.Irreps.scalars(1)),
    }
    params, _ = split_tree(tree, {})
    return params


def nequip_forward(params, species: jnp.ndarray, pos: jnp.ndarray,
                   graph: Dict, halo: HaloSpec, cfg: NequIPConfig) -> jnp.ndarray:
    """species [N_pad] int32, pos [N_pad, 3] -> per-node site energy [N_pad]."""
    src, dst = graph["edge_src"], graph["edge_dst"]
    hid, sh_ir = cfg.hidden_irreps, cfg.sh_irreps
    scalars = ir.Irreps.scalars(cfg.hidden_mul)

    vec = pos[dst] - pos[src]                                  # [E, 3]
    r = jnp.linalg.norm(vec + 1e-12, axis=-1)
    rbf = ir.bessel_rbf(r, cfg.n_rbf, cfg.cutoff) * graph["edge_mask"][:, None]
    sh = jnp.concatenate([ir.sh_l(vec, l) for l in range(cfg.l_max + 1)], axis=-1)

    x = params["embed"][species] * graph["node_mask"][:, None]  # scalar irreps
    x = x.astype(cfg.act_dtype)
    n_pad = x.shape[0]
    in_ir = scalars
    for li, p_l in enumerate(params["layers"]):
        lin = in_ir

        def layer(p_l, x):
            msg = ir.weighted_tensor_product(p_l["tp"], x[src], sh.astype(x.dtype),
                                             rbf.astype(x.dtype), lin, sh_ir, hid)
            msg = msg * (graph["edge_inv_mult"] * graph["edge_mask"])[:, None].astype(x.dtype)
            agg = segment.segment_sum(msg, dst, n_pad)
            if cfg.edge_parallel_axes:
                agg = jax.lax.psum(agg, cfg.edge_parallel_axes)
            agg = halo_sync(agg, graph, halo, combine="sum")    # consistent-MP
            xn = ir.linear_irreps(p_l["lin_self"], x, lin, hid) \
                + ir.linear_irreps(p_l["lin_agg"], agg, hid, hid)
            return (ir.gate_irreps(xn, hid)
                    * graph["node_mask"][:, None]).astype(cfg.act_dtype)

        x = jax.checkpoint(layer)(p_l, x) if cfg.remat else layer(p_l, x)
        in_ir = hid
    x = x.astype(jnp.float32)
    e_site = ir.linear_irreps(params["readout"], x, hid, ir.Irreps.scalars(1))
    return e_site[..., 0] * graph["node_mask"]
