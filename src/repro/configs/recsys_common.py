"""RecSys-family shapes (DLRM cells)."""
from __future__ import annotations

RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_candidates=1_000_000),
}
