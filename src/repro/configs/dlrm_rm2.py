"""DLRM RM2 [arXiv:1906.00091]: 13 dense + 26 sparse, dim 64,
bot 13-512-256-64, top 512-512-256-1, dot interaction. ~50M embedding rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.recsys_common import RECSYS_SHAPES
from repro.launch.mesh import batch_axes_of
from repro.models.dlrm import DLRMConfig, dlrm_forward, init_dlrm, retrieval_score
from repro.sharding import split_tree
from repro.train.optimizer import AdamWConfig, adamw_update, init_adamw

ARCH_ID = "dlrm-rm2"
FAMILY = "recsys"


def config() -> DLRMConfig:
    return DLRMConfig(
        n_dense=13, n_sparse=26, embed_dim=64,
        bot_mlp=(512, 256, 64), top_mlp=(512, 512, 256, 1),
        vocab_sizes=DLRMConfig.rm2().vocab_sizes, multi_hot=1)


def smoke_config() -> DLRMConfig:
    return DLRMConfig.smoke()


def _rules(mesh):
    return {"rows": "model", "embed": None, "mlp_in": None, "mlp_out": None}


def build_dryrun_cell(shape_id, mesh, overrides=None):
    cfg = config()
    shape = RECSYS_SHAPES[shape_id]
    B = shape["batch"]
    batch_axes = batch_axes_of(mesh) if B > 1 else ()
    rules = _rules(mesh)

    tree_sds = jax.eval_shape(functools.partial(init_dlrm, cfg=cfg),
                              jax.random.PRNGKey(0))
    params_sds, pspecs = split_tree(tree_sds, rules, mesh)

    sds = jax.ShapeDtypeStruct
    dense = sds((B, cfg.n_dense), jnp.float32)
    sparse = sds((B, cfg.n_sparse, cfg.multi_hot), jnp.int32)
    bspec = P(batch_axes or None, None)
    sspec = P(batch_axes or None, None, None)
    meta = dict(kind=shape["kind"], batch=B,
                n_params=sum(cfg.vocab_sizes) * cfg.embed_dim)

    if shape["kind"] == "train":
        labels = sds((B, 1), jnp.float32)
        opt = AdamWConfig()
        sparse_push = bool((overrides or {}).get("sparse_grads"))
        if sparse_push:
            # tables updated with sparse SGD pushes (production scheme);
            # Adam states only for the dense MLPs
            mlp_sds = {k: params_sds[k] for k in ("bot", "top")}
            opt_sds = jax.eval_shape(functools.partial(init_adamw, cfg=opt), mlp_sds)
            mlp_specs = {k: pspecs[k] for k in ("bot", "top")}
            state_sds = {"params": params_sds, "opt": opt_sds}
            state_specs = {"params": pspecs,
                           "opt": {"m": mlp_specs, "v": mlp_specs, "step": P()}}
            step = _make_sparse_push_step(cfg, mesh, batch_axes, opt)
        else:
            opt_sds = jax.eval_shape(functools.partial(init_adamw, cfg=opt), params_sds)
            state_sds = {"params": params_sds, "opt": opt_sds}
            state_specs = {"params": pspecs,
                           "opt": {"m": pspecs, "v": pspecs, "step": P()}}

            def step(state, dense_, sparse_, labels_):
                def loss_fn(p):
                    logits = dlrm_forward(p, dense_, sparse_, cfg, mesh, batch_axes)
                    logp = jax.nn.log_sigmoid(logits)
                    logn = jax.nn.log_sigmoid(-logits)
                    return -(labels_ * logp + (1 - labels_) * logn).mean()

                loss, grads = jax.value_and_grad(loss_fn)(state["params"])
                new_p, new_opt, _ = adamw_update(grads, state["opt"],
                                                 state["params"], opt)
                return {"params": new_p, "opt": new_opt}, loss

        args = (state_sds, dense, sparse, labels)
        in_specs = (state_specs, bspec, sspec, bspec)
        out_specs = (state_specs, None)
        meta["donate"] = (0,)
        # fwd+bwd on MLPs + interactions; embedding grads are scatter updates
        meta["model_flops"] = 6 * B * _mlp_flops(cfg)
        return step, args, in_specs, out_specs, meta

    if shape["kind"] == "serve":
        def step(params, dense_, sparse_):
            return dlrm_forward(params, dense_, sparse_, cfg, mesh, batch_axes)
        args = (params_sds, dense, sparse)
        in_specs = (pspecs, bspec, sspec)
        out_specs = bspec
        meta["model_flops"] = 2 * B * _mlp_flops(cfg)
        return step, args, in_specs, out_specs, meta

    # retrieval: 1 query vs n_candidates item embeddings (sharded over model)
    n_cand = shape["n_candidates"]
    cand = sds((n_cand, cfg.embed_dim), jnp.float32)

    def step(params, dense_, sparse_, cand_):
        return retrieval_score(params, dense_, sparse_, cand_, cfg, top_k=100)

    args = (params_sds, dense, sparse, cand)
    in_specs = (pspecs, P(None, None), P(None, None, None), P("model", None))
    out_specs = (None, None)
    meta["model_flops"] = 2 * n_cand * cfg.embed_dim
    return step, args, in_specs, out_specs, meta


def _make_sparse_push_step(cfg: DLRMConfig, mesh, batch_axes, opt,
                           table_lr: float = 0.01):
    """§Perf iteration: replace the dense [50M x 64] f32 table-grad
    all-reduce with a sparse (idx, bf16 cotangent) all-gather over the data
    axis + local scatter-add on the owning row shard (napkin math: the batch
    touches <= B x F of 50M rows -> ~7x less wire; see EXPERIMENTS §Perf).

    Entire step runs inside shard_map so the reduction is explicit.
    """
    from repro.models.dlrm import dlrm_interact, embedding_bag_local

    F, H, D = cfg.n_sparse, cfg.multi_hot, cfg.embed_dim

    def step_local(state, dense_, sparse_, labels_):
        tables = state["params"]["tables"]          # local rows [rows_loc, D]
        mlps = {k: state["params"][k] for k in ("bot", "top")}
        Bl = dense_.shape[0]
        rows_loc = tables.shape[0]
        shard = jax.lax.axis_index("model")
        lo = shard.astype(jnp.int32) * rows_loc

        flat = sparse_.reshape(-1)                   # [Bl*F*H]
        bag = jnp.repeat(jnp.arange(Bl * F), H)
        emb_loc = embedding_bag_local(tables, flat, bag, Bl * F,
                                      row_range=(lo, lo + rows_loc))
        emb = jax.lax.psum(emb_loc, "model").reshape(Bl, F, D)

        def loss_fn(mlp_p, emb_in):
            logits = dlrm_interact({**mlp_p, "tables": tables}, dense_, emb_in, cfg)
            logp = jax.nn.log_sigmoid(logits)
            logn = jax.nn.log_sigmoid(-logits)
            return -(labels_ * logp + (1 - labels_) * logn).mean()

        loss, (g_mlp, g_emb) = jax.value_and_grad(loss_fn, argnums=(0, 1))(mlps, emb)

        # dense MLP grads: normal pmean over every axis
        all_axes = tuple(mesh.axis_names)
        g_mlp = jax.tree.map(lambda g: jax.lax.pmean(g, all_axes), g_mlp)
        loss = jax.lax.pmean(loss, all_axes)

        # ---- sparse push: gather (idx, bf16 cot) over data, not dense AR ----
        cot = jnp.repeat(g_emb.reshape(Bl * F, D), H, axis=0).astype(jnp.bfloat16)
        idx_all = jax.lax.all_gather(flat, "data", axis=0, tiled=True)
        cot_all = jax.lax.all_gather(cot, "data", axis=0, tiled=True)
        mine = (idx_all >= lo) & (idx_all < lo + rows_loc)
        local_rows = jnp.clip(idx_all - lo, 0, rows_loc - 1)
        upd = jax.ops.segment_sum(
            jnp.where(mine[:, None], cot_all.astype(jnp.float32), 0.0),
            local_rows, num_segments=rows_loc)
        n_data = 1
        for a in batch_axes:
            n_data *= mesh.shape[a]
        new_tables = tables - table_lr * (upd / n_data).astype(tables.dtype)

        new_mlps, new_opt, _ = adamw_update(g_mlp, state["opt"], mlps, opt)
        new_params = {**new_mlps, "tables": new_tables}
        return {"params": new_params, "opt": new_opt}, loss

    tspec = P("model", None)

    def step(state, dense_, sparse_, labels_):
        pspecs_local = {"tables": tspec,
                        "bot": P(), "top": P()}
        state_specs = {"params": pspecs_local,
                       "opt": {"m": {"bot": P(), "top": P()},
                               "v": {"bot": P(), "top": P()}, "step": P()}}
        return jax.shard_map(
            step_local, mesh=mesh,
            in_specs=(state_specs, P(batch_axes, None), P(batch_axes, None, None),
                      P(batch_axes, None)),
            out_specs=(state_specs, P()),
            check_vma=False,
        )(state, dense_, sparse_, labels_)

    return step


def _mlp_flops(cfg: DLRMConfig) -> int:
    dims_b = (cfg.n_dense,) + cfg.bot_mlp
    dims_t = (cfg.n_interactions + cfg.bot_mlp[-1],) + cfg.top_mlp
    f = sum(a * b for a, b in zip(dims_b[:-1], dims_b[1:]))
    f += sum(a * b for a, b in zip(dims_t[:-1], dims_t[1:]))
    f += (cfg.n_sparse + 1) ** 2 * cfg.embed_dim  # interaction
    return f
