"""The paper's own architecture (Table I): consistent encode-process-decode
GNN, 'small' (N_H=8, M=4, 2 MLP hidden) and 'large' (N_H=32, M=4, 5 hidden),
trained on Taylor-Green-vortex velocity autoencoding over SEM meshes."""
from repro.core.gnn import GNNConfig

ARCH_ID = "paper-gnn"
FAMILY = "gnn"


def config() -> GNNConfig:
    return GNNConfig.large()


def small_config() -> GNNConfig:
    return GNNConfig.small()


def smoke_config() -> GNNConfig:
    return GNNConfig(hidden=4, n_mp_layers=2, mlp_hidden_layers=1)
