"""DeepSeek-V2 236B [arXiv:2405.04434]: 60L d5120, MLA (kv_lora=512),
MoE 160 routed top-6 + 2 shared experts (d_ff 1536), first layer dense."""
from repro.models.transformer.config import MLAConfig, MoEConfig, TransformerConfig

ARCH_ID = "deepseek-v2-236b"
FAMILY = "lm"


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        vocab=102400, d_model=5120, n_layers=60,
        n_q=128, n_kv=128, head_dim=192,          # MLA qk_dim = 128 nope + 64 rope
        d_ff=12288,                               # first dense layer hidden
        mlp_variant="swiglu",
        mla=MLAConfig(q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v_dim=128),
        moe=MoEConfig(n_experts=160, top_k=6, d_ff=1536, n_shared=2,
                      first_dense_layers=1, first_dense_ff=12288,
                      capacity_factor=1.25, renormalize=False, aux_coef=0.003),
        rope_theta=10000.0,
        tied_embeddings=False,
        train_microbatches=16,
        remat="full",   # dots policy would save per-layer expert/mlp matmul outputs
        attn_parallel="heads",                    # 128 heads / 16 = 8 per device
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke",
        vocab=256, d_model=32, n_layers=3,
        n_q=4, n_kv=4, head_dim=24,
        d_ff=64, mlp_variant="swiglu",
        mla=MLAConfig(q_lora=48, kv_lora=32, qk_nope=16, qk_rope=8, v_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=16, n_shared=1,
                      first_dense_layers=1, first_dense_ff=64,
                      # E/K => capacity == local token count: drop-free, so
                      # smoke tests can compare prefill/decode/forward exactly
                      capacity_factor=4.0, renormalize=False),
        tied_embeddings=False,
        attn_parallel="heads",
    )
