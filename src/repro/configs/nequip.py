"""NequIP [arXiv:2101.03164]: 5L, 32 channels, l_max=2, 8 RBF, cutoff 5."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import gnn_common as G
from repro.models.gnn_zoo.nequip import NequIPConfig, init_nequip, nequip_forward

ARCH_ID = "nequip"
FAMILY = "gnn"


def config(shape: dict | None = None) -> NequIPConfig:
    return NequIPConfig(n_layers=5, hidden_mul=32, l_max=2, n_rbf=8, cutoff=5.0)


def smoke_config() -> NequIPConfig:
    return NequIPConfig(n_layers=2, hidden_mul=8, l_max=2, n_rbf=4, cutoff=3.0,
                        n_species=4)


def _inputs_factory(shape, R, n_pad, e_pad, graph_axis, edge_parallel=False):
    sds = jax.ShapeDtypeStruct
    inputs = {"species": sds((R, n_pad), jnp.int32),
              "pos": sds((R, n_pad, 3), jnp.float32),
              "target": sds((R, n_pad), jnp.float32)}
    specs = {"species": P(graph_axis, None),
             "pos": P(graph_axis, None, None),
             "target": P(graph_axis, None)}
    return inputs, specs


def _loss_local_factory(shape, halo, graph_axis, mesh, overrides=None):
    cfg = config(shape)
    ov = overrides or {}
    kw = {}
    if ov.get("remat"):
        kw["remat"] = True
    if ov.get("act_bf16"):
        kw["act_dtype"] = jnp.bfloat16
    if ov.get("edge_parallel"):
        kw["edge_parallel_axes"] = ("model",)
    if kw:
        cfg = type(cfg)(**{**cfg.__dict__, **kw})

    def loss_local(params, inputs, graph):
        e_site = nequip_forward(params, inputs["species"][0], inputs["pos"][0],
                                graph, halo, cfg)
        return G.consistent_mse_loss(e_site, inputs["target"][0],
                                     graph["node_inv_mult"], (graph_axis,))
    return loss_local


def _param_factory(shape):
    cfg = config(shape)
    return jax.eval_shape(functools.partial(init_nequip, cfg=cfg),
                          jax.random.PRNGKey(0))


def build_dryrun_cell(shape_id, mesh, overrides=None):
    return G.build_gnn_dryrun_cell(
        shape_id, mesh,
        loss_local_factory=_loss_local_factory,
        inputs_factory=_inputs_factory,
        param_factory=_param_factory,
        overrides=overrides)
