"""Granite-34B-code [arXiv:2405.04324]: 88L d6144, MQA (kv=1), gelu MLP 24576."""
from repro.models.transformer.config import TransformerConfig

ARCH_ID = "granite-34b"
FAMILY = "lm"


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        vocab=49152, d_model=6144, n_layers=88,
        n_q=48, n_kv=1, head_dim=128,
        d_ff=24576, mlp_variant="gelu_mlp",
        rope_theta=10000.0,
        tied_embeddings=True,
        train_microbatches=16,
        remat="full",   # dots policy would save per-layer expert/mlp matmul outputs
        attn_parallel="heads",                    # 48 / 16 = 3
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke",
        vocab=256, d_model=32, n_layers=2,
        n_q=4, n_kv=1, head_dim=16,
        d_ff=96, mlp_variant="gelu_mlp",
        tied_embeddings=True,
        attn_parallel="heads",
    )
