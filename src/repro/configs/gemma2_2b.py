"""Gemma-2-2B [arXiv:2408.00118]: 26L d2304, 8H/kv4 head_dim 256, GeGLU 9216,
alternating local(4096)/global attention, logit softcaps, pre+post norms."""
from repro.models.transformer.config import TransformerConfig

ARCH_ID = "gemma2-2b"
FAMILY = "lm"


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        vocab=256000, d_model=2304, n_layers=26,
        n_q=8, n_kv=4, head_dim=256,
        d_ff=9216, mlp_variant="geglu",
        rope_theta=10000.0,
        window=4096, window_pattern="alternate",
        attn_softcap=50.0, final_softcap=30.0,
        post_norms=True, gemma_norm=True,
        tied_embeddings=True,
        train_microbatches=4,
        attn_parallel="seq",                      # 8 heads don't divide 16
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke",
        vocab=256, d_model=32, n_layers=2,
        n_q=4, n_kv=2, head_dim=16,
        d_ff=64, mlp_variant="geglu",
        window=8, window_pattern="alternate",
        attn_softcap=50.0, final_softcap=30.0,
        post_norms=True, gemma_norm=True,
        tied_embeddings=True,
        attn_parallel="seq",
    )
