"""DBRX 132B [hf:databricks/dbrx-base]: 40L d6144, GQA kv=8, MoE 16e top-4."""
from repro.models.transformer.config import MoEConfig, TransformerConfig

ARCH_ID = "dbrx-132b"
FAMILY = "lm"


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        vocab=100352, d_model=6144, n_layers=40,
        n_q=48, n_kv=8, head_dim=128,
        d_ff=10752, mlp_variant="swiglu",
        moe=MoEConfig(n_experts=16, top_k=4, d_ff=10752,
                      capacity_factor=1.25, renormalize=True, aux_coef=0.01),
        rope_theta=500000.0,
        tied_embeddings=False,
        train_microbatches=16,
        remat="full",   # dots policy would save per-layer expert/mlp matmul outputs
        attn_parallel="heads",                    # 48 heads / 16 = 3 per device
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke",
        vocab=256, d_model=32, n_layers=2,
        n_q=4, n_kv=2, head_dim=16,
        d_ff=48, mlp_variant="swiglu",
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=48, capacity_factor=2.0),
        tied_embeddings=False,
        attn_parallel="heads",
    )
