"""GraphCast [arXiv:2212.12794]: 16L d512 encoder-processor-decoder.

The assigned generic-graph shapes exercise the processor at scale; the
weather configuration (mesh_refinement=6, n_vars=227, icosahedral multimesh)
is available via ``weather_config`` and examples/graphcast_weather.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import gnn_common as G
from repro.core.graph_state import NMPPlan
from repro.models.gnn_zoo.graphcast import (
    GraphCastConfig, graphcast_forward, init_graphcast,
)

ARCH_ID = "graphcast"
FAMILY = "gnn"
EDGE_IN = 4


def config(shape: dict | None = None) -> GraphCastConfig:
    shape = shape or G.GNN_SHAPES["full_graph_sm"]
    if shape["kind"] == "molecule":
        return GraphCastConfig(in_dim=8, hidden=512, n_layers=16, out_dim=1,
                               edge_in=EDGE_IN)
    return GraphCastConfig(in_dim=shape["d_feat"], hidden=512, n_layers=16,
                           out_dim=shape["n_classes"], edge_in=EDGE_IN)


def weather_config(refinement: int = 6) -> GraphCastConfig:
    return GraphCastConfig(in_dim=227, hidden=512, n_layers=16, out_dim=227,
                           edge_in=EDGE_IN, name=f"graphcast-weather-r{refinement}")


def smoke_config() -> GraphCastConfig:
    return GraphCastConfig(in_dim=16, hidden=32, n_layers=3, out_dim=4,
                           mlp_hidden_layers=1)


def _inputs_factory(shape, R, n_pad, e_pad, graph_axis, edge_parallel=False):
    sds = jax.ShapeDtypeStruct
    d = shape.get("d_feat", 8)
    inputs = {"x": sds((R, n_pad, d), jnp.float32),
              "edge_feats": sds((R, e_pad, EDGE_IN), jnp.float32),
              "labels": sds((R, n_pad), jnp.int32)}
    specs = {"x": P(graph_axis, None, None),
             "edge_feats": P(graph_axis, "model" if edge_parallel else None, None),
             "labels": P(graph_axis, None)}
    return inputs, specs


def _loss_local_factory(shape, halo, graph_axis, mesh, overrides=None):
    cfg = config(shape)
    ov = overrides or {}
    if ov.get("edge_parallel"):
        cfg = type(cfg)(**{**cfg.__dict__, "edge_parallel_axes": ("model",)})
    if ov.get("remat"):
        cfg = type(cfg)(**{**cfg.__dict__, "remat": True})
    if ov.get("act_bf16"):
        cfg = type(cfg)(**{**cfg.__dict__, "act_dtype": jnp.bfloat16})
    if ov.get("remat_segment"):
        cfg = type(cfg)(**{**cfg.__dict__, "remat_segment": int(ov["remat_segment"])})
    params_bf16 = bool(ov.get("params_bf16"))
    regression = shape["kind"] == "molecule"

    plan = NMPPlan(halo=halo)

    def loss_local(params, inputs, graph):
        if params_bf16:
            params = jax.tree.map(
                lambda x: x.astype(jnp.bfloat16)
                if x.dtype == jnp.float32 else x, params)
        out = graphcast_forward(params, inputs["x"][0], inputs["edge_feats"][0],
                                graph, plan, cfg)
        if regression:
            tgt = inputs["labels"][0].astype(jnp.float32)[:, None]
            return G.consistent_mse_loss(out, tgt, graph["node_inv_mult"], (graph_axis,))
        return G.consistent_ce_loss(out, inputs["labels"][0],
                                    graph["node_inv_mult"], (graph_axis,))
    return loss_local


def _param_factory(shape):
    cfg = config(shape)
    return jax.eval_shape(functools.partial(init_graphcast, cfg=cfg),
                          jax.random.PRNGKey(0))


def build_dryrun_cell(shape_id, mesh, overrides=None):
    return G.build_gnn_dryrun_cell(
        shape_id, mesh,
        loss_local_factory=_loss_local_factory,
        inputs_factory=_inputs_factory,
        param_factory=_param_factory,
        overrides=overrides)
