"""GNN-family shapes + dry-run cell machinery.

Mesh layout for GNN cells on the production mesh (see DESIGN.md):
  * 'data' axis  = the paper's spatial graph decomposition (R = 16
    sub-graphs; halo ppermute/all_to_all run over 'data');
  * 'model' axis = hidden-dim tensor parallelism where the arch is wide
    enough (GraphCast d=512); replicated otherwise (v1 — the §Perf log
    hillclimbs edge-parallel sharding over 'model' for one cell);
  * 'pod' axis   = data parallelism over snapshots (gradient psum only).

The full-config dry-run builds *spec-only* partitioned metadata
(`synthetic_partitioned_meta`): shapes + XOR-pairing ppermute rounds, no
host-side partitioning of 61M-edge graphs. Smoke tests run the REAL
partitioner on reduced graphs.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.graph_state import ShardedGraph
from repro.core.halo import NEIGHBOR, NONE, HaloSpec
from repro.train.optimizer import AdamWConfig, adamw_update, init_adamw

GNN_SHAPES: Dict[str, dict] = {
    "full_graph_sm": dict(kind="full", n_nodes=2708, n_edges=10556, d_feat=1433,
                          n_classes=7),
    "minibatch_lg": dict(kind="minibatch", n_nodes=232965, n_edges=114615892,
                         batch_nodes=1024, fanouts=(15, 10), d_feat=602,
                         n_classes=41),
    "ogb_products": dict(kind="full", n_nodes=2449029, n_edges=61859140,
                         d_feat=100, n_classes=47),
    "molecule": dict(kind="molecule", n_nodes=30, n_edges=64, batch=128),
}


def _round_up(x, m=128):
    # multiple of 128 so the edge dim can also shard over the model axis
    # (edge-parallel §Perf mode)
    return ((int(x) + m - 1) // m) * m


EDGE_KEYS = ("edge_src", "edge_dst", "edge_mask", "edge_inv_mult")


def xor_rounds(R: int, k: int) -> Tuple[Tuple[Tuple[int, int], ...], ...]:
    """k ppermute rounds from XOR pairings (valid perfect matchings for R=2^j)."""
    rounds = []
    for c in range(1, k + 1):
        perm = []
        for r in range(R):
            s = r ^ c
            if s < R:
                perm.append((r, s))
        rounds.append(tuple(perm))
    return tuple(rounds)


def synthetic_partitioned_meta(R: int, n_nodes: int, n_edges_directed: int,
                               halo_frac: float = 0.12, k_rounds: int = 8,
                               imbalance: float = 1.10):
    """ShapeDtypeStructs of ``PartitionedGraphs.device_arrays()`` for a graph
    of this size partitioned R ways (dry-run only — no data)."""
    n_pad = _round_up(n_nodes * imbalance / R + 1)
    e_pad = _round_up(n_edges_directed * imbalance / R + 1)
    buf = _round_up(max(n_pad * halo_frac / 4, 8))
    f32 = jnp.float32
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    meta = dict(
        node_mask=sds((R, n_pad), f32), node_inv_mult=sds((R, n_pad), f32),
        edge_src=sds((R, e_pad), i32), edge_dst=sds((R, e_pad), i32),
        edge_mask=sds((R, e_pad), f32), edge_inv_mult=sds((R, e_pad), f32),
        a2a_send_idx=sds((R, R, buf), i32), a2a_send_mask=sds((R, R, buf), f32),
        a2a_recv_idx=sds((R, R, buf), i32), a2a_recv_mask=sds((R, R, buf), f32),
        nbr_send_idx=sds((R, k_rounds, buf), i32),
        nbr_send_mask=sds((R, k_rounds, buf), f32),
        nbr_recv_idx=sds((R, k_rounds, buf), i32),
        nbr_recv_mask=sds((R, k_rounds, buf), f32),
    )
    return meta, n_pad, e_pad


def meta_specs(meta, graph_axis: str, edge_parallel: bool = False):
    out = {}
    for k, v in meta.items():
        if edge_parallel and k in EDGE_KEYS:
            out[k] = P(graph_axis, "model", *([None] * (v.ndim - 2)))
        else:
            out[k] = P(graph_axis, *([None] * (v.ndim - 1)))
    return out


# ---------------------------------------------------------------------------
# generic distributed GNN train step (shard_map over the whole mesh)
# ---------------------------------------------------------------------------

def make_gnn_train_step(loss_local, mesh: Mesh, in_specs_inputs, graph_axis: str,
                        opt: AdamWConfig, edge_parallel: bool = False):
    """loss_local(params, inputs, meta) -> scalar (may use collectives).

    Returns (step, wrap) where step(state, inputs, meta) -> (state', loss) is
    ready for jit with the in_specs produced alongside.
    """
    all_axes = tuple(mesh.axis_names)

    def step_local(state, inputs, meta):
        graph_l = ShardedGraph.from_arrays({k: v[0] for k, v in meta.items()})

        def loss_fn(p):
            return loss_local(p, inputs, graph_l)

        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        grads = jax.tree.map(lambda g: jax.lax.pmean(g, all_axes), grads)
        loss = jax.lax.pmean(loss, all_axes)
        new_p, new_opt, _ = adamw_update(grads, state["opt"], state["params"], opt)
        return {"params": new_p, "opt": new_opt}, loss

    def wrap(meta):
        return jax.shard_map(
            step_local, mesh=mesh,
            in_specs=(P(), in_specs_inputs,
                      meta_specs(meta, graph_axis, edge_parallel)),
            out_specs=(P(), P()),
            check_vma=False,
        )

    return step_local, wrap


def make_gnn_eval_step(fwd_local, mesh: Mesh, in_specs_inputs, out_specs,
                       graph_axis: str):
    def eval_local(params, inputs, meta):
        graph_l = ShardedGraph.from_arrays({k: v[0] for k, v in meta.items()})
        return fwd_local(params, inputs, graph_l)

    def wrap(meta):
        return jax.shard_map(
            eval_local, mesh=mesh,
            in_specs=(P(), in_specs_inputs, meta_specs(meta, graph_axis)),
            out_specs=out_specs, check_vma=False,
        )
    return eval_local, wrap


def consistent_ce_loss(logits, labels, node_inv_mult, axes):
    """Partition-consistent node-classification cross entropy (Eq. 6 analog)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    s = jnp.sum(-ll * node_inv_mult)
    n = jnp.sum(node_inv_mult)
    return jax.lax.psum(s, axes) / jnp.maximum(jax.lax.psum(n, axes), 1e-9)


def consistent_mse_loss(pred, target, node_inv_mult, axes):
    err = jnp.sum((pred - target) ** 2, axis=-1) if pred.ndim > 1 else (pred - target) ** 2
    s = jnp.sum(err * node_inv_mult)
    n = jnp.sum(node_inv_mult)
    return jax.lax.psum(s, axes) / jnp.maximum(jax.lax.psum(n, axes), 1e-9)


# ---------------------------------------------------------------------------
# dry-run cell builder shared by the four GNN archs
# ---------------------------------------------------------------------------

def build_gnn_dryrun_cell(shape_id: str, mesh: Mesh, *,
                          loss_local_factory, inputs_factory, param_factory,
                          halo_mode: str = NEIGHBOR, n_params_meta: int = 0,
                          overrides=None):
    overrides = overrides or {}
    edge_parallel = bool(overrides.get("edge_parallel"))
    """Wire one (gnn arch x shape) cell.

    loss_local_factory(shape, halo, graph_axis, mesh) -> loss_local(params, inputs, meta_l)
    inputs_factory(shape, R_graph, n_pad, e_pad, batch_axes) -> (inputs_sds, inputs_specs)
    param_factory(shape) -> params ShapeDtypeStruct tree (replicated P()).
    """
    shape = dict(GNN_SHAPES[shape_id])
    graph_axis = "data"
    R = mesh.shape[graph_axis]
    kind = shape["kind"]

    if kind == "full":
        meta, n_pad, e_pad = synthetic_partitioned_meta(
            R, shape["n_nodes"], shape["n_edges"] * 2)
        halo = HaloSpec(mode=halo_mode, axis=graph_axis, perms=xor_rounds(R, 8))
    elif kind == "minibatch":
        n_pad, e_pad = _minibatch_pads(shape)
        meta = _block_meta_sds(R, n_pad, e_pad)
        halo = HaloSpec(mode=NONE, axis=graph_axis)
    else:  # molecule: per-device block-diagonal batch
        per_dev = max(shape["batch"] // R, 1)
        n_pad = per_dev * shape["n_nodes"]
        e_pad = per_dev * shape["n_edges"]
        meta = _block_meta_sds(R, n_pad, e_pad)
        halo = HaloSpec(mode=NONE, axis=graph_axis)

    inputs, input_specs = inputs_factory(shape, R, n_pad, e_pad, graph_axis,
                                          edge_parallel=edge_parallel)
    loss_local = loss_local_factory(shape, halo, graph_axis, mesh,
                                    overrides=overrides)
    params_sds = param_factory(shape)
    opt = AdamWConfig()
    opt_sds = jax.eval_shape(functools.partial(init_adamw, cfg=opt), params_sds)
    state_sds = {"params": params_sds, "opt": opt_sds}

    step_local, wrap = make_gnn_train_step(loss_local, mesh, input_specs,
                                           graph_axis, opt,
                                           edge_parallel=edge_parallel)

    def step(state, inputs_, meta_):
        return wrap(meta_)(state, inputs_, meta_)

    args = (state_sds, inputs, meta)
    in_specs = (P(), input_specs, meta_specs(meta, graph_axis, edge_parallel))
    out_specs = (P(), P())
    cell_meta = dict(kind=kind, n_pad=n_pad, e_pad=e_pad,
                     halo_mode=halo.mode, graph_axis=graph_axis,
                     donate=(0,))
    return step, args, in_specs, out_specs, cell_meta


def _minibatch_pads(shape):
    from repro.graph.sampler import SampledBlock
    seeds_per_dev = max(shape["batch_nodes"] // 16, 1)
    n_pad, e_pad = SampledBlock.pad_sizes(seeds_per_dev, shape["fanouts"])
    return _round_up(n_pad), _round_up(e_pad)


def _block_meta_sds(R, n_pad, e_pad):
    f32, i32 = jnp.float32, jnp.int32
    sds = jax.ShapeDtypeStruct
    # no-halo meta still carries (tiny) halo arrays so device_arrays keys match
    return dict(
        node_mask=sds((R, n_pad), f32), node_inv_mult=sds((R, n_pad), f32),
        edge_src=sds((R, e_pad), i32), edge_dst=sds((R, e_pad), i32),
        edge_mask=sds((R, e_pad), f32), edge_inv_mult=sds((R, e_pad), f32),
        a2a_send_idx=sds((R, R, 8), i32), a2a_send_mask=sds((R, R, 8), f32),
        a2a_recv_idx=sds((R, R, 8), i32), a2a_recv_mask=sds((R, R, 8), f32),
        nbr_send_idx=sds((R, 1, 8), i32), nbr_send_mask=sds((R, 1, 8), f32),
        nbr_recv_idx=sds((R, 1, 8), i32), nbr_recv_mask=sds((R, 1, 8), f32),
    )
