"""Llama-3.2-3B [hf:meta-llama/Llama-3.2-3B]: 28L d3072, GQA kv=8, swiglu 8192."""
from repro.models.transformer.config import TransformerConfig

ARCH_ID = "llama3.2-3b"
FAMILY = "lm"


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        vocab=128256, d_model=3072, n_layers=28,
        n_q=24, n_kv=8, head_dim=128,
        d_ff=8192, mlp_variant="swiglu",
        rope_theta=500000.0,
        tied_embeddings=True,
        train_microbatches=4,
        attn_parallel="seq",                      # 24 heads don't divide 16
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke",
        vocab=256, d_model=32, n_layers=2,
        n_q=4, n_kv=2, head_dim=16,
        d_ff=64, mlp_variant="swiglu",
        tied_embeddings=True,
        attn_parallel="seq",
    )
