"""GAT-Cora [arXiv:1710.10903]: 2L, hidden 8, 8 heads, attn aggregation."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import gnn_common as G
from repro.models.gnn_zoo.gat import GATConfig, gat_forward, init_gat

ARCH_ID = "gat-cora"
FAMILY = "gnn"


def config(shape: dict | None = None) -> GATConfig:
    shape = shape or G.GNN_SHAPES["full_graph_sm"]
    if shape["kind"] == "molecule":
        return GATConfig(in_dim=8, hidden=8, heads=8, n_classes=1, n_layers=2)
    return GATConfig(in_dim=shape["d_feat"], hidden=8, heads=8,
                     n_classes=shape["n_classes"], n_layers=2)


def smoke_config() -> GATConfig:
    return GATConfig(in_dim=16, hidden=4, heads=2, n_classes=3, n_layers=2)


def _inputs_factory(shape, R, n_pad, e_pad, graph_axis, edge_parallel=False):
    sds = jax.ShapeDtypeStruct
    d = shape.get("d_feat", 8)
    inputs = {"x": sds((R, n_pad, d), jnp.float32),
              "labels": sds((R, n_pad), jnp.int32)}
    specs = {"x": P(graph_axis, None, None), "labels": P(graph_axis, None)}
    return inputs, specs


def _loss_local_factory(shape, halo, graph_axis, mesh, overrides=None):
    cfg = config(shape)
    regression = shape["kind"] == "molecule"

    def loss_local(params, inputs, graph):
        x = inputs["x"][0]
        out = gat_forward(params, x, graph, halo, cfg)
        if regression:
            tgt = inputs["labels"][0].astype(jnp.float32)[:, None]
            return G.consistent_mse_loss(out, tgt, graph["node_inv_mult"], (graph_axis,))
        return G.consistent_ce_loss(out, inputs["labels"][0],
                                    graph["node_inv_mult"], (graph_axis,))
    return loss_local


def _param_factory(shape):
    cfg = config(shape)
    return jax.eval_shape(functools.partial(init_gat, cfg=cfg), jax.random.PRNGKey(0))


def build_dryrun_cell(shape_id, mesh, overrides=None):
    return G.build_gnn_dryrun_cell(
        shape_id, mesh,
        loss_local_factory=_loss_local_factory,
        inputs_factory=_inputs_factory,
        param_factory=_param_factory,
        overrides=overrides)
