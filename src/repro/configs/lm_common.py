"""Shared helpers for LM architecture configs: sharding rules + shape table."""
from __future__ import annotations

from typing import Dict

from jax.sharding import Mesh

from repro.models.transformer.config import TransformerConfig
from repro.sharding import Rules


# The four LM input-shape cells (assignment spec).
LM_SHAPES: Dict[str, dict] = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


def batch_axes_for(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def lm_rules(mesh: Mesh, cfg: TransformerConfig) -> Rules:
    """Logical-dim -> mesh-axis rules.

    TP over 'model' for mlp/vocab/experts (+ heads when divisible); FSDP over
    'data' for the embed dim of every weight; activations batch-sharded over
    ('pod','data'). Heads that don't divide the model axis stay replicated —
    those archs use sequence-parallel attention instead (cfg.attn_parallel).
    """
    n_model = mesh.shape["model"]
    heads_ok = cfg.n_q % n_model == 0
    kv_ok = cfg.n_kv % n_model == 0
    return {
        "act_batch": batch_axes_for(mesh),
        "act_vocab": "model",
        "act_heads": "model" if heads_ok else None,
        "act_kv_heads": "model" if kv_ok else None,
        "vocab": "model",
        "embed": "data",
        "mlp": "model",
        "experts": "model",
        "expert_mlp": None,
        "heads": "model" if heads_ok else None,
        "kv_heads": "model" if kv_ok else None,
        "head_dim": None,
        "q_lora": None,
        "kv_lora": None,
        "layers": None,
    }
