"""Architecture registry: 10 assigned archs + the paper's own GNN.

Modules are imported lazily so that e.g. LM-only workflows don't pull the
equivariant-irreps machinery.
"""
from __future__ import annotations

import importlib
from typing import Dict

# arch id -> (module path, family)
ARCHS: Dict[str, tuple] = {
    # LM family
    "deepseek-v2-236b": ("repro.configs.deepseek_v2_236b", "lm"),
    "dbrx-132b": ("repro.configs.dbrx_132b", "lm"),
    "llama3.2-3b": ("repro.configs.llama3_2_3b", "lm"),
    "granite-34b": ("repro.configs.granite_34b", "lm"),
    "gemma2-2b": ("repro.configs.gemma2_2b", "lm"),
    # GNN family
    "mace": ("repro.configs.mace", "gnn"),
    "graphcast": ("repro.configs.graphcast", "gnn"),
    "gat-cora": ("repro.configs.gat_cora", "gnn"),
    "nequip": ("repro.configs.nequip", "gnn"),
    # RecSys
    "dlrm-rm2": ("repro.configs.dlrm_rm2", "recsys"),
    # the paper's own architecture (not part of the 40-cell matrix)
    "paper-gnn": ("repro.configs.paper_gnn", "gnn"),
}


def get_arch(arch_id: str):
    path, family = ARCHS[arch_id]
    return importlib.import_module(path), family


def family_of(arch_id: str) -> str:
    return ARCHS[arch_id][1]


def assigned_archs():
    return [a for a in ARCHS if a != "paper-gnn"]
