"""Host data pipeline: deterministic, replayable, prefetching device feeds.

Production needs on a pod: (a) each host prepares only its addressable shard
(b) batches are keyed by step so a restarted/rescheduled job replays the
exact stream (the fault-tolerance test asserts bitwise recovery), (c) host
preprocessing overlaps device compute (background prefetch thread), and
(d) arrays land directly with the step function's NamedShardings.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator, Optional

import numpy as np
import jax


class PrefetchingLoader:
    """Wraps ``batch_fn(step) -> pytree of np arrays`` with device placement
    and N-deep background prefetch.

    ``shardings``: pytree of NamedSharding (or None leaves) congruent with
    the batch; ``device_put`` happens on the prefetch thread so H2D transfer
    overlaps the previous step's compute.
    """

    def __init__(self, batch_fn: Callable[[int], Any], shardings: Any = None,
                 prefetch: int = 2, start_step: int = 0):
        self.batch_fn = batch_fn
        self.shardings = shardings
        self.prefetch = max(1, prefetch)
        self._q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _place(self, batch):
        if self.shardings is None:
            return jax.tree.map(jax.numpy.asarray, batch)
        return jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else jax.numpy.asarray(x),
            batch, self.shardings)

    def _work(self):
        step = self._step
        try:
            while not self._stop.is_set():
                item = (step, self._place(self.batch_fn(step)))
                while not self._stop.is_set():
                    try:
                        self._q.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                step += 1
        except BaseException as e:  # surfaced on next __next__
            self._err = e

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        if self._err is not None:
            raise self._err
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=5)


def token_batch_fn(vocab: int, batch: int, seq: int, seed: int = 0):
    """Deterministic synthetic LM stream: (tokens, targets) keyed by step."""
    def fn(step: int):
        rng = np.random.default_rng(np.uint64(seed) + np.uint64(step))
        toks = rng.integers(0, vocab, (batch, seq + 1), dtype=np.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
    return fn


def prepare_gnn_meta(pg, coords, *, backend: str = "xla",
                     seg_block_n: int | None = 128,
                     seg_block_e: int | None = 128,
                     schedule: str = "blocking", hidden: int | None = None,
                     hierarchy=None):
    """Host-side static metadata prep for the GNN step functions.

    Wraps ``rank_static_inputs`` and, for the fused NMP backend, attaches the
    compact gather/scatter index layout (``seg_perm``/``seg_src``/``seg_dst``)
    from the per-partition cache (``PartitionedGraphs.segment_layout``): the
    O(E log E) sort runs once per partition here — never inside the per-step
    data path.

    Pass ``seg_block_n=None`` / ``seg_block_e=None`` to pick tile sizes from
    the static autotune table (``repro.kernels.segment_agg.ops.
    pick_block_sizes``, keyed on ``hidden``/dtype/backend and overridable
    via the ``REPRO_SEG_BLOCKS`` env var).

    ``schedule="overlap"`` additionally attaches the cached interior/boundary
    edge split (and, for the fused backend, the per-side layouts) consumed
    by ``nmp_layer(schedule="overlap")``.

    ``hierarchy`` (a ``repro.core.coarsen.MultiLevelGraphs`` whose level 0
    is ``pg``) switches to the multilevel layout: the same level-0 keys plus
    ``lvl{l}_*`` coarse-level arrays and restriction/prolongation transfer
    maps, with the per-level seg layouts / interior splits attached under
    the same rules as level 0.
    """
    from repro.core.reference import rank_static_inputs
    seg = None
    if backend == "fused":
        if seg_block_n is None or seg_block_e is None:
            if hidden is None:
                raise ValueError(
                    "autotuned block sizes (seg_block_n/seg_block_e=None) "
                    "need hidden= — the table is keyed on the model width")
            from repro.kernels.segment_agg.ops import pick_block_sizes
            auto_n, auto_e = pick_block_sizes(hidden)
            seg = (seg_block_n or auto_n, seg_block_e or auto_e)
        else:
            seg = (seg_block_n, seg_block_e)
    if hierarchy is not None:
        if hierarchy.levels[0] is not pg:
            raise ValueError("hierarchy.levels[0] must be the pg passed in "
                             "(the fine partition the step fns shard over)")
        # the hierarchy carries its build-time coords (coarse centroids are
        # derived from them) — refuse a mismatched coords argument rather
        # than silently using a different coordinate source per level
        if coords is not None and coords is not hierarchy.coords[0] \
                and not np.array_equal(coords, hierarchy.coords[0]):
            raise ValueError(
                "coords disagrees with hierarchy.coords[0]: the hierarchy's "
                "build-time coordinates define every level's static edge "
                "features — rebuild the hierarchy from the transformed mesh "
                "instead of passing different coords here")
        from repro.core.coarsen import multilevel_static_inputs
        return multilevel_static_inputs(hierarchy, seg_layout=seg,
                                        split=schedule == "overlap")
    return rank_static_inputs(pg, coords, seg_layout=seg,
                              split=schedule == "overlap")


def host_shard(batch, host_id: int, n_hosts: int):
    """Slice a global batch to this host's addressable rows (multi-host IO)."""
    def sl(x):
        per = x.shape[0] // n_hosts
        return x[host_id * per:(host_id + 1) * per]
    return jax.tree.map(sl, batch)
