"""Host data pipeline: deterministic, replayable, prefetching device feeds.

Production needs on a pod: (a) each host prepares only its addressable shard
(b) batches are keyed by step so a restarted/rescheduled job replays the
exact stream (the fault-tolerance test asserts bitwise recovery), (c) host
preprocessing overlaps device compute (background prefetch thread), and
(d) arrays land directly with the step function's NamedShardings.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator, Optional

import numpy as np
import jax


class PrefetchingLoader:
    """Wraps ``batch_fn(step) -> pytree of np arrays`` with device placement
    and N-deep background prefetch.

    ``shardings``: pytree of NamedSharding (or None leaves) congruent with
    the batch; ``device_put`` happens on the prefetch thread so H2D transfer
    overlaps the previous step's compute.
    """

    def __init__(self, batch_fn: Callable[[int], Any], shardings: Any = None,
                 prefetch: int = 2, start_step: int = 0):
        self.batch_fn = batch_fn
        self.shardings = shardings
        self.prefetch = max(1, prefetch)
        self._q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _place(self, batch):
        if self.shardings is None:
            return jax.tree.map(jax.numpy.asarray, batch)
        return jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else jax.numpy.asarray(x),
            batch, self.shardings)

    def _work(self):
        step = self._step
        try:
            while not self._stop.is_set():
                item = (step, self._place(self.batch_fn(step)))
                while not self._stop.is_set():
                    try:
                        self._q.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                step += 1
        except BaseException as e:  # surfaced on next __next__
            self._err = e

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        # Poll with a timeout and re-check the producer each lap: a plain
        # blocking get() would hang forever when the producer thread dies
        # (batch_fn raised) with the queue empty — the error is set AFTER
        # the consumer already parked on the queue.  Queued batches drain
        # before the error surfaces, so a mid-stream failure still delivers
        # every batch produced ahead of it.
        while True:
            try:
                return self._q.get(timeout=0.1)
            except queue.Empty:
                if self._err is not None:
                    raise self._err
                if not self._thread.is_alive():
                    # producer exited cleanly (close() raced us): no more
                    # items will ever arrive
                    raise StopIteration

    def close(self):
        self._stop.set()
        self._thread.join(timeout=5)


def token_batch_fn(vocab: int, batch: int, seq: int, seed: int = 0):
    """Deterministic synthetic LM stream: (tokens, targets) keyed by step."""
    def fn(step: int):
        rng = np.random.default_rng(np.uint64(seed) + np.uint64(step))
        toks = rng.integers(0, vocab, (batch, seq + 1), dtype=np.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
    return fn


# Static graph metadata prep for the GNN step functions moved to
# ``repro.core.graph_state.ShardedGraph.build(pg, coords, plan, hierarchy=)``
# — the host-side layout/split passes stay memoized per partition there.


def host_shard(batch, host_id: int, n_hosts: int):
    """Slice a global batch to this host's addressable rows (multi-host IO).

    The leading (batch) dim must divide evenly: silently dropping trailing
    rows would desynchronize the hosts' step counts (and lose data), so an
    uneven batch raises instead.
    """
    def sl(x):
        if x.shape[0] % n_hosts != 0:
            raise ValueError(
                f"batch dim {x.shape[0]} is not divisible by n_hosts="
                f"{n_hosts}: host_shard would silently drop "
                f"{x.shape[0] % n_hosts} trailing rows — pad or resize the "
                "global batch to a multiple of the host count")
        per = x.shape[0] // n_hosts
        return x[host_id * per:(host_id + 1) * per]
    return jax.tree.map(sl, batch)
