"""Host data pipeline: deterministic, replayable, prefetching device feeds.

Production needs on a pod: (a) each host prepares only its addressable shard
(b) batches are keyed by step so a restarted/rescheduled job replays the
exact stream (the fault-tolerance test asserts bitwise recovery), (c) host
preprocessing overlaps device compute (background prefetch thread), and
(d) arrays land directly with the step function's NamedShardings.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator, Optional

import numpy as np
import jax


class PrefetchingLoader:
    """Wraps ``batch_fn(step) -> pytree of np arrays`` with device placement
    and N-deep background prefetch.

    ``shardings``: pytree of NamedSharding (or None leaves) congruent with
    the batch; ``device_put`` happens on the prefetch thread so H2D transfer
    overlaps the previous step's compute.

    ``n_producers``: producer threads sharing the one bounded queue (the
    serving engine's ingest transport runs several solver feeds through a
    single loader).  Producer t generates steps ``start_step + t,
    start_step + t + n_producers, ...`` — the step stream is covered
    exactly once with no shared mutable counter, but items may interleave
    across producers, so consumers must key on the step id each item
    carries (every batch function in this repo is pure in ``step``).
    Error semantics are drain-then-raise: the FIRST producer error (kept
    under a lock — concurrent failures must not overwrite it) stops every
    producer, batches already queued drain normally, then the error
    surfaces on ``__next__``.
    """

    def __init__(self, batch_fn: Callable[[int], Any], shardings: Any = None,
                 prefetch: int = 2, start_step: int = 0, n_producers: int = 1):
        self.batch_fn = batch_fn
        self.shardings = shardings
        self.prefetch = max(1, prefetch)
        self.n_producers = max(1, n_producers)
        self._q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        self._stop = threading.Event()
        self._err: Optional[BaseException] = None
        self._err_lock = threading.Lock()
        self._threads = [
            threading.Thread(target=self._work, args=(start_step + t,),
                             daemon=True)
            for t in range(self.n_producers)]
        for t in self._threads:
            t.start()

    def _place(self, batch):
        if self.shardings is None:
            return jax.tree.map(jax.numpy.asarray, batch)
        return jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else jax.numpy.asarray(x),
            batch, self.shardings)

    def _work(self, step: int):
        try:
            while not self._stop.is_set():
                item = (step, self._place(self.batch_fn(step)))
                while not self._stop.is_set():
                    try:
                        self._q.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                step += self.n_producers
        except BaseException as e:  # surfaced on next __next__
            with self._err_lock:
                if self._err is None:
                    self._err = e
            # one dead producer poisons the stream: stop the others so the
            # queue drains to empty and the error actually surfaces
            # (otherwise healthy producers keep the queue non-empty forever)
            self._stop.set()

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        # Poll with a timeout and re-check the producers each lap: a plain
        # blocking get() would hang forever when a producer thread dies
        # (batch_fn raised) with the queue empty — the error is set AFTER
        # the consumer already parked on the queue.  Queued batches drain
        # before the error surfaces, so a mid-stream failure still delivers
        # every batch produced ahead of it.
        while True:
            try:
                return self._q.get(timeout=0.1)
            except queue.Empty:
                if self._err is not None:
                    raise self._err
                if not any(t.is_alive() for t in self._threads):
                    # every producer exited cleanly (close() raced us): no
                    # more items will ever arrive
                    raise StopIteration

    def close(self):
        self._stop.set()
        for t in self._threads:
            t.join(timeout=5)


def token_batch_fn(vocab: int, batch: int, seq: int, seed: int = 0):
    """Deterministic synthetic LM stream: (tokens, targets) keyed by step."""
    def fn(step: int):
        rng = np.random.default_rng(np.uint64(seed) + np.uint64(step))
        toks = rng.integers(0, vocab, (batch, seq + 1), dtype=np.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
    return fn


# Static graph metadata prep for the GNN step functions moved to
# ``repro.core.graph_state.ShardedGraph.build(pg, coords, plan, hierarchy=)``
# — the host-side layout/split passes stay memoized per partition there.


def host_shard(batch, host_id: int, n_hosts: int):
    """Slice a global batch to this host's addressable rows (multi-host IO).

    The leading (batch) dim must divide evenly: silently dropping trailing
    rows would desynchronize the hosts' step counts (and lose data), so an
    uneven batch raises instead.
    """
    def sl(x):
        if x.shape[0] % n_hosts != 0:
            raise ValueError(
                f"batch dim {x.shape[0]} is not divisible by n_hosts="
                f"{n_hosts}: host_shard would silently drop "
                f"{x.shape[0] % n_hosts} trailing rows — pad or resize the "
                "global batch to a multiple of the host count")
        per = x.shape[0] // n_hosts
        return x[host_id * per:(host_id + 1) * per]
    return jax.tree.map(sl, batch)
