"""Serving example: batched prefill + decode with a reduced LM config.

Demonstrates the serving path the decode_32k / long_500k dry-run cells lower:
prefill a batch of prompts, then step the sequence-sharded KV cache decoder,
greedily sampling tokens.

    PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import llama3_2_3b
from repro.models.transformer.model import (
    ParallelCtx, decode_step, init_transformer, prefill_step,
)
from repro.sharding import split_tree


def main():
    cfg = llama3_2_3b.smoke_config()
    ctx = ParallelCtx.single_device()
    params, _ = split_tree(init_transformer(jax.random.PRNGKey(0), cfg), {})

    batch, prompt_len, gen_len = 4, 12, 10
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)), jnp.int32)

    prefill = jax.jit(lambda p, t: prefill_step(p, t, cfg, ctx,
                                                capacity=prompt_len + gen_len))
    decode = jax.jit(lambda p, c, t, n: decode_step(p, c, t, n, cfg, ctx))

    logits, cache = prefill(params, prompts)
    tok = jnp.argmax(logits, axis=-1)[:, None]
    generated = [tok]
    for i in range(gen_len - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits[:, 0], axis=-1)[:, None]
        generated.append(tok)
    out = jnp.concatenate(generated, axis=1)
    print(f"served batch={batch}: prompt {prompt_len} tokens -> generated "
          f"{out.shape[1]} tokens each")
    print("sample token ids:", np.asarray(out[0]))
    assert out.shape == (batch, gen_len)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print("OK")


if __name__ == "__main__":
    main()
