"""Quickstart: build a mesh-based graph, partition it, and verify consistency.

Runs on 1 CPU device in ~a minute:
  1. generate a spectral-element box mesh (GLL points -> graph);
  2. partition into R=4 sub-graphs with halo metadata;
  3. evaluate the paper's consistent GNN un-partitioned and partitioned;
  4. show Eq. 2 holds (outputs identical) and what breaks without halos.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    A2A, NONE, GNNConfig, HaloSpec, NMPPlan, ShardedGraph, box_mesh,
    init_gnn, partition_mesh, gather_node_features, scatter_node_outputs,
    taylor_green_velocity,
)
from repro.core.reference import gnn_forward_stacked


def main():
    # 1) mesh: 4x4x2 spectral elements at polynomial order p=3
    mesh = box_mesh((4, 4, 2), p=3)
    print(f"SEM mesh: {mesh.n_elem} elements, {mesh.n_nodes} unique GLL nodes")

    # 2) partition (NekRS-style 2x2x1 blocks) — coincident nodes become halos
    pg = partition_mesh(mesh, (2, 2, 1))
    print(f"partitioned R={pg.R}: N_pad={pg.n_pad}, E_pad={pg.e_pad}, "
          f"halo rounds={pg.halo.n_rounds}")

    # 3) the paper's GNN on Taylor-Green-vortex velocity
    cfg = GNNConfig.small()
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    vel = taylor_green_velocity(mesh.coords)

    pg1 = partition_mesh(mesh, (1, 1, 1))
    y_ref = gnn_forward_stacked(
        params, jnp.asarray(gather_node_features(pg1, vel)),
        ShardedGraph.build(pg1, mesh.coords),
        NMPPlan(halo=HaloSpec(mode=NONE)))
    y_ref = scatter_node_outputs(pg1, np.asarray(y_ref))

    graph = ShardedGraph.build(pg, mesh.coords)
    x = jnp.asarray(gather_node_features(pg, vel))
    y_con = scatter_node_outputs(pg, np.asarray(gnn_forward_stacked(
        params, x, graph, NMPPlan(halo=HaloSpec(mode=A2A)))))
    y_std = scatter_node_outputs(pg, np.asarray(gnn_forward_stacked(
        params, x, graph, NMPPlan(halo=HaloSpec(mode=NONE)))))

    print(f"max |consistent - unpartitioned| = {np.abs(y_con - y_ref).max():.2e}"
          "   (Eq. 2 holds)")
    print(f"max |standard   - unpartitioned| = {np.abs(y_std - y_ref).max():.2e}"
          "   (halo-less NMP is wrong at partition boundaries)")


if __name__ == "__main__":
    main()
