"""End-to-end driver: distributed training of the consistent mesh GNN.

Trains the paper's 'small' GNN on Taylor-Green-vortex snapshots over a
partitioned SEM mesh with REAL collectives (shard_map over a (data, graph)
device mesh), AdamW, async checkpointing + restart, and straggler monitoring.
Uses 8 host devices (set before jax import).

    PYTHONPATH=src python examples/train_cfd_gnn.py [--steps 300]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse


from repro.core import GNNConfig, box_mesh, partition_mesh
from repro.launch.mesh import make_mesh
from repro.train.loop import TrainConfig, train_consistent_gnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--halo", default="neighbor", choices=["neighbor", "a2a", "none"])
    ap.add_argument("--ckpt", default="/tmp/repro_cfd_ckpt")
    args = ap.parse_args()

    sem_mesh = box_mesh((4, 4, 2), p=3)
    pg = partition_mesh(sem_mesh, (2, 2, 1))           # R=4 spatial partitions
    mesh_dev = make_mesh((2, 4), ("data", "graph"))    # DP=2 x graph=4

    cfg = GNNConfig.small()
    tcfg = TrainConfig(n_steps=args.steps, batch=2, halo_mode=args.halo,
                       ckpt_dir=args.ckpt, ckpt_every=100, lr=2e-3)
    hist = train_consistent_gnn(mesh_dev, pg, sem_mesh, cfg, tcfg)
    losses = hist["losses"]
    print(f"steps={len(losses)}  loss: {losses[0]:.6f} -> {losses[-1]:.6f}  "
          f"(straggler events: {hist['straggler_events']})")
    assert losses[-1] < losses[0], "training should reduce the loss"
    print(f"checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()
