"""GraphCast weather mode: icosahedral multimesh + grid2mesh/mesh2grid.

Builds the proper encoder-processor-decoder weather pipeline on a reduced
icosphere (refinement 3; the full config uses refinement 6 + 0.25 deg grid)
and runs one prediction step over synthetic atmospheric state.

    PYTHONPATH=src python examples/graphcast_weather.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.graph_state import NMPPlan, ShardedGraph
from repro.core.halo import NONE, HaloSpec
from repro.core.partition import partition_graph
from repro.models.gnn_zoo.graphcast import (
    GraphCastConfig, graphcast_forward, grid2mesh_edges, icosahedral_mesh,
    init_graphcast, latlon_grid,
)


def main():
    refinement = 3
    n_vars = 16                                 # reduced from 227
    mesh_xyz, mesh_edges = icosahedral_mesh(refinement)
    grid_xyz = latlon_grid(19, 36)              # reduced from 721x1440
    g2m = grid2mesh_edges(grid_xyz, mesh_xyz, k=3)
    print(f"icosphere r={refinement}: {mesh_xyz.shape[0]} mesh nodes, "
          f"{mesh_edges.shape[0]} multimesh edges; grid {grid_xyz.shape[0]} "
          f"nodes, {g2m.shape[0]} grid2mesh edges")

    # unified graph: [grid nodes | mesh nodes] with 3 edge sets
    n_grid, n_mesh = grid_xyz.shape[0], mesh_xyz.shape[0]
    mesh_off = n_grid
    edges = np.concatenate([
        np.stack([g2m[:, 0], g2m[:, 1] + mesh_off], -1),          # grid->mesh
        np.concatenate([mesh_edges, mesh_edges[:, ::-1]]) + mesh_off,  # multimesh
        np.stack([g2m[:, 1] + mesh_off, g2m[:, 0]], -1),          # mesh->grid
    ])
    n_total = n_grid + n_mesh
    pg = partition_graph(n_total, edges, 1)
    graph = ShardedGraph.from_arrays(
        {k: jnp.asarray(v) for k, v in pg.device_arrays().items()}).rank(0)

    cfg = GraphCastConfig(in_dim=n_vars + 3, hidden=64, n_layers=4,
                          out_dim=n_vars, mlp_hidden_layers=1)
    params = init_graphcast(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(0)
    state = rng.normal(size=(n_grid, n_vars)).astype(np.float32)
    xyz = np.concatenate([grid_xyz, mesh_xyz]).astype(np.float32)
    x = np.zeros((pg.n_pad, n_vars + 3), np.float32)
    x[:n_grid, :n_vars] = state
    x[:n_total, n_vars:] = xyz
    ef = np.zeros((graph["edge_src"].shape[0], cfg.edge_in), np.float32)
    src, dst = np.asarray(graph["edge_src"]), np.asarray(graph["edge_dst"])
    rel = xyz[np.clip(dst, 0, n_total - 1) % n_total] - xyz[np.clip(src, 0, n_total - 1) % n_total]
    ef[:, :3] = rel * np.asarray(graph["edge_mask"])[:, None]
    ef[:, 3] = np.linalg.norm(rel, axis=-1) * np.asarray(graph["edge_mask"])

    out = graphcast_forward(params, jnp.asarray(x), jnp.asarray(ef), graph,
                            NMPPlan(halo=HaloSpec(mode=NONE)), cfg)
    pred = np.asarray(out)[:n_grid]
    print(f"predicted next-state grid field: {pred.shape}, finite: "
          f"{np.isfinite(pred).all()}")
    assert np.isfinite(pred).all()
    print("OK")


if __name__ == "__main__":
    main()
