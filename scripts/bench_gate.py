"""Perf gate for the NMP hot loop and the halo/compute schedule.

Emits ``BENCH_segment_agg.json`` (xla/fused timings, gather mode, tile
sizes, optional graph-size sweep, per-SHA history)
and — when ``--halo-out``/``--halo-baseline`` ask for it —
``BENCH_halo_overlap.json`` (blocking-vs-overlap schedule timings per rank
count); with baseline files provided, fails on regressions beyond
``--max-regression``:

* segment-agg: fused-path wall time vs the baseline's when both runs have
  compiled ``fused_us``.  Interpreter-mode runs (no TPU attached) record
  their timing under ``fused_interpret_us`` instead; absolute interpreted
  timings are not comparable to compiled ones, so those runs are gated
  LOOSELY on the interpret/xla *ratio* vs the baseline's ratio (2x
  headroom on top of ``--max-regression``, because machine load alone
  drifts the ratio ~1.6x) — a structural blow-up in the fused path still
  shows up there.
* halo overlap: the overlap/blocking *ratio* per rank count vs the
  baseline's ratio.  Both schedules compile on any host, and the ratio
  normalizes hardware differences away, so this gate also runs on CPU CI.
  Whenever the halo payload is generated, the baseline-free packed-halo
  gate also runs: packed wire volume <= dense neighbor everywhere and <
  dense A2A per rank at >= 4 ranks, packed-vs-dense copy agreement exactly
  0.0, and the autotuned (schedule x halo-mode x wire) triple equal to the
  argmin of the measured candidate table recorded next to it.
* resilience (``--resilience-out``): baseline-free.  The resilient loop's
  loss trajectory must be BITWISE identical to an uncheckpointed run and
  the checkpoint round trip byte-exact (strict — checkpointing must never
  perturb training); the steady-state overhead at ``ckpt_every`` is
  bounded loosely by ``--resilience-max-overhead`` (the bench model is
  tiny, so the percentage is a worst case — the bound catches structural
  catastrophes like a synchronous full-tree save per step).
* serving (``--serve-out``): baseline-free.  The streamed engine output
  must equal the offline rollout eval BITWISE (strict — the consistency
  guarantee extended to serving) and cached graph reuse must beat the
  cold ``register_mesh`` build by > ``--serve-min-cache-speedup`` (loose
  — catches the cache being bypassed, not runner weather).
* partition quality (``--partition-out``): structural, baseline-free.
  Every method x rank-count cell must report bitwise copy agreement
  (``max_abs_err == 0.0``) and the spectral partitioner must strictly beat
  the block partitioner's halo volume at >= 4 ranks on the stretched mesh
  — these are topological properties, not timings, so the gate is strict
  and runs identically on any host.

Usage:
    PYTHONPATH=src python scripts/bench_gate.py
    PYTHONPATH=src python scripts/bench_gate.py \
        --baseline BENCH_segment_agg.json \
        --halo-baseline BENCH_halo_overlap.json --max-regression 0.3
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (_REPO, os.path.join(_REPO, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)


def gate_segment_agg(payload: dict, base: dict, max_regression: float) -> bool:
    """True iff the fused segment-agg path did not regress.

    Compiled runs gate ``fused_us`` strictly against the baseline's wall
    time.  Interpreter-mode runs (CPU CI, no TPU attached) only carry
    ``fused_interpret_us``; absolute interpreted timings are meaningless,
    but a blow-up in the interpret/xla *ratio* still means the fused code
    path got structurally slower (e.g. an accidental extra pass).  The
    ratio is only loosely host-normalized — the compiled xla path speeds
    up more on an idle core than the interpreter loop does, drifting the
    ratio ~1.6x with machine load alone — so the limit gets 2x headroom
    on top of the fractional allowance: it catches 3x+ structural
    regressions without flaking on runner weather."""
    if "fused_us" in payload and "fused_us" in base:
        limit = base["fused_us"] * (1.0 + max_regression)
        if payload["fused_us"] > limit:
            print(f"REGRESSION: fused {payload['fused_us']:.0f} us > "
                  f"{limit:.0f} us (baseline {base['fused_us']:.0f} us "
                  f"+{max_regression:.0%})")
            return False
        print(f"segment-agg compiled gate ok: fused {payload['fused_us']:.0f} "
              f"us (baseline {base['fused_us']:.0f} us)")
        return True
    # say WHY the strict compiled gate did not fire — for years of CPU-only
    # CI runs this branch was silent-ish and nobody noticed the compiled
    # gate had never run once (ROADMAP carry-over)
    if "fused_us" not in payload:
        print("compiled gate SKIPPED (interpret-only host): this run has "
              "fused_interpret_us only — the strict compiled fused_us gate "
              "needs an accelerator runner")
    else:
        print("compiled gate SKIPPED (no compiled baseline): this run has "
              "fused_us but the baseline does not — commit a baseline from "
              "an accelerator runner to arm the strict gate")
    have = ("fused_interpret_us" in payload and "xla_us" in payload
            and payload["xla_us"] > 0)
    have_base = ("fused_interpret_us" in base and "xla_us" in base
                 and base["xla_us"] > 0)
    if not (have and have_base):
        print("segment-agg ratio gate skipped too: no comparable timings "
              "(need fused_us in both runs, or fused_interpret_us + xla_us)")
        return True
    ratio = payload["fused_interpret_us"] / payload["xla_us"]
    ratio_base = base["fused_interpret_us"] / base["xla_us"]
    limit = ratio_base * 2.0 * (1.0 + max_regression)
    if ratio > limit:
        print(f"REGRESSION: fused interpret/xla ratio {ratio:.1f} > "
              f"{limit:.1f} (baseline {ratio_base:.1f} x2 "
              f"+{max_regression:.0%}, loose interpret-mode gate)")
        return False
    print(f"segment-agg interpret gate ok: interpret/xla ratio {ratio:.1f} "
          f"(limit {limit:.1f}, baseline {ratio_base:.1f})")
    return True


def _geomean_ratio(cases, floor: float = 0.0) -> float:
    ratios = [max(c["overlap_us"] / c["blocking_us"], floor)
              for c in cases if c["blocking_us"] > 0]
    if not ratios:
        return 1.0
    prod = 1.0
    for r in ratios:
        prod *= r
    return prod ** (1.0 / len(ratios))


def gate_halo_overlap(payload: dict, base: dict, max_regression: float) -> bool:
    """True iff the geometric-mean overlap/blocking ratio across rank counts
    did not regress vs the baseline's (hardware-normalized, so it gates on
    CPU CI too).

    Two noise defenses for micro-timings on shared runners: a structural
    regression (e.g. the overlap schedule accidentally serializing or
    doubling work) raises the ratio at *every* rank count, so gating the
    geometric mean averages per-grid noise away; and baseline ratios are
    floored at 1.0 — sub-1.0 committed ratios are measurement luck, and the
    allowance should never be tighter than ``1 + max_regression``."""
    gm_base = _geomean_ratio(base.get("cases", []), floor=1.0)
    gm_now = _geomean_ratio(payload["cases"])
    per_grid = ", ".join(
        f"R={c['ranks']} {c['overlap_us'] / c['blocking_us']:.2f}"
        for c in payload["cases"] if c["blocking_us"] > 0)
    limit = gm_base * (1.0 + max_regression)
    if gm_now > limit:
        print(f"REGRESSION: overlap/blocking geomean ratio {gm_now:.2f} > "
              f"{limit:.2f} (baseline {gm_base:.2f} +{max_regression:.0%}; "
              f"per grid: {per_grid})")
        return False
    print(f"halo-overlap gate ok: geomean ratio {gm_now:.2f} "
          f"(limit {limit:.2f}; per grid: {per_grid})")
    return True


def gate_packed_halo(payload: dict) -> bool:
    """True iff the packed halo exchange holds its structural invariants on
    every multi-rank case.  Baseline-free — all three properties are
    topological/arithmetic, not timings:

    * wire volume: the bucketed packed-neighbor format never ships more
      bytes than the dense neighbor format (it is a prefix truncation of
      it), and at >= 4 ranks it ships strictly fewer bytes per rank than
      dense A2A — the whole point of neighbor-bucketed buffers is that the
      dense ``[R, Bf]`` wire pays the worst pair's width R-1 times over.
    * copy agreement: packed vs dense exchange differ by exactly 0.0 —
      the packed path is pure data movement, so any nonzero difference is
      an indexing bug, not roundoff.
    * tuner faithfulness: the (schedule x halo-mode x wire) triple the
      autotuner resolved must be the argmin of the measured candidate
      table recorded alongside it."""
    ok = True
    for c in payload["cases"]:
        if "wire_bytes" not in c:
            continue
        ranks = c["ranks"]
        wb = c["wire_bytes"]
        packed, dense, a2a = (wb["neighbor-packed"], wb["neighbor"],
                              wb["a2a"])
        for field in ("total", "max"):
            if packed[field] > dense[field]:
                print(f"REGRESSION: packed wire {field} {packed[field]} > "
                      f"dense neighbor {dense[field]} at R={ranks} (packed "
                      f"is a prefix truncation — it can never grow)")
                ok = False
        if ranks >= 4 and packed["max"] >= a2a["max"]:
            print(f"REGRESSION: packed wire bytes/rank {packed['max']} >= "
                  f"dense A2A {a2a['max']} at R={ranks} (bucketed buffers "
                  f"must beat the dense [R, Bf] wire at >= 4 ranks)")
            ok = False
        if c["packed_max_abs_err"] != 0.0:
            print(f"REGRESSION: packed vs dense exchange disagree by "
                  f"{c['packed_max_abs_err']:g} at R={ranks} (want exactly "
                  f"0.0 — packed is pure data movement)")
            ok = False
        if not c.get("auto_matches_best"):
            print(f"REGRESSION: autotuned triple {c.get('auto_triple')} is "
                  f"not the argmin of the measured candidate table at "
                  f"R={ranks}")
            ok = False
    if ok:
        summary = "; ".join(
            f"R={c['ranks']} packed={c['wire_bytes']['neighbor-packed']['max']}"
            f"B/rank a2a={c['wire_bytes']['a2a']['max']}B/rank "
            f"pick={'|'.join(str(t) for t in c['auto_triple'])}"
            for c in payload["cases"] if "wire_bytes" in c)
        print(f"packed-halo gate ok: agreement exact, tuner faithful, "
              f"wire {summary}")
    return ok


def gate_partition(payload: dict) -> bool:
    """True iff the partition-quality sweep holds its structural invariants:
    bitwise copy agreement in every method x rank-count cell (partitioning
    is consistency-neutral under Eq. 2), and spectral halo volume strictly
    below block's at >= 4 ranks (the stretched mesh is the case block
    grids handle worst — if spectral stops winning there, the partitioner
    regressed).  No baseline needed: both properties are topological."""
    ok = True
    for c in payload["cases"]:
        ranks = c["ranks"]
        for method, q in c["methods"].items():
            if q["max_abs_err"] != 0.0:
                print(f"REGRESSION: partition {method} @ R={ranks} has "
                      f"copy disagreement {q['max_abs_err']:g} (want 0.0)")
                ok = False
        hv_b = c["methods"]["block"]["halo_volume"]
        hv_s = c["methods"]["spectral"]["halo_volume"]
        if ranks >= 4 and hv_s >= hv_b:
            print(f"REGRESSION: spectral halo volume {hv_s} >= block "
                  f"{hv_b} at R={ranks} (spectral must win on the "
                  f"stretched mesh at >= 4 ranks)")
            ok = False
    if ok:
        summary = "; ".join(
            f"R={c['ranks']} block={c['methods']['block']['halo_volume']} "
            f"spectral={c['methods']['spectral']['halo_volume']}"
            for c in payload["cases"])
        print(f"partition gate ok: copy agreement exact, halo volume "
              f"{summary}")
    return ok


def gate_resilience(payload: dict, max_overhead: float) -> bool:
    """True iff checkpointing stayed invisible to training and cheap enough.

    Baseline-free.  The strict half is correctness: the resilient loop's
    loss trajectory must be BITWISE identical to the bare loop's, and a
    save -> restore round trip must be byte-exact — checkpointing that
    perturbs training is a correctness bug, not a perf problem.  The loose
    half is cost: ``overhead_pct`` (run_resilient vs bare loop at
    ``ckpt_every``) must stay under ``max_overhead``.  The bench model is
    deliberately tiny (~10 ms steps), so the percentage is a worst case
    and shared-runner noise is real; the bound only exists to catch a
    structural catastrophe such as an accidental synchronous full-tree
    save (or restore) on every step."""
    ok = True
    if not payload.get("losses_bitwise_equal"):
        print("REGRESSION: resilient loss trajectory != bare loop "
              "(checkpointing perturbed training)")
        ok = False
    if not payload.get("restore_exact"):
        print("REGRESSION: checkpoint save -> restore round trip is not "
              "byte-exact")
        ok = False
    if payload["overhead_pct"] > max_overhead:
        print(f"REGRESSION: resilience overhead {payload['overhead_pct']:.1f}% "
              f"> {max_overhead:.0f}% at ckpt_every={payload['ckpt_every']} "
              f"(save {payload['save_ms']:.1f} ms, "
              f"restore {payload['restore_ms']:.1f} ms)")
        ok = False
    if ok:
        print(f"resilience gate ok: trajectory bitwise, restore exact, "
              f"{payload['overhead_pct']:.1f}% overhead at ckpt_every="
              f"{payload['ckpt_every']} (save {payload['save_ms']:.1f} ms, "
              f"restore {payload['restore_ms']:.1f} ms, "
              f"{payload['tree_bytes']}B state)")
    return ok


def gate_serve(payload: dict, min_cache_speedup: float = 5.0) -> bool:
    """True iff the serving engine holds its structural invariants.

    Baseline-free.  Strict half: ``bitwise_vs_offline`` — every bench run
    asserts the streamed engine output equals the batch-1 offline rollout
    eval bitwise, so batching/padding/queueing stay arithmetically
    invisible (the serving extension of the paper's consistency
    guarantee).  Loose half: graph-cache reuse must beat the cold
    ``register_mesh`` build by > ``min_cache_speedup`` — absolute
    latencies are host-dependent, but a resident engine whose cache hit
    costs anywhere near a partition + ShardedGraph + NMPPlan rebuild has
    structurally lost its reason to exist (real speedups are 100x+; 5x
    only catches the cache being bypassed)."""
    ok = True
    if not payload.get("bitwise_vs_offline"):
        print("REGRESSION: streamed engine output != offline rollout eval "
              "(batching/padding/queueing must be arithmetically invisible)")
        ok = False
    gc = payload["graph_cache"]
    if gc["speedup"] <= min_cache_speedup:
        print(f"REGRESSION: graph-cache reuse speedup {gc['speedup']:.1f}x "
              f"<= {min_cache_speedup:.0f}x (cold build "
              f"{gc['cold_build_ms']:.1f} ms, hit {gc['hit_ms']:.3f} ms) — "
              "is register_mesh rebuilding per request?")
        ok = False
    if ok:
        best = max(payload["cases"], key=lambda c: c["req_per_s"])
        print(f"serve gate ok: bitwise vs offline, graph-cache reuse "
              f"{gc['speedup']:.0f}x (cold {gc['cold_build_ms']:.1f} ms -> "
              f"hit {gc['hit_ms']:.3f} ms); best {best['req_per_s']:.1f} "
              f"req/s at {best['batch_slots']} slots "
              f"(p50 {best['latency_ms_p50']:.1f} ms)")
    return ok


def _load(path: str | None) -> dict | None:
    if not path or not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_segment_agg.json")
    ap.add_argument("--halo-out", default=None,
                    help="where to write BENCH_halo_overlap.json; the halo "
                         "sweep only runs when this or --halo-baseline is "
                         "given (keeps the segment-agg-only quick check "
                         "quick)")
    ap.add_argument("--multilevel-out", default=None,
                    help="where to write BENCH_multilevel.json (us/node vs "
                         "V-cycle depth); the sweep only runs when given. "
                         "Its partitioned-vs-1-rank consistency assertions "
                         "are the gate — timings are recorded, not gated "
                         "(absolute us/node is host-dependent)")
    ap.add_argument("--rollout-out", default=None,
                    help="where to write BENCH_rollout.json (us/node/step "
                         "vs autoregressive rollout depth K, both "
                         "schedules); the sweep only runs when given. Its "
                         "1-rank-vs-partitioned consistency assertions are "
                         "the gate — timings are recorded, not gated")
    ap.add_argument("--partition-out", default=None,
                    help="where to write BENCH_partition.json (block vs "
                         "spectral partition quality on a stretched mesh); "
                         "the sweep only runs when given.  Gated strictly "
                         "and baseline-free: every cell must report "
                         "max_abs_err == 0.0 and spectral must beat block's "
                         "halo volume at >= 4 ranks")
    ap.add_argument("--resilience-out", default=None,
                    help="where to write BENCH_resilience.json (checkpoint "
                         "save/restore latency + steady-state run_resilient "
                         "overhead %%); the benchmark only runs when given. "
                         "Gated baseline-free: loss trajectory must be "
                         "bitwise-identical to an uncheckpointed run, the "
                         "save/restore round trip byte-exact, and overhead "
                         "under --resilience-max-overhead")
    ap.add_argument("--serve-out", default=None,
                    help="where to write BENCH_serve.json (inference-engine "
                         "latency/throughput vs batch slots, graph-cache "
                         "reuse); the benchmark only runs when given.  Gated "
                         "baseline-free: streamed output must equal the "
                         "offline rollout eval bitwise, and cached graph "
                         "reuse must beat the cold build by > "
                         "--serve-min-cache-speedup")
    ap.add_argument("--serve-min-cache-speedup", type=float, default=5.0,
                    help="min cold-build / cache-hit ratio for register_mesh "
                         "(loose: real speedups are 100x+; the bound only "
                         "catches the cache being bypassed)")
    ap.add_argument("--resilience-max-overhead", type=float, default=200.0,
                    help="max resilient-vs-bare overhead %% on the "
                         "deliberately tiny bench model (loose: catches "
                         "structural catastrophes, not runner weather)")
    ap.add_argument("--baseline", default=None,
                    help="previous BENCH_segment_agg.json to gate against")
    ap.add_argument("--halo-baseline", default=None,
                    help="previous BENCH_halo_overlap.json to gate against")
    ap.add_argument("--max-regression", type=float, default=0.25,
                    help="allowed fractional slowdown vs baseline")
    ap.add_argument("--sweep-sizes", default=None,
                    help="comma-separated node counts for the fused-vs-xla "
                         "graph-size sweep, recorded under 'sweep' in the "
                         "segment-agg JSON (e.g. '1000' on CPU CI, "
                         "'1000,10000,100000' on TPU)")
    args = ap.parse_args()

    # load baselines BEFORE running: --out/--halo-out default to the baseline
    # paths, so the documented `--baseline BENCH_segment_agg.json` invocation
    # would otherwise gate the fresh run against itself
    base = _load(args.baseline)
    halo_base = _load(args.halo_baseline)

    from benchmarks.run import write_halo_overlap_json, write_segment_agg_json
    sweep = [int(s) for s in args.sweep_sizes.split(",")] \
        if args.sweep_sizes else None
    payload = write_segment_agg_json(args.out, sweep_sizes=sweep)
    print(json.dumps(payload, indent=2, sort_keys=True))

    ok = True
    if base is not None:
        ok &= gate_segment_agg(payload, base, args.max_regression)
    if args.halo_out or args.halo_baseline:
        halo_payload = write_halo_overlap_json(
            args.halo_out or "BENCH_halo_overlap.json")
        print(json.dumps(halo_payload, indent=2, sort_keys=True))
        if halo_base is not None:
            ok &= gate_halo_overlap(halo_payload, halo_base, args.max_regression)
        # structural invariants of the packed wire format need no baseline
        ok &= gate_packed_halo(halo_payload)
    if args.multilevel_out:
        # the sweep asserts multilevel consistency internally (raises on
        # violation); the JSON is an uploaded artifact, not a timing gate
        from benchmarks.run import write_multilevel_json
        ml_payload = write_multilevel_json(args.multilevel_out)
        print(json.dumps(ml_payload, indent=2, sort_keys=True))
    if args.rollout_out:
        # likewise consistency-asserted internally, timings recorded only
        from benchmarks.run import write_rollout_json
        ro_payload = write_rollout_json(args.rollout_out)
        print(json.dumps(ro_payload, indent=2, sort_keys=True))
    if args.partition_out:
        from benchmarks.run import write_partition_json
        part_payload = write_partition_json(args.partition_out)
        print(json.dumps(part_payload, indent=2, sort_keys=True))
        ok &= gate_partition(part_payload)
    if args.resilience_out:
        from benchmarks.run import write_resilience_json
        res_payload = write_resilience_json(args.resilience_out)
        print(json.dumps(res_payload, indent=2, sort_keys=True))
        ok &= gate_resilience(res_payload, args.resilience_max_overhead)
    if args.serve_out:
        from benchmarks.run import write_serve_json
        serve_payload = write_serve_json(args.serve_out)
        print(json.dumps(serve_payload, indent=2, sort_keys=True))
        ok &= gate_serve(serve_payload, args.serve_min_cache_speedup)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
