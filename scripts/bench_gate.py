"""Perf gate for the NMP segment-agg hot loop.

Emits ``BENCH_segment_agg.json`` (xla/fused timings + layout padding-waste)
and, when a baseline file is provided, fails if the fused path regressed by
more than ``--max-regression``.  Interpreter-mode runs (no TPU attached) are
recorded but never gated — their timings are not comparable to compiled ones.

Usage:
    PYTHONPATH=src python scripts/bench_gate.py
    PYTHONPATH=src python scripts/bench_gate.py --baseline BENCH_segment_agg.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (_REPO, os.path.join(_REPO, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_segment_agg.json")
    ap.add_argument("--baseline", default=None,
                    help="previous BENCH_segment_agg.json to gate against")
    ap.add_argument("--max-regression", type=float, default=0.25,
                    help="allowed fractional fused-path slowdown vs baseline")
    args = ap.parse_args()

    from benchmarks.run import write_segment_agg_json
    payload = write_segment_agg_json(args.out)
    print(json.dumps(payload, indent=2, sort_keys=True))

    if not args.baseline or not os.path.exists(args.baseline):
        return 0
    with open(args.baseline) as f:
        base = json.load(f)
    if payload["fused_interpret"] or base.get("fused_interpret", True):
        print("gate skipped: interpreter-mode timings are not comparable")
        return 0
    limit = base["fused_us"] * (1.0 + args.max_regression)
    if payload["fused_us"] > limit:
        print(f"REGRESSION: fused {payload['fused_us']:.0f} us > "
              f"{limit:.0f} us (baseline {base['fused_us']:.0f} us "
              f"+{args.max_regression:.0%})")
        return 1
    print(f"gate ok: fused {payload['fused_us']:.0f} us "
          f"(baseline {base['fused_us']:.0f} us)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
