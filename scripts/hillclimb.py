"""§Perf hillclimb driver: run a cell with named override sets, re-lower,
re-analyze, and print the roofline terms per iteration.

    PYTHONPATH=src python scripts/hillclimb.py graphcast ogb_products \
        '{}' '{"remat":true}' '{"remat":true,"act_bf16":true}' ...
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json
import sys
from pathlib import Path

from repro.launch.dryrun import run_cell
from repro.roofline.hlo_analysis import analyze

OUT = Path("runs/hillclimb")


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    OUT.mkdir(parents=True, exist_ok=True)
    results = []
    for i, ov_json in enumerate(sys.argv[3:]):
        ov = json.loads(ov_json)
        tag = f"hc{i}_" + "_".join(sorted(ov)) if ov else "hc0_baseline"
        try:
            rec = run_cell(arch, shape, False, save_hlo=True, overrides=ov, tag=tag)
            s = analyze(Path(rec["hlo_path"]).read_text(), total_devices=256)
            t = s.terms()
            row = dict(tag=tag, overrides=ov,
                       peak_gib=rec["per_device_bytes"]["peak_estimate"] / 2 ** 30,
                       compute_ms=t["compute_s"] * 1e3, memory_ms=t["memory_s"] * 1e3,
                       collective_ms=t["collective_s"] * 1e3,
                       dot_flops=s.dot_flops, wire_bytes=s.collective_wire_bytes,
                       by_collective=s.by_collective,
                       compile_s=rec["compile_s"])
            print(f"[{tag}] peak {row['peak_gib']:8.1f} GiB | compute "
                  f"{row['compute_ms']:9.1f} ms | memory {row['memory_ms']:9.1f} ms | "
                  f"collective {row['collective_ms']:8.1f} ms", flush=True)
        except Exception as e:
            row = dict(tag=tag, overrides=ov, error=f"{type(e).__name__}: {e}")
            print(f"[{tag}] FAIL {type(e).__name__}: {str(e)[:300]}", flush=True)
        results.append(row)
    out_path = OUT / f"{arch}_{shape}.json"
    existing = json.loads(out_path.read_text()) if out_path.exists() else []
    out_path.write_text(json.dumps(existing + results, indent=1))
    print(f"-> {out_path}")


if __name__ == "__main__":
    main()
