"""Consistency tests (Eqs. 2, 3, 6) — the paper's core claims.

Fast single-device checks use the stacked reference evaluator on the
ShardedGraph/NMPPlan API; the real shard_map/collective path is exercised
by the subprocess driver test at the bottom (needs 8 host devices, hence
its own process).
"""
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (
    A2A, NONE, GNNConfig, HaloSpec, NMPPlan, ShardedGraph, box_mesh,
    init_gnn, partition_mesh, partition_graph, gather_node_features,
    taylor_green_velocity,
)
from repro.core.halo import halo_sync_reference
from repro.core.reference import loss_and_grad_stacked
from repro.core.partition import scatter_node_outputs


@pytest.fixture(scope="module")
def small_case():
    mesh = box_mesh((4, 4, 2), p=3)
    cfg = GNNConfig.small()
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    x_global = taylor_green_velocity(mesh.coords)
    return mesh, cfg, params, x_global


def _eval(pg, mesh, params, cfg, x_global, mode):
    plan = NMPPlan(halo=HaloSpec(mode=mode))
    graph = ShardedGraph.build(pg, mesh.coords, plan)
    x = jnp.asarray(gather_node_features(pg, x_global))
    loss, y, grads = loss_and_grad_stacked(params, x, x, graph, plan,
                                           cfg.node_out)
    return float(loss), np.asarray(y), grads


@pytest.mark.parametrize("method", ["block", "spectral"])
def test_eq2_forward_partition_invariance(small_case, method):
    """Eq. 2 holds for both partitioners — how the mesh is decomposed
    (block element grids vs spectral bisection vertex cuts) is a pure
    performance knob."""
    mesh, cfg, params, x_global = small_case
    pg1 = partition_mesh(mesh, (1, 1, 1))
    l1, y1, _ = _eval(pg1, mesh, params, cfg, x_global, NONE)
    y1g = scatter_node_outputs(pg1, y1)
    for grid in ((2, 1, 1), (2, 2, 1), (2, 2, 2)):
        pg = partition_mesh(mesh, grid, method=method)
        l, y, _ = _eval(pg, mesh, params, cfg, x_global, A2A)
        yg = scatter_node_outputs(pg, y)
        np.testing.assert_allclose(yg, y1g, rtol=3e-5, atol=2e-6)
        assert abs(l - l1) < 1e-6


@pytest.mark.parametrize("method", ["block", "spectral"])
def test_eq3_gradient_partition_invariance(small_case, method):
    mesh, cfg, params, x_global = small_case
    pg1 = partition_mesh(mesh, (1, 1, 1))
    _, _, g1 = _eval(pg1, mesh, params, cfg, x_global, NONE)
    pg = partition_mesh(mesh, (2, 2, 1), method=method)
    _, _, g4 = _eval(pg, mesh, params, cfg, x_global, A2A)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g4)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=1e-3, atol=2e-6)


def test_inconsistent_mode_deviates(small_case):
    mesh, cfg, params, x_global = small_case
    pg1 = partition_mesh(mesh, (1, 1, 1))
    l1, _, _ = _eval(pg1, mesh, params, cfg, x_global, NONE)
    devs = []
    for grid in ((2, 1, 1), (2, 2, 1), (2, 2, 2)):
        pg = partition_mesh(mesh, grid)
        l, _, _ = _eval(pg, mesh, params, cfg, x_global, NONE)
        devs.append(abs(l - l1))
    assert all(d > 1e-6 for d in devs)
    # deviation grows with R (paper Fig. 6 left trend)
    assert devs[2] > devs[0]


@pytest.mark.parametrize("seed", range(3))
def test_property_random_params_and_fields(small_case, seed):
    """Property-style: consistency holds for random params and random fields."""
    mesh, cfg, _, _ = small_case
    key = jax.random.PRNGKey(100 + seed)
    kp, kx = jax.random.split(key)
    params = init_gnn(kp, cfg)
    x_global = np.asarray(jax.random.normal(kx, (mesh.n_nodes, 3)), dtype=np.float32)
    pg1 = partition_mesh(mesh, (1, 1, 1))
    l1, _, _ = _eval(pg1, mesh, params, cfg, x_global, NONE)
    pg = partition_mesh(mesh, (4, 2, 1))
    l, _, _ = _eval(pg, mesh, params, cfg, x_global, A2A)
    assert abs(l - l1) < 2e-6 * max(1.0, abs(l1))


def test_generic_edge_partition_consistency():
    """The beyond-paper generic partitioner also satisfies Eq. 2."""
    rng = np.random.default_rng(7)
    n = 60
    edges = rng.integers(0, n, size=(300, 2))
    edges = edges[edges[:, 0] != edges[:, 1]]
    cfg = GNNConfig(hidden=8, n_mp_layers=2, mlp_hidden_layers=2, node_in=3, edge_in=7)
    params = init_gnn(jax.random.PRNGKey(3), cfg)
    x_global = rng.normal(size=(n, 3)).astype(np.float32)
    coords = rng.normal(size=(n, 3)).astype(np.float32)

    def ev(R):
        pg = partition_graph(n, edges, R)
        plan = NMPPlan(halo=HaloSpec(mode=A2A if R > 1 else NONE))
        graph = ShardedGraph.build(pg, coords, plan)
        x = jnp.asarray(gather_node_features(pg, x_global))
        loss, y, _ = loss_and_grad_stacked(params, x, x, graph, plan,
                                           cfg.node_out)
        return float(loss), scatter_node_outputs(pg, np.asarray(y))

    l1, y1 = ev(1)
    for R in (2, 5):
        lr, yr = ev(R)
        assert abs(lr - l1) < 2e-6
        np.testing.assert_allclose(yr, y1, rtol=3e-5, atol=2e-6)


def test_halo_sync_max_combine():
    """Max-combine sync: all coincident copies end with the global max."""
    mesh = box_mesh((2, 2), p=2)
    pg = partition_mesh(mesh, (2, 2))
    graph = ShardedGraph.build(pg, mesh.coords)
    rng = np.random.default_rng(0)
    a = rng.normal(size=(pg.R, pg.n_pad, 4)).astype(np.float32)
    a = a * pg.node_mask[..., None]
    out = halo_sync_reference(jnp.asarray(a), graph, HaloSpec(mode=A2A), combine="max")
    out = np.asarray(out)
    # brute force: per global id, max over all copies
    best = {}
    for r in range(pg.R):
        for i in range(pg.n_pad):
            if pg.node_mask[r, i] > 0:
                g = int(pg.global_ids[r, i])
                best[g] = np.maximum(best.get(g, -np.inf), a[r, i])
    for r in range(pg.R):
        for i in range(pg.n_pad):
            if pg.node_mask[r, i] > 0:
                g = int(pg.global_ids[r, i])
                np.testing.assert_allclose(out[r, i], best[g], rtol=1e-6)


@pytest.mark.parametrize("grid,mode", [((1, 1, 1), NONE), ((2, 2, 1), A2A)])
def test_fused_backend_matches_xla_values_and_grads(grid, mode):
    """The Pallas fused NMP backend preserves the consistency guarantee
    through the kernel swap: forward outputs AND jax.grad values match the
    xla backend to fp32 tolerance on a 1-rank graph and a 4-partition halo
    graph (interpret mode exercises the production kernel path on CPU)."""
    mesh = box_mesh((2, 2, 2), p=2)
    cfg = GNNConfig(hidden=8, n_mp_layers=2, mlp_hidden_layers=2)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    x_global = taylor_green_velocity(mesh.coords)

    pg = partition_mesh(mesh, grid)
    plan_f = NMPPlan(halo=HaloSpec(mode=mode), backend="fused",
                     interpret=True, block_n=16, block_e=32)
    plan_x = plan_f.replace(backend="xla")
    # one fused-capable graph serves both backends
    graph = ShardedGraph.build(pg, mesh.coords, plan_f)
    x = jnp.asarray(gather_node_features(pg, x_global))

    l_x, y_x, g_x = loss_and_grad_stacked(params, x, x, graph, plan_x,
                                          cfg.node_out)
    l_f, y_f, g_f = loss_and_grad_stacked(params, x, x, graph, plan_f,
                                          cfg.node_out)

    assert abs(float(l_f) - float(l_x)) < 1e-6 * max(1.0, abs(float(l_x)))
    np.testing.assert_allclose(np.asarray(y_f), np.asarray(y_x),
                               rtol=1e-4, atol=1e-5)
    for a, b in zip(jax.tree.leaves(g_x), jax.tree.leaves(g_f)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-3, atol=2e-5)


def test_fused_backend_partition_invariance():
    """Eq. 2 holds *within* the fused backend as well: partitioned fused run
    reproduces the 1-rank fused run node-for-node."""
    mesh = box_mesh((2, 2, 2), p=2)
    cfg = GNNConfig(hidden=8, n_mp_layers=2, mlp_hidden_layers=2)
    params = init_gnn(jax.random.PRNGKey(1), cfg)
    x_global = taylor_green_velocity(mesh.coords)

    def ev(grid, mode):
        pg = partition_mesh(mesh, grid)
        plan = NMPPlan(halo=HaloSpec(mode=mode), backend="fused",
                       interpret=True, block_n=16, block_e=32)
        graph = ShardedGraph.build(pg, mesh.coords, plan)
        x = jnp.asarray(gather_node_features(pg, x_global))
        loss, y, _ = loss_and_grad_stacked(params, x, x, graph, plan,
                                           cfg.node_out)
        return float(loss), scatter_node_outputs(pg, np.asarray(y))

    l1, y1 = ev((1, 1, 1), NONE)
    l4, y4 = ev((2, 2, 1), A2A)
    assert abs(l4 - l1) < 2e-6 * max(1.0, abs(l1))
    np.testing.assert_allclose(y4, y1, rtol=3e-5, atol=2e-6)


@pytest.mark.parametrize("grid,mode", [
    ((1, 1, 1), NONE),      # 1 rank: overlap degenerates to interior-only
    ((4, 1, 1), A2A),       # 4-partition 1D slab decomposition
    ((2, 2, 1), A2A),       # 4-partition 2D pencils
])
def test_overlap_schedule_matches_blocking(grid, mode):
    """schedule="overlap" (interior/boundary split, exchange on the boundary
    partial aggregate only) is arithmetically identical to the blocking
    schedule: loss, node outputs AND parameter gradients agree to fp32
    tolerance on 1-rank and multi-partition halo graphs."""
    mesh = box_mesh((4, 2, 2), p=2)
    cfg = GNNConfig(hidden=8, n_mp_layers=2, mlp_hidden_layers=2)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    x_global = taylor_green_velocity(mesh.coords)

    pg = partition_mesh(mesh, grid)
    plan_o = NMPPlan(halo=HaloSpec(mode=mode), schedule="overlap")
    plan_b = plan_o.replace(schedule="blocking")
    graph = ShardedGraph.build(pg, mesh.coords, plan_o)
    x = jnp.asarray(gather_node_features(pg, x_global))

    l_b, y_b, g_b = loss_and_grad_stacked(params, x, x, graph, plan_b,
                                          cfg.node_out)
    l_o, y_o, g_o = loss_and_grad_stacked(params, x, x, graph, plan_o,
                                          cfg.node_out)

    assert abs(float(l_o) - float(l_b)) < 1e-6 * max(1.0, abs(float(l_b)))
    np.testing.assert_allclose(np.asarray(y_o), np.asarray(y_b),
                               rtol=1e-4, atol=1e-5)
    for a, b in zip(jax.tree.leaves(g_b), jax.tree.leaves(g_o)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=2e-3, atol=2e-4)
    # overlap on the partitioned graph reproduces Eq. 2 as well: same loss
    # as the un-partitioned reference
    if grid != (1, 1, 1):
        pg1 = partition_mesh(mesh, (1, 1, 1))
        plan1 = NMPPlan(halo=HaloSpec(mode=NONE), schedule="overlap")
        graph1 = ShardedGraph.build(pg1, mesh.coords, plan1)
        x1 = jnp.asarray(gather_node_features(pg1, x_global))
        l1, _, _ = loss_and_grad_stacked(params, x1, x1, graph1, plan1,
                                         cfg.node_out)
        assert abs(float(l_o) - float(l1)) < 2e-6 * max(1.0, abs(float(l1)))


def test_overlap_schedule_matches_blocking_fused_backend():
    """The overlap schedule composes with the fused Pallas backend: each side
    of the interior/boundary split runs through its own compact layout
    (seg_perm_bnd / seg_perm_int) and still matches the blocking fused run
    for values and gradients (interpret mode on CPU)."""
    mesh = box_mesh((2, 2, 2), p=2)
    cfg = GNNConfig(hidden=8, n_mp_layers=2, mlp_hidden_layers=2)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    x_global = taylor_green_velocity(mesh.coords)

    pg = partition_mesh(mesh, (2, 2, 1))
    plan_o = NMPPlan(halo=HaloSpec(mode=A2A), backend="fused",
                     interpret=True, block_n=16, block_e=32,
                     schedule="overlap")
    plan_b = plan_o.replace(schedule="blocking")
    graph = ShardedGraph.build(pg, mesh.coords, plan_o)
    x = jnp.asarray(gather_node_features(pg, x_global))

    l_b, y_b, g_b = loss_and_grad_stacked(params, x, x, graph, plan_b,
                                          cfg.node_out)
    l_o, y_o, g_o = loss_and_grad_stacked(params, x, x, graph, plan_o,
                                          cfg.node_out)

    assert abs(float(l_o) - float(l_b)) < 1e-6 * max(1.0, abs(float(l_b)))
    np.testing.assert_allclose(np.asarray(y_o), np.asarray(y_b),
                               rtol=1e-4, atol=1e-5)
    for a, b in zip(jax.tree.leaves(g_b), jax.tree.leaves(g_o)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=2e-3, atol=2e-4)


def test_overlap_schedule_requires_split_arrays():
    """Clear error when the split arrays are missing from the graph (built
    with a blocking plan, evaluated with an overlap one)."""
    mesh = box_mesh((2, 2, 2), p=2)
    cfg = GNNConfig(hidden=8, n_mp_layers=2, mlp_hidden_layers=2)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    pg = partition_mesh(mesh, (2, 1, 1))
    graph = ShardedGraph.build(pg, mesh.coords)        # blocking-only arrays
    x = jnp.asarray(gather_node_features(pg, taylor_green_velocity(mesh.coords)))
    plan = NMPPlan(halo=HaloSpec(mode=A2A), schedule="overlap")
    with pytest.raises(ValueError, match="split"):
        loss_and_grad_stacked(params, x, x, graph, plan, cfg.node_out)


def test_shard_map_collective_path_subprocess():
    """Full multi-device test on real collectives (8 host CPU devices)."""
    driver = os.path.join(os.path.dirname(__file__), "drivers", "consistency_driver.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, driver], env=env, capture_output=True, text=True,
                         timeout=600)
    assert res.returncode == 0, f"driver failed:\n{res.stdout[-3000:]}\n{res.stderr[-3000:]}"
    assert "CONSISTENCY DRIVER PASS" in res.stdout
