"""Data pipeline: deterministic replay + prefetch ordering + host sharding."""
import numpy as np

from repro.data.pipeline import PrefetchingLoader, host_shard, token_batch_fn


def test_token_batches_deterministic_replay():
    fn = token_batch_fn(vocab=100, batch=4, seq=8, seed=3)
    a = fn(7)
    b = fn(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = fn(8)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # targets are next-token shifted
    full_a = fn(7)
    assert full_a["tokens"].shape == (4, 8)


def test_prefetching_loader_order_and_restart():
    fn = token_batch_fn(vocab=50, batch=2, seq=4, seed=0)
    loader = PrefetchingLoader(fn, prefetch=3, start_step=5)
    try:
        steps, batches = [], []
        for _ in range(4):
            s, b = next(loader)
            steps.append(s)
            batches.append(np.asarray(b["tokens"]))
        assert steps == [5, 6, 7, 8]
    finally:
        loader.close()
    # a "restarted" loader from step 6 replays the same stream
    loader2 = PrefetchingLoader(fn, prefetch=2, start_step=6)
    try:
        s, b = next(loader2)
        assert s == 6
        np.testing.assert_array_equal(np.asarray(b["tokens"]), batches[1])
    finally:
        loader2.close()


def test_host_shard():
    batch = {"x": np.arange(12).reshape(6, 2)}
    sh = host_shard(batch, host_id=1, n_hosts=3)
    np.testing.assert_array_equal(np.asarray(sh["x"]), batch["x"][2:4])
