"""Data pipeline: deterministic replay + prefetch ordering + host sharding."""
import time

import numpy as np
import pytest

from repro.data.pipeline import PrefetchingLoader, host_shard, token_batch_fn


def test_token_batches_deterministic_replay():
    fn = token_batch_fn(vocab=100, batch=4, seq=8, seed=3)
    a = fn(7)
    b = fn(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = fn(8)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # targets are next-token shifted
    full_a = fn(7)
    assert full_a["tokens"].shape == (4, 8)


def test_prefetching_loader_order_and_restart():
    fn = token_batch_fn(vocab=50, batch=2, seq=4, seed=0)
    loader = PrefetchingLoader(fn, prefetch=3, start_step=5)
    try:
        steps, batches = [], []
        for _ in range(4):
            s, b = next(loader)
            steps.append(s)
            batches.append(np.asarray(b["tokens"]))
        assert steps == [5, 6, 7, 8]
    finally:
        loader.close()
    # a "restarted" loader from step 6 replays the same stream
    loader2 = PrefetchingLoader(fn, prefetch=2, start_step=6)
    try:
        s, b = next(loader2)
        assert s == 6
        np.testing.assert_array_equal(np.asarray(b["tokens"]), batches[1])
    finally:
        loader2.close()


def test_prefetching_loader_surfaces_producer_error_without_hanging():
    """Regression: a batch_fn that raises used to kill the producer thread
    while the consumer blocked forever on the empty queue — the error was
    set AFTER the consumer parked on q.get().  __next__ must now surface
    the exception promptly."""
    def bad_fn(step: int):
        raise RuntimeError(f"boom at step {step}")

    loader = PrefetchingLoader(bad_fn, prefetch=2)
    try:
        t0 = time.monotonic()
        with pytest.raises(RuntimeError, match="boom at step"):
            next(loader)
        assert time.monotonic() - t0 < 10.0, "error took too long to surface"
    finally:
        loader.close()


def test_prefetching_loader_error_after_good_batches():
    """The failure mode mid-stream: N good batches, then the producer dies —
    the queued batches drain normally, then the error surfaces (no hang)."""
    def flaky_fn(step: int):
        if step >= 2:
            raise ValueError("stream ended")
        return {"x": np.full((2,), step)}

    loader = PrefetchingLoader(flaky_fn, prefetch=1)
    try:
        got = []
        with pytest.raises(ValueError, match="stream ended"):
            for _ in range(5):
                s, b = next(loader)
                got.append(s)
        assert got == [0, 1]
    finally:
        loader.close()


def test_prefetching_loader_multi_producer_covers_stream_exactly_once():
    """N producers stride the step sequence (producer t gets start_step + t,
    + n_producers, ...): the union is every step exactly once, interleaved
    in any order — consumers key on the step id each item carries."""
    def fn(step: int):
        return {"x": np.full((2,), step)}

    loader = PrefetchingLoader(fn, prefetch=4, start_step=3, n_producers=3)
    try:
        seen = [next(loader)[0] for _ in range(12)]
        # no step is ever produced twice
        assert len(set(seen)) == 12, f"duplicated steps: {seen}"
        # per producer (= residue class of the stride), steps arrive in
        # order with no gaps from that producer's first step — together
        # with uniqueness this is exactly-once coverage of the stream
        for t in range(3):
            cls = [s for s in seen if (s - 3) % 3 == t]
            assert cls == list(range(3 + t, 3 + t + 3 * len(cls), 3)), \
                f"producer {t} skipped or reordered steps: {cls}"
    finally:
        loader.close()


def test_prefetching_loader_multi_producer_backpressure():
    """With the queue full, every producer parks in put(): total batch_fn
    calls stay bounded by queue depth + one in-flight item per producer —
    producers must not run ahead of the consumer."""
    calls = []

    def fn(step: int):
        calls.append(step)          # list.append is atomic under the GIL
        return {"x": np.zeros(1)}

    loader = PrefetchingLoader(fn, prefetch=2, n_producers=2)
    try:
        time.sleep(0.5)
        assert len(calls) <= 2 + 2, \
            f"producers ran ahead of backpressure: {len(calls)} calls"
        # drain: the stream continues correctly after the stall (unique
        # steps, each producer's residue class in order with no gaps)
        seen = [next(loader)[0] for _ in range(6)]
        assert len(set(seen)) == 6, f"duplicated steps: {seen}"
        for t in range(2):
            cls = [s for s in seen if s % 2 == t]
            assert cls == list(range(t, t + 2 * len(cls), 2)), \
                f"producer {t} skipped or reordered steps: {cls}"
    finally:
        loader.close()


def test_prefetching_loader_multi_producer_drain_then_raise():
    """One producer dying stops ALL producers (first error wins, kept under
    a lock), already-queued batches drain, then the error surfaces — the
    healthy producers must not keep the stream alive forever."""
    def fn(step: int):
        if step == 3:
            raise RuntimeError("producer for step 3 died")
        return {"x": np.full((2,), step)}

    loader = PrefetchingLoader(fn, prefetch=2, n_producers=2)
    try:
        got = []
        t0 = time.monotonic()
        with pytest.raises(RuntimeError, match="step 3 died"):
            for _ in range(20):
                s, _ = next(loader)
                got.append(s)
        assert time.monotonic() - t0 < 10.0, "error took too long to surface"
        assert 3 not in got
        assert len(got) == len(set(got)), f"duplicated steps: {got}"
    finally:
        loader.close()


def test_prefetching_loader_close_joins_all_producers():
    def fn(step: int):
        return {"x": np.zeros(1)}

    loader = PrefetchingLoader(fn, prefetch=1, n_producers=3)
    next(loader)
    loader.close()
    assert not any(t.is_alive() for t in loader._threads)


def test_host_shard():
    batch = {"x": np.arange(12).reshape(6, 2)}
    sh = host_shard(batch, host_id=1, n_hosts=3)
    np.testing.assert_array_equal(np.asarray(sh["x"]), batch["x"][2:4])
    # every host covers the batch exactly once
    parts = [host_shard(batch, h, 3)["x"] for h in range(3)]
    np.testing.assert_array_equal(np.concatenate(parts), batch["x"])


def test_host_shard_rejects_uneven_batch():
    """Regression: an uneven batch used to silently drop trailing rows
    (6 % 4 == 2 rows lost); it must raise instead."""
    batch = {"x": np.arange(12).reshape(6, 2)}
    with pytest.raises(ValueError, match="not divisible"):
        host_shard(batch, host_id=0, n_hosts=4)
