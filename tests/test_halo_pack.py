"""Packed halo wire format: fused Pallas pack/unpack ops vs XLA references,
bucketed ``pk{k}_*`` array construction, wire-byte accounting, and BITWISE
packed-vs-dense equality of values and gradients through ``halo_sync_stacked``
and the full stacked GNN forward (both schedules, 1-rank and multi-rank).

"Bitwise" is asserted as ``max |packed - dense| == 0.0`` — exact equality up
to the sign of zero (dense rounds may add one more exact +0.0 padding slot
than the truncated packed buffer)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (GNNConfig, HaloSpec, NEIGHBOR, NMPPlan, ShardedGraph,
                        box_mesh, init_gnn, partition_mesh)
from repro.core.halo import halo_sync_stacked
from repro.core.mesh_gen import taylor_green_velocity
from repro.core.partition import (build_2d_halo_rounds, flat_rounds2d_perms,
                                  from_element_partition, gather_node_features,
                                  pack, packed_halo_arrays, partition_elements)
from repro.core.reference import gnn_forward_stacked
from repro.kernels.halo_pack import (halo_pack, halo_pack_ref,
                                     halo_unpack_add, halo_unpack_add_ref)


def _bitwise(a, b):
    assert a.dtype == b.dtype and a.shape == b.shape
    assert float(jnp.abs(a - b).max()) == 0.0


# ---------------------------------------------------------------------------
# op level: Pallas (interpret) vs XLA reference, values + grads
# ---------------------------------------------------------------------------

def test_halo_pack_op_bitwise_values_and_grads():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(19, 5)).astype(np.float32))
    idx = jnp.asarray(rng.permutation(19)[:13].astype(np.int32))
    mask = jnp.asarray((rng.random(13) < 0.7).astype(np.float32))
    out = halo_pack(x, idx, mask, interpret=True)
    _bitwise(out, halo_pack_ref(x, idx, mask))

    w = jnp.asarray(rng.normal(size=out.shape).astype(np.float32))
    g = jax.grad(lambda v: (halo_pack(v, idx, mask, interpret=True) * w).sum())(x)
    g_ref = jax.grad(lambda v: (halo_pack_ref(v, idx, mask) * w).sum())(x)
    _bitwise(g, g_ref)


def test_halo_unpack_add_op_bitwise_values_and_grads():
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(19, 5)).astype(np.float32))
    buf = jnp.asarray(rng.normal(size=(13, 5)).astype(np.float32))
    idx = jnp.asarray(rng.permutation(19)[:13].astype(np.int32))
    mask = jnp.asarray((rng.random(13) < 0.7).astype(np.float32))
    out = halo_unpack_add(a, buf, idx, mask, interpret=True)
    _bitwise(out, halo_unpack_add_ref(a, buf, idx, mask))

    w = jnp.asarray(rng.normal(size=a.shape).astype(np.float32))

    def loss(fn):
        return lambda aa, bb: (fn(aa, bb, idx, mask) * w).sum()
    ga, gb = jax.grad(loss(lambda aa, bb, i, m: halo_unpack_add(
        aa, bb, i, m, interpret=True)), argnums=(0, 1))(a, buf)
    ga_r, gb_r = jax.grad(loss(halo_unpack_add_ref), argnums=(0, 1))(a, buf)
    _bitwise(ga, ga_r)
    _bitwise(gb, gb_r)


def test_halo_unpack_add_duplicate_indices_close():
    """Duplicate destinations (not produced by the halo plans, which keep
    per-round recv ids unique, but the op must still be correct): the
    sequential in-kernel adds may re-associate vs the XLA scatter, so this
    one compares with a float tolerance instead of bitwise."""
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.normal(size=(6, 3)).astype(np.float32))
    buf = jnp.asarray(rng.normal(size=(9, 3)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 6, size=9).astype(np.int32))
    mask = jnp.ones((9,), jnp.float32)
    out = halo_unpack_add(a, buf, idx, mask, interpret=True)
    ref = halo_unpack_add_ref(a, buf, idx, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_halo_pack_wire_compression_composes():
    """halo.py compresses AFTER the fused pack — the kernel's output must
    cast to the wire dtype exactly like the dense masked gather does."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(10, 4)).astype(np.float32))
    idx = jnp.asarray(rng.permutation(10)[:6].astype(np.int32))
    mask = jnp.asarray((rng.random(6) < 0.8).astype(np.float32))
    fused = halo_pack(x, idx, mask, interpret=True).astype(jnp.bfloat16)
    dense = halo_pack_ref(x, idx, mask).astype(jnp.bfloat16)
    _bitwise(fused, dense)


# ---------------------------------------------------------------------------
# format level: bucketed arrays + wire-byte accounting
# ---------------------------------------------------------------------------

def _neighbor_case(grid=(2, 2, 1)):
    mesh = box_mesh((4, 2, 2), p=2)
    pg = partition_mesh(mesh, grid)
    plan = NMPPlan.build(pg, NEIGHBOR, packed=True, interpret=True)
    graph = ShardedGraph.build(pg, mesh.coords, plan)
    return mesh, pg, plan, graph


def test_packed_halo_arrays_are_prefix_truncations():
    _, pg, _, graph = _neighbor_case()
    h = pg.halo
    K, B = h.nbr_send_idx.shape[1], h.nbr_send_idx.shape[2]
    pk = pg.packed_halo()
    assert len(pk) == 4 * K
    for k in range(K):
        w = pk[f"pk{k}_send_idx"].shape[-1]
        assert w <= B and w % 8 == 0
        # pure truncation of the dense arrays (what makes packed bitwise)
        np.testing.assert_array_equal(pk[f"pk{k}_send_idx"],
                                      h.nbr_send_idx[:, k, :w])
        np.testing.assert_array_equal(pk[f"pk{k}_recv_mask"],
                                      h.nbr_recv_mask[:, k, :w])
        # nothing real beyond the truncation
        assert float(h.nbr_send_mask[:, k, w:].sum()) == 0.0
        # and the stacked graph carries them
        assert graph[f"pk{k}_send_idx"].shape == (pg.R, w)


def test_packed_halo_arrays_rejects_non_prefix_packed():
    _, pg, _, _ = _neighbor_case()
    h = pg.halo
    bad = dict(nbr_send_idx=h.nbr_send_idx.copy(),
               nbr_send_mask=np.zeros_like(h.nbr_send_mask),
               nbr_recv_idx=h.nbr_recv_idx.copy(),
               nbr_recv_mask=np.zeros_like(h.nbr_recv_mask))
    bad["nbr_send_mask"][0, 0, -1] = 1.0        # lone real entry at the tail
    with pytest.raises(ValueError, match="prefix-packed"):
        packed_halo_arrays(bad, bucket=8)


def test_wire_bytes_packed_not_worse_than_dense():
    _, pg, _, _ = _neighbor_case()
    a2a = pg.wire_bytes("a2a", feat_dim=8)
    dense = pg.wire_bytes("neighbor", feat_dim=8)
    packed = pg.wire_bytes("neighbor", packed=True, feat_dim=8)
    assert packed["max"] <= dense["max"] and packed["total"] <= dense["total"]
    assert packed["total"] <= a2a["total"]
    # bf16 wire halves the payload exactly
    half = pg.wire_bytes("neighbor", packed=True, feat_dim=8,
                         wire_dtype=np.float16)
    assert half["total"] * 2 == packed["total"]
    with pytest.raises(ValueError, match="neighbor-only"):
        pg.wire_bytes("a2a", packed=True)


# ---------------------------------------------------------------------------
# exchange level: packed vs dense through halo_sync_stacked, bitwise
# ---------------------------------------------------------------------------

def _stacked_aggregate(pg, f=6, seed=3):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(pg.R, pg.n_pad, f)).astype(np.float32))
    return a * jnp.asarray(pg.node_mask)[..., None]


def test_packed_neighbor_bitwise_values_and_grads():
    _, pg, plan, graph = _neighbor_case()
    packed = plan.halo
    dense = dataclasses.replace(packed, packed=False)
    assert packed.packed and packed.interpret
    a = _stacked_aggregate(pg)
    out_d = halo_sync_stacked(a, graph, dense)
    out_p = halo_sync_stacked(a, graph, packed)
    _bitwise(out_p, out_d)
    # the exchange did something (otherwise the test is vacuous)
    assert float(jnp.abs(out_d - a).max()) > 0

    w = jnp.asarray(np.random.default_rng(4).normal(
        size=out_d.shape).astype(np.float32))
    g_d = jax.grad(lambda v: (halo_sync_stacked(v, graph, dense) * w).sum())(a)
    g_p = jax.grad(lambda v: (halo_sync_stacked(v, graph, packed) * w).sum())(a)
    _bitwise(g_p, g_d)


def test_packed_neighbor_combine_max_bitwise():
    """combine='max' keeps the XLA scatter path but still runs on the narrow
    packed arrays — same results, smaller wire."""
    _, pg, plan, graph = _neighbor_case()
    packed = plan.halo
    dense = dataclasses.replace(packed, packed=False)
    a = _stacked_aggregate(pg, seed=5)
    _bitwise(halo_sync_stacked(a, graph, packed, combine="max"),
             halo_sync_stacked(a, graph, dense, combine="max"))


def test_packed_single_rank_is_identity():
    _, pg, plan, graph = _neighbor_case(grid=(1, 1, 1))
    a = _stacked_aggregate(pg)
    _bitwise(halo_sync_stacked(a, graph, plan.halo), a)


def test_packed_rounds2d_bitwise():
    mesh = box_mesh((4, 4, 2), p=2)
    Ga, Gb = 2, 2
    e2r = partition_elements(mesh, (Gb, Ga, 1))
    graphs = from_element_partition(mesh, e2r, Ga * Gb)
    pg = pack(graphs, mesh.n_nodes)
    rounds2d, nbr = build_2d_halo_rounds(graphs, (Ga, Gb), ("data", "model"))
    dense = HaloSpec(mode=NEIGHBOR, rounds2d=rounds2d, interpret=True)
    packed = dataclasses.replace(dense, packed=True)
    graph = ShardedGraph.build(pg, mesh.coords, NMPPlan(halo=dense))
    graph = graph.with_arrays(
        **{k: jnp.asarray(v) for k, v in nbr.items()},
        **{k: jnp.asarray(v) for k, v in packed_halo_arrays(nbr).items()})
    perms = flat_rounds2d_perms((Ga, Gb))
    assert len(perms) == len(rounds2d)
    a = _stacked_aggregate(pg, seed=6)
    out_d = halo_sync_stacked(a, graph, dense, rounds_perms=perms)
    out_p = halo_sync_stacked(a, graph, packed, rounds_perms=perms)
    _bitwise(out_p, out_d)
    assert float(jnp.abs(out_d - a).max()) > 0


# ---------------------------------------------------------------------------
# model level: full stacked GNN forward + parameter grads, both schedules
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("schedule", ["blocking", "overlap"])
@pytest.mark.parametrize("grid", [(1, 1, 1), (2, 2, 1)])
def test_packed_full_forward_bitwise(schedule, grid):
    mesh = box_mesh((4, 2, 2), p=2)
    pg = partition_mesh(mesh, grid)
    plan_p = NMPPlan.build(pg, NEIGHBOR, packed=True, schedule=schedule,
                           interpret=True)
    plan_d = NMPPlan.build(pg, NEIGHBOR, packed=False, schedule=schedule,
                           interpret=True)
    graph = ShardedGraph.build(pg, mesh.coords, plan_p)
    cfg = GNNConfig.small()
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(gather_node_features(pg, taylor_green_velocity(mesh.coords)))

    def fwd(p, plan):
        return gnn_forward_stacked(p, x, graph, plan, sync_fn=halo_sync_stacked)

    y_d = fwd(params, plan_d)
    y_p = fwd(params, plan_p)
    _bitwise(y_p, y_d)

    g_d = jax.grad(lambda p: (fwd(p, plan_d) ** 2).sum())(params)
    g_p = jax.grad(lambda p: (fwd(p, plan_p) ** 2).sum())(params)
    for ld, lp in zip(jax.tree_util.tree_leaves(g_d),
                      jax.tree_util.tree_leaves(g_p)):
        _bitwise(lp, ld)
