"""Graph-aware partitioning + schedule autotuning (PR 6).

Three groups:

* spectral partitioner properties — determinism, balance, part coverage,
  and the headline structural win: on a stretched mesh the spectral
  bisection cuts halo volume vs the block element grid at >= 4 ranks;
* partition-choice neutrality (Eq. 2/3) — arbitrary ``node2part`` maps
  (random, heavily imbalanced, with an empty rank) pushed through
  ``from_edge_partition`` reproduce the 1-rank loss, node outputs and
  parameter gradients under BOTH halo/compute schedules;
* ``schedule="auto"`` resolution — R=1 shortcut, structural heuristic
  fallback, per-(graph, policy) caching of the measured winner, and the
  actionable error when an unresolved auto plan reaches layer dispatch.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (
    A2A, NONE, GNNConfig, HaloSpec, NMPPlan, ShardedGraph, box_mesh,
    gather_node_features, init_gnn, interior_frac, mesh_node2part,
    partition_graph, partition_mesh, partition_quality, spectral_node2part,
)
from repro.core import consistent_mp
from repro.core.graph_state import nmp_impl
from repro.core.mesh_gen import mesh_graph_edges
from repro.core.partition import scatter_node_outputs
from repro.core.reference import loss_and_grad_stacked


# ---------------------------------------------------------------------------
# spectral partitioner properties
# ---------------------------------------------------------------------------

def _stretched_mesh():
    return box_mesh((8, 2, 2), p=2, lengths=(4.0, 1.0, 1.0))


def test_spectral_balance_and_coverage():
    mesh = _stretched_mesh()
    edges = mesh_graph_edges(mesh)
    for R in (2, 3, 4, 8):
        n2p = spectral_node2part(mesh.n_nodes, edges, R)
        assert n2p.shape == (mesh.n_nodes,)
        sizes = np.bincount(n2p, minlength=R)
        assert (sizes > 0).all(), f"empty part at R={R}: {sizes}"
        # recursive bisection splits each budget floor/ceil, so every part
        # stays within the balance slack of the ideal share
        ideal = mesh.n_nodes / R
        assert sizes.max() <= np.ceil(ideal * (1 + 0.05)) + R


def test_spectral_determinism():
    mesh = _stretched_mesh()
    edges = mesh_graph_edges(mesh)
    a = spectral_node2part(mesh.n_nodes, edges, 4, seed=0)
    b = spectral_node2part(mesh.n_nodes, edges, 4, seed=0)
    np.testing.assert_array_equal(a, b)


def test_spectral_beats_block_halo_volume_on_stretched_mesh():
    """The bench-gate criterion, as a test: at >= 4 ranks on an anisotropic
    mesh, spectral bisection finds the short cuts the fixed block grid
    can't, strictly reducing halo volume (total replica count)."""
    mesh = box_mesh((16, 2, 2), p=2, lengths=(8.0, 1.0, 1.0))
    for grid in ((2, 2, 1), (2, 2, 2)):
        q_b = partition_quality(partition_mesh(mesh, grid))
        q_s = partition_quality(partition_mesh(mesh, grid, method="spectral"))
        assert q_s["halo_volume"] < q_b["halo_volume"], (grid, q_b, q_s)
        assert q_s["empty_ranks"] == 0
        # imbalance counts halo replicas on top of the balanced primary
        # ownership, so it sits above the 5% bisection slack
        assert q_s["imbalance"] < 1.8


def test_partition_quality_1rank_degenerate():
    mesh = box_mesh((2, 2, 2), p=2)
    q = partition_quality(partition_mesh(mesh, (1, 1, 1)))
    assert q["halo_volume"] == 0
    assert q["edge_cut"] == 0
    assert q["replication"] == 1.0
    assert q["imbalance"] == 1.0
    assert q["boundary_frac_max"] == 0.0


def test_partition_mesh_rejects_unknown_method():
    mesh = box_mesh((2, 2, 2), p=2)
    with pytest.raises(ValueError, match="method"):
        partition_mesh(mesh, (2, 1, 1), method="metis")


# ---------------------------------------------------------------------------
# partition-choice neutrality: arbitrary node2part maps satisfy Eq. 2/3
# ---------------------------------------------------------------------------

def _random_graph(seed=0, n=60):
    rng = np.random.default_rng(seed)
    edges = rng.integers(0, n, size=(300, 2))
    edges = edges[edges[:, 0] != edges[:, 1]]
    cfg = GNNConfig(hidden=8, n_mp_layers=2, mlp_hidden_layers=2,
                    node_in=3, edge_in=7)
    params = init_gnn(jax.random.PRNGKey(3), cfg)
    x_global = rng.normal(size=(n, 3)).astype(np.float32)
    coords = rng.normal(size=(n, 3)).astype(np.float32)
    return n, edges, cfg, params, x_global, coords


def _eval_n2p(n, edges, cfg, params, x_global, coords, node2part, R,
              schedule):
    pg = partition_graph(n, edges, R, node2part=node2part)
    plan = NMPPlan(halo=HaloSpec(mode=A2A if R > 1 else NONE),
                   schedule=schedule)
    graph = ShardedGraph.build(pg, coords, plan)
    x = jnp.asarray(gather_node_features(pg, x_global))
    loss, y, grads = loss_and_grad_stacked(params, x, x, graph, plan,
                                           cfg.node_out)
    return float(loss), scatter_node_outputs(pg, np.asarray(y)), grads


@pytest.mark.parametrize("schedule", ["blocking", "overlap"])
@pytest.mark.parametrize("kind", ["random", "imbalanced", "empty_rank"])
def test_arbitrary_node2part_is_consistency_neutral(kind, schedule):
    """Eq. 2/3 hold for ANY node->part map, however bad: values and grads
    match the 1-rank run whether the map is random, 90/10 imbalanced, or
    leaves a rank with no nodes at all."""
    n, edges, cfg, params, x_global, coords = _random_graph()
    rng = np.random.default_rng(42)
    R = 4
    if kind == "random":
        node2part = rng.integers(0, R, size=n)
    elif kind == "imbalanced":
        node2part = np.where(rng.random(n) < 0.9, 0,
                             rng.integers(1, R, size=n))
    else:  # one rank owns nothing
        node2part = rng.integers(0, R - 1, size=n)
    l1, y1, g1 = _eval_n2p(n, edges, cfg, params, x_global, coords,
                           None, 1, schedule)
    lr, yr, gr = _eval_n2p(n, edges, cfg, params, x_global, coords,
                           node2part, R, schedule)
    assert abs(lr - l1) < 2e-6 * max(1.0, abs(l1)), (kind, schedule)
    np.testing.assert_allclose(yr, y1, rtol=3e-5, atol=2e-6)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(gr)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-3, atol=2e-6,
                                   err_msg=f"{kind}/{schedule}")


def test_spectral_node2part_on_generic_graph():
    """partition_graph(method='spectral') wires the spectral map through the
    generic vertex-cut path and stays consistency-neutral."""
    n, edges, cfg, params, x_global, coords = _random_graph(seed=1)
    l1, y1, _ = _eval_n2p(n, edges, cfg, params, x_global, coords,
                          None, 1, "blocking")
    pg = partition_graph(n, edges, 3, method="spectral")
    plan = NMPPlan(halo=HaloSpec(mode=A2A))
    graph = ShardedGraph.build(pg, coords, plan)
    x = jnp.asarray(gather_node_features(pg, x_global))
    loss, y, _ = loss_and_grad_stacked(params, x, x, graph, plan,
                                       cfg.node_out)
    assert abs(float(loss) - l1) < 2e-6 * max(1.0, abs(l1))
    np.testing.assert_allclose(scatter_node_outputs(pg, np.asarray(y)), y1,
                               rtol=3e-5, atol=2e-6)


# ---------------------------------------------------------------------------
# schedule="auto" resolution
# ---------------------------------------------------------------------------

def _auto_case(grid=(2, 2, 1)):
    mesh = box_mesh((4, 2, 2), p=2)
    pg = partition_mesh(mesh, grid)
    plan = NMPPlan(halo=HaloSpec(mode=NONE if pg.R == 1 else A2A),
                   schedule="auto")
    graph = ShardedGraph.build(pg, mesh.coords, plan)
    return plan, graph


def test_autotune_r1_shortcut():
    plan, graph = _auto_case((1, 1, 1))
    assert plan.autotune(graph).schedule == "blocking"


def test_autotune_fixed_schedule_is_noop():
    plan, graph = _auto_case()
    fixed = plan.replace(schedule="overlap")
    assert fixed.autotune(graph) is fixed


def test_autotune_heuristic_fallback_matches_interior_frac():
    plan, graph = _auto_case()
    picked = plan.autotune(graph, measure=False).schedule
    frac = interior_frac(graph.levels[0])
    want = "overlap" if frac < 0.5 else "blocking"
    assert picked == want


def test_autotune_measured_pick_is_cached(monkeypatch):
    """The expensive timing probe runs once per (graph, policy): a second
    autotune on the same graph is a pure cache hit."""
    plan, graph = _auto_case()
    calls = []

    def fake_measure(plan, g0, hidden, iters):
        calls.append(1)
        return "overlap"

    monkeypatch.setattr(consistent_mp, "_measure_best_schedule", fake_measure)
    monkeypatch.setattr(consistent_mp, "_SCHEDULE_CACHE", {})
    p1 = plan.autotune(graph, measure=True)
    p2 = plan.autotune(graph, measure=True)
    assert p1.schedule == p2.schedule == "overlap"
    assert len(calls) == 1


def test_autotune_env_var_disables_measurement(monkeypatch):
    plan, graph = _auto_case()

    def boom(*a, **kw):
        raise AssertionError("measurement ran despite REPRO_SCHEDULE_AUTOTUNE=0")

    monkeypatch.setattr(consistent_mp, "_measure_best_schedule", boom)
    monkeypatch.setattr(consistent_mp, "_SCHEDULE_CACHE", {})
    monkeypatch.setenv("REPRO_SCHEDULE_AUTOTUNE", "0")
    picked = plan.autotune(graph).schedule
    assert picked in ("blocking", "overlap")


def test_unresolved_auto_plan_errors_at_dispatch():
    plan = NMPPlan(halo=HaloSpec(mode=A2A), schedule="auto")
    with pytest.raises(ValueError, match="autotune"):
        nmp_impl(plan)


# ---------------------------------------------------------------------------
# halo mode "auto": the (schedule x halo-mode x wire) cross-product
# ---------------------------------------------------------------------------

def _mode_auto_case(grid=(2, 2, 1), **plan_kw):
    mesh = box_mesh((4, 2, 2), p=2)
    pg = partition_mesh(mesh, grid)
    plan = NMPPlan.build(pg, "auto", schedule="auto", interpret=True,
                         **plan_kw)
    graph = ShardedGraph.build(pg, mesh.coords, plan)
    return plan, graph


def test_autotune_mode_auto_heuristic_picks_packed_neighbor():
    plan, graph = _mode_auto_case()
    out = plan.autotune(graph, measure=False)
    assert out.halo.mode == "neighbor" and out.halo.packed
    assert out.halo.wire_dtype is None          # never introduces lossy wire
    frac = interior_frac(graph.levels[0])
    assert out.schedule == ("overlap" if frac < 0.5 else "blocking")
    # the resolved plan dispatches and keeps the coarse specs' own perms
    nmp_impl(out)
    assert out.halo.perms == plan.halo.perms


def test_autotune_mode_auto_r1_resolves_none():
    plan, graph = _mode_auto_case((1, 1, 1))
    out = plan.autotune(graph)
    assert out.schedule == "blocking"
    assert out.halo.mode == "none" and not out.halo.packed
    assert out.halo.wire_dtype is None


def test_autotune_mode_auto_keeps_requested_wire_in_heuristic():
    plan, graph = _mode_auto_case(wire_dtype=jnp.bfloat16)
    out = plan.autotune(graph, measure=False)
    assert jnp.dtype(out.halo.wire_dtype).name == "bfloat16"


def test_autotune_mode_auto_measured_argmin_cached(monkeypatch):
    """Mode-auto resolution argmins the candidate table and caches the
    triple: the (expensive) sweep runs once per (graph, policy)."""
    plan, graph = _mode_auto_case()
    calls = []
    table = {("blocking", "a2a", None): 3.0,
             ("blocking", "neighbor", None): 2.0,
             ("overlap", "neighbor-packed", None): 1.0}

    def fake_sweep(plan, graph, hidden, iters, schedules, modes, wires):
        calls.append(1)
        return dict(table)

    monkeypatch.setattr(consistent_mp, "measure_plan_candidates", fake_sweep)
    monkeypatch.setattr(consistent_mp, "_SCHEDULE_CACHE", {})
    p1 = plan.autotune(graph, measure=True)
    p2 = plan.autotune(graph, measure=True)
    assert p1.schedule == p2.schedule == "overlap"
    assert p1.halo.mode == "neighbor" and p1.halo.packed
    assert len(calls) == 1


def test_measure_plan_candidates_real_sweep_matches_autotune():
    """The miniature of the bench acceptance check: a real measured sweep on
    a small graph covers the full candidate grid, and autotune's pick IS the
    argmin of the same memoized table."""
    from repro.core import measure_plan_candidates
    plan, graph = _mode_auto_case((2, 1, 1))
    table = measure_plan_candidates(plan, graph, hidden=8, iters=1)
    assert set(table) == {(s, m, None)
                          for s in ("blocking", "overlap")
                          for m in ("a2a", "neighbor", "neighbor-packed")}
    assert all(np.isfinite(t) and t > 0 for t in table.values())
    out = plan.autotune(graph, measure=True, hidden=8, iters=1)
    best_s, best_m, best_w = min(table, key=table.get)
    assert out.schedule == best_s
    assert out.halo.packed == best_m.endswith("-packed")
    assert out.halo.mode == best_m.replace("-packed", "")
    assert out.halo.wire_dtype is None and best_w is None


def test_unresolved_mode_auto_errors_at_exchange():
    from repro.core.halo import halo_sync
    plan, graph = _mode_auto_case()
    with pytest.raises(ValueError, match="autotune"):
        halo_sync(jnp.zeros((8, 4)), graph.rank(0), plan.halo)


def test_mesh_node2part_matches_partition_mesh_spectral():
    """partition_mesh(method='spectral') and the explicit mesh_node2part +
    node2part path produce the same decomposition (the multilevel driver
    relies on this equivalence)."""
    mesh = box_mesh((4, 2, 2), p=2)
    pg_a = partition_mesh(mesh, (2, 2, 1), method="spectral")
    n2p = mesh_node2part(mesh, 4)
    edges = mesh_graph_edges(mesh)
    pg_b = partition_graph(mesh.n_nodes, np.concatenate(
        [edges, edges[:, ::-1]]), 4, node2part=n2p)
    assert partition_quality(pg_a)["halo_volume"] == \
        partition_quality(pg_b)["halo_volume"]
