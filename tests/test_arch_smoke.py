"""Per-architecture smoke: reduced config, one real train step on CPU.

(The FULL configs are exercised shape-only by the dry-run; see
tests/test_dryrun.py for the compile-path guard.)
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import gnn_common as G
from repro.core.halo import NONE, A2A, HaloSpec
from repro.core.partition import partition_graph, gather_node_features
from repro.graph.datasets import cora_like
from repro.launch.mesh import make_mesh
from repro.train.optimizer import AdamWConfig


def _tiny_mesh():
    return make_mesh((1, 1), ("data", "model"))


def _real_meta_for(n, edges, R=1):
    pg = partition_graph(n, edges, R)
    meta = {k: jnp.asarray(v) for k, v in pg.device_arrays().items()}
    return pg, meta


@pytest.mark.parametrize("arch", ["gat-cora", "graphcast", "nequip", "mace"])
def test_gnn_arch_one_train_step(arch):
    """One AdamW step through the production step builder (shard_map path)."""
    from repro.configs import get_arch
    mod, family = get_arch(arch)
    assert family == "gnn"
    mesh = _tiny_mesh()
    n = 48
    edges, feats, labels = cora_like(seed=1, n=n, m_und=140, d=16, n_classes=3)
    pg, meta = _real_meta_for(n, edges)
    n_pad, e_pad = pg.n_pad, pg.e_pad
    halo = HaloSpec(mode=NONE, axis="data")

    shape = dict(kind="full", n_nodes=n, n_edges=140, d_feat=16, n_classes=3)
    rng = np.random.default_rng(0)
    if arch in ("nequip", "mace"):
        cfg = mod.smoke_config()
        params = (__import__("repro.models.gnn_zoo.nequip", fromlist=["init_nequip"]).init_nequip
                  if arch == "nequip" else
                  __import__("repro.models.gnn_zoo.mace", fromlist=["init_mace"]).init_mace)(
            jax.random.PRNGKey(0), cfg)
        fwd = (__import__("repro.models.gnn_zoo.nequip", fromlist=["nequip_forward"]).nequip_forward
               if arch == "nequip" else
               __import__("repro.models.gnn_zoo.mace", fromlist=["mace_forward"]).mace_forward)
        inputs = {
            "species": jnp.asarray(rng.integers(0, cfg.n_species, (1, n_pad)), jnp.int32),
            "pos": jnp.asarray(rng.normal(size=(1, n_pad, 3)), jnp.float32),
            "target": jnp.asarray(rng.normal(size=(1, n_pad)), jnp.float32),
        }
        input_specs = {"species": P("data", None), "pos": P("data", None, None),
                       "target": P("data", None)}

        def loss_local(p, inp, m):
            e = fwd(p, inp["species"][0], inp["pos"][0], m, halo, cfg)
            return G.consistent_mse_loss(e, inp["target"][0], m["node_inv_mult"], ("data",))
    else:
        loss_local = mod._loss_local_factory(shape, halo, "data", mesh)
        inputs_sds, input_specs = mod._inputs_factory(shape, 1, n_pad, e_pad, "data")
        inputs = {}
        for k, s in inputs_sds.items():
            if s.dtype == jnp.int32:
                inputs[k] = jnp.asarray(rng.integers(0, 3, s.shape), jnp.int32)
            else:
                inputs[k] = jnp.asarray(rng.normal(size=s.shape), jnp.float32)
        params_sds = mod._param_factory(shape)
        params = jax.tree.map(
            lambda s: jnp.asarray(rng.normal(size=s.shape) * 0.05, s.dtype)
            if jnp.issubdtype(s.dtype, jnp.floating)
            else jnp.zeros(s.shape, s.dtype), params_sds)

    from repro.train.optimizer import init_adamw
    opt = AdamWConfig()
    state = {"params": params, "opt": init_adamw(params, opt)}
    # meta arrays carry the leading rank axis from device_arrays (R=1 here)
    meta_stacked = {k: jnp.asarray(v) for k, v in pg.device_arrays().items()}

    _, wrap = G.make_gnn_train_step(loss_local, mesh, input_specs, "data", opt)
    step = jax.jit(wrap(meta_stacked))
    new_state, loss = step(state, inputs, meta_stacked)
    assert np.isfinite(float(loss)), arch
    # params actually moved
    d = max(float(jnp.abs(a - b).max()) for a, b in
            zip(jax.tree.leaves(new_state["params"]), jax.tree.leaves(state["params"])))
    assert d > 0, arch


def test_paper_gnn_smoke():
    from repro.configs import paper_gnn
    from repro.core import (NMPPlan, ShardedGraph, box_mesh, init_gnn,
                            partition_mesh, taylor_green_velocity)
    from repro.core.reference import loss_and_grad_stacked
    cfg = paper_gnn.smoke_config()
    mesh = box_mesh((2, 2, 1), p=2)   # 3-D: velocity has node_in=3 components
    pg = partition_mesh(mesh, (2, 1, 1))
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    plan = NMPPlan(halo=HaloSpec(mode=A2A))
    graph = ShardedGraph.build(pg, mesh.coords, plan)
    x = jnp.asarray(gather_node_features(pg, taylor_green_velocity(mesh.coords)))
    loss, y, grads = loss_and_grad_stacked(params, x, x, graph, plan,
                                           cfg.node_out)
    assert np.isfinite(float(loss))
    assert np.isfinite(np.asarray(y)).all()
