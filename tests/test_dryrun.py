"""Dry-run compile-path guard: one cheap cell per family on both production
meshes, in a subprocess (needs 512 host devices set before jax init)."""
import json
import os
import subprocess
import sys

import pytest

CELLS = [
    ("gat-cora", "full_graph_sm"),      # gnn family (fast compile)
    ("dlrm-rm2", "serve_p99"),          # recsys
    ("llama3.2-3b", "decode_32k"),      # lm
]


@pytest.mark.parametrize("arch,shape", CELLS)
def test_dryrun_cell_compiles_on_both_meshes(arch, shape, tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    out = tmp_path / "rec.jsonl"
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "both", "--no-hlo", "--out", str(out)],
        env=env, capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    recs = [json.loads(l) for l in out.read_text().splitlines()]
    assert len(recs) == 2 and all(r["status"] == "ok" for r in recs)
    assert {r["mesh"] for r in recs} == {"16x16", "2x16x16"}
    for r in recs:
        assert r["per_device_bytes"]["peak_estimate"] < 16 * 2 ** 30
