"""Unit + property-style tests for the partitioner and halo plans."""
import numpy as np
import pytest

from repro.core.mesh_gen import box_mesh, mesh_graph_edges, undirected_to_directed
from repro.core.partition import (
    from_edge_partition, from_element_partition, greedy_edge_coloring,
    partition_elements, partition_mesh,
    gather_node_features, scatter_node_outputs,
)


def _brute_force_multiplicities(graphs, n_nodes):
    node_mult = np.zeros(n_nodes, dtype=int)
    for g in graphs:
        node_mult[g.global_ids] += 1
    return node_mult


@pytest.mark.parametrize("rank_grid", [(2, 1, 1), (2, 2, 1), (2, 2, 2)])
def test_element_partition_multiplicities(rank_grid):
    m = box_mesh((4, 4, 2), p=2)
    R = int(np.prod(rank_grid))
    e2r = partition_elements(m, rank_grid)
    assert e2r.shape == (m.n_elem,)
    assert set(np.unique(e2r)) == set(range(R))
    graphs = from_element_partition(m, e2r, R)
    # node multiplicity via brute force matches 1/inv_mult
    mult = _brute_force_multiplicities(graphs, m.n_nodes)
    for g in graphs:
        np.testing.assert_allclose(1.0 / g.node_inv_mult, mult[g.global_ids])
    # sum over copies of 1/d_i equals global node count (Eq. 6c)
    total = sum(g.node_inv_mult.sum() for g in graphs)
    np.testing.assert_allclose(total, m.n_nodes, rtol=1e-6)
    # edges weighted by 1/d_ij sum to global directed edge count
    total_e = sum(g.edge_inv_mult.sum() for g in graphs)
    assert abs(total_e - 2 * mesh_graph_edges(m).shape[0]) < 1e-5


def test_partition_covers_all_edges_exactly_once_weighted():
    m = box_mesh((4, 2, 2), p=3)
    pg = partition_mesh(m, (2, 2, 1))
    # reconstruct global weighted edge multiset
    und = mesh_graph_edges(m)
    seen = {}
    for r in range(pg.R):
        mask = pg.edge_mask[r] > 0
        src_g = pg.global_ids[r][pg.edge_src[r][mask]]
        dst_g = pg.global_ids[r][pg.edge_dst[r][mask]]
        for a, b, w in zip(src_g, dst_g, pg.edge_inv_mult[r][mask]):
            seen[(int(a), int(b))] = seen.get((int(a), int(b)), 0.0) + w
    d = undirected_to_directed(und)
    assert len(seen) == d.shape[0]
    for v in seen.values():
        assert abs(v - 1.0) < 1e-6


@pytest.mark.parametrize("seed", range(4))
def test_generic_edge_partition_properties(seed):
    """Property: random graph, random R — edge conservation + multiplicities."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(20, 80))
    m_edges = int(rng.integers(n, 4 * n))
    edges = rng.integers(0, n, size=(m_edges, 2))
    edges = edges[edges[:, 0] != edges[:, 1]]
    R = int(rng.choice([2, 3, 4, 8]))
    graphs = from_edge_partition(n, edges, R)
    # every directed edge appears exactly once globally
    total = sum(g.n_edges for g in graphs)
    assert total == edges.shape[0]
    # every node has a copy somewhere; multiplicity matches inv_mult
    mult = _brute_force_multiplicities(graphs, n)
    assert (mult >= 1).all()
    for g in graphs:
        np.testing.assert_allclose(1.0 / g.node_inv_mult, mult[g.global_ids])
        assert np.all(g.edge_inv_mult == 1.0)  # d_ij == 1 for edge partitioning
        if g.n_edges:
            assert g.edges.min() >= 0 and g.edges.max() < g.n_nodes


def test_greedy_edge_coloring_valid():
    rng = np.random.default_rng(0)
    for _ in range(10):
        R = int(rng.integers(3, 12))
        pairs = set()
        for _ in range(int(rng.integers(2, 3 * R))):
            a, b = rng.integers(0, R, 2)
            if a != b:
                pairs.add((min(a, b), max(a, b)))
        rounds = greedy_edge_coloring(sorted(pairs))
        got = set()
        deg = {}
        for a, b in pairs:
            deg[a] = deg.get(a, 0) + 1
            deg[b] = deg.get(b, 0) + 1
        for rnd in rounds:
            ranks = [x for p in rnd for x in p]
            assert len(ranks) == len(set(ranks)), "round not disjoint"
            got |= set(rnd)
        assert got == pairs
        if pairs:
            assert len(rounds) <= max(deg.values()) + 1  # Vizing-ish bound


def test_halo_plan_symmetry():
    m = box_mesh((4, 4), p=2)
    pg = partition_mesh(m, (2, 2))
    h = pg.halo
    R = pg.R
    for r in range(R):
        for s in range(R):
            # send mask r->s == recv mask s<-r, same buffer occupancy
            np.testing.assert_array_equal(h.a2a_send_mask[r, s], h.a2a_recv_mask[s, r])
    # shared ids actually coincide: exchanged global ids match both sides
    for r in range(R):
        for s in range(R):
            m_rs = h.a2a_send_mask[r, s] > 0
            if not m_rs.any():
                continue
            gids_sent = pg.global_ids[r][h.a2a_send_idx[r, s][m_rs]]
            gids_recv = pg.global_ids[s][h.a2a_recv_idx[s, r][m_rs]]
            np.testing.assert_array_equal(gids_sent, gids_recv)


def test_neighbor_rounds_cover_all_pairs():
    m = box_mesh((4, 4, 2), p=1)
    pg = partition_mesh(m, (2, 2, 2))
    h = pg.halo
    covered = set()
    for k, perm in enumerate(h.perms):
        for (a, b) in perm:
            covered.add((min(a, b), max(a, b)))
    expect = set()
    for r in range(pg.R):
        for s in range(r + 1, pg.R):
            if (h.a2a_send_mask[r, s] > 0).any():
                expect.add((r, s))
    assert covered == expect


def test_segment_layout_cache_roundtrip():
    """Layout memoization on PartitionedGraphs: same dict object on re-query,
    real edges covered exactly once per rank, padding edges dropped, per-slot
    src/dst ids match the edge arrays, tiles dst-sorted."""
    m = box_mesh((4, 4, 2), p=2)
    pg = partition_mesh(m, (2, 2, 1))
    lay = pg.segment_layout(16, 32)
    assert pg.segment_layout(16, 32) is lay          # cache hit, no recompute
    assert pg.segment_layout(16, 16) is not lay      # different key
    perm, src, dst = lay["perm"], lay["src"], lay["dst"]
    assert perm.shape == (pg.R, lay["n_tiles"], 32)
    assert src.shape == perm.shape and dst.shape == perm.shape
    for r in range(pg.R):
        flat = perm[r].reshape(-1)
        real = flat >= 0
        np.testing.assert_array_equal(
            np.sort(flat[real]), np.nonzero(pg.edge_mask[r] > 0)[0])
        # slots carry the edge's global src/dst node ids, dst-sorted
        np.testing.assert_array_equal(src[r].reshape(-1)[real],
                                      pg.edge_src[r][flat[real]])
        np.testing.assert_array_equal(dst[r].reshape(-1)[real],
                                      pg.edge_dst[r][flat[real]])
        assert (np.diff(pg.edge_dst[r][flat[real]]) >= 0).all()
        # padding slots are zeroed (the kernel weight-masks them)
        assert (src[r].reshape(-1)[~real] == 0).all()
        assert (dst[r].reshape(-1)[~real] == 0).all()
    # device_arrays carries the maps through to step metadata
    meta = pg.device_arrays(seg_layout=(16, 32))
    np.testing.assert_array_equal(meta["seg_perm"], perm)
    np.testing.assert_array_equal(meta["seg_src"], src)
    np.testing.assert_array_equal(meta["seg_dst"], dst)


def test_interior_split_properties():
    """Interior/boundary classification invariants the overlap schedule
    relies on: the masks partition the real edges, boundary nodes are exactly
    the multi-copy (shared) nodes, boundary edges are exactly the edges
    landing on them, and the per-side fused layouts tile each side's edges
    exactly once."""
    m = box_mesh((4, 4, 2), p=2)
    pg = partition_mesh(m, (2, 2, 1))
    sp = pg.interior_split()
    assert pg.interior_split() is sp                 # memoized on pg

    # masks partition edge_mask, disjointly
    np.testing.assert_allclose(sp["edge_bnd_mask"] + sp["edge_int_mask"],
                               pg.edge_mask)
    assert float((sp["edge_bnd_mask"] * sp["edge_int_mask"]).max()) == 0.0

    # boundary node <=> a coincident copy exists on another rank (d_i > 1)
    expect_bnd = ((pg.node_inv_mult < 1.0) & (pg.node_mask > 0)).astype(np.float32)
    np.testing.assert_array_equal(sp["node_bnd_mask"], expect_bnd)

    # edge side <=> side of its destination node
    for r in range(pg.R):
        real = pg.edge_mask[r] > 0
        dst_bnd = sp["node_bnd_mask"][r][pg.edge_dst[r]] > 0
        np.testing.assert_array_equal(sp["edge_bnd_mask"][r][real] > 0,
                                      dst_bnd[real])

    # compacted index lists enumerate each side exactly once
    for part in ("bnd", "int"):
        for r in range(pg.R):
            got = np.sort(sp[f"edge_{part}_idx"][r][sp[f"edge_{part}_valid"][r] > 0])
            np.testing.assert_array_equal(
                got, np.nonzero(sp[f"edge_{part}_mask"][r] > 0)[0])

    frac = sp["interior_frac"]
    assert 0.0 < frac < 1.0
    assert abs(frac - sp["edge_int_mask"].sum() / pg.edge_mask.sum()) < 1e-6

    # per-side fused layouts: disjoint union of the full layout's real edges
    lay_b = pg.segment_layout(16, 32, part="bnd")
    lay_i = pg.segment_layout(16, 32, part="int")
    for r in range(pg.R):
        eb = lay_b["perm"][r][lay_b["perm"][r] >= 0]
        ei = lay_i["perm"][r][lay_i["perm"][r] >= 0]
        assert np.intersect1d(eb, ei).size == 0
        np.testing.assert_array_equal(
            np.sort(np.concatenate([eb, ei])),
            np.nonzero(pg.edge_mask[r] > 0)[0])

    # device_arrays(split=True) carries everything through to step metadata
    meta = pg.device_arrays(seg_layout=(16, 32), split=True)
    for k in ("edge_bnd_idx", "edge_bnd_valid", "edge_int_idx",
              "edge_int_valid", "seg_perm_bnd", "seg_src_bnd",
              "seg_dst_bnd", "seg_perm_int", "seg_src_int", "seg_dst_int"):
        assert k in meta, k

    # single-rank graph: no boundary at all
    pg1 = partition_mesh(m, (1, 1, 1))
    sp1 = pg1.interior_split()
    assert sp1["interior_frac"] == 1.0
    assert float(sp1["edge_bnd_mask"].sum()) == 0.0


def test_zero_boundary_partition_fused_layout_and_consistency():
    """Degenerate partition: a 1-rank graph has zero boundary edges, so the
    "bnd" side's compact layout is a single all-padding tile — the fused
    kernel must still run it (values and grads) and produce exact zeros,
    while the "int" side reproduces the unsplit layout's edge set."""
    import jax
    import jax.numpy as jnp
    from repro.core import NMPPlan, ShardedGraph
    from repro.core.consistent_mp import (
        edge_update_aggregate, edge_update_aggregate_part, init_nmp_layer)

    m = box_mesh((2, 2, 2), p=2)
    pg = partition_mesh(m, (1, 1, 1))
    lay_b = pg.segment_layout(16, 32, part="bnd")
    assert (lay_b["perm"] == -1).all()               # no boundary edges
    lay_i = pg.segment_layout(16, 32, part="int")
    np.testing.assert_array_equal(
        np.sort(lay_i["perm"][lay_i["perm"] >= 0]),
        np.nonzero(pg.edge_mask[0] > 0)[0])

    plan = NMPPlan(backend="fused", interpret=True, block_n=16, block_e=32,
                   schedule="overlap")
    graph_r = ShardedGraph.build(pg, m.coords, plan).rank(0)
    rng = np.random.default_rng(0)
    params = init_nmp_layer(jax.random.PRNGKey(0), 8, 2)
    x = jnp.asarray(rng.normal(size=(pg.n_pad, 8)), jnp.float32)
    e = jnp.asarray(rng.normal(size=(pg.e_pad, 8)), jnp.float32)

    def run(part):
        def f(p, x, e):
            eo, ao = edge_update_aggregate_part(p, x, e, graph_r, part, plan)
            return eo, ao
        (eo, ao), vjp = jax.vjp(lambda p, x, e: f(p, x, e), params, x, e)
        g = vjp((jnp.ones_like(eo), jnp.ones_like(ao)))
        return eo, ao, g

    e_b, a_b, g_b = run("bnd")
    assert float(jnp.abs(e_b).max()) == 0.0 and float(jnp.abs(a_b).max()) == 0.0
    assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(g_b))
    # int side alone == unsplit fused result
    e_i, a_i, _ = run("int")
    e_all, a_all = edge_update_aggregate(params, x, e, graph_r, plan)
    np.testing.assert_allclose(np.asarray(e_i), np.asarray(e_all),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(a_i), np.asarray(a_all),
                               rtol=1e-5, atol=1e-6)


def test_gather_scatter_roundtrip():
    m = box_mesh((3, 3), p=2)
    pg = partition_mesh(m, (3, 1))
    rng = np.random.default_rng(1)
    gx = rng.normal(size=(m.n_nodes, 5)).astype(np.float32)
    per_rank = gather_node_features(pg, gx)
    assert per_rank.shape == (pg.R, pg.n_pad, 5)
    back = scatter_node_outputs(pg, per_rank)
    np.testing.assert_allclose(back, gx)
