"""ShardedGraph pytree semantics + NMPPlan staticness (the unified
execution-state API introduced by the graph_state refactor).

The load-bearing properties: the graph round-trips through
``jax.tree.flatten/unflatten`` unchanged, rebuilding an identical graph or
plan never retraces a jitted step (keys live in the hashable treedef,
plans compare by value), and the retired raw-meta-dict path fails loudly
with a ``TypeError`` instead of a shape error three layers down.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import (
    A2A, GNNConfig, HaloSpec, NMPPlan, ShardedGraph, box_mesh,
    build_hierarchy, init_gnn, partition_mesh, nmp_impl,
    registered_nmp_impls,
)
from repro.core.gnn import gnn_forward
from repro.core.graph_state import as_graph


@pytest.fixture(scope="module")
def small_graph():
    mesh = box_mesh((2, 2, 2), p=2)
    pg = partition_mesh(mesh, (2, 1, 1))
    plan = NMPPlan(halo=HaloSpec(mode=A2A), schedule="overlap")
    return ShardedGraph.build(pg, mesh.coords, plan), pg, mesh


def test_flatten_unflatten_identity(small_graph):
    graph, _, _ = small_graph
    leaves, treedef = jax.tree.flatten(graph)
    rebuilt = jax.tree.unflatten(treedef, leaves)
    assert isinstance(rebuilt, ShardedGraph)
    assert sorted(rebuilt.keys()) == sorted(graph.keys())
    assert jax.tree.structure(rebuilt) == treedef
    for k in graph.keys():
        np.testing.assert_array_equal(np.asarray(rebuilt[k]),
                                      np.asarray(graph[k]))
    # leaves flow through tree.map and come back as a ShardedGraph
    doubled = jax.tree.map(lambda v: v * 2, graph)
    assert isinstance(doubled, ShardedGraph)
    np.testing.assert_array_equal(np.asarray(doubled["edge_src"]),
                                  2 * np.asarray(graph["edge_src"]))


def test_multilevel_flatten_roundtrip():
    mesh = box_mesh((2, 2, 2), p=2)
    ml = build_hierarchy(mesh, (2, 1, 1), 2)
    graph = ShardedGraph.build(ml.levels[0], mesh.coords, hierarchy=ml)
    assert graph.n_levels == 2
    assert "t_fine" in graph.levels[1]
    leaves, treedef = jax.tree.flatten(graph)
    rebuilt = jax.tree.unflatten(treedef, leaves)
    assert rebuilt.n_levels == 2
    np.testing.assert_array_equal(np.asarray(rebuilt.levels[1]["t_rw"]),
                                  np.asarray(graph.levels[1]["t_rw"]))
    # rank slicing strips the leading axis on EVERY level
    r0 = graph.rank(0)
    assert r0["node_mask"].ndim == graph["node_mask"].ndim - 1
    assert r0.levels[1]["node_mask"].ndim == \
        graph.levels[1]["node_mask"].ndim - 1


def test_jit_does_not_retrace_across_rebuilds(small_graph):
    """A rebuilt (structurally identical) graph + an equal fresh plan hit
    the same jit cache entry: trace count stays 1."""
    graph, pg, mesh = small_graph
    traces = []

    @jax.jit
    def f(g):
        traces.append(1)
        return g["node_mask"].sum()

    f(graph)
    # a fresh object built from the same partition, plus a flatten round trip
    graph2 = ShardedGraph.build(
        pg, mesh.coords, NMPPlan(halo=HaloSpec(mode=A2A), schedule="overlap"))
    f(graph2)
    f(jax.tree.unflatten(jax.tree.structure(graph), jax.tree.leaves(graph)))
    assert len(traces) == 1

    # plans that differ only by identity (equal static fields) do not
    # retrace when passed statically either
    traces2 = []

    def g_fn(graph, plan):
        traces2.append(1)
        return graph["node_mask"].sum() * (plan.block_n > 0)

    g_jit = jax.jit(g_fn, static_argnums=(1,))
    p1 = NMPPlan(halo=HaloSpec(mode=A2A), schedule="overlap", block_n=64)
    p2 = NMPPlan(halo=HaloSpec(mode=A2A), schedule="overlap", block_n=64)
    assert p1 == p2 and hash(p1) == hash(p2)
    g_jit(graph, p1)
    g_jit(graph2, p2)
    assert len(traces2) == 1
    # ...while a plan differing in a static field DOES retrace (it selects
    # different code)
    g_jit(graph, p1.replace(block_n=128))
    assert len(traces2) == 2


def test_meta_dict_path_raises_typeerror(small_graph):
    """Stale callers that still pass raw meta dicts fail loudly."""
    graph, pg, mesh = small_graph
    cfg = GNNConfig(hidden=8, n_mp_layers=1, mlp_hidden_layers=1)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((pg.n_pad, 3))
    meta_dict = dict(graph.items())
    plan = NMPPlan(halo=HaloSpec(mode=A2A))
    with pytest.raises(TypeError, match="meta dicts are no longer accepted"):
        gnn_forward(params, x, meta_dict, plan)
    with pytest.raises(TypeError, match="ShardedGraph"):
        as_graph([1, 2, 3])
    # and a missing array names the fix, not a KeyError deep in XLA
    blocking_graph = ShardedGraph.build(pg, mesh.coords)
    with pytest.raises(KeyError, match="ShardedGraph.build"):
        blocking_graph["seg_perm"]


def test_specs_match_structure_and_axes(small_graph):
    graph, _, _ = small_graph
    specs = graph.specs("graph")
    assert isinstance(specs, ShardedGraph)
    assert jax.tree.structure(specs) == jax.tree.structure(graph)
    s = specs["node_mask"]
    assert isinstance(s, P) and s[0] == "graph"
    # two-axis layout for two-level spatial grids
    regrid = jax.tree.map(lambda v: v.reshape((2, 1) + v.shape[1:]), graph)
    specs2 = regrid.specs(("data", "model"))
    assert specs2["node_mask"][:2] == ("data", "model")


def test_with_arrays_and_level_errors(small_graph):
    graph, _, _ = small_graph
    extra = graph.with_arrays(foo=jnp.zeros((2, 3)))
    assert "foo" in extra and "foo" not in graph
    assert extra.coarse is graph.coarse
    with pytest.raises(ValueError, match="multilevel graph"):
        graph.level(1)


def test_autotune_blocks_from_table():
    """The PR3 static block-size autotune stays reachable from the plan."""
    from repro.kernels.segment_agg.ops import pick_block_sizes
    plan = NMPPlan(backend="fused").autotune_blocks(16)
    assert (plan.block_n, plan.block_e) == pick_block_sizes(16)
    # other fields survive the replace
    assert plan.backend == "fused"


def test_nmp_registry_cells_and_unknown_plan():
    assert registered_nmp_impls() == (
        ("fused", "blocking"), ("fused", "overlap"),
        ("xla", "blocking"), ("xla", "overlap"))
    with pytest.raises(ValueError, match="no NMP implementation registered"):
        nmp_impl(NMPPlan(backend="tpu-next"))
    with pytest.raises(ValueError, match="precision"):
        NMPPlan(precision="fp8")
