"""Pipeline-parallel driver (subprocess, 8 host devices): GPipe forward over
4 stages must equal the sequential composition, and gradients must flow."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp

from repro.launch.mesh import make_mesh
from repro.train.pipeline import pipeline_forward


def main():
    S, M, B, D = 4, 6, 2, 16
    mesh = make_mesh((S, 2), ("stage", "data"))
    rng = np.random.default_rng(0)
    ws = jnp.asarray(rng.normal(size=(S, D, D)).astype(np.float32) * 0.3)
    micros = jnp.asarray(rng.normal(size=(M, B, D)).astype(np.float32))

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    out = pipeline_forward(stage_fn, ws, micros, mesh)

    ref = micros
    for s in range(S):
        ref = jnp.tanh(ref @ ws[s])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-6)
    print("pipeline forward matches sequential")

    def loss(ws):
        return (pipeline_forward(stage_fn, ws, micros, mesh) ** 2).sum()

    def loss_ref(ws):
        r = micros
        for s in range(S):
            r = jnp.tanh(r @ ws[s])
        return (r ** 2).sum()

    g = jax.grad(loss)(ws)
    g_ref = jax.grad(loss_ref)(ws)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-3, atol=1e-5)
    print("pipeline gradients match sequential")
    print("PIPELINE DRIVER PASS")


if __name__ == "__main__":
    main()
