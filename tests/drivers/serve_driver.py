"""Serving-engine driver (subprocess, real collectives).

Runs the resident :class:`repro.runtime.engine.InferenceEngine` end to end
— fingerprinted checkpoint load, mesh registration (graph cache), warmup,
multi-producer streaming through the bounded request queue — and asserts:

  1. every streamed prediction is BITWISE identical to an offline
     ``rollout_step`` eval of the same snapshot, built independently from
     scratch (own partition, plan, jitted step fns) at the same device
     count — batching, slot padding, queueing and threading must be
     arithmetically invisible;
  2. streamed R-rank predictions match the single-device stacked reference
     to fp32 tolerance (the paper's 1-rank == R-rank guarantee, extended
     to serving);
  3. a mesh the checkpoint was not trained on is refused BY NAME (both
     hashes in the error), at registration and at submit;
  4. a killed producer thread terminates the engine with an error instead
     of hanging: queued results drain, the stream raises, the engine is
     closed, and later submits are refused.

Adapts to the forced host-device count ({1,2,4} — the CI serve-smoke
job runs 1 and 2); standalone invocations default to 2 devices.  Exit
code 0 = all assertions passed.
"""
import argparse
import os
import tempfile
import time

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=2")

import numpy as np
import jax

from repro.core import (
    GNNConfig, HaloSpec, NMPPlan, NONE, ShardedGraph, box_mesh, init_gnn,
    partition_mesh, gather_node_features, taylor_green_velocity,
)
from repro.core.distributed import shard_graph
from repro.core.partition import scatter_node_outputs
from repro.core.reference import rollout_stacked
from repro.ckpt import checkpoint as ckpt
from repro.launch.mesh import make_mesh
from repro.runtime.engine import (
    EngineConfig, EngineError, InferenceEngine, MeshMismatchError,
)
from repro.train.loop import TrainConfig, mesh_fingerprint_hash, \
    run_fingerprint
from repro.train.rollout import make_rollout_predict_fn

K = 2
DT = 0.05
N_REQ = 6


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--schedule", default="blocking",
                    choices=["blocking", "overlap"])
    ap.add_argument("--partitioner", default="block",
                    choices=["block", "spectral"])
    args = ap.parse_args()
    R = len(jax.devices())
    assert R in (1, 2, 4), f"need 1, 2 or 4 host devices, got {R}"

    sem = box_mesh((4, 4, 2), p=2)
    cfg = GNNConfig(hidden=8, n_mp_layers=2, mlp_hidden_layers=2)
    params = init_gnn(jax.random.PRNGKey(0), cfg)

    def snapshot_fn(step: int):
        return taylor_green_velocity(
            sem.coords, t=(step * DT) % 2.0).astype(np.float32)

    with tempfile.TemporaryDirectory() as d:
        ckdir = os.path.join(d, "ck")
        fp = run_fingerprint(
            sem, partition_mesh(sem, (1, 1, 1)), cfg,
            TrainConfig(partitioner=args.partitioner), NMPPlan())
        ckpt.save(ckdir, 0, {"params": params}, extra={"fingerprint": fp})

        engine = InferenceEngine(
            ckdir, cfg,
            EngineConfig(batch_slots=3, rollout_steps=K,
                         partitioner=args.partitioner),
            plan=NMPPlan(schedule=args.schedule))
        mesh_hash = engine.register_mesh(sem)
        engine.warmup()
        engine.start()
        streamed = dict(engine.stream(mesh_hash, snapshot_fn, N_REQ,
                                      n_producers=2))
        assert len(streamed) == N_REQ, sorted(streamed)
        print(f"streamed {N_REQ} requests on R={R} "
              f"(schedule={args.schedule}, partitioner={args.partitioner}, "
              f"steps {sorted(streamed)})")

        # ---- 1. bitwise vs an independently built offline rollout eval ----
        pg = partition_mesh(sem, (R, 1, 1), method=args.partitioner)
        plan = NMPPlan.build(pg, "a2a" if R > 1 else "none", axis="graph",
                             schedule=args.schedule)
        graph = ShardedGraph.build(pg, sem.coords, plan)
        mesh_dev = make_mesh((1, R), ("data", "graph"))
        predict = make_rollout_predict_fn(mesh_dev, cfg, plan, K)
        gs = shard_graph(mesh_dev, graph)
        for step, res in streamed.items():
            xs = gather_node_features(pg, snapshot_fn(step))[None]
            preds = np.asarray(predict(params, xs, gs))[0]
            offline = np.stack([scatter_node_outputs(pg, preds[k])
                                for k in range(K)])
            assert np.array_equal(offline, res.preds), \
                f"step {step}: streamed output not bitwise-equal offline eval"
        print(f"bitwise vs offline rollout eval: OK ({N_REQ} requests)")

        # ---- 2. fp32-consistent vs the 1-rank stacked reference ----
        pg1 = partition_mesh(sem, (1, 1, 1))
        plan1 = NMPPlan(halo=HaloSpec(mode=NONE), schedule=args.schedule)
        graph1 = ShardedGraph.build(pg1, sem.coords, plan1)
        for step in sorted(streamed)[:2]:
            x1 = gather_node_features(pg1, snapshot_fn(step))
            t1 = np.zeros((K,) + x1.shape, np.float32)
            _, preds1 = rollout_stacked(params, x1, t1, graph1, plan1,
                                        cfg.node_out)
            ref = np.stack([scatter_node_outputs(pg1, np.asarray(preds1[k]))
                            for k in range(K)])
            np.testing.assert_allclose(streamed[step].preds, ref,
                                       rtol=3e-4, atol=1e-5)
        print("fp32-consistent vs 1-rank stacked reference: OK")

        # ---- 3. mesh mismatch refused by name ----
        other = box_mesh((3, 3, 2), p=2)
        other_hash = mesh_fingerprint_hash(other)
        try:
            engine.register_mesh(other)
            raise AssertionError("mismatched mesh was accepted")
        except MeshMismatchError as e:
            assert fp["mesh_hash"] in str(e) and other_hash in str(e), str(e)
        try:
            engine.submit(other_hash, snapshot_fn(0))
            raise AssertionError("mismatched submit was accepted")
        except MeshMismatchError:
            pass
        print("mesh mismatch refused by name: OK")

        # ---- 4. killed producer terminates the engine, no hang ----
        def dying(step: int):
            if step >= 2:
                raise RuntimeError("injected producer death")
            return snapshot_fn(step)

        got = []
        t0 = time.monotonic()
        try:
            for step, _ in engine.stream(mesh_hash, dying, N_REQ,
                                         n_producers=1):
                got.append(step)
            raise AssertionError("stream survived a dead producer")
        except EngineError as e:
            assert "producer" in str(e), str(e)
        elapsed = time.monotonic() - t0
        assert elapsed < 60, f"producer death took {elapsed:.0f}s to surface"
        assert got == [0, 1], \
            f"drain-then-raise violated: yielded {got} before the error"
        assert engine.closed, "engine left half-alive after producer death"
        try:
            engine.submit(mesh_hash, snapshot_fn(0))
            raise AssertionError("submit accepted after terminal failure")
        except EngineError:
            pass
        print(f"killed producer terminated the engine in {elapsed:.1f}s "
              "(drained [0, 1] first): OK")

    print("SERVE DRIVER PASS")


if __name__ == "__main__":
    main()
