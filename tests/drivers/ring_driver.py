"""Ring attention driver (subprocess, 8 host devices): exactness vs the
single-device blocked oracle, incl. causal + sliding-window + GQA."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax.numpy as jnp

from repro.launch.mesh import make_mesh
from repro.models.transformer.attention import blocked_attention
from repro.models.transformer.ring_attention import ring_attention


def main():
    mesh = make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    for (B, S, Hq, Hkv, D, caus, win) in [
        (2, 64, 4, 2, 16, True, 0),
        (2, 128, 4, 4, 32, True, 24),
        (4, 64, 2, 1, 16, False, 0),
    ]:
        q = jnp.asarray(rng.normal(size=(B, S, Hq, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
        out = ring_attention(q, k, v, mesh, ("data",), scale=D ** -0.5,
                             causal=caus, window=win)
        ref = blocked_attention(q, k, v, scale=D ** -0.5, causal=caus,
                                window=win, q_block=32, kv_block=32)
        err = float(jnp.abs(out - ref).max())
        print(f"S={S} Hq/Hkv={Hq}/{Hkv} causal={caus} win={win}: err {err:.2e}")
        assert err < 3e-5, err
    print("RING DRIVER PASS")


if __name__ == "__main__":
    main()
