"""Two-level (2-axis) halo driver (subprocess): sub-graphs spread over BOTH
mesh axes (a (2, n_dev/2) grid), halo exchange routed as chained ppermute
hops.  Loss must equal the un-partitioned R=1 value (Eq. 2 across two mesh
axes).

Respects an externally-forced device count (2, 4 or 8 — the CI
consistency-matrix job); standalone invocations default to 4.  ``--schedule
overlap`` additionally checks the overlap schedule against blocking (values
and grads); ``--schedule blocking`` skips that half for matrix jobs that
only exercise the blocking path."""
import argparse
import os
os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import (
    GNNConfig, HaloSpec, NEIGHBOR, NMPPlan, NONE, ShardedGraph, box_mesh,
    init_gnn,
)
from repro.core.gnn import gnn_forward
from repro.core.partition import (
    build_2d_halo_rounds, from_element_partition, pack, partition_elements,
    partition_mesh, gather_node_features,
)
from repro.core.reference import loss_and_grad_stacked
from repro.core.mesh_gen import taylor_green_velocity
from repro.launch.mesh import make_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--schedule", default="overlap",
                    choices=["blocking", "overlap"],
                    help="'overlap' additionally verifies the overlap "
                         "schedule against blocking")
    args = ap.parse_args()
    n_dev = len(jax.devices())
    assert n_dev in (2, 4, 8), f"need 2, 4 or 8 host devices, got {n_dev}"

    sem = box_mesh((4, 4, 2), p=2)
    cfg = GNNConfig.small()
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    vel = taylor_green_velocity(sem.coords)

    # ---- R=1 reference ----
    pg1 = partition_mesh(sem, (1, 1, 1))
    plan1 = NMPPlan(halo=HaloSpec(mode=NONE))
    graph1 = ShardedGraph.build(pg1, sem.coords, plan1)
    x1 = jnp.asarray(gather_node_features(pg1, vel))
    l_ref, _, _ = loss_and_grad_stacked(params, x1, x1, graph1, plan1,
                                        cfg.node_out)
    l_ref = float(l_ref)

    # ---- (Ga, Gb) grid partition over ('data','model') ----
    Ga, Gb = 2, n_dev // 2
    e2r = partition_elements(sem, (Gb, Ga, 1))     # rank = a*Gb + b (y-major)
    graphs = from_element_partition(sem, e2r, Ga * Gb)
    pg = pack(graphs, sem.n_nodes)
    rounds2d, nbr = build_2d_halo_rounds(graphs, (Ga, Gb), ("data", "model"))
    spec = HaloSpec(mode=NEIGHBOR, rounds2d=rounds2d)

    def plan_for(schedule):
        return NMPPlan(halo=spec, schedule=schedule)

    # an overlap-capable graph also serves the blocking schedule
    graph = ShardedGraph.build(pg, sem.coords, plan_for("overlap"))
    graph = graph.with_arrays(**{k: jnp.asarray(v) for k, v in nbr.items()})
    x = jnp.asarray(gather_node_features(pg, vel))

    # reshape rank axis -> (Ga, Gb) so each device owns one sub-graph
    def regrid(v):
        return v.reshape((Ga, Gb) + v.shape[1:])

    graph_g = jax.tree.map(regrid, graph)
    x_g = regrid(x)

    mesh = make_mesh((Ga, Gb), ("data", "model"))

    def make_loss(schedule):
        plan = plan_for(schedule)

        def local(params, xg, gg):
            g = jax.tree.map(lambda v: v[0, 0], gg)
            y = gnn_forward(params, xg[0, 0], g, plan)
            err2 = jnp.sum((y - xg[0, 0]) ** 2, axis=-1)
            s = jnp.sum(err2 * g["node_inv_mult"])
            n = jnp.sum(g["node_inv_mult"])
            return (jax.lax.psum(s, ("data", "model"))
                    / (jax.lax.psum(n, ("data", "model")) * cfg.node_out))
        return local

    graph_specs = graph_g.specs(("data", "model"))

    def run_loss(schedule, params_):
        return jax.shard_map(
            make_loss(schedule), mesh=mesh,
            in_specs=(P(), P("data", "model", None, None), graph_specs),
            out_specs=P(), check_vma=False,
        )(params_, x_g, graph_g)

    # one compile serves both the R=1 comparison and the schedule check
    l_b, g_b = jax.jit(jax.value_and_grad(lambda p: run_loss("blocking", p)))(params)
    loss = float(l_b)
    print(f"R=1 loss {l_ref:.8f} | 2-level ({Ga}x{Gb} over data x model) "
          f"{loss:.8f} | dev {abs(loss - l_ref):.2e}")
    assert abs(loss - l_ref) < 2e-6 * max(1.0, abs(l_ref))

    if args.schedule == "overlap":
        # ---- overlap schedule over the two-level rounds2d halo: the chained
        # ppermute hops run on the boundary partial aggregate only; values AND
        # parameter gradients must match the blocking schedule ----
        l_o, g_o = jax.jit(jax.value_and_grad(lambda p: run_loss("overlap", p)))(params)
        assert abs(float(l_o) - float(l_b)) < 1e-6 * max(1.0, abs(float(l_b)))
        for a, b in zip(jax.tree.leaves(g_b), jax.tree.leaves(g_o)):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       rtol=2e-3, atol=2e-4)
        print(f"overlap schedule over rounds2d: loss {float(l_o):.8f} "
              f"(matches blocking, grads to fp32 tolerance)")

    # sanity: without the halo the 2x2 partition must deviate
    plan_none = NMPPlan(halo=HaloSpec(mode=NONE))

    def local_none(params, xg, gg):
        g = jax.tree.map(lambda v: v[0, 0], gg)
        y = gnn_forward(params, xg[0, 0], g, plan_none)
        err2 = jnp.sum((y - xg[0, 0]) ** 2, axis=-1)
        s = jnp.sum(err2 * g["node_inv_mult"])
        n = jnp.sum(g["node_inv_mult"])
        return (jax.lax.psum(s, ("data", "model"))
                / (jax.lax.psum(n, ("data", "model")) * cfg.node_out))

    loss_none = float(jax.jit(jax.shard_map(
        local_none, mesh=mesh,
        in_specs=(P(), P("data", "model", None, None), graph_specs),
        out_specs=P(), check_vma=False,
    ))(params, x_g, graph_g))
    assert abs(loss_none - l_ref) > 1e-6, "inconsistent mode should deviate"
    print(f"without halo: {loss_none:.8f} (deviates, as expected)")
    print("HALO2D DRIVER PASS")


if __name__ == "__main__":
    main()
