"""Multilevel V-cycle driver (subprocess, real collectives).

Runs the consistent multilevel GNN through the production shard_map path —
per-level halo ppermute/all_to_all rounds plus the halo-summed restriction /
prolongation transfers — and asserts 1-rank == R-rank for values and
parameter gradients against the single-device stacked reference.

Adapts to however many host devices the caller forces: the CI
``consistency-matrix`` job runs it under
``XLA_FLAGS=--xla_force_host_platform_device_count={2,4}`` for both
halo/compute schedules (``--schedule``); ``--partitioner spectral`` routes
the level-0 decomposition (and the majority-vote element ownership the
coarse levels derive from it) through spectral bisection instead of block
element grids.  Standalone invocations default to 4 devices.  Exit code
0 = all assertions passed.
"""
import argparse
import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    A2A, NEIGHBOR, NONE, GNNConfig, HaloSpec, NMPPlan, ShardedGraph,
    box_mesh, build_hierarchy, gather_node_features, init_gnn,
    mesh_node2part, taylor_green_velocity,
)
from repro.core.distributed import make_gnn_step_fns, shard_inputs
from repro.core.reference import loss_and_grad_stacked
from repro.launch.mesh import make_mesh

N_LEVELS = 3
GRIDS = {2: [(2, 1, 1)], 4: [(4, 1, 1), (2, 2, 1)], 8: [(4, 2, 1)]}


def run_case(sem, cfg, params, x_global, rank_grid, mode, schedule,
             partitioner="block"):
    R = int(np.prod(rank_grid))
    node2part = (mesh_node2part(sem, R) if partitioner == "spectral"
                 else None)
    ml = build_hierarchy(sem, rank_grid, N_LEVELS, node2part=node2part)
    pg = ml.levels[0]
    plan = NMPPlan.build(ml, mode, axis="graph", schedule=schedule)
    graph = ShardedGraph.build(pg, sem.coords, plan, hierarchy=ml)
    x = gather_node_features(pg, x_global)[None]          # [B=1, R, N_pad, F]
    mesh_dev = make_mesh((1, R), ("data", "graph"))
    _, _, grad_step, _ = make_gnn_step_fns(mesh_dev, cfg, plan)
    xs, gs = shard_inputs(mesh_dev, jnp.asarray(x), graph)
    loss, grads = grad_step(params, xs, xs, gs)
    return float(loss), jax.tree.map(np.asarray, grads)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--schedule", default="blocking",
                    choices=["blocking", "overlap"])
    ap.add_argument("--partitioner", default="block",
                    choices=["block", "spectral"])
    args = ap.parse_args()
    n_dev = len(jax.devices())
    assert n_dev in GRIDS, f"need 2, 4 or 8 host devices, got {n_dev}"

    sem = box_mesh((4, 4, 2), p=2)
    cfg = GNNConfig(hidden=8, n_mp_layers=1, mlp_hidden_layers=2,
                    n_levels=N_LEVELS, coarse_mp_layers=1)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    x_global = taylor_green_velocity(sem.coords)

    # ---- 1-rank oracle (stacked reference) ----
    ml1 = build_hierarchy(sem, (1, 1, 1), N_LEVELS)
    plan1 = NMPPlan(halo=HaloSpec(mode=NONE), schedule=args.schedule)
    graph1 = ShardedGraph.build(ml1.levels[0], sem.coords, plan1,
                                hierarchy=ml1)
    x1 = jnp.asarray(gather_node_features(ml1.levels[0], x_global))
    l1, _, g1 = loss_and_grad_stacked(params, x1, x1, graph1, plan1,
                                      cfg.node_out)
    l1 = float(l1)
    print(f"R=1 multilevel ({N_LEVELS} levels, {args.schedule}, "
          f"{args.partitioner}) loss {l1:.8f}")

    for rank_grid in GRIDS[n_dev]:
        R = int(np.prod(rank_grid))
        for mode in (A2A, NEIGHBOR):
            loss, grads = run_case(sem, cfg, params, x_global, rank_grid,
                                   mode, args.schedule, args.partitioner)
            dev = abs(loss - l1)
            print(f"R={R} grid={rank_grid} mode={mode:9s} "
                  f"loss={loss:.8f} dev={dev:.2e}")
            assert dev < 2e-6 * max(1.0, abs(l1)), (rank_grid, mode, loss, l1)
            for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(grads)):
                np.testing.assert_allclose(
                    b, np.asarray(a), rtol=2e-3, atol=2e-5,
                    err_msg=f"grad mismatch grid={rank_grid} mode={mode}")

    # without any exchange the partitioned V-cycle must deviate (the
    # restriction halo-sum is load-bearing)
    loss_none, _ = run_case(sem, cfg, params, x_global, GRIDS[n_dev][0],
                            NONE, args.schedule, args.partitioner)
    assert abs(loss_none - l1) > 1e-6, "inconsistent multilevel should deviate"
    print(f"halo none deviates as expected: {loss_none:.8f}")
    print("MULTILEVEL DRIVER PASS")


if __name__ == "__main__":
    main()
