"""Autoregressive rollout driver (subprocess, real collectives).

Runs the jitted K-step rollout (``repro.train.rollout``) through the
production shard_map path — K chained halo-consistent forwards inside one
``lax.scan``, per-step consistent losses, optional pushforward noise — and
asserts 1-rank == R-rank for the rollout loss, the per-step predictions and
the parameter gradients against the single-device stacked reference
(``repro.core.reference.rollout_stacked``), for the schedule selected with
``--schedule`` and the mesh decomposition selected with ``--partitioner``
(block grids or spectral bisection — either must be consistency-neutral).

Adapts to the forced host-device count ({2,4,8} — the CI
consistency-matrix job); standalone invocations default to 4 devices.
Exit code 0 = all assertions passed.
"""
import argparse
import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import (
    A2A, NEIGHBOR, NONE, GNNConfig, HaloSpec, NMPPlan, ShardedGraph,
    box_mesh, init_gnn, partition_mesh, gather_node_features,
    taylor_green_velocity,
)
from repro.core.distributed import shard_inputs
from repro.core.partition import scatter_node_outputs
from repro.core.reference import rollout_stacked
from repro.launch.mesh import make_mesh
from repro.train.rollout import make_rollout_step_fns

K = 3
DT = 0.05
GRIDS = {2: [(2, 1, 1)], 4: [(4, 1, 1), (2, 2, 1)], 8: [(4, 2, 1)]}


def _rel_err(a, b):
    na = float(jnp.sqrt(sum(jnp.sum(jnp.square(x))
                            for x in jax.tree.leaves(a))))
    nd = float(jnp.sqrt(sum(jnp.sum(jnp.square(x - y)) for x, y in
                            zip(jax.tree.leaves(a), jax.tree.leaves(b)))))
    return nd / max(na, 1e-12)


def _sequences(pg, sem):
    x0 = gather_node_features(pg, taylor_green_velocity(sem.coords))
    tgts = np.stack([
        gather_node_features(pg, taylor_green_velocity(sem.coords,
                                                       t=(k + 1) * DT))
        for k in range(K)])
    return jnp.asarray(x0), jnp.asarray(tgts)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--schedule", default="blocking",
                    choices=["blocking", "overlap"])
    ap.add_argument("--partitioner", default="block",
                    choices=["block", "spectral"])
    args = ap.parse_args()
    n_dev = len(jax.devices())
    assert n_dev in GRIDS, f"need 2, 4 or 8 host devices, got {n_dev}"

    sem = box_mesh((4, 4, 2), p=2)
    cfg = GNNConfig(hidden=8, n_mp_layers=2, mlp_hidden_layers=2)
    params = init_gnn(jax.random.PRNGKey(0), cfg)

    # ---- 1-rank oracle ----
    pg1 = partition_mesh(sem, (1, 1, 1))
    plan1 = NMPPlan(halo=HaloSpec(mode=NONE), schedule=args.schedule)
    graph1 = ShardedGraph.build(pg1, sem.coords, plan1)
    x1, t1 = _sequences(pg1, sem)
    (l1, preds1), g1 = jax.value_and_grad(
        lambda p: rollout_stacked(p, x1, t1, graph1, plan1, cfg.node_out),
        has_aux=True)(params)
    l1 = float(l1)
    preds1_g = np.stack([scatter_node_outputs(pg1, np.asarray(preds1[k]))
                         for k in range(K)])
    print(f"R=1 K={K} rollout loss {l1:.8f} "
          f"(schedule={args.schedule}, partitioner={args.partitioner}, "
          f"{n_dev} devices)")

    for rank_grid in GRIDS[n_dev]:
        R = int(np.prod(rank_grid))
        pg = partition_mesh(sem, rank_grid, method=args.partitioner)
        for mode in (A2A, NEIGHBOR):
            plan = NMPPlan.build(pg, mode, axis="graph",
                                 schedule=args.schedule)
            graph = ShardedGraph.build(pg, sem.coords, plan)
            x0, tgts = _sequences(pg, sem)
            mesh_dev = make_mesh((1, R), ("data", "graph"))
            rollout_eval, rollout_grad = make_rollout_step_fns(
                mesh_dev, cfg, plan, K)
            xs, gs = shard_inputs(mesh_dev, x0[None], graph)
            ts = jax.device_put(tgts[None], NamedSharding(
                mesh_dev, P(("data",), None, "graph", None, None)))
            ns, _ = shard_inputs(mesh_dev, jnp.zeros_like(x0)[None], graph)
            loss, grads = rollout_grad(params, xs, ts, ns, gs)
            _, preds = rollout_eval(params, xs, ts, ns, gs)
            dev = abs(float(loss) - l1)
            gerr = _rel_err(g1, grads)
            print(f"R={R} grid={rank_grid} mode={mode:9s} "
                  f"loss={float(loss):.8f} dev={dev:.2e} grad_rel={gerr:.2e}")
            assert dev < 2e-6 * max(1.0, abs(l1)), (rank_grid, mode)
            assert gerr < 5e-4, (rank_grid, mode, gerr)
            preds_g = np.stack([
                scatter_node_outputs(pg, np.asarray(preds[0, k]))
                for k in range(K)])
            np.testing.assert_allclose(preds_g, preds1_g, rtol=3e-4,
                                       atol=1e-5)

    # without the exchange the K-step rollout must deviate (errors compound
    # through the autoregressive feedback, so this is the sharpest test of
    # the halo's necessity)
    rank_grid = GRIDS[n_dev][0]
    R = int(np.prod(rank_grid))
    pg = partition_mesh(sem, rank_grid, method=args.partitioner)
    plan_none = NMPPlan(halo=HaloSpec(mode=NONE), schedule=args.schedule)
    graph = ShardedGraph.build(pg, sem.coords, plan_none)
    x0, tgts = _sequences(pg, sem)
    l_none, _ = rollout_stacked(params, x0, tgts, graph, plan_none,
                                cfg.node_out)
    assert abs(float(l_none) - l1) > 1e-6, "inconsistent rollout should deviate"
    print(f"halo none deviates as expected: {float(l_none):.8f}")
    print("ROLLOUT DRIVER PASS")


if __name__ == "__main__":
    main()
