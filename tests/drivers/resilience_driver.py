"""Elastic fault-tolerance driver: kill/resume across REAL process boundaries.

Unlike the in-process tests (tests/test_resilience.py), every training run
here is a fresh subprocess with its own forced host-device count, so a
"dropped rank" is a real ``os._exit`` mid-run (async checkpoint thread dies
in flight, no cleanup) and a resume is a cold process that must rebuild the
partition — possibly for a DIFFERENT rank count or partitioner — and
restore from disk.  On real collectives (shard_map over a
('data','graph') mesh) the orchestrator asserts:

  1. same-R kill -> resume reproduces the uninterrupted run's loss
     trajectory BITWISE (XLA CPU is deterministic; the restored
     params/opt/rng are byte-identical and batches replay by step);
  2. elastic R -> R' resume (and a partitioner switch, block <-> spectral):
     the restored history prefix is bitwise and the post-resume trajectory
     continues within Eq. 2/3 float32 consistency tolerance — the partition
     is arithmetically invisible, only summation order changes;
  3. a crash INSIDE the checkpoint save (no COMMIT written) is recovered
     in-process: the half-written step is skipped, restore falls back to
     the previous committed step, and the final trajectory is still bitwise;
  4. a committed shard corrupted after the fact is detected by checksum and
     restore falls back to the previous committed step (bitwise trajectory).

Respects an externally-forced ``XLA_FLAGS=--xla_force_host_platform_
device_count={2,4}`` (the CI consistency-matrix resilience leg) as the rank
budget R; resumes use R' = R // 2.  Standalone invocations default to 4.
``--partitioner`` selects the decomposition of the killed run; the elastic
resume deliberately uses the OTHER partitioner.

Exit code 0 = all assertions passed.
"""
import argparse
import json
import os
import re
import subprocess
import sys
import tempfile
from pathlib import Path

GRIDS = {1: (1, 1, 1), 2: (2, 1, 1), 4: (2, 2, 1), 8: (4, 2, 1)}
KILL_EXIT = 17
STEPS = 12
EVERY = 3
KILL_AT = 8
# post-resume tolerance for a repartitioned trajectory: per-step float32
# summation reorder is ~1e-7 relative (see consistency_driver), with a few
# optimizer steps of compounding on top
ELASTIC_RTOL = 1e-4


def build_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--schedule", default="blocking",
                    choices=["blocking", "overlap"])
    ap.add_argument("--partitioner", default="block",
                    choices=["block", "spectral"])
    # worker mode (one training run in this process)
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--ranks", type=int, default=None)
    ap.add_argument("--steps", type=int, default=STEPS)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=EVERY)
    ap.add_argument("--kill-at", type=int, default=None)
    ap.add_argument("--crash-save-at", type=int, default=None)
    ap.add_argument("--save-stage", default="pre_commit",
                    choices=["pre_commit", "truncate_shard"])
    ap.add_argument("--out", default=None)
    return ap


def run_worker(args):
    # must precede the jax import: each worker forces its own device count
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.ranks}")
    from repro.core import GNNConfig, NMPPlan, box_mesh, partition_mesh
    from repro.ckpt import checkpoint as ckpt
    from repro.launch.mesh import make_mesh
    from repro.runtime.fault_tolerance import FaultPlan, ResilientConfig
    from repro.train.loop import TrainConfig, train_consistent_gnn

    sem = box_mesh((2, 2, 2), p=2)
    pg = partition_mesh(sem, GRIDS[args.ranks], method=args.partitioner)
    mesh_dev = make_mesh((1, args.ranks), ("data", "graph"))
    cfg = GNNConfig(hidden=8, n_mp_layers=2)
    rc = ResilientConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                         max_restarts=3, backoff_base=0.01)
    tcfg = TrainConfig(n_steps=args.steps, batch=1, lr=1e-3,
                       halo_mode="neighbor", seed=0,
                       plan=NMPPlan(schedule=args.schedule),
                       partitioner=args.partitioner, resilience=rc)
    fault = None
    if args.kill_at is not None:
        fault = FaultPlan(kill_process_at_step=args.kill_at,
                          exit_code=KILL_EXIT)
    elif args.crash_save_at is not None:
        fault = FaultPlan(crash_save_at_step=args.crash_save_at,
                          save_stage=args.save_stage)
    hist = train_consistent_gnn(mesh_dev, pg, sem, cfg, tcfg, fault=fault)
    out = {"losses": hist["losses"], "restarts": hist["restarts"],
           "resume_steps": hist["resume_steps"], "elastic": hist["elastic"],
           "latest_step": ckpt.latest_step(args.ckpt_dir)}
    Path(args.out).write_text(json.dumps(out))
    print(f"worker R={args.ranks} partitioner={args.partitioner} done: "
          f"{len(hist['losses'])} losses, restarts={hist['restarts']}")


def spawn(workdir, tag, ranks, partitioner, schedule, ckpt_dir, *,
          kill_at=None, crash_save_at=None, save_stage="pre_commit",
          expect_rc=0):
    out = Path(workdir) / f"{tag}.json"
    cmd = [sys.executable, os.path.abspath(__file__), "--worker",
           "--ranks", str(ranks), "--partitioner", partitioner,
           "--schedule", schedule, "--ckpt-dir", str(ckpt_dir),
           "--out", str(out)]
    if kill_at is not None:
        cmd += ["--kill-at", str(kill_at)]
    if crash_save_at is not None:
        cmd += ["--crash-save-at", str(crash_save_at),
                "--save-stage", save_stage]
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=600)
    if r.returncode != expect_rc:
        print(r.stdout)
        print(r.stderr, file=sys.stderr)
        raise AssertionError(
            f"worker {tag}: expected exit {expect_rc}, got {r.returncode}")
    return json.loads(out.read_text()) if expect_rc == 0 else None


def main():
    args = build_parser().parse_args()
    if args.worker:
        return run_worker(args)

    m = re.search(r"--xla_force_host_platform_device_count=(\d+)",
                  os.environ.get("XLA_FLAGS", ""))
    budget = int(m.group(1)) if m else 4
    assert budget in GRIDS, f"need a 1/2/4/8 device budget, got {budget}"
    R, R2 = budget, max(budget // 2, 1)
    other = {"block": "spectral", "spectral": "block"}[args.partitioner]
    print(f"resilience driver: R={R} -> R'={R2}, "
          f"partitioner={args.partitioner} (elastic resume -> {other}), "
          f"schedule={args.schedule}")

    with tempfile.TemporaryDirectory() as wd:
        sp = lambda *a, **k: spawn(wd, *a, schedule=args.schedule, **k)  # noqa: E731

        ref = sp("ref", R, args.partitioner, ckpt_dir=Path(wd) / "dref")
        assert len(ref["losses"]) == STEPS and ref["restarts"] == 0

        # -- 1. same-R hard kill (os._exit mid-run) -> cold-process resume
        d1 = Path(wd) / "d1"
        sp("kill1", R, args.partitioner, ckpt_dir=d1,
           kill_at=KILL_AT, expect_rc=KILL_EXIT)
        r1 = sp("resume1", R, args.partitioner, ckpt_dir=d1)
        assert r1["losses"] == ref["losses"], (
            "same-R resume is not bitwise:\n"
            f"  ref    {ref['losses']}\n  resume {r1['losses']}")
        assert r1["resume_steps"], "resume1 never restored a checkpoint"
        print(f"same-R kill/resume: bitwise over {STEPS} steps "
              f"(resumed from step {r1['resume_steps'][0]})")

        # -- 2. elastic: kill on R ranks, resume on R' with the OTHER
        #       partitioner — prefix bitwise, continuation within tolerance
        d2 = Path(wd) / "d2"
        sp("kill2", R, args.partitioner, ckpt_dir=d2,
           kill_at=KILL_AT, expect_rc=KILL_EXIT)
        r2 = sp("resume2", R2, other, ckpt_dir=d2)
        s = r2["resume_steps"][0]
        assert r2["losses"][:s + 1] == ref["losses"][:s + 1], (
            "restored history prefix is not bitwise")
        for i in range(s + 1, STEPS):
            dev = abs(r2["losses"][i] - ref["losses"][i])
            assert dev <= ELASTIC_RTOL * max(1.0, abs(ref["losses"][i])), (
                f"elastic continuation diverged at step {i}: "
                f"{r2['losses'][i]} vs {ref['losses'][i]} (dev {dev:.2e})")
        if R2 != R:
            el = r2["elastic"]
            assert el and el["from_ranks"] == R and el["to_ranks"] == R2, el
        max_dev = max(abs(a - b) for a, b in
                      zip(r2["losses"][s + 1:], ref["losses"][s + 1:]))
        print(f"elastic R={R}/{args.partitioner} -> R'={R2}/{other}: prefix "
              f"bitwise, continuation max dev {max_dev:.2e} <= {ELASTIC_RTOL}")

        # -- 3. crash INSIDE the async checkpoint save (no COMMIT): the
        #       surfaced save error triggers an in-process restart that
        #       falls back past the half-written step
        r3 = sp("savecrash", R, args.partitioner, ckpt_dir=Path(wd) / "d3",
                crash_save_at=2 * EVERY)
        assert r3["restarts"] >= 1, "save crash never surfaced"
        assert r3["losses"] == ref["losses"], (
            "recovery from a mid-checkpoint crash is not bitwise")
        assert r3["resume_steps"] and r3["resume_steps"][0] < 2 * EVERY, (
            "restore did not fall back past the uncommitted step")
        print(f"mid-checkpoint crash: restarted {r3['restarts']}x, fell back "
              f"to step {r3['resume_steps'][0]}, bitwise trajectory")

        # -- 4. corrupt a COMMITTED shard post-hoc: checksum detects it and
        #       restore falls back to the previous committed step
        d4 = Path(wd) / "d4"
        sp("kill4", R, args.partitioner, ckpt_dir=d4,
           kill_at=KILL_AT, expect_rc=KILL_EXIT)
        from repro.ckpt import checkpoint as ckpt
        from repro.runtime.fault_tolerance import FaultPlan
        newest = ckpt.latest_step(d4)
        assert newest is not None and newest > 0
        FaultPlan.corrupt_shard(d4, newest)
        r4 = sp("resume4", R, args.partitioner, ckpt_dir=d4)
        assert r4["resume_steps"][0] < newest, (
            f"resume used the corrupted step {newest}")
        assert r4["losses"] == ref["losses"], (
            "recovery from a corrupted shard is not bitwise")
        print(f"corrupted shard at step {newest}: fell back to step "
              f"{r4['resume_steps'][0]}, bitwise trajectory")

    print("RESILIENCE DRIVER PASS")


if __name__ == "__main__":
    sys.exit(main())
