"""Multi-device consistency driver (run as a subprocess with host devices).

Verifies on REAL collectives (shard_map over a ('data','graph') mesh):
  Eq. 2 — forward/loss partition invariance vs the R=1 un-partitioned
          baseline, both halo modes (A2A, NEIGHBOR);
  Eq. 3 — gradient consistency vs R=1;
  inconsistent mode (halo None) deviates;
  shard_map path agrees with the single-device stacked reference;
  bf16 wire compression (``HaloSpec.wire_dtype`` -> ``_maybe_compress``)
  stays within bf16 tolerance of the uncompressed exchange.

Respects an externally-forced ``XLA_FLAGS=--xla_force_host_platform_
device_count={2,4,8}`` (the CI consistency-matrix job) and scales the rank
grids to the device count; standalone invocations default to 8 devices.
``--schedule`` selects the halo/compute schedule (the overlap schedule must
reproduce the same losses/grads bit-for-bit-ish); ``--partitioner`` selects
how the mesh is decomposed (block element grids vs spectral bisection) —
partitioning is a pure performance knob under Eq. 2/3, so every assertion
must hold identically for either method.  ``--halo auto`` swaps the fixed
mode matrix for the (halo-mode x wire) autotune leg: the measured tuner
resolves the exchange format on the actual graph (packed Pallas candidates
included, interpreted on CPU hosts) and the resolved plan must still
reproduce the R=1 baseline through the real collectives.

Exit code 0 = all assertions passed.
"""
import argparse
import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    A2A, NEIGHBOR, NONE, GNNConfig, HaloSpec, NMPPlan, ShardedGraph,
    box_mesh, init_gnn, partition_mesh, gather_node_features,
    taylor_green_velocity,
)
from repro.core.distributed import make_gnn_step_fns, shard_inputs
from repro.core.reference import loss_and_grad_stacked

# (rank_grid, data_parallel) cases per forced host-device count
CASES = {
    2: (((2, 1, 1), 1),),
    4: (((2, 1, 1), 2), ((2, 2, 1), 1)),
    8: (((2, 1, 1), 4), ((2, 2, 1), 2), ((4, 2, 1), 1)),
}


def run_case(mesh_dev, pg, sem_mesh, params, cfg, mode, batch=2,
             schedule="blocking", wire_dtype=None, plan=None):
    """Run loss+grad through the shard_map path on a (data, graph) mesh."""
    if plan is None:
        plan = NMPPlan.build(pg, mode, axis="graph", wire_dtype=wire_dtype,
                             schedule=schedule)
    graph = ShardedGraph.build(pg, sem_mesh.coords, plan)
    x_global = gather_node_features(pg, taylor_green_velocity(sem_mesh.coords))
    # batch of identical snapshots (loss must be invariant to B here)
    x = np.broadcast_to(x_global[None], (batch,) + x_global.shape).copy()
    _, _, grad_step, _ = make_gnn_step_fns(mesh_dev, cfg, plan)
    xs, gs = shard_inputs(mesh_dev, jnp.asarray(x), graph)
    loss, grads = grad_step(params, xs, xs, gs)
    return float(loss), jax.tree.map(np.asarray, grads)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--schedule", default="blocking",
                    choices=["blocking", "overlap"])
    ap.add_argument("--partitioner", default="block",
                    choices=["block", "spectral"])
    ap.add_argument("--halo", default="matrix", choices=["matrix", "auto"],
                    help="'matrix' runs the fixed A2A/NEIGHBOR/NONE mode "
                         "sweep; 'auto' instead exercises the (halo-mode x "
                         "wire) autotuner end-to-end — the measured pick is "
                         "resolved on the actual graph and then verified "
                         "against the R=1 baseline on REAL collectives")
    args = ap.parse_args()
    n_dev = len(jax.devices())
    assert n_dev in CASES, f"need 2, 4 or 8 host devices, got {n_dev}"
    sem_mesh = box_mesh((4, 4, 2), p=3)
    cfg = GNNConfig.small()
    params = init_gnn(jax.random.PRNGKey(0), cfg)

    # ---- R=1 baseline (reference path, exact) ----
    pg1 = partition_mesh(sem_mesh, (1, 1, 1))
    plan1 = NMPPlan(halo=HaloSpec(mode=NONE), schedule=args.schedule)
    graph1 = ShardedGraph.build(pg1, sem_mesh.coords, plan1)
    x1 = jnp.asarray(gather_node_features(pg1, taylor_green_velocity(sem_mesh.coords)))
    l1, _, g1 = loss_and_grad_stacked(params, x1, x1, graph1, plan1,
                                      cfg.node_out)
    l1 = float(l1)
    print(f"R=1 loss {l1:.8f} (schedule={args.schedule}, "
          f"partitioner={args.partitioner}, {n_dev} devices)")

    if args.halo == "auto":
        # ---- mode+wire autotune leg: build with halo mode "auto" and a
        # candidate bf16 wire, let the measured tuner resolve the (halo-mode
        # x wire) pair on the actual graph, then push the resolved plan
        # through the REAL shard_map collectives.  interpret=True lets the
        # packed Pallas candidates run (interpreted) on CPU hosts; the
        # consistency bound depends on whether the tuner kept the lossy
        # wire (it may only ever DROP it, never introduce one unasked). ----
        for rank_grid, data_sz in CASES[n_dev]:
            R = int(np.prod(rank_grid))
            pg = partition_mesh(sem_mesh, rank_grid, method=args.partitioner)
            mesh_dev = jax.make_mesh((data_sz, R), ("data", "graph"))
            plan = NMPPlan.build(pg, "auto", axis="graph",
                                 wire_dtype=jnp.bfloat16,
                                 schedule=args.schedule, interpret=True)
            graph = ShardedGraph.build(pg, sem_mesh.coords, plan)
            plan = plan.autotune(graph, measure=True, hidden=cfg.hidden,
                                 iters=3)
            assert plan.halo.mode != "auto", "autotune left mode unresolved"
            loss, grads = run_case(mesh_dev, pg, sem_mesh, params, cfg,
                                   plan.halo.mode, batch=data_sz, plan=plan)
            wire = plan.halo.wire_dtype
            tol = 2e-2 if wire is not None else 1e-6
            pick = (f"{plan.halo.mode}"
                    f"{'-packed' if plan.halo.packed else ''}"
                    f"|{jnp.dtype(wire).name if wire is not None else 'fp32'}")
            print(f"R={R} halo=auto pick={pick} loss={loss:.8f} "
                  f"dev={abs(loss - l1):.2e}")
            assert abs(loss - l1) < tol * max(1.0, abs(l1)), (R, pick, loss, l1)
            gtol = (2e-2, 2e-2) if wire is not None else (1e-3, 2e-6)
            for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(grads)):
                np.testing.assert_allclose(
                    b, np.asarray(a), rtol=gtol[0], atol=gtol[1],
                    err_msg=f"grad mismatch R={R} halo=auto pick={pick}")
        print("CONSISTENCY DRIVER PASS")
        return

    results = {}
    for rank_grid, data_sz in CASES[n_dev]:
        R = int(np.prod(rank_grid))
        pg = partition_mesh(sem_mesh, rank_grid, method=args.partitioner)
        mesh_dev = jax.make_mesh((data_sz, R), ("data", "graph"))
        for mode in (A2A, NEIGHBOR, NONE):
            loss, grads = run_case(mesh_dev, pg, sem_mesh, params, cfg, mode,
                                   batch=data_sz, schedule=args.schedule)
            results[(R, mode)] = (loss, grads)
            print(f"R={R} mode={mode:9s} loss={loss:.8f} dev={abs(loss-l1):.2e}")

    for (R, mode), (loss, grads) in results.items():
        if mode == NONE:
            assert abs(loss - l1) > 1e-6, f"inconsistent R={R} should deviate"
            continue
        assert abs(loss - l1) < 1e-6 * max(1.0, abs(l1)), (R, mode, loss, l1)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(grads)):
            np.testing.assert_allclose(b, np.asarray(a), rtol=1e-3, atol=2e-6,
                                       err_msg=f"grad mismatch R={R} mode={mode}")

    # A2A and NEIGHBOR must agree with each other bit-for-bit-ish
    for rank_grid, _ in CASES[n_dev]:
        R = int(np.prod(rank_grid))
        la, ln = results[(R, A2A)][0], results[(R, NEIGHBOR)][0]
        assert abs(la - ln) < 1e-7, (R, la, ln)

    # ---- bf16 wire compression through the REAL collectives: the
    # _maybe_compress path quantizes the on-wire halo buffers; the loss must
    # stay within bf16 tolerance of the uncompressed run and must not be
    # bitwise identical (the compression actually engaged) ----
    rank_grid, data_sz = CASES[n_dev][-1]
    R = int(np.prod(rank_grid))
    pg = partition_mesh(sem_mesh, rank_grid, method=args.partitioner)
    mesh_dev = jax.make_mesh((data_sz, R), ("data", "graph"))
    l_comp, _ = run_case(mesh_dev, pg, sem_mesh, params, cfg, NEIGHBOR,
                         batch=data_sz, schedule=args.schedule,
                         wire_dtype=jnp.bfloat16)
    l_full = results[(R, NEIGHBOR)][0]
    assert abs(l_comp - l_full) < 2e-2 * max(1.0, abs(l_full)), (l_comp, l_full)
    assert l_comp != l_full, "bf16 wire compression did not engage"
    print(f"bf16 wire compression: loss {l_comp:.8f} "
          f"(dev {abs(l_comp - l_full):.2e} from fp32 wire, within tolerance)")

    print("CONSISTENCY DRIVER PASS")


if __name__ == "__main__":
    sys.exit(main())
