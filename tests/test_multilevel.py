"""Multilevel (coarse-grid) message passing: hierarchy construction and the
paper-grade consistency guarantee extended to the V-cycle (ISSUE 4).

The load-bearing assertion: ``multilevel_vcycle`` on 1 rank matches the
4-partition 1D-slab and 2x2-pencil runs — values AND parameter gradients —
for both NMP backends (xla / fused-Pallas-interpret) and both halo/compute
schedules (blocking / overlap).  The restriction/prolongation halo-sums are
what make this hold; ``test_restriction_without_halo_sum_deviates`` pins
that they are load-bearing.
"""
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (
    A2A, NONE, GNNConfig, HaloSpec, NMPPlan, ShardedGraph, box_mesh,
    build_hierarchy, gather_node_features, init_gnn, taylor_green_velocity,
)
from repro.core.partition import scatter_node_outputs
from repro.core.reference import loss_and_grad_stacked


_HIERARCHIES = {}


def _hierarchy(elements, p, grid, n_levels=3):
    """Hierarchies are memoized per (mesh, grid) — the host-side build and
    its cached layouts/splits are reused across the backend x schedule
    parametrization, like production reuses one partition per run."""
    key = (elements, p, grid, n_levels)
    if key not in _HIERARCHIES:
        _HIERARCHIES[key] = build_hierarchy(box_mesh(elements, p=p), grid,
                                            n_levels)
    return _HIERARCHIES[key]


def _case(elements=(4, 4, 2), p=2, n_levels=3, seed=0):
    mesh = box_mesh(elements, p=p)
    cfg = GNNConfig(hidden=8, n_mp_layers=1, mlp_hidden_layers=2,
                    n_levels=n_levels, coarse_mp_layers=1)
    params = init_gnn(jax.random.PRNGKey(seed), cfg)
    x_global = taylor_green_velocity(mesh.coords)
    return mesh, cfg, params, x_global


def _eval(mesh, cfg, params, x_global, grid, mode, *, backend="xla",
          schedule="blocking", n_levels=3):
    ml = _hierarchy(mesh.nelem_axes, mesh.p, grid, n_levels)
    plan = NMPPlan.build(ml, mode, backend=backend,
                         interpret=backend == "fused", block_n=16, block_e=32,
                         schedule=schedule)
    graph = ShardedGraph.build(ml.levels[0], ml.coords[0], plan, hierarchy=ml)
    x = jnp.asarray(gather_node_features(ml.levels[0], x_global))
    loss, y, grads = loss_and_grad_stacked(params, x, x, graph, plan,
                                           cfg.node_out)
    return float(loss), scatter_node_outputs(ml.levels[0], np.asarray(y)), grads


# ---------------------------------------------------------------------------
# hierarchy construction
# ---------------------------------------------------------------------------

def test_hierarchy_shapes_and_weights():
    mesh = box_mesh((4, 4, 2), p=2)
    ml = build_hierarchy(mesh, (2, 2, 1), 3)
    assert ml.level_sizes() == [mesh.n_nodes, mesh.n_elem, 4]  # (2,2,1) blocks
    assert len(ml.transfers) == 2
    for lvl, t in enumerate(ml.transfers, start=1):
        # restriction weights sum to 1 per coarse node (mean over children),
        # prolongation weights to 1 per fine node (mean over parents) —
        # summed over ALL ranks because each transfer edge lives on exactly one
        pg_c, pg_f = ml.levels[lvl], ml.levels[lvl - 1]
        r_sum = np.zeros(pg_c.n_global)
        p_sum = np.zeros(pg_f.n_global)
        for r in range(pg_c.R):
            mask = t.r_w[r] > 0
            np.add.at(r_sum, pg_c.global_ids[r][t.coarse_idx[r][mask]],
                      t.r_w[r][mask])
            np.add.at(p_sum, pg_f.global_ids[r][t.fine_idx[r][mask]],
                      t.p_w[r][mask])
        np.testing.assert_allclose(r_sum, 1.0, atol=1e-6)
        np.testing.assert_allclose(p_sum, 1.0, atol=1e-6)


def test_hierarchy_coarse_nodes_live_with_children():
    """Level-1 primary copies reuse the element partition: every rank's
    transfer edges reference only rank-local endpoints (no -1 paddings)."""
    mesh = box_mesh((4, 4, 2), p=2)
    ml = build_hierarchy(mesh, (2, 2, 1), 2)
    t = ml.transfers[0]
    pg_f, pg_c = ml.levels[0], ml.levels[1]
    for r in range(pg_f.R):
        mask = t.r_w[r] > 0
        assert np.all(pg_f.node_mask[r][t.fine_idx[r][mask]] > 0)
        assert np.all(pg_c.node_mask[r][t.coarse_idx[r][mask]] > 0)
    # centroids: level-1 coords are the element GLL-node means
    e0 = ml.coords[1][0]
    np.testing.assert_allclose(e0, mesh.coords[mesh.elem_nodes[0]].mean(0),
                               atol=1e-12)


def test_hierarchy_coarse_edges_are_element_adjacency():
    """Level-1 edges connect exactly the element pairs sharing a GLL node."""
    mesh = box_mesh((2, 2), p=1)
    ml = build_hierarchy(mesh, (1, 1), 2)
    pg = ml.levels[1]
    got = set()
    for i in range(pg.e_pad):
        if pg.edge_mask[0, i] > 0:
            got.add((int(pg.global_ids[0, pg.edge_src[0, i]]),
                     int(pg.global_ids[0, pg.edge_dst[0, i]])))
    expect = set()
    for a in range(mesh.n_elem):
        for b in range(mesh.n_elem):
            if a != b and np.intersect1d(mesh.elem_nodes[a],
                                         mesh.elem_nodes[b]).size:
                expect.add((a, b))
    assert got == expect


def test_hierarchy_rejects_zero_levels():
    mesh = box_mesh((2, 2), p=1)
    with pytest.raises(ValueError, match="n_levels"):
        build_hierarchy(mesh, (1, 1), 0)


# ---------------------------------------------------------------------------
# the consistency guarantee, backend x schedule
# ---------------------------------------------------------------------------

_BASELINES = {}


def _baseline(backend):
    """The 1-rank V-cycle run, computed once per backend (the blocking and
    overlap schedules are arithmetically identical, so both compare against
    the same oracle)."""
    if backend not in _BASELINES:
        mesh, cfg, params, x_global = _FIXED[backend]
        _BASELINES[backend] = _eval(
            mesh, cfg, params, x_global, (1, 1, 1), NONE, backend=backend,
            n_levels=cfg.n_levels)
    return _BASELINES[backend]


def _fused_case():
    # every Pallas call runs through the interpreter (~seconds per kernel
    # invocation), so the fused cases shrink what the xla cases keep big:
    # p=1 mesh, 2 levels, 1-hidden-layer MLPs — the partition/halo/transfer
    # structure exercised is identical
    mesh = box_mesh((4, 2, 2), p=1)
    cfg = GNNConfig(hidden=8, n_mp_layers=1, mlp_hidden_layers=1,
                    n_levels=2, coarse_mp_layers=1)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    return mesh, cfg, params, taylor_green_velocity(mesh.coords)


_FIXED = {
    "xla": _case(),
    "fused": _fused_case(),
}


@pytest.mark.parametrize("backend,schedule", [
    ("xla", "blocking"), ("xla", "overlap"),
    ("fused", "blocking"), ("fused", "overlap"),
])
def test_multilevel_consistency(backend, schedule):
    """V-cycle on 1 rank == 4-partition 1D slabs == 2x2 pencils (fp32
    tolerance, values + grads), for both NMP backends (fused = the Pallas
    kernels in interpret mode, running the production path with each coarse
    level's own cached compact layout) and both halo/compute schedules."""
    mesh, cfg, params, x_global = _FIXED[backend]
    l1, y1, g1 = _baseline(backend)
    for grid in ((4, 1, 1), (2, 2, 1)):
        l, y, g = _eval(mesh, cfg, params, x_global, grid, A2A,
                        backend=backend, schedule=schedule,
                        n_levels=cfg.n_levels)
        assert abs(l - l1) < 2e-6 * max(1.0, abs(l1)), (grid, l, l1)
        np.testing.assert_allclose(y, y1, rtol=3e-5, atol=5e-6)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g)):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       rtol=2e-3, atol=2e-5)


def test_multilevel_fused_matches_xla():
    """Backend swap preserves the multilevel arithmetic on a partitioned
    hierarchy (values + grads to fp32 tolerance)."""
    mesh, cfg, params, x_global = _FIXED["fused"]
    l_x, y_x, g_x = _eval(mesh, cfg, params, x_global, (2, 2, 1), A2A,
                          n_levels=cfg.n_levels)
    l_f, y_f, g_f = _eval(mesh, cfg, params, x_global, (2, 2, 1), A2A,
                          backend="fused", n_levels=cfg.n_levels)
    assert abs(l_f - l_x) < 1e-6 * max(1.0, abs(l_x))
    np.testing.assert_allclose(y_f, y_x, rtol=1e-4, atol=1e-5)
    for a, b in zip(jax.tree.leaves(g_x), jax.tree.leaves(g_f)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=2e-3, atol=2e-5)


def test_restriction_without_halo_sum_deviates():
    """The halo-sum on the restriction aggregate is load-bearing: skipping
    every exchange (halo mode 'none') on a partitioned hierarchy must NOT
    reproduce the 1-rank V-cycle."""
    mesh, cfg, params, x_global = _case()
    l1, _, _ = _eval(mesh, cfg, params, x_global, (1, 1, 1), NONE)
    l4, _, _ = _eval(mesh, cfg, params, x_global, (2, 2, 1), NONE)
    assert abs(l4 - l1) > 1e-6


def test_multilevel_requires_coarse_graph():
    """Clear error when multilevel params meet a single-level graph."""
    mesh, cfg, params, x_global = _case()
    ml = build_hierarchy(mesh, (2, 2, 1), 3)
    plan = NMPPlan(halo=HaloSpec(mode=A2A))
    graph = ShardedGraph.build(ml.levels[0], mesh.coords, plan)  # level 0 only
    x = jnp.asarray(gather_node_features(ml.levels[0], x_global))
    with pytest.raises(ValueError, match="multilevel graph"):
        loss_and_grad_stacked(params, x, x, graph, plan, cfg.node_out)


def test_neighbor_mode_requires_per_level_halo_specs():
    """The level-0 NEIGHBOR perms encode the FINE rank adjacency; reusing
    them for coarse levels would be silently inconsistent, so the V-cycle
    refuses rather than falling back."""
    from repro.core import NEIGHBOR, multilevel_vcycle
    from repro.core.halo import halo_spec_from_plan
    mesh, cfg, params, _ = _case()
    ml = _hierarchy(mesh.nelem_axes, mesh.p, (2, 2, 1), 3)
    spec = halo_spec_from_plan(ml.levels[0].halo, NEIGHBOR)
    plan = NMPPlan(halo=spec)                      # no coarse_halos entries
    graph = ShardedGraph.build(ml.levels[0], ml.coords[0], plan, hierarchy=ml)
    h = jnp.zeros((ml.levels[0].n_pad, cfg.hidden))
    with pytest.raises(ValueError, match="one HaloSpec per coarse level"):
        multilevel_vcycle(params["coarse"], h, graph.rank(0), plan)


def test_graph_build_hierarchy_coords_guard():
    """ShardedGraph.build refuses coords that disagree with the hierarchy's
    build-time coordinates (which define every level's edge features)."""
    mesh, _, _, _ = _case()
    ml = _hierarchy(mesh.nelem_axes, mesh.p, (2, 2, 1), 3)
    graph = ShardedGraph.build(ml.levels[0], mesh.coords, hierarchy=ml)
    assert graph.n_levels == 3
    assert "t_fine" in graph.levels[2] and "node_mask" in graph.levels[1]
    with pytest.raises(ValueError, match="hierarchy.coords"):
        ShardedGraph.build(ml.levels[0], mesh.coords + 1.0, hierarchy=ml)


def test_deeper_level_than_blocks_degenerates_gracefully():
    """A hierarchy deeper than the element grid collapses to a single-node
    level (zero coarse edges) and stays consistent."""
    mesh = box_mesh((2, 2, 2), p=2)
    cfg = GNNConfig(hidden=8, n_mp_layers=1, mlp_hidden_layers=2,
                    n_levels=3, coarse_mp_layers=1)
    params = init_gnn(jax.random.PRNGKey(1), cfg)
    x_global = taylor_green_velocity(mesh.coords)
    l1, y1, _ = _eval(mesh, cfg, params, x_global, (1, 1, 1), NONE)
    l2, y2, _ = _eval(mesh, cfg, params, x_global, (2, 1, 1), A2A)
    assert abs(l2 - l1) < 2e-6 * max(1.0, abs(l1))
    np.testing.assert_allclose(y2, y1, rtol=3e-5, atol=2e-6)


def test_vcycle_changes_the_output():
    """Sanity: the coarse path contributes (levels>1 differs from the flat
    model with identical fine params)."""
    mesh, cfg, params, x_global = _case()
    flat = {k: v for k, v in params.items() if k != "coarse"}
    ml = build_hierarchy(mesh, (1, 1, 1), 3)
    plan = NMPPlan(halo=HaloSpec(mode=NONE))
    graph = ShardedGraph.build(ml.levels[0], ml.coords[0], plan, hierarchy=ml)
    x = jnp.asarray(gather_node_features(ml.levels[0], x_global))
    _, y_ml, _ = loss_and_grad_stacked(params, x, x, graph, plan, cfg.node_out)
    _, y_flat, _ = loss_and_grad_stacked(flat, x, x, graph, plan, cfg.node_out)
    assert float(jnp.abs(jnp.asarray(y_ml) - jnp.asarray(y_flat)).max()) > 1e-4


@pytest.mark.slow
def test_multilevel_shard_map_collective_path_subprocess():
    """The V-cycle on REAL collectives (4 host CPU devices): per-level halo
    rounds plus the halo-summed transfers, vs the 1-rank stacked oracle.

    slow-marked: the tier-1 CI job would only duplicate the CI
    consistency-matrix job, which runs this exact driver in 4 cells
    ({2,4} devices x {blocking,overlap}) on every PR; plain ``pytest``
    (the ROADMAP tier-1 verify command) still includes it."""
    driver = os.path.join(os.path.dirname(__file__), "drivers",
                          "multilevel_driver.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, driver], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, \
        f"driver failed:\n{res.stdout[-3000:]}\n{res.stderr[-3000:]}"
    assert "MULTILEVEL DRIVER PASS" in res.stdout
