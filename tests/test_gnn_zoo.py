"""GNN zoo + DLRM smoke/correctness tests (reduced configs, 1 device)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.graph_state import NMPPlan, ShardedGraph
from repro.core.halo import A2A, NONE, HaloSpec
from repro.core.partition import partition_graph, gather_node_features
from repro.graph.datasets import cora_like, molecules, batch_molecules, criteo_like
from repro.models.gnn_zoo.gat import GATConfig, gat_forward, init_gat
from repro.models.gnn_zoo.graphcast import (
    GraphCastConfig, graphcast_forward, icosahedral_mesh, init_graphcast,
)
from repro.models.gnn_zoo.mace import MACEConfig, init_mace, mace_forward
from repro.models.gnn_zoo.nequip import NequIPConfig, init_nequip, nequip_forward
from repro.models.dlrm import DLRMConfig, dlrm_forward, init_dlrm
from repro.sharding import split_tree


def _single_rank_graph(n, edges):
    """rank-local ShardedGraph for an un-partitioned graph on one device."""
    pg = partition_graph(n, edges, 1)
    graph = ShardedGraph.from_arrays(
        {k: jnp.asarray(v) for k, v in pg.device_arrays().items()}).rank(0)
    return graph, pg


@pytest.fixture(scope="module")
def tiny_graph():
    edges, feats, labels = cora_like(seed=0, n=80, m_und=240, d=16, n_classes=3)
    graph, pg = _single_rank_graph(80, edges)
    return graph, pg, feats, labels


def test_gat_forward_and_consistency(tiny_graph):
    graph, _, feats, labels = tiny_graph
    cfg = GATConfig(in_dim=16, hidden=4, heads=2, n_classes=3, n_layers=2)
    params = init_gat(jax.random.PRNGKey(0), cfg)
    n_pad = graph["node_mask"].shape[0]
    x = jnp.zeros((n_pad, 16)).at[:80].set(feats)
    out1 = gat_forward(params, x, graph, HaloSpec(mode=NONE), cfg)
    assert out1.shape == (n_pad, 3)
    assert np.isfinite(np.asarray(out1)).all()

    # partition R=4 and compare with the stacked-reference halo (Eq. 2 for GAT:
    # the consistent distributed softmax must match the un-partitioned run)
    edges, feats4, _ = cora_like(seed=0, n=80, m_und=240, d=16, n_classes=3)
    pg = partition_graph(80, edges, 4)
    meta4 = ShardedGraph.from_arrays(
        {k: jnp.asarray(v) for k, v in pg.device_arrays().items()})
    x4 = jnp.asarray(gather_node_features(pg, feats4))
    spec = HaloSpec(mode=A2A)
    outs = _gat_forward_stacked(params, x4, meta4, spec, cfg)
    from repro.core.partition import scatter_node_outputs
    glob = scatter_node_outputs(pg, np.asarray(outs))
    out1_valid = np.asarray(out1)[:80]
    np.testing.assert_allclose(glob, out1_valid, rtol=2e-4, atol=1e-5)


def _gat_forward_stacked(params, x, meta_stacked, spec, cfg):
    """GAT over all ranks on one device with the reference (gather) halo —
    the same layer math as gat._gat_layer, lockstepped across ranks."""
    for i, p in enumerate(params["layers"]):
        last = i == len(params["layers"]) - 1
        outs = _gat_layer_stacked(p, x, meta_stacked, spec, concat=not last)
        x = outs if last else jax.nn.elu(outs)
    return x


def _gat_layer_stacked(p, x, meta, spec, concat):
    from repro.core.halo import halo_sync_reference
    from repro.graph import segment
    R, n_pad = x.shape[0], x.shape[1]
    h = jnp.einsum("rnd,dhk->rnhk", x, p["w"])
    s_src = jnp.einsum("rnhk,hk->rnh", h, p["a_src"])
    s_dst = jnp.einsum("rnhk,hk->rnh", h, p["a_dst"])
    m_locs, exps, aggs = [], [], []
    for r in range(R):
        sc = jax.nn.leaky_relu(s_src[r][meta["edge_src"][r]] + s_dst[r][meta["edge_dst"][r]], 0.2)
        sc = jnp.where(meta["edge_mask"][r][:, None] > 0, sc, -1e30)
        m_loc = segment.segment_max(sc, meta["edge_dst"][r], n_pad)
        m_loc = jnp.where(meta["node_mask"][r][:, None] > 0, m_loc, -1e30)
        m_locs.append(m_loc)
    m = halo_sync_reference(jnp.stack(m_locs), meta, spec, combine="max")
    dens, aggs = [], []
    for r in range(R):
        sc = jax.nn.leaky_relu(s_src[r][meta["edge_src"][r]] + s_dst[r][meta["edge_dst"][r]], 0.2)
        sc = jnp.where(meta["edge_mask"][r][:, None] > 0, sc, -1e30)
        m_safe = jnp.where(jnp.isfinite(m[r]), m[r], 0.0)
        ex = jnp.exp(sc - m_safe[meta["edge_dst"][r]]) * meta["edge_mask"][r][:, None]
        ex = ex * meta["edge_inv_mult"][r][:, None]
        dens.append(segment.segment_sum(ex, meta["edge_dst"][r], n_pad))
        aggs.append(segment.segment_sum(ex[..., None] * h[r][meta["edge_src"][r]],
                                        meta["edge_dst"][r], n_pad))
    den = halo_sync_reference(jnp.stack(dens), meta, spec, combine="sum")
    agg = jnp.stack(aggs)
    agg = halo_sync_reference(agg.reshape(R, n_pad, -1), meta, spec, combine="sum") \
        .reshape(agg.shape)
    out = agg / jnp.maximum(den, 1e-20)[..., None]
    out = out * meta["node_mask"][..., None, None]
    if concat:
        return out.reshape(R, n_pad, -1)
    return out.mean(axis=2)


def test_graphcast_forward(tiny_graph):
    graph, pg, feats, labels = tiny_graph
    cfg = GraphCastConfig(in_dim=16, hidden=32, n_layers=3, out_dim=4,
                          mlp_hidden_layers=1)
    params = init_graphcast(jax.random.PRNGKey(0), cfg)
    n_pad = graph["node_mask"].shape[0]
    x = jnp.zeros((n_pad, 16)).at[:80].set(feats)
    ef = jnp.ones((graph["edge_src"].shape[0], 4)) * graph["edge_mask"][:, None]
    out = graphcast_forward(params, x, ef, graph,
                            NMPPlan(halo=HaloSpec(mode=NONE)), cfg)
    assert out.shape == (n_pad, 4)
    assert np.isfinite(np.asarray(out)).all()


def test_graphcast_multilevel_vcycle():
    """GraphCast with ``n_levels > 1``: the scanned processor feeds the
    consistent V-cycle; the coarse path contributes to the output and
    receives gradient."""
    from repro.core import HaloSpec as HS, box_mesh, build_hierarchy

    mesh = box_mesh((2, 2, 2), p=2)
    ml = build_hierarchy(mesh, (1, 1, 1), 2)
    plan = NMPPlan(halo=HS(mode=NONE))
    graph = ShardedGraph.build(ml.levels[0], mesh.coords, plan,
                               hierarchy=ml).rank(0)
    cfg = GraphCastConfig(in_dim=3, hidden=16, n_layers=2, out_dim=3,
                          mlp_hidden_layers=1, n_levels=2, coarse_mp_layers=1)
    params = init_graphcast(jax.random.PRNGKey(0), cfg)
    assert len(params["coarse"]) == 1
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(graph["node_mask"].shape[0], 3)).astype(np.float32))
    ef = graph["static_edge_feats"]

    def loss(p):
        y = graphcast_forward(p, x, ef, graph, plan, cfg)
        return jnp.sum(y ** 2)

    l, g = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(l))
    coarse_g = np.concatenate([np.abs(np.asarray(t)).ravel()
                               for t in jax.tree.leaves(g["coarse"])])
    assert coarse_g.max() > 0, "no gradient reached the coarse levels"
    # and the V-cycle changes the output vs the flat model
    flat = {k: v for k, v in params.items() if k != "coarse"}
    y_ml = graphcast_forward(params, x, ef, graph, plan, cfg)
    y_flat = graphcast_forward(flat, x, ef, graph, plan, cfg)
    assert float(jnp.abs(y_ml - y_flat).max()) > 1e-5


def test_icosahedral_mesh_counts():
    v, e = icosahedral_mesh(2)
    assert v.shape[0] == 162          # 10*4^2+2
    # multimesh edges: levels 0..2 unions
    assert e.shape[0] > 30            # at least base edges
    np.testing.assert_allclose(np.linalg.norm(v, axis=1), 1.0, rtol=1e-9)


@pytest.mark.parametrize("model", ["nequip", "mace"])
def test_equivariant_models_invariance(model):
    """Site energies are invariant under global rotation (E(3) symmetry)."""
    species, pos, edge_lists = molecules(batch=2, n_atoms=12, n_species=4, seed=1)
    sp, ps, meta_np = batch_molecules(species, pos, edge_lists, e_pad_per=48)
    meta = {k: jnp.asarray(v) for k, v in meta_np.items()}
    # pad halo keys (no halo)
    for k in ("a2a_send_idx", "a2a_recv_idx"):
        meta[k] = jnp.zeros((1, 8), jnp.int32)
    for k in ("a2a_send_mask", "a2a_recv_mask"):
        meta[k] = jnp.zeros((1, 8), jnp.float32)
    meta = ShardedGraph.from_arrays(meta)

    if model == "nequip":
        cfg = NequIPConfig(n_layers=2, hidden_mul=8, l_max=2, n_rbf=4,
                           cutoff=3.0, n_species=4)
        params = init_nequip(jax.random.PRNGKey(0), cfg)

        def fwd(p, s, x):
            return nequip_forward(p, s, x, meta, HaloSpec(mode=NONE), cfg)
    else:
        cfg = MACEConfig(n_layers=2, hidden_mul=8, l_max=2, correlation=3,
                         n_rbf=4, cutoff=3.0, n_species=4)
        params = init_mace(jax.random.PRNGKey(0), cfg)

        def fwd(p, s, x):
            return mace_forward(p, s, x, meta, HaloSpec(mode=NONE), cfg)

    e1 = fwd(params, jnp.asarray(sp), jnp.asarray(ps))
    assert np.isfinite(np.asarray(e1)).all()
    assert float(jnp.abs(e1).max()) > 0

    from repro.models.gnn_zoo.irreps import _rand_rotations
    R = _rand_rotations(1, seed=5)[0].astype(np.float32)
    e2 = fwd(params, jnp.asarray(sp), jnp.asarray(ps @ R.T))
    np.testing.assert_allclose(np.asarray(e2), np.asarray(e1), rtol=5e-4, atol=1e-5)

    # translation invariance
    e3 = fwd(params, jnp.asarray(sp), jnp.asarray(ps + np.float32([1.3, -0.7, 2.1])))
    np.testing.assert_allclose(np.asarray(e3), np.asarray(e1), rtol=5e-4, atol=1e-5)


def test_equivariant_forces(

):
    """Forces (-dE/dpos) rotate covariantly."""
    species, pos, edge_lists = molecules(batch=1, n_atoms=10, n_species=4, seed=2)
    sp, ps, meta_np = batch_molecules(species, pos, edge_lists, e_pad_per=48)
    meta = ShardedGraph.from_arrays({k: jnp.asarray(v) for k, v in meta_np.items()})
    cfg = NequIPConfig(n_layers=2, hidden_mul=8, l_max=2, n_rbf=4, cutoff=3.0,
                       n_species=4)
    params = init_nequip(jax.random.PRNGKey(0), cfg)

    def energy(x):
        return nequip_forward(params, jnp.asarray(sp), x, meta,
                              HaloSpec(mode=NONE), cfg).sum()

    f1 = -jax.grad(energy)(jnp.asarray(ps))
    from repro.models.gnn_zoo.irreps import _rand_rotations
    R = _rand_rotations(1, seed=9)[0].astype(np.float32)
    f2 = -jax.grad(energy)(jnp.asarray(ps @ R.T))
    np.testing.assert_allclose(np.asarray(f2), np.asarray(f1) @ R.T,
                               rtol=2e-3, atol=1e-5)


def test_dlrm_forward_and_train():
    cfg = DLRMConfig.smoke()
    tree = init_dlrm(jax.random.PRNGKey(0), cfg)
    params, _ = split_tree(tree, {})
    dense, sparse, labels = criteo_like(32, cfg, seed=0)
    logits = dlrm_forward(params, jnp.asarray(dense), jnp.asarray(sparse), cfg)
    assert logits.shape == (32, 1)
    assert np.isfinite(np.asarray(logits)).all()

    def loss_fn(p):
        lg = dlrm_forward(p, jnp.asarray(dense), jnp.asarray(sparse), cfg)
        return ((lg - labels) ** 2).mean()

    g = jax.grad(loss_fn)(params)
    gn = float(jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in jax.tree.leaves(g))))
    assert np.isfinite(gn) and gn > 0


def test_dlrm_sharded_lookup_matches_dense():
    """Row-sharded embedding bag (shard_map + psum) == plain lookup."""
    cfg = DLRMConfig.smoke()
    tree = init_dlrm(jax.random.PRNGKey(0), cfg)
    params, _ = split_tree(tree, {})
    dense, sparse, _ = criteo_like(16, cfg, seed=1)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    y_plain = dlrm_forward(params, jnp.asarray(dense), jnp.asarray(sparse), cfg)
    y_shard = dlrm_forward(params, jnp.asarray(dense), jnp.asarray(sparse), cfg,
                           mesh=mesh, batch_axes=("data",))
    np.testing.assert_allclose(np.asarray(y_shard), np.asarray(y_plain),
                               rtol=1e-5, atol=1e-6)
