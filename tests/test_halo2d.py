"""Two-level (2-axis) halo exchange: subprocess exactness test."""
import os
import subprocess
import sys


def test_two_level_halo_consistency_subprocess():
    driver = os.path.join(os.path.dirname(__file__), "drivers", "halo2d_driver.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, driver], env=env, capture_output=True,
                         text=True, timeout=600)
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    assert "HALO2D DRIVER PASS" in res.stdout
