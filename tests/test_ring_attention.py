"""Ring attention: subprocess exactness test."""
import os
import subprocess
import sys


def test_ring_attention_matches_blocked_subprocess():
    driver = os.path.join(os.path.dirname(__file__), "drivers", "ring_driver.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, driver], env=env, capture_output=True,
                         text=True, timeout=600)
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    assert "RING DRIVER PASS" in res.stdout
