"""Unit tests: SEM mesh generation (GLL points, coincidence structure, graphs)."""
import numpy as np
import pytest

from repro.core.mesh_gen import (
    box_mesh, edge_features, element_lattice_edges, gll_points,
    mesh_graph_edges, taylor_green_velocity, undirected_to_directed,
)


def test_gll_points_basic():
    np.testing.assert_allclose(gll_points(1), [-1.0, 1.0])
    np.testing.assert_allclose(gll_points(2), [-1.0, 0.0, 1.0], atol=1e-12)
    # p=3 GLL interior nodes at +-1/sqrt(5)
    np.testing.assert_allclose(gll_points(3), [-1, -1 / np.sqrt(5), 1 / np.sqrt(5), 1], atol=1e-12)


@pytest.mark.parametrize("p", [1, 2, 3, 5, 7])
def test_gll_points_properties(p):
    x = gll_points(p)
    assert x.shape == (p + 1,)
    assert x[0] == -1.0 and x[-1] == 1.0
    np.testing.assert_allclose(x, -x[::-1], atol=1e-12)  # symmetric
    assert np.all(np.diff(x) > 0)


@pytest.mark.parametrize("nelem,p", [((2, 2), 1), ((3, 2), 3), ((2, 2, 2), 2), ((4, 2, 1), 5)])
def test_box_mesh_counts(nelem, p):
    m = box_mesh(nelem, p)
    # unique nodes = global lattice
    expect = np.prod([n * p + 1 for n in nelem])
    assert m.n_nodes == expect
    assert m.n_elem == np.prod(nelem)
    assert m.elem_nodes.shape == (m.n_elem, (p + 1) ** len(nelem))
    # every element's ids are valid and coords in box
    assert m.elem_nodes.min() >= 0 and m.elem_nodes.max() < m.n_nodes
    assert m.coords.min() >= 0.0 and m.coords.max() <= 1.0


def test_coincident_nodes_shared_between_elements():
    m = box_mesh((2, 1), p=2)
    # elements 0 and 1 share a full edge of 3 lattice points
    shared = np.intersect1d(m.elem_nodes[0], m.elem_nodes[1])
    assert shared.size == 3
    # shared nodes sit on the x = 0.5 plane
    np.testing.assert_allclose(m.coords[shared][:, 0], 0.5, atol=1e-12)


@pytest.mark.parametrize("p,dim", [(1, 2), (3, 2), (1, 3), (3, 3)])
def test_element_lattice_edges_count(p, dim):
    e = element_lattice_edges(p, dim)
    # per axis: p*(p+1)^(dim-1) edges
    assert e.shape == (dim * p * (p + 1) ** (dim - 1), 2)
    assert np.all(e[:, 0] != e[:, 1])


def test_mesh_graph_edges_dedup():
    m = box_mesh((2, 2), p=1)
    e = mesh_graph_edges(m)
    # 3x3 lattice grid graph: 2*3*2 = 12 undirected edges
    assert e.shape == (12, 2)
    assert np.all(e[:, 0] < e[:, 1])
    d = undirected_to_directed(e)
    assert d.shape == (24, 2)


def test_graph_edges_match_lattice_grid():
    """For p>=1 the dedup'd mesh graph equals the global lattice grid graph."""
    for nelem, p in (((2, 2), 2), ((3, 1, 2), 1)):
        m = box_mesh(nelem, p)
        e = mesh_graph_edges(m)
        npts = [n * p + 1 for n in nelem]
        expect = 0
        for ax in range(len(nelem)):
            expect += (npts[ax] - 1) * int(np.prod(npts)) // npts[ax]
        assert e.shape[0] == expect


def test_undirected_to_directed_edge_cases():
    # empty input stays empty with the right shape (rank-local sub-graphs of
    # empty ranks hit this)
    empty = undirected_to_directed(np.zeros((0, 2), dtype=np.int64))
    assert empty.shape == (0, 2)
    # single edge -> both directions, order preserved then reversed
    d = undirected_to_directed(np.array([[3, 7]]))
    np.testing.assert_array_equal(d, [[3, 7], [7, 3]])
    # doubling is exact: every undirected pair appears exactly once per
    # direction, no dedup is performed here (dedup is the caller's contract)
    und = np.array([[0, 1], [0, 1], [1, 2]])
    d = undirected_to_directed(und)
    assert d.shape == (6, 2)
    np.testing.assert_array_equal(d[:3], und)
    np.testing.assert_array_equal(d[3:], und[:, ::-1])


def test_edge_features_edge_cases():
    coords = np.array([[0.0, 0.0], [3.0, 4.0], [1.0, 1.0]])
    # relative position + magnitude, dim+1 columns
    f = edge_features(coords, np.array([[0, 1]]))
    np.testing.assert_allclose(f, [[3.0, 4.0, 5.0]])
    # direction matters: the reversed edge negates the vector, not the norm
    f_rev = edge_features(coords, np.array([[1, 0]]))
    np.testing.assert_allclose(f_rev, [[-3.0, -4.0, 5.0]])
    # self-loop -> zero vector, zero magnitude (no NaN from the norm)
    f_self = edge_features(coords, np.array([[2, 2]]))
    np.testing.assert_allclose(f_self, [[0.0, 0.0, 0.0]])
    assert np.isfinite(f_self).all()
    # empty edge list -> [0, dim+1]
    f_empty = edge_features(coords, np.zeros((0, 2), dtype=np.int64))
    assert f_empty.shape == (0, 3)
    # 3D coords -> 4 columns (the paper's 7-dim init = these + rel velocity)
    c3 = np.array([[0.0, 0.0, 0.0], [1.0, 2.0, 2.0]])
    f3 = edge_features(c3, np.array([[0, 1]]))
    np.testing.assert_allclose(f3, [[1.0, 2.0, 2.0, 3.0]])


def test_taylor_green_divergence_free_sample():
    m = box_mesh((4, 4, 4), p=2)
    v = taylor_green_velocity(m.coords, t=0.0)
    assert v.shape == (m.n_nodes, 3)
    assert np.isfinite(v).all()
    # decay over time
    v2 = taylor_green_velocity(m.coords, t=1.0)
    assert np.linalg.norm(v2) < np.linalg.norm(v)
