"""Pipeline parallelism: bubble math + subprocess equivalence test."""
import os
import subprocess
import sys

from repro.train.pipeline import pipeline_bubble_fraction


def test_bubble_fraction():
    assert pipeline_bubble_fraction(4, 6) == 3 / 9
    assert pipeline_bubble_fraction(1, 8) == 0.0


def test_pipeline_matches_sequential_subprocess():
    driver = os.path.join(os.path.dirname(__file__), "drivers", "pipeline_driver.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, driver], env=env, capture_output=True,
                         text=True, timeout=600)
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    assert "PIPELINE DRIVER PASS" in res.stdout
