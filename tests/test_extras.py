"""Extra coverage: halo wire compression, elastic checkpoint restore,
consistent reductions, sampler block-meta integration."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ckpt
from repro.core import (A2A, GNNConfig, HaloSpec, NMPPlan, ShardedGraph,
                        box_mesh, init_gnn, partition_mesh)
from repro.core.halo import halo_sync_reference
from repro.core.partition import gather_node_features
from repro.core.reference import gnn_forward_stacked
from repro.core.consistent_loss import consistent_node_count, consistent_node_sum


def test_maybe_compress_unit():
    """The halo_sync on-wire compression hook: converts only when a
    wire_dtype is set AND differs from the buffer dtype, always reporting
    the dtype to restore after the collective."""
    from repro.core.halo import _maybe_compress
    buf = jnp.ones((4, 3), jnp.float32)
    # no wire dtype -> pass-through, original dtype reported
    out, orig = _maybe_compress(buf, HaloSpec(mode=A2A))
    assert out is buf and orig == jnp.float32
    # bf16 wire -> converted, fp32 reported for the post-exchange restore
    out, orig = _maybe_compress(buf, HaloSpec(mode=A2A, wire_dtype=jnp.bfloat16))
    assert out.dtype == jnp.bfloat16 and orig == jnp.float32
    # wire dtype equal to the buffer dtype -> no conversion op emitted
    out, orig = _maybe_compress(buf, HaloSpec(mode=A2A, wire_dtype=jnp.float32))
    assert out is buf and orig == jnp.float32
    # quantization is real: a value not representable in bf16 round-trips lossy
    v = jnp.asarray([[1.0 + 2.0 ** -12]], jnp.float32)
    comp, _ = _maybe_compress(v, HaloSpec(mode=A2A, wire_dtype=jnp.bfloat16))
    assert float(comp.astype(jnp.float32)[0, 0]) != float(v[0, 0])


def test_halo_wire_bf16_compression_close():
    """bf16 on-wire halo (beyond-paper) stays within bf16 tolerance of f32."""
    mesh = box_mesh((4, 2, 2), p=2)
    pg = partition_mesh(mesh, (2, 2, 1))
    graph = ShardedGraph.build(pg, mesh.coords)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(pg.R, pg.n_pad, 8)).astype(np.float32))
    a = a * pg.node_mask[..., None]
    full = halo_sync_reference(a, graph, HaloSpec(mode=A2A))
    comp = halo_sync_reference(a, graph, HaloSpec(mode=A2A, wire_dtype=jnp.bfloat16))
    np.testing.assert_allclose(np.asarray(comp), np.asarray(full), rtol=2e-2, atol=2e-2)
    # and it actually changed something (quantization happened)
    assert float(jnp.abs(comp - full).max()) > 0


@pytest.mark.parametrize("combine", ["sum", "max"])
@pytest.mark.parametrize("mode_name", ["a2a", "neighbor", "rounds2d"])
def test_halo_wire_bf16_mode_combine_matrix(mode_name, combine):
    """Wire compression composes with every exchange topology and combine.

    The invariant under audit: masking happens BEFORE compression on the
    send side (``_wire_encode``), and the receive side re-masks with a
    fresh full-precision neutral — so the bf16-rounded ``max`` neutral
    (-1e30 -> ~-1.004e30) never reaches the combine, and padded rows never
    contribute a quantized zero to a ``sum``.  Each bf16 cell must stay
    within bf16 tolerance of its own full-precision mode AND of the A2A
    oracle (the topologies agree with each other, compressed or not)."""
    import dataclasses
    from repro.core import NEIGHBOR, halo_sync_stacked
    from repro.core.partition import (build_2d_halo_rounds,
                                      flat_rounds2d_perms,
                                      from_element_partition,
                                      pack, partition_elements)

    mesh = box_mesh((4, 4, 2), p=2)
    perms = None
    if mode_name == "rounds2d":
        Ga, Gb = 2, 2
        e2r = partition_elements(mesh, (Gb, Ga, 1))
        graphs = from_element_partition(mesh, e2r, Ga * Gb)
        pg = pack(graphs, mesh.n_nodes)
        rounds2d, nbr = build_2d_halo_rounds(graphs, (Ga, Gb),
                                             ("data", "model"))
        spec = HaloSpec(mode=NEIGHBOR, rounds2d=rounds2d)
        graph = ShardedGraph.build(pg, mesh.coords, NMPPlan(halo=spec))
        graph = graph.with_arrays(**{k: jnp.asarray(v)
                                     for k, v in nbr.items()})
        perms = flat_rounds2d_perms((Ga, Gb))
    else:
        pg = partition_mesh(mesh, (2, 2, 1))
        mode = A2A if mode_name == "a2a" else NEIGHBOR
        plan = NMPPlan.build(pg, mode)
        graph = ShardedGraph.build(pg, mesh.coords, plan)
        spec = plan.halo

    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.normal(size=(pg.R, pg.n_pad, 8)).astype(np.float32))
    a = a * jnp.asarray(pg.node_mask)[..., None]

    bf16 = dataclasses.replace(spec, wire_dtype=jnp.bfloat16)
    full = halo_sync_stacked(a, graph, spec, combine=combine,
                             rounds_perms=perms)
    comp = halo_sync_stacked(a, graph, bf16, combine=combine,
                             rounds_perms=perms)
    assert comp.dtype == full.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(comp), np.asarray(full),
                               rtol=2e-2, atol=2e-2)
    # quantization really happened on the wire
    assert float(jnp.abs(comp - full).max()) > 0
    # and stays consistent with the canonical-order A2A oracle: no masked
    # row leaked a compressed neutral/zero into the combine
    oracle = halo_sync_reference(a, graph, HaloSpec(mode=A2A),
                                 combine=combine)
    np.testing.assert_allclose(np.asarray(comp), np.asarray(oracle),
                               rtol=2e-2, atol=2e-2)


def test_elastic_checkpoint_restore_across_partitionings(tmp_path):
    """Params saved while training at R=4 restore and evaluate at R=2 with
    identical (consistent!) outputs — checkpoints are partition-independent."""
    mesh = box_mesh((4, 2, 2), p=2)
    cfg = GNNConfig.small()
    params = init_gnn(jax.random.PRNGKey(5), cfg)
    ckpt.save(tmp_path, 11, {"params": params})
    restored, _ = ckpt.restore(tmp_path, {"params": params})

    from repro.core.mesh_gen import taylor_green_velocity
    from repro.core.partition import scatter_node_outputs
    outs = {}
    for grid in ((2, 2, 1), (2, 1, 1)):
        pg = partition_mesh(mesh, grid)
        plan = NMPPlan(halo=HaloSpec(mode=A2A))
        graph = ShardedGraph.build(pg, mesh.coords, plan)
        x = jnp.asarray(gather_node_features(pg, taylor_green_velocity(mesh.coords)))
        y = gnn_forward_stacked(restored["params"], x, graph, plan)
        outs[grid] = scatter_node_outputs(pg, np.asarray(y))
    np.testing.assert_allclose(outs[(2, 2, 1)], outs[(2, 1, 1)], rtol=3e-5, atol=2e-6)


def test_consistent_node_reductions():
    mesh = box_mesh((2, 2), p=3)
    pg = partition_mesh(mesh, (2, 2))
    inv = jnp.asarray(pg.node_inv_mult)
    # N_eff equals the true global node count (Eq. 6c)
    total = sum(float(consistent_node_count(inv[r])) for r in range(pg.R))
    assert abs(total - mesh.n_nodes) < 1e-4
    # consistent sum of a global field equals the unpartitioned sum
    rng = np.random.default_rng(0)
    f = rng.normal(size=(mesh.n_nodes, 2)).astype(np.float32)
    per = gather_node_features(pg, f)
    s = sum(np.asarray(consistent_node_sum(jnp.asarray(per[r]), inv[r]))
            for r in range(pg.R))
    np.testing.assert_allclose(s, f.sum(axis=0), rtol=1e-4)


def test_sampler_block_meta_runs_through_gnn():
    from repro.graph.datasets import powerlaw_graph
    from repro.graph.sampler import CSRGraph, block_meta, sample_block
    from repro.models.gnn_zoo.gat import GATConfig, gat_forward, init_gat
    from repro.core.halo import NONE

    edges = powerlaw_graph(300, avg_deg=6, seed=4)
    g = CSRGraph.from_edges(300, edges)
    rng = np.random.default_rng(1)
    block = sample_block(g, rng.choice(300, 8, replace=False), (4, 3), rng)
    graph = ShardedGraph.from_arrays(
        {k: jnp.asarray(v) for k, v in block_meta(block).items()})
    cfg = GATConfig(in_dim=5, hidden=4, heads=2, n_classes=3, n_layers=2)
    params = init_gat(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(block.node_ids.shape[0], 5)).astype(np.float32))
    out = gat_forward(params, x, graph, HaloSpec(mode=NONE), cfg)
    assert np.isfinite(np.asarray(out)).all()
