"""Elastic fault-tolerant training: in-process tests.

Covers the resilience layer end-to-end on a single-device mesh (R = 1, so
everything runs in-process; real multi-device kill/resume lives in
tests/drivers/resilience_driver.py and the CI resilience leg):

  * crash -> restore -> replay is BITWISE identical to an uninterrupted run
    (one-step and K-rollout training, and run extension across calls);
  * elastic resume across a partitioner switch (block <-> spectral): the
    fingerprint records the change and the trajectory continues within
    consistency tolerance;
  * replay-critical fingerprint mismatches (different mesh, different seed)
    are rejected with an actionable error;
  * run_resilient recovers from ANY exception (not just InjectedFailure),
    applies bounded exponential backoff, and re-raises past max_restarts;
  * checkpoint hardening: template shape/key validation naming the bad key,
    checksum detection of corrupted shards with fallback to the previous
    committed step, prune never deleting the newest step, latest_step
    surviving leftover *.tmp debris;
  * seed primitives: AsyncCheckpointer error surfacing on wait(), crash
    mid-save leaving no COMMIT, StragglerMonitor EWMA threshold behavior.
"""
import json
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ckpt
from repro.core import GNNConfig, box_mesh, partition_mesh
from repro.launch.mesh import make_mesh
from repro.runtime.fault_tolerance import (
    FaultPlan, InjectedFailure, ResilientConfig, backoff_seconds,
    run_resilient,
)
from repro.runtime.straggler import StragglerMonitor
from repro.train.loop import TrainConfig, train_consistent_gnn


@pytest.fixture(scope="module")
def setup():
    sem = box_mesh((2, 2, 2), p=2)
    pg = partition_mesh(sem, (1, 1, 1))
    mesh_dev = make_mesh((1, 1), ("data", "graph"))
    cfg = GNNConfig(hidden=8, n_mp_layers=2)
    return sem, pg, mesh_dev, cfg


def _base(**kw):
    kw.setdefault("n_steps", 8)
    kw.setdefault("batch", 1)
    kw.setdefault("lr", 1e-3)
    kw.setdefault("halo_mode", "none")
    kw.setdefault("seed", 0)
    return TrainConfig(**kw)


def _rc(d, **kw):
    kw.setdefault("ckpt_every", 2)
    kw.setdefault("backoff_base", 0.001)
    return ResilientConfig(ckpt_dir=str(d), **kw)


# ---------------------------------------------------------------------------
# tentpole: resilient GNN training — bitwise recovery, elastic resume
# ---------------------------------------------------------------------------

def test_crash_recovery_bitwise_one_step(setup, tmp_path):
    """Injected crash at step 5 -> restore -> replay: bitwise == uninterrupted."""
    sem, pg, mesh_dev, cfg = setup
    ref = train_consistent_gnn(mesh_dev, pg, sem, cfg, _base())
    tcfg = _base(resilience=_rc(tmp_path))
    hist = train_consistent_gnn(mesh_dev, pg, sem, cfg, tcfg,
                                fault=FaultPlan(crash_at_step=5))
    assert hist["restarts"] == 1
    assert hist["resume_steps"] and hist["resume_steps"][0] <= 4
    assert hist["losses"] == ref["losses"]          # bitwise, incl. replay
    for a, b in zip(jax.tree.leaves(hist["params"]),
                    jax.tree.leaves(ref["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_crash_recovery_bitwise_rollout(setup, tmp_path):
    """Same guarantee on the K-rollout path (curriculum + pushforward noise)."""
    sem, pg, mesh_dev, cfg = setup
    kw = dict(rollout_curriculum=(1, 2), pushforward_noise=0.01,
              pushforward_noise_final=0.0)
    ref = train_consistent_gnn(mesh_dev, pg, sem, cfg, _base(**kw))
    tcfg = _base(**kw, resilience=_rc(tmp_path))
    hist = train_consistent_gnn(mesh_dev, pg, sem, cfg, tcfg,
                                fault=FaultPlan(crash_at_step=5))
    assert hist["restarts"] == 1
    assert hist["losses"] == ref["losses"]
    assert hist["rollout_k"] == ref["rollout_k"]


def test_resume_extends_run_bitwise(setup, tmp_path):
    """A completed 4-step resilient run resumed to 8 steps == one 8-step run."""
    sem, pg, mesh_dev, cfg = setup
    ref = train_consistent_gnn(mesh_dev, pg, sem, cfg, _base())
    train_consistent_gnn(mesh_dev, pg, sem, cfg,
                         _base(n_steps=4, resilience=_rc(tmp_path)))
    hist = train_consistent_gnn(mesh_dev, pg, sem, cfg,
                                _base(resilience=_rc(tmp_path)))
    assert hist["resume_steps"] == [3]
    assert hist["losses"] == ref["losses"]


@pytest.mark.parametrize("save_with,resume_with",
                         [("block", "spectral"), ("spectral", "block")])
def test_elastic_partitioner_switch(setup, tmp_path, save_with, resume_with):
    """Checkpoint under one partitioner, resume under the other: the
    fingerprint records the elastic change and the trajectory continues
    within Eq. 2/3 consistency tolerance."""
    sem, _, mesh_dev, cfg = setup
    ref = train_consistent_gnn(
        mesh_dev, partition_mesh(sem, (1, 1, 1), method=save_with), sem, cfg,
        _base(partitioner=save_with))
    train_consistent_gnn(
        mesh_dev, partition_mesh(sem, (1, 1, 1), method=save_with), sem, cfg,
        _base(n_steps=4, partitioner=save_with, resilience=_rc(tmp_path)))
    hist = train_consistent_gnn(
        mesh_dev, partition_mesh(sem, (1, 1, 1), method=resume_with), sem,
        cfg, _base(partitioner=resume_with, resilience=_rc(tmp_path)))
    el = hist["elastic"]
    assert el is not None and el["from_partitioner"] == save_with
    assert el["to_partitioner"] == resume_with and el["step"] == 4
    assert hist["losses"][:4] == ref["losses"][:4]      # restored prefix
    for a, b in zip(hist["losses"][4:], ref["losses"][4:]):
        assert abs(a - b) < 1e-6 * max(1.0, abs(b))


def test_replay_critical_mismatch_rejected(setup, tmp_path):
    """Resuming onto a different mesh or with a different seed is refused
    with an error naming the fingerprint field."""
    sem, pg, mesh_dev, cfg = setup
    train_consistent_gnn(mesh_dev, pg, sem, cfg,
                         _base(n_steps=4, resilience=_rc(tmp_path)))
    sem2 = box_mesh((2, 2, 2), p=3)                      # different problem
    pg2 = partition_mesh(sem2, (1, 1, 1))
    with pytest.raises(ValueError, match="mesh_hash"):
        train_consistent_gnn(mesh_dev, pg2, sem2, cfg,
                             _base(resilience=_rc(tmp_path)))
    with pytest.raises(ValueError, match="seed"):
        train_consistent_gnn(mesh_dev, pg, sem, cfg,
                             _base(seed=1, resilience=_rc(tmp_path)))


def test_mid_checkpoint_crash_recovers_bitwise(setup, tmp_path):
    """A save that dies before COMMIT surfaces via the async checkpointer,
    triggers a restart, and restore falls back past the half-written step."""
    sem, pg, mesh_dev, cfg = setup
    ref = train_consistent_gnn(mesh_dev, pg, sem, cfg, _base())
    tcfg = _base(resilience=_rc(tmp_path))
    hist = train_consistent_gnn(
        mesh_dev, pg, sem, cfg, tcfg,
        fault=FaultPlan(crash_save_at_step=4, save_stage="pre_commit"))
    assert hist["restarts"] >= 1
    assert hist["resume_steps"][0] < 4                   # fell back
    assert hist["losses"] == ref["losses"]


def test_corrupted_shard_falls_back_bitwise(setup, tmp_path):
    """Post-commit corruption is caught by checksum; restore falls back to
    the previous committed step and the replayed trajectory is bitwise."""
    sem, pg, mesh_dev, cfg = setup
    ref = train_consistent_gnn(mesh_dev, pg, sem, cfg, _base())
    train_consistent_gnn(mesh_dev, pg, sem, cfg,
                         _base(n_steps=5, resilience=_rc(tmp_path)))
    newest = ckpt.latest_step(tmp_path)
    assert newest == 4
    FaultPlan.corrupt_shard(tmp_path, newest)
    hist = train_consistent_gnn(mesh_dev, pg, sem, cfg,
                                _base(resilience=_rc(tmp_path)))
    assert hist["resume_steps"][0] < newest
    assert hist["losses"] == ref["losses"]


# ---------------------------------------------------------------------------
# satellite: run_resilient catch-all recovery + backoff
# ---------------------------------------------------------------------------

def _toy():
    def init_state():
        return {"w": jnp.zeros(4), "step": jnp.asarray(0)}

    def step_fn(state, batch):
        w = state["w"] + batch
        return {"w": w, "step": state["step"] + 1}, {"loss": float(w.sum())}

    def batch_fn(step):
        return jnp.full((4,), float(step % 7) * 0.25)

    return init_state, step_fn, batch_fn


def test_noninjected_failure_recovered(tmp_path):
    """A real crash (here: RuntimeError from the step fn) is recovered, not
    just the test-only InjectedFailure — regression for the seed bug where
    only InjectedFailure was caught."""
    init_state, step_fn, batch_fn = _toy()
    fired = []

    def flaky_step(state, batch):
        if int(state["step"]) == 9 and not fired:
            fired.append(1)
            raise RuntimeError("spurious OOM")
        return step_fn(state, batch)

    cfg = ResilientConfig(ckpt_dir=str(tmp_path), ckpt_every=4,
                          max_restarts=2, backoff_base=0.001)
    state, hist = run_resilient(init_state, flaky_step, batch_fn, 15, cfg)
    assert hist["restarts"] == 1
    ref = init_state()
    for s in range(15):
        ref, _ = step_fn(ref, batch_fn(s))
    np.testing.assert_array_equal(np.asarray(state["w"]), np.asarray(ref["w"]))
    # the history holds exactly one loss per step despite the replay
    assert len(hist["losses"]) == 15
    assert hist["backoffs"] == [0.001]


def test_persistent_failure_reraises_past_max_restarts(tmp_path):
    init_state, step_fn, batch_fn = _toy()

    def broken_step(state, batch):
        raise OSError("disk gone")

    cfg = ResilientConfig(ckpt_dir=str(tmp_path), ckpt_every=4,
                          max_restarts=2, backoff_base=0.001)
    with pytest.raises(OSError, match="disk gone"):
        run_resilient(init_state, broken_step, batch_fn, 10, cfg)


def test_backoff_is_bounded_exponential():
    cfg = ResilientConfig(backoff_base=0.5, backoff_max=3.0)
    assert [backoff_seconds(r, cfg) for r in (1, 2, 3, 4, 5)] == \
        [0.5, 1.0, 2.0, 3.0, 3.0]


# ---------------------------------------------------------------------------
# satellite: checkpoint hardening
# ---------------------------------------------------------------------------

def test_restore_names_mismatched_key(tmp_path):
    ckpt.save(tmp_path, 0, {"a": jnp.zeros((2, 3)), "b": jnp.ones(4)})
    with pytest.raises(ValueError, match="'a'"):
        ckpt.restore(tmp_path, {"a": jnp.zeros((3, 2)), "b": jnp.ones(4)})
    with pytest.raises(ValueError, match="extra"):
        ckpt.restore(tmp_path, {"a": jnp.zeros((2, 3)), "b": jnp.ones(4),
                                "extra": jnp.zeros(1)})


def test_corrupted_shard_detected_and_fallback(tmp_path):
    tree = {"w": jnp.arange(64, dtype=jnp.float32)}
    ckpt.save(tmp_path, 0, tree)
    ckpt.save(tmp_path, 5, {"w": tree["w"] + 1})
    FaultPlan.corrupt_shard(tmp_path, 5)
    with pytest.raises(ckpt.CheckpointCorruption):
        ckpt.restore(tmp_path, tree, step=5)
    restored, manifest = ckpt.restore_with_fallback(tmp_path, tree)
    assert manifest["step"] == 0
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    # every committed step corrupted -> FileNotFoundError, not a crash
    FaultPlan.corrupt_shard(tmp_path, 0)
    with pytest.raises(FileNotFoundError, match="all corrupted"):
        ckpt.restore_with_fallback(tmp_path, tree)


def test_prune_never_deletes_newest(tmp_path):
    for s in (0, 5, 10):
        ckpt.save(tmp_path, s, {"x": jnp.full(3, float(s))})
    ckpt.prune(tmp_path, keep=0)                        # misconfigured
    assert ckpt.committed_steps(tmp_path) == [10]
    ckpt.prune(tmp_path, keep=-3)
    assert ckpt.committed_steps(tmp_path) == [10]


def test_latest_step_survives_tmp_debris(tmp_path):
    ckpt.save(tmp_path, 3, {"x": jnp.zeros(2)})
    # a crash mid-save leaves step_*.tmp behind; it must not break scanning
    (tmp_path / "step_0000000007.tmp").mkdir()
    (tmp_path / "garbage").mkdir()
    assert ckpt.latest_step(tmp_path) == 3
    ckpt.prune(tmp_path, keep=1)
    assert ckpt.latest_step(tmp_path) == 3


# ---------------------------------------------------------------------------
# satellite: seed primitives — async errors, no-COMMIT saves, straggler EWMA
# ---------------------------------------------------------------------------

def test_async_checkpointer_surfaces_error_on_wait(tmp_path):
    target = tmp_path / "cannot_mkdir"
    target.write_text("a file where the ckpt dir should be")
    saver = ckpt.AsyncCheckpointer(target)
    saver.save(0, {"x": jnp.zeros(2)})
    with pytest.raises(Exception):
        saver.wait()
    assert saver.last_error is None          # consumed, not sticky


def test_crash_mid_save_leaves_no_commit(tmp_path):
    ckpt.save(tmp_path, 0, {"x": jnp.zeros(2)})
    plan = FaultPlan(crash_save_at_step=5, save_stage="pre_commit")
    with plan.installed():
        with pytest.raises(InjectedFailure):
            ckpt.save(tmp_path, 5, {"x": jnp.ones(2)})
    assert ckpt.latest_step(tmp_path) == 0   # half-written step is invisible
    assert (tmp_path / "step_0000000005.tmp").exists()
    assert not (tmp_path / "step_0000000005.tmp" / "COMMIT").exists()


def test_truncated_shard_detected(tmp_path):
    ckpt.save(tmp_path, 0, {"x": jnp.arange(128, dtype=jnp.float32)})
    plan = FaultPlan(crash_save_at_step=3, save_stage="truncate_shard")
    with plan.installed():
        with pytest.raises(InjectedFailure):
            ckpt.save(tmp_path, 3, {"x": jnp.arange(128, dtype=jnp.float32)})
    assert ckpt.latest_step(tmp_path) == 0
    restored, manifest = ckpt.restore_with_fallback(
        tmp_path, {"x": jnp.zeros(128)})
    assert manifest["step"] == 0


def test_manifest_carries_checksums_and_extra(tmp_path):
    ckpt.save(tmp_path, 2, {"x": jnp.arange(4, dtype=jnp.float32)},
              extra={"fingerprint": {"ranks": 2}})
    m = ckpt.peek_manifest(tmp_path)
    assert m["step"] == 2
    assert set(m["checksums"]) == {"x"}
    assert m["extra"]["fingerprint"]["ranks"] == 2
    # manifests stay plain JSON (no numpy leakage)
    json.dumps(m)


def test_straggler_ewma_threshold_behavior():
    mon = StragglerMonitor(alpha=0.1, k_std=4.0, slack=1.5, warmup_steps=5)
    # during warmup nothing fires, even for an extreme outlier
    for s in range(4):
        assert mon.observe(s, 0.1) is None
    assert mon.observe(4, 5.0) is None                  # n == warmup
    mon2 = StragglerMonitor(alpha=0.1, k_std=4.0, slack=1.5, warmup_steps=3)
    for s in range(10):
        mon2.observe(s, 0.1)
    base_mean = mon2.mean
    # above k_std*std but below slack*mean -> not an outlier
    assert mon2.observe(10, 0.12) is None
    # far beyond both thresholds -> event, and EWMA excludes it
    ev = mon2.observe(11, 2.0)
    assert ev is not None and ev.step == 11
    assert mon2.mean < base_mean * 1.5
    # end_step without start_step (post-crash restart) is a no-op
    assert mon2.end_step(12) is None
    mon2.reset()
    assert mon2.mean is None and mon2.n == 0 and len(mon2.events) == 1


# ---------------------------------------------------------------------------
# preemption: SIGTERM -> early checkpoint -> clean exit -> resume
# ---------------------------------------------------------------------------

_PREEMPT_CHILD = r"""
import json, os, sys
import jax.numpy as jnp
from repro.runtime.fault_tolerance import ResilientConfig, run_resilient

ckpt_dir, out_path = sys.argv[1], sys.argv[2]

def init_state():
    return {"w": jnp.zeros(4), "step": jnp.asarray(0)}

def step_fn(state, batch):
    import time
    time.sleep(0.05)                      # slow enough to be hit mid-run
    w = state["w"] + batch
    return {"w": w, "step": state["step"] + 1}, {"loss": float(w.sum())}

def batch_fn(step):
    return jnp.full((4,), float(step % 7) * 0.25)

cfg = ResilientConfig(ckpt_dir=ckpt_dir, ckpt_every=1000)  # never periodic
print("READY", flush=True)
state, hist = run_resilient(init_state, step_fn, batch_fn, 10000, cfg)
with open(out_path, "w") as f:
    json.dump({"preempted_at": hist["preempted_at"],
               "n_losses": len(hist["losses"])}, f)
"""


def test_sigterm_preemption_checkpoints_and_resumes(tmp_path):
    """SIGTERM mid-run: the child commits an early 'preempted' checkpoint,
    exits 0 (clean return, not a signal death), and a fresh run_resilient
    resumes from exactly the preempted step with a continuous bitwise
    history — the zero-lost-work eviction path."""
    import signal as _signal
    import subprocess
    import sys
    import time as _time

    ckpt_dir = tmp_path / "ckpt"
    out_path = tmp_path / "hist.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    proc = subprocess.Popen(
        [sys.executable, "-c", _PREEMPT_CHILD, str(ckpt_dir), str(out_path)],
        stdout=subprocess.PIPE, env=env, text=True)
    assert proc.stdout.readline().strip() == "READY"
    _time.sleep(0.5)                      # let a few 50 ms steps land
    proc.send_signal(_signal.SIGTERM)
    assert proc.wait(timeout=60) == 0     # clean return, not -SIGTERM

    hist = json.loads(out_path.read_text())
    step = hist["preempted_at"]
    assert step is not None and hist["n_losses"] == step + 1
    # the early checkpoint is committed and carries the preemption reason
    assert ckpt.latest_step(ckpt_dir) == step
    _, manifest = ckpt.restore_with_fallback(
        ckpt_dir, {"w": jnp.zeros(4), "step": jnp.asarray(0)})
    assert manifest["extra"]["reason"] == "preempted"

    # the relaunch resumes from the preempted step and finishes the run
    init_state, step_fn, batch_fn = _toy()
    n_steps = step + 5
    state, hist2 = run_resilient(init_state, step_fn, batch_fn, n_steps,
                                 _rc(ckpt_dir, ckpt_every=1000))
    assert hist2["resume_steps"] == [step]
    ref_state, ref = run_resilient(init_state, step_fn, batch_fn, n_steps,
                                   _rc(tmp_path / "ref"))
    assert hist2["losses"] == ref["losses"]          # bitwise incl. replay
    np.testing.assert_array_equal(np.asarray(state["w"]),
                                  np.asarray(ref_state["w"]))


def test_preemption_guard_restores_previous_handler():
    """The guard is scoped: inside, SIGTERM sets the flag without killing
    the process; after exit, the previous handler is back in place."""
    import signal as _signal
    from repro.runtime.fault_tolerance import preemption_guard

    prev = _signal.getsignal(_signal.SIGTERM)
    with preemption_guard() as flag:
        assert not flag["preempted"]
        os.kill(os.getpid(), _signal.SIGTERM)
        assert flag["preempted"] and flag["signum"] == _signal.SIGTERM
    assert _signal.getsignal(_signal.SIGTERM) == prev
    # disabled guard installs nothing
    with preemption_guard(enabled=False) as flag:
        assert _signal.getsignal(_signal.SIGTERM) == prev
        assert not flag["preempted"]
