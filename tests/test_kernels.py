"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracles."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.segment_agg.ops import dst_aligned_layout, fused_edge_mlp_agg
from repro.kernels.segment_agg.ref import edge_mlp_agg_ref
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # B, Sq, Skv, Hq, Hkv, D, causal, window, softcap, bq, bk
    (1, 128, 128, 2, 2, 64, True, 0, None, 32, 32),
    (2, 96, 96, 4, 2, 32, True, 0, None, 32, 16),
    (1, 160, 160, 2, 1, 64, True, 48, None, 32, 32),
    (1, 64, 64, 2, 2, 128, False, 0, 30.0, 32, 32),
    (1, 72, 72, 1, 1, 16, True, 0, None, 16, 16),   # non-multiple seq
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(case, dtype):
    B, Sq, Skv, Hq, Hkv, D, caus, win, cap, bq, bk = case
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, Sq, Hq, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Skv, Hkv, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Skv, Hkv, D)), dtype)
    out = flash_attention(q, k, v, scale=D ** -0.5, causal=caus, window=win,
                          softcap=cap, block_q=bq, block_k=bk, interpret=True)
    G = Hq // Hkv
    ref = attention_ref(
        q.transpose(0, 2, 1, 3),
        jnp.repeat(k.transpose(0, 2, 1, 3), G, 1),
        jnp.repeat(v.transpose(0, 2, 1, 3), G, 1),
        scale=D ** -0.5, causal=caus, window=win, softcap=cap,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


# ---------------------------------------------------------------------------
# fused edge-MLP + segment aggregation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_segment_agg_random_graphs(seed, dtype):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(20, 90))
    E = int(rng.integers(50, 400))
    fin, hid = 24, 16
    block_n, block_e = 16, 32
    dst = rng.integers(0, n, E)
    feats = rng.normal(size=(E, fin)).astype(np.float32)
    wgt = rng.uniform(0.5, 1.0, E).astype(np.float32)
    w1 = rng.normal(size=(fin, hid)).astype(np.float32) * 0.2
    b1 = rng.normal(size=(hid,)).astype(np.float32) * 0.1
    w2 = rng.normal(size=(hid, hid)).astype(np.float32) * 0.2
    b2 = rng.normal(size=(hid,)).astype(np.float32) * 0.1

    layout = dst_aligned_layout(dst, n, block_n, block_e)
    e_new, agg = fused_edge_mlp_agg(
        jnp.asarray(feats, dtype), jnp.asarray(dst, jnp.int32), jnp.asarray(wgt),
        jnp.asarray(w1), jnp.asarray(b1), jnp.asarray(w2), jnp.asarray(b2),
        layout, n_nodes=n, block_n=block_n, block_e=block_e, interpret=True)

    e_ref, agg_ref = edge_mlp_agg_ref(
        jnp.asarray(feats), jnp.asarray(w1), jnp.asarray(b1), jnp.asarray(w2),
        jnp.asarray(b2), jnp.asarray(dst), jnp.asarray(wgt), n)
    np.testing.assert_allclose(np.asarray(e_new), np.asarray(e_ref),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(agg)[:n], np.asarray(agg_ref),
                               rtol=1e-4, atol=1e-4)


def test_segment_agg_mesh_graph_low_waste():
    """Bounded-degree SEM mesh graphs tile tightly under dst alignment."""
    from repro.core.mesh_gen import box_mesh, mesh_graph_edges, undirected_to_directed
    m = box_mesh((4, 4, 2), p=3)
    e = undirected_to_directed(mesh_graph_edges(m))
    layout = dst_aligned_layout(e[:, 1], m.n_nodes, 128, 256)
    assert layout["waste"] < 0.6


@pytest.mark.parametrize("seed", range(3))
def test_dst_aligned_layout_properties(seed):
    """Vectorized layout pass: every in-range edge appears exactly once, in
    the node block owning its dst; out-of-range (sentinel) edges are dropped;
    dstl is the block-local dst."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(10, 70))
    E = int(rng.integers(20, 300))
    block_n, block_e = 16, 8
    dst = rng.integers(0, n + 5, E)          # some >= n -> dropped
    layout = dst_aligned_layout(dst, n, block_n, block_e)
    perm, dstl = layout["perm"], layout["dstl"]
    kept = np.sort(perm[perm >= 0])
    np.testing.assert_array_equal(kept, np.nonzero(dst < n)[0])
    for b in range(layout["n_node_blocks"]):
        sel = perm[b][perm[b] >= 0]
        assert ((dst[sel] >= b * block_n) & (dst[sel] < (b + 1) * block_n)).all()
        np.testing.assert_array_equal(dstl[b][perm[b] >= 0],
                                      dst[sel] - b * block_n)
    assert (dstl[perm < 0] == 0).all()
    assert 0.0 <= layout["waste"] < 1.0


def _random_nmp_case(seed, n_hidden=2, final_layernorm=True):
    from repro import nn
    rng = np.random.default_rng(seed)
    n, E, H = int(rng.integers(20, 60)), int(rng.integers(40, 200)), 8
    src = rng.integers(0, n, E)
    dst = rng.integers(0, n, E)
    emask = (rng.uniform(size=E) > 0.1).astype(np.float32)
    einv = rng.uniform(0.3, 1.0, E).astype(np.float32) * emask
    x = jnp.asarray(rng.normal(size=(n, H)), jnp.float32)
    e = jnp.asarray(rng.normal(size=(E, H)), jnp.float32)
    params = nn.init_mlp(jax.random.PRNGKey(seed), 3 * H, [H] * n_hidden, H,
                         final_layernorm=final_layernorm)
    meta = dict(edge_src=jnp.asarray(src, jnp.int32),
                edge_dst=jnp.asarray(dst, jnp.int32),
                edge_mask=jnp.asarray(emask), edge_inv_mult=jnp.asarray(einv))
    return n, dst, emask, x, e, params, meta


@pytest.mark.parametrize("seed", range(3))
@pytest.mark.parametrize("n_hidden,ln", [(2, True), (0, False)])
def test_fused_nmp_forward_and_custom_vjp_gradcheck(seed, n_hidden, ln):
    """The custom-VJP fused op matches jax.grad of the XLA reference path
    (interpret mode), for deep+LN and single-layer no-LN edge MLPs."""
    from repro.graph import segment
    from repro import nn
    from repro.kernels.segment_agg.ops import fused_nmp_edge_agg

    n, dst, emask, x, e, params, meta = _random_nmp_case(seed, n_hidden, ln)
    block_n, block_e = 16, 32
    layout = dst_aligned_layout(
        np.where(emask > 0, dst, n), n, block_n, block_e)
    perm = jnp.asarray(layout["perm"])
    dstl = jnp.asarray(layout["dstl"])

    def xla_path(p, x, e):
        xi = segment.gather(x, meta["edge_src"])
        xj = segment.gather(x, meta["edge_dst"])
        e_new = (e + nn.mlp(p, jnp.concatenate([xi, xj, e], -1))) \
            * meta["edge_mask"][:, None]
        agg = segment.segment_sum(e_new * meta["edge_inv_mult"][:, None],
                                  meta["edge_dst"], n)
        return e_new, agg

    def fused_path(p, x, e):
        return fused_nmp_edge_agg(
            x, e, p, perm, dstl, meta["edge_src"], meta["edge_mask"],
            meta["edge_inv_mult"], block_n=block_n, interpret=True)

    o_x = jax.jit(xla_path)(params, x, e)
    o_f = jax.jit(fused_path)(params, x, e)
    for a, b in zip(o_x, o_f):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-4, atol=1e-5)

    def scalar(fn):
        def L(p, x, e):
            en, ag = fn(p, x, e)
            return jnp.sum(jnp.sin(en)) + jnp.sum(ag * jnp.cos(ag))
        return L

    g_x = jax.jit(jax.grad(scalar(xla_path), argnums=(0, 1, 2)))(params, x, e)
    g_f = jax.jit(jax.grad(scalar(fused_path), argnums=(0, 1, 2)))(params, x, e)
    for a, b in zip(jax.tree.leaves(g_x), jax.tree.leaves(g_f)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-3, atol=2e-4)


# ---------------------------------------------------------------------------
# embedding bag
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 4, 64, 32), (16, 1, 256, 16), (4, 8, 128, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_sweep(shape, dtype):
    B, H, V, D = shape
    rng = np.random.default_rng(1)
    table = jnp.asarray(rng.normal(size=(V, D)), dtype)
    idx = jnp.asarray(rng.integers(0, V, (B, H)), jnp.int32)
    out = embedding_bag(table, idx, interpret=True)
    ref = embedding_bag_ref(table, idx)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])
