"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracles."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.segment_agg.ops import dst_aligned_layout, fused_edge_mlp_agg
from repro.kernels.segment_agg.ref import edge_mlp_agg_ref
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # B, Sq, Skv, Hq, Hkv, D, causal, window, softcap, bq, bk
    (1, 128, 128, 2, 2, 64, True, 0, None, 32, 32),
    (2, 96, 96, 4, 2, 32, True, 0, None, 32, 16),
    (1, 160, 160, 2, 1, 64, True, 48, None, 32, 32),
    (1, 64, 64, 2, 2, 128, False, 0, 30.0, 32, 32),
    (1, 72, 72, 1, 1, 16, True, 0, None, 16, 16),   # non-multiple seq
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(case, dtype):
    B, Sq, Skv, Hq, Hkv, D, caus, win, cap, bq, bk = case
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, Sq, Hq, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Skv, Hkv, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Skv, Hkv, D)), dtype)
    out = flash_attention(q, k, v, scale=D ** -0.5, causal=caus, window=win,
                          softcap=cap, block_q=bq, block_k=bk, interpret=True)
    G = Hq // Hkv
    ref = attention_ref(
        q.transpose(0, 2, 1, 3),
        jnp.repeat(k.transpose(0, 2, 1, 3), G, 1),
        jnp.repeat(v.transpose(0, 2, 1, 3), G, 1),
        scale=D ** -0.5, causal=caus, window=win, softcap=cap,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


# ---------------------------------------------------------------------------
# fused edge-MLP + segment aggregation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_segment_agg_random_graphs(seed, dtype):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(20, 90))
    E = int(rng.integers(50, 400))
    fin, hid = 24, 16
    block_n, block_e = 16, 32
    dst = rng.integers(0, n, E)
    feats = rng.normal(size=(E, fin)).astype(np.float32)
    wgt = rng.uniform(0.5, 1.0, E).astype(np.float32)
    w1 = rng.normal(size=(fin, hid)).astype(np.float32) * 0.2
    b1 = rng.normal(size=(hid,)).astype(np.float32) * 0.1
    w2 = rng.normal(size=(hid, hid)).astype(np.float32) * 0.2
    b2 = rng.normal(size=(hid,)).astype(np.float32) * 0.1

    layout = dst_aligned_layout(dst, n, block_n, block_e)
    e_new, agg = fused_edge_mlp_agg(
        jnp.asarray(feats, dtype), jnp.asarray(dst, jnp.int32), jnp.asarray(wgt),
        jnp.asarray(w1), jnp.asarray(b1), jnp.asarray(w2), jnp.asarray(b2),
        layout, n_nodes=n, block_n=block_n, block_e=block_e, interpret=True)

    e_ref, agg_ref = edge_mlp_agg_ref(
        jnp.asarray(feats), jnp.asarray(w1), jnp.asarray(b1), jnp.asarray(w2),
        jnp.asarray(b2), jnp.asarray(dst), jnp.asarray(wgt), n)
    np.testing.assert_allclose(np.asarray(e_new), np.asarray(e_ref),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(agg)[:n], np.asarray(agg_ref),
                               rtol=1e-4, atol=1e-4)


def test_segment_agg_mesh_graph_low_waste():
    """Bounded-degree SEM mesh graphs tile tightly under dst alignment."""
    from repro.core.mesh_gen import box_mesh, mesh_graph_edges, undirected_to_directed
    m = box_mesh((4, 4, 2), p=3)
    e = undirected_to_directed(mesh_graph_edges(m))
    layout = dst_aligned_layout(e[:, 1], m.n_nodes, 128, 256)
    assert layout["waste"] < 0.6


# ---------------------------------------------------------------------------
# embedding bag
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 4, 64, 32), (16, 1, 256, 16), (4, 8, 128, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_sweep(shape, dtype):
    B, H, V, D = shape
    rng = np.random.default_rng(1)
    table = jnp.asarray(rng.normal(size=(V, D)), dtype)
    idx = jnp.asarray(rng.integers(0, V, (B, H)), jnp.int32)
    out = embedding_bag(table, idx, interpret=True)
    ref = embedding_bag_ref(table, idx)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])
